"""Benchmark harness: one module per paper table/figure + framework benches.

``PYTHONPATH=src python -m benchmarks.run [--scale S] [--only t2,t3,...]``

Prints ``name,us_per_call,derived`` CSV rows (one per measurement) followed
by per-table human summaries. Results also land in results/bench.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=float(os.environ.get("BENCH_SCALE", 0.25)))
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from . import (
        bench_configs,
        bench_delta_bits,
        bench_filter,
        bench_kernels,
        bench_pipeline,
        bench_rw_time,
        bench_storage,
    )

    modules = {
        "t2_storage": bench_storage,
        "t3_rw_time": bench_rw_time,
        "f8_delta_bits": bench_delta_bits,
        "f9f10_configs": bench_configs,
        "f11_filter": bench_filter,
        "kernels": bench_kernels,
        "pipeline": bench_pipeline,
    }
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    all_rows = {}
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            rows = mod.run(scale=args.scale)
        except Exception as e:  # keep the harness alive; report the failure
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            import traceback
            traceback.print_exc(file=sys.stderr)
            continue
        dt = time.perf_counter() - t0
        all_rows[name] = rows
        for r in rows:
            n = r.get("name") or f"{r.get('table','')}/{r.get('dataset','')}/" \
                                 f"{r.get('fmt', r.get('order', r.get('sort','')))}" \
                                 f"/{r.get('codec', r.get('query', r.get('encoding','')))}"
            us = 1e6 * float(r.get("s", r.get("write_s", 0.0)) or 0.0)
            derived = ";".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in r.items()
                if k not in ("table", "dataset", "name", "s", "write_s")
            )
            print(f"{n},{us:.1f},{derived}", flush=True)
        print(f"# {name} done in {dt:.1f}s", flush=True)

    print()
    for name, mod in modules.items():
        if name in all_rows and hasattr(mod, "summarize"):
            for line in mod.summarize(all_rows[name]):
                print(line)

    os.makedirs("results", exist_ok=True)
    with open("results/bench.json", "w") as fh:
        json.dump(all_rows, fh, indent=1, default=str)
    print("\n[bench] saved results/bench.json")


if __name__ == "__main__":
    main()

"""Paper Figures 9+10: SpatialParquet configuration sweep.

Fig 9a: FP-delta vs raw, +- gzip, source order (eB shows no gain unsorted).
Fig 9b: same after Hilbert sorting.
Fig 10: encoding + sorting overhead at write time.
"""

from __future__ import annotations

import os

from repro.core.writer import write_file

from .common import file_mb, make_dataset, timer, tmppath


def run(scale: float = 1.0, datasets=("PT", "TR", "MB", "eB")) -> list[dict]:
    rows = []
    for ds in datasets:
        cols = make_dataset(ds, scale)
        for sort in (None, "hilbert", "z"):
            for enc in ("fp_delta", "raw"):
                for codec in ("none", "gzip"):
                    p = tmppath(".spqf")
                    with timer() as t:
                        write_file(p, columns=cols, sort=sort, encoding=enc, codec=codec)
                    rows.append(dict(
                        table="F9F10", dataset=ds, sort=sort or "source",
                        encoding=enc, codec=codec, mb=file_mb(p), write_s=t["s"],
                    ))
                    os.unlink(p)
    return rows


def summarize(rows) -> list[str]:
    out = ["# Figures 9/10: size MB & write s by (sort, encoding, codec)"]
    for r in rows:
        out.append(
            f"F9 {r['dataset']}/{r['sort']}/{r['encoding']}/{r['codec']}: "
            f"{r['mb']:.1f}MB {r['write_s']:.2f}s"
        )
    return out

"""Paper Table 2: output size in MB with/without compression, 4 formats x 4
datasets. Expectation from the paper: SpatialParquet(FP-delta) smallest
uncompressed by ~2-4x; GeoJSON largest uncompressed but competitive gzipped
(whole-file gzip); WKB-based formats in between."""

from __future__ import annotations

import os

from repro.baselines.geojson_format import write_geojson
from repro.baselines.geoparquet_like import GeoParquetLikeWriter
from repro.baselines.shapefile import write_shapefile
from repro.core.writer import write_file

from .common import dataset_geometries, file_mb, make_dataset, timer, tmppath


def run(scale: float = 1.0, datasets=("PT", "TR", "MB", "eB")) -> list[dict]:
    rows = []
    for ds in datasets:
        cols = make_dataset(ds, scale, sort="hilbert")
        geoms = dataset_geometries(cols)
        npts = cols.n_values
        for codec, tag in (("none", "uncompressed"), ("gzip", "gzip")):
            # --- SpatialParquet (hilbert-sorted, like the paper's §5.1 setup)
            p = tmppath(".spqf")
            with timer() as t:
                write_file(p, columns=cols, sort=None, codec=codec,
                           row_group_records=1 << 20)
            rows.append(dict(table="T2", dataset=ds, fmt="spatialparquet",
                             codec=tag, mb=file_mb(p), write_s=t["s"], n_points=npts))
            os.unlink(p)
            # --- GeoParquet-like (WKB + MBR columns)
            p = tmppath(".gpq")
            with timer() as t:
                with GeoParquetLikeWriter(p, codec=codec) as w:
                    w.write_geometries(geoms)
            rows.append(dict(table="T2", dataset=ds, fmt="geoparquet",
                             codec=tag, mb=file_mb(p), write_s=t["s"], n_points=npts))
            os.unlink(p)
            # --- Shapefile (gzip applied per part file, as in the paper)
            p = tmppath(".shp")
            with timer() as t:
                write_shapefile(p, geoms)
                if codec == "gzip":
                    import gzip as _gz
                    blob = _gz.compress(open(p, "rb").read(), 6)
                    open(p, "wb").write(blob)
            rows.append(dict(table="T2", dataset=ds, fmt="shapefile",
                             codec=tag, mb=file_mb(p), write_s=t["s"], n_points=npts))
            os.unlink(p)
            # --- GeoJSON (whole-file gzip)
            p = tmppath(".geojson")
            with timer() as t:
                write_geojson(p, geoms, gz=(codec == "gzip"))
            rows.append(dict(table="T2", dataset=ds, fmt="geojson",
                             codec=tag, mb=file_mb(p), write_s=t["s"], n_points=npts))
            os.unlink(p)
    return rows


def summarize(rows) -> list[str]:
    out = ["# Table 2: size MB (uncompressed | gzip)"]
    for ds in ("PT", "TR", "MB", "eB"):
        line = [f"T2 {ds}:"]
        for fmt in ("spatialparquet", "geoparquet", "shapefile", "geojson"):
            u = next((r["mb"] for r in rows if r["dataset"] == ds and r["fmt"] == fmt
                      and r["codec"] == "uncompressed"), None)
            g = next((r["mb"] for r in rows if r["dataset"] == ds and r["fmt"] == fmt
                      and r["codec"] == "gzip"), None)
            if u is not None:
                line.append(f"{fmt}={u:.1f}|{g:.1f}")
        out.append(" ".join(line))
    return out

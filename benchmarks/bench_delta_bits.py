"""Paper Figure 8: histogram of deltas needing >= n significant bits,
sorted (Hilbert / Z) vs source order, for the eB and MB analogs.

Reproduces the paper's two claims: (a) the unsorted eBird spike at 64 bits
(alternating signs) disappears under SFC sorting; (b) MSBuildings benefits
less (already regionally clustered)."""

from __future__ import annotations

import numpy as np

from repro.core.fp_delta import compute_best_delta_bits, delta_bit_histogram
from repro.core.writer import permute_records, record_centroids
from repro.core.sfc import sort_keys

from .common import make_dataset


def _suffix_hist(x) -> np.ndarray:
    h = delta_bit_histogram(x)
    return np.cumsum(h[::-1])[::-1]  # h[n] = #deltas needing >= n bits


def run(scale: float = 1.0, datasets=("eB", "MB")) -> list[dict]:
    rows = []
    for ds in datasets:
        cols = make_dataset(ds, scale)
        variants = {"source": cols}
        for method in ("hilbert", "z"):
            cx, cy = record_centroids(cols)
            keys = sort_keys(cx, cy, method)
            variants[method] = permute_records(cols, np.argsort(keys, kind="stable"))
        for name, v in variants.items():
            sh = _suffix_hist(v.x)
            nstar = compute_best_delta_bits(v.x)
            rows.append(dict(
                table="F8", dataset=ds, order=name, n_star=nstar,
                ge32=int(sh[32]), ge48=int(sh[48]), eq64=int(sh[64]),
                total=int(sh[1]),
                spike64_frac=float(sh[64] / max(sh[1], 1)),
            ))
    return rows


def summarize(rows) -> list[str]:
    out = ["# Figure 8: deltas needing >=n bits (x column)"]
    for r in rows:
        out.append(
            f"F8 {r['dataset']}/{r['order']}: n*={r['n_star']} "
            f">=32b={r['ge32']} >=48b={r['ge48']} =64b={r['eq64']} "
            f"(64b spike {100*r['spike64_frac']:.2f}%)"
        )
    return out

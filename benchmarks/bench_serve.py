"""Serve-tier smoke benchmark: concurrent bbox queries with shared decodes.

For each query count (default 1, 16, 256) this builds a fresh
:class:`~repro.serve.query_scheduler.SpatialQueryServer` over a sharded PT
dataset, submits that many overlapping bbox queries, drains them in
admission waves, and records the per-query latency histogram percentiles
(``serve_p50_s``/``serve_p99_s``, from the ``serve.query_latency_s`` obs
histogram — the serving view: tails, not the floor) plus the
``shared_decode_ratio`` (row-group touches per actual decode: how many solo
decodes one shared decode replaced; at 256 queries it shows each surviving
row group decoded once per wave). ``sequential_s`` times the same queries as
solo ``scanner.scan`` calls for the unshared baseline.

Results merge into the smoke benchmark's JSON (default ``BENCH_read.json``)
under the ``"serve"`` key, so CI keeps one perf-trajectory artifact::

    PYTHONPATH=src python -m benchmarks.smoke --out BENCH_read.json
    PYTHONPATH=src python -m benchmarks.bench_serve --out BENCH_read.json
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

from repro import obs
from repro.dataset import SpatialDatasetScanner, write_dataset
from repro.serve.query_scheduler import SpatialQueryServer

from .common import make_dataset
from .smoke import selectivity_bbox

# selectivity targets the query mix cycles through (overlapping central
# boxes, so concurrent queries share row groups)
QUERY_FRACS = (0.01, 0.05, 0.10, 0.25, 0.50)


def _query_boxes(geo, n: int) -> list:
    return [selectivity_bbox(geo, QUERY_FRACS[i % len(QUERY_FRACS)])
            for i in range(n)]


def run(scale: float = 0.1, dataset: str = "PT", n_shards: int = 4,
        query_counts=(1, 16, 256), device: str = "cpu",
        max_wave: int = 64) -> dict:
    cols = make_dataset(dataset, scale, sort="hilbert")
    droot = tempfile.mkdtemp(prefix="bench_serve_")
    try:
        write_dataset(droot, columns=cols, n_shards=n_shards, sort="hilbert",
                      codec="none")
        sc = SpatialDatasetScanner(droot)
        geo, _, _ = sc.scan()
        rows = []
        for n_q in query_counts:
            boxes = _query_boxes(geo, n_q)
            # warm-up: compile/populate off the clock, then a fresh server
            # and a fresh metrics registry per count
            with SpatialQueryServer(sc, device=device,
                                    max_wave=max_wave) as warm:
                warm.submit(boxes[0])
                warm.run()
            obs.enable()
            try:
                with SpatialQueryServer(sc, device=device,
                                        max_wave=max_wave) as srv:
                    t0 = time.perf_counter()
                    for b in boxes:
                        srv.submit(b)
                    srv.run()
                    served_s = time.perf_counter() - t0
                    pcts = obs.percentiles("serve.query_latency_s")
                    m = srv.metrics()
            finally:
                obs.disable()
            t0 = time.perf_counter()
            for b in boxes:
                sc.scan(bbox=b, refine=True, device=device, parallel=False)
            sequential_s = time.perf_counter() - t0
            rows.append({
                "queries": n_q,
                "serve_p50_s": round(pcts.get("p50", 0.0), 6),
                "serve_p99_s": round(pcts.get("p99", 0.0), 6),
                "served_s": round(served_s, 6),
                "sequential_s": round(sequential_s, 6),
                "waves": m["waves"],
                "rg_touches": m["rg_touches"],
                "rg_decodes": m["rg_decodes"],
                "shared_decode_ratio": round(m["shared_decode_ratio"], 3),
            })
    finally:
        shutil.rmtree(droot, ignore_errors=True)
    return {
        "dataset": dataset,
        "scale": scale,
        "device": device,
        "n_shards": n_shards,
        "max_wave": max_wave,
        "by_query_count": rows,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--dataset", default="PT")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--device", default="cpu", choices=("cpu", "jax"))
    ap.add_argument("--queries", type=int, nargs="+", default=[1, 16, 256])
    ap.add_argument("--max-wave", type=int, default=64)
    ap.add_argument("--out", default="BENCH_read.json",
                    help="merge results under the 'serve' key of this JSON")
    args = ap.parse_args()
    result = run(scale=args.scale, dataset=args.dataset, n_shards=args.shards,
                 query_counts=tuple(args.queries), device=args.device,
                 max_wave=args.max_wave)
    merged = {}
    if os.path.exists(args.out):
        with open(args.out) as fh:
            merged = json.load(fh)
    merged["serve"] = result
    with open(args.out, "w") as fh:
        json.dump(merged, fh, indent=1)
        fh.write("\n")
    print(json.dumps(result, indent=1))
    print(f"[bench_serve] merged into {args.out}")


if __name__ == "__main__":
    main()

"""End-to-end data-lake -> training-batch pipeline throughput (beyond-paper:
the framework integration). Writes a trajectory data lake, then measures
tokens/s through read (with and without spatial filter pushdown), tokenize,
pack, prefetch."""

from __future__ import annotations

import os
import tempfile
import time

from repro.core.writer import write_file
from repro.data.pipeline import Prefetcher, TrajectoryBatcher
from repro.data.synthetic import PORTO_BBOX, porto_taxi_like
from repro.data.tokenizer import GeoTokenizer
from repro.core.pages import best_codec


def run(scale: float = 1.0) -> list[dict]:
    rows = []
    tmp = tempfile.mkdtemp()
    files = []
    for i in range(2):
        cols = porto_taxi_like(n_traj=max(int(2000 * scale), 100), seed=i)
        p = os.path.join(tmp, f"part{i}.spqf")
        write_file(p, columns=cols, sort="hilbert", codec=best_codec())
        files.append(p)

    tok = GeoTokenizer(PORTO_BBOX, order=6)
    for bbox, tag in ((None, "full"),
                      ((PORTO_BBOX[0], PORTO_BBOX[1],
                        (PORTO_BBOX[0] + PORTO_BBOX[2]) / 2,
                        (PORTO_BBOX[1] + PORTO_BBOX[3]) / 2), "filtered")):
        it = Prefetcher(TrajectoryBatcher(files, tok, seq_len=128, global_batch=16,
                                          bbox=bbox, loop=True))
        n_batches, n_tokens = 0, 0
        t0 = time.perf_counter()
        for batch in it:
            n_batches += 1
            n_tokens += batch["tokens"].size
            if n_batches >= 20:
                break
        dt = time.perf_counter() - t0
        rows.append(dict(table="P", name=f"pipeline_{tag}",
                         tokens_per_s=n_tokens / dt, batches=n_batches,
                         stalls=it.stalls))
    for p in files:
        os.unlink(p)
    return rows


def summarize(rows) -> list[str]:
    return ["# Pipeline"] + [
        f"P {r['name']}: {r['tokens_per_s']:.0f} tok/s (stalls={r['stalls']})" for r in rows
    ]

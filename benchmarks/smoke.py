"""Smoke read/write benchmark: a fast perf-trajectory anchor for CI.

Writes a JSON file (default ``BENCH_read.json``) with wall-clock seconds and
byte counts for the PT dataset so later PRs can regress against a recorded
baseline::

    PYTHONPATH=src python -m benchmarks.smoke [--scale 0.25] [--out BENCH_read.json]

Reported fields: ``write_s``, ``read_columnar_s`` (coalesced fast path),
``read_columnar_legacy_s`` (one read per blob, same decode),
``device_decode_s`` (``device="jax"`` page-stream decode — Pallas interpret
mode off-TPU, so this is a correctness-plane number in CI), ``file_bytes``,
``raw_coord_bytes``, ``n_records``, ``n_values``, plus the sharded-dataset
trajectory: ``dataset_write_s``, ``dataset_scan_s`` (async full scan over
``dataset_n_shards`` shards), ``dataset_scan_bbox_s`` and its pruning ratio
``dataset_bbox_bytes_read``/``dataset_bytes_total``. Timings are best-of-N
to shrink scheduler noise.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import shutil
import tempfile
import time

from repro.core.reader import SpatialParquetReader
from repro.core.writer import write_file
from repro.dataset import SpatialDatasetScanner, write_dataset

from .common import SCALE_1, make_dataset, tmppath


def run(scale: float = 0.25, dataset: str = "PT", repeats: int = 3,
        n_shards: int = 4) -> dict:
    cols = make_dataset(dataset, scale, sort="hilbert")
    path = tmppath(".spqf")
    droot = tempfile.mkdtemp(prefix="smoke_ds_")
    try:
        write_s = min(
            _timed(lambda: write_file(path, columns=cols, sort=None, codec="none"))
            for _ in range(repeats)
        )
        file_bytes = os.path.getsize(path)
        with SpatialParquetReader(path) as r:
            read_s = min(
                _timed(lambda: r.read_columnar()) for _ in range(repeats)
            )
            read_legacy_s = min(
                _timed(lambda: r.read_columnar(coalesce=False)) for _ in range(repeats)
            )
            r.read_columnar(device="jax")  # warm-up: jit compile off the clock
            device_decode_s = min(
                _timed(lambda: r.read_columnar(device="jax"))
                for _ in range(repeats)
            )
            geo, _, stats = r.read_columnar()

        # sharded dataset: async full scan + shard-pruned bbox scan
        dataset_write_s = min(
            _timed(lambda: write_dataset(
                droot, columns=cols, n_shards=n_shards, sort="hilbert",
                codec="none"))
            for _ in range(repeats)
        )
        sc = SpatialDatasetScanner(droot, max_workers=n_shards)
        dataset_scan_s = min(_timed(lambda: sc.scan()) for _ in range(repeats))
        x0, y0, x1, y1 = sc.manifest.mbr
        bbox = (x0, y0, x0 + (x1 - x0) / 4, y0 + (y1 - y0) / 4)
        dataset_scan_bbox_s = min(
            _timed(lambda: sc.scan(bbox=bbox)) for _ in range(repeats)
        )
        _, _, dstats = sc.scan(bbox=bbox)
    finally:
        if os.path.exists(path):
            os.unlink(path)
        shutil.rmtree(droot, ignore_errors=True)
    return {
        "dataset": dataset,
        "scale": scale,
        "scale_1_config": SCALE_1[dataset],
        "write_s": round(write_s, 6),
        "read_columnar_s": round(read_s, 6),
        "read_columnar_legacy_s": round(read_legacy_s, 6),
        "device_decode_s": round(device_decode_s, 6),
        "file_bytes": file_bytes,
        "raw_coord_bytes": int(cols.n_values) * 2 * cols.x.dtype.itemsize,
        "bytes_read": stats.bytes_read,
        "dataset_n_shards": n_shards,
        "dataset_write_s": round(dataset_write_s, 6),
        "dataset_scan_s": round(dataset_scan_s, 6),
        "dataset_scan_bbox_s": round(dataset_scan_bbox_s, 6),
        "dataset_bbox_bytes_read": dstats.bytes_read,
        "dataset_bytes_total": dstats.bytes_total,
        "dataset_bbox_shards_read": dstats.shards_read,
        "n_records": int(geo.n_records),
        "n_values": int(geo.n_values),
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--dataset", default="PT")
    ap.add_argument("--out", default="BENCH_read.json")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--shards", type=int, default=4)
    args = ap.parse_args()
    result = run(scale=args.scale, dataset=args.dataset, repeats=args.repeats,
                 n_shards=args.shards)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=1)
        fh.write("\n")
    print(json.dumps(result, indent=1))
    print(f"[smoke] saved {args.out}")


if __name__ == "__main__":
    main()

"""Smoke read/write benchmark: a fast perf-trajectory anchor for CI.

Writes a JSON file (default ``BENCH_read.json``) with wall-clock seconds and
byte counts for the PT dataset so later PRs can regress against a recorded
baseline::

    PYTHONPATH=src python -m benchmarks.smoke [--scale 0.25] [--out BENCH_read.json]

Reported fields: ``write_s``, ``read_columnar_s`` (coalesced fast path,
double-buffered row groups), ``read_columnar_legacy_s`` (one read per blob,
same decode), ``device_decode_s`` (``device="jax"`` page-stream decode),
``device_refine_s`` (fused on-device decode→bbox-refine at ~50% record
selectivity) and ``refine_sweep`` — host vs fused device refinement at ~1%,
~10% and ~50% record selectivity with the measured selectivity per box.
Off-TPU the kernels run in Pallas interpret mode, so the device numbers are
correctness-plane trajectories in CI, not speedups. Also recorded:
``file_bytes``, ``raw_coord_bytes``, ``n_records``, ``n_values``, plus the
sharded-dataset trajectory: ``dataset_write_s``, ``dataset_scan_s`` (async
full scan over ``dataset_n_shards`` shards), ``dataset_scan_bbox_s`` and its
pruning ratio ``dataset_bbox_bytes_read``/``dataset_bytes_total``, plus the
fault-tolerant remote path: ``remote_scan_s`` (full read through a
``RemoteRangeSource`` over an in-process range-GET server, ``cold_cache``
vs ``warm_cache`` block cache). Timings are best-of-N to shrink scheduler
noise.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import shutil
import tempfile
import time

import numpy as np

from repro.core.reader import SpatialParquetReader
from repro.core.writer import write_file
from repro.dataset import SpatialDatasetScanner, write_dataset
from repro.io import InProcessRangeServer, RemoteRangeSource

from .common import SCALE_1, make_dataset, tmppath

# record-selectivity targets of the fused-refine sweep (fraction of records
# a central quantile box should retain)
SWEEP_TARGETS = (0.01, 0.10, 0.50)


def selectivity_bbox(geo, frac: float):
    """A central bbox retaining roughly ``frac`` of the records: quantile
    span of sqrt(frac) per axis around the median."""
    x = np.asarray(geo.x, np.float64)
    y = np.asarray(geo.y, np.float64)
    side = float(np.sqrt(frac)) / 2.0
    return (
        float(np.quantile(x, 0.5 - side)), float(np.quantile(y, 0.5 - side)),
        float(np.quantile(x, 0.5 + side)), float(np.quantile(y, 0.5 + side)),
    )


def run(scale: float = 0.25, dataset: str = "PT", repeats: int = 3,
        n_shards: int = 4) -> dict:
    cols = make_dataset(dataset, scale, sort="hilbert")
    path = tmppath(".spqf")
    droot = tempfile.mkdtemp(prefix="smoke_ds_")
    try:
        write_s = min(
            _timed(lambda: write_file(path, columns=cols, sort=None, codec="none"))
            for _ in range(repeats)
        )
        file_bytes = os.path.getsize(path)
        with SpatialParquetReader(path) as r:
            read_s = min(
                _timed(lambda: r.read_columnar()) for _ in range(repeats)
            )
            read_legacy_s = min(
                _timed(lambda: r.read_columnar(coalesce=False)) for _ in range(repeats)
            )
            r.read_columnar(device="jax")  # warm-up: jit compile off the clock
            device_decode_s = min(
                _timed(lambda: r.read_columnar(device="jax"))
                for _ in range(repeats)
            )
            geo, _, stats = r.read_columnar()

            # fused decode→refine selectivity sweep (host vs device)
            refine_sweep = []
            for target in SWEEP_TARGETS:
                bbox = selectivity_bbox(geo, target)
                # warm-up compiles this bucket off the clock
                _, _, dstats_r = r.read_columnar(
                    bbox=bbox, refine=True, device="jax")
                host_s = min(
                    _timed(lambda: r.read_columnar(bbox=bbox, refine=True))
                    for _ in range(repeats)
                )
                dev_s = min(
                    _timed(lambda: r.read_columnar(
                        bbox=bbox, refine=True, device="jax"))
                    for _ in range(repeats)
                )
                refine_sweep.append({
                    "target": target,
                    "selectivity": round(
                        dstats_r.records_returned / max(geo.n_records, 1), 4),
                    "host_refine_s": round(host_s, 6),
                    "device_refine_s": round(dev_s, 6),
                    "records": dstats_r.records_returned,
                })
            device_refine_s = refine_sweep[-1]["device_refine_s"]

        # remote (object-store-style) scan through the fault-tolerant
        # source: in-process range-GET server, cold vs warm block cache
        server = InProcessRangeServer(path)

        def remote_scan_cold():
            with SpatialParquetReader(source=RemoteRangeSource(server)) as rr:
                rr.read_columnar()

        remote_scan_cold_s = min(
            _timed(remote_scan_cold) for _ in range(repeats)
        )
        with SpatialParquetReader(source=RemoteRangeSource(server)) as rr:
            rr.read_columnar()  # populate the block cache off the clock
            remote_scan_warm_s = min(
                _timed(lambda: rr.read_columnar()) for _ in range(repeats)
            )

        # sharded dataset: async full scan + shard-pruned bbox scan
        dataset_write_s = min(
            _timed(lambda: write_dataset(
                droot, columns=cols, n_shards=n_shards, sort="hilbert",
                codec="none"))
            for _ in range(repeats)
        )
        sc = SpatialDatasetScanner(droot, max_workers=n_shards)
        dataset_scan_s = min(_timed(lambda: sc.scan()) for _ in range(repeats))
        x0, y0, x1, y1 = sc.manifest.mbr
        bbox = (x0, y0, x0 + (x1 - x0) / 4, y0 + (y1 - y0) / 4)
        dataset_scan_bbox_s = min(
            _timed(lambda: sc.scan(bbox=bbox)) for _ in range(repeats)
        )
        _, _, dstats = sc.scan(bbox=bbox)
    finally:
        if os.path.exists(path):
            os.unlink(path)
        shutil.rmtree(droot, ignore_errors=True)
    return {
        "dataset": dataset,
        "scale": scale,
        "scale_1_config": SCALE_1[dataset],
        "write_s": round(write_s, 6),
        "read_columnar_s": round(read_s, 6),
        "read_columnar_legacy_s": round(read_legacy_s, 6),
        "device_decode_s": round(device_decode_s, 6),
        "device_refine_s": device_refine_s,
        "refine_sweep": refine_sweep,
        "file_bytes": file_bytes,
        "raw_coord_bytes": int(cols.n_values) * 2 * cols.x.dtype.itemsize,
        "bytes_read": stats.bytes_read,
        "dataset_n_shards": n_shards,
        "dataset_write_s": round(dataset_write_s, 6),
        "dataset_scan_s": round(dataset_scan_s, 6),
        "dataset_scan_bbox_s": round(dataset_scan_bbox_s, 6),
        "dataset_bbox_bytes_read": dstats.bytes_read,
        "dataset_bytes_total": dstats.bytes_total,
        "dataset_bbox_shards_read": dstats.shards_read,
        "remote_scan_s": {
            "cold_cache": round(remote_scan_cold_s, 6),
            "warm_cache": round(remote_scan_warm_s, 6),
        },
        "n_records": int(geo.n_records),
        "n_values": int(geo.n_values),
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--dataset", default="PT")
    ap.add_argument("--out", default="BENCH_read.json")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--shards", type=int, default=4)
    args = ap.parse_args()
    result = run(scale=args.scale, dataset=args.dataset, repeats=args.repeats,
                 n_shards=args.shards)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=1)
        fh.write("\n")
    print(json.dumps(result, indent=1))
    print(f"[smoke] saved {args.out}")


if __name__ == "__main__":
    main()

"""Smoke read/write benchmark: a fast perf-trajectory anchor for CI.

Writes a JSON file (default ``BENCH_read.json``) with wall-clock seconds and
byte counts for the PT dataset so later PRs can regress against a recorded
baseline::

    PYTHONPATH=src python -m benchmarks.smoke [--scale 0.25] [--out BENCH_read.json]

Reported fields: ``write_s``, ``read_columnar_s`` (coalesced fast path,
double-buffered row groups), ``read_columnar_legacy_s`` (one read per blob,
same decode), ``device_decode_s`` (``device="jax"`` page-stream decode),
``device_refine_s`` (fused on-device decode→bbox-refine at ~50% record
selectivity) and ``refine_sweep`` — host vs fused device refinement at ~1%,
~10% and ~50% record selectivity with the measured selectivity per box.
Off-TPU the kernels run in Pallas interpret mode, so the device numbers are
correctness-plane trajectories in CI, not speedups. Also recorded:
``file_bytes``, ``raw_coord_bytes``, ``n_records``, ``n_values``, plus the
sharded-dataset trajectory: ``dataset_write_s``, ``dataset_scan_s`` (async
full scan over ``dataset_n_shards`` shards), ``dataset_scan_bbox_s`` and its
pruning ratio ``dataset_bbox_bytes_read``/``dataset_bytes_total``, the
predicate-pushdown trajectory: ``filter_scan_s`` (attribute-filtered scan
over a lake whose per-shard zone maps are disjoint on the filter column)
with ``filter_zone_pruned_bytes`` / ``filter_zone_pruned_ratio`` (bytes the
zone maps pruned before any shard file was opened), the
crash-safe catalog trajectory: ``catalog_commit_s`` (atomic snapshot commit
latency) and ``compact_s`` with ``compact_shards_before`` /
``compact_shards_after`` (one background-compaction cycle), plus the
fault-tolerant remote path: ``remote_scan_s`` (full read through a
``RemoteRangeSource`` over an in-process range-GET server, ``cold_cache``
vs ``warm_cache`` block cache). Timings are best-of-N to shrink scheduler
noise; ``latency_percentiles`` additionally reports the p50/p99 of every
repeated timing (the serve-tier view: tails, not just the floor).

``--trace scan_trace.json`` re-runs the fused device dataset scan with
:mod:`repro.obs` tracing enabled, verifies the traced results are
bit-identical to the untraced ones (exit code 1 otherwise), and writes the
Chrome trace-event JSON (with the metrics snapshot embedded) for Perfetto.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import shutil
import tempfile
import time

import numpy as np

from repro.core.reader import SpatialParquetReader
from repro.core.writer import write_file
from repro.dataset import (
    Catalog,
    Compactor,
    SpatialDatasetScanner,
    write_dataset,
)
from repro.io import InProcessRangeServer, RemoteRangeSource

from .common import SCALE_1, make_dataset, tmppath

# record-selectivity targets of the fused-refine sweep (fraction of records
# a central quantile box should retain)
SWEEP_TARGETS = (0.01, 0.10, 0.50)


def selectivity_bbox(geo, frac: float):
    """A central bbox retaining roughly ``frac`` of the records: quantile
    span of sqrt(frac) per axis around the median."""
    x = np.asarray(geo.x, np.float64)
    y = np.asarray(geo.y, np.float64)
    side = float(np.sqrt(frac)) / 2.0
    return (
        float(np.quantile(x, 0.5 - side)), float(np.quantile(y, 0.5 - side)),
        float(np.quantile(x, 0.5 + side)), float(np.quantile(y, 0.5 + side)),
    )


def run(scale: float = 0.25, dataset: str = "PT", repeats: int = 3,
        n_shards: int = 4, trace: str | None = None) -> dict:
    cols = make_dataset(dataset, scale, sort="hilbert")
    path = tmppath(".spqf")
    droot = tempfile.mkdtemp(prefix="smoke_ds_")
    froot = tempfile.mkdtemp(prefix="smoke_flt_")
    # p50/p99 of every repeated timing, keyed like the min-based fields
    pcts: dict[str, dict] = {}

    def bench(name: str, fn) -> float:
        samples = [_timed(fn) for _ in range(repeats)]
        pcts[name] = _percentiles(samples)
        return min(samples)

    try:
        write_s = bench(
            "write_s",
            lambda: write_file(path, columns=cols, sort=None, codec="none"))
        file_bytes = os.path.getsize(path)
        with SpatialParquetReader(path) as r:
            read_s = bench("read_columnar_s", lambda: r.read_columnar())
            read_legacy_s = bench(
                "read_columnar_legacy_s",
                lambda: r.read_columnar(coalesce=False))
            r.read_columnar(device="jax")  # warm-up: jit compile off the clock
            device_decode_s = bench(
                "device_decode_s", lambda: r.read_columnar(device="jax"))
            geo, _, stats = r.read_columnar()

            # fused decode→refine selectivity sweep (host vs device)
            refine_sweep = []
            for target in SWEEP_TARGETS:
                bbox = selectivity_bbox(geo, target)
                # warm-up compiles this bucket off the clock
                _, _, dstats_r = r.read_columnar(
                    bbox=bbox, refine=True, device="jax")
                host = [
                    _timed(lambda: r.read_columnar(bbox=bbox, refine=True))
                    for _ in range(repeats)
                ]
                dev = [
                    _timed(lambda: r.read_columnar(
                        bbox=bbox, refine=True, device="jax"))
                    for _ in range(repeats)
                ]
                row = {
                    "target": target,
                    "selectivity": round(
                        dstats_r.records_returned / max(geo.n_records, 1), 4),
                    "host_refine_s": round(min(host), 6),
                    "device_refine_s": round(min(dev), 6),
                    "records": dstats_r.records_returned,
                }
                row.update({f"host_refine_{k}": v
                            for k, v in _percentiles(host).items()})
                row.update({f"device_refine_{k}": v
                            for k, v in _percentiles(dev).items()})
                refine_sweep.append(row)
            device_refine_s = refine_sweep[-1]["device_refine_s"]

        # remote (object-store-style) scan through the fault-tolerant
        # source: in-process range-GET server, cold vs warm block cache
        server = InProcessRangeServer(path)

        def remote_scan_cold():
            with SpatialParquetReader(source=RemoteRangeSource(server)) as rr:
                rr.read_columnar()

        remote_scan_cold_s = bench("remote_scan_cold_s", remote_scan_cold)
        with SpatialParquetReader(source=RemoteRangeSource(server)) as rr:
            rr.read_columnar()  # populate the block cache off the clock
            remote_scan_warm_s = bench(
                "remote_scan_warm_s", lambda: rr.read_columnar())

        # sharded dataset: async full scan + shard-pruned bbox scan
        dataset_write_s = bench(
            "dataset_write_s",
            lambda: write_dataset(droot, columns=cols, n_shards=n_shards,
                                  sort="hilbert", codec="none"))
        sc = SpatialDatasetScanner(droot, max_workers=n_shards)
        dataset_scan_s = bench("dataset_scan_s", lambda: sc.scan())
        x0, y0, x1, y1 = sc.manifest.mbr
        bbox = (x0, y0, x0 + (x1 - x0) / 4, y0 + (y1 - y0) / 4)
        dataset_scan_bbox_s = bench(
            "dataset_scan_bbox_s", lambda: sc.scan(bbox=bbox))
        _, _, dstats = sc.scan(bbox=bbox)
        trace_info = (_traced_scan_check(sc, bbox, trace)
                      if trace is not None else None)

        # attribute-predicate pushdown: a sort=None lake whose `seq` column
        # is contiguous per shard, so the persisted zone maps prune all but
        # one shard before any file is opened
        from repro.core.filters import Range

        write_dataset(
            froot, columns=cols,
            extra={"seq": np.arange(cols.n_records, dtype=np.int64)},
            n_shards=n_shards, sort=None, codec="none")
        fsc = SpatialDatasetScanner(froot, max_workers=n_shards)
        pred = Range("seq", 0, max(0, cols.n_records // n_shards - 1))
        fhit = fsc.index.query(None, filter=pred)
        filter_zone_pruned_bytes = int(
            fsc.index.data_bytes.sum() - fsc.index.data_bytes[fhit].sum())
        filter_scan_s = bench("filter_scan_s", lambda: fsc.scan(filter=pred))
        _, _, fstats = fsc.scan(filter=pred)
        fsc.close()

        # crash-safe catalog: metadata-only snapshot commit latency, then one
        # background-compaction cycle (merges the bench lake back to SFC
        # order; single run — a second cycle would be a no-op)
        cat = Catalog.open(droot)
        catalog_commit_s = bench(
            "catalog_commit_s",
            lambda: cat.commit_manifest(cat.head_snapshot().manifest))
        compact_shards_before = cat.head_snapshot().manifest.n_shards
        compactor = Compactor(cat, target_records=1 << 62)
        compact_s = _timed(compactor.run_once)
        compact_shards_after = cat.head_snapshot().manifest.n_shards
    finally:
        if os.path.exists(path):
            os.unlink(path)
        shutil.rmtree(droot, ignore_errors=True)
        shutil.rmtree(froot, ignore_errors=True)
    return {
        "dataset": dataset,
        "scale": scale,
        "scale_1_config": SCALE_1[dataset],
        "write_s": round(write_s, 6),
        "read_columnar_s": round(read_s, 6),
        "read_columnar_legacy_s": round(read_legacy_s, 6),
        "device_decode_s": round(device_decode_s, 6),
        "device_refine_s": device_refine_s,
        "refine_sweep": refine_sweep,
        "file_bytes": file_bytes,
        "raw_coord_bytes": int(cols.n_values) * 2 * cols.x.dtype.itemsize,
        "bytes_read": stats.bytes_read,
        "dataset_n_shards": n_shards,
        "dataset_write_s": round(dataset_write_s, 6),
        "dataset_scan_s": round(dataset_scan_s, 6),
        "dataset_scan_bbox_s": round(dataset_scan_bbox_s, 6),
        "dataset_bbox_bytes_read": dstats.bytes_read,
        "dataset_bytes_total": dstats.bytes_total,
        "dataset_bbox_shards_read": dstats.shards_read,
        "filter_scan_s": round(filter_scan_s, 6),
        "filter_zone_pruned_bytes": filter_zone_pruned_bytes,
        "filter_zone_pruned_ratio": round(
            filter_zone_pruned_bytes / max(1, fstats.bytes_total), 4),
        "filter_shards_read": fstats.shards_read,
        "filter_records_returned": fstats.records_returned,
        "catalog_commit_s": round(catalog_commit_s, 6),
        "compact_s": round(compact_s, 6),
        "compact_shards_before": compact_shards_before,
        "compact_shards_after": compact_shards_after,
        "remote_scan_s": {
            "cold_cache": round(remote_scan_cold_s, 6),
            "warm_cache": round(remote_scan_warm_s, 6),
        },
        "n_records": int(geo.n_records),
        "n_values": int(geo.n_values),
        "latency_percentiles": pcts,
        "trace": trace_info,
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _percentiles(samples) -> dict:
    return {"p50": round(float(np.percentile(samples, 50)), 6),
            "p99": round(float(np.percentile(samples, 99)), 6)}


def _result_fingerprint(geo, extras) -> bytes:
    parts = []
    if geo is not None:
        geo = geo.coords_to_host()
        for f in ("types", "type_rep", "rep", "defn", "x", "y"):
            parts.append(np.asarray(getattr(geo, f)).tobytes())
    for k in sorted(extras):
        parts.append(k.encode())
        parts.append(np.asarray(extras[k]).tobytes())
    return b"".join(parts)


def _traced_scan_check(sc, bbox, trace_path: str) -> dict:
    """Traced fused device scan, verified bit-identical to the untraced one.

    Exports the Chrome trace JSON (metrics snapshot embedded) to
    ``trace_path``; exits non-zero if tracing perturbed the results.
    """
    from repro import obs

    ref = sc.scan(bbox=bbox, refine=True, device="jax")
    tracer = obs.enable()
    try:
        out = sc.scan(bbox=bbox, refine=True, device="jax")
    finally:
        obs.disable()
    if _result_fingerprint(ref[0], ref[1]) != _result_fingerprint(out[0], out[1]):
        raise SystemExit(
            "[smoke] traced scan results differ from untraced scan")
    tracer.export(trace_path, metrics=obs.snapshot())
    spans = [e for e in tracer.events if e["ph"] == "X"]
    return {
        "path": trace_path,
        "spans": len(spans),
        "stages": sorted({e["name"] for e in spans}),
        "bit_identical": True,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--dataset", default="PT")
    ap.add_argument("--out", default="BENCH_read.json")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="run a traced fused device scan, verify it is "
                         "bit-identical to the untraced one, and write the "
                         "Chrome trace-event JSON here")
    args = ap.parse_args()
    result = run(scale=args.scale, dataset=args.dataset, repeats=args.repeats,
                 n_shards=args.shards, trace=args.trace)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=1)
        fh.write("\n")
    print(json.dumps(result, indent=1))
    print(f"[smoke] saved {args.out}")


if __name__ == "__main__":
    main()

"""Paper Figure 11: light-weight spatial index — read time & pages pruned for
no filter / small range (~0.01% of area) / large range (~1%).

Each indexed Spatial Parquet query is timed twice: host refinement
(``refine=True``) and the fused on-device decode→bbox-refine path
(``refine=True, device="jax"`` — Pallas interpret mode off-TPU, so treat it
as a correctness-plane trajectory there). A second table (``SWEEP``) runs
the record-selectivity sweep (~1% / ~10% / ~50% retained) used by the CI
smoke bench, at full benchmark scale.

Also reports GeoParquet-like page pruning (the paper notes it has "similar
benefit" through its MBR columns) for comparison."""

from __future__ import annotations

import os

import numpy as np

from repro.baselines.geoparquet_like import GeoParquetLikeReader, GeoParquetLikeWriter
from repro.core.reader import SpatialParquetReader
from repro.core.writer import write_file

from .common import dataset_geometries, make_dataset, timer, tmppath
from .smoke import SWEEP_TARGETS, selectivity_bbox


def _query_boxes(cols, area_fracs):
    xs, ys = cols.x, cols.y
    x0, x1 = float(np.min(xs)), float(np.max(xs))
    y0, y1 = float(np.min(ys)), float(np.max(ys))
    boxes = {}
    for name, frac in area_fracs.items():
        side = np.sqrt(frac)
        w, h = (x1 - x0) * side, (y1 - y0) * side
        # center on a data point so the query is non-empty
        cxq, cyq = float(xs[len(xs) // 3]), float(ys[len(ys) // 3])
        boxes[name] = (cxq - w / 2, cyq - h / 2, cxq + w / 2, cyq + h / 2)
    return boxes


def run(scale: float = 1.0, datasets=("PT", "eB")) -> list[dict]:
    rows = []
    for ds in datasets:
        cols = make_dataset(ds, scale, sort="hilbert")
        boxes = _query_boxes(cols, {"small": 1e-4, "large": 1e-2})
        boxes["none"] = None

        p = tmppath(".spqf")
        write_file(p, columns=cols, sort=None, codec="none",
                   page_values=16384, row_group_records=1 << 20)
        r = SpatialParquetReader(p)
        for qname in ("none", "small", "large"):
            with timer() as t:
                geo, _, st = r.read_columnar(bbox=boxes[qname], refine=True)
            rows.append(dict(
                table="F11", dataset=ds, fmt="spatialparquet", query=qname,
                s=t["s"], pages_read=st.pages_read, pages_total=st.pages_total,
                bytes_read=st.bytes_read, bytes_total=st.bytes_total,
                records=st.records_returned,
            ))
            if boxes[qname] is not None:
                # fused on-device refinement (warm-up compiles off the clock)
                r.read_columnar(bbox=boxes[qname], refine=True, device="jax")
                with timer() as t:
                    _, _, std = r.read_columnar(
                        bbox=boxes[qname], refine=True, device="jax")
                rows.append(dict(
                    table="F11", dataset=ds, fmt="spatialparquet-devrefine",
                    query=qname, s=t["s"], pages_read=std.pages_read,
                    pages_total=std.pages_total, bytes_read=std.bytes_read,
                    bytes_total=std.bytes_total, records=std.records_returned,
                ))

        # record-selectivity sweep: host vs fused device refinement
        full, _, _ = r.read_columnar()
        for target in SWEEP_TARGETS:
            bbox = selectivity_bbox(full, target)
            r.read_columnar(bbox=bbox, refine=True, device="jax")  # warm-up
            with timer() as th:
                r.read_columnar(bbox=bbox, refine=True)
            with timer() as td:
                _, _, stdv = r.read_columnar(bbox=bbox, refine=True,
                                             device="jax")
            rows.append(dict(
                table="SWEEP", dataset=ds, fmt="spatialparquet",
                query=f"sel{int(target * 100):02d}", s=th["s"],
                device_refine_s=td["s"],
                selectivity=round(stdv.records_returned / max(full.n_records, 1), 4),
                records=stdv.records_returned,
            ))
        r.close()
        os.unlink(p)

        geoms = dataset_geometries(cols)
        p = tmppath(".gpq")
        with GeoParquetLikeWriter(p) as w:
            w.write_geometries(geoms)
        rd = GeoParquetLikeReader(p)
        for qname in ("none", "small", "large"):
            with timer() as t:
                out, pr, pt = rd.read(bbox=boxes[qname])
            rows.append(dict(
                table="F11", dataset=ds, fmt="geoparquet", query=qname,
                s=t["s"], pages_read=pr, pages_total=pt, records=len(out),
            ))
        rd.close()
        os.unlink(p)
    return rows


def summarize(rows) -> list[str]:
    out = ["# Figure 11: indexed range reads (pages read/total, seconds)"]
    for r in rows:
        if r["table"] == "SWEEP":
            out.append(
                f"SWEEP {r['dataset']}/{r['query']}: host {r['s']:.3f}s "
                f"device {r['device_refine_s']:.3f}s "
                f"selectivity={r['selectivity']} records={r['records']}"
            )
        else:
            out.append(
                f"F11 {r['dataset']}/{r['fmt']}/{r['query']}: {r['s']:.3f}s "
                f"pages={r['pages_read']}/{r['pages_total']} records={r.get('records','-')}"
            )
    return out

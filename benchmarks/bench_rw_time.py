"""Paper Table 3: write/read time (uncompressed).

Reports SpatialParquet through BOTH access paths: the object API (the path
the paper measured, slowed by per-record reconstruction) and the columnar
fast path (the paper's §5.1 future-work fix — "lower-level access to the
coordinate arrays" — which we implement as the primary pipeline path)."""

from __future__ import annotations

import os

from repro.baselines.geojson_format import read_geojson, write_geojson
from repro.baselines.geoparquet_like import GeoParquetLikeReader, GeoParquetLikeWriter
from repro.baselines.shapefile import read_shapefile, write_shapefile
from repro.core.reader import SpatialParquetReader
from repro.core.writer import write_file

from .common import dataset_geometries, make_dataset, timer, tmppath


def run(scale: float = 1.0, datasets=("PT", "TR", "MB", "eB")) -> list[dict]:
    rows = []
    for ds in datasets:
        cols = make_dataset(ds, scale, sort="hilbert")
        geoms = dataset_geometries(cols)

        p = tmppath(".spqf")
        with timer() as t:
            write_file(p, columns=cols, sort=None, codec="none")
        rows.append(dict(table="T3", dataset=ds, fmt="spatialparquet", op="write", s=t["s"]))
        r = SpatialParquetReader(p)
        with timer() as t:
            g, _, _ = r.read_columnar()
        rows.append(dict(table="T3", dataset=ds, fmt="spatialparquet(columnar)", op="read", s=t["s"]))
        with timer() as t:
            objs, _ = r.read()
        rows.append(dict(table="T3", dataset=ds, fmt="spatialparquet(object)", op="read", s=t["s"]))
        r.close()
        os.unlink(p)

        p = tmppath(".gpq")
        with timer() as t:
            with GeoParquetLikeWriter(p) as w:
                w.write_geometries(geoms)
        rows.append(dict(table="T3", dataset=ds, fmt="geoparquet", op="write", s=t["s"]))
        rd = GeoParquetLikeReader(p)
        with timer() as t:
            rd.read()
        rows.append(dict(table="T3", dataset=ds, fmt="geoparquet", op="read", s=t["s"]))
        rd.close()
        os.unlink(p)

        p = tmppath(".shp")
        with timer() as t:
            write_shapefile(p, geoms)
        rows.append(dict(table="T3", dataset=ds, fmt="shapefile", op="write", s=t["s"]))
        with timer() as t:
            read_shapefile(p)
        rows.append(dict(table="T3", dataset=ds, fmt="shapefile", op="read", s=t["s"]))
        os.unlink(p)

        p = tmppath(".geojson")
        with timer() as t:
            write_geojson(p, geoms)
        rows.append(dict(table="T3", dataset=ds, fmt="geojson", op="write", s=t["s"]))
        with timer() as t:
            read_geojson(p)
        rows.append(dict(table="T3", dataset=ds, fmt="geojson", op="read", s=t["s"]))
        os.unlink(p)
    return rows


def summarize(rows) -> list[str]:
    out = ["# Table 3: write/read seconds (uncompressed)"]
    for ds in ("PT", "TR", "MB", "eB"):
        sub = [r for r in rows if r["dataset"] == ds]
        line = [f"T3 {ds}:"]
        for r in sub:
            line.append(f"{r['fmt']}.{r['op']}={r['s']:.2f}s")
        out.append(" ".join(line))
    return out

"""Shared helpers for the paper-table benchmarks."""

from __future__ import annotations

import os
import tempfile
import time
from contextlib import contextmanager

import numpy as np

from repro.core.columnar import GeometryColumns, assemble
from repro.data.synthetic import DATASETS

# dataset scales (records) at scale=1.0 — structure-matched, size-reduced
# analogs of paper Table 1 (see DESIGN.md §10)
SCALE_1 = {
    "PT": dict(n_traj=8_000),        # ~0.4M points, MultiPoint
    "TR": dict(n_roads=30_000),      # ~1.0M points, MultiLineString
    "MB": dict(n_buildings=80_000),  # 0.4M points, Polygon
    "eB": dict(n_points=400_000),    # 0.4M points, Point
}


def make_dataset(name: str, scale: float = 1.0, sort: str | None = None) -> GeometryColumns:
    kw = {k: max(int(v * scale), 10) for k, v in SCALE_1[name].items()}
    cols = DATASETS[name](**kw)
    if sort:
        # paper §5.1: "the source data for writing these files are sorted
        # using the Hilbert-curve method" — applied to ALL formats equally
        from repro.core.sfc import sort_keys
        from repro.core.writer import permute_records, record_centroids

        cx, cy = record_centroids(cols)
        keys = sort_keys(cx, cy, sort)
        cols = permute_records(cols, np.argsort(keys, kind="stable"))
    return cols


def dataset_geometries(cols: GeometryColumns):
    return assemble(cols)


@contextmanager
def timer():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def tmppath(suffix=""):
    fd, p = tempfile.mkstemp(suffix=suffix)
    os.close(fd)
    os.unlink(p)
    return p


def file_mb(path) -> float:
    return os.path.getsize(path) / 1e6


def fmt_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"

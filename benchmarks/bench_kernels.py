"""Kernel-plane benchmarks (beyond-paper: the TPU adaptation).

* host FP-delta codec throughput (the paper's encoder, vectorized numpy),
* Pallas miniblock codec (interpret mode on CPU — correctness-plane numbers;
  real TPU timing comes from the roofline model),
* page-stream device decode of the paper-exact format (host plan + batched
  Pallas/jnp execution — the read path's ``device="jax"`` back half),
* miniblock size penalty vs the paper-exact n* stream (DESIGN.md §5 claims
  <~8% on GPS-like data),
* flash-attention kernel vs jnp oracle equivalence timing at small shape.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.fp_delta import (
    fp_delta_decode,
    fp_delta_encode,
    fp_delta_encode_pages,
    fp_delta_plan,
)
from repro.kernels import fp_delta as fpd

from .common import make_dataset


def _throughput(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    return (time.perf_counter() - t0) / reps, out


def run(scale: float = 1.0) -> list[dict]:
    rows = []
    cols = make_dataset("PT", scale)
    x64 = np.ascontiguousarray(cols.x)
    x32 = x64.astype(np.float32)

    s, (payload, st) = _throughput(lambda a: fp_delta_encode(a), x64)
    rows.append(dict(table="K", name="host_fp_delta64_encode",
                     mbps=x64.nbytes / s / 1e6, n=len(x64),
                     ratio=x64.nbytes / len(payload), n_star=st.n_bits))
    s, _ = _throughput(lambda p: fp_delta_decode(p, len(x64), np.float64), payload)
    rows.append(dict(table="K", name="host_fp_delta64_decode",
                     mbps=x64.nbytes / s / 1e6, n=len(x64)))

    # page-stream device decode: host escape resolution + one batched launch
    n_pages = 8
    bounds = [(i * len(x64) // n_pages, (i + 1) * len(x64) // n_pages)
              for i in range(n_pages)]
    plans = [fp_delta_plan(payload, v1 - v0, np.float64)
             for (payload, _), (v0, v1) in zip(
                 fp_delta_encode_pages(x64, bounds), bounds)]
    s, _ = _throughput(lambda: fpd.decode_pages(plans, use_pallas=True))
    rows.append(dict(table="K", name="stream_decode64_interpret",
                     mbps=x64.nbytes / s / 1e6, n=len(x64), pages=n_pages))
    s, _ = _throughput(lambda: fpd.decode_pages(plans, use_pallas=False))
    rows.append(dict(table="K", name="stream_decode64_ref",
                     mbps=x64.nbytes / s / 1e6, n=len(x64), pages=n_pages))

    p32, st32 = fp_delta_encode(x32)
    stream = fpd.encode(x32, use_pallas=False)
    mini_bytes = stream.compact_bits() / 8
    rows.append(dict(table="K", name="miniblock_vs_exact_penalty",
                     exact_bytes=len(p32), mini_bytes=int(mini_bytes),
                     penalty_pct=100.0 * (mini_bytes / len(p32) - 1.0)))

    n = min(len(x32), 64 * 1024)
    xs = x32[:n]
    s, _ = _throughput(lambda a: fpd.encode(a, use_pallas=True), xs)
    rows.append(dict(table="K", name="pallas_encode_interpret", mbps=xs.nbytes / s / 1e6, n=n))
    st2 = fpd.encode(xs, use_pallas=True)
    s, _ = _throughput(lambda st_: fpd.decode(st_, use_pallas=True), st2)
    rows.append(dict(table="K", name="pallas_decode_interpret", mbps=xs.nbytes / s / 1e6, n=n))

    # flash attention oracle-vs-kernel micro check
    import jax, jax.numpy as jnp
    from repro.kernels.flash_attention import attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (1, 4, 256, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (1, 4, 256, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (1, 4, 256, 64)).astype(np.float32))
    o_ref = attention(q, k, v, causal=True, use_pallas=False)
    o_pal = attention(q, k, v, causal=True, use_pallas=True)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    rows.append(dict(table="K", name="flash_attention_maxerr", err=err))
    return rows


def summarize(rows) -> list[str]:
    out = ["# Kernel plane"]
    for r in rows:
        extras = {k: v for k, v in r.items() if k not in ("table", "name")}
        out.append(f"K {r['name']}: " + " ".join(f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}" for k, v in extras.items()))
    return out

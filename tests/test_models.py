"""Per-architecture smoke tests (assignment requirement) + decode equivalence.

Every assigned arch instantiates its REDUCED config and runs one forward +
one train step on CPU asserting output shapes and finiteness; representative
archs additionally check that prefill+decode match the full forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, get_config
from repro.models.model import build_model
from repro.train.optimizer import OptConfig, opt_init, opt_update

B, S = 2, 64


def _batch(cfg, rng_np, seq=S, batch=B):
    out = {"tokens": rng_np.integers(0, cfg.vocab, (batch, seq)).astype(np.int32)}
    if cfg.family == "encdec":
        out["frames"] = rng_np.normal(
            0, 1, (batch, seq // cfg.frontend_downsample, cfg.frontend_dim or cfg.d_model)
        ).astype(np.float32)
    if cfg.family == "vlm":
        out["tokens"] = out["tokens"][:, : seq - cfg.vision_tokens]
        out["patches"] = rng_np.normal(0, 1, (batch, cfg.vision_tokens, cfg.frontend_dim)).astype(np.float32)
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, aux, _ = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    # one full train step (grad + optimizer update)
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = opt_init(oc, params)
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    assert np.isfinite(float(loss))
    gnorms = [float(jnp.abs(g).max()) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(gnorms)), "non-finite grads"
    new_params, _, _ = opt_update(oc, params, grads, opt)
    # params actually changed
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch", ["qwen3-8b", "minicpm3-4b", "zamba2-1.2b",
                                  "mamba2-130m", "whisper-medium", "pixtral-12b"])
def test_decode_matches_forward(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, rng, seq=32)
    toks = batch["tokens"]
    logits_full, _, _ = model.forward(params, batch)
    cache = model.init_cache(B, 64)
    pre = dict(batch, tokens=toks[:, :-1])
    _, cache = model.forward_with_cache(params, pre, cache)
    step_logits, _ = model.decode_step(params, toks[:, -1:], cache)
    a = np.asarray(logits_full[:, -1])
    b = np.asarray(step_logits[:, -1])
    rel = np.max(np.abs(a - b)) / max(np.max(np.abs(a)), 1e-6)
    assert rel < 2e-3, rel


@pytest.mark.parametrize("arch", ["arctic-480b", "qwen2-moe-a2.7b"])
def test_moe_decode_dropless(arch, rng):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts))
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = rng.integers(0, cfg.vocab, (B, 32)).astype(np.int32)
    logits_full, _, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, 64)
    _, cache = model.forward_with_cache(params, {"tokens": toks[:, :-1]}, cache)
    step_logits, _ = model.decode_step(params, toks[:, -1:], cache)
    rel = np.max(np.abs(np.asarray(logits_full[:, -1]) - np.asarray(step_logits[:, -1])))
    assert rel / max(np.max(np.abs(np.asarray(logits_full[:, -1]))), 1e-6) < 2e-3


def test_full_configs_match_assignment():
    """The exact dims from the assignment table."""
    spec = {
        "whisper-medium": dict(n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
                               d_ff=4096, vocab=51865),
        "minicpm3-4b": dict(n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
                            d_ff=6400, vocab=73448),
        "granite-20b": dict(n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
                            d_ff=24576, vocab=49152),
        "qwen3-8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
                         d_ff=12288, vocab=151936, qk_norm=True),
        "internlm2-1.8b": dict(n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
                               d_ff=8192, vocab=92544),
        "zamba2-1.2b": dict(n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
                            d_ff=8192, vocab=32000),
        "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
                            vocab=32000),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
                                vocab=151936),
        "mamba2-130m": dict(n_layers=24, d_model=768, vocab=50280),
        "pixtral-12b": dict(n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
                            d_ff=14336, vocab=131072),
    }
    for arch, want in spec.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert get_config("arctic-480b").moe.n_experts == 128
    assert get_config("arctic-480b").moe.top_k == 2
    assert get_config("qwen2-moe-a2.7b").moe.n_experts == 60
    assert get_config("qwen2-moe-a2.7b").moe.top_k == 4
    assert get_config("mamba2-130m").ssm.d_state == 128
    assert get_config("zamba2-1.2b").ssm.d_state == 64
    assert len(ASSIGNED) == 10

"""Sharded dataset layer: manifest, shard pruning, async scans, stats merge."""

import json
import os

import numpy as np
import pytest

from repro.core.reader import ReadStats, SpatialParquetReader
from repro.core.writer import write_file
from repro.data.synthetic import PORTO_BBOX, porto_taxi_like
from repro.dataset import (
    DatasetIndex,
    DatasetManifest,
    SpatialDatasetScanner,
    is_dataset,
    shard_path,
    write_dataset,
)


def _cols_and_extra(n_traj=400, seed=7):
    cols = porto_taxi_like(n_traj=n_traj, seed=seed)
    return cols, {"tid": np.arange(cols.n_records, dtype=np.int64)}


def _grid_bboxes(n=3):
    """n x n cells over Porto + full extent, None, and a far-away miss."""
    x0, y0, x1, y1 = PORTO_BBOX
    xs = np.linspace(x0, x1, n + 1)
    ys = np.linspace(y0, y1, n + 1)
    boxes = [
        (xs[i], ys[j], xs[i + 1], ys[j + 1]) for i in range(n) for j in range(n)
    ]
    boxes.append(PORTO_BBOX)           # full extent
    boxes.append((50.0, 50.0, 51.0, 51.0))  # empty: far from Porto
    boxes.append(None)                 # no filter
    return boxes


# ------------------------------------------------------------ ReadStats merge
def test_readstats_merge_arithmetic():
    a = ReadStats(pages_total=10, pages_read=4, bytes_total=1000, bytes_read=400,
                  records_scanned=40, records_returned=30, shards_total=2,
                  shards_read=1)
    b = ReadStats(pages_total=6, pages_read=6, bytes_total=600, bytes_read=600,
                  records_scanned=60, records_returned=60, shards_total=1,
                  shards_read=1)
    for m in (a + b, a.merge(b), sum([a, b])):
        assert m.pages_total == 16 and m.pages_read == 10
        assert m.bytes_total == 1600 and m.bytes_read == 1000
        assert m.records_scanned == 100 and m.records_returned == 90
        assert m.shards_total == 3 and m.shards_read == 2
        assert m.pages_skipped == 6 and m.shards_skipped == 1
    # pages_skipped aggregates: (10-4) + (6-6) == sum of parts
    assert (a + b).pages_skipped == a.pages_skipped + b.pages_skipped
    # identity for sum() and original operands untouched
    assert sum([a]) is a
    assert a.pages_total == 10 and b.pages_total == 6
    with pytest.raises(TypeError):
        a + 5


# ------------------------------------------------------------------ manifest
def test_write_dataset_manifest_roundtrip(tmp_path):
    cols, extra = _cols_and_extra()
    root = tmp_path / "lake"
    m = write_dataset(root, columns=cols, extra=extra, n_shards=4,
                      sort="hilbert", page_values=2048)
    assert is_dataset(root)
    loaded = DatasetManifest.load(root)
    assert loaded.n_shards == 4
    assert loaded.n_records == cols.n_records == m.n_records
    assert loaded.n_values == cols.n_values
    assert loaded.sort == "hilbert"
    assert loaded.extra_schema == {"tid": "<i8"}
    assert loaded.coord_dtype == np.dtype(cols.x.dtype).str
    # the manifest is plain JSON on disk
    with open(os.path.join(root, "manifest.json")) as fh:
        raw = json.load(fh)
    assert raw["format"] == "spatial-parquet-dataset"
    # per-shard entries match the shard files they describe
    for s in loaded.shards:
        p = shard_path(root, s)
        assert os.path.getsize(p) == s.file_bytes
        with SpatialParquetReader(p) as r:
            assert r.n_records == s.n_records
            assert len(r.index) == s.n_pages
            g, _, _ = r.read_columnar()
            assert s.mbr == pytest.approx(
                (g.x.min(), g.y.min(), g.x.max(), g.y.max())
            )
    # union MBR covers every coordinate
    mbr = loaded.mbr
    assert mbr[0] <= cols.x.min() and mbr[2] >= cols.x.max()
    assert mbr[1] <= cols.y.min() and mbr[3] >= cols.y.max()


def test_dataset_fewer_records_than_shards(tmp_path):
    cols, _ = _cols_and_extra(n_traj=3)
    m = write_dataset(tmp_path / "tiny", columns=cols, n_shards=8)
    assert m.n_shards == 3  # empty tails skipped
    geo, _, st = SpatialDatasetScanner(tmp_path / "tiny").scan()
    assert geo.n_records == 3
    assert st.shards_total == st.shards_read == 3


# -------------------------------------------------------- dataset-level index
def test_dataset_index_query_matches_bruteforce(tmp_path):
    cols, _ = _cols_and_extra()
    m = write_dataset(tmp_path / "lake", columns=cols, n_shards=6,
                      sort="hilbert", page_values=2048)
    idx = DatasetIndex(m)
    for bbox in _grid_bboxes():
        hit = idx.query(bbox)
        if bbox is None:
            expect = list(range(m.n_shards))
        else:
            qx0, qy0, qx1, qy1 = bbox
            expect = [
                i for i, s in enumerate(m.shards)
                if s.mbr[0] <= qx1 and s.mbr[2] >= qx0
                and s.mbr[1] <= qy1 and s.mbr[3] >= qy0
            ]
        assert list(hit) == expect
        # shard_runs is symmetric to page_runs: consecutive cover of hit
        runs = idx.shard_runs(bbox, hit=hit)
        covered = [i for s0, s1 in runs for i in range(s0, s1)]
        assert covered == expect
        assert all(s1 > s0 for s0, s1 in runs)
    assert idx.selectivity(None) == 1.0
    assert idx.selectivity((50.0, 50.0, 51.0, 51.0)) == 0.0


# -------------------------------------------- single-file vs K-shard datasets
@pytest.mark.parametrize("n_shards", [1, 4])
def test_dataset_equals_single_file(tmp_path, n_shards):
    """Same records as 1 file and K shards: identical geometry + pruning."""
    cols, extra = _cols_and_extra()
    single = os.path.join(tmp_path, "single.spqf")
    write_file(single, columns=cols, extra=extra, sort="hilbert",
               page_values=2048, extra_schema={"tid": "<i8"})
    root = tmp_path / f"lake{n_shards}"
    write_dataset(root, columns=cols, extra=extra, n_shards=n_shards,
                  sort="hilbert", page_values=2048)
    sc = SpatialDatasetScanner(root)
    with SpatialParquetReader(single) as r:
        for bbox in _grid_bboxes():
            g1, e1, s1 = r.read_columnar(bbox=bbox, refine=True)
            g2, e2, s2 = sc.scan(bbox=bbox, refine=True)
            if g1 is None or g1.n_records == 0:
                assert g2 is None or g2.n_records == 0
                continue
            # identical record sets; the global-SFC-sorted sharding even
            # preserves record order, so arrays match bit-for-bit
            assert np.array_equal(g1.x, g2.x)
            assert np.array_equal(g1.y, g2.y)
            assert np.array_equal(g1.types, g2.types)
            assert np.array_equal(g1.rep, g2.rep)
            assert np.array_equal(g1.defn, g2.defn)
            assert np.array_equal(e1["tid"], e2["tid"])
            assert s1.records_returned == s2.records_returned
            if n_shards == 1:
                # one shard holds the same pages as the single file:
                # pruning decisions must be identical
                assert s1.pages_read == s2.pages_read
                assert s1.pages_total == s2.pages_total
                assert s1.bytes_read == s2.bytes_read
                assert s1.bytes_total == s2.bytes_total


def test_async_scan_bit_identical_to_sequential(tmp_path):
    cols, extra = _cols_and_extra(n_traj=600)
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, n_shards=5,
                  sort="hilbert", page_values=2048)
    sc = SpatialDatasetScanner(root, max_workers=4)
    for bbox in (None, PORTO_BBOX,
                 (PORTO_BBOX[0], PORTO_BBOX[1],
                  (PORTO_BBOX[0] + PORTO_BBOX[2]) / 2,
                  (PORTO_BBOX[1] + PORTO_BBOX[3]) / 2)):
        gp, ep, sp = sc.scan(bbox=bbox, parallel=True)
        gs, es, ss = sc.scan(bbox=bbox, parallel=False)
        assert np.array_equal(gp.x, gs.x) and np.array_equal(gp.y, gs.y)
        assert gp.x.tobytes() == gs.x.tobytes()  # bit-identical coordinates
        assert np.array_equal(ep["tid"], es["tid"])
        assert sp == ss


def test_shard_pruning_reads_strictly_fewer_bytes(tmp_path):
    cols, _ = _cols_and_extra(n_traj=800)
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, n_shards=6, sort="hilbert",
                  page_values=2048)
    sc = SpatialDatasetScanner(root)
    _, _, full = sc.scan()
    assert full.shards_read == full.shards_total == 6
    assert full.bytes_read == full.bytes_total
    # a corner query must drop whole shards, and the aggregate ReadStats
    # must show it: same denominator, strictly smaller numerator
    corner = (PORTO_BBOX[0], PORTO_BBOX[1],
              PORTO_BBOX[0] + 0.05, PORTO_BBOX[1] + 0.04)
    _, _, st = sc.scan(bbox=corner)
    assert st.shards_total == 6 and 0 < st.shards_read < 6
    assert st.bytes_total == full.bytes_total
    assert st.pages_total == full.pages_total
    assert st.bytes_read < full.bytes_read
    assert st.pages_read < full.pages_read
    # a miss reads nothing but still accounts for the whole dataset
    _, _, miss = sc.scan(bbox=(50.0, 50.0, 51.0, 51.0))
    assert miss.shards_read == 0 and miss.bytes_read == 0
    assert miss.bytes_total == full.bytes_total


def test_scanner_column_projection(tmp_path):
    cols, extra = _cols_and_extra()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, n_shards=3,
                  sort="hilbert", page_values=2048)
    sc = SpatialDatasetScanner(root)
    geo, ex, _ = sc.scan(columns=("geometry",))
    assert geo is not None and ex == {}
    geo, ex, st = sc.scan(columns=("tid",))
    assert geo is None
    assert np.array_equal(np.sort(ex["tid"]), np.arange(cols.n_records))
    assert st.records_returned == cols.n_records


def test_scanner_object_read(tmp_path):
    cols, _ = _cols_and_extra(n_traj=40)
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, n_shards=2, sort="hilbert")
    geoms, st = SpatialDatasetScanner(root).read()
    assert len(geoms) == 40 == st.records_returned

"""Adversarial FP-delta round-trips and codec guard-rails (no hypothesis).

Exercises every escape-resolution path of the vectorized decoder: the
no-escape fast path, the sparse fixpoint, the dense candidate scan, and the
``out=`` in-place contract used by the coalesced reader.
"""

import numpy as np
import pytest

from repro.core.fp_delta import (
    fp_delta_decode,
    fp_delta_encode,
    fp_delta_encode_pages,
    unzigzag,
)
from repro.core.pages import (
    CodecUnavailable,
    PageMeta,
    decode_page,
    encode_page,
    have_codec,
)


def _ibits(x):
    return x.view(np.int64 if x.dtype.itemsize == 8 else np.int32)


def roundtrip(x, n_bits=None):
    payload, st_ = fp_delta_encode(x, n_bits=n_bits)
    y = fp_delta_decode(payload, len(x), x.dtype)
    assert np.array_equal(_ibits(x), _ibits(y)), "roundtrip not bit-exact"
    # the out= path must produce the identical bits in the caller's buffer
    out = np.empty(len(x), dtype=x.dtype)
    y2 = fp_delta_decode(payload, len(x), x.dtype, out=out)
    assert y2 is out
    assert np.array_equal(_ibits(x), _ibits(out)), "out= decode not bit-exact"
    return st_


# ------------------------------------------------------- escape-marker edges
@pytest.mark.parametrize("n", [1, 2, 5, 13, 31, 63])
def test_value_equal_to_marker_escapes(n):
    # a delta whose zigzag is exactly the all-ones marker must escape
    marker_delta = unzigzag(np.array([(1 << n) - 1], np.uint64), 64)[0]
    base = np.int64(1000)
    x = np.array([base, base + marker_delta, base, base + marker_delta], np.int64)
    st_ = roundtrip(x, n_bits=n)
    assert st_.n_resets >= 2


@pytest.mark.parametrize("width,dtype", [(64, np.int64), (32, np.int32)])
def test_n_equals_width_minus_one(width, dtype, rng):
    x = rng.integers(-(2 ** (width - 2)), 2 ** (width - 2), 500).astype(dtype)
    roundtrip(x, n_bits=width - 1)


def test_single_value():
    for v in (3.14, -0.0, np.nan, np.inf):
        x = np.array([v], np.float64)
        p, st_ = fp_delta_encode(x)
        y = fp_delta_decode(p, 1, np.float64)
        assert np.array_equal(_ibits(x), _ibits(y))
        assert st_.n_bits == 0  # a lone value always stores raw


def test_nan_inf_coordinates(rng):
    x = rng.normal(0, 1, 64)
    x[::7] = np.nan
    x[3::11] = np.inf
    x[5::13] = -np.inf
    x[8] = -0.0
    roundtrip(x)


def test_empty_page():
    p, st_ = fp_delta_encode(np.zeros(0, np.float64))
    assert p == b"" and st_.n_values == 0
    assert len(fp_delta_decode(p, 0, np.float64)) == 0


@pytest.mark.parametrize("n_bits", [1, 2, 3])
def test_reset_dense_streams(n_bits, rng):
    # forcing a tiny n makes nearly every delta escape: the dense candidate
    # scan must still resolve every marker exactly
    x = rng.integers(-10**9, 10**9, 4000).astype(np.int64)
    st_ = roundtrip(x, n_bits=n_bits)
    assert st_.n_resets > 0.9 * (len(x) - 1)


def test_alternating_dense_sparse_segments(rng):
    # long smooth runs interrupted by jumps: mixes inline runs and escapes
    parts = []
    for i in range(20):
        base = rng.integers(-2**60, 2**60)
        parts.append(base + np.arange(200, dtype=np.int64) * (i + 1))
    x = np.concatenate(parts)
    st_ = roundtrip(x)
    assert st_.n_resets >= 19  # at least one escape per jump


def test_escape_raw_value_full_of_ones(rng):
    # raw escape values that are nearly all 1-bits try to fool the marker
    # scanner with fake candidate runs straddling the raw region
    x = np.array([0, -1, 0, -1, 2**40, -1, -2], np.int64)
    for n in (3, 7, 15):
        roundtrip(x, n_bits=n)


def test_float32_roundtrip_with_escapes(rng):
    x = np.cumsum(rng.normal(0, 1e-3, 10_000)).astype(np.float32)
    x[::97] = rng.normal(0, 1e30, len(x[::97])).astype(np.float32)
    roundtrip(x)


def test_out_must_match_shape_and_dtype():
    p, _ = fp_delta_encode(np.arange(8, dtype=np.float64))
    with pytest.raises(ValueError):
        fp_delta_decode(p, 8, np.float64, out=np.empty(7, np.float64))
    with pytest.raises(ValueError):
        fp_delta_decode(p, 8, np.float64, out=np.empty(8, np.float32))
    with pytest.raises(ValueError):
        fp_delta_decode(p, 8, np.float64, out=np.empty(16, np.float64)[::2])


def test_out_validated_before_payload_parse():
    # a bad out= buffer must raise ValueError even when the payload is
    # garbage or empty — validation happens before any byte is parsed
    with pytest.raises(ValueError):
        fp_delta_decode(b"", 0, np.float64, out=np.empty(3, np.float64))
    with pytest.raises(ValueError):
        fp_delta_decode(b"\xff", 2, np.float64, out=np.empty(2, np.int64))
    with pytest.raises(ValueError):
        fp_delta_decode(b"\xff", 2, np.float64, out=np.empty((2, 1), np.float64))


def test_decode_page_raw_out_strict():
    # regression: the raw-page out= path used to silently value-cast a
    # wrong-dtype buffer (e.g. float32 <- float64) instead of raising
    vals = np.arange(6, dtype=np.float64)
    buf, _ = encode_page(vals, "raw", "none")
    meta = PageMeta(offset=0, nbytes=len(buf), count=6, rec_start=0,
                    rec_count=6, vmin=0.0, vmax=5.0, encoding="raw",
                    n_bits=0, n_resets=0)
    with pytest.raises(ValueError):
        decode_page(buf, meta, np.float64, "none", out=np.empty(6, np.float32))
    with pytest.raises(ValueError):
        decode_page(buf, meta, np.float64, "none", out=np.empty(5, np.float64))
    with pytest.raises(ValueError):
        decode_page(buf, meta, np.float64, "none",
                    out=np.empty(12, np.float64)[::2])
    out = np.empty(6, np.float64)
    assert decode_page(buf, meta, np.float64, "none", out=out) is out
    assert np.array_equal(out, vals)


def test_decode_into_slice_of_larger_buffer(rng):
    x = np.round(np.cumsum(rng.normal(0, 1e-4, 1000)), 6)
    p, _ = fp_delta_encode(x)
    big = np.zeros(3000, np.float64)
    fp_delta_decode(p, 1000, np.float64, out=big[1000:2000])
    assert np.array_equal(big[1000:2000], x)
    assert (big[:1000] == 0).all() and (big[2000:] == 0).all()


def test_batch_encode_matches_per_page(rng):
    x = np.round(np.cumsum(rng.normal(0, 1e-4, 20_000)) - 8.6, 6)
    bounds = [(0, 1), (1, 5000), (5000, 5000), (5000, 13117), (13117, 20_000)]
    for (bp, bst), (v0, v1) in zip(fp_delta_encode_pages(x, bounds), bounds):
        sp, sst = fp_delta_encode(x[v0:v1])
        assert bp == sp and bst == sst, (v0, v1)


# --------------------------------------------------------------- codec guard
def test_unknown_codec_rejected():
    with pytest.raises(ValueError):
        encode_page(np.arange(4.0), "fp_delta", "lz77")


def test_codec_unavailable_is_clear():
    if have_codec("zstd"):
        pytest.skip("zstandard installed; unavailability path not reachable")
    with pytest.raises(CodecUnavailable):
        encode_page(np.arange(4.0), "fp_delta", "zstd")
    meta = PageMeta(offset=0, nbytes=4, count=4, rec_start=0, rec_count=4,
                    vmin=0.0, vmax=3.0, encoding="raw", n_bits=0, n_resets=0)
    with pytest.raises(CodecUnavailable):
        decode_page(b"\x00" * 4, meta, np.float64, "zstd")

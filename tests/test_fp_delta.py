"""FP-delta codec: paper Algorithms 1-3. Property tests via hypothesis.

``hypothesis`` is optional: without it, the property tests run fixed
deterministic samples (seeded numpy rng) instead of being skipped. The
structured/adversarial edge cases live in test_codec_edge.py and never
needed hypothesis.
"""

import numpy as np
import pytest

from repro.core.fp_delta import (
    compute_best_delta_bits,
    delta_bit_histogram,
    encoded_size_bits,
    fp_delta_decode,
    fp_delta_encode,
    significant_bits,
    unzigzag,
    zigzag,
)

try:
    from hypothesis import given, settings, strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional wheel
    HAVE_HYPOTHESIS = False

_SEEDS = [0, 1, 7, 42, 1234]


def _ibits(x):
    return x.view(np.int64 if x.dtype.itemsize == 8 else np.int32)


def roundtrip(x, n_bits=None):
    payload, st_ = fp_delta_encode(x, n_bits=n_bits)
    y = fp_delta_decode(payload, len(x), x.dtype)
    assert np.array_equal(_ibits(x), _ibits(y)), "roundtrip not bit-exact"
    return st_


def _random_floats(seed, dtype, max_size=300):
    """Mix of smooth, jumpy, and special-value floats (NaN/Inf included)."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(0, max_size + 1))
    smooth = np.cumsum(rng.normal(0, 1e-4, k))
    if np.dtype(dtype) == np.float32:  # keep wild values f32-representable
        with np.errstate(invalid="ignore"):  # signalling-NaN casts warn
            wild = rng.integers(0, 2**32, k, dtype=np.uint32).view(np.float32).astype(np.float64)
    else:
        wild = rng.integers(0, 2**64, k, dtype=np.uint64).view(np.float64)
    pick = rng.integers(0, 4, k)
    out = np.where(pick == 0, wild, smooth)
    out[pick == 2] = np.nan
    out[pick == 3] = np.inf * rng.choice([-1.0, 1.0], int((pick == 3).sum()))
    return out.astype(dtype)


def _check_nstar_is_optimal(x):
    nstar = compute_best_delta_bits(x)
    sizes = {n: encoded_size_bits(x, n) for n in range(0, 64)}
    assert sizes[nstar] == min(sizes.values())


if HAVE_HYPOTHESIS:
    @given(hyp_st.lists(hyp_st.floats(allow_nan=True, allow_infinity=True, width=64),
                        min_size=0, max_size=300))
    @settings(max_examples=200, deadline=None)
    def test_roundtrip_arbitrary_f64(vals):
        roundtrip(np.array(vals, dtype=np.float64))

    @given(hyp_st.lists(hyp_st.floats(allow_nan=True, allow_infinity=True, width=32),
                        min_size=0, max_size=300))
    @settings(max_examples=100, deadline=None)
    def test_roundtrip_arbitrary_f32(vals):
        roundtrip(np.array(vals, dtype=np.float32))

    @given(hyp_st.lists(hyp_st.integers(-2**63, 2**63 - 1), min_size=1, max_size=200),
           hyp_st.integers(1, 63))
    @settings(max_examples=100, deadline=None)
    def test_roundtrip_forced_width_i64(vals, n):
        roundtrip(np.array(vals, dtype=np.int64), n_bits=n)

    @given(hyp_st.integers(-2**63, 2**63 - 1))
    def test_zigzag_involution(v):
        z = zigzag(np.array([v], np.int64), 64)
        assert unzigzag(z, 64)[0] == v
        # zigzag maps small magnitudes to small unsigned values
        if -(2**30) < v < 2**30:
            assert int(z[0]) <= 2 * abs(v)

    @given(hyp_st.lists(hyp_st.floats(allow_nan=False, allow_infinity=False, width=64),
                        min_size=2, max_size=300))
    @settings(max_examples=100, deadline=None)
    def test_nstar_is_optimal(vals):
        _check_nstar_is_optimal(np.array(vals, dtype=np.float64))

    @given(hyp_st.lists(hyp_st.floats(allow_nan=False, allow_infinity=False, width=64),
                        min_size=2, max_size=200))
    @settings(max_examples=50, deadline=None)
    def test_histogram_totals(vals):
        x = np.array(vals, dtype=np.float64)
        h = delta_bit_histogram(x)
        assert h.sum() == len(x) - 1  # paper: sum h = |X| - 1
else:
    @pytest.mark.parametrize("seed", _SEEDS)
    def test_roundtrip_arbitrary_f64(seed):
        roundtrip(_random_floats(seed, np.float64))

    @pytest.mark.parametrize("seed", _SEEDS)
    def test_roundtrip_arbitrary_f32(seed):
        roundtrip(_random_floats(seed, np.float32))

    @pytest.mark.parametrize("seed", _SEEDS)
    def test_roundtrip_forced_width_i64(seed):
        rng = np.random.default_rng(seed)
        vals = rng.integers(-2**63, 2**63 - 1, 200, dtype=np.int64)
        for n in (1, 2, 7, 21, 40, 63):
            roundtrip(vals, n_bits=n)

    def test_zigzag_involution():
        vals = np.concatenate([
            np.array([0, 1, -1, 2**62, -(2**62), 2**63 - 1, -(2**63)], np.int64),
            np.random.default_rng(0).integers(-2**63, 2**63 - 1, 500, dtype=np.int64),
        ])
        z = zigzag(vals, 64)
        assert np.array_equal(unzigzag(z, 64), vals)
        small = vals[np.abs(vals) < 2**30]
        assert (zigzag(small, 64).astype(np.int64) <= 2 * np.abs(small)).all()

    @pytest.mark.parametrize("seed", _SEEDS)
    def test_nstar_is_optimal(seed):
        rng = np.random.default_rng(seed)
        _check_nstar_is_optimal(np.cumsum(rng.normal(0, 10.0 ** rng.integers(-9, 3), 300)))

    @pytest.mark.parametrize("seed", _SEEDS)
    def test_histogram_totals(seed):
        x = np.random.default_rng(seed).normal(0, 1, 200)
        h = delta_bit_histogram(x)
        assert h.sum() == len(x) - 1  # paper: sum h = |X| - 1


# ---------------------------------------------------------------- structured
def test_gps_like_compresses(rng):
    x = np.round(np.cumsum(rng.normal(0, 1e-4, 50_000)) + 41.15, 6)
    st_ = roundtrip(x)
    assert st_.payload_bits < 0.75 * 64 * len(x), "should beat raw storage"


def test_payload_matches_cost_model(rng):
    x = np.cumsum(rng.normal(0, 1e-5, 10_000)) - 8.6
    n = compute_best_delta_bits(x)
    _, st_ = fp_delta_encode(x)
    assert st_.payload_bits == encoded_size_bits(x, n)


def test_raw_mode_on_random_bits(rng):
    x = rng.integers(-2**63, 2**63 - 1, 4096, dtype=np.int64).view(np.float64)
    st_ = roundtrip(x)
    assert st_.n_bits == 0  # optimizer must choose raw mode


def test_constant_column():
    x = np.full(10_000, -73.98542, dtype=np.float64)
    st_ = roundtrip(x)
    # all-zero deltas pack at n*=1: ~1 bit/value (the paper leaves RLE-after-
    # delta as future work in §5.2; a 64x saving nonetheless)
    assert st_.n_bits == 1
    assert st_.payload_bits < 1.2 * len(x) + 128


def test_significant_bits_exact():
    vals = np.array([0, 1, 2, 3, 4, 255, 256, 2**52, 2**63 - 1], np.uint64)
    exp = [0, 1, 2, 2, 3, 8, 9, 53, 63]
    assert list(significant_bits(vals, 64)) == exp


def test_marker_collision_escapes():
    # craft deltas equal to the all-ones marker at n bits
    n = 5
    marker_delta = unzigzag(np.array([(1 << n) - 1], np.uint64), 64)[0]
    base = np.int64(1000)
    x = np.array([base, base + marker_delta, base], np.int64)
    roundtrip(x, n_bits=n)

"""Observability layer: tracer no-op guarantees, span attribution, export.

Three properties carry the whole subsystem and are pinned here:

1. **Disabled is free and invisible** — ``obs.span`` returns one shared
   singleton (no allocation), and a scan traced vs untraced returns
   bit-identical bytes.
2. **Attribution is correct** — spans nest by explicit parent ids, survive
   thread hand-offs (scanner workers, prefetch), and the fused device scan's
   trace covers every pipeline stage with per-shard / per-row-group args.
3. **The numbers are right** — histogram quantile estimates track numpy
   percentiles, stats folding matches the stats objects, and a skip-policy
   scan keeps the failed attempts' SourceStats (the silent-drop regression).
"""

import json
import os
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro import obs
from repro.core.columnar import from_ragged
from repro.core.reader import ReadStats, SpatialParquetReader
from repro.core.writer import write_file
from repro.dataset import SpatialDatasetScanner, write_dataset
from repro.io import LocalFileSource
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import NULL_SPAN, Tracer


@pytest.fixture
def rng():
    return np.random.default_rng(11)


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with telemetry disabled."""
    obs.disable()
    yield
    obs.disable()


def _point_cols(rng, n, spread=100.0):
    pts = np.round(rng.uniform(-spread, spread, (n, 2)), 6)
    return from_ragged(np.ones(n, np.uint8), pts,
                       np.ones(n, np.int64), np.ones(n, np.int64))


def _fingerprint(geo, extras):
    geo = geo.coords_to_host()
    parts = [np.asarray(getattr(geo, f)).tobytes()
             for f in ("types", "type_rep", "rep", "defn", "x", "y")]
    for k in sorted(extras):
        parts.append(np.asarray(extras[k]).tobytes())
    return b"".join(parts)


@pytest.fixture
def sample_file(rng, tmp_path):
    path = str(tmp_path / "obs.spqf")
    cols = _point_cols(rng, 4000)
    tag = rng.integers(0, 50, 4000).astype(np.int32)
    write_file(path, columns=cols, extra={"tag": tag},
               extra_schema={"tag": "<i4"}, page_values=512,
               sort="hilbert", row_group_records=1000)
    return path


@pytest.fixture
def lake(rng, tmp_path):
    root = str(tmp_path / "lake")
    os.makedirs(root)
    write_dataset(root, columns=_point_cols(rng, 6000), n_shards=4,
                  page_values=512)
    return root


# ------------------------------------------------------------ disabled = free
def test_disabled_span_is_shared_singleton():
    # no Span object is ever allocated while tracing is off
    assert obs.span("decode", shard=1) is NULL_SPAN
    assert obs.span("anything") is obs.span("else")
    assert obs.timed("io.read_s") is NULL_SPAN
    with obs.span("decode", rg=3) as sp:
        assert sp is NULL_SPAN
        sp.add(pages=7)  # attribute adds are absorbed
    assert obs.current_span() is None


def test_disabled_recorders_are_noops():
    obs.count("a", 5)
    obs.gauge("b", 1.0)
    obs.observe("c", 0.1)
    obs.instant("d")
    assert obs.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_disabled_submit_is_plain_submit():
    with ThreadPoolExecutor(max_workers=1) as pool:
        assert obs.submit(pool, lambda x: x + 1, 41).result() == 42


def test_reads_bit_identical_tracing_on_vs_off(sample_file):
    bbox = (-50.0, -50.0, 50.0, 50.0)
    with SpatialParquetReader(sample_file) as r:
        variants = [
            dict(),
            dict(bbox=bbox, refine=True),
            dict(bbox=bbox, refine=True, device="jax"),
        ]
        for kw in variants:
            g0, e0, s0 = r.read_columnar(**kw)
            obs.enable()
            try:
                g1, e1, s1 = r.read_columnar(**kw)
            finally:
                obs.disable()
            assert _fingerprint(g0, e0) == _fingerprint(g1, e1), kw
            assert s0.bytes_read == s1.bytes_read


def test_scan_bit_identical_tracing_on_vs_off(lake):
    sc = SpatialDatasetScanner(lake)
    bbox = (-60.0, -60.0, 60.0, 60.0)
    g0, e0, _ = sc.scan(bbox=bbox, refine=True, device="jax")
    obs.enable()
    try:
        g1, e1, _ = sc.scan(bbox=bbox, refine=True, device="jax")
    finally:
        obs.disable()
    assert _fingerprint(g0, e0) == _fingerprint(g1, e1)


# --------------------------------------------------- nesting + thread handoff
def test_span_nesting_parent_ids():
    tracer = obs.enable()
    with obs.span("outer") as outer:
        with obs.span("inner") as inner:
            assert inner.parent_id == outer.span_id
            assert obs.current_span() is inner
        assert obs.current_span() is outer
    obs.disable()
    ev = {e["name"]: e for e in tracer.spans()}
    assert ev["inner"]["args"]["parent_id"] == ev["outer"]["args"]["span_id"]
    assert ev["outer"]["args"]["parent_id"] == 0


def test_span_handoff_across_threads():
    tracer = obs.enable()
    with ThreadPoolExecutor(max_workers=2) as pool:
        with obs.span("parent") as parent:
            def worker(i):
                with obs.span("child", i=i) as c:
                    return c.parent_id, threading.get_ident()
            futs = [obs.submit(pool, worker, i) for i in range(4)]
            got = [f.result() for f in futs]
    obs.disable()
    # every child, on whatever thread, parents under the submitting span
    assert all(pid == parent.span_id for pid, _ in got)
    children = tracer.spans("child")
    assert len(children) == 4
    assert {e["args"]["i"] for e in children} == {0, 1, 2, 3}
    # real OS thread ids recorded (pool threads differ from main)
    assert {e["tid"] for e in children} <= {t for _, t in got}


def test_scanner_trace_per_shard_attribution(lake):
    sc = SpatialDatasetScanner(lake)
    tracer = obs.enable()
    try:
        sc.scan(bbox=None, refine=False)
    finally:
        obs.disable()
    ds = tracer.spans("scan.dataset")
    assert len(ds) == 1
    shards = tracer.spans("shard")
    assert {e["args"]["shard"] for e in shards} == {0, 1, 2, 3}
    # worker-thread shard spans all parent under the dataset span
    assert {e["args"]["parent_id"] for e in shards} == \
        {ds[0]["args"]["span_id"]}
    # row-group work attributes to a row group and nests under some span
    rgs = tracer.spans("rg.decode") + tracer.spans("rg.launch")
    assert rgs and all("rg" in e["args"] for e in rgs)


def test_fused_device_scan_trace_covers_stages(lake):
    sc = SpatialDatasetScanner(lake)
    bbox = (-60.0, -60.0, 60.0, 60.0)
    tracer = obs.enable()
    try:
        sc.scan(bbox=bbox, refine=True, device="jax")
    finally:
        obs.disable()
    names = {e["name"] for e in tracer.spans()}
    # plan → fetch → decode/refine launch → transfer, shard + file context
    assert {"scan.dataset", "shard", "scan.file", "rg.plan", "rg.fetch",
            "rg.launch"} <= names
    launches = tracer.spans("rg.launch")
    assert all("rg" in e["args"] for e in launches)
    snap = obs.snapshot()
    assert snap["counters"]["read.shards_read"] == 4
    assert "scan.dataset_latency_s" in snap["histograms"]
    assert "scan.latency_s" in snap["histograms"]
    assert snap["gauges"]["scan.host_cpu_s_per_gb"] > 0


# ------------------------------------------------------------------- export
def test_chrome_trace_export_roundtrip(tmp_path, lake):
    sc = SpatialDatasetScanner(lake)
    tracer = obs.enable()
    try:
        sc.scan()
    finally:
        obs.disable()
    out = str(tmp_path / "trace.json")
    tracer.export(out, metrics=obs.snapshot())
    with open(out) as fh:
        doc = json.load(fh)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    # schema: every event carries the chrome trace-event required fields
    for ev in events:
        assert {"name", "ph", "pid"} <= set(ev)
        assert ev["ph"] in ("X", "i", "M")
        if ev["ph"] == "X":
            assert ev["ts"] >= 0 and ev["dur"] >= 0
            assert {"span_id", "parent_id"} <= set(ev["args"])
    # thread metadata names the worker threads
    meta = [e for e in events if e["ph"] == "M"]
    assert meta and all(e["name"] == "thread_name" for e in meta)
    # the metrics snapshot rides along without breaking the trace shape
    assert "counters" in doc["metrics"]


def test_tracer_summary_aggregates():
    tracer = Tracer()
    for i in range(3):
        span = type("S", (), {"name": "stage", "cat": "x", "args": {},
                              "span_id": i + 1, "parent_id": 0})()
        tracer._complete(span, 0, 1000 * (i + 1))
    (row,) = tracer.summary()
    assert row["name"] == "stage" and row["count"] == 3
    assert row["total_ms"] == pytest.approx(0.006)
    assert row["max_ms"] == pytest.approx(0.003)


# ------------------------------------------------------------------ metrics
def test_histogram_quantiles_track_numpy(rng):
    h = Histogram("lat")
    samples = rng.lognormal(mean=-4.0, sigma=1.5, size=5000)
    for v in samples:
        h.observe(v)
    for q in (0.5, 0.9, 0.99):
        est = h.quantile(q)
        exact = float(np.percentile(samples, q * 100))
        assert est == pytest.approx(exact, rel=0.15), q
    snap = h.snapshot()
    assert snap["count"] == 5000
    assert snap["min"] == pytest.approx(samples.min())
    assert snap["max"] == pytest.approx(samples.max())


def test_histogram_edges():
    h = Histogram("x")
    assert np.isnan(h.quantile(0.5))
    h.observe(0.01)
    # one observation: every quantile collapses to it (clamped bounds)
    assert h.quantile(0.0) == pytest.approx(0.01)
    assert h.quantile(1.0) == pytest.approx(0.01)
    # out-of-range values land in clamped under/overflow buckets
    h2 = Histogram("y", bounds=[1.0, 2.0])
    h2.observe(0.5)
    h2.observe(10.0)
    assert h2.quantile(0.0) == pytest.approx(0.5)
    assert h2.quantile(1.0) == pytest.approx(10.0)


def test_histogram_extreme_quantiles_exact():
    """q=0 / q=1 return the exact observed extremes (no interpolation), and
    a single-bucket histogram still answers every quantile sanely."""
    h = Histogram("x", bounds=[0.0, 100.0])  # one real bucket
    for v in (3.0, 7.0, 50.0):
        h.observe(v)
    assert h.quantile(0.0) == 3.0
    assert h.quantile(1.0) == 50.0
    assert 3.0 <= h.quantile(0.5) <= 50.0
    with pytest.raises(ValueError):
        h.quantile(-0.1)
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_huge_counts_no_float_drift():
    """Bucket totals past 2**53: a float accumulator would absorb small
    counts (cum + c == cum) and push every quantile into the last bucket;
    the exact integer accumulation must keep low quantiles in the first."""
    h = Histogram("x", bounds=[0.0, 1.0, 2.0, 3.0])
    h._counts[1] = 3            # bucket [0, 1)
    h._counts[2] = 2**60        # bucket [1, 2)
    h._counts[3] = 5            # bucket [2, 3)
    h.count = 3 + 2**60 + 5
    h.min, h.max = 0.5, 2.5
    h.sum = float(h.count)
    # q tiny enough that the target falls inside the 3-count bucket
    q = 1.0 / float(h.count)
    assert 0.0 <= h.quantile(q) <= 1.0
    assert 1.0 <= h.quantile(0.5) <= 2.0
    # near-1 quantile interpolates inside the last bucket, clamped to max
    assert 2.0 <= h.quantile(1.0 - 1e-18) <= 2.5
    assert h.quantile(1.0) == 2.5


def test_fold_read_stats_counters():
    reg = MetricsRegistry()
    st = ReadStats(pages_total=10, pages_read=4, bytes_total=1000,
                   bytes_read=400, retries=2, cache_hits=3)
    reg.fold_read_stats(st)
    reg.fold_read_stats(st)  # accumulates across queries
    snap = reg.snapshot()
    assert snap["counters"]["read.pages_read"] == 8
    assert snap["counters"]["read.retries"] == 4
    assert snap["counters"]["read.cache_hits"] == 6
    # bools and non-numerics never become counters
    assert "read.failures" in snap["counters"]


# --------------------------------------------- satellite: failed-attempt stats
def test_skip_policy_keeps_failed_attempt_source_stats(lake):
    """A skipped shard's attempts did real I/O (and recoveries); their
    SourceStats deltas must fold into the aggregate, not vanish."""
    bad = {"n": 0}

    def factory(path):
        src = LocalFileSource(path)
        if path.endswith("shard-00000.spqf"):
            def boom(offset, nbytes, *, refresh=False):
                # a failing attempt that accrued recoveries before dying
                src.stats.requests += 1
                src.stats.retries += 3
                src.stats.timeouts += 1
                src.stats.cache_hits += 2
                src.stats.cache_misses += 5
                bad["n"] += 1
                raise IOError("injected failure")
            src.read_at = boom
            src.readinto_at = lambda off, buf: boom(off, len(buf))
        return src

    sc = SpatialDatasetScanner(lake, on_error="skip", shard_retries=1,
                               source_factory=factory)
    geo, _, st = sc.scan()
    assert bad["n"] >= 2  # both attempts really failed
    assert len(st.failures) == 1 and st.failures[0].shard_index == 0
    assert st.shards_read == 3 and geo is not None
    # the regression: every failed attempt's deltas are in the aggregate
    n = bad["n"]
    assert st.retries == 3 * n
    assert st.timeouts == 1 * n
    assert st.cache_hits == 2 * n
    assert st.cache_misses == 5 * n


def test_raise_policy_attaches_partial_stats(lake):
    def factory(path):
        src = LocalFileSource(path)
        if path.endswith("shard-00001.spqf"):
            def boom(offset, nbytes, *, refresh=False):
                src.stats.retries += 7
                raise IOError("injected failure")
            src.read_at = boom
            src.readinto_at = lambda off, buf: boom(off, len(buf))
        return src

    sc = SpatialDatasetScanner(lake, on_error="raise", source_factory=factory)
    with pytest.raises(Exception) as ei:
        sc.scan()
    cause = ei.value.__cause__
    assert getattr(cause, "spqf_source_stats").retries == 7

"""Differential/property suite for the on-device FP-delta page decode.

Three independent implementations must agree **bit-for-bit** on every
stream:

* host ``fp_delta_decode`` (numpy; the paper-exact oracle),
* ``decode_stream_ref`` (pure jnp; one flat global segmented scan),
* the Pallas kernel ``decode_stream_blocks`` in interpret mode (block-local
  scans + associative carry stitch — structurally different from the ref).

The grid covers token widths, escape densities (none / sparse / dense /
every-delta), page sizes around the kernel's STREAM_BLOCK, reset-segment
layouts, and multi-page streams mixing raw-mode pages in. Property tests
follow the PR 1 optional-deps convention: with ``hypothesis`` installed
they generate adversarial floats; without it they run fixed seeded samples
instead of being skipped.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.fp_delta import (
    fp_delta_decode,
    fp_delta_encode,
    fp_delta_execute,
    fp_delta_plan,
)
from repro.core.pages import ENC_FP_DELTA, PageMeta, page_plan
from repro.kernels.fp_delta import (
    STREAM_BLOCK,
    build_page_stream,
    decode_page_stream,
    decode_pages,
)

try:
    from hypothesis import given, settings, strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional wheel
    HAVE_HYPOTHESIS = False

_SEEDS = [0, 1, 7, 42, 1234]


def _ibits(x):
    return x.view(np.int64 if x.dtype.itemsize == 8 else np.int32)


def tri_decode(pages, n_bits=None):
    """Encode pages, then decode them through all three back ends and
    assert bitwise agreement. ``pages``: list of 1-D arrays (one stream)."""
    dtype = pages[0].dtype
    enc = [fp_delta_encode(p, n_bits=n_bits)[0] for p in pages]
    plans = [fp_delta_plan(e, len(p), dtype) for e, p in zip(enc, pages)]
    host = [fp_delta_decode(e, len(p), dtype) for e, p in zip(enc, pages)]
    for p, h in zip(pages, host):  # host decode must already round-trip
        assert np.array_equal(_ibits(p), _ibits(h))
    stream = build_page_stream(plans)
    ref_out = decode_page_stream(stream, use_pallas=False)
    pal_out = decode_page_stream(stream, use_pallas=True, interpret=True)
    got_ref = np.split(ref_out, np.cumsum(stream.counts)[:-1])
    got_pal = np.split(pal_out, np.cumsum(stream.counts)[:-1])
    for h, r_, k_ in zip(host, got_ref, got_pal):
        assert np.array_equal(_ibits(h), _ibits(r_)), "jnp oracle != host"
        assert np.array_equal(_ibits(h), _ibits(k_)), "Pallas kernel != host"
    return plans


def _page(rng, n, density, dtype):
    """One page of ``n`` values with the requested escape density."""
    x = (np.cumsum(rng.normal(0, 1e-4, n)) + 40.7).astype(dtype)
    if density == "none":
        return x
    if density == "sparse":
        hits = rng.integers(0, n, max(n // 500, 2))
        x[hits] = rng.normal(0, 1e30, len(hits)).astype(dtype)
        return x
    # "dense": wild bit patterns force an escape on nearly every delta
    if np.dtype(dtype) == np.float32:
        return rng.integers(0, 2**32, n, dtype=np.uint32).view(np.float32)
    return rng.integers(0, 2**64, n, dtype=np.uint64).view(np.float64)


# ------------------------------------------------------------ the main grid
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("density", ["none", "sparse", "dense"])
@pytest.mark.parametrize(
    "n", [1, 2, STREAM_BLOCK - 1, STREAM_BLOCK, STREAM_BLOCK + 1, 3000]
)
def test_stream_grid(rng, dtype, density, n):
    tri_decode([_page(rng, n, density, dtype)])


@pytest.mark.parametrize("dtype,n_bits", [
    (np.float32, 1), (np.float32, 5), (np.float32, 13), (np.float32, 31),
    (np.float64, 1), (np.float64, 13), (np.float64, 43), (np.float64, 63),
])
def test_stream_forced_widths(rng, dtype, n_bits):
    x = _page(rng, 2050, "sparse", dtype)
    plans = tri_decode([x], n_bits=n_bits)
    assert plans[0].n == n_bits


def test_stream_raw_mode(rng):
    # n_bits=0 is raw mode: no delta tokens, every value a W-bit anchor
    for dtype in (np.float32, np.float64):
        plans = tri_decode([_page(rng, 700, "dense", dtype)], n_bits=0)
        assert plans[0].n == 0 and plans[0].n_escapes == 0


# ----------------------------------------------------- reset-segment layouts
def _with_jumps(n, where, dtype=np.float64):
    x = np.linspace(1.0, 2.0, n).astype(dtype)
    x[np.asarray(where)] = np.asarray(
        [(-1e308 if i % 2 else 1e308) for i in range(len(where))], dtype)
    return x


@pytest.mark.parametrize("layout", [
    [1],                         # escape on the very first delta
    [2049],                      # escape on the last delta
    [700, 701, 702, 703],        # consecutive escapes (zero-length segments)
    list(range(1, 2050, 2)),     # alternating: every other delta escapes
    [1023, 1024, 1025],          # escapes straddling a kernel block boundary
])
def test_reset_segment_layouts(layout):
    x = _with_jumps(2050, layout)
    # force a real token width: dense layouts would otherwise make the n*
    # optimizer fall back to raw mode and sidestep the escape machinery
    plans = tri_decode([x], n_bits=13)
    assert plans[0].n_escapes >= len(layout) // 2


def test_marker_collision_escapes():
    # a delta whose zigzag equals the all-ones marker must escape (the
    # classic FP-delta corner); device decode must reproduce it exactly
    from repro.core.fp_delta import unzigzag
    n = 13
    marker_delta = unzigzag(np.array([(1 << n) - 1], np.uint64), 64)[0]
    base = np.int64(1 << 40)
    vals = np.empty(600, np.int64)
    vals[0::2] = base
    vals[1::2] = base + marker_delta
    plans = tri_decode([vals.view(np.float64)], n_bits=n)
    assert plans[0].n_escapes >= 250


def test_constant_run_and_single_segment(rng):
    tri_decode([np.full(2100, -17.25, np.float64)])
    tri_decode([np.full(STREAM_BLOCK * 2, 3.5, np.float32)])


# ----------------------------------------------------------- batched streams
def test_multi_page_stream_mixed(rng):
    """One launch over pages with different n*, raw-mode and empty pages."""
    pages, n_bits = [], []
    for k, nb in [(1, None), (STREAM_BLOCK - 1, None), (0, None),
                  (STREAM_BLOCK + 1, 0), (3000, 7), (2, None)]:
        pages.append(_page(rng, k, "sparse" if k > 2 else "none", np.float64))
        n_bits.append(nb)
    dtype = np.float64
    enc = [fp_delta_encode(p, n_bits=nb)[0] for p, nb in zip(pages, n_bits)]
    plans = [fp_delta_plan(e, len(p), dtype) for e, p in zip(enc, pages)]
    host = [fp_delta_decode(e, len(p), dtype) for e, p in zip(enc, pages)]
    for use_pallas in (False, True):
        outs = decode_pages(plans, use_pallas=use_pallas, interpret=True)
        assert len(outs) == len(plans)
        for h, o in zip(host, outs):
            assert np.array_equal(_ibits(h), _ibits(o))


def test_launch_chunking_and_oversized_page_fallback(rng, monkeypatch):
    """With a tiny launch cap, decode_pages must split pages across launches
    and host-decode any single page too large for one — same bits."""
    import repro.kernels.fp_delta.ops as fpd_ops

    pages = [_page(rng, n, "sparse", np.float64) for n in (900, 2000, 40, 1500)]
    enc = [fp_delta_encode(p)[0] for p in pages]
    plans = [fp_delta_plan(e, len(p), np.float64) for e, p in zip(enc, pages)]
    # cap below the largest page: forces multi-launch + the host fallback
    cap = (len(plans[1].words) - 1) * 64 - 1
    monkeypatch.setattr(fpd_ops, "_MAX_LAUNCH_BITS", cap)
    with pytest.raises(ValueError, match="per-launch cap"):
        build_page_stream([plans[1]])
    outs = decode_pages(plans, use_pallas=True, interpret=True)
    for p, o in zip(pages, outs):
        assert np.array_equal(_ibits(p), _ibits(o))


def test_mixed_width_stream_rejected(rng):
    p32 = fp_delta_plan(fp_delta_encode(_page(rng, 50, "none", np.float32))[0],
                        50, np.float32)
    p64 = fp_delta_plan(fp_delta_encode(_page(rng, 50, "none", np.float64))[0],
                        50, np.float64)
    with pytest.raises(ValueError, match="mixed widths"):
        build_page_stream([p32, p64])


# ----------------------------------------------------------------- plan API
def test_plan_matches_encoder_stats(rng):
    x = _page(rng, 4000, "sparse", np.float64)
    payload, st = fp_delta_encode(x)
    plan = fp_delta_plan(payload, len(x), np.float64)
    assert plan.n == st.n_bits
    assert plan.n_escapes == st.n_resets == int(plan.flags.sum())
    assert plan.n_values == len(x)
    # offsets strictly increase, and every escaped token is followed by a
    # W-bit raw value before the next token starts
    assert (np.diff(plan.offsets) > 0).all()
    gaps = np.diff(np.append(plan.offsets, st.payload_bits))
    assert (gaps[plan.flags] >= plan.n + 64).all()
    y = fp_delta_execute(plan)
    assert np.array_equal(_ibits(x), _ibits(y))


def test_page_plan_requires_fp_delta():
    meta = PageMeta(0, 8, 1, 0, 1, 0.0, 0.0, "raw", 0, 0)
    with pytest.raises(ValueError, match="fp_delta"):
        page_plan(b"\x00" * 8, meta, np.float64, "none")


# -------------------------------------------------------- reader-level diff
def test_reader_device_bit_identical_pt025(tmp_path):
    """Acceptance: read_columnar(device="jax") == host path on PT @ 0.25."""
    from repro.core.reader import SpatialParquetReader
    from repro.core.writer import write_file
    from repro.data.synthetic import DATASETS

    cols = DATASETS["PT"](n_traj=2000)  # PT @ 0.25 (SCALE_1 is 8000)
    path = tmp_path / "pt025.spqf"
    # small pages: the bbox below can prune, and one row group batches many
    # pages into a single device launch
    write_file(path, columns=cols, codec="none", sort="hilbert",
               page_values=4096)
    with SpatialParquetReader(path) as r:
        g0, e0, s0 = r.read_columnar()
        g1, e1, s1 = r.read_columnar(device="jax")
        assert np.array_equal(_ibits(g0.x), _ibits(g1.x))
        assert np.array_equal(_ibits(g0.y), _ibits(g1.y))
        assert np.array_equal(g0.types, g1.types)
        assert s0 == s1
        # pruned bbox read: device path must agree page-for-page
        x0, y0 = float(g0.x.min()), float(g0.y.min())
        bbox = (x0, y0, float(np.median(g0.x)), float(np.median(g0.y)))
        g2, _, s2 = r.read_columnar(bbox=bbox)
        g3, _, s3 = r.read_columnar(bbox=bbox, device="jax")
        assert s2.pages_read < s2.pages_total  # the bbox actually pruned
        assert np.array_equal(_ibits(g2.x), _ibits(g3.x))
        assert np.array_equal(_ibits(g2.y), _ibits(g3.y))
        assert s2 == s3
        with pytest.raises(ValueError, match="device"):
            r.read_columnar(device="tpu")


def test_reader_device_raw_and_float32(tmp_path):
    """Raw-encoded pages and float32 coords through the device path."""
    from repro.core.reader import SpatialParquetReader
    from repro.core.writer import write_file
    from repro.data.synthetic import DATASETS

    import dataclasses

    cols = DATASETS["eB"](n_points=3000)
    cols32 = dataclasses.replace(
        cols, x=cols.x.astype(np.float32), y=cols.y.astype(np.float32))
    for enc, dtype in [("raw", np.float64), ("fp_delta", np.float32)]:
        c = cols if dtype == np.float64 else cols32
        path = tmp_path / f"{enc}_{np.dtype(dtype).name}.spqf"
        write_file(path, columns=c, codec="none", encoding=enc)
        with SpatialParquetReader(path) as r:
            g0, _, _ = r.read_columnar()
            g1, _, _ = r.read_columnar(device="jax")
            assert np.array_equal(_ibits(g0.x), _ibits(g1.x))
            assert np.array_equal(_ibits(g0.y), _ibits(g1.y))


def test_dataset_scanner_device(tmp_path):
    from repro.data.synthetic import DATASETS
    from repro.dataset import SpatialDatasetScanner, write_dataset

    cols = DATASETS["PT"](n_traj=120)
    root = tmp_path / "ds"
    write_dataset(root, columns=cols, n_shards=3, sort="hilbert", codec="none")
    sc = SpatialDatasetScanner(root, max_workers=3)
    g0, _, s0 = sc.scan()
    g1, _, s1 = sc.scan(device="jax")
    assert np.array_equal(_ibits(g0.x), _ibits(g1.x))
    assert np.array_equal(_ibits(g0.y), _ibits(g1.y))
    assert s0 == s1
    x0, y0, x1, y1 = sc.manifest.mbr
    bbox = (x0, y0, x0 + (x1 - x0) / 3, y0 + (y1 - y0) / 3)
    g2, _, _ = sc.scan(bbox=bbox, parallel=False)
    g3, _, _ = sc.scan(bbox=bbox, device="jax")
    if g2 is not None:
        assert np.array_equal(_ibits(g2.x), _ibits(g3.x))


# ------------------------------------------------- adversarial property tests
def _device_roundtrip(x):
    payload, _ = fp_delta_encode(x)
    plan = fp_delta_plan(payload, len(x), x.dtype)
    host = fp_delta_decode(payload, len(x), x.dtype)
    assert np.array_equal(_ibits(x), _ibits(host))
    for use_pallas in (False, True):
        dev, = decode_pages([plan], use_pallas=use_pallas, interpret=True)
        assert np.array_equal(_ibits(x), _ibits(dev))


def _adversarial(seed, dtype, max_size=400):
    """NaN payloads, signed zeros/infs, denormals, constant runs,
    alternating-sign coordinates — the worst floats we can think of."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, max_size + 1))
    info = np.finfo(dtype)
    bits = np.uint32 if np.dtype(dtype) == np.float32 else np.uint64
    w = np.dtype(dtype).itemsize * 8
    nan_payload = (rng.integers(0, 2**w, k, dtype=bits)
                   | bits((2 ** (w - np.finfo(dtype).nmant - 1) - 1)
                          << np.finfo(dtype).nmant)).view(dtype)
    denorm = (rng.integers(0, 2 ** info.nmant, k, dtype=bits)).view(dtype)
    alt = (np.cumsum(rng.normal(0, 1e-3, k)) *
           np.where(np.arange(k) % 2 == 0, 1.0, -1.0)).astype(dtype)
    pool = np.stack([
        nan_payload, denorm, alt,
        np.full(k, rng.choice([0.0, -0.0, np.inf, -np.inf, 2.5])).astype(dtype),
    ])
    pick = rng.integers(0, pool.shape[0], k)
    return pool[pick, np.arange(k)]


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=hyp_st.integers(0, 2**32 - 1),
        dtype=hyp_st.sampled_from([np.float32, np.float64]),
    )
    def test_property_adversarial_roundtrip(seed, dtype):
        _device_roundtrip(_adversarial(seed, dtype))

    @settings(max_examples=15, deadline=None)
    @given(
        vals=hyp_st.lists(
            hyp_st.floats(width=64, allow_nan=True, allow_infinity=True,
                          allow_subnormal=True),
            min_size=1, max_size=200,
        )
    )
    def test_property_hypothesis_floats(vals):
        _device_roundtrip(np.array(vals, np.float64))

else:  # deterministic fallback, PR 1 convention: run, don't skip

    @pytest.mark.parametrize("seed", _SEEDS)
    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_property_adversarial_roundtrip(seed, dtype):
        _device_roundtrip(_adversarial(seed, dtype))

"""Coalesced-I/O read path: equivalence, syscall budget, stats accounting."""

import os
import tempfile

import numpy as np
import pytest

from repro.core.columnar import from_ragged
from repro.core.reader import SpatialParquetReader
from repro.core.writer import write_file
from tests.geom_helpers import random_geometry


def _point_cols(rng, n, spread=100.0):
    pts = np.round(rng.uniform(-spread, spread, (n, 2)), 6)
    return pts, from_ragged(np.ones(n, np.uint8), pts,
                            np.ones(n, np.int64), np.ones(n, np.int64))


def _write_sample(path, rng, n=20_000, **kw):
    pts, cols = _point_cols(rng, n)
    ts = rng.integers(0, 1 << 40, n)
    tag = rng.integers(0, 100, n).astype(np.int32)
    kw.setdefault("page_values", 1024)
    kw.setdefault("sort", "hilbert")
    write_file(path, columns=cols, extra={"ts": ts, "tag": tag},
               extra_schema={"ts": "<i8", "tag": "<i4"}, **kw)
    return pts


class CountingFile:
    """File proxy counting data-read syscalls (read/readinto)."""

    def __init__(self, fh):
        self._fh = fh
        self.reads = 0

    def read(self, *a):
        self.reads += 1
        return self._fh.read(*a)

    def readinto(self, b):
        self.reads += 1
        return self._fh.readinto(b)

    def __getattr__(self, name):
        return getattr(self._fh, name)


def _geo_equal(a, b):
    if a is None or b is None:
        return a is b
    return all(
        np.array_equal(getattr(a, f), getattr(b, f))
        for f in ("types", "type_rep", "rep", "defn", "x", "y")
    )


@pytest.mark.parametrize("bbox", [None, (-95.0, -95.0, -70.0, -70.0), (200.0, 200.0, 300.0, 300.0)])
def test_coalesced_matches_per_page(rng, bbox):
    p = tempfile.mktemp(".spqf")
    _write_sample(p, rng, row_group_records=6000)
    with SpatialParquetReader(p) as r:
        g1, e1, s1 = r.read_columnar(bbox=bbox, coalesce=True)
        g2, e2, s2 = r.read_columnar(bbox=bbox, coalesce=False)
    assert _geo_equal(g1, g2)
    for k in e1:
        assert np.array_equal(e1[k], e2[k]), k
    assert s1 == s2
    os.unlink(p)


def test_coalesced_matches_per_page_mixed_geoms(rng):
    geoms = [random_geometry(np.random.default_rng(s)) for s in range(300)]
    p = tempfile.mktemp(".spqf")
    write_file(p, geometries=geoms, row_group_records=100, page_values=64)
    with SpatialParquetReader(p) as r:
        g1, _, _ = r.read_columnar(coalesce=True)
        g2, _, _ = r.read_columnar(coalesce=False)
        back, _ = r.read()
    assert _geo_equal(g1, g2)
    assert back == geoms
    os.unlink(p)


def test_full_scan_is_one_read_per_row_group(rng):
    p = tempfile.mktemp(".spqf")
    _write_sample(p, rng, row_group_records=5000)  # 4 row groups
    with SpatialParquetReader(p) as r:
        n_groups = len(r.footer["row_groups"])
        assert n_groups == 4
        counter = CountingFile(r._source._fh)
        r._source._fh = counter
        geo, extras, _ = r.read_columnar()
        assert geo.n_records == 20_000
        # every row group's blobs are adjacent -> exactly one coalesced read
        assert counter.reads == n_groups, counter.reads
    os.unlink(p)


def test_pruned_read_syscalls_bounded_by_runs(rng):
    p = tempfile.mktemp(".spqf")
    _write_sample(p, rng, row_group_records=1 << 20)
    bbox = (-95.0, -95.0, -70.0, -70.0)
    with SpatialParquetReader(p) as r:
        runs = r.index.page_runs(bbox)
        assert len(runs) >= 1
        counter = CountingFile(r._source._fh)
        r._source._fh = counter
        geo, extras, st = r.read_columnar(bbox=bbox)
        assert st.pages_read < st.pages_total, "index should prune pages"
        # one range for the levels + at most 3 per run (x, y, extras merge
        # when adjacent); coalescing may merge further, never split
        max_ranges = 1 + 3 * len(runs)
        assert counter.reads <= max_ranges, (counter.reads, len(runs))
    os.unlink(p)


def test_page_runs_are_consecutive_and_cover_hits(rng):
    p = tempfile.mktemp(".spqf")
    _write_sample(p, rng, row_group_records=7000)
    bbox = (-50.0, -50.0, 20.0, 20.0)
    with SpatialParquetReader(p) as r:
        idx = r.index
        runs = idx.page_runs(bbox)
        hit = set(idx.query(bbox).tolist())
        covered = set()
        for rg, p0, p1 in runs:
            assert p1 > p0
            base = int(np.searchsorted(idx.row_group, rg))
            for page in range(p0, p1):
                covered.add(base + page)
        assert covered == hit
    os.unlink(p)


def test_bytes_read_counts_every_blob(rng):
    p = tempfile.mktemp(".spqf")
    _write_sample(p, rng, row_group_records=1 << 20)
    with SpatialParquetReader(p) as r:
        # full scan reads every blob: bytes_read must equal bytes_total
        _, _, st = r.read_columnar()
        assert st.bytes_read == st.bytes_total
        # geometry-only projection skips the extra pages
        _, _, st_geo = r.read_columnar(columns=("geometry",))
        assert 0 < st_geo.bytes_read < st.bytes_read
        # extras-only projection still accounts for what it reads
        _, extras, st_extra = r.read_columnar(columns=("ts",))
        assert len(extras["ts"]) == 20_000
        assert st_extra.bytes_read > 0
        assert st_extra.bytes_read < st_geo.bytes_read
        # and a pruned query reads strictly less than the full scan
        _, _, st_q = r.read_columnar(bbox=(-95.0, -95.0, -70.0, -70.0))
        assert 0 < st_q.bytes_read < st.bytes_read
    os.unlink(p)


def test_extras_only_projection_matches(rng):
    p = tempfile.mktemp(".spqf")
    rng2 = np.random.default_rng(5)
    pts, cols = _point_cols(rng2, 4000)
    ts = np.arange(4000, dtype=np.int64)
    write_file(p, columns=cols, extra={"ts": ts}, extra_schema={"ts": "<i8"},
               page_values=512)
    with SpatialParquetReader(p) as r:
        geo, extras, _ = r.read_columnar(columns=("ts",))
        assert geo is None
        assert np.array_equal(extras["ts"], ts)  # unsorted write: order kept
    os.unlink(p)


def test_index_entries_view_matches_arrays(rng):
    p = tempfile.mktemp(".spqf")
    _write_sample(p, rng, row_group_records=6000)
    with SpatialParquetReader(p) as r:
        idx = r.index
        entries = idx.entries
        assert len(entries) == len(idx)
        for i in (0, len(entries) // 2, len(entries) - 1):
            e = entries[i]
            assert e.row_group == int(idx.row_group[i])
            assert e.page == int(idx.page[i])
            assert e.rec_start == int(idx.rec_start[i])
            assert e.nbytes == int(idx.nbytes[i])
            assert e.bbox[0] <= e.bbox[2] and e.bbox[1] <= e.bbox[3]
    os.unlink(p)


def test_format_magic_and_footer_unchanged(rng):
    """v1 layout (checksums=False) is byte-compatible with the pre-checksum
    format; default writes are v2 (new magic, per-blob CRCs, footer CRC)."""
    from repro.core.writer import MAGIC, MAGIC_V2
    import struct

    # v1: explicit checksums=False keeps the original trailer exactly
    p = tempfile.mktemp(".spqf")
    _write_sample(p, rng, checksums=False)
    blob = open(p, "rb").read()
    assert blob.startswith(MAGIC) and blob.endswith(MAGIC)
    (flen,) = struct.unpack("<I", blob[-(len(MAGIC) + 4):-len(MAGIC)])
    assert flen < len(blob)
    with SpatialParquetReader(p) as r:
        assert r.footer["version"] == 1
        assert "checksum_algo" not in r.footer
        assert "crc" not in r.footer["row_groups"][0]["x_pages"][0]
        assert set(r.footer["row_groups"][0]) >= {
            "type", "type_rep", "rep", "defn", "x_pages", "y_pages", "extra",
        }
    os.unlink(p)

    # v2 (default): same trailer shape under the new magic, CRCs everywhere
    p = tempfile.mktemp(".spqf")
    _write_sample(p, rng)
    blob = open(p, "rb").read()
    assert blob.startswith(MAGIC_V2) and blob.endswith(MAGIC_V2)
    (flen,) = struct.unpack("<I", blob[-(len(MAGIC_V2) + 4):-len(MAGIC_V2)])
    assert flen < len(blob)
    with SpatialParquetReader(p) as r:
        assert r.footer["version"] == 2
        assert r.footer["checksum_algo"] in ("crc32c", "crc32")
        rg = r.footer["row_groups"][0]
        assert isinstance(rg["x_pages"][0]["crc"], int)
        assert isinstance(rg["type"]["crc"], int)
        assert set(rg) >= {
            "type", "type_rep", "rep", "defn", "x_pages", "y_pages", "extra",
        }
    os.unlink(p)

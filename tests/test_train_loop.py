"""Training loop: learning, resume, data pipeline integration."""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.data.pipeline import Prefetcher, synthetic_token_iter
from repro.launch.mesh import make_host_mesh
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig
from repro.train.train_loop import run_train_loop


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


def test_loss_decreases_and_resumes(tmp_path, mesh):
    cfg = get_config("internlm2-1.8b").reduced()
    oc = OptConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    data = synthetic_token_iter(cfg.vocab, seq_len=64, global_batch=4)
    mgr = CheckpointManager(tmp_path, async_save=False, keep=2)
    state, hist = run_train_loop(
        cfg, mesh, oc, data, global_batch=4, seq=64, steps=25,
        checkpoint_mgr=mgr, checkpoint_every=10, log_every=5,
    )
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3
    # resume continues where it stopped
    state2, hist2 = run_train_loop(
        cfg, mesh, oc, data, global_batch=4, seq=64, steps=30,
        checkpoint_mgr=mgr, checkpoint_every=0, log_every=5,
    )
    assert hist2[0]["step"] == 25


def test_grad_accum_equivalence(mesh, rng):
    """accum=2 over the same tokens gives (near-)identical update to accum=1."""
    import dataclasses
    from repro.models.model import build_model
    from repro.train.optimizer import opt_init
    from repro.train.train_loop import make_train_step

    base = get_config("internlm2-1.8b").reduced()
    oc = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10, grad_clip=1e9)
    toks = rng.integers(0, base.vocab, (4, 32)).astype(np.int32)
    outs = {}
    for accum in (1, 2):
        cfg = dataclasses.replace(base, grad_accum=accum)
        step_fn, pshard, oshard, bstruct, bshard, _ = make_train_step(
            cfg, mesh, oc, global_batch=4, seq=32)
        model = build_model(cfg)
        params = jax.jit(model.init, out_shardings=pshard)(jax.random.PRNGKey(3))
        opt = jax.jit(lambda p: opt_init(oc, p, cfg.opt_state_dtype),
                      out_shardings=oshard)(params)
        batch = {"tokens": toks.reshape(accum, 4 // accum, 32)}
        new_p, _, metrics = step_fn(params, opt, batch)
        outs[accum] = (jax.tree.leaves(new_p)[0], float(metrics["loss"]))
    # same data, same init: losses match to accumulation-order tolerance
    assert abs(outs[1][1] - outs[2][1]) < 5e-3
    assert np.allclose(np.asarray(outs[1][0]), np.asarray(outs[2][0]), atol=5e-4)


def test_prefetcher_stall_reuse():
    import time

    def slow_gen():
        yield {"x": 1}
        time.sleep(2.0)
        yield {"x": 2}

    pf = Prefetcher(slow_gen(), depth=1, stall_timeout=0.2)
    first = next(pf)
    assert first == {"x": 1}
    second = next(pf)  # producer still sleeping: reuse
    assert second == {"x": 1}
    assert pf.stalls >= 1
    third = next(pf)
    while third == {"x": 1}:
        third = next(pf)
    assert third == {"x": 2}

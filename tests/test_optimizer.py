"""Optimizers vs closed-form references; schedules; clipping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (
    OptConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
    opt_init,
    opt_update,
)


def numpy_adamw(params, grads, m, v, t, oc):
    out_p, out_m, out_v = {}, {}, {}
    lr = float(lr_schedule(oc, jnp.asarray(t)))
    # replicate the global-norm clip
    gn = np.sqrt(sum(float((np.asarray(g) ** 2).sum()) for g in grads.values()))
    scale = min(1.0, oc.grad_clip / max(gn, 1e-9))
    for k in params:
        g = np.asarray(grads[k]) * scale
        mm = oc.b1 * np.asarray(m[k]) + (1 - oc.b1) * g
        vv = oc.b2 * np.asarray(v[k]) + (1 - oc.b2) * g * g
        mh = mm / (1 - oc.b1**t)
        vh = vv / (1 - oc.b2**t)
        upd = mh / (np.sqrt(vh) + oc.eps)
        if np.asarray(params[k]).ndim >= 2:
            upd = upd + oc.weight_decay * np.asarray(params[k])
        out_p[k] = np.asarray(params[k]) - lr * upd
        out_m[k], out_v[k] = mm, vv
    return out_p, out_m, out_v


def test_adamw_matches_reference(rng):
    oc = OptConfig(lr=1e-2, warmup_steps=0, total_steps=1000, grad_clip=1.0,
                   weight_decay=0.1)
    params = {"w": jnp.asarray(rng.normal(0, 1, (4, 3)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(0, 1, (3,)).astype(np.float32))}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in params.items()}
    state = {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}
    p_np, m_np, v_np = params, m, v
    for t in range(1, 4):
        grads = {k: jnp.asarray(rng.normal(0, 1, vv.shape).astype(np.float32))
                 for k, vv in params.items()}
        new_p, state, _ = adamw_update(oc, p_np, grads, state)
        ref_p, ref_m, ref_v = numpy_adamw(
            {k: np.asarray(x) for k, x in p_np.items()},
            {k: np.asarray(x) for k, x in grads.items()},
            {k: np.asarray(x) for k, x in (m_np if t == 1 else m_np).items()},
            {k: np.asarray(x) for k, x in (v_np if t == 1 else v_np).items()},
            t, oc,
        )
        for k in params:
            assert np.allclose(np.asarray(new_p[k]), ref_p[k], atol=1e-5), k
        p_np, m_np, v_np = new_p, state["m"], state["v"]


def test_lr_schedule_shape():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_schedule(oc, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6          # end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)  # cosine floor
    assert all(a >= b - 1e-9 for a, b in zip(lrs[2:], lrs[3:]))  # monotone decay


def test_grad_clip(rng):
    g = {"w": jnp.asarray(rng.normal(0, 100, (64,)).astype(np.float32))}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-4
    assert float(norm) > 1.0


def test_adafactor_memory_factored(rng):
    oc = OptConfig(kind="adafactor")
    params = {"w": jnp.asarray(rng.normal(0, 1, (32, 16)).astype(np.float32))}
    state = opt_init(oc, params)
    assert state["f"]["w"]["vr"].shape == (32,)
    assert state["f"]["w"]["vc"].shape == (16,)
    grads = {"w": jnp.asarray(rng.normal(0, 1, (32, 16)).astype(np.float32))}
    new_p, state, _ = opt_update(oc, params, grads, state)
    assert np.isfinite(np.asarray(new_p["w"])).all()


def test_bf16_moment_storage(rng):
    params = {"w": jnp.asarray(rng.normal(0, 1, (8, 8)).astype(np.float32))}
    state = adamw_init(params, "bfloat16")
    assert state["m"]["w"].dtype == jnp.bfloat16
    oc = OptConfig()
    grads = {"w": jnp.ones((8, 8), jnp.float32)}
    new_p, state, _ = adamw_update(oc, params, grads, state)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(new_p["w"])).all()

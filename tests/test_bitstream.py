"""Bit-stream pack/unpack invariants.

``hypothesis`` is optional: without it, the property tests run fixed
deterministic samples (seeded numpy rng) instead of being skipped.
"""

import numpy as np
import pytest

from repro.core.bitstream import (
    bytes_to_words,
    marker_candidates,
    pack_tokens,
    read_one,
    unpack_at,
    unpack_fixed,
    width_mask,
    words_to_bytes,
)

try:
    from hypothesis import given, settings, strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional wheel
    HAVE_HYPOTHESIS = False

_SEEDS = [0, 1, 7, 42, 1234]


def _random_tokens(seed, max_size=200):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(0, max_size + 1))
    vals = rng.integers(0, 2**63, k, dtype=np.uint64) * 2 + rng.integers(0, 2, k).astype(np.uint64)
    widths = rng.integers(1, 65, k)
    return [(int(v), int(w)) for v, w in zip(vals, widths)]


def _check_pack_then_sequential_read(tokens):
    vals = np.array([t[0] for t in tokens], np.uint64)
    widths = np.array([t[1] for t in tokens], np.int64)
    words, total = pack_tokens(vals, widths)
    assert total == int(widths.sum())
    off = 0
    for v, w in tokens:
        got = read_one(words, off, w)
        assert got == (v & int(width_mask(w))), (v, w)
        off += w


def _check_fixed_width_vector_roundtrip(width, vals):
    vals = np.array(vals, np.uint64) & width_mask(width)
    words, total = pack_tokens(vals, np.full(len(vals), width, np.int64))
    got = unpack_fixed(words, 0, len(vals), width)
    assert np.array_equal(got, vals)


def _check_bytes_serialization_roundtrip(tokens):
    vals = np.array([t[0] for t in tokens], np.uint64)
    widths = np.array([t[1] for t in tokens], np.int64)
    words, total = pack_tokens(vals, widths)
    buf = words_to_bytes(words, total)
    assert len(buf) == (total + 7) // 8
    words2 = bytes_to_words(buf)
    off = 0
    for v, w in tokens:
        assert read_one(words2, off, w) == (v & int(width_mask(w)))
        off += w


if HAVE_HYPOTHESIS:
    @given(hyp_st.lists(hyp_st.tuples(hyp_st.integers(0, 2**64 - 1), hyp_st.integers(1, 64)),
                        min_size=0, max_size=200))
    @settings(max_examples=200, deadline=None)
    def test_pack_then_sequential_read(tokens):
        _check_pack_then_sequential_read(tokens)

    @given(hyp_st.integers(1, 64),
           hyp_st.lists(hyp_st.integers(0, 2**64 - 1), min_size=1, max_size=300))
    @settings(max_examples=100, deadline=None)
    def test_fixed_width_vector_roundtrip(width, vals):
        _check_fixed_width_vector_roundtrip(width, vals)

    @given(hyp_st.lists(hyp_st.tuples(hyp_st.integers(0, 2**64 - 1), hyp_st.integers(1, 64)),
                        min_size=1, max_size=100))
    @settings(max_examples=100, deadline=None)
    def test_bytes_serialization_roundtrip(tokens):
        _check_bytes_serialization_roundtrip(tokens)
else:
    @pytest.mark.parametrize("seed", _SEEDS)
    def test_pack_then_sequential_read(seed):
        _check_pack_then_sequential_read(_random_tokens(seed))

    @pytest.mark.parametrize("seed", _SEEDS)
    def test_fixed_width_vector_roundtrip(seed):
        rng = np.random.default_rng(seed)
        for width in (1, 2, 7, 31, 32, 33, 63, 64):
            vals = rng.integers(0, 2**63, 300, dtype=np.uint64) * 2 + 1
            _check_fixed_width_vector_roundtrip(width, list(vals))

    @pytest.mark.parametrize("seed", _SEEDS)
    def test_bytes_serialization_roundtrip(seed):
        toks = _random_tokens(seed)
        if not toks:
            toks = [(5, 8)]
        _check_bytes_serialization_roundtrip(toks)


def test_mixed_stream_alignment():
    # header(8) + raw(64) + many 7-bit values (the fp-delta layout)
    vals = [5, 0xDEADBEEFCAFEF00D] + list(range(100))
    widths = [8, 64] + [7] * 100
    words, total = pack_tokens(np.array(vals, np.uint64), np.array(widths, np.int64))
    assert read_one(words, 0, 8) == 5
    assert read_one(words, 8, 64) == 0xDEADBEEFCAFEF00D
    got = unpack_fixed(words, 72, 100, 7)
    assert np.array_equal(got, np.arange(100, dtype=np.uint64))


def test_unpack_at_arbitrary_offsets(rng):
    vals = rng.integers(0, 2**64, 500, dtype=np.uint64)
    widths = rng.integers(1, 65, 500)
    words, total = pack_tokens(vals, widths)
    offs = np.cumsum(widths) - widths
    # gather every token individually at its exact (unsorted) offset
    perm = rng.permutation(500)
    for w in np.unique(widths):
        sel = perm[widths[perm] == w]
        got = unpack_at(words, offs[sel], int(w))
        assert np.array_equal(got, vals[sel] & width_mask(int(w)))


@pytest.mark.parametrize("n", [1, 2, 3, 7, 17, 33, 64])
def test_marker_candidates_exact(n):
    # build a stream with known runs of ones at known bit positions
    rng = np.random.default_rng(n)
    total_bits = 4096
    bits = np.zeros(total_bits, dtype=np.uint8)
    planted = sorted(rng.choice(total_bits - 2 * n, 8, replace=False).tolist())
    for p in planted:
        bits[p : p + n] = 1
    words = np.zeros(total_bits // 64 + 1, dtype=np.uint64)
    packed = np.packbits(bits, bitorder="little")
    words[: len(packed) // 8] = packed.view("<u8")
    got = set(marker_candidates(words, n).tolist())
    # brute force: every position where n consecutive ones start
    want = {
        i for i in range(total_bits - n + 1) if bits[i : i + n].all()
    }
    assert got == want

"""Bit-stream pack/unpack invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.bitstream import (
    bytes_to_words,
    pack_tokens,
    read_one,
    unpack_fixed,
    width_mask,
    words_to_bytes,
)


@given(st.lists(st.tuples(st.integers(0, 2**64 - 1), st.integers(1, 64)),
                min_size=0, max_size=200))
@settings(max_examples=200, deadline=None)
def test_pack_then_sequential_read(tokens):
    vals = np.array([t[0] for t in tokens], np.uint64)
    widths = np.array([t[1] for t in tokens], np.int64)
    words, total = pack_tokens(vals, widths)
    assert total == int(widths.sum())
    off = 0
    for v, w in tokens:
        got = read_one(words, off, w)
        assert got == (v & int(width_mask(w))), (v, w)
        off += w


@given(st.integers(1, 64), st.lists(st.integers(0, 2**64 - 1), min_size=1, max_size=300))
@settings(max_examples=100, deadline=None)
def test_fixed_width_vector_roundtrip(width, vals):
    vals = np.array(vals, np.uint64) & width_mask(width)
    words, total = pack_tokens(vals, np.full(len(vals), width, np.int64))
    got = unpack_fixed(words, 0, len(vals), width)
    assert np.array_equal(got, vals)


@given(st.lists(st.tuples(st.integers(0, 2**64 - 1), st.integers(1, 64)),
                min_size=1, max_size=100))
@settings(max_examples=100, deadline=None)
def test_bytes_serialization_roundtrip(tokens):
    vals = np.array([t[0] for t in tokens], np.uint64)
    widths = np.array([t[1] for t in tokens], np.int64)
    words, total = pack_tokens(vals, widths)
    buf = words_to_bytes(words, total)
    assert len(buf) == (total + 7) // 8
    words2 = bytes_to_words(buf)
    off = 0
    for v, w in tokens:
        assert read_one(words2, off, w) == (v & int(width_mask(w)))
        off += w


def test_mixed_stream_alignment():
    # header(8) + raw(64) + many 7-bit values (the fp-delta layout)
    vals = [5, 0xDEADBEEFCAFEF00D] + list(range(100))
    widths = [8, 64] + [7] * 100
    words, total = pack_tokens(np.array(vals, np.uint64), np.array(widths, np.int64))
    assert read_one(words, 0, 8) == 5
    assert read_one(words, 8, 64) == 0xDEADBEEFCAFEF00D
    got = unpack_fixed(words, 72, 100, 7)
    assert np.array_equal(got, np.arange(100, dtype=np.uint64))

"""Mamba2/SSD: the chunked dual form must equal the naive recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_scan


def naive_recurrence(xh, dt, a_neg, b_mat, c_mat):
    """y_t = C_t . S_t;  S_t = exp(dt_t * A) S_{t-1} + dt_t B_t (x) x_t."""
    bsz, L, h, p = xh.shape
    n = b_mat.shape[-1]
    S = np.zeros((bsz, h, n, p))
    ys = np.zeros_like(np.asarray(xh))
    for t in range(L):
        decay = np.exp(np.asarray(dt)[:, t] * np.asarray(a_neg))  # (B,H)
        S = S * decay[:, :, None, None] + np.einsum(
            "bh,bn,bhp->bhnp", np.asarray(dt)[:, t], np.asarray(b_mat)[:, t], np.asarray(xh)[:, t]
        )
        ys[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(c_mat)[:, t], S)
    return ys, S


@pytest.mark.parametrize("L,chunk", [(32, 8), (64, 16), (48, 48), (96, 32)])
def test_ssd_equals_recurrence(rng, L, chunk):
    bsz, h, p, n = 2, 3, 4, 8
    xh = jnp.asarray(rng.normal(0, 1, (bsz, L, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (bsz, L, h)).astype(np.float32))
    a_neg = jnp.asarray(-rng.uniform(0.5, 2.0, h).astype(np.float32))
    b_mat = jnp.asarray(rng.normal(0, 1, (bsz, L, n)).astype(np.float32))
    c_mat = jnp.asarray(rng.normal(0, 1, (bsz, L, n)).astype(np.float32))
    y, s_final = jax.jit(lambda *a: ssd_scan(*a, chunk=chunk))(xh, dt, a_neg, b_mat, c_mat)
    y_ref, s_ref = naive_recurrence(xh, dt, a_neg, b_mat, c_mat)
    assert np.allclose(np.asarray(y), y_ref, atol=1e-4), np.abs(np.asarray(y) - y_ref).max()
    assert np.allclose(np.asarray(s_final), s_ref, atol=1e-4)


def test_ssd_init_state_continuation(rng):
    """Splitting a sequence across two ssd_scan calls must be seamless."""
    bsz, L, h, p, n, chunk = 1, 64, 2, 4, 8, 16
    xh = jnp.asarray(rng.normal(0, 1, (bsz, L, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (bsz, L, h)).astype(np.float32))
    a_neg = jnp.asarray(-rng.uniform(0.5, 2.0, h).astype(np.float32))
    b_mat = jnp.asarray(rng.normal(0, 1, (bsz, L, n)).astype(np.float32))
    c_mat = jnp.asarray(rng.normal(0, 1, (bsz, L, n)).astype(np.float32))
    y_full, s_full = ssd_scan(xh, dt, a_neg, b_mat, c_mat, chunk=chunk)
    half = L // 2
    y1, s1 = ssd_scan(xh[:, :half], dt[:, :half], a_neg, b_mat[:, :half],
                      c_mat[:, :half], chunk=chunk)
    y2, s2 = ssd_scan(xh[:, half:], dt[:, half:], a_neg, b_mat[:, half:],
                      c_mat[:, half:], chunk=chunk, init_state=s1)
    assert np.allclose(np.asarray(y_full[:, half:]), np.asarray(y2), atol=1e-4)
    assert np.allclose(np.asarray(s_full), np.asarray(s2), atol=1e-4)


def test_ssm_block_decode_matches_forward(rng):
    """Token-by-token ssm_decode_step == full-sequence ssm_forward."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import ssm as ssm_mod

    cfg = get_config("mamba2-130m").reduced()
    cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=8))
    params = ssm_mod.init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    bsz, L = 2, 24
    x = jnp.asarray(rng.normal(0, 0.5, (bsz, L, cfg.d_model)).astype(np.float32))
    y_full, _ = ssm_mod.ssm_forward(cfg, params, x)
    cache = ssm_mod.init_ssm_cache(cfg, bsz, jnp.float32)
    outs = []
    for t in range(L):
        y_t, cache = ssm_mod.ssm_decode_step(cfg, params, x[:, t : t + 1], cache)
        outs.append(np.asarray(y_t[:, 0]))
    y_step = np.stack(outs, axis=1)
    assert np.allclose(np.asarray(y_full), y_step, atol=2e-4), \
        np.abs(np.asarray(y_full) - y_step).max()

"""End-to-end behaviour tests: the paper's full pipeline as one system.

Data lake (Spatial Parquet write, Hilbert sort, FP-delta, zstd) -> indexed
range read -> tokenize -> train a trajectory LM -> checkpoint (FP-delta
compressed) -> serve continuations. Each stage's invariants are asserted.
"""

import dataclasses
import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pages import have_codec
from repro.core.reader import SpatialParquetReader
from repro.core.writer import write_file
from repro.data.pipeline import Prefetcher, TrajectoryBatcher
from repro.data.synthetic import PORTO_BBOX, porto_taxi_like
from repro.data.tokenizer import GeoTokenizer
from repro.launch.mesh import make_host_mesh
from repro.serve.scheduler import BatchedServer
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig
from repro.train.train_loop import run_train_loop


def test_lake_to_model_to_serving(tmp_path):
    # ---- 1. the data lake: paper's format end to end
    cols = porto_taxi_like(n_traj=800, seed=11)
    lake_file = os.path.join(tmp_path, "porto.spqf")
    codec = "zstd" if have_codec("zstd") else "gzip"  # zstd wheel is optional
    write_file(lake_file, columns=cols, sort="hilbert", codec=codec,
               page_values=8192)
    raw_bytes = cols.n_values * 16
    assert os.path.getsize(lake_file) < raw_bytes, "FP-delta+zstd must beat raw"

    with SpatialParquetReader(lake_file) as r:
        assert r.n_records == 800
        # the light-weight index prunes a city-corner query
        q = (PORTO_BBOX[0], PORTO_BBOX[1],
             PORTO_BBOX[0] + 0.05, PORTO_BBOX[1] + 0.04)
        sub, _, st = r.read_columnar(bbox=q, refine=True)
        assert st.pages_read <= st.pages_total
        if sub is not None and sub.n_records:
            assert sub.x.min() >= q[0] - 0.05  # records intersect the box

    # ---- 2. tokenize + train (loss must decrease)
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    cfg = dataclasses.replace(get_config("spatial-lm"), vocab=tok.vocab,
                              n_layers=2, d_model=128)
    data = Prefetcher(TrajectoryBatcher([lake_file], tok, seq_len=64,
                                        global_batch=4))
    mesh = make_host_mesh(1, 1)
    mgr = CheckpointManager(tmp_path / "ck", compress=True, async_save=False)
    oc = OptConfig(lr=1e-3, warmup_steps=5, total_steps=30, grad_clip=0.5)
    state, hist = run_train_loop(cfg, mesh, oc, iter(data), global_batch=4,
                                 seq=64, steps=20, checkpoint_mgr=mgr,
                                 checkpoint_every=10, log_every=10)
    assert hist[-1]["loss"] < hist[0]["loss"], "training must learn"
    assert mgr.latest_step() == 20
    assert mgr.last_stats.stored_bytes <= mgr.last_stats.raw_bytes

    # ---- 3. serve continuations from the trained params
    srv = BatchedServer(cfg, state.params, max_batch=2, max_len=96)
    mat = tok.encode_trajectories(cols.slice_records(0, 4), 32)
    for i in range(3):
        srv.submit(mat[i][mat[i] > 0][:10], max_new_tokens=6, rid=i)
    done = srv.run()
    assert len(done) == 3
    cell_w = (PORTO_BBOX[2] - PORTO_BBOX[0]) / 63  # half-cell edge overshoot
    for req in done:
        cells = [t for t in req.out_tokens if t >= 3]
        if cells:  # generated cells decode inside the tokenizer's bbox
            xy = tok.decode_tokens(np.array(cells))
            assert (xy[:, 0] >= PORTO_BBOX[0] - cell_w).all()
            assert (xy[:, 0] <= PORTO_BBOX[2] + cell_w).all()

"""Spatial Parquet file format: write/read/filter correctness + the §4 index."""

import os
import tempfile

import numpy as np
import pytest

from repro.core import (
    SpatialParquetReader,
    SpatialParquetWriter,
    write_file,
)
from repro.core.columnar import from_ragged
from repro.core.rle import decode_levels, encode_levels, rle_decode, rle_encode
from repro.core.pages import have_codec
from repro.core.sfc import hilbert_key, z_key
from tests.geom_helpers import random_geometry


def _point_cols(rng, n, spread=100.0):
    pts = np.round(rng.uniform(-spread, spread, (n, 2)), 6)
    return pts, from_ragged(np.ones(n, np.uint8), pts,
                            np.ones(n, np.int64), np.ones(n, np.int64))


@pytest.mark.parametrize("codec", ["none", "gzip", "zstd"])
@pytest.mark.parametrize("encoding", ["fp_delta", "raw"])
def test_roundtrip_codecs(rng, codec, encoding):
    if not have_codec(codec):
        pytest.skip(f"codec {codec!r} unavailable (optional wheel not installed)")
    pts, cols = _point_cols(rng, 5000)
    p = tempfile.mktemp(".spqf")
    write_file(p, columns=cols, codec=codec, encoding=encoding, page_values=1024)
    with SpatialParquetReader(p) as r:
        geo, _, st = r.read_columnar()
    assert geo.n_records == 5000
    assert np.array_equal(np.sort(geo.x), np.sort(pts[:, 0]))
    os.unlink(p)


def test_bbox_filter_equals_bruteforce(rng):
    pts, cols = _point_cols(rng, 20_000)
    p = tempfile.mktemp(".spqf")
    write_file(p, columns=cols, sort="hilbert", page_values=512,
               row_group_records=1 << 20)
    q = (-95.0, -95.0, -70.0, -70.0)
    with SpatialParquetReader(p) as r:
        geo, _, st = r.read_columnar(bbox=q, refine=True)
    inq = ((pts[:, 0] >= q[0]) & (pts[:, 0] <= q[2])
           & (pts[:, 1] >= q[1]) & (pts[:, 1] <= q[3]))
    assert geo.n_records == int(inq.sum())
    assert st.pages_read < st.pages_total, "index should prune pages"
    os.unlink(p)


def test_mixed_geometry_file_roundtrip(rng):
    geoms = [random_geometry(np.random.default_rng(s)) for s in range(200)]
    p = tempfile.mktemp(".spqf")
    codec = "zstd" if have_codec("zstd") else "gzip"
    write_file(p, geometries=geoms, codec=codec, row_group_records=64)
    with SpatialParquetReader(p) as r:
        back, _ = r.read()
    assert back == geoms
    os.unlink(p)


def test_sorted_write_clusters_pages(rng):
    pts, cols = _point_cols(rng, 30_000)
    sizes = {}
    for sort in (None, "hilbert"):
        p = tempfile.mktemp(".spqf")
        write_file(p, columns=cols, sort=sort, page_values=2048)
        with SpatialParquetReader(p) as r:
            # average page bbox area is much tighter when sorted
            areas = [
                max(e.bbox[2] - e.bbox[0], 0) * max(e.bbox[3] - e.bbox[1], 0)
                for e in r.index.entries
            ]
            sizes[sort] = np.mean(areas)
        os.unlink(p)
    assert sizes["hilbert"] < 0.25 * sizes[None]


def test_extra_columns_and_projection(rng):
    pts, cols = _point_cols(rng, 4000)
    ts = rng.integers(0, 1 << 40, 4000)
    p = tempfile.mktemp(".spqf")
    write_file(p, columns=cols, extra={"ts": ts}, extra_schema={"ts": "<i8"},
               sort="z", page_values=512)
    with SpatialParquetReader(p) as r:
        _, extras, _ = r.read_columnar(columns=("ts",))
        assert np.array_equal(np.sort(extras["ts"]), np.sort(ts))
    os.unlink(p)


def test_streaming_writer_multiple_groups(rng):
    p = tempfile.mktemp(".spqf")
    total = 0
    with SpatialParquetWriter(p, row_group_records=1000, sort="hilbert") as w:
        for i in range(5):
            _, cols = _point_cols(np.random.default_rng(i), 700)
            w.write_columns(cols)
            total += 700
    with SpatialParquetReader(p) as r:
        assert r.n_records == total
        assert len(r.footer["row_groups"]) >= 3
        geo, _, _ = r.read_columnar()
        assert geo.n_records == total
    os.unlink(p)


def test_corrupt_magic_rejected(tmp_path):
    p = tmp_path / "bad.spqf"
    p.write_bytes(b"NOTAPARQUETFILE")
    with pytest.raises(ValueError):
        SpatialParquetReader(str(p))


# ----------------------------------------------------------------- RLE / SFC
def test_rle_roundtrip(rng):
    v = np.repeat(rng.integers(0, 7, 50), rng.integers(1, 2000, 50)).astype(np.uint8)
    assert np.array_equal(rle_decode(rle_encode(v)), v)
    assert len(rle_encode(v)) < v.nbytes // 4  # big runs compress hard


def test_levels_roundtrip(rng):
    for vals in (rng.integers(0, 4, 10_000), np.zeros(5000), rng.integers(0, 2, 17)):
        v = vals.astype(np.uint8)
        assert np.array_equal(decode_levels(encode_levels(v)), v)


def test_hilbert_locality(rng):
    # consecutive hilbert cells are spatial neighbors: d(k, k+1) == 1 step
    order = 6
    n = 1 << order
    keys = hilbert_key(
        np.repeat(np.arange(n), n).astype(np.uint64),
        np.tile(np.arange(n), n).astype(np.uint64),
        order,
    )
    inv = np.argsort(keys)
    xs, ys = inv // n, inv % n
    d = np.abs(np.diff(xs)) + np.abs(np.diff(ys))
    assert d.max() == 1, "hilbert curve must move one cell at a time"


def test_zcurve_bijective(rng):
    xq = rng.integers(0, 2**16, 5000).astype(np.uint64)
    yq = rng.integers(0, 2**16, 5000).astype(np.uint64)
    keys = z_key(xq, yq)
    assert len(np.unique(keys)) == len(np.unique(xq * (1 << 16) + yq))

"""MoE dispatch: dropless == dense reference; capacity + padding semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import init_moe, moe_block


def _cfg(**moe_kw):
    moe = MoEConfig(**{**dict(n_experts=8, top_k=2, d_expert=16,
                              capacity_factor=8.0), **moe_kw})
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
                       n_kv_heads=2, d_ff=16, vocab=64, moe=moe, dtype="float32",
                       param_dtype="float32")


def dense_reference(cfg, p, x):
    """Compute-all-experts reference (no dispatch, no capacity)."""
    moe = cfg.moe
    n, d = x.shape
    logits = x @ p["router"]
    e_pad = p["router"].shape[1]
    if e_pad > moe.n_experts:
        logits = np.where(np.arange(e_pad)[None] >= moe.n_experts, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, moe.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    out = np.zeros_like(np.asarray(x))
    for t in range(n):
        for j in range(moe.top_k):
            e = int(gi[t, j])
            h = jax.nn.silu(x[t] @ p["w_gate"][e]) * (x[t] @ p["w_up"][e])
            out[t] += float(gv[t, j]) * np.asarray(h @ p["w_down"][e])
    return out


def test_dropless_matches_dense_reference(rng):
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (1, 24, 32)).astype(np.float32))
    y, aux = moe_block(cfg, p, x)
    y_ref = dense_reference(cfg, p, x[0])
    assert np.allclose(np.asarray(y[0]), y_ref, atol=1e-4)
    assert float(aux["moe_aux_loss"]) >= 0.0


def test_padding_experts_never_routed(rng):
    cfg = _cfg(n_experts=6, pad_experts_to=8)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, 32)).astype(np.float32))
    # padding experts have -inf logits: set their weights to NaN; output must
    # stay finite iff they are never selected
    wg = np.array(p["w_gate"])  # writable copy
    wg[6:] = np.nan
    p = dict(p, w_gate=jnp.asarray(wg))
    y, _ = moe_block(cfg, p, x)
    assert bool(jnp.isfinite(y).all())


def test_capacity_drops_are_bounded(rng):
    cfg = _cfg(capacity_factor=1.0)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (1, 64, 32)).astype(np.float32))
    y, _ = moe_block(cfg, p, x)
    # with cf=1 some tokens may drop (zero contribution) but output is finite
    assert bool(jnp.isfinite(y).all())


def test_shared_and_dense_parallel_paths(rng):
    cfg = _cfg(n_shared=2, dense_ff_parallel=16)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    assert "shared" in p and "dense" in p
    x = jnp.asarray(rng.normal(0, 1, (1, 8, 32)).astype(np.float32))
    y, _ = moe_block(cfg, p, x)
    assert y.shape == x.shape
    # removing shared experts changes the output (they are active)
    p2 = dict(p)
    p2["shared"] = jax.tree.map(lambda a: a * 0, p["shared"])
    y2, _ = moe_block(cfg, p2, x)
    assert not np.allclose(np.asarray(y), np.asarray(y2))


def test_load_balance_loss_ordering(rng):
    """Uniform routing must have lower aux loss than collapsed routing."""
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (1, 128, 32)).astype(np.float32))
    _, aux_u = moe_block(cfg, p, x)
    # collapse: bias router hard to expert 0
    r = np.asarray(p["router"]).copy()
    r[:, 0] += 100.0
    _, aux_c = moe_block(cfg, dict(p, router=jnp.asarray(r)), x)
    assert float(aux_c["moe_aux_loss"]) > float(aux_u["moe_aux_loss"])

"""Geo tokenizer + Spatial-Parquet-backed training pipeline."""

import os

import numpy as np
import pytest

from repro.core.writer import write_file
from repro.data.pipeline import TrajectoryBatcher
from repro.data.synthetic import (
    PORTO_BBOX,
    buildings_like,
    ebird_like,
    porto_taxi_like,
    roads_like,
)
from repro.data.tokenizer import BOS, EOS, PAD, GeoTokenizer
from repro.core.pages import best_codec


def test_tokenizer_cell_roundtrip(rng):
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    x = rng.uniform(PORTO_BBOX[0], PORTO_BBOX[2], 1000)
    y = rng.uniform(PORTO_BBOX[1], PORTO_BBOX[3], 1000)
    t = tok.encode_points(x, y)
    assert t.min() >= 3 and t.max() < tok.vocab
    xy = tok.decode_tokens(t)
    # decoded cell centers are within one cell diagonal
    cell_w = (PORTO_BBOX[2] - PORTO_BBOX[0]) / 2**6
    cell_h = (PORTO_BBOX[3] - PORTO_BBOX[1]) / 2**6
    assert np.all(np.abs(xy[:, 0] - x) <= cell_w)
    assert np.all(np.abs(xy[:, 1] - y) <= cell_h)


def test_tokenizer_locality(rng):
    """Nearby points share tokens more often than far points."""
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    x = rng.uniform(PORTO_BBOX[0], PORTO_BBOX[2], 2000)
    y = rng.uniform(PORTO_BBOX[1], PORTO_BBOX[3], 2000)
    t0 = tok.encode_points(x, y)
    t_near = tok.encode_points(x + 1e-5, y + 1e-5)
    assert (t0 == t_near).mean() > 0.9


def test_synthetic_generators_shapes():
    for cols, t in ((porto_taxi_like(50), 4), (roads_like(50), 5),
                    (buildings_like(50), 3), (ebird_like(500), 1)):
        assert cols.n_records >= 50 or t == 1
        assert (cols.types == t).all()
        assert np.isfinite(cols.x).all() and np.isfinite(cols.y).all()


def test_trajectory_batcher_end_to_end(tmp_path, rng):
    cols = porto_taxi_like(n_traj=300, seed=1)
    p = os.path.join(tmp_path, "a.spqf")
    write_file(p, columns=cols, sort="hilbert", codec=best_codec())
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    it = iter(TrajectoryBatcher([p], tok, seq_len=96, global_batch=8, accum=2))
    batch = next(it)
    assert batch["tokens"].shape == (2, 4, 96)
    flat = batch["tokens"].reshape(-1, 96)
    assert (flat[:, 0] == BOS).all()
    assert ((flat == EOS).sum(axis=1) >= 1).all()
    assert flat.max() < tok.vocab


def test_batcher_bbox_pushdown(tmp_path):
    cols = porto_taxi_like(n_traj=400, seed=2)
    p = os.path.join(tmp_path, "b.spqf")
    write_file(p, columns=cols, sort="hilbert", page_values=2048)
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    half = (PORTO_BBOX[0], PORTO_BBOX[1],
            (PORTO_BBOX[0] + PORTO_BBOX[2]) / 2, (PORTO_BBOX[1] + PORTO_BBOX[3]) / 2)
    it = iter(TrajectoryBatcher([p], tok, seq_len=64, global_batch=4, bbox=half))
    batch = next(it)
    # all tokens decode into (or near) the filtered half-box
    toks = batch["tokens"].reshape(-1)
    toks = toks[toks >= 3]
    xy = tok.decode_tokens(toks)
    cell_w = (PORTO_BBOX[2] - PORTO_BBOX[0]) / 2**6
    # record-exact pushdown: overshoot bounded by one trajectory's own extent
    # (a record intersecting the box keeps all its points) + one cell
    assert xy[:, 0].max() <= half[2] + 0.02 + cell_w

"""Geo tokenizer + Spatial-Parquet-backed training pipeline."""

import os
import time

import numpy as np
import pytest

from repro.core.writer import write_file
from repro.data.pipeline import Prefetcher, TrajectoryBatcher, expand_sources
from repro.data.synthetic import (
    PORTO_BBOX,
    buildings_like,
    ebird_like,
    porto_taxi_like,
    roads_like,
)
from repro.data.tokenizer import BOS, EOS, PAD, GeoTokenizer
from repro.core.pages import best_codec


def test_tokenizer_cell_roundtrip(rng):
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    x = rng.uniform(PORTO_BBOX[0], PORTO_BBOX[2], 1000)
    y = rng.uniform(PORTO_BBOX[1], PORTO_BBOX[3], 1000)
    t = tok.encode_points(x, y)
    assert t.min() >= 3 and t.max() < tok.vocab
    xy = tok.decode_tokens(t)
    # decoded cell centers are within one cell diagonal
    cell_w = (PORTO_BBOX[2] - PORTO_BBOX[0]) / 2**6
    cell_h = (PORTO_BBOX[3] - PORTO_BBOX[1]) / 2**6
    assert np.all(np.abs(xy[:, 0] - x) <= cell_w)
    assert np.all(np.abs(xy[:, 1] - y) <= cell_h)


def test_tokenizer_locality(rng):
    """Nearby points share tokens more often than far points."""
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    x = rng.uniform(PORTO_BBOX[0], PORTO_BBOX[2], 2000)
    y = rng.uniform(PORTO_BBOX[1], PORTO_BBOX[3], 2000)
    t0 = tok.encode_points(x, y)
    t_near = tok.encode_points(x + 1e-5, y + 1e-5)
    assert (t0 == t_near).mean() > 0.9


def test_synthetic_generators_shapes():
    for cols, t in ((porto_taxi_like(50), 4), (roads_like(50), 5),
                    (buildings_like(50), 3), (ebird_like(500), 1)):
        assert cols.n_records >= 50 or t == 1
        assert (cols.types == t).all()
        assert np.isfinite(cols.x).all() and np.isfinite(cols.y).all()


def test_trajectory_batcher_end_to_end(tmp_path, rng):
    cols = porto_taxi_like(n_traj=300, seed=1)
    p = os.path.join(tmp_path, "a.spqf")
    write_file(p, columns=cols, sort="hilbert", codec=best_codec())
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    it = iter(TrajectoryBatcher([p], tok, seq_len=96, global_batch=8, accum=2))
    batch = next(it)
    assert batch["tokens"].shape == (2, 4, 96)
    flat = batch["tokens"].reshape(-1, 96)
    assert (flat[:, 0] == BOS).all()
    assert ((flat == EOS).sum(axis=1) >= 1).all()
    assert flat.max() < tok.vocab


def test_batcher_bbox_pushdown(tmp_path):
    cols = porto_taxi_like(n_traj=400, seed=2)
    p = os.path.join(tmp_path, "b.spqf")
    write_file(p, columns=cols, sort="hilbert", page_values=2048)
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    half = (PORTO_BBOX[0], PORTO_BBOX[1],
            (PORTO_BBOX[0] + PORTO_BBOX[2]) / 2, (PORTO_BBOX[1] + PORTO_BBOX[3]) / 2)
    it = iter(TrajectoryBatcher([p], tok, seq_len=64, global_batch=4, bbox=half))
    batch = next(it)
    # all tokens decode into (or near) the filtered half-box
    toks = batch["tokens"].reshape(-1)
    toks = toks[toks >= 3]
    xy = tok.decode_tokens(toks)
    cell_w = (PORTO_BBOX[2] - PORTO_BBOX[0]) / 2**6
    # record-exact pushdown: overshoot bounded by one trajectory's own extent
    # (a record intersecting the box keeps all its points) + one cell
    assert xy[:, 0].max() <= half[2] + 0.02 + cell_w


def test_prefetcher_propagates_worker_exception_promptly():
    """A raising iterable must surface its error well before stall_timeout."""

    def boom():
        raise ValueError("bad shard")
        yield  # pragma: no cover - makes this a generator

    pf = Prefetcher(boom(), depth=2, stall_timeout=30.0)
    t0 = time.perf_counter()
    with pytest.raises(ValueError, match="bad shard"):
        next(pf)
    assert time.perf_counter() - t0 < 5.0  # not a stall_timeout sit-out
    # the failure is sticky, not converted into StopIteration
    with pytest.raises(ValueError, match="bad shard"):
        next(pf)
    assert pf.stalls == 0


def test_prefetcher_delivers_buffered_items_then_error():
    def two_then_boom():
        yield 1
        yield 2
        raise RuntimeError("producer died")

    pf = Prefetcher(two_then_boom(), depth=4, stall_timeout=30.0)
    assert next(pf) == 1
    assert next(pf) == 2
    with pytest.raises(RuntimeError, match="producer died"):
        next(pf)


def test_prefetcher_exhaustion_is_sticky():
    """next() past StopIteration must not re-serve the last batch as a stall."""
    pf = Prefetcher(iter([1, 2]), depth=4, stall_timeout=30.0)
    assert list(pf) == [1, 2]
    t0 = time.perf_counter()
    with pytest.raises(StopIteration):
        next(pf)
    assert time.perf_counter() - t0 < 5.0  # no stall_timeout wait
    assert pf.stalls == 0


def test_batcher_rejects_empty_sources(tmp_path):
    from repro.dataset import write_dataset

    cols = porto_taxi_like(n_traj=50, seed=9)
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, n_shards=2, sort="hilbert")
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    with pytest.raises(ValueError, match="bbox pruned"):
        TrajectoryBatcher([root], tok, seq_len=64, global_batch=4,
                          bbox=(50.0, 50.0, 51.0, 51.0))
    with pytest.raises(ValueError, match="no input"):
        TrajectoryBatcher([], tok, seq_len=64, global_batch=4)


def test_batcher_stripes_over_dataset_shards(tmp_path):
    from repro.dataset import write_dataset

    cols = porto_taxi_like(n_traj=300, seed=5)
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, n_shards=4, sort="hilbert",
                  page_values=2048)
    # dataset dirs expand to their shard files (the striping unit)
    assert len(expand_sources([root])) == 4
    single = os.path.join(tmp_path, "one.spqf")
    write_file(single, columns=cols, sort="hilbert")
    assert expand_sources([single]) == [single]
    # bbox pruning drops whole shards before the batcher ever opens them
    corner = (PORTO_BBOX[0], PORTO_BBOX[1],
              PORTO_BBOX[0] + 0.05, PORTO_BBOX[1] + 0.04)
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    b = TrajectoryBatcher([root], tok, seq_len=64, global_batch=4, bbox=corner)
    assert 0 < len(b.files) < 4
    batch = next(iter(b))
    assert batch["tokens"].shape == (1, 4, 64)
    # full-extent batcher over shards yields well-formed batches too
    b2 = TrajectoryBatcher([root, single], tok, seq_len=64, global_batch=4)
    assert len(b2.files) == 5
    batch = next(iter(Prefetcher(b2, depth=2)))
    assert batch["tokens"].shape == (1, 4, 64)

"""Multi-tenant bbox query server: bit-identity vs sequential scans, cache
behavior (hit / evict / generation invalidation), and per-query ReadStats
attribution (see repro/serve/query_scheduler.py)."""

import numpy as np
import pytest

from repro.data.synthetic import PORTO_BBOX, porto_taxi_like
from repro.dataset.scanner import SpatialDatasetScanner
from repro.dataset.writer import write_dataset
from repro.serve.query_scheduler import SpatialQueryServer

STAT_FIELDS = ("pages_total", "pages_read", "bytes_total", "bytes_read",
               "records_scanned", "records_returned", "shards_total",
               "shards_read")


@pytest.fixture(scope="module")
def lake(tmp_path_factory):
    cols = porto_taxi_like(n_traj=300, seed=11)
    extra = {"tid": np.arange(cols.n_records, dtype=np.int64)}
    root = tmp_path_factory.mktemp("serve_lake") / "lake"
    write_dataset(root, columns=cols, extra=extra, n_shards=3,
                  sort="hilbert", page_values=2048)
    return SpatialDatasetScanner(root)


def _boxes():
    """Overlapping grid cells + full extent, empty, None and NaN queries."""
    x0, y0, x1, y1 = PORTO_BBOX
    xs = np.linspace(x0, x1, 4)
    ys = np.linspace(y0, y1, 4)
    boxes = [(xs[i], ys[j], xs[i + 1], ys[j + 1])
             for i in range(3) for j in range(3)]
    boxes.append(PORTO_BBOX)                 # full extent
    boxes.append((50.0, 50.0, 51.0, 51.0))   # empty: far from Porto
    boxes.append(None)                       # no filter
    boxes.append((np.nan, y0, x1, y1))       # NaN bound: keeps nothing
    return boxes


def _assert_geo_equal(a, b, ctx):
    if a is None or b is None:
        assert a is None and b is None, ctx
        return
    for f in ("types", "type_rep", "rep", "defn", "x", "y"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), (ctx, f)


@pytest.mark.parametrize("device", ["cpu", "jax"])
def test_concurrent_queries_match_sequential_scan(lake, device):
    srv = SpatialQueryServer(lake, device=device, cache_rgs=64, max_wave=8)
    boxes = _boxes()
    with srv:
        qs = [srv.submit(b) for b in boxes]
        done = srv.run()
        assert done == qs and all(q.done for q in qs)
        assert srv.waves >= 2  # 13 queries over max_wave=8: multi-wave
        for q, b in zip(qs, boxes):
            geo, extras, _ = lake.scan(b, refine=True, device=device,
                                       parallel=False)
            _assert_geo_equal(q.geo, geo, (device, b))
            assert set(q.extras) == set(extras), (device, b)
            for k in extras:
                assert np.array_equal(q.extras[k], extras[k]), (device, b, k)


@pytest.mark.parametrize("device", ["cpu", "jax"])
def test_per_query_stats_match_solo_scan(lake, device):
    boxes = _boxes()
    with SpatialQueryServer(lake, device=device, cache_rgs=64) as srv:
        qs = [srv.submit(b) for b in boxes]
        srv.run()
    for q, b in zip(qs, boxes):
        _, _, st = lake.scan(b, refine=True, device=device, parallel=False)
        for f in STAT_FIELDS:
            assert getattr(q.stats, f) == getattr(st, f), (device, b, f)
        assert q.latency_s >= 0.0


def test_shared_decode_and_cache_hits(lake):
    bbox = PORTO_BBOX
    with SpatialQueryServer(lake, device="cpu", cache_rgs=64,
                            max_wave=64) as srv:
        n_q = 16
        for _ in range(n_q):
            srv.submit(bbox)
        srv.run()
        union = {(s, rg) for s in range(len(lake.index))
                 for rg, _, _ in srv._reader(s).index.page_runs(bbox)}
        # the whole wave decoded each surviving row group exactly once
        assert srv.rg_decodes == len(union)
        assert srv.rg_touches == n_q * len(union)
        m = srv.metrics()
        assert m["shared_decode_ratio"] == pytest.approx(n_q)
        # a second wave over the same region is pure cache hits
        srv.submit(bbox)
        srv.run()
        assert srv.rg_decodes == len(union)
        assert srv.cache.hits >= len(union)


def test_cache_eviction_keeps_results_exact(lake):
    boxes = _boxes()[:10]
    with SpatialQueryServer(lake, device="cpu", cache_rgs=1,
                            max_wave=4) as srv:
        qs = [srv.submit(b) for b in boxes]
        srv.run()
        assert srv.cache.evictions > 0
        assert len(srv.cache) <= 1
    for q, b in zip(qs, boxes):
        geo, extras, _ = lake.scan(b, refine=True, parallel=False)
        _assert_geo_equal(q.geo, geo, ("evict", b))
        for k in extras:
            assert np.array_equal(q.extras[k], extras[k])


def test_generation_invalidation_forces_redecode(lake):
    with SpatialQueryServer(lake, device="cpu", cache_rgs=64) as srv:
        srv.submit(PORTO_BBOX)
        srv.run()
        decodes = srv.rg_decodes
        assert decodes > 0
        srv.submit(PORTO_BBOX)
        srv.run()
        assert srv.rg_decodes == decodes  # warm: no new decode
        srv.invalidate()
        assert len(srv.cache) == 0
        q = srv.submit(PORTO_BBOX)
        srv.run()
        assert srv.rg_decodes == 2 * decodes  # stale entries unreachable
        geo, _, _ = lake.scan(PORTO_BBOX, refine=True, parallel=False)
        _assert_geo_equal(q.geo, geo, "post-invalidate")


def test_catalog_commit_auto_invalidates_next_wave(tmp_path):
    """A compaction commit between waves must bump the server's generation:
    readers reopen, the row-group cache redecodes, results stay identical."""
    from repro.dataset import Catalog, Compactor

    cols = porto_taxi_like(n_traj=240, seed=13)
    extra = {"tid": np.arange(cols.n_records, dtype=np.int64)}
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, n_shards=6,
                  sort="hilbert", page_values=2048)
    scanner = SpatialDatasetScanner(root)
    with SpatialQueryServer(scanner, device="cpu", cache_rgs=64) as srv:
        assert srv.data_generation == 1
        q0 = srv.submit(PORTO_BBOX)
        srv.run()
        decodes = srv.rg_decodes
        assert decodes > 0
        gen_key = srv.generation

        cat = Catalog.open(root)
        comp = Compactor(cat, target_records=1 << 30, page_values=2048)
        assert comp.run_once().generation == 2

        # next wave: refresh() sees gen 2 → readers closed, cache dropped
        q1 = srv.submit(PORTO_BBOX)
        srv.run()
        assert srv.data_generation == 2
        assert srv.generation == gen_key + 1  # stale cache keys unreachable
        assert srv.rg_decodes > decodes  # the wave redecoded, not served stale
        _assert_geo_equal(q1.geo, q0.geo, "post-compaction")
        for k in q0.extras:
            assert np.array_equal(q1.extras[k], q0.extras[k])
        # steady state: no bump without a commit, cache warm again
        decodes = srv.rg_decodes
        srv.submit(PORTO_BBOX)
        srv.run()
        assert srv.data_generation == 2
        assert srv.rg_decodes == decodes


def test_columns_subset(lake):
    with SpatialQueryServer(lake, device="cpu") as srv:
        q_all = srv.submit(PORTO_BBOX)
        q_geom = srv.submit(PORTO_BBOX, columns=("geometry",))
        srv.run()
    geo, extras, _ = lake.scan(PORTO_BBOX, refine=True, parallel=False)
    _assert_geo_equal(q_all.geo, geo, "columns=None")
    assert set(q_all.extras) == {"tid"}
    assert np.array_equal(q_all.extras["tid"], extras["tid"])
    _assert_geo_equal(q_geom.geo, geo, "columns=(geometry,)")
    assert q_geom.extras == {}

"""Roofline machinery: HLO collective parser, correction math, model flops."""

import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch.roofline import (
    Corrected,
    correct_with_calibration,
    count_params,
    model_flops,
    parse_collectives,
    roofline_terms,
)

HLO = """
HloModule test
fused {
  %p0 = f32[256,1024]{1,0} parameter(0)
}
ENTRY main {
  %x = bf16[32,4096,128]{2,1,0} parameter(0)
  %small = f32[4,2048]{1,0} parameter(1)
  %big = f32[16,128]{1,0} parameter(2)
  %ar = bf16[32,4096,128]{2,1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=add
  %ag = f32[64,2048]{1,0} all-gather(%small), replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%big), replica_groups={{0,1}}, to_apply=add
  %cp = f32[1024]{0} collective-permute(%x), source_target_pairs={{0,1}}
  %a2a = f32[16,64]{1,0} all-to-all(%big), replica_groups={{0,1,2,3}}
}
"""


def test_parse_collectives_shapes_and_ring_model():
    out = parse_collectives(HLO)
    assert set(out) == {"all-reduce", "all-gather", "reduce-scatter",
                        "collective-permute", "all-to-all"}
    ar = out["all-reduce"]
    s = 32 * 4096 * 128 * 2  # bf16
    assert ar["count"] == 1
    assert ar["ring_bytes"] == pytest.approx(2 * s * 3 / 4)
    ag = out["all-gather"]
    assert ag["ring_bytes"] == pytest.approx(64 * 2048 * 4 * 15 / 16)
    rs = out["reduce-scatter"]
    assert rs["raw_bytes"] == 16 * 128 * 4  # operand resolved via symbol table
    assert rs["ring_bytes"] == pytest.approx(16 * 128 * 4 / 2)
    cp = out["collective-permute"]
    assert cp["ring_bytes"] == 1024 * 4


def test_correction_math():
    group = {"flops": 10.0, "bytes": 100.0, "coll_ring": 5.0, "coll_raw": 3.0}
    layer = {"flops": 1.0, "bytes": 10.0, "coll_ring": 0.5, "coll_raw": 0.3}
    outside = {"flops": 7.0, "bytes": 70.0, "coll_ring": 0.0, "coll_raw": 0.0}
    c = correct_with_calibration(group, layer, outside, n_layers=38, period=6)
    assert c.flops == 7.0 + 6 * 10.0 + 2 * 1.0
    assert c.bytes == 70.0 + 6 * 100.0 + 2 * 10.0


def test_roofline_terms_dominance():
    t = roofline_terms(flops=197e12, bytes_=0.0, coll_ring=0.0)
    assert t["dominant"] == "compute" and t["compute_s"] == pytest.approx(1.0)
    assert t["roofline_fraction"] == pytest.approx(1.0)
    t = roofline_terms(flops=197e10, bytes_=819e9, coll_ring=0.0)
    assert t["dominant"] == "memory"
    t = roofline_terms(flops=0.0, bytes_=0.0, coll_ring=50e9 * 3)
    assert t["dominant"] == "collective" and t["collective_s"] == pytest.approx(3.0)


def test_count_params_sane():
    # internlm2-1.8b non-embedding params ~1.5e9
    n = count_params(get_config("internlm2-1.8b"))
    assert 1.2e9 < n < 1.8e9
    # arctic active << total
    total = count_params(get_config("arctic-480b"), active_only=False)
    active = count_params(get_config("arctic-480b"), active_only=True)
    assert total > 4e11 and active < 0.1 * total
    # zamba2 shared block execution-weighted
    za = count_params(get_config("zamba2-1.2b"), active_only=True)
    zs = count_params(get_config("zamba2-1.2b"), active_only=False)
    assert za > zs


def test_model_flops_shapes():
    cfg = get_config("qwen3-8b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    f_pre = model_flops(cfg, SHAPES["prefill_32k"])
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert f_train == pytest.approx(6 * count_params(cfg, True) * 256 * 4096)
    assert f_pre == pytest.approx(2 * count_params(cfg, True) * 32 * 32768)
    assert f_dec == pytest.approx(2 * count_params(cfg, True) * 128)


def test_input_specs_no_allocation():
    """input_specs must return ShapeDtypeStructs for every cell kind."""
    import jax

    from repro.launch.dryrun import input_specs

    cfg = get_config("internlm2-1.8b")
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        specs = input_specs(cfg, SHAPES[shape_name])
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    tr = input_specs(cfg, SHAPES["train_4k"])
    assert set(tr) == {"params", "opt_state", "batch"}
    de = input_specs(cfg, SHAPES["decode_32k"])
    assert de["tokens"].shape == (128, 1)
    assert de["cache"]["layers"]["k"].shape[2] == 32768

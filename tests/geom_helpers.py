"""Shared random-geometry builders for tests (no hypothesis dependency).

Lives outside the test modules so suites that don't use property testing
(test_file_format, test_read_path, ...) can import these without pulling in
the optional ``hypothesis`` wheel.
"""

import numpy as np

from repro.core.geometry import Geometry, signed_area


def _coords(rng, n):
    return np.round(rng.normal(0, 10, (n, 2)), 6)


def _ring(rng, n=5, cw=True):
    ang = np.sort(rng.uniform(0, 2 * np.pi, n))
    pts = np.stack([np.cos(ang), np.sin(ang)], 1) * rng.uniform(0.5, 3.0)
    pts = pts + rng.uniform(-50, 50, 2)
    ring = np.vstack([pts, pts[:1]])
    return ring[::-1].copy() if cw == (signed_area(ring) > 0) else ring


def random_geometry(rng, allow_collection=True) -> Geometry:
    kind = rng.integers(0, 8 if allow_collection else 7)
    if kind == 0:
        return Geometry.empty()
    if kind == 1:
        return Geometry.point(*_coords(rng, 1)[0])
    if kind == 2:
        return Geometry.linestring(_coords(rng, rng.integers(2, 8)))
    if kind == 3:
        holes = [_ring(rng, 4) * 0.1 for _ in range(rng.integers(0, 3))]
        return Geometry.polygon(_ring(rng, rng.integers(4, 8)), holes)
    if kind == 4:
        return Geometry.multipoint(_coords(rng, rng.integers(1, 6)))
    if kind == 5:
        return Geometry.multilinestring(
            [_coords(rng, rng.integers(2, 6)) for _ in range(rng.integers(1, 4))]
        )
    if kind == 6:
        polys = []
        for _ in range(rng.integers(1, 4)):
            holes = [_ring(rng, 4) * 0.1 for _ in range(rng.integers(0, 2))]
            polys.append((_ring(rng, rng.integers(4, 7)), holes))
        return Geometry.multipolygon(polys)
    return Geometry.collection(
        [random_geometry(rng, allow_collection=True) for _ in range(rng.integers(1, 4))]
    )

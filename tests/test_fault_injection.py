"""Fault-injection matrix for the fault-tolerant I/O layer.

Every scenario drives the real read path against the in-process range-GET
server with a deterministic fault schedule, then asserts two things: the
result is bit-identical to a clean local read (minus skipped shards for the
degraded scanner), and the recovery counters (ReadStats / SourceStats)
account for exactly the injected faults — no silent retries, no silent
data loss.
"""

import json
import os
import tempfile

import numpy as np
import pytest

from repro.core.columnar import from_ragged
from repro.core.reader import SpatialParquetReader
from repro.core.writer import MAGIC, MAGIC_V2, write_file
from repro.dataset import (
    DatasetError,
    DatasetManifest,
    ShardReadError,
    SpatialDatasetScanner,
    write_dataset,
)
from repro.io import (
    FAULT_CORRUPT,
    FAULT_ERROR,
    FAULT_STALL,
    FAULT_TRUNCATE,
    ChecksumError,
    FaultSpec,
    InProcessRangeServer,
    LocalFileSource,
    RangeRequestError,
    RemoteRangeSource,
    RetriesExhausted,
    crc32c,
)


@pytest.fixture
def rng():
    return np.random.default_rng(7)


def _point_cols(rng, n, spread=100.0):
    pts = np.round(rng.uniform(-spread, spread, (n, 2)), 6)
    return from_ragged(np.ones(n, np.uint8), pts,
                       np.ones(n, np.int64), np.ones(n, np.int64))


def _write_sample(path, rng, n=6000, **kw):
    cols = _point_cols(rng, n)
    tag = rng.integers(0, 100, n).astype(np.int32)
    kw.setdefault("page_values", 512)
    kw.setdefault("sort", "hilbert")
    kw.setdefault("row_group_records", 2000)
    return write_file(path, columns=cols, extra={"tag": tag},
                      extra_schema={"tag": "<i4"}, **kw)


def _remote(server, **kw):
    """A remote source tuned for tests: instant backoff, deterministic."""
    kw.setdefault("backoff_base", 0.0)
    kw.setdefault("backoff_max", 0.0)
    kw.setdefault("max_concurrency", 1)  # exact request-count assertions
    return RemoteRangeSource(server, **kw)


def _geo_equal(a, b):
    return (
        np.array_equal(a.x, b.x) and np.array_equal(a.y, b.y)
        and np.array_equal(a.types, b.types)
        and np.array_equal(a.rep, b.rep) and np.array_equal(a.defn, b.defn)
    )


@pytest.fixture
def sample(rng, tmp_path):
    p = str(tmp_path / "sample.spqf")
    _write_sample(p, rng)
    with SpatialParquetReader(p) as r:
        clean = r.read_columnar()
    return p, clean


# --------------------------------------------------------------- source unit
PAYLOAD = bytes(range(256)) * 64  # 16 KiB


def test_remote_source_reads_bytes_identically():
    server = InProcessRangeServer(PAYLOAD)
    with _remote(server, block_size=1024, cache_blocks=4) as src:
        assert src.read_at(0, 100) == PAYLOAD[:100]
        assert src.read_at(5000, 3000) == PAYLOAD[5000:8000]
        # reads past EOF are short, like a file
        assert src.read_at(len(PAYLOAD) - 10, 100) == PAYLOAD[-10:]
        buf = bytearray(500)
        assert src.readinto_at(1234, buf) == 500
        assert bytes(buf) == PAYLOAD[1234:1734]


def test_transient_5xx_retried_until_success():
    server = InProcessRangeServer(
        PAYLOAD, faults=[FaultSpec(FAULT_ERROR, times=2)])
    with _remote(server, max_retries=4) as src:
        assert src.read_at(0, 64) == PAYLOAD[:64]
        assert src.stats.retries == 2
        assert server.n_faulted(FAULT_ERROR) == 2
        assert server.n_requests == 3  # 2 failures + 1 success


def test_truncated_response_retried():
    server = InProcessRangeServer(
        PAYLOAD, faults=[FaultSpec(FAULT_TRUNCATE, times=1, drop_bytes=7)])
    with _remote(server) as src:
        assert src.read_at(0, 512) == PAYLOAD[:512]
        assert src.stats.retries == 1
        assert server.n_faulted(FAULT_TRUNCATE) == 1


def test_stalled_read_hits_deadline_and_retries():
    server = InProcessRangeServer(
        PAYLOAD, faults=[FaultSpec(FAULT_STALL, times=1, delay=0.08)])
    with _remote(server, timeout=0.02) as src:
        assert src.read_at(0, 64) == PAYLOAD[:64]
        assert src.stats.timeouts == 1
        assert src.stats.retries == 1


def test_retries_exhausted_is_attributed():
    server = InProcessRangeServer(
        PAYLOAD, faults=[FaultSpec(FAULT_ERROR, times=None)])  # never heals
    with _remote(server, max_retries=3) as src:
        with pytest.raises(RetriesExhausted) as ei:
            src.read_at(0, 64)
    err = ei.value
    assert err.attempts == 4  # 1 try + 3 retries
    assert err.offset == 0
    assert "503" in str(err.last_error)
    assert server.n_requests == 4


def test_fatal_4xx_fails_immediately_without_retry():
    server = InProcessRangeServer(
        PAYLOAD, faults=[FaultSpec(FAULT_ERROR, times=None, status=404)])
    with _remote(server, max_retries=5) as src:
        with pytest.raises(RangeRequestError):
            src.read_at(0, 64)
        assert src.stats.retries == 0
    assert server.n_requests == 1


def test_block_cache_hits_on_rescan():
    server = InProcessRangeServer(PAYLOAD)
    with _remote(server, block_size=1024, cache_blocks=32) as src:
        src.read_at(0, 4096)
        cold = server.n_requests
        src.read_at(0, 4096)
        assert server.n_requests == cold  # warm: zero new GETs
        assert src.stats.cache_hits >= 4
        # refresh bypasses and repopulates the cache
        src.read_at(0, 1024, refresh=True)
        assert server.n_requests == cold + 1


def test_request_coalescing_bounds_gets():
    server = InProcessRangeServer(PAYLOAD)
    with _remote(server, block_size=512, max_request_bytes=4096) as src:
        src.read_at(0, len(PAYLOAD))  # 32 blocks, 8 blocks per GET
        assert server.n_requests == 4


# ------------------------------------------------------------ reader + faults
def test_remote_read_bit_identical_to_local(sample):
    path, (geo, extras, _) = sample
    server = InProcessRangeServer(path)
    with SpatialParquetReader(source=_remote(server)) as r:
        rg, rex, st = r.read_columnar()
    assert _geo_equal(geo, rg)
    assert np.array_equal(extras["tag"], rex["tag"])
    assert st.checksum_failures == 0


def test_transient_faults_during_scan_are_recovered_and_counted(sample):
    path, (geo, extras, _) = sample
    size = os.path.getsize(path)
    # faults pinned to mid-file offsets so they hit data reads, not the
    # footer probes at open time (keeps the per-query ReadStats delta exact)
    mid = (size // 4, size // 2)
    server = InProcessRangeServer(path, faults=[
        FaultSpec(FAULT_ERROR, times=2, match_offset=mid),
        FaultSpec(FAULT_TRUNCATE, times=1, match_offset=mid),
    ])
    src = _remote(server, block_size=4096, timeout=5.0)
    with SpatialParquetReader(source=src) as r:
        rg, rex, st = r.read_columnar()
    assert _geo_equal(geo, rg)
    assert np.array_equal(extras["tag"], rex["tag"])
    assert st.retries == 3  # == injected faults, all transient
    assert server.n_faulted() == 3
    assert st.checksum_failures == 0


def test_corrupt_response_heals_via_checksum_refetch(sample, rng):
    path, (geo, _, _) = sample
    with SpatialParquetReader(path) as r:
        page = r.footer["row_groups"][1]["x_pages"][0]
    server = InProcessRangeServer(path, faults=[
        FaultSpec(FAULT_CORRUPT, times=1,
                  match_offset=(page["offset"], page["offset"] + page["nbytes"])),
    ])
    src = _remote(server, block_size=4096)
    with SpatialParquetReader(source=src) as r:
        rg, _, st = r.read_columnar()
    assert _geo_equal(geo, rg)  # recovered bytes, not the corrupt ones
    assert st.checksum_failures == 1
    assert st.retries >= 1  # the healing refetch
    assert server.n_faulted(FAULT_CORRUPT) == 1


def test_permanent_corruption_raises_attributed_checksum_error(sample):
    path, _ = sample
    with SpatialParquetReader(path) as r:
        page = r.footer["row_groups"][0]["x_pages"][0]
    # block_size > file size: every GET serves the whole object from offset
    # 0, so flip_at lands on the exact page byte in every (never-healing)
    # response — including the cache-bypassing checksum refetch
    server = InProcessRangeServer(path, faults=[
        FaultSpec(FAULT_CORRUPT, times=None, flip_at=page["offset"],
                  match_offset=(page["offset"], page["offset"] + page["nbytes"])),
    ])
    with SpatialParquetReader(source=_remote(server)) as r:
        with pytest.raises(ChecksumError) as ei:
            r.read_columnar()
    assert ei.value.offset == page["offset"]
    assert "checksum mismatch" in str(ei.value)


def test_on_disk_bitflip_detected_by_local_read(rng, tmp_path):
    p = str(tmp_path / "flip.spqf")
    _write_sample(p, rng)
    with SpatialParquetReader(p) as r:
        page = r.footer["row_groups"][0]["y_pages"][0]
    blob = bytearray(open(p, "rb").read())
    blob[page["offset"]] ^= 0x01
    open(p, "wb").write(bytes(blob))
    with SpatialParquetReader(p) as r:
        with pytest.raises(ChecksumError):
            r.read_columnar()
    # verification off: the reader no longer guards decode
    with SpatialParquetReader(p, verify_checksums=False) as r:
        g, _, st = r.read_columnar()  # decodes whatever the bits say
        assert st.checksum_failures == 0


def test_footer_corruption_detected_at_open(rng, tmp_path):
    p = str(tmp_path / "foot.spqf")
    _write_sample(p, rng)
    blob = bytearray(open(p, "rb").read())
    blob[-len(MAGIC_V2) - 4 - 10] ^= 0xFF  # inside the stored footer
    open(p, "wb").write(bytes(blob))
    with pytest.raises(ChecksumError):
        SpatialParquetReader(p)


def test_v1_files_read_without_checksums(rng, tmp_path):
    p2 = str(tmp_path / "v2.spqf")
    p1 = str(tmp_path / "v1.spqf")
    _write_sample(p2, rng)
    rng2 = np.random.default_rng(7)
    _write_sample(p1, rng2, checksums=False)
    raw = open(p1, "rb").read()
    assert raw.startswith(MAGIC) and raw.endswith(MAGIC)
    with SpatialParquetReader(p1) as r:
        assert r.checksum_algo is None
        g1, _, _ = r.read_columnar()
    with SpatialParquetReader(p2) as r:
        g2, _, _ = r.read_columnar()
    assert np.array_equal(np.sort(g1.x), np.sort(g2.x))


def test_device_path_verifies_checksums(sample):
    jax = pytest.importorskip("jax")
    del jax
    path, (geo, _, _) = sample
    with SpatialParquetReader(path) as r:
        page = r.footer["row_groups"][0]["x_pages"][0]
    blob = bytearray(open(path, "rb").read())
    blob[page["offset"] + 1] ^= 0x10
    corrupt = str(path) + ".bad"
    open(corrupt, "wb").write(bytes(blob))
    with SpatialParquetReader(corrupt) as r:
        with pytest.raises(ChecksumError):
            r.read_columnar(device="jax")
    os.unlink(corrupt)


# ----------------------------------------------------------- degraded scans
@pytest.fixture
def lake(rng, tmp_path):
    n = 8000
    cols = _point_cols(rng, n)
    root = str(tmp_path / "lake")
    os.makedirs(root)
    manifest = write_dataset(root, columns=cols, n_shards=4,
                             page_values=512)
    sc = SpatialDatasetScanner(root)
    clean_geo, _, _ = sc.scan()
    return root, manifest, clean_geo


def _corrupt_shard(root, manifest, i):
    path = os.path.join(root, manifest.shards[i].path)
    with SpatialParquetReader(path) as r:
        page = r.footer["row_groups"][0]["x_pages"][0]
    blob = bytearray(open(path, "rb").read())
    blob[page["offset"]] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    return path


def test_scanner_raise_policy_attributes_shard(lake):
    root, manifest, _ = lake
    _corrupt_shard(root, manifest, 2)
    sc = SpatialDatasetScanner(root, on_error="raise")
    with pytest.raises(ShardReadError) as ei:
        sc.scan()
    assert ei.value.shard_index == 2
    assert isinstance(ei.value.cause, ChecksumError)


def test_scanner_skip_policy_returns_surviving_shards(lake):
    root, manifest, clean_geo = lake
    _corrupt_shard(root, manifest, 1)
    sc = SpatialDatasetScanner(root, on_error="skip", shard_retries=1)
    geo, _, st = sc.scan()
    # bit-identical to the clean scan minus exactly the skipped shard
    lost = manifest.shards[1].n_records
    assert geo.n_records == clean_geo.n_records - lost
    healthy = np.sort(clean_geo.x)
    degraded = np.sort(geo.x)
    assert np.isin(degraded, healthy).all()
    assert st.shards_failed == 1
    assert st.failures[0].shard_index == 1
    assert st.failures[0].error_type == "ChecksumError"
    assert st.failures[0].attempts == 2  # 1 try + 1 shard retry
    assert st.shard_retries == 1
    assert st.shards_read == 3


def test_scanner_retry_policy_heals_transient_shard(lake):
    root, manifest, clean_geo = lake
    # shard 0's server 5xxs long enough to sink the first open (source does
    # 1 try, no retries), then heals: the scanner's shard-level retry wins
    servers = {}

    def factory(path):
        if path not in servers:
            faults = []
            if path.endswith(manifest.shards[0].path):
                faults = [FaultSpec(FAULT_ERROR, times=1)]
            servers[path] = InProcessRangeServer(path, faults=faults)
        return RemoteRangeSource(servers[path], max_retries=0,
                                 backoff_base=0.0, backoff_max=0.0)

    sc = SpatialDatasetScanner(root, on_error="retry", shard_retries=2,
                               source_factory=factory)
    geo, _, st = sc.scan()
    assert geo.n_records == clean_geo.n_records
    assert np.array_equal(np.sort(geo.x), np.sort(clean_geo.x))
    assert st.shard_retries == 1
    assert st.shards_failed == 0


def test_scanner_retry_policy_exhausts_to_error(lake):
    root, manifest, _ = lake
    _corrupt_shard(root, manifest, 0)
    sc = SpatialDatasetScanner(root, on_error="retry", shard_retries=1)
    with pytest.raises(ShardReadError) as ei:
        sc.scan()
    assert ei.value.shard_index == 0


def test_scanner_rejects_unknown_policy(lake):
    root, _, _ = lake
    with pytest.raises(ValueError):
        SpatialDatasetScanner(root, on_error="ignore")


# -------------------------------------------------------- manifest hardening
def test_manifest_errors_are_attributed(lake, tmp_path):
    root, manifest, _ = lake
    mp = os.path.join(root, "manifest.json")

    def check(content, needle):
        open(mp, "w").write(content)
        with pytest.raises(DatasetError) as ei:
            DatasetManifest.load(root)
        assert needle in str(ei.value)

    d = manifest.to_dict()
    check('{"format": "spatial-parquet-dataset"', "not valid JSON")
    check('[]', "JSON object")
    check('{"format": "something-else"}', "not a spatial-parquet-dataset")
    check(json.dumps({**d, "version": 99}), "newer than")
    check(json.dumps({k: v for k, v in d.items() if k != "shards"}),
          "missing key 'shards'")
    bad = json.loads(json.dumps(d)); del bad["shards"][0]["mbr"]
    check(json.dumps(bad), "missing key 'mbr'")
    bad = json.loads(json.dumps(d)); bad["shards"][0]["path"] = "../../etc/x"
    check(json.dumps(bad), "escapes the dataset root")
    bad = json.loads(json.dumps(d)); bad["shards"][0]["path"] = "/abs/path"
    check(json.dumps(bad), "escapes the dataset root")
    bad = json.loads(json.dumps(d)); bad["shards"][0]["n_records"] = -3
    check(json.dumps(bad), "non-negative")
    bad = json.loads(json.dumps(d)); bad["shards"].pop()
    check(json.dumps(bad), "partial write")
    missing = str(tmp_path / "nowhere")
    with pytest.raises(DatasetError) as ei:
        DatasetManifest.load(missing)
    assert "no manifest found" in str(ei.value)


def test_good_manifest_roundtrips_after_hardening(lake):
    root, manifest, _ = lake
    loaded = DatasetManifest.load(root)
    assert loaded.to_dict() == manifest.to_dict()


# ------------------------------------------------------------ lifecycle edges
def test_reader_closes_source_when_open_fails(tmp_path):
    p = str(tmp_path / "garbage.spqf")
    open(p, "wb").write(b"not a spatial parquet file at all........")
    src = LocalFileSource(p)
    with pytest.raises(ValueError):
        SpatialParquetReader(source=src)
    assert src.closed


def test_reader_close_is_idempotent(sample):
    path, _ = sample
    r = SpatialParquetReader(path)
    r.read_columnar()
    r.close()
    r.close()
    assert r.closed


def test_crc32c_known_vectors():
    # RFC 3720 / kernel test vectors for Castagnoli CRC
    assert crc32c(b"") == 0
    assert crc32c(b"123456789") == 0xE3069283
    assert crc32c(bytes(32)) == 0x8A9136AA

"""Distribution tests that need >1 device: run in subprocesses with forced
host device counts (tests themselves keep the real 1-device view)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_runs_8dev():
    out = _run("""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.train.optimizer import OptConfig, opt_init
        from repro.train.train_loop import make_train_step
        from repro.models.model import build_model

        cfg = get_config("internlm2-1.8b").reduced()
        mesh = make_host_mesh(4, 2)
        assert dict(mesh.shape) == {"data": 4, "model": 2}
        oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        step_fn, pshard, oshard, bstruct, bshard, fb = make_train_step(
            cfg, mesh, oc, global_batch=8, seq=64)
        model = build_model(cfg)
        with mesh:
            params = jax.jit(model.init, out_shardings=pshard)(jax.random.PRNGKey(0))
            opt = jax.jit(lambda p: opt_init(oc, p, cfg.opt_state_dtype),
                          out_shardings=oshard)(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, cfg.vocab, (1, 8, 64)).astype(np.int32)}
        batch = jax.tree.map(lambda a, s: jax.device_put(a, s), batch, bshard)
        losses = []
        for _ in range(3):
            params, opt, metrics = step_fn(params, opt, batch)
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(l) for l in losses), losses
        assert losses[-1] < losses[0], losses
        print("SHARDED_OK", losses)
    """)
    assert "SHARDED_OK" in out


def test_elastic_restore_across_device_counts(tmp_path):
    """Save on a 2-device mesh, restore on 8 devices (elastic scaling)."""
    ckpt = str(tmp_path / "ck")
    _run(f"""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.train.optimizer import OptConfig, opt_init
        from repro.train.train_loop import make_train_step
        from repro.models.model import build_model
        from repro.train.checkpoint import CheckpointManager

        cfg = get_config("internlm2-1.8b").reduced()
        mesh = make_host_mesh(2, 1)
        oc = OptConfig()
        step_fn, pshard, oshard, bstruct, bshard, fb = make_train_step(
            cfg, mesh, oc, global_batch=4, seq=32)
        model = build_model(cfg)
        with mesh:
            params = jax.jit(model.init, out_shardings=pshard)(jax.random.PRNGKey(7))
            opt = jax.jit(lambda p: opt_init(oc, p, cfg.opt_state_dtype),
                          out_shardings=oshard)(params)
        mgr = CheckpointManager({ckpt!r}, async_save=False)
        mgr.save(11, params, opt)
        print("SAVED", float(jax.tree.leaves(params)[0].sum()))
    """, devices=2)
    out = _run(f"""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.train.optimizer import OptConfig, opt_init
        from repro.train.train_loop import make_train_step
        from repro.train.checkpoint import CheckpointManager

        cfg = get_config("internlm2-1.8b").reduced()
        mesh = make_host_mesh(4, 2)
        oc = OptConfig()
        step_fn, pshard, oshard, bstruct, bshard, fb = make_train_step(
            cfg, mesh, oc, global_batch=8, seq=32)
        mgr = CheckpointManager({ckpt!r}, async_save=False)
        restored = mgr.restore_latest(mesh, pshard, oshard)
        assert restored is not None
        step, params, opt = restored
        assert step == 11
        rng = np.random.default_rng(0)
        batch = {{"tokens": rng.integers(0, cfg.vocab, (1, 8, 32)).astype(np.int32)}}
        batch = jax.tree.map(lambda a, s: jax.device_put(a, s), batch, bshard)
        params, opt, metrics = step_fn(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        print("ELASTIC_OK", float(metrics["loss"]))
    """, devices=8)
    assert "ELASTIC_OK" in out


def test_supervisor_restarts_after_injected_failure(tmp_path):
    """Trainer crashes at step 6; supervisor relaunches; run completes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    hb = str(tmp_path / "hb")
    ck = str(tmp_path / "ck")
    cmd = [sys.executable, "-m", "repro.launch.supervisor",
           "--heartbeat", hb, "--max-restarts", "2", "--",
           "--arch", "internlm2-1.8b", "--reduced", "--steps", "12",
           "--global-batch", "4", "--seq", "32", "--ckpt-dir", ck,
           "--ckpt-every", "4", "--fail-at-step", "6"]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "restart 1" in r.stdout
    assert "exited cleanly" in r.stdout


def test_param_specs_all_archs_production_mesh():
    """Sharding rules produce valid specs for every arch on the (16,16) mesh
    shape (structure only — uses an abstract mesh, no devices needed)."""
    out = _run("""
        import jax
        import numpy as np
        from jax.sharding import Mesh
        from repro.configs import ASSIGNED, get_config
        from repro.models.model import build_model
        from repro.sharding.specs import param_specs

        from repro.launch.mesh import _mk_mesh
        mesh = _mk_mesh((16, 16), ("data", "model"))
        for arch in ASSIGNED:
            cfg = get_config(arch)
            model = build_model(cfg)
            pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            specs, fallbacks = param_specs(cfg, mesh, pshape)
            flat_shapes = jax.tree.leaves(pshape)
            flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "spec") or type(x).__name__ == "PartitionSpec")
            assert len(flat_shapes) == len(flat_specs), arch
            # every sharded dim divides its axis
            print(arch, "fallbacks:", len(fallbacks))
        print("SPECS_OK")
    """, devices=256)
    assert "SPECS_OK" in out


def test_sp_sharded_decode_matches_single_device():
    """Sequence-sharded KV cache (SP fallback) decode == unsharded decode.

    Uses a GQA config whose kv heads don't divide the model axis, forcing
    the cache spec onto the seq-over-'model' path; logits must match a
    single-device run bit-closely."""
    out = _run("""
        import dataclasses, jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.models.model import build_model
        from repro.train.train_loop import make_serve_step

        cfg = get_config("qwen3-8b").reduced()
        # kv=4 heads vs model axis 8 -> not divisible -> SP over model on seq
        cfg = dataclasses.replace(cfg, n_kv_heads=4, n_heads=4, attn_impl="ref")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, PRE, CAP = 8, 31, 64
        rng = np.random.default_rng(0)
        toks = rng.integers(3, cfg.vocab, (B, PRE + 1)).astype(np.int32)

        # single-device reference
        cache = model.init_cache(B, CAP)
        _, cache = model.forward_with_cache(params, {"tokens": toks[:, :PRE]}, cache)
        ref_logits, _ = model.decode_step(params, toks[:, PRE:], cache)
        ref = np.asarray(ref_logits[:, -1])

        # sharded serve_step on a (1, 8) mesh (pure TP/SP; batch unsharded ok)
        mesh = make_host_mesh(1, 8)
        step_fn, pshard, cshape, cshard, tok_shard, fb = make_serve_step(cfg, mesh, B, CAP)
        # verify the cache spec actually seq-shards over 'model'
        kspec = jax.tree_util.tree_flatten_with_path(cshard)[0]
        seq_sharded = any("k" in "".join(str(p) for p in path)
                          and getattr(s.spec[2] if len(s.spec) > 2 else None, "__str__", lambda: "")() == "model"
                          for path, s in kspec if hasattr(s, "spec"))
        with mesh:
            params_s = jax.device_put(params, pshard)
            cache_s = jax.device_put(jax.tree.map(np.asarray, model.init_cache(B, CAP)), cshard)
            # prefill on sharded mesh via jit with the same shardings
            prefill = jax.jit(model.forward_with_cache,
                              in_shardings=(pshard, {"tokens": tok_shard}, cshard),
                              out_shardings=(None, cshard))
            _, cache_s = prefill(params_s, {"tokens": toks[:, :PRE]}, cache_s)
            nxt, cache_s = step_fn(params_s, toks[:, PRE:], cache_s)
        # compare greedy tokens (logits path) with reference argmax
        ref_next = np.argmax(ref, axis=-1)
        got_next = np.asarray(nxt)[:, 0]
        assert np.array_equal(ref_next, got_next), (ref_next, got_next)
        print("SP_DECODE_OK", seq_sharded)
    """, devices=8, timeout=900)
    assert "SP_DECODE_OK" in out

"""Baseline formats (WKB / GeoParquet-like / GeoJSON / Shapefile) roundtrip +
the paper's core storage claim at test scale."""

import os

import numpy as np
import pytest

from repro.baselines.geojson_format import read_geojson, write_geojson
from repro.baselines.geoparquet_like import GeoParquetLikeReader, GeoParquetLikeWriter
from repro.baselines.shapefile import read_shapefile, write_shapefile
from repro.baselines.wkb import geometry_to_wkb, wkb_to_geometry
from repro.core.geometry import TYPE_MULTILINESTRING, TYPE_MULTIPOLYGON, Geometry
from repro.core.writer import write_file
from repro.data.synthetic import porto_taxi_like
from repro.core.columnar import assemble
from tests.geom_helpers import random_geometry


def test_wkb_roundtrip_random(rng):
    for s in range(60):
        g = random_geometry(np.random.default_rng(s))
        buf = geometry_to_wkb(g)
        back, off = wkb_to_geometry(buf)
        assert off == len(buf)
        if g.geom_type == TYPE_MULTIPOLYGON:
            # WKB regroups rings into polygons; flat ring lists must agree
            assert back.geom_type == g.geom_type and len(back.parts) == len(g.parts)
            assert all(np.array_equal(a, b) for a, b in zip(g.parts, back.parts))
        else:
            assert back == g


def test_geojson_roundtrip(tmp_path, rng):
    geoms = [random_geometry(np.random.default_rng(s)) for s in range(40)]
    p = os.path.join(tmp_path, "x.geojson")
    write_geojson(p, geoms)
    back = read_geojson(p)
    assert len(back) == len(geoms)
    for a, b in zip(geoms, back):
        assert a.geom_type == b.geom_type or a.geom_type == 0
        assert abs(a.num_points - b.num_points) == 0


def test_geoparquet_like_roundtrip_and_pruning(tmp_path, rng):
    cols = porto_taxi_like(n_traj=500, seed=3)
    geoms = assemble(cols)
    p = os.path.join(tmp_path, "x.gpq")
    with GeoParquetLikeWriter(p, page_records=64) as w:
        w.write_geometries(geoms)
    r = GeoParquetLikeReader(p)
    back, pr, pt = r.read()
    assert len(back) == len(geoms)
    b0 = geoms[0].bbox()
    got, pr2, pt2 = r.read(bbox=b0)
    assert len(got) >= 1
    r.close()


def test_shapefile_roundtrip(tmp_path, rng):
    geoms = [Geometry.multilinestring([rng.normal(0, 1, (4, 2)), rng.normal(0, 1, (3, 2))])
             for _ in range(20)]
    p = os.path.join(tmp_path, "x.shp")
    write_shapefile(p, geoms)
    back = read_shapefile(p)
    assert len(back) == 20
    for a, b in zip(geoms, back):
        assert b.geom_type == TYPE_MULTILINESTRING
        assert all(np.array_equal(x, y) for x, y in zip(a.parts, b.parts))


def test_paper_claim_spatialparquet_smallest(tmp_path):
    """Table 2 direction at test scale: SP(fp-delta) < WKB-based < GeoJSON."""
    cols = porto_taxi_like(n_traj=1500, seed=4)
    geoms = assemble(cols)
    p_sp = os.path.join(tmp_path, "a.spqf")
    write_file(p_sp, columns=cols, sort="hilbert")
    p_gq = os.path.join(tmp_path, "a.gpq")
    with GeoParquetLikeWriter(p_gq) as w:
        w.write_geometries(geoms)
    p_gj = os.path.join(tmp_path, "a.geojson")
    write_geojson(p_gj, geoms)
    s_sp, s_gq, s_gj = (os.path.getsize(p) for p in (p_sp, p_gq, p_gj))
    assert s_sp < s_gq < s_gj, (s_sp, s_gq, s_gj)
    assert s_sp < 0.6 * s_gq, "expect >1.6x vs WKB-based (paper shows ~2x)"

"""Attribute-predicate pushdown: AST semantics, zone-map pruning soundness,
and cross-level differential equivalence.

The contract under test, at every granularity:

* pruning (shard zone maps, page zone stats) may only *skip work*, never
  change a result — a filtered read is bit-identical to reading everything
  and masking row-by-row with the numpy oracle;
* the fused device path (``bbox ∧ attrs`` inside the decode launch) returns
  exactly the host path's records;
* bbox semantics are canonical at every level: a NaN or inverted bbox
  matches nothing at shard, page, and record granularity alike.
"""

import json
import os

import numpy as np
import pytest

from repro.core import SpatialParquetReader, write_file
from repro.core.filters import (
    And,
    ColumnZones,
    In,
    IsNull,
    Predicate,
    Range,
    canonical_bbox,
    validate_predicate,
)
from repro.core.reader import _LEVEL_NAMES, footer_data_bytes
from repro.data.synthetic import porto_taxi_like
from repro.dataset import SpatialDatasetScanner, SpatialDatasetWriter
from repro.dataset.errors import DatasetError
from repro.dataset.index import DatasetIndex
from repro.dataset.manifest import MANIFEST_NAME, DatasetManifest, ShardInfo

SCHEMA = {"speed": "float64", "heading": "float32", "tid": "int64"}
SUB_BBOX = (-8.65, 41.12, -8.60, 41.18)


def _extras_for(cols, seed=0):
    rng = np.random.default_rng(seed)
    n = cols.n_records
    speed = rng.uniform(0.0, 100.0, n)
    speed[::17] = np.nan
    heading = rng.uniform(-180.0, 180.0, n).astype(np.float32)
    return {"speed": speed, "heading": heading,
            "tid": np.arange(n, dtype=np.int64)}


@pytest.fixture(scope="module")
def spqf(tmp_path_factory):
    cols = porto_taxi_like(n_traj=600, seed=5)
    extra = _extras_for(cols, seed=5)
    path = str(tmp_path_factory.mktemp("filters") / "f.spqf")
    foot = write_file(path, columns=cols, extra=extra, extra_schema=SCHEMA,
                      page_values=1024)
    return path, foot


def _oracle(pred: Predicate, extras: dict) -> np.ndarray:
    """Plain-numpy reference mask (same arrays the reader returns)."""
    return pred.mask(extras)


# --------------------------------------------------------------------- AST
def test_canonical_bbox():
    assert canonical_bbox((0, 1, 2, 3)) == (0.0, 1.0, 2.0, 3.0)
    assert canonical_bbox((1.5, 2.5, 1.5, 2.5)) == (1.5, 2.5, 1.5, 2.5)
    for bad in [(np.nan, 0, 1, 1), (0, np.nan, 1, 1), (0, 0, np.nan, 1),
                (0, 0, 1, np.nan), (2, 0, 1, 1), (0, 2, 1, 1)]:
        assert canonical_bbox(bad) is None


def test_range_mask_semantics():
    v = np.array([np.nan, -0.0, 0.0, 5.0, -5.0, np.inf, -np.inf,
                  np.nextafter(0.0, 1.0)])
    ex = {"c": v}
    # NaN never matches a range
    assert not _oracle(Range("c", -np.inf, np.inf), ex)[0]
    # +-0 compare equal: lo=hi=0.0 keeps both zeros
    m = Range("c", 0.0, 0.0).mask(ex)
    assert m.tolist() == [False, True, True, False, False, False, False, False]
    # denormals sit strictly between 0 and the smallest normal
    m = Range("c", np.nextafter(0.0, 1.0), 1.0).mask(ex)
    assert m[7] and not m[1] and not m[2]
    # both-None = IS NOT NULL
    assert Range("c").mask(ex).tolist() == [False] + [True] * 7


def test_range_rejects_nan_bounds():
    with pytest.raises(ValueError):
        Range("c", lo=np.nan)
    with pytest.raises(ValueError):
        Range("c", hi=float("nan"))


def test_in_rejects_empty_and_nan():
    with pytest.raises(ValueError):
        In("c", ())
    with pytest.raises(ValueError):
        In("c", (1.0, np.nan))


def test_isnull_and_flattening():
    ex = {"a": np.array([1.0, np.nan]), "b": np.array([1, 2], np.int64)}
    assert IsNull("a").mask(ex).tolist() == [False, True]
    assert IsNull("b").mask(ex).tolist() == [False, False]  # ints: no nulls
    p = And(Range("a", 0.0), And(In("b", (2,)), IsNull("a")))
    assert all(not isinstance(c, And) for c in p.preds)
    assert p.columns() == {"a", "b"}
    q = Range("a", 0.0) & In("b", (2,)) & IsNull("a")
    assert q.key == p.key


def test_validate_predicate():
    with pytest.raises(TypeError):
        validate_predicate(object(), SCHEMA)
    with pytest.raises(ValueError, match="not in extra columns"):
        validate_predicate(Range("nope", 0.0), SCHEMA)
    validate_predicate(Range("speed", 0.0) & In("tid", (1,)), SCHEMA)


def test_zone_mask_conservative():
    z = ColumnZones(
        vmin=np.array([0.0, 10.0, np.nan, np.inf]),
        vmax=np.array([5.0, 20.0, np.nan, -np.inf]),
        nnan=np.array([0, 0, -1, 3], np.int64),
        count=np.array([4, 4, -1, 3], np.int64),
    )
    lookup = {"c": z}.get
    # zone 2 has unknown stats -> always kept; zone 3 is all-NaN -> prunable
    assert Range("c", 6.0, 9.0).zone_mask(lookup, 4).tolist() == [
        False, False, True, False]
    assert In("c", (15.0,)).zone_mask(lookup, 4).tolist() == [
        False, True, True, False]
    # IsNull keeps any zone that may hold a NaN
    assert IsNull("c").zone_mask(lookup, 4).tolist() == [
        False, False, True, True]
    # unknown column -> nothing prunable
    assert Range("d", 0.0).zone_mask(lookup, 4).all()


# ------------------------------------------------------------- file level
def test_writer_persists_extra_stats(spqf):
    path, foot = spqf
    r = SpatialParquetReader(path)
    _, extras, _ = r.read_columnar()
    for rg in foot["row_groups"]:
        st = rg["extra_stats"]
        assert set(st) == set(SCHEMA)
        for k in SCHEMA:
            for p in rg["extra"][k]:
                assert "nnan" in p
    agg = foot["row_groups"][0]["extra_stats"]["speed"]
    sp = extras["speed"]
    assert agg["nnan"] == int(np.isnan(sp[: agg["count"]]).sum())
    r.close()


@pytest.mark.parametrize("device", ["cpu", "jax"])
def test_selectivity_sweep_matches_oracle(spqf, device):
    if device == "jax":
        pytest.importorskip("jax")
    path, _ = spqf
    r = SpatialParquetReader(path)
    _, full, _ = r.read_columnar()
    sp = full["speed"]
    qs = np.nanquantile(sp, [0.0, 0.1, 0.5, 0.9, 1.0])
    for lo in qs:
        pred = Range("speed", float(lo))
        ref = _oracle(pred, full)
        _, got, st = r.read_columnar(filter=pred, device=device)
        for k in SCHEMA:
            assert np.array_equal(got[k], full[k][ref],
                                  equal_nan=True), (device, lo, k)
        assert st.records_returned == int(ref.sum())
    r.close()


@pytest.mark.parametrize("device", ["cpu", "jax"])
def test_bbox_and_filter_fused_vs_oracle(spqf, device):
    if device == "jax":
        pytest.importorskip("jax")
    path, _ = spqf
    r = SpatialParquetReader(path)
    pred = Range("speed", 20.0, 60.0) & Range("heading", 0.0)
    geo_b, ex_b, _ = r.read_columnar(bbox=SUB_BBOX, refine=True)
    ref = _oracle(pred, ex_b)
    geo_h, ex_h, _ = r.read_columnar(bbox=SUB_BBOX, refine=True, filter=pred)
    geo_d, ex_d, _ = r.read_columnar(bbox=SUB_BBOX, refine=True, filter=pred,
                                     device=device)
    assert np.array_equal(ex_h["tid"], ex_b["tid"][ref])
    for f in ("types", "type_rep", "rep", "defn", "x", "y"):
        assert np.array_equal(getattr(geo_h, f),
                              np.asarray(getattr(geo_d, f))), (device, f)
    for k in SCHEMA:
        assert np.array_equal(ex_h[k], ex_d[k], equal_nan=True), (device, k)
    r.close()


def test_special_value_columns_roundtrip(tmp_path):
    """NaN / ±0 / denormal / huge-int attribute values: zone stats stay
    conservative, record masks stay exact, f32 columns keep exact bounds."""
    cols = porto_taxi_like(n_traj=64, seed=9)
    n = cols.n_records
    tiny = np.nextafter(0.0, 1.0)
    vals = np.resize(np.array(
        [np.nan, -0.0, 0.0, tiny, -tiny, 1e300, -1e300, 1.0]), n)
    f32 = np.resize(np.array(
        [np.float32(np.nan), np.float32(-0.0), np.float32(3.3),
         np.finfo(np.float32).tiny], np.float32), n)
    big = np.resize(np.array(
        [2**53 + 1, -(2**53) - 1, 0, 2**62], np.int64), n)
    path = str(tmp_path / "sv.spqf")
    write_file(path, columns=cols,
               extra={"v": vals, "f": f32, "big": big},
               extra_schema={"v": "float64", "f": "float32", "big": "int64"},
               page_values=256)
    r = SpatialParquetReader(path)
    _, full, _ = r.read_columnar()
    preds = [
        Range("v", 0.0, 0.0),           # must keep both zeros
        Range("v", tiny, 1.0),          # denormal boundary
        IsNull("v"),
        Range("f", np.float32(3.3), np.float32(3.3)),
        In("big", (2**53 + 1,)),        # > 2^53: float stats are rounded
        Range("big", 2**62, None),
        Range("v", -1e300, None) & IsNull("f"),
    ]
    for pred in preds:
        ref = _oracle(pred, full)
        assert ref.any(), pred.key  # the sweep must actually select rows
        _, got, st = r.read_columnar(filter=pred)
        for k in full:
            assert np.array_equal(got[k], full[k][ref],
                                  equal_nan=True), (pred.key, k)
    r.close()


def test_page_zone_pruning_skips_pages_same_answer(tmp_path):
    """A file sorted so tid is monotone per page: In() prunes most pages via
    zone stats, and the pruned read equals the unpruned one bit-for-bit."""
    cols = porto_taxi_like(n_traj=800, seed=11)
    extra = _extras_for(cols, seed=11)
    path = str(tmp_path / "zp.spqf")
    write_file(path, columns=cols, extra=extra, extra_schema=SCHEMA,
               page_values=512, sort=None)
    r = SpatialParquetReader(path)
    pred = In("tid", (3, 500, 790))
    _, full, st_full = r.read_columnar()
    ref = _oracle(pred, full)
    _, got, st = r.read_columnar(filter=pred)
    assert np.array_equal(got["tid"], full["tid"][ref])
    assert st.pages_read < st_full.pages_read  # zone maps actually pruned
    # pruning changed the work, not the answer: compare against a reader
    # whose zone statistics are erased (every page looks unknown)
    r2 = SpatialParquetReader(path)
    for rg in r2.footer["row_groups"]:
        for pages in rg["extra"].values():
            for p in pages:
                p["vmin"] = p["vmax"] = float("nan")
                p.pop("nnan", None)
    r2.index._zones = None
    _, got2, st2 = r2.read_columnar(filter=pred)
    assert st2.pages_read == st_full.pages_read
    for k in SCHEMA:
        assert np.array_equal(got[k], got2[k], equal_nan=True)
    r.close()
    r2.close()


def test_filter_columns_trimmed_from_output(spqf):
    path, _ = spqf
    r = SpatialParquetReader(path)
    geo, ex, _ = r.read_columnar(filter=Range("speed", 50.0),
                                 columns=("geometry", "tid"))
    assert sorted(ex) == ["tid"]
    assert geo is not None
    # geometry-less projection still filters
    geo2, ex2, _ = r.read_columnar(filter=Range("speed", 50.0),
                                   columns=("tid",))
    assert geo2 is None
    assert np.array_equal(ex2["tid"], ex["tid"])
    r.close()


# ------------------------------------------------- cross-level consistency
def _dataset(tmp_path, n_traj=1200, sort="hilbert", n_shards=4, seed=3):
    cols = porto_taxi_like(n_traj=n_traj, seed=seed)
    extra = _extras_for(cols, seed=seed)
    root = str(tmp_path / f"lake_{sort}_{n_shards}")
    with SpatialDatasetWriter(root, extra_schema=SCHEMA, n_shards=n_shards,
                              sort=sort, page_values=1024) as w:
        w.write_columns(cols, extra=extra)
    return root


def test_bbox_consistency_across_levels(tmp_path):
    """Satellite 1: one canonicalization rule at shard, page, and record
    granularity — the same bbox gives the same answer at every level."""
    root = _dataset(tmp_path)
    sc = SpatialDatasetScanner(root)
    r = sc.open_shard(0)
    nan_boxes = [(np.nan, 0.0, 1.0, 1.0), (0.0, 0.0, np.nan, 1.0)]
    inverted = [(-8.0, 41.0, -9.0, 42.0), (-9.0, 42.0, -8.0, 41.0)]
    for bbox in nan_boxes + inverted:
        assert len(sc.index.query(bbox)) == 0
        assert len(r.index.query(bbox)) == 0
        geo, ex, st = r.read_columnar(bbox=bbox, refine=True)
        assert st.records_returned == 0
        geo, ex, st = sc.scan(bbox=bbox, refine=True)
        assert st.records_returned == 0 and st.shards_read == 0
    # a live bbox agrees between pruning-only and refined record sets:
    # refined records are a subset of every coarser level's selection
    geo_all, ex_all, _ = sc.scan()
    geo_r, ex_r, _ = sc.scan(bbox=SUB_BBOX, refine=True)
    geo_p, ex_p, _ = sc.scan(bbox=SUB_BBOX)  # page/shard pruning only
    assert set(ex_r["tid"]) <= set(ex_p["tid"]) <= set(ex_all["tid"])
    r.close()
    sc.close()


# ----------------------------------------------------------- dataset level
def test_dataset_scan_filter_differential(tmp_path):
    root = _dataset(tmp_path)
    sc = SpatialDatasetScanner(root)
    assert all(s.zone_maps is not None and set(s.zone_maps) == set(SCHEMA)
               for s in sc.manifest.shards)
    pred = Range("speed", 10.0, 35.0)
    geo0, full, st0 = sc.scan()
    ref = _oracle(pred, full)
    g1, e1, s1 = sc.scan(filter=pred)
    g2, e2, s2 = sc.scan(filter=pred, parallel=False)
    for k in SCHEMA:
        assert np.array_equal(e1[k], full[k][ref], equal_nan=True)
        assert np.array_equal(e1[k], e2[k], equal_nan=True)
    # bbox ∧ attrs through the dataset path
    gb, eb, sb = sc.scan(bbox=SUB_BBOX, refine=True)
    refb = _oracle(pred, eb)
    gf, ef, sf = sc.scan(bbox=SUB_BBOX, refine=True, filter=pred)
    assert np.array_equal(ef["tid"], eb["tid"][refb])
    sc.close()


def test_dataset_zone_maps_prune_shards(tmp_path):
    # sort=None keeps input order, so each shard holds a contiguous tid
    # range and In() on a single tid must open exactly one shard
    root = _dataset(tmp_path, sort=None, n_shards=5)
    sc = SpatialDatasetScanner(root)
    pred = In("tid", (7,))
    hit = sc.index.query(None, filter=pred)
    assert len(hit) == 1
    g, e, st = sc.scan(filter=pred)
    assert st.shards_read == 1 and st.shards_total == 5
    assert e["tid"].tolist() == [7]
    # stripping the zone maps may only add work, never change the answer
    man_path = os.path.join(root, MANIFEST_NAME)
    with open(man_path) as fh:
        d = json.load(fh)
    for s in d["shards"]:
        s.pop("zone_maps", None)
    stripped = DatasetManifest.from_dict(d, where="stripped")
    idx = DatasetIndex(stripped)
    assert len(idx.query(None, filter=pred)) == 5
    g2, e2, st2 = sc._scan_pinned(stripped, idx, None, None, False, False,
                                  True, "cpu", False, pred)
    assert st2.shards_read == 5
    for k in SCHEMA:
        assert np.array_equal(e[k], e2[k], equal_nan=True)
    sc.close()


def test_zone_maps_survive_compaction(tmp_path):
    from repro.dataset.catalog import Catalog, Compactor

    root = _dataset(tmp_path, n_traj=600, n_shards=4)
    pred = Range("speed", 0.0, 25.0)
    with SpatialDatasetScanner(root) as sc:
        _, before, _ = sc.scan(filter=pred)
        total = sum(z["count"] for s in sc.manifest.shards
                    for k, z in s.zone_maps.items() if k == "tid")
        assert total == sc.manifest.n_records
    cat = Catalog.open(root)
    comp = Compactor(cat, target_records=1 << 30)
    assert comp.run_once() is not None
    with SpatialDatasetScanner(root) as sc2:
        assert sc2.manifest.n_shards < 4
        for s in sc2.manifest.shards:
            assert s.zone_maps is not None and set(s.zone_maps) == set(SCHEMA)
        _, after, _ = sc2.scan(filter=pred)
        for k in SCHEMA:
            assert np.array_equal(np.sort(before[k]), np.sort(after[k]),
                                  equal_nan=True)


def test_empty_dataset_selectivity_and_scan(tmp_path):
    """Satellite 3: an empty dataset prunes nothing — selectivity is 1.0
    ("no pruning"), not 0.0 ("perfect pruning") — and a filtered scan of
    zero shards returns cleanly."""
    root = str(tmp_path / "empty")
    with SpatialDatasetWriter(root, extra_schema=SCHEMA) as w:
        pass
    sc = SpatialDatasetScanner(root)
    assert sc.index.selectivity(None) == 1.0
    assert sc.index.selectivity((0.0, 0.0, 1.0, 1.0)) == 1.0
    geo, extras, st = sc.scan(filter=Range("speed", 0.0))
    assert geo is None and extras == {} and st.shards_read == 0
    sc.close()
    # same contract one level down, for an empty single file
    from repro.core.columnar import GeometryColumns

    empty = GeometryColumns(*(np.zeros(0, np.uint8) for _ in range(4)),
                            np.zeros(0, np.float64), np.zeros(0, np.float64))
    path = str(tmp_path / "empty.spqf")
    write_file(path, columns=empty)
    r = SpatialParquetReader(path)
    assert r.index.selectivity(None) == 1.0
    r.close()


def test_manifest_zone_map_validation(tmp_path):
    base = dict(path="s.spqf", mbr=(0.0, 0.0, 1.0, 1.0), n_records=1,
                n_values=1, n_pages=1, data_bytes=10, file_bytes=20)
    ShardInfo(**base, zone_maps={"a": {
        "min": 0.0, "max": 1.0, "nnan": 0, "count": 1}}).validate(0, "t")
    for bad in [
        {"a": {"min": 0.0, "max": 1.0, "nnan": 0}},           # missing key
        {"a": {"min": "x", "max": 1.0, "nnan": 0, "count": 1}},
        {"a": {"min": 0.0, "max": 1.0, "nnan": -1, "count": 1}},
        {"a": {"min": 0.0, "max": None, "nnan": 0, "count": 1}},  # half-null
        {"a": {"min": 0.0, "max": 1.0, "nnan": True, "count": 1}},
        "not-a-dict",
    ]:
        with pytest.raises(DatasetError):
            ShardInfo(**base, zone_maps=bad).validate(0, "t")
    # round-trips through to_dict/from_dict (json-safe)
    info = ShardInfo(**base, zone_maps={"a": {
        "min": None, "max": None, "nnan": 3, "count": 3}})
    d = json.loads(json.dumps(info.to_dict()))
    assert ShardInfo.from_dict(d).zone_maps == info.zone_maps


# ------------------------------------------------------------- serve level
@pytest.mark.parametrize("device", ["cpu", "jax"])
def test_serve_per_query_filters(tmp_path, device):
    if device == "jax":
        pytest.importorskip("jax")
    from repro.serve.query_scheduler import SpatialQueryServer

    root = _dataset(tmp_path, n_traj=800, n_shards=3)
    sc = SpatialDatasetScanner(root)
    pred = Range("speed", 20.0, 70.0)
    solo = {
        "a": sc.scan(bbox=SUB_BBOX, refine=True, filter=pred),
        "b": sc.scan(filter=In("tid", (1, 2, 750))),
        "c": sc.scan(bbox=SUB_BBOX, refine=True),
        "d": sc.scan(filter=pred, columns=("geometry", "tid")),
    }
    with SpatialQueryServer(sc, device=device) as srv:
        for _ in range(2):  # second wave re-tests through the rg cache
            qs = {
                "a": srv.submit(bbox=SUB_BBOX, filter=pred),
                "b": srv.submit(filter=In("tid", (1, 2, 750))),
                "c": srv.submit(bbox=SUB_BBOX),
                "d": srv.submit(filter=pred, columns=("geometry", "tid")),
            }
            srv.run()
            for name, q in qs.items():
                geo_s, ex_s, st_s = solo[name]
                assert sorted(q.extras) == sorted(ex_s), name
                for k in q.extras:
                    assert np.array_equal(q.extras[k], ex_s[k],
                                          equal_nan=True), (name, k)
                assert q.stats.bytes_read == st_s.bytes_read, name
                assert q.stats.records_returned == st_s.records_returned
        assert srv.cache.hits > 0
        with pytest.raises(ValueError):
            srv.submit(filter=Range("nope", 0.0))
    sc.close()


# ------------------------------------------------------- stats accounting
@pytest.mark.parametrize("device", ["cpu", "jax"])
@pytest.mark.parametrize("columns", [
    None, ("geometry",), ("geometry", "speed"), ("tid",),
    ("geometry", "speed", "heading", "tid")])
def test_bytes_read_matches_footer_exactly(spqf, device, columns):
    """Satellite 4: ``bytes_read`` equals the footer-declared sizes of the
    blobs the projection actually fetched — level streams only when geometry
    is read, coordinate pages of hit runs, extras pages of requested
    columns — on the host and device paths alike."""
    if device == "jax":
        pytest.importorskip("jax")
    path, foot = spqf
    r = SpatialParquetReader(path)
    want_geom = columns is None or "geometry" in columns
    want_extra = (list(SCHEMA) if columns is None
                  else [c for c in columns if c in SCHEMA])
    expected = 0
    for rg in foot["row_groups"]:
        if want_geom:
            expected += sum(rg[name]["nbytes"] for name in _LEVEL_NAMES)
            expected += sum(p["nbytes"] for p in rg["x_pages"])
            expected += sum(p["nbytes"] for p in rg["y_pages"])
        for k in want_extra:
            expected += sum(p["nbytes"] for p in rg["extra"][k])
    _, _, st = r.read_columnar(columns=columns, device=device)
    assert st.bytes_read == expected, (device, columns)
    assert st.bytes_total == footer_data_bytes(foot)
    r.close()


def test_bytes_read_with_bbox_and_filter(spqf):
    """Pruned reads account exactly too: only hit runs' coordinate pages and
    the extras pages of (requested ∪ filter) columns are counted."""
    path, foot = spqf
    r = SpatialParquetReader(path)
    idx = r.index
    pred = Range("speed", 30.0)
    hit = idx.query(SUB_BBOX, filter=pred)
    runs_by_rg = {}
    for rg_i, p0, p1 in idx.page_runs(SUB_BBOX, hit=hit):
        runs_by_rg.setdefault(rg_i, []).append((p0, p1))
    expected = 0
    for rg_i, runs in runs_by_rg.items():
        rg = foot["row_groups"][rg_i]
        base = int(np.searchsorted(idx.row_group, rg_i, side="left"))
        expected += sum(rg[name]["nbytes"] for name in _LEVEL_NAMES)
        for p0, p1 in runs:
            j0, j1 = base + p0, base + p1 - 1
            expected += int(idx.x_nbytes[j0:j1 + 1].sum()
                            + idx.y_nbytes[j0:j1 + 1].sum())
            for k in ("speed", "tid"):  # requested ∪ filter columns
                expected += sum(rg["extra"][k][p]["nbytes"]
                                for p in range(p0, p1))
    _, ex, st = r.read_columnar(bbox=SUB_BBOX, refine=True, filter=pred,
                                columns=("geometry", "tid"))
    assert st.bytes_read == expected
    assert sorted(ex) == ["tid"]
    r.close()


# ---------------------------------------------------------------- obs wiring
def test_obs_zone_bytes_and_selectivity(tmp_path):
    from repro import obs

    root = _dataset(tmp_path, sort=None, n_shards=4, n_traj=400)
    sc = SpatialDatasetScanner(root)
    obs.enable()
    try:
        sc.scan(filter=In("tid", (5,)))
        snap = obs.snapshot()
        assert snap["counters"].get("pruned.zone_bytes", 0) > 0
        assert "filter.selectivity" in snap["histograms"]
    finally:
        # disable() keeps the registry readable; reset it so later tests
        # observing the module-level snapshot see the pristine empty shape
        obs.enable()
        obs.disable()
    sc.close()

"""Crash-safe catalog suite: commit protocol, pinning, compaction, GC, and
the write-path fault-injection matrix.

The differential contract under test: after ANY injected crash the dataset
directory reopens as either the complete old snapshot or the complete new
one — bit-identical to a clean run of whichever side the crash landed on —
and concurrent scans pinned to a generation stay bit-identical while the
background compactor commits and GC reclaims superseded files.
"""

import json
import os
import threading

import numpy as np
import pytest

from repro.data.synthetic import PORTO_BBOX, porto_taxi_like
from repro.dataset import (
    Catalog,
    CommitConflict,
    Compactor,
    DatasetError,
    DatasetManifest,
    SpatialDatasetScanner,
    file_crc32c,
    pinned_generations,
    write_dataset,
)
from repro.io.faults import (
    CRASH_COMMIT_POST_RENAME,
    CRASH_COMMIT_PRE_RENAME,
    CRASH_COMPACT_MID,
    CRASH_GC_MID,
    CRASH_SHARD_TORN,
    InjectedCrash,
    arm_crash,
    crash_injection,
    disarm_crashes,
)

WRITE_KW = dict(n_shards=4, sort="hilbert", page_values=512,
                row_group_records=2048)


@pytest.fixture(autouse=True)
def _clean_crash_points():
    disarm_crashes()
    yield
    disarm_crashes()


def _cols(seed=7, n_traj=200):
    cols = porto_taxi_like(n_traj=n_traj, seed=seed)
    return cols, {"tid": np.arange(cols.n_records, dtype=np.int64)}


def _snapshot_of_scan(scanner, bbox=None, refine=False, **kw):
    geo, extras, stats = scanner.scan(bbox=bbox, refine=refine, **kw)
    return geo, extras, stats


def _assert_identical(a, b):
    ga, ea, _ = a
    gb, eb, _ = b
    if ga is None or gb is None:
        assert ga is None and gb is None
    else:
        for f in ("types", "type_rep", "rep", "defn", "x", "y"):
            np.testing.assert_array_equal(getattr(ga, f), getattr(gb, f))
    assert set(ea) == set(eb)
    for k in ea:
        np.testing.assert_array_equal(ea[k], eb[k])


# ----------------------------------------------------------- commit protocol
def test_write_commits_snapshot_head_and_mirror(tmp_path):
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    names = sorted(os.listdir(root))
    assert "snap-0000000001.json" in names
    assert "HEAD" in names and "manifest.json" in names
    head = json.loads((root / "HEAD").read_text())
    assert head["generation"] == 1
    snap = json.loads((root / "snap-0000000001.json").read_text())
    assert snap["format"] == "spatial-parquet-snapshot"
    assert snap["parent"] is None
    # mirror == snapshot manifest, and the scanner reports the generation
    assert (json.loads((root / "manifest.json").read_text())
            == snap["manifest"])
    sc = SpatialDatasetScanner(root)
    assert sc.generation == 1
    # every shard entry carries a correct whole-file CRC-32C
    for s in sc.manifest.shards:
        assert s.crc32c == file_crc32c(root / s.path)


def test_second_write_layers_new_generation(tmp_path):
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    first = _snapshot_of_scan(SpatialDatasetScanner(root))
    cols2, extra2 = _cols(seed=8, n_traj=120)
    write_dataset(root, columns=cols2, extra=extra2, **WRITE_KW)
    cat = Catalog.open(root)
    assert cat.head_generation() == 2
    # gen-2 shards are generation-qualified: nothing live was overwritten
    snap2 = cat.head_snapshot()
    assert all(s.path.startswith("shard-g000002-")
               for s in snap2.manifest.shards)
    # gen 1 is inside the retention window and still scannable
    with SpatialDatasetScanner(root, pin_generation=1) as old:
        _assert_identical(first, _snapshot_of_scan(old))


def test_commit_conflict_detected(tmp_path):
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    cat = Catalog.open(root)
    tx = cat.begin()
    # another writer commits the same generation first
    Catalog.open(root).commit_manifest(cat.head_snapshot().manifest)
    with pytest.raises(CommitConflict):
        tx.commit(cat.load_snapshot(1).manifest)
    assert Catalog.open(root).head_generation() == 2


def test_open_heals_stale_head_and_torn_mirror(tmp_path):
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    (root / "HEAD").unlink()
    (root / "manifest.json").write_text('{"torn": tru')  # torn mid-write
    cat = Catalog.open(root)
    assert cat.head_generation() == 1
    assert json.loads((root / "HEAD").read_text())["generation"] == 1
    assert (DatasetManifest.load(root).to_dict()
            == cat.head_snapshot().manifest.to_dict())


def test_legacy_manifest_only_dataset_is_generation_zero(tmp_path):
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    clean = _snapshot_of_scan(SpatialDatasetScanner(root))
    # strip the catalog files: what an old writer would have left behind
    for name in list(os.listdir(root)):
        if name.startswith("snap-") or name == "HEAD":
            (root / name).unlink()
    sc = SpatialDatasetScanner(root)
    assert sc.generation == 0
    _assert_identical(clean, _snapshot_of_scan(sc))
    # and a commit on top of it starts the snapshot chain at 1
    snap = Catalog.open(root).commit_manifest(sc.manifest)
    assert snap.generation == 1


# ------------------------------------------------------------------ pinning
def test_pin_protects_generation_from_gc(tmp_path):
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    cat = Catalog.open(root, keep_snapshots=1)
    pin = cat.pin()  # gen 1
    assert pinned_generations(root) == {1}
    comp = Compactor(cat, target_records=1 << 30, page_values=512,
                     row_group_records=2048)
    assert comp.run_once().generation == 2
    # GC already ran inside commit (auto_gc): pinned gen 1 must survive
    assert (root / "snap-0000000001.json").is_file()
    old_shards = [s.path for s in cat.load_snapshot(1).manifest.shards]
    assert all((root / p).is_file() for p in old_shards)
    pin.release()
    assert pinned_generations(root) == set()
    cat.gc()
    assert not (root / "snap-0000000001.json").exists()
    assert not any((root / p).exists() for p in old_shards)


def test_gc_retention_window_and_foreign_files(tmp_path):
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    (root / "NOTES.txt").write_text("not ours")
    (root / ".snap-0000000009.json.tmp-dead").write_text("orphan tmp")
    cat = Catalog.open(root, keep_snapshots=2)
    m = cat.head_snapshot().manifest
    for _ in range(3):
        cat.commit_manifest(m)
    gens = cat.list_generations()
    assert gens == [3, 4]  # two newest retained, 1 and 2 collected
    assert (root / "NOTES.txt").is_file()  # unrecognized names never touched
    assert not (root / ".snap-0000000009.json.tmp-dead").exists()


def test_orphans_dry_run_matches_gc(tmp_path):
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    (root / "shard-g000099-00000.spqf").write_bytes(b"unreferenced")
    cat = Catalog.open(root, auto_gc=False)
    doomed = cat.orphans()
    assert doomed == ["shard-g000099-00000.spqf"]
    assert cat.gc()["deleted"] == doomed
    assert cat.orphans() == []


# --------------------------------------------------------------- compaction
def test_compaction_is_bit_identical(tmp_path):
    cols, extra = _cols(n_traj=300)
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, n_shards=6,
                  sort="hilbert", page_values=512, row_group_records=2048)
    sc = SpatialDatasetScanner(root)
    x0, y0, x1, y1 = PORTO_BBOX
    boxes = [None, PORTO_BBOX, (x0, y0, (x0 + x1) / 2, (y0 + y1) / 2)]
    before = [_snapshot_of_scan(sc, bbox=b, refine=b is not None)
              for b in boxes]

    cat = Catalog.open(root)
    comp = Compactor(cat, target_records=1 << 30, page_values=512,
                     row_group_records=2048)
    snap = comp.run_once()
    assert snap is not None and snap.generation == 2
    assert snap.manifest.n_shards < 6
    assert snap.manifest.n_records == sc.manifest.n_records

    fresh = SpatialDatasetScanner(root)
    assert fresh.generation == 2
    for b, want in zip(boxes, before):
        _assert_identical(want,
                          _snapshot_of_scan(fresh, bbox=b, refine=b is not None))
    # nothing left to merge
    assert comp.run_once() is None


def test_compaction_plan_respects_target(tmp_path):
    cols, extra = _cols(n_traj=300)
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, n_shards=6,
                  sort="hilbert", page_values=512, row_group_records=2048)
    cat = Catalog.open(root)
    m = cat.head_snapshot().manifest
    per = m.shards[0].n_records
    comp = Compactor(cat, target_records=per * 2)
    runs = comp.plan(m)
    assert runs and all(hi - lo == 2 for lo, hi in runs)
    # a target below any pair produces no plan
    assert Compactor(cat, target_records=1).plan(m) == []


# --------------------------------------------------- crash-injection matrix
def _crash_case(tmp_path, point, **arm_kw):
    """Crash a second-generation write at ``point``; return (root, clean)
    where ``clean`` is the pre-crash scan (the old snapshot's content)."""
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    clean = _snapshot_of_scan(SpatialDatasetScanner(root))
    cols2, extra2 = _cols(seed=9, n_traj=150)
    with crash_injection(point, **arm_kw) as ci:
        write_dataset(root, columns=cols2, extra=extra2, **WRITE_KW)
    assert ci.crashed
    return root, clean


@pytest.mark.parametrize("point,arm_kw", [
    (CRASH_SHARD_TORN, {"truncate_frac": 0.5}),
    (CRASH_SHARD_TORN, {"truncate_to": 0}),
    (CRASH_COMMIT_PRE_RENAME, {}),
])
def test_crash_before_commit_point_keeps_old_snapshot(tmp_path, point, arm_kw):
    root, clean = _crash_case(tmp_path, point, **arm_kw)
    cat = Catalog.open(root)
    assert cat.head_generation() == 1
    sc = SpatialDatasetScanner(root)
    assert sc.generation == 1
    _assert_identical(clean, _snapshot_of_scan(sc))
    # the partial files are recognized orphans; GC removes every one
    deleted = set(cat.gc()["deleted"])
    assert all(n.startswith((".", "shard-g000002-")) for n in deleted)
    live = {s.path for s in cat.head_snapshot().manifest.shards}
    assert live <= set(os.listdir(root))
    _assert_identical(clean, _snapshot_of_scan(SpatialDatasetScanner(root)))


def test_crash_after_commit_point_keeps_new_snapshot(tmp_path):
    root, _ = _crash_case(tmp_path, CRASH_COMMIT_POST_RENAME)
    # the rename IS the commit: generation 2 is live even though HEAD and
    # the mirror were never updated; open() heals both
    cat = Catalog.open(root)
    assert cat.head_generation() == 2
    assert json.loads((root / "HEAD").read_text())["generation"] == 2
    sc = SpatialDatasetScanner(root)
    assert sc.generation == 2
    # bit-identical to a clean run that wrote the same second dataset
    cols2, extra2 = _cols(seed=9, n_traj=150)
    ref_root = tmp_path / "ref"
    write_dataset(ref_root, columns=cols2, extra=extra2, **WRITE_KW)
    _assert_identical(_snapshot_of_scan(SpatialDatasetScanner(ref_root)),
                      _snapshot_of_scan(sc))


def test_crash_mid_compaction_keeps_old_snapshot(tmp_path):
    cols, extra = _cols(n_traj=300)
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, n_shards=6,
                  sort="hilbert", page_values=512, row_group_records=2048)
    clean = _snapshot_of_scan(SpatialDatasetScanner(root))
    cat = Catalog.open(root)
    per = cat.head_snapshot().manifest.shards[0].n_records
    comp = Compactor(cat, target_records=per * 2, page_values=512,
                     row_group_records=2048)
    with crash_injection(CRASH_COMPACT_MID) as ci:
        comp.run_once()
    assert ci.crashed
    cat2 = Catalog.open(root)
    assert cat2.head_generation() == 1
    _assert_identical(clean, _snapshot_of_scan(SpatialDatasetScanner(root)))
    orphans = cat2.gc()["deleted"]
    assert orphans and all(n.startswith("shard-g000002-") for n in orphans)
    # compaction still completes after the crash is gone
    snap = comp.run_once()
    assert snap is not None and snap.generation == 2
    _assert_identical(clean, _snapshot_of_scan(SpatialDatasetScanner(root)))


def test_crash_mid_gc_is_resumable(tmp_path):
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    clean = _snapshot_of_scan(SpatialDatasetScanner(root))
    cat = Catalog.open(root, keep_snapshots=1, auto_gc=False)
    cat.commit_manifest(cat.head_snapshot().manifest, gc=False)
    doomed = cat.orphans()
    assert doomed  # gen-1 snapshot at least
    arm_crash(CRASH_GC_MID)  # dies after the first unlink
    with pytest.raises(InjectedCrash):
        cat.gc()
    disarm_crashes()
    # head unharmed, scans identical, and a re-run finishes the job
    cat2 = Catalog.open(root, keep_snapshots=1)
    assert cat2.head_generation() == 2
    _assert_identical(clean, _snapshot_of_scan(SpatialDatasetScanner(root)))
    cat2.gc()
    assert cat2.orphans() == []


def test_interrupted_writer_burns_one_crash_then_recovers(tmp_path):
    cols, extra = _cols()
    root = tmp_path / "lake"
    with crash_injection(CRASH_COMMIT_PRE_RENAME) as ci:
        write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    assert ci.crashed
    with pytest.raises(DatasetError):
        Catalog.open(root)  # never committed: not a dataset
    # the crash point is disarmed: the retried write succeeds and GC (run
    # inside the commit) removes the first attempt's orphans
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    cat = Catalog.open(root)
    assert cat.head_generation() == 1
    assert cat.orphans() == []


# ------------------------------------------------ satellite 1: writer cleanup
def test_writer_exception_cleans_partial_shards(tmp_path, monkeypatch):
    """An ordinary mid-write failure must not leave partial shard files."""
    import repro.dataset.catalog as catalog_mod

    cols, extra = _cols()
    root = tmp_path / "lake"
    real_write_file = catalog_mod.write_file
    calls = {"n": 0}

    def flaky_write_file(path, **kw):
        calls["n"] += 1
        footer = real_write_file(path, **kw)
        if calls["n"] == 3:
            raise RuntimeError("disk full")
        return footer

    monkeypatch.setattr(catalog_mod, "write_file", flaky_write_file)
    with pytest.raises(RuntimeError, match="disk full"):
        write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    # abort() deleted the staged files; nothing but the empty dir remains
    assert [n for n in os.listdir(root) if n.endswith(".spqf")] == []
    with pytest.raises(DatasetError):
        SpatialDatasetScanner(root)
    # the same failure layered on a live dataset leaves it untouched
    monkeypatch.setattr(catalog_mod, "write_file", real_write_file)
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    clean = _snapshot_of_scan(SpatialDatasetScanner(root))
    calls["n"] = 0
    monkeypatch.setattr(catalog_mod, "write_file", flaky_write_file)
    with pytest.raises(RuntimeError, match="disk full"):
        write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    assert Catalog.open(root).head_generation() == 1
    assert Catalog.open(root).orphans() == []
    _assert_identical(clean, _snapshot_of_scan(SpatialDatasetScanner(root)))


# --------------------------------------- scan-during-compaction differential
def _device_params():
    params = ["cpu"]
    try:
        import jax  # noqa: F401
        params.append("jax")
    except Exception:
        pass
    return params


@pytest.mark.parametrize("on_error", ["raise", "retry", "skip"])
@pytest.mark.parametrize("device", _device_params())
def test_scan_during_compaction_is_bit_identical(tmp_path, on_error, device):
    """A scanner pinned to generation N keeps returning bit-identical
    results while a background compactor commits N+1..N+k and GC runs."""
    cols, extra = _cols(n_traj=240)
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, n_shards=6,
                  sort="hilbert", page_values=512, row_group_records=2048)

    with SpatialDatasetScanner(root, on_error=on_error,
                               pin_generation=1) as sc:
        want = _snapshot_of_scan(sc, bbox=PORTO_BBOX, refine=True,
                                 device=device)
        cat = Catalog.open(root, keep_snapshots=1)
        per = cat.head_snapshot().manifest.shards[0].n_records
        comp = Compactor(cat, target_records=per * 2, page_values=512,
                         row_group_records=2048, interval_s=0.01)
        done = threading.Event()
        results = []

        def scan_loop():
            try:
                for _ in range(8):
                    results.append(_snapshot_of_scan(
                        sc, bbox=PORTO_BBOX, refine=True, device=device))
            finally:
                done.set()

        t = threading.Thread(target=scan_loop)
        with comp:
            t.start()
            done.wait(120)
        t.join(120)
        assert comp.last_error is None
        assert len(results) == 8
        for got in results:
            _assert_identical(want, got)
        # compaction really happened underneath those scans
        assert cat.head_generation() > 1
        # the pinned generation's files survived every auto-GC
        assert all((root / s.path).is_file() for s in sc.manifest.shards)

    # pin released: GC may now reclaim gen 1, and a fresh scanner on the
    # compacted head still returns the identical records
    cat.gc()
    _assert_identical(want, _snapshot_of_scan(
        SpatialDatasetScanner(root), bbox=PORTO_BBOX, refine=True,
        device=device))


# ----------------------------------------- concurrent-transaction regressions
def _meta_manifest(template, shards):
    return DatasetManifest(
        coord_dtype=template.coord_dtype, codec=template.codec,
        encoding=template.encoding, sort=None, extra_schema={},
        shards=shards)


def test_racing_transactions_stage_disjoint_files(tmp_path):
    """Two transactions on the same parent (writer vs compactor) must stage
    under different filenames, and the CAS loser's abort() must only unlink
    its own files — never the winner's committed ones."""
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    tx1 = Catalog.open(root).begin()
    tx2 = Catalog.open(root).begin()
    assert tx1.generation == tx2.generation == 2
    c1 = porto_taxi_like(n_traj=20, seed=1)
    c2 = porto_taxi_like(n_traj=20, seed=2)
    i1 = tx1.stage_shard(c1, page_values=512, row_group_records=2048)
    i2 = tx2.stage_shard(c2, page_values=512, row_group_records=2048)
    assert i1.path != i2.path
    template = tx1.catalog.head_snapshot().manifest
    snap = tx1.commit(_meta_manifest(template, [i1]))
    assert snap.generation == 2
    # tx1's auto-GC ran inside its commit: tx2's in-flight staged file is
    # exempt until the transaction resolves
    assert (root / i2.path).is_file()
    with pytest.raises(CommitConflict):
        tx2.commit(_meta_manifest(template, [i2]))
    tx2.abort()
    assert not (root / i2.path).exists()    # loser cleaned its own file
    assert (root / i1.path).is_file()       # ...and never the winner's
    cat = Catalog.open(root)
    assert cat.head_generation() == 2
    assert [s.path for s in cat.head_snapshot().manifest.shards] == [i1.path]


def test_gc_spares_inflight_staged_files(tmp_path):
    """An explicit gc() racing a live transaction must not collect files the
    about-to-commit snapshot will reference."""
    from repro.dataset.catalog import inflight_names

    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    cat = Catalog.open(root, auto_gc=False)
    tx = cat.begin()
    info = tx.stage_shard(porto_taxi_like(n_traj=20, seed=3),
                          page_values=512, row_group_records=2048)
    other = Catalog.open(root)
    assert other.orphans() == []            # staged file is not an orphan
    other.gc()
    assert (root / info.path).is_file()
    template = cat.head_snapshot().manifest
    tx.commit(_meta_manifest(template, [info]))
    assert (root / info.path).is_file()
    assert inflight_names(root) == set()    # exemption dropped on resolve
    # a dead transaction's staged files DO become collectable orphans
    tx2 = Catalog.open(root).begin()
    dead = tx2.stage_shard(porto_taxi_like(n_traj=20, seed=4),
                           page_values=512, row_group_records=2048)
    tx2._forsake()                          # simulated writer death
    assert dead.path in Catalog.open(root).orphans()


def test_same_generation_cross_process_commit_conflicts(tmp_path, monkeypatch):
    """Even when both committers pass the head CAS (the cross-process stale
    read), the exclusive-create commit point lets exactly one win; the loser
    gets CommitConflict instead of silently overwriting the snapshot."""
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    cat = Catalog.open(root)
    template = cat.head_snapshot().manifest
    tx = cat.begin()                                   # parent 1 → gen 2
    winner = Catalog.open(root)
    winner.commit_manifest(_meta_manifest(template, list(template.shards)))
    committed = (root / "snap-0000000002.json").read_bytes()
    # simulate the other process's CAS read happening before the winner's
    # commit became visible
    monkeypatch.setattr(cat, "head_generation", lambda: 1)
    with pytest.raises(CommitConflict):
        tx.commit(_meta_manifest(template, []))
    assert (root / "snap-0000000002.json").read_bytes() == committed
    # the loser's snapshot temp was cleaned up
    assert not [n for n in os.listdir(root) if n.startswith(".snap-")]


def test_virgin_directory_racing_creators_do_not_share_names(tmp_path):
    """Only the sole in-flight creator of a new root gets the historical
    plain shard names; a concurrent second transaction is token-qualified."""
    root = tmp_path / "lake"
    tx1 = Catalog.open(root, create=True).begin()
    tx2 = Catalog.open(root, create=True).begin()
    try:
        assert tx1.shard_filename(0) == "shard-00000.spqf"
        name2 = tx2.shard_filename(0)
        assert name2.startswith("shard-g000001-") and tx2.token in name2
        assert name2 != tx1.shard_filename(0)
    finally:
        tx1.abort()
        tx2.abort()


def test_compactor_loop_survives_transient_errors(tmp_path):
    """The background loop must count + retry ordinary exceptions, not die
    silently on the first bad tick."""
    import time as _time

    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    cat = Catalog.open(root)
    comp = Compactor(cat, target_records=1 << 30, page_values=512,
                     row_group_records=2048, interval_s=0.01)
    real = comp.run_once
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError("transient store hiccup")
        return real()

    comp.run_once = flaky
    with comp:
        deadline = _time.monotonic() + 60
        while comp.compactions == 0 and _time.monotonic() < deadline:
            _time.sleep(0.02)
    assert comp.compactions >= 1            # recovered and compacted
    assert comp.errors == 2
    assert isinstance(comp.last_error, OSError)
    assert Catalog.open(root).head_generation() == 2


def test_unpinned_scanner_survives_generation_retirement(tmp_path):
    """A long-lived unpinned scanner must keep scanning (against the head)
    after the generation it last saw leaves the retention window."""
    cols, extra = _cols(n_traj=300)
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, n_shards=6,
                  sort="hilbert", page_values=512, row_group_records=2048)
    sc = SpatialDatasetScanner(root)
    assert sc.generation == 1
    clean = _snapshot_of_scan(sc)
    cat = Catalog.open(root, keep_snapshots=1)
    per = cat.head_snapshot().manifest.shards[0].n_records
    comp = Compactor(cat, target_records=per * 2, page_values=512,
                     row_group_records=2048)
    while comp.run_once() is not None:
        pass
    cat.gc()
    assert 1 not in cat.list_generations()  # gen 1 fully retired
    # no refresh(): the scan itself must adopt the newest generation
    got = _snapshot_of_scan(sc)
    _assert_identical(clean, got)


def test_unpinned_scanner_scan_holds_pin_for_scan_duration(tmp_path):
    """Even without pin_generation, each scan() pins its generation so a
    concurrent commit + GC cannot delete files mid-scan; refresh() then
    adopts the new head."""
    cols, extra = _cols()
    root = tmp_path / "lake"
    write_dataset(root, columns=cols, extra=extra, **WRITE_KW)
    sc = SpatialDatasetScanner(root)
    clean = _snapshot_of_scan(sc)
    cat = Catalog.open(root, keep_snapshots=1)
    comp = Compactor(cat, target_records=1 << 30, page_values=512,
                     row_group_records=2048)
    assert comp.run_once().generation == 2
    # gen 1 files may be GC'd between scans, but within the retention
    # window of this catalog they were kept until a later gc(); either way
    # the scanner refreshes and serves the head
    assert sc.refresh() == 2
    assert sc.generation == 2
    _assert_identical(clean, _snapshot_of_scan(sc))

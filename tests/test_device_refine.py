"""Differential suite for the fused on-device decode→bbox-refine scan.

The contract under test: ``read_columnar(device="jax", refine=True)`` (and
the dataset scanner's equivalent) must select a record set **bit-identical**
to the host refine path — NaN-propagating ``minimum.reduceat`` + float
compares — across selectivities, degenerate bboxes (empty, point, full
extent), encodings (fp_delta / raw), codecs, coordinate widths, and page /
row-group layouts, while executing the refinement on-device (order-key limb
math, no ``jax_enable_x64``) and transferring only surviving records.

Everything runs in Pallas interpret mode, so CPU CI exercises the full
chain. Property tests follow the PR 1 optional-deps convention: with
``hypothesis`` installed they generate adversarial floats; without it they
run fixed seeded samples instead of being skipped.
"""

import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.columnar import DeviceCoords, from_ragged
from repro.core.fp_delta import fp_delta_encode, fp_delta_plan
from repro.core.reader import SpatialParquetReader, _bbox_keep_mask
from repro.core.writer import write_file
from repro.data.synthetic import DATASETS
from repro.kernels.fp_delta import (
    build_page_stream,
    build_refine_aux,
    compile_cache_stats,
    decode_refine_stream,
    gather_stream_values,
    ragged_ranges,
)

try:
    from hypothesis import given, settings, strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional wheel
    HAVE_HYPOTHESIS = False

_SEEDS = [0, 1, 7, 42, 1234]


def _ib(a):
    return a.view(np.int64 if a.dtype.itemsize == 8 else np.int32)


def assert_same_result(res_host, res_dev, ctx=""):
    """Full three-tuple equality: every level/coord/extra array bit-for-bit
    plus the stats account."""
    gh, eh, sh = res_host
    gd, ed, sd = res_dev
    assert (gh is None) == (gd is None), ctx
    if gh is not None:
        gd = gd.coords_to_host()
        for f in ("types", "type_rep", "rep", "defn"):
            assert np.array_equal(getattr(gh, f), getattr(gd, f)), (ctx, f)
        assert np.array_equal(_ib(gh.x), _ib(gd.x)), ctx
        assert np.array_equal(_ib(gh.y), _ib(gd.y)), ctx
    assert set(eh) == set(ed), ctx
    for k in eh:
        assert np.array_equal(eh[k], ed[k]), (ctx, k)
    assert sh == sd, ctx


# --------------------------------------------------------------- op-level
def _refine_direct(pages_x, pages_y, counts_per_rec, pairs, bbox, dtype,
                   use_pallas):
    """Drive decode_refine_stream directly from raw per-page value arrays."""
    plans = []
    for px, py in zip(pages_x, pages_y):
        for v in (px, py):
            payload, _ = fp_delta_encode(v.astype(dtype, copy=False))
            plans.append(fp_delta_plan(payload, len(v), dtype))
    stream = build_page_stream(plans)
    aux = build_refine_aux(stream, pairs, counts_per_rec)
    return stream, aux, decode_refine_stream(
        stream, aux, bbox, use_pallas=use_pallas, interpret=True)


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("use_pallas", [True, False])
def test_op_mask_matches_host_oracle(rng, dtype, use_pallas):
    """Adversarial values (NaN, ±inf, ±0, denormals) straddling a kernel
    block boundary: the device mask equals the reduceat oracle."""
    n_rec = 64
    counts = rng.integers(0, 40, n_rec)
    counts[5] = 0
    vals = []
    for c in counts:
        v = rng.normal(0, 5, c)
        for special in (np.nan, np.inf, -np.inf, -0.0, 5e-324):
            if c and rng.random() < 0.3:
                v[rng.integers(0, c)] = special
        vals.append(v.astype(dtype))
    yvals = [rng.normal(0, 5, c).astype(dtype) for c in counts]
    split = 33
    pages_x = [np.concatenate(vals[:split]) if split else np.zeros(0, dtype),
               np.concatenate(vals[split:])]
    pages_y = [np.concatenate(yvals[:split]), np.concatenate(yvals[split:])]
    pairs = [(0, split), (split, n_rec)]
    bbox = (-2.0, -3.0, 4.0, 3.0)
    stream, aux, res = _refine_direct(
        pages_x, pages_y, counts, pairs, bbox, np.dtype(dtype), use_pallas)
    x_all = np.concatenate(pages_x)
    y_all = np.concatenate(pages_y)
    oracle = _bbox_keep_mask(x_all, y_all, counts, bbox)
    assert np.array_equal(res.keep, oracle)
    # survivor gather is bit-exact and only transfers survivors
    sel = res.keep
    ix = ragged_ranges(aux.x_start[sel], aux.counts[sel])
    got = gather_stream_values(res.lo, res.hi, ix, np.dtype(dtype).itemsize * 8,
                               dtype)
    starts = np.cumsum(counts) - counts
    want = np.concatenate(
        [x_all[s : s + c] for s, c in zip(starts[sel], counts[sel])]
        or [np.zeros(0, dtype)])
    assert np.array_equal(_ib(got), _ib(want.astype(dtype)))


def test_op_nan_bbox_keeps_nothing(rng):
    counts = np.array([3, 4])
    xs = [np.arange(7, dtype=np.float64)]
    ys = [np.arange(7, dtype=np.float64)]
    _, _, res = _refine_direct(xs, ys, counts, [(0, 2)],
                               (np.nan, 0.0, 1.0, 1.0), np.dtype(np.float64),
                               True)
    assert not res.keep.any()
    assert res.lo is None  # the launch is skipped entirely


# ------------------------------------------------------------ reader-level
def _pt_file(tmp_path, name="pt.spqf", **kw):
    cols = DATASETS["PT"](n_traj=300)
    path = tmp_path / name
    kw.setdefault("codec", "none")
    kw.setdefault("sort", "hilbert")
    kw.setdefault("page_values", 2048)
    write_file(path, columns=cols, **kw)
    return path


def _quantile_bbox(geo, frac):
    x = np.asarray(geo.x, np.float64)
    y = np.asarray(geo.y, np.float64)
    return (float(x.min()), float(y.min()),
            float(np.quantile(x, frac)), float(np.quantile(y, frac)))


def test_reader_selectivity_sweep(tmp_path):
    """Empty, ~1%, ~10%, ~50%, full-extent and point-degenerate queries:
    full-result equality incl. stats."""
    path = _pt_file(tmp_path)
    with SpatialParquetReader(path) as r:
        g0, _, _ = r.read_columnar()
        boxes = {
            "p01": _quantile_bbox(g0, 0.01),
            "p10": _quantile_bbox(g0, 0.10),
            "p50": _quantile_bbox(g0, 0.50),
            "full": _quantile_bbox(g0, 1.0),
            "point": (float(g0.x[7]), float(g0.y[7]),
                      float(g0.x[7]), float(g0.y[7])),
            "miss": (float(g0.x.min()) - 3.0, float(g0.y.min()) - 3.0,
                     float(g0.x.min()) - 2.0, float(g0.y.min()) - 2.0),
        }
        for name, bbox in boxes.items():
            host = r.read_columnar(bbox=bbox, refine=True)
            dev = r.read_columnar(bbox=bbox, refine=True, device="jax")
            assert_same_result(host, dev, name)
        # full-extent refine keeps everything; miss keeps nothing
        assert r.read_columnar(bbox=boxes["full"], refine=True,
                               device="jax")[2].records_returned == g0.n_records


def test_reader_refines_to_zero_after_page_hits(tmp_path):
    """A bbox that hits pages but no exact record: both paths agree on the
    empty-but-not-None result."""
    path = _pt_file(tmp_path, page_values=512)
    with SpatialParquetReader(path) as r:
        g0, _, _ = r.read_columnar()
        # slot a sliver between two consecutive distinct x values
        xs = np.unique(np.asarray(g0.x, np.float64))
        mid = len(xs) // 2
        lohi = (np.nextafter(xs[mid], xs[mid + 1]),
                np.nextafter(xs[mid + 1], xs[mid]))
        bbox = (lohi[0], float(g0.y.min()), lohi[1], float(g0.y.max()))
        host = r.read_columnar(bbox=bbox, refine=True)
        dev = r.read_columnar(bbox=bbox, refine=True, device="jax")
        assert host[2].pages_read > 0
        assert_same_result(host, dev, "sliver")


@pytest.mark.parametrize("enc,codec,dtype", [
    ("fp_delta", "gzip", np.float64),
    ("raw", "none", np.float64),
    ("raw", "gzip", np.float32),
    ("fp_delta", "none", np.float32),
])
def test_reader_encodings_codecs_widths(tmp_path, enc, codec, dtype):
    cols = DATASETS["eB"](n_points=2500)
    if np.dtype(dtype) == np.float32:
        cols = dataclasses.replace(
            cols, x=cols.x.astype(np.float32), y=cols.y.astype(np.float32))
    path = tmp_path / f"{enc}_{codec}_{np.dtype(dtype).name}.spqf"
    write_file(path, columns=cols, codec=codec, encoding=enc,
               page_values=700, row_group_records=900)
    with SpatialParquetReader(path) as r:
        g0, _, _ = r.read_columnar()
        for frac in (0.2, 0.7):
            bbox = _quantile_bbox(g0, frac)
            assert_same_result(
                r.read_columnar(bbox=bbox, refine=True),
                r.read_columnar(bbox=bbox, refine=True, device="jax"),
                (enc, codec, frac))


def test_reader_boundary_layouts(tmp_path):
    """Records at page and row-group boundaries: tiny pages force every
    record to sit against a boundary; oversized records get solo pages."""
    cols = DATASETS["PT"](n_traj=90)  # trajectories of ~50 points
    path = tmp_path / "tiny_pages.spqf"
    # page_values far below a single trajectory: one record per page, and
    # row groups of 7 records so runs straddle row-group boundaries
    write_file(path, columns=cols, codec="none", sort="hilbert",
               page_values=16, row_group_records=7)
    with SpatialParquetReader(path) as r:
        assert r.footer["row_groups"][0]["x_pages"][0]["rec_count"] >= 1
        g0, _, _ = r.read_columnar()
        for frac in (0.15, 0.5, 0.9):
            bbox = _quantile_bbox(g0, frac)
            assert_same_result(
                r.read_columnar(bbox=bbox, refine=True),
                r.read_columnar(bbox=bbox, refine=True, device="jax"),
                frac)


def test_reader_empty_and_collection_records(tmp_path):
    """Empty geometries (no coordinates) are dropped by refine on both
    paths, kept by plain reads on both paths."""
    n = 40
    types = np.full(n, 1, np.uint8)
    parts_per = np.ones(n, np.int64)
    parts_per[::5] = 0  # every 5th record empty
    types[::5] = 0
    n_vals = int((parts_per > 0).sum())
    coords = np.stack([np.linspace(0, 1, n_vals),
                       np.linspace(0, 1, n_vals)], 1)
    cols = from_ragged(types, coords, np.ones(n_vals, np.int64), parts_per)
    path = tmp_path / "empties.spqf"
    write_file(path, columns=cols, codec="none", page_values=8)
    with SpatialParquetReader(path) as r:
        bbox = (0.0, 0.0, 0.6, 0.6)
        assert_same_result(
            r.read_columnar(bbox=bbox, refine=True),
            r.read_columnar(bbox=bbox, refine=True, device="jax"),
            "empties")
        host = r.read_columnar(bbox=bbox, refine=True)
        assert host[0].n_records < host[2].records_scanned


def test_fused_chunking_and_host_pair_fallback(tmp_path, monkeypatch):
    """With a tiny launch cap the fused path must split page pairs across
    launches, and host-decode pairs too large for any launch — same record
    set and bits either way."""
    import repro.kernels.fp_delta.ops as fpd_ops

    path = _pt_file(tmp_path, name="chunk.spqf", page_values=256)
    with SpatialParquetReader(path) as r:
        g0, _, _ = r.read_columnar()
        bbox = _quantile_bbox(g0, 0.6)
        host = r.read_columnar(bbox=bbox, refine=True)
        monkeypatch.setattr(fpd_ops, "_MAX_LAUNCH_BITS", 8192)  # ~1 pair/launch
        assert_same_result(
            host, r.read_columnar(bbox=bbox, refine=True, device="jax"),
            "multi-chunk")
        monkeypatch.setattr(fpd_ops, "_MAX_LAUNCH_BITS", 1024)  # pairs too big
        assert_same_result(
            host, r.read_columnar(bbox=bbox, refine=True, device="jax"),
            "host-pair fallback")


def test_reader_geometry_collections(tmp_path, rng):
    """Multi-sub-geometry records (GeometryCollections with embedded empty
    sub-geometries) keep their type_rep structure through the fused filter."""
    from repro.core.columnar import shred
    from repro.core.geometry import (
        TYPE_GEOMETRYCOLLECTION,
        TYPE_LINESTRING,
        TYPE_POINT,
        Geometry,
    )

    geoms = []
    for i in range(60):
        if i % 3 == 0:
            geoms.append(Geometry(TYPE_POINT, [rng.uniform(0, 10, (1, 2))]))
        elif i % 3 == 1:
            geoms.append(Geometry(TYPE_LINESTRING, [rng.uniform(0, 10, (4, 2))]))
        else:
            subs = [Geometry(TYPE_POINT, [rng.uniform(0, 10, (1, 2))]),
                    Geometry.empty(),
                    Geometry(TYPE_LINESTRING, [rng.uniform(0, 10, (3, 2))])]
            geoms.append(Geometry(TYPE_GEOMETRYCOLLECTION, [], subs))
    path = tmp_path / "collections.spqf"
    write_file(path, columns=shred(geoms), codec="none", page_values=12)
    with SpatialParquetReader(path) as r:
        for bbox in [(1.0, 1.0, 6.0, 6.0), (0.0, 0.0, 10.0, 10.0),
                     (9.9, 9.9, 9.95, 9.95)]:
            assert_same_result(
                r.read_columnar(bbox=bbox, refine=True),
                r.read_columnar(bbox=bbox, refine=True, device="jax"),
                bbox)


def test_extras_filtered_through_fused_refine(tmp_path, rng):
    """Extra columns (multi-dtype) are record-filtered by the device mask
    exactly like the host path, including column projections."""
    from repro.core.columnar import assemble
    from repro.core.writer import SpatialParquetWriter

    geoms = assemble(DATASETS["PT"](n_traj=150))
    n = len(geoms)
    extra = {"ts": np.arange(n, dtype=np.int64),
             "w": rng.normal(0, 1, n).astype(np.float32)}
    path = tmp_path / "extras.spqf"
    with SpatialParquetWriter(path, codec="none", page_values=512,
                              extra_schema={"ts": "<i8", "w": "<f4"}) as wtr:
        wtr.write_geometries(geoms, extra=extra)
    with SpatialParquetReader(path) as r:
        g0, e0, _ = r.read_columnar()
        assert set(e0) == {"ts", "w"}
        bbox = _quantile_bbox(g0, 0.5)
        host = r.read_columnar(bbox=bbox, refine=True)
        assert 0 < len(host[1]["ts"]) < n  # the refine actually filtered
        assert_same_result(
            host, r.read_columnar(bbox=bbox, refine=True, device="jax"),
            "extras")
        assert_same_result(
            r.read_columnar(bbox=bbox, columns=("geometry", "w"), refine=True),
            r.read_columnar(bbox=bbox, columns=("geometry", "w"), refine=True,
                            device="jax"),
            "projection")


def test_keep_on_device_roundtrip(tmp_path):
    path = _pt_file(tmp_path)
    with SpatialParquetReader(path) as r:
        g0, _, _ = r.read_columnar()
        bbox = _quantile_bbox(g0, 0.4)
        gh, eh, sh = r.read_columnar(bbox=bbox, refine=True)
        gk, ek, sk = r.read_columnar(bbox=bbox, refine=True, device="jax",
                                     keep_on_device=True)
        assert isinstance(gk.x, DeviceCoords) and isinstance(gk.y, DeviceCoords)
        assert len(gk.x) == gh.n_values  # structural API works device-side
        assert gk.n_records == gh.n_records
        host = gk.coords_to_host()
        assert np.array_equal(_ib(gh.x), _ib(host.x))
        assert np.array_equal(_ib(gh.y), _ib(host.y))
        assert sh == sk
        # plain full read may also stay device-resident
        gk2, _, _ = r.read_columnar(device="jax", keep_on_device=True)
        assert np.array_equal(_ib(g0.x), _ib(gk2.coords_to_host().x))
        with pytest.raises(ValueError, match="keep_on_device"):
            r.read_columnar(keep_on_device=True)


def test_float32_bound_rounding_gap(tmp_path):
    """A float32 coordinate in the rounding gap of a float64 query bound:
    np.float32(0.1) == 0.10000000149 > 0.1, so the host drops it — the
    device bound must tighten to the largest f32 <= 0.1 (regression: NEP 50
    weak promotion silently skipped the tightening)."""
    from repro.kernels.minmax.ref import _canonical_bound

    assert float(_canonical_bound(0.1, np.float32, "hi")) < 0.1
    assert float(_canonical_bound(0.1, np.float32, "lo")) > 0.1
    assert float(_canonical_bound(1e300, np.float32, "hi")) == float(
        np.finfo(np.float32).max)
    n = 32
    xs = np.full(n, np.float32(0.1))  # all sit just above the f64 bound
    ys = np.linspace(0, 1, n).astype(np.float32)
    cols = from_ragged(np.full(n, 1, np.uint8),
                       np.stack([xs, ys], 1).astype(np.float64),
                       np.ones(n, np.int64), np.ones(n, np.int64))
    cols = dataclasses.replace(cols, x=xs, y=ys)
    path = tmp_path / "gap.spqf"
    write_file(path, columns=cols, codec="none", page_values=8)
    with SpatialParquetReader(path) as r:
        for bbox in [(0.0, 0.0, 0.1, 1.0),     # hi bound in the gap: drop all
                     (0.1, 0.0, 1.0, 1.0),     # lo bound in the gap: drop all
                     (0.0, 0.0, 0.2, 1.0)]:    # clear of the gap: keep all
            assert_same_result(
                r.read_columnar(bbox=bbox, refine=True),
                r.read_columnar(bbox=bbox, refine=True, device="jax"),
                bbox)
        assert r.read_columnar(bbox=(0.0, 0.0, 0.1, 1.0), refine=True,
                               device="jax")[2].records_returned == 0


def test_device_coords_numpy_roundtrip(rng):
    for dtype in (np.float64, np.float32):
        arr = rng.normal(0, 1, 257).astype(dtype)
        arr[3] = np.nan
        back = DeviceCoords.from_numpy(arr).to_numpy()
        assert np.array_equal(_ib(arr), _ib(back))


def test_double_buffered_row_groups_equivalence(tmp_path):
    """prefetch_row_groups ∈ {0, 1, 3} are byte-identical, with and without
    the fused device path."""
    cols = DATASETS["PT"](n_traj=200)
    path = tmp_path / "multirg.spqf"
    write_file(path, columns=cols, codec="none", sort="hilbert",
               page_values=256, row_group_records=25)
    results = []
    for pf in (0, 1, 3):
        with SpatialParquetReader(path, prefetch_row_groups=pf) as r:
            assert len(r.footer["row_groups"]) > 3
            g0, e0, s0 = r.read_columnar()
            bbox = _quantile_bbox(g0, 0.5)
            results.append((
                (g0, e0, s0),
                r.read_columnar(bbox=bbox, refine=True),
                r.read_columnar(bbox=bbox, refine=True, device="jax"),
            ))
    for later in results[1:]:
        for a, b in zip(results[0], later):
            assert_same_result(a, b, "prefetch")


# ---------------------------------------------------------- scanner-level
def test_scanner_fused_refine(tmp_path):
    from repro.dataset import SpatialDatasetScanner, write_dataset

    cols = DATASETS["PT"](n_traj=120)
    root = tmp_path / "ds"
    write_dataset(root, columns=cols, n_shards=3, sort="hilbert", codec="none")
    sc = SpatialDatasetScanner(root, max_workers=3)
    x0, y0, x1, y1 = sc.manifest.mbr
    for fx in (0.3, 0.7, 1.0):
        bbox = (x0, y0, x0 + (x1 - x0) * fx, y0 + (y1 - y0) * fx)
        host = sc.scan(bbox=bbox, refine=True)
        dev = sc.scan(bbox=bbox, refine=True, device="jax")
        assert_same_result(host, dev, fx)
        kod = sc.scan(bbox=bbox, refine=True, device="jax",
                      keep_on_device=True)
        assert isinstance(kod[0].x, DeviceCoords)
        assert np.array_equal(_ib(host[0].x), _ib(kod[0].coords_to_host().x))
        assert host[2] == kod[2]


def test_scanner_compile_cache_stable_across_scans(tmp_path):
    """The AOT cache is shared across worker threads: a repeated 4-shard
    device scan must not trace any new shape bucket."""
    from repro.dataset import SpatialDatasetScanner, write_dataset

    cols = DATASETS["PT"](n_traj=100)
    root = tmp_path / "ds_cache"
    write_dataset(root, columns=cols, n_shards=4, sort="hilbert", codec="none")
    sc = SpatialDatasetScanner(root, max_workers=4)
    x0, y0, x1, y1 = sc.manifest.mbr
    bbox = (x0, y0, x0 + (x1 - x0) / 2, y0 + (y1 - y0) / 2)
    sc.scan(bbox=bbox, refine=True, device="jax")
    n1 = compile_cache_stats()["count"]
    assert n1 > 0
    sc.scan(bbox=bbox, refine=True, device="jax")
    sc.scan(bbox=bbox, refine=True, device="jax", keep_on_device=True)
    assert compile_cache_stats()["count"] == n1


# ----------------------------------------------------------- pipeline-level
def test_pipeline_device_batches_identical(tmp_path):
    from repro.data.pipeline import TrajectoryBatcher
    from repro.data.tokenizer import GeoTokenizer
    from repro.dataset import write_dataset

    cols = DATASETS["PT"](n_traj=80)
    root = tmp_path / "ds_pipe"
    write_dataset(root, columns=cols, n_shards=2, sort="hilbert", codec="none")
    x = np.asarray(cols.x, np.float64)
    y = np.asarray(cols.y, np.float64)
    full = (float(x.min()), float(y.min()), float(x.max()), float(y.max()))
    bbox = (full[0], full[1],
            full[0] + (full[2] - full[0]) * 0.7,
            full[1] + (full[3] - full[1]) * 0.7)
    tok = GeoTokenizer(full)
    kw = dict(seq_len=24, global_batch=4, bbox=bbox, seed=11, loop=False)
    host = [b["tokens"] for _, b in zip(range(3), TrajectoryBatcher([root], tok, **kw))]
    dev = [b["tokens"] for _, b in zip(
        range(3), TrajectoryBatcher([root], tok, device="jax", **kw))]
    assert len(host) == len(dev) > 0
    for a, b in zip(host, dev):
        assert np.array_equal(a, b)


# --------------------------------------------------- batched page statistics
def test_column_page_stats_batched_matches_loop(rng):
    """The single-launch batched column_page_stats equals the per-page
    reference (incl. empty pages -> (inf, -inf))."""
    from repro.kernels.minmax import column_page_stats, page_minmax

    values = rng.normal(0, 100, 5000).astype(np.float32)
    bounds = np.unique(rng.integers(0, len(values), 37))
    bounds = np.concatenate([[0], bounds, [len(values)], [len(values)]])
    bounds = np.sort(bounds).astype(np.int64)  # incl. a trailing empty page
    mn, mx = column_page_stats(values, bounds)
    for i in range(len(bounds) - 1):
        chunk = values[bounds[i]: bounds[i + 1]]
        if not len(chunk):
            assert mn[i] == np.inf and mx[i] == -np.inf
        else:
            assert mn[i] == chunk.min() and mx[i] == chunk.max()
    # one launch: a single page_minmax call underneath (smoke: big ragged set)
    mn0, mx0 = column_page_stats(np.zeros(0, np.float32), np.zeros(1, np.int64))
    assert len(mn0) == 0 and len(mx0) == 0


# ------------------------------------------------- adversarial property tests
def _refine_roundtrip(seed):
    rng = np.random.default_rng(seed)
    dtype = np.float64 if seed % 2 == 0 else np.float32
    n_rec = int(rng.integers(1, 40))
    counts = rng.integers(0, 15, n_rec)
    total = int(counts.sum())
    pool = np.array([0.0, -0.0, np.inf, -np.inf, np.nan, 1e-300, -1.5, 2.5,
                     5e-324, 3.14])
    x = pool[rng.integers(0, len(pool), max(total, 1))][:total].astype(dtype)
    y = rng.normal(0, 2, total).astype(dtype)
    split = int(rng.integers(0, n_rec + 1))
    vs = int(counts[:split].sum())
    pairs = [(0, split), (split, n_rec)]
    qs = rng.normal(0, 2, 4)
    bbox = (min(qs[0], qs[1]), min(qs[2], qs[3]),
            max(qs[0], qs[1]), max(qs[2], qs[3]))
    stream, aux, res = _refine_direct(
        [x[:vs], x[vs:]], [y[:vs], y[vs:]], counts, pairs, bbox,
        np.dtype(dtype), True)
    oracle = _bbox_keep_mask(x, y, counts, bbox)
    assert np.array_equal(res.keep, oracle), seed


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=hyp_st.integers(0, 2**32 - 1))
    def test_property_refine_mask(seed):
        _refine_roundtrip(seed)

else:  # deterministic fallback, PR 1 convention: run, don't skip

    @pytest.mark.parametrize("seed", _SEEDS)
    def test_property_refine_mask(seed):
        _refine_roundtrip(seed)

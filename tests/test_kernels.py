"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.kernels import fp_delta as fpd
from repro.kernels.flash_attention import attention
from repro.kernels.minmax import page_minmax


# ------------------------------------------------------------ fp_delta kernel
@pytest.mark.parametrize("gen", ["smooth", "random", "constant", "mixed"])
@pytest.mark.parametrize("n", [1, 1000, 1024, 4096, 5000])
def test_fp_delta_kernel_roundtrip(rng, gen, n):
    if gen == "smooth":
        x = (np.cumsum(rng.normal(0, 1e-4, n)) + 41).astype(np.float32)
    elif gen == "random":
        x = rng.integers(-2**31, 2**31 - 1, n).astype(np.int32).view(np.float32)
    elif gen == "constant":
        x = np.full(n, 2.5, np.float32)
    else:
        x = (np.cumsum(rng.normal(0, 1e-4, n)) + 41).astype(np.float32)
        x[:: max(n // 7, 1)] = rng.normal(0, 1e6, len(x[:: max(n // 7, 1)]))
    s_k = fpd.encode(x, use_pallas=True)
    s_r = fpd.encode(x, use_pallas=False)
    assert np.array_equal(np.asarray(s_k.packed), np.asarray(s_r.packed))
    assert np.array_equal(np.asarray(s_k.widths), np.asarray(s_r.widths))
    assert np.array_equal(np.asarray(s_k.anchors), np.asarray(s_r.anchors))
    for use_pallas in (True, False):
        y = fpd.decode(s_k, use_pallas=use_pallas)
        assert np.array_equal(np.asarray(y).view(np.int32), x.view(np.int32))


def test_fp_delta_bytes_roundtrip(rng):
    x = (np.cumsum(rng.normal(0, 1e-3, 20_000)) - 8.6).astype(np.float32)
    buf = fpd.compress_array(x)
    y = fpd.decompress_array(buf, x.shape, np.float32)
    assert np.array_equal(y.view(np.int32), x.view(np.int32))
    assert len(buf) < x.nbytes


def test_fp_delta_int32(rng):
    x = rng.integers(-5000, 5000, 3000).astype(np.int32)
    buf = fpd.compress_array(x)
    assert np.array_equal(fpd.decompress_array(buf, x.shape, np.int32), x)


def test_width_law(rng):
    """Block width must be the smallest pow2 covering the max delta bits."""
    from repro.kernels.fp_delta.ref import MINIBLOCK, encode_blocks_ref
    x = np.zeros((1, MINIBLOCK), np.float32)
    xi = x.view(np.int32)
    xi[0, 1:] = np.arange(MINIBLOCK - 1) % 3  # deltas {1,1,-2}: zigzag max 3 -> w=2
    outs = jax.jit(encode_blocks_ref)(jnp.asarray(x))
    assert int(outs[1][0]) == 2
    # a single 11-bit outlier becomes an exception, width stays 2
    xi[0, 1] = 300
    outs = jax.jit(encode_blocks_ref)(jnp.asarray(x))
    assert int(outs[1][0]) == 2
    assert int(outs[5][0]) >= 1  # exception recorded


def test_exception_path(rng):
    """Blocks with isolated huge outliers keep a narrow width + exceptions."""
    from repro.kernels.fp_delta.ref import MINIBLOCK, encode_blocks_ref
    import jax.numpy as jnp
    x = (np.cumsum(rng.normal(0, 1e-4, MINIBLOCK)) + 40).astype(np.float32)
    x[100] = -1e30
    x[500] = np.float32(np.inf)
    outs = jax.jit(encode_blocks_ref)(jnp.asarray(x[None]))
    widths, counts = outs[1], outs[5]
    assert int(widths[0]) < 32
    assert int(counts[0]) >= 2
    s = fpd.encode(x)
    y = fpd.decode(s)
    assert np.array_equal(np.asarray(y).view(np.int32), x.view(np.int32))


def test_arbitrary_width_group_packing(rng):
    """pack/unpack at every supported width is the identity."""
    from repro.kernels.fp_delta.ref import WIDTHS, pack_candidate, unpack_candidate
    import jax.numpy as jnp
    for w in WIDTHS:
        vals = jnp.asarray(rng.integers(0, 2**w, 1024, dtype=np.int64).astype(np.uint32))
        words = pack_candidate(vals, w)
        assert int(jnp.count_nonzero(words[1024 * w // 32:])) == 0, w
        back = unpack_candidate(words, w)
        assert np.array_equal(np.asarray(back), np.asarray(vals)), w


# ---------------------------------------------------------------- minmax
@pytest.mark.parametrize("shape", [(1, 2048), (4, 4096), (3, 5000), (2, 100)])
def test_minmax_kernel(rng, shape):
    x = jnp.asarray(rng.normal(0, 5, shape).astype(np.float32))
    mn_k, mx_k = page_minmax(x, use_pallas=True)
    assert np.allclose(np.asarray(mn_k), np.asarray(x).min(1))
    assert np.allclose(np.asarray(mx_k), np.asarray(x).max(1))


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,d,causal",
    [
        (2, 4, 4, 128, 128, 64, True),
        (1, 8, 2, 256, 256, 64, True),
        (2, 2, 2, 128, 128, 32, False),
        (1, 4, 4, 128, 384, 64, True),   # decode-aligned rectangular
        (1, 2, 2, 1, 128, 64, True),     # single-token decode
        (1, 2, 2, 100, 128, 64, True),   # ragged q (front padding)
    ],
)
def test_flash_vs_oracle(rng, b, hq, hkv, sq, sk, d, causal):
    q = jnp.asarray(rng.normal(0, 1, (b, hq, sq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (b, hkv, sk, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (b, hkv, sk, d)).astype(np.float32))
    o_ref = attention(q, k, v, causal=causal, use_pallas=False)
    o_pal = attention(q, k, v, causal=causal, use_pallas=True)
    assert float(jnp.max(jnp.abs(o_ref - o_pal))) < 2e-5


def test_flash_bf16(rng):
    q = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64))).astype(jnp.bfloat16)
    o_ref = attention(q, k, v, causal=True, use_pallas=False)
    o_pal = attention(q, k, v, causal=True, use_pallas=True)
    err = float(jnp.max(jnp.abs(o_ref.astype(jnp.float32) - o_pal.astype(jnp.float32))))
    assert err < 3e-2

"""Serving scheduler + cache spec unit tests."""

import time

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.scheduler import BatchedServer


def _solo_tokens(cfg, params, prompt, max_new_tokens, max_len=64):
    """Reference output: the request alone in a max_batch=1 server."""
    srv = BatchedServer(cfg, params, max_batch=1, max_len=max_len)
    srv.submit(prompt, max_new_tokens=max_new_tokens)
    return srv.run()[0].out_tokens


def test_scheduler_drains_queue(rng):
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, max_batch=3, max_len=64)
    reqs = [srv.submit(rng.integers(3, cfg.vocab, int(n)), max_new_tokens=6, rid=i)
            for i, n in enumerate(rng.integers(3, 12, 7))]
    done = srv.run()
    assert len(done) == 7
    assert {r.rid for r in done} == set(range(7))
    for r in done:
        assert 1 <= len(r.out_tokens) <= 6
        assert r.t_first >= r.t_submit


def test_admission_wave_preserves_inflight_slots(rng):
    """Admitting wave 2 mid-decode must not clobber wave 1's cache rows.

    Request A decodes a few tokens alone, then B is admitted into the free
    slot; A's already-emitted prefix must stand and both outputs must match
    the same request run with no co-tenant (regression: admission used to
    reset ``cache["pos"]`` and the KV rows for the whole batch)."""
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pa = rng.integers(3, cfg.vocab, 9).astype(np.int32)
    pb = rng.integers(3, cfg.vocab, 5).astype(np.int32)

    def alone(prompt, n):
        srv = BatchedServer(cfg, params, max_batch=2, max_len=64)
        srv.submit(prompt, max_new_tokens=n)
        return srv.run()[0].out_tokens

    srv = BatchedServer(cfg, params, max_batch=2, max_len=64)
    a = srv.submit(pa, max_new_tokens=10)
    srv._fill_slots()
    srv._decode_once()
    srv._decode_once()                      # A is mid-generation
    mid = list(a.out_tokens)
    assert len(mid) == 3
    b = srv.submit(pb, max_new_tokens=6)
    done = srv.run()
    assert {r.rid for r in done} == {a.rid, b.rid}
    assert a.out_tokens[: len(mid)] == mid
    assert a.out_tokens == alone(pa, 10)
    assert b.out_tokens == alone(pb, 6)


def test_rids_unique_after_queue_drains(rng):
    """Default rids must keep increasing across drain/refill cycles
    (regression: ``rid = len(self.queue)`` collided after a drain)."""
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, max_batch=2, max_len=64)
    prompts = [rng.integers(3, cfg.vocab, 5).astype(np.int32) for _ in range(4)]
    first = [srv.submit(p, max_new_tokens=3) for p in prompts[:2]]
    done = srv.run()                        # queue drains to empty
    second = [srv.submit(p, max_new_tokens=3) for p in prompts[2:]]
    done += srv.run()
    rids = [r.rid for r in first + second]
    assert len(set(rids)) == 4, rids
    assert {r.rid for r in done} == set(rids)


def test_scheduler_uses_monotonic_clock_and_obs(monkeypatch, rng):
    """Timestamps come from perf_counter (never wall-clock ``time.time``),
    and TTFT / total latency land in the obs histograms."""

    class _NoWallClock:
        perf_counter = staticmethod(time.perf_counter)

        @staticmethod
        def time():
            raise AssertionError("scheduler must not read wall-clock time")

    monkeypatch.setattr("repro.serve.scheduler.time", _NoWallClock)
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, max_batch=2, max_len=64)
    obs.enable()
    try:
        for i in range(3):
            srv.submit(rng.integers(3, cfg.vocab, 4 + i), max_new_tokens=3)
        done = srv.run()
    finally:
        obs.disable()
    assert len(done) == 3
    for r in done:
        assert r.t_done >= r.t_first >= r.t_submit > 0.0
    assert obs.get_registry().histogram("serve.ttft_s").count == 3
    assert obs.get_registry().histogram("serve.latency_s").count == 3
    assert "p50" in obs.percentiles("serve.latency_s")


def test_scheduler_greedy_matches_manual_decode(rng):
    """Single request through the scheduler == manual prefill+decode loop."""
    cfg = get_config("mamba2-130m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompt = rng.integers(3, cfg.vocab, 8).astype(np.int32)

    srv = BatchedServer(cfg, params, max_batch=1, max_len=64)
    req = srv.submit(prompt, max_new_tokens=5)
    done = srv.run()
    got = done[0].out_tokens

    import jax.numpy as jnp
    cache = model.init_cache(1, 64)
    logits, cache = model.forward_with_cache(params, {"tokens": prompt[None]}, cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(4):
        logits, cache = model.decode_step(params, np.array([[toks[-1]]], np.int32), cache)
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert got == toks, (got, toks)

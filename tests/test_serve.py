"""Serving scheduler + cache spec unit tests."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.scheduler import BatchedServer


def test_scheduler_drains_queue(rng):
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, max_batch=3, max_len=64)
    reqs = [srv.submit(rng.integers(3, cfg.vocab, int(n)), max_new_tokens=6, rid=i)
            for i, n in enumerate(rng.integers(3, 12, 7))]
    done = srv.run()
    assert len(done) == 7
    assert {r.rid for r in done} == set(range(7))
    for r in done:
        assert 1 <= len(r.out_tokens) <= 6
        assert r.t_first >= r.t_submit


def test_scheduler_greedy_matches_manual_decode(rng):
    """Single request through the scheduler == manual prefill+decode loop."""
    cfg = get_config("mamba2-130m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompt = rng.integers(3, cfg.vocab, 8).astype(np.int32)

    srv = BatchedServer(cfg, params, max_batch=1, max_len=64)
    req = srv.submit(prompt, max_new_tokens=5)
    done = srv.run()
    got = done[0].out_tokens

    import jax.numpy as jnp
    cache = model.init_cache(1, 64)
    logits, cache = model.forward_with_cache(params, {"tokens": prompt[None]}, cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(4):
        logits, cache = model.decode_step(params, np.array([[toks[-1]]], np.int32), cache)
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert got == toks, (got, toks)

"""Geometry shredding (paper §2): shred∘assemble == id on random geometries.

``hypothesis`` is optional: when missing, the property test runs a fixed
deterministic sample instead of being skipped.
"""

import numpy as np
import pytest

from repro.core.columnar import assemble, from_ragged, multipolygon_polygons, shred
from repro.core.geometry import TYPE_MULTIPOINT, Geometry, is_cw, polygons_from_rings
from repro.core.writer import concat_columns, permute_records, record_centroids
from tests.geom_helpers import _coords, _ring, random_geometry

try:
    from hypothesis import given, settings, strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional wheel
    HAVE_HYPOTHESIS = False


def _check_shred_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    geoms = [random_geometry(rng) for _ in range(n)]
    cols = shred(geoms)
    assert cols.n_records == n
    back = assemble(cols)
    assert back == geoms


if HAVE_HYPOTHESIS:
    @given(hyp_st.integers(0, 10_000), hyp_st.integers(1, 40))
    @settings(max_examples=60, deadline=None)
    def test_shred_assemble_roundtrip(seed, n):
        _check_shred_roundtrip(seed, n)
else:
    @pytest.mark.parametrize("seed,n", [(0, 1), (1, 7), (17, 40), (123, 25), (999, 13)])
    def test_shred_assemble_roundtrip(seed, n):
        _check_shred_roundtrip(seed, n)


def test_multipolygon_winding_reconstruction(rng):
    polys = [(_ring(rng, 6), [_ring(rng, 4) * 0.2, _ring(rng, 4) * 0.1]),
             (_ring(rng, 5), []),
             (_ring(rng, 4), [_ring(rng, 4) * 0.3])]
    g = Geometry.multipolygon(polys)
    regrouped = polygons_from_rings(g.parts)
    assert [len(p) for p in regrouped] == [3, 1, 2]
    for rings in regrouped:
        assert is_cw(rings[0])
        assert all(not is_cw(r) for r in rings[1:])


def test_levels_are_two_bits(rng):
    geoms = [random_geometry(rng) for _ in range(50)]
    cols = shred(geoms)
    assert cols.rep.max() <= 3 and cols.defn.max() <= 1 and cols.type_rep.max() <= 1


def test_permute_records_roundtrip(rng):
    geoms = [random_geometry(rng, allow_collection=True) for _ in range(30)]
    cols = shred(geoms)
    perm = rng.permutation(30)
    permuted = permute_records(cols, perm)
    back = assemble(permuted)
    assert back == [geoms[i] for i in perm]
    # subset gather
    sub = permute_records(cols, np.array([3, 1, 7]))
    assert assemble(sub) == [geoms[3], geoms[1], geoms[7]]


def test_slice_and_concat(rng):
    geoms = [random_geometry(rng) for _ in range(20)]
    cols = shred(geoms)
    a, b = cols.slice_records(0, 7), cols.slice_records(7, 20)
    merged = concat_columns([a, b])
    assert assemble(merged) == geoms


def test_record_centroids_match_bbox(rng):
    geoms = [random_geometry(rng) for _ in range(40)]
    cols = shred(geoms)
    cx, cy = record_centroids(cols)
    for i, g in enumerate(geoms):
        if g.num_points == 0:
            continue
        b = g.bbox()
        assert abs(cx[i] - (b[0] + b[2]) / 2) < 1e-9
        assert abs(cy[i] - (b[1] + b[3]) / 2) < 1e-9


def test_ragged_fastpath_matches_object_path(rng):
    n, k = 200, 12
    coords = _coords(rng, n * k)
    cols_fast = from_ragged(
        np.full(n, TYPE_MULTIPOINT, np.uint8), coords,
        np.ones(n * k, np.int64), np.full(n, k, np.int64),
    )
    geoms = [Geometry.multipoint(coords[i * k : (i + 1) * k]) for i in range(n)]
    cols_obj = shred(geoms)
    for f in ("types", "type_rep", "rep", "defn", "x", "y"):
        assert np.array_equal(getattr(cols_fast, f), getattr(cols_obj, f)), f

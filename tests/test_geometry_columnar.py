"""Geometry shredding (paper §2): shred∘assemble == id on random geometries."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.columnar import assemble, from_ragged, multipolygon_polygons, shred
from repro.core.geometry import (
    TYPE_MULTIPOINT,
    Geometry,
    is_cw,
    polygons_from_rings,
    signed_area,
)
from repro.core.writer import concat_columns, permute_records, record_centroids


def _coords(rng, n):
    return np.round(rng.normal(0, 10, (n, 2)), 6)


def _ring(rng, n=5, cw=True):
    ang = np.sort(rng.uniform(0, 2 * np.pi, n))
    pts = np.stack([np.cos(ang), np.sin(ang)], 1) * rng.uniform(0.5, 3.0)
    pts = pts + rng.uniform(-50, 50, 2)
    ring = np.vstack([pts, pts[:1]])
    return ring[::-1].copy() if cw == (signed_area(ring) > 0) else ring


def random_geometry(rng, allow_collection=True) -> Geometry:
    kind = rng.integers(0, 8 if allow_collection else 7)
    if kind == 0:
        return Geometry.empty()
    if kind == 1:
        return Geometry.point(*_coords(rng, 1)[0])
    if kind == 2:
        return Geometry.linestring(_coords(rng, rng.integers(2, 8)))
    if kind == 3:
        holes = [_ring(rng, 4) * 0.1 for _ in range(rng.integers(0, 3))]
        return Geometry.polygon(_ring(rng, rng.integers(4, 8)), holes)
    if kind == 4:
        return Geometry.multipoint(_coords(rng, rng.integers(1, 6)))
    if kind == 5:
        return Geometry.multilinestring(
            [_coords(rng, rng.integers(2, 6)) for _ in range(rng.integers(1, 4))]
        )
    if kind == 6:
        polys = []
        for _ in range(rng.integers(1, 4)):
            holes = [_ring(rng, 4) * 0.1 for _ in range(rng.integers(0, 2))]
            polys.append((_ring(rng, rng.integers(4, 7)), holes))
        return Geometry.multipolygon(polys)
    return Geometry.collection(
        [random_geometry(rng, allow_collection=True) for _ in range(rng.integers(1, 4))]
    )


@given(st.integers(0, 10_000), st.integers(1, 40))
@settings(max_examples=60, deadline=None)
def test_shred_assemble_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    geoms = [random_geometry(rng) for _ in range(n)]
    cols = shred(geoms)
    assert cols.n_records == n
    back = assemble(cols)
    assert back == geoms


def test_multipolygon_winding_reconstruction(rng):
    polys = [(_ring(rng, 6), [_ring(rng, 4) * 0.2, _ring(rng, 4) * 0.1]),
             (_ring(rng, 5), []),
             (_ring(rng, 4), [_ring(rng, 4) * 0.3])]
    g = Geometry.multipolygon(polys)
    regrouped = polygons_from_rings(g.parts)
    assert [len(p) for p in regrouped] == [3, 1, 2]
    for rings in regrouped:
        assert is_cw(rings[0])
        assert all(not is_cw(r) for r in rings[1:])


def test_levels_are_two_bits(rng):
    geoms = [random_geometry(rng) for _ in range(50)]
    cols = shred(geoms)
    assert cols.rep.max() <= 3 and cols.defn.max() <= 1 and cols.type_rep.max() <= 1


def test_permute_records_roundtrip(rng):
    geoms = [random_geometry(rng, allow_collection=True) for _ in range(30)]
    cols = shred(geoms)
    perm = rng.permutation(30)
    permuted = permute_records(cols, perm)
    back = assemble(permuted)
    assert back == [geoms[i] for i in perm]
    # subset gather
    sub = permute_records(cols, np.array([3, 1, 7]))
    assert assemble(sub) == [geoms[3], geoms[1], geoms[7]]


def test_slice_and_concat(rng):
    geoms = [random_geometry(rng) for _ in range(20)]
    cols = shred(geoms)
    a, b = cols.slice_records(0, 7), cols.slice_records(7, 20)
    merged = concat_columns([a, b])
    assert assemble(merged) == geoms


def test_record_centroids_match_bbox(rng):
    geoms = [random_geometry(rng) for _ in range(40)]
    cols = shred(geoms)
    cx, cy = record_centroids(cols)
    for i, g in enumerate(geoms):
        if g.num_points == 0:
            continue
        b = g.bbox()
        assert abs(cx[i] - (b[0] + b[2]) / 2) < 1e-9
        assert abs(cy[i] - (b[1] + b[3]) / 2) < 1e-9


def test_ragged_fastpath_matches_object_path(rng):
    n, k = 200, 12
    coords = _coords(rng, n * k)
    cols_fast = from_ragged(
        np.full(n, TYPE_MULTIPOINT, np.uint8), coords,
        np.ones(n * k, np.int64), np.full(n, k, np.int64),
    )
    geoms = [Geometry.multipoint(coords[i * k : (i + 1) * k]) for i in range(n)]
    cols_obj = shred(geoms)
    for f in ("types", "type_rep", "rep", "defn", "x", "y"):
        assert np.array_equal(getattr(cols_fast, f), getattr(cols_obj, f)), f

"""Checkpointing: FP-delta compression, integrity, GC, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager, _decode_leaf, _encode_leaf


def _tree(rng):
    return {
        "params": {
            "w": rng.normal(0, 0.02, (64, 32)).astype(np.float32),
            "scale": np.ones(32, np.float32),
            "emb": rng.normal(0, 1, (100, 16)).astype(np.float32),
            "bf": rng.normal(0, 1, (33, 7)).astype(np.float32).astype(jnp.bfloat16),
        },
        "opt_state": {
            "m": {"w": np.zeros((64, 32), np.float32)},
            "step": np.asarray(7, np.int32),
        },
    }


def _eq_tree(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        xa, ya = np.atleast_1d(np.asarray(x)), np.atleast_1d(np.asarray(y))
        assert xa.dtype == ya.dtype and xa.shape == ya.shape
        assert np.array_equal(xa.view(np.uint8), ya.view(np.uint8)), xa.dtype


@pytest.mark.parametrize("compress", [True, False])
def test_save_load_bit_exact(tmp_path, rng, compress):
    t = _tree(rng)
    mgr = CheckpointManager(tmp_path, compress=compress, async_save=False)
    mgr.save(3, t["params"], t["opt_state"])
    step, loaded = mgr.load_host()
    assert step == 3
    _eq_tree(t, loaded)
    if compress:
        assert mgr.last_stats.stored_bytes < mgr.last_stats.raw_bytes * 1.02


def test_leaf_codecs_roundtrip(rng):
    for arr in (rng.normal(0, 1, 5000).astype(np.float32),
                rng.normal(0, 1, 5000).astype(np.float64),
                rng.integers(0, 9, 5000).astype(np.int32),
                rng.normal(0, 1, 4097).astype(np.float32).astype(jnp.bfloat16),
                np.arange(10, dtype=np.int64)):
        buf, codec = _encode_leaf(np.asarray(arr), True)
        back = _decode_leaf(buf, codec, np.asarray(arr).shape, np.asarray(arr).dtype)
        assert np.array_equal(np.asarray(arr).view(np.uint8), back.view(np.uint8))


def test_corruption_detected(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, async_save=False)
    t = _tree(rng)
    mgr.save(1, t["params"], t["opt_state"])
    name = f"step_{1:08d}"
    data = os.path.join(tmp_path, name, "data.bin")
    blob = bytearray(open(data, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(data, "wb").write(bytes(blob))
    with pytest.raises(IOError, match="crc"):
        mgr.load_host()


def test_gc_keeps_last_k(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, t["params"], t["opt_state"])
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, async_save=True)
    t = _tree(rng)
    mgr.save(5, t["params"], t["opt_state"])
    mgr.wait()
    assert mgr.latest_step() == 5


def test_elastic_restore_same_process(tmp_path, rng):
    """Host checkpoint restores under a different mesh (1 device here; the
    cross-device-count restore runs in test_distributed via subprocess)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    mgr = CheckpointManager(tmp_path, async_save=False)
    t = _tree(rng)
    mgr.save(2, t["params"], t["opt_state"])
    mesh = make_host_mesh(1, 1)
    shard = NamedSharding(mesh, P())
    pshard = jax.tree.map(lambda _: shard, t["params"])
    oshard = jax.tree.map(lambda _: shard, t["opt_state"])
    step, params, opt = mgr.restore_latest(mesh, pshard, oshard)
    assert step == 2
    _eq_tree(params, t["params"])

"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real device
count (only launch/dryrun.py forces 512 host devices)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)

"""Minimal ESRI Shapefile (.shp) writer/reader — the third paper baseline.

Implements the 1998 ESRI whitepaper main-file layout for the shape types the
evaluation datasets use: Point(1), PolyLine(3), Polygon(5), MultiPoint(8).
Like the paper's setup, data is partitioned into <=1M-record .shp parts and
compression (gzip) is applied per part file.

(No .shx/.dbf sidecars: the paper strips attributes and compares pure
geometry storage; the .shp main file is where geometry bytes live.)
"""

from __future__ import annotations

import struct

import numpy as np

from repro.core.columnar import multipolygon_polygons
from repro.core.geometry import (
    TYPE_LINESTRING,
    TYPE_MULTILINESTRING,
    TYPE_MULTIPOINT,
    TYPE_MULTIPOLYGON,
    TYPE_POINT,
    TYPE_POLYGON,
    Geometry,
)

SHP_POINT, SHP_POLYLINE, SHP_POLYGON, SHP_MULTIPOINT = 1, 3, 5, 8

_TO_SHP = {
    TYPE_POINT: SHP_POINT,
    TYPE_LINESTRING: SHP_POLYLINE,
    TYPE_MULTILINESTRING: SHP_POLYLINE,
    TYPE_POLYGON: SHP_POLYGON,
    TYPE_MULTIPOLYGON: SHP_POLYGON,
    TYPE_MULTIPOINT: SHP_MULTIPOINT,
}


def _record_body(g: Geometry) -> bytes:
    st = _TO_SHP[g.geom_type]
    if st == SHP_POINT:
        x, y = g.parts[0][0]
        return struct.pack("<idd", SHP_POINT, x, y)
    if st == SHP_MULTIPOINT:
        pts = np.vstack(g.parts)
        xmin, ymin = pts.min(0)
        xmax, ymax = pts.max(0)
        return (
            struct.pack("<i4di", SHP_MULTIPOINT, xmin, ymin, xmax, ymax, len(pts))
            + pts.astype("<f8").tobytes()
        )
    # PolyLine / Polygon: parts + points
    if g.geom_type == TYPE_MULTIPOLYGON:
        rings = [r for poly in multipolygon_polygons(g) for r in poly]
    else:
        rings = g.parts
    pts = np.vstack(rings)
    sizes = np.array([len(r) for r in rings], np.int64)
    part_offsets = (np.cumsum(sizes) - sizes).astype("<i4")
    xmin, ymin = pts.min(0)
    xmax, ymax = pts.max(0)
    return (
        struct.pack("<i4dii", st, xmin, ymin, xmax, ymax, len(rings), len(pts))
        + part_offsets.tobytes()
        + pts.astype("<f8").tobytes()
    )


def write_shapefile(path, geoms: list[Geometry]) -> None:
    records = []
    total = 100  # header bytes
    for i, g in enumerate(geoms):
        body = _record_body(g)
        records.append(struct.pack(">ii", i + 1, len(body) // 2) + body)
        total += len(records[-1])
    boxes = np.array([g.bbox() for g in geoms], np.float64) if geoms else np.zeros((1, 4))
    header = struct.pack(
        ">i5ii", 9994, 0, 0, 0, 0, 0, total // 2
    ) + struct.pack(
        "<ii4d4d",
        1000, _TO_SHP[geoms[0].geom_type] if geoms else 0,
        float(boxes[:, 0].min()), float(boxes[:, 1].min()),
        float(boxes[:, 2].max()), float(boxes[:, 3].max()),
        0.0, 0.0, 0.0, 0.0,
    )
    with open(path, "wb") as fh:
        fh.write(header)
        for r in records:
            fh.write(r)


def read_shapefile(path) -> list[Geometry]:
    buf = open(path, "rb").read()
    out: list[Geometry] = []
    off = 100
    while off < len(buf):
        _, content_words = struct.unpack_from(">ii", buf, off)
        off += 8
        body = buf[off : off + content_words * 2]
        off += content_words * 2
        (st,) = struct.unpack_from("<i", body, 0)
        if st == SHP_POINT:
            x, y = struct.unpack_from("<dd", body, 4)
            out.append(Geometry.point(x, y))
        elif st == SHP_MULTIPOINT:
            (n,) = struct.unpack_from("<i", body, 36)
            pts = np.frombuffer(body, "<f8", n * 2, 40).reshape(n, 2)
            out.append(Geometry(TYPE_MULTIPOINT, [pts[i : i + 1].copy() for i in range(n)]))
        elif st in (SHP_POLYLINE, SHP_POLYGON):
            nparts, npts = struct.unpack_from("<ii", body, 36)
            offsets = np.frombuffer(body, "<i4", nparts, 44)
            pts = np.frombuffer(body, "<f8", npts * 2, 44 + 4 * nparts).reshape(npts, 2)
            bounds = np.append(offsets, npts)
            parts = [pts[bounds[i] : bounds[i + 1]].copy() for i in range(nparts)]
            t = TYPE_MULTILINESTRING if st == SHP_POLYLINE else TYPE_MULTIPOLYGON
            out.append(Geometry(t, parts))
        else:
            raise ValueError(f"unsupported shape type {st}")
    return out

"""GeoParquet-like baseline (paper §5.1's strongest competitor).

Faithful to the paper's description of its Java GeoParquet implementation:
"five values per geometry object — one the WKB of the geometry, the other
four the minimum-bounding-rectangle for easy filtering". Column container
with raw (uncompressed) encodings + optional page-level gzip/zstd, page
min/max stats on the MBR columns for the same pruning semantics.

No FP-delta and no columnar coordinate exposure — that's precisely what the
paper's comparison isolates.
"""

from __future__ import annotations

import struct

import msgpack
import numpy as np

from repro.core.columnar import assemble
from repro.core.geometry import Geometry, bbox_intersects
from repro.core.pages import compress, decompress

from .wkb import geometry_to_wkb, wkb_to_geometry

MAGIC = b"GPQL1\x00"


class GeoParquetLikeWriter:
    def __init__(self, path, *, codec: str = "none", page_records: int = 8192):
        self.path = str(path)
        self.codec = codec
        self.page_records = page_records
        self._fh = open(self.path, "wb")
        self._fh.write(MAGIC)
        self._offset = len(MAGIC)
        self._pages: list[dict] = []

    def write_geometries(self, geoms: list[Geometry]) -> None:
        for i in range(0, len(geoms), self.page_records):
            chunk = geoms[i : i + self.page_records]
            wkbs = [geometry_to_wkb(g) for g in chunk]
            lengths = np.array([len(w) for w in wkbs], np.uint32)
            boxes = np.array([g.bbox() for g in chunk], np.float64)  # (n, 4)
            payload = (
                struct.pack("<I", len(chunk))
                + lengths.astype("<u4").tobytes()
                + boxes.astype("<f8").tobytes()
                + b"".join(wkbs)
            )
            comp = compress(payload, self.codec)
            self._fh.write(comp)
            self._pages.append({
                "offset": self._offset,
                "nbytes": len(comp),
                "count": len(chunk),
                "bbox": [float(boxes[:, 0].min()), float(boxes[:, 1].min()),
                         float(boxes[:, 2].max()), float(boxes[:, 3].max())],
            })
            self._offset += len(comp)

    def write_columns(self, cols) -> None:
        self.write_geometries(assemble(cols))

    def close(self) -> dict:
        footer = {"codec": self.codec, "pages": self._pages,
                  "n_records": sum(p["count"] for p in self._pages)}
        blob = msgpack.packb(footer, use_bin_type=True)
        self._fh.write(blob)
        self._fh.write(struct.pack("<I", len(blob)))
        self._fh.write(MAGIC)
        self._fh.close()
        return footer

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class GeoParquetLikeReader:
    def __init__(self, path):
        self.path = str(path)
        self._fh = open(self.path, "rb")
        self._fh.seek(-(len(MAGIC) + 4), 2)
        (flen,) = struct.unpack("<I", self._fh.read(4))
        self._fh.seek(-(len(MAGIC) + 4 + flen), 2)
        self.footer = msgpack.unpackb(self._fh.read(flen), raw=False)
        self.codec = self.footer["codec"]

    def read(self, bbox=None, refine: bool = True):
        """Returns (geometries, pages_read, pages_total)."""
        out: list[Geometry] = []
        pages_read = 0
        for page in self.footer["pages"]:
            if bbox is not None and not bbox_intersects(
                (page["bbox"][0], page["bbox"][1], page["bbox"][2], page["bbox"][3]), bbox
            ):
                continue
            pages_read += 1
            self._fh.seek(page["offset"])
            payload = decompress(self._fh.read(page["nbytes"]), self.codec)
            (n,) = struct.unpack_from("<I", payload, 0)
            lengths = np.frombuffer(payload, "<u4", n, 4)
            boxes = np.frombuffer(payload, "<f8", n * 4, 4 + 4 * n).reshape(n, 4)
            off = 4 + 4 * n + 32 * n
            for i in range(n):
                if bbox is not None and refine and not bbox_intersects(boxes[i], bbox):
                    off += int(lengths[i])
                    continue
                g, off = wkb_to_geometry(payload, off)
                out.append(g)
        return out, pages_read, len(self.footer["pages"])

    def close(self):
        self._fh.close()

"""GeoJSON (RFC 7946) baseline — the row-oriented text format of Table 2/3.

Uses orjson (fast C JSON) when available to be fair on write/read time,
falling back to the stdlib ``json`` module otherwise (same bytes modulo
float formatting; benchmark numbers then flatter Spatial Parquet, so treat
them as an upper bound). Compression is whole-file gzip exactly as the paper
applies it ("the entire dataset is written as one giant .geojson.gz file").
"""

from __future__ import annotations

import gzip

import numpy as np

try:
    import orjson
except ImportError:  # pragma: no cover - orjson is an optional speedup
    import json as _json

    class orjson:  # type: ignore[no-redef]
        """Minimal stdlib shim for the two orjson entry points we use."""

        @staticmethod
        def dumps(obj) -> bytes:
            return _json.dumps(obj, separators=(",", ":")).encode()

        @staticmethod
        def loads(blob):
            if isinstance(blob, (bytes, bytearray, memoryview)):
                blob = bytes(blob).decode()
            return _json.loads(blob)

from repro.core.columnar import assemble, multipolygon_polygons, shred
from repro.core.geometry import (
    TYPE_GEOMETRYCOLLECTION,
    TYPE_LINESTRING,
    TYPE_MULTILINESTRING,
    TYPE_MULTIPOINT,
    TYPE_MULTIPOLYGON,
    TYPE_POINT,
    TYPE_POLYGON,
    Geometry,
)

_NAMES = {
    TYPE_POINT: "Point",
    TYPE_LINESTRING: "LineString",
    TYPE_POLYGON: "Polygon",
    TYPE_MULTIPOINT: "MultiPoint",
    TYPE_MULTILINESTRING: "MultiLineString",
    TYPE_MULTIPOLYGON: "MultiPolygon",
}


def geometry_to_json_obj(g: Geometry) -> dict:
    t = g.geom_type
    if t == TYPE_POINT:
        return {"type": "Point", "coordinates": g.parts[0][0].tolist()}
    if t == TYPE_LINESTRING:
        return {"type": "LineString", "coordinates": g.parts[0].tolist()}
    if t == TYPE_POLYGON:
        return {"type": "Polygon", "coordinates": [r.tolist() for r in g.parts]}
    if t == TYPE_MULTIPOINT:
        return {"type": "MultiPoint", "coordinates": [p[0].tolist() for p in g.parts]}
    if t == TYPE_MULTILINESTRING:
        return {"type": "MultiLineString", "coordinates": [l.tolist() for l in g.parts]}
    if t == TYPE_MULTIPOLYGON:
        return {
            "type": "MultiPolygon",
            "coordinates": [[r.tolist() for r in rings] for rings in multipolygon_polygons(g)],
        }
    if t == TYPE_GEOMETRYCOLLECTION:
        return {"type": "GeometryCollection",
                "geometries": [geometry_to_json_obj(s) for s in g.sub_geometries]}
    return {"type": "GeometryCollection", "geometries": []}


def json_obj_to_geometry(o: dict) -> Geometry:
    t = o["type"]
    c = o.get("coordinates")
    if t == "Point":
        return Geometry.point(c[0], c[1])
    if t == "LineString":
        return Geometry.linestring(c)
    if t == "Polygon":
        return Geometry(TYPE_POLYGON, [np.asarray(r, np.float64) for r in c])
    if t == "MultiPoint":
        return Geometry(TYPE_MULTIPOINT, [np.asarray([p], np.float64) for p in c])
    if t == "MultiLineString":
        return Geometry(TYPE_MULTILINESTRING, [np.asarray(l, np.float64) for l in c])
    if t == "MultiPolygon":
        parts = [np.asarray(r, np.float64) for rings in c for r in rings]
        return Geometry(TYPE_MULTIPOLYGON, parts)
    if t == "GeometryCollection":
        return Geometry(TYPE_GEOMETRYCOLLECTION, [],
                        [json_obj_to_geometry(s) for s in o["geometries"]])
    raise ValueError(f"unknown GeoJSON type {t}")


def write_geojson(path, geoms: list[Geometry], *, gz: bool = False) -> None:
    features = [
        {"type": "Feature", "properties": {}, "geometry": geometry_to_json_obj(g)}
        for g in geoms
    ]
    blob = orjson.dumps({"type": "FeatureCollection", "features": features})
    if gz:
        blob = gzip.compress(blob, 6)
    with open(path, "wb") as fh:
        fh.write(blob)


def read_geojson(path, *, gz: bool = False) -> list[Geometry]:
    blob = open(path, "rb").read()
    if gz:
        blob = gzip.decompress(blob)
    obj = orjson.loads(blob)
    return [json_obj_to_geometry(f["geometry"]) for f in obj["features"]]


def write_geojson_columns(path, cols, **kw) -> None:
    write_geojson(path, assemble(cols), **kw)

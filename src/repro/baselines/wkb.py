"""Well-Known Binary (ISO 13249-3 / OGC SFA) encode/decode.

Used by the GeoParquet-like and Shapefile baselines and their benchmarks.
Little-endian, 2-D geometries, vectorized per-geometry bodies.
"""

from __future__ import annotations

import struct

import numpy as np

from repro.core.columnar import multipolygon_polygons
from repro.core.geometry import (
    TYPE_GEOMETRYCOLLECTION,
    TYPE_LINESTRING,
    TYPE_MULTILINESTRING,
    TYPE_MULTIPOINT,
    TYPE_MULTIPOLYGON,
    TYPE_POINT,
    TYPE_POLYGON,
    Geometry,
)

_LE = 1


def _coords_bytes(arr: np.ndarray) -> bytes:
    return np.ascontiguousarray(arr, dtype="<f8").tobytes()


def geometry_to_wkb(g: Geometry) -> bytes:
    t = g.geom_type
    if t == TYPE_POINT:
        return struct.pack("<bI", _LE, 1) + _coords_bytes(g.parts[0][0])
    if t == TYPE_LINESTRING:
        pts = g.parts[0]
        return struct.pack("<bII", _LE, 2, len(pts)) + _coords_bytes(pts)
    if t == TYPE_POLYGON:
        out = [struct.pack("<bII", _LE, 3, len(g.parts))]
        for ring in g.parts:
            out.append(struct.pack("<I", len(ring)) + _coords_bytes(ring))
        return b"".join(out)
    if t == TYPE_MULTIPOINT:
        out = [struct.pack("<bII", _LE, 4, len(g.parts))]
        for p in g.parts:
            out.append(struct.pack("<bI", _LE, 1) + _coords_bytes(p[0]))
        return b"".join(out)
    if t == TYPE_MULTILINESTRING:
        out = [struct.pack("<bII", _LE, 5, len(g.parts))]
        for line in g.parts:
            out.append(struct.pack("<bII", _LE, 2, len(line)) + _coords_bytes(line))
        return b"".join(out)
    if t == TYPE_MULTIPOLYGON:
        polys = multipolygon_polygons(g)
        out = [struct.pack("<bII", _LE, 6, len(polys))]
        for rings in polys:
            out.append(struct.pack("<bII", _LE, 3, len(rings)))
            for ring in rings:
                out.append(struct.pack("<I", len(ring)) + _coords_bytes(ring))
        return b"".join(out)
    if t == TYPE_GEOMETRYCOLLECTION:
        out = [struct.pack("<bII", _LE, 7, len(g.sub_geometries))]
        for sub in g.sub_geometries:
            out.append(geometry_to_wkb(sub))
        return b"".join(out)
    # empty geometry: encode as empty collection
    return struct.pack("<bII", _LE, 7, 0)


def wkb_to_geometry(buf: bytes, offset: int = 0) -> tuple[Geometry, int]:
    bo, t = struct.unpack_from("<bI", buf, offset)
    offset += 5

    def rd_pts(n, off):
        arr = np.frombuffer(buf, "<f8", n * 2, off).reshape(n, 2).copy()
        return arr, off + 16 * n

    if t == 1:
        pts, offset = rd_pts(1, offset)
        return Geometry(TYPE_POINT, [pts]), offset
    if t == 2:
        (n,) = struct.unpack_from("<I", buf, offset)
        pts, offset = rd_pts(n, offset + 4)
        return Geometry(TYPE_LINESTRING, [pts]), offset
    if t == 3:
        (nr,) = struct.unpack_from("<I", buf, offset)
        offset += 4
        rings = []
        for _ in range(nr):
            (n,) = struct.unpack_from("<I", buf, offset)
            ring, offset = rd_pts(n, offset + 4)
            rings.append(ring)
        return Geometry(TYPE_POLYGON, rings), offset
    if t in (4, 5, 6):
        (k,) = struct.unpack_from("<I", buf, offset)
        offset += 4
        parts: list[np.ndarray] = []
        for _ in range(k):
            sub, offset = wkb_to_geometry(buf, offset)
            parts.extend(sub.parts)
        return Geometry({4: TYPE_MULTIPOINT, 5: TYPE_MULTILINESTRING, 6: TYPE_MULTIPOLYGON}[t], parts), offset
    if t == 7:
        (k,) = struct.unpack_from("<I", buf, offset)
        offset += 4
        subs = []
        for _ in range(k):
            sub, offset = wkb_to_geometry(buf, offset)
            subs.append(sub)
        if not subs:
            return Geometry.empty(), offset
        return Geometry(TYPE_GEOMETRYCOLLECTION, [], subs), offset
    raise ValueError(f"unsupported WKB type {t}")

"""The light-weight spatial index (paper §4).

The index *is* the per-page [min,max] column statistics: together the x and y
ranges of page ``i`` form its bounding box. A query rectangle
``(xmin, ymin, xmax, ymax)`` is split into the two 1-D ranges and pages whose
boxes miss either range are skipped without being read (or decompressed).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class PageIndexEntry:
    row_group: int
    page: int
    bbox: tuple[float, float, float, float]  # xmin, ymin, xmax, ymax
    rec_start: int
    rec_count: int
    nbytes: int  # stored bytes of x+y pages (for pruning accounting)


class SpatialIndex:
    """In-memory view of the footer statistics with vectorized pruning."""

    def __init__(self, footer: dict):
        entries: list[PageIndexEntry] = []
        for rg_i, rg in enumerate(footer["row_groups"]):
            xp, yp = rg["x_pages"], rg["y_pages"]
            assert len(xp) == len(yp), "x/y pages must be aligned"
            for p_i, (px, py) in enumerate(zip(xp, yp)):
                entries.append(
                    PageIndexEntry(
                        row_group=rg_i,
                        page=p_i,
                        bbox=(px["vmin"], py["vmin"], px["vmax"], py["vmax"]),
                        rec_start=px["rec_start"],
                        rec_count=px["rec_count"],
                        nbytes=px["nbytes"] + py["nbytes"],
                    )
                )
        self.entries = entries
        if entries:
            b = np.array([e.bbox for e in entries], dtype=np.float64)
            self._xmin, self._ymin, self._xmax, self._ymax = b.T
        else:
            self._xmin = self._ymin = self._xmax = self._ymax = np.zeros(0)

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def total_bytes(self) -> int:
        return int(sum(e.nbytes for e in self.entries))

    def query(self, bbox: tuple[float, float, float, float] | None) -> np.ndarray:
        """Indices of pages intersecting ``bbox`` (all pages if None)."""
        if bbox is None:
            return np.arange(len(self.entries))
        qx0, qy0, qx1, qy1 = bbox
        hit = (
            (self._xmin <= qx1)
            & (self._xmax >= qx0)
            & (self._ymin <= qy1)
            & (self._ymax >= qy0)
        )
        return np.flatnonzero(hit)

    def selectivity(self, bbox) -> float:
        """Fraction of pages the query must read (1.0 = no pruning)."""
        if not len(self.entries):
            return 0.0
        return len(self.query(bbox)) / len(self.entries)

"""The light-weight spatial index (paper §4).

The index *is* the per-page [min,max] column statistics: together the x and y
ranges of page ``i`` form its bounding box. A query rectangle
``(xmin, ymin, xmax, ymax)`` is split into the two 1-D ranges and pages whose
boxes miss either range are skipped without being read (or decompressed).

Layout is structure-of-arrays: one packed numpy array per field
(``row_group``, ``page``, ``rec_start``, ``rec_count``, ``count``,
``nbytes``, the four bbox sides, and the x/y blob offsets/sizes), built once
from the footer. Queries are pure vector ops, and :meth:`page_runs` hands the
reader maximal runs of consecutive hit pages per row group — the unit the
coalesced-I/O read path turns into single ``readinto`` calls — with no
Python-side dict/sort grouping. The legacy per-page :class:`PageIndexEntry`
view is still available through the lazy :attr:`entries` property.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .filters import ColumnZones, Predicate, canonical_bbox


@dataclass
class PageIndexEntry:
    row_group: int
    page: int
    bbox: tuple[float, float, float, float]  # xmin, ymin, xmax, ymax
    rec_start: int
    rec_count: int
    nbytes: int  # stored bytes of x+y pages (for pruning accounting)


class SpatialIndex:
    """In-memory SoA view of the footer statistics with vectorized pruning."""

    def __init__(self, footer: dict):
        rgs = footer["row_groups"]
        n = sum(len(rg["x_pages"]) for rg in rgs)
        self.row_group = np.empty(n, dtype=np.int32)
        self.page = np.empty(n, dtype=np.int32)
        self.rec_start = np.empty(n, dtype=np.int64)
        self.rec_count = np.empty(n, dtype=np.int64)
        self.count = np.empty(n, dtype=np.int64)      # values per page
        self.nbytes = np.empty(n, dtype=np.int64)     # stored x+y bytes
        self.x_offset = np.empty(n, dtype=np.int64)
        self.x_nbytes = np.empty(n, dtype=np.int64)
        self.y_offset = np.empty(n, dtype=np.int64)
        self.y_nbytes = np.empty(n, dtype=np.int64)
        self._xmin = np.empty(n, dtype=np.float64)
        self._ymin = np.empty(n, dtype=np.float64)
        self._xmax = np.empty(n, dtype=np.float64)
        self._ymax = np.empty(n, dtype=np.float64)

        i = 0
        for rg_i, rg in enumerate(rgs):
            xp, yp = rg["x_pages"], rg["y_pages"]
            assert len(xp) == len(yp), "x/y pages must be aligned"
            for p_i, (px, py) in enumerate(zip(xp, yp)):
                self.row_group[i] = rg_i
                self.page[i] = p_i
                self.rec_start[i] = px["rec_start"]
                self.rec_count[i] = px["rec_count"]
                self.count[i] = px["count"]
                self.nbytes[i] = px["nbytes"] + py["nbytes"]
                self.x_offset[i] = px["offset"]
                self.x_nbytes[i] = px["nbytes"]
                self.y_offset[i] = py["offset"]
                self.y_nbytes[i] = py["nbytes"]
                self._xmin[i] = px["vmin"]
                self._xmax[i] = px["vmax"]
                self._ymin[i] = py["vmin"]
                self._ymax[i] = py["vmax"]
                i += 1
        self._entries: list[PageIndexEntry] | None = None
        self._footer_rgs = rgs
        self._zones: dict[str, ColumnZones] | None = None

    def __len__(self) -> int:
        return len(self.row_group)

    @property
    def entries(self) -> list[PageIndexEntry]:
        """Lazy AoS view (kept for diagnostics/tests; hot paths use arrays)."""
        if self._entries is None:
            self._entries = [
                PageIndexEntry(
                    row_group=int(self.row_group[i]),
                    page=int(self.page[i]),
                    bbox=(
                        float(self._xmin[i]), float(self._ymin[i]),
                        float(self._xmax[i]), float(self._ymax[i]),
                    ),
                    rec_start=int(self.rec_start[i]),
                    rec_count=int(self.rec_count[i]),
                    nbytes=int(self.nbytes[i]),
                )
                for i in range(len(self))
            ]
        return self._entries

    @property
    def total_bytes(self) -> int:
        return int(self.nbytes.sum())

    def zone_lookup(self, column: str) -> ColumnZones | None:
        """Per-page statistics of one extra column (None when unknown).

        Built lazily from the footer's extra-column page metadata: ``vmin``/
        ``vmax`` are the page stats (NaN for pages written before NaN-safe
        stats — treated as unknown, never pruned), ``nnan`` is the per-page
        NaN count (``-1`` for files without it), ``count`` the record count.
        """
        if self._zones is None:
            zones: dict[str, ColumnZones] = {}
            cols = self._footer_rgs[0].get("extra", {}) if self._footer_rgs else {}
            n = len(self)
            for k in cols:
                vmin = np.empty(n, np.float64)
                vmax = np.empty(n, np.float64)
                nnan = np.empty(n, np.int64)
                i = 0
                for rg in self._footer_rgs:
                    for p in rg["extra"][k]:
                        vmin[i] = p["vmin"]
                        vmax[i] = p["vmax"]
                        nnan[i] = p.get("nnan", -1)
                        i += 1
                zones[k] = ColumnZones(vmin, vmax, nnan, self.rec_count.copy())
            self._zones = zones
        return self._zones.get(column)

    def query(
        self,
        bbox: tuple[float, float, float, float] | None,
        filter: Predicate | None = None,
    ) -> np.ndarray:
        """Indices of pages that may satisfy ``bbox`` ∧ ``filter``.

        ``bbox=None`` means no spatial constraint; an empty bbox under
        :func:`~repro.core.filters.canonical_bbox` (NaN bound or inverted
        extent) hits nothing. ``filter`` prunes via the per-page zone
        statistics of the extra columns it references (conservative: a page
        is only dropped when its stats prove no record can match).
        """
        if bbox is None:
            hit = np.ones(len(self), bool)
        else:
            b = canonical_bbox(bbox)
            if b is None:
                return np.zeros(0, dtype=np.intp)
            qx0, qy0, qx1, qy1 = b
            hit = (
                (self._xmin <= qx1)
                & (self._xmax >= qx0)
                & (self._ymin <= qy1)
                & (self._ymax >= qy0)
            )
        if filter is not None:
            hit = hit & filter.zone_mask(self.zone_lookup, len(self))
        return np.flatnonzero(hit)

    def page_runs(self, bbox, hit: np.ndarray | None = None) -> list[tuple[int, int, int]]:
        """Maximal runs of consecutive hit pages: ``(row_group, p0, p1)``.

        Pages ``p0 .. p1-1`` of ``row_group`` all intersect ``bbox``. Runs are
        emitted in file order (entries are built sorted by row group then
        page), so the reader can turn each one into a single coalesced read.
        Pass ``hit`` (a ``query(bbox)`` result — possibly predicate-pruned)
        to avoid re-running the query.
        """
        if hit is None:
            hit = self.query(bbox)
        if len(hit) == 0:
            return []
        rgh = self.row_group[hit]
        ph = self.page[hit]
        brk = np.flatnonzero((np.diff(ph) != 1) | (np.diff(rgh) != 0)) + 1
        starts = np.concatenate([[0], brk])
        ends = np.append(brk, len(hit))
        return [
            (int(rgh[s]), int(ph[s]), int(ph[e - 1]) + 1)
            for s, e in zip(starts, ends)
        ]

    def selectivity(self, bbox) -> float:
        """Fraction of pages the query must read (1.0 = no pruning).

        An empty file reports 1.0 — "nothing was pruned" — so downstream
        pruning-ratio accounting never mistakes an empty index for a
        perfectly-pruned one.
        """
        if not len(self):
            return 1.0
        return len(self.query(bbox)) / len(self)

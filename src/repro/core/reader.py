"""Spatial Parquet file reader: projection, range-filter pushdown, pruning.

The reader exposes two access paths:

* ``read(...)`` — the object API returning :class:`Geometry` lists (paper's
  reported read path), and
* ``read_columnar(...)`` — direct access to the decoded coordinate arrays.
  The paper (§5.1) names exactly this as the fix for its read-speed gap
  ("providing a lower-level access to the coordinate arrays from Parquet
  rather than reading one value at a time"); it is our primary fast path and
  what the training data pipeline consumes.

Coalesced-I/O architecture (the batched hot path)
-------------------------------------------------

``read_columnar`` never reads one page at a time. Per row group it collects
the ``(offset, nbytes)`` of every blob it needs — the four level streams,
each run of consecutive hit x/y pages (runs come straight from
``SpatialIndex.page_runs``, no Python-side grouping), and the matching extra
column pages — merges byte ranges whose gap is at most ``coalesce_max_gap``,
and issues exactly one ``seek`` + ``readinto`` per merged range into a
preallocated buffer. Individual blobs are then zero-copy ``memoryview``
slices of those buffers. For a full-file scan of one row group this is a
single read syscall for the whole group.

Row groups are **double-buffered** (``prefetch_row_groups``, default 1): a
single reader thread issues row group N+1's coalesced reads while the main
thread decodes row group N from already-filled buffers, so intra-file I/O
overlaps decode exactly like the dataset scanner overlaps shards. Results
are byte-identical to the sequential order (``prefetch_row_groups=0``
disables the overlap; ``coalesce=False`` implies it).

Decoding is allocation-lean to match: the total hit value count is known from
the index, so the x/y (and extra) destination arrays are preallocated once
and every page decodes straight into its slice via the ``out=`` contract of
``decode_page``/``fp_delta_decode`` — no per-page list-append or trailing
``np.concatenate`` over coordinates. Pass ``coalesce=False`` to force the
legacy one-read-per-blob behaviour (same decode path, used by the
equivalence tests).

Accelerated decode (``device="jax"``)
-------------------------------------

``read_columnar(device="jax")`` moves the FP-delta back half — fixed-width
gather, escape injection, segmented cumsum, un-zigzag, float bitcast — onto
the accelerator: the host still parses headers and resolves escapes
(``fp_delta_plan``), then every surviving coordinate page of a row group is
concatenated into one Pallas page-stream launch
(``repro.kernels.fp_delta.decode_pages``). Results are **bit-identical** to
the host path (asserted by tests/test_device_decode.py); raw-encoded pages,
level streams, and extra columns stay on the host. Off-TPU the kernels run
in interpret mode, so the full path is exercised in CPU CI.

Fused device refinement (``device="jax", refine=True``)
-------------------------------------------------------

With both flags set, refinement runs *where the data decodes*: the same
launch chain appends a segmented per-record min/max (``repro.kernels.minmax``
over IEEE-754 order keys — uint32 limb math, so float64 refines without
``jax_enable_x64``) and the bbox survivor test
(``repro.kernels.fp_delta.decode_refine_stream``). Pruned records **never
materialize on the host**: only the record mask and the surviving
coordinates cross back (raw-encoded pages join the launch through a
synthetic raw-mode plan, see ``pages.page_stream_plan``). The surviving
record set is bit-identical to the host refine. ``keep_on_device=True``
additionally leaves the surviving coordinates on the accelerator, returning
:class:`~repro.core.columnar.DeviceCoords` columns for zero-copy handoff
into downstream device consumers (``repro.data.pipeline``).

Fault-tolerant storage boundary (``repro.io``)
----------------------------------------------

The reader no longer touches a file handle directly: all I/O goes through a
:class:`~repro.io.source.ByteRangeSource`. The default
:class:`~repro.io.source.LocalFileSource` preserves the historical
``seek``+``readinto``-per-merged-run behaviour byte-for-byte; passing
``source=RemoteRangeSource(...)`` runs the identical read path against an
object-store-style backend with retries, deadlines and a read-through block
cache. Format-v2 files carry per-blob checksums which are verified on every
stored blob *before* it is decompressed, planned or launched (host and
device paths alike); a mismatch triggers one cache-bypassing re-fetch (which
heals a poisoned block cache) and raises an attributed
:class:`~repro.io.checksum.ChecksumError` only if the bytes are still wrong.
All recoveries are counted in :class:`ReadStats` (``retries``, ``timeouts``,
``checksum_failures``, ``cache_hits``/``cache_misses``).
"""

from __future__ import annotations

import struct
import time
from bisect import bisect_right
from collections import deque
from dataclasses import dataclass, field
from dataclasses import replace as dc_replace

import msgpack
import numpy as np

from repro import obs
from repro.io.checksum import ChecksumError, checksum_fn, crc32c
from repro.io.source import LocalFileSource

from .columnar import DeviceCoords, GeometryColumns, assemble
from .filters import Predicate, canonical_bbox, validate_predicate
from .fp_delta import fp_delta_execute
from .geometry import Geometry
from .index import SpatialIndex
from .pages import (
    ENC_FP_DELTA,
    PageMeta,
    decode_page,
    decompress,
    page_plan,
    page_stream_plan,
)
from .rle import decode_levels, rle_decode
from .writer import MAGIC, MAGIC_V2, permute_records

_LEVEL_NAMES = ("type", "type_rep", "rep", "defn")


def footer_data_bytes(footer: dict) -> int:
    """Total stored bytes of every blob (levels, coord pages, extras)."""
    total = 0
    for rg in footer["row_groups"]:
        total += sum(rg[name]["nbytes"] for name in _LEVEL_NAMES)
        total += sum(p["nbytes"] for p in rg["x_pages"])
        total += sum(p["nbytes"] for p in rg["y_pages"])
        for ep in rg.get("extra", {}).values():
            total += sum(p["nbytes"] for p in ep)
    return total


def footer_page_count(footer: dict) -> int:
    """Number of x/y page pairs (the unit of the per-page spatial index)."""
    return sum(len(rg["x_pages"]) for rg in footer["row_groups"])


@dataclass
class ReadStats:
    """Pruning accounting for the light-weight index (paper Figure 11).

    ``bytes_read``/``bytes_total`` count every stored blob (level streams,
    coordinate pages, extra-column pages) — not just x/y pages — so pruning
    ratios reflect what actually hits the disk.

    Stats are *mergeable*: ``a + b`` (or ``a.merge(b)``, or ``sum(stats)``)
    field-wise sums two accounts, so a multi-shard dataset scan reports one
    aggregate. ``shards_total``/``shards_read`` stay 0 for single-file reads
    and are filled in by the dataset scanner, where pruned shards contribute
    their page/byte totals but nothing to the ``*_read`` side.

    Recovery accounting (the fault-tolerant I/O layer): ``retries`` counts
    re-issued range requests (backoff retries inside a
    :class:`~repro.io.remote.RemoteRangeSource` plus checksum-triggered blob
    re-fetches), ``timeouts`` the requests dropped for missing their
    deadline, ``checksum_failures`` every blob whose stored CRC mismatched
    (recovered or not), ``cache_hits``/``cache_misses`` the remote block
    cache, ``shard_retries`` scanner-level shard re-reads, and ``failures``
    the attributed record of shards a ``skip``-policy scan dropped (list of
    :class:`~repro.dataset.errors.ShardFailure`).
    """

    pages_total: int = 0
    pages_read: int = 0
    bytes_total: int = 0
    bytes_read: int = 0
    records_scanned: int = 0
    records_returned: int = 0
    shards_total: int = 0
    shards_read: int = 0
    retries: int = 0
    timeouts: int = 0
    checksum_failures: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    shard_retries: int = 0
    failures: list = field(default_factory=list)

    @property
    def pages_skipped(self) -> int:
        return self.pages_total - self.pages_read

    @property
    def shards_skipped(self) -> int:
        return self.shards_total - self.shards_read

    @property
    def shards_failed(self) -> int:
        return len(self.failures)

    def merge(self, other: "ReadStats") -> "ReadStats":
        """Field-wise sum of two accounts (one aggregate per dataset scan)."""
        return ReadStats(
            pages_total=self.pages_total + other.pages_total,
            pages_read=self.pages_read + other.pages_read,
            bytes_total=self.bytes_total + other.bytes_total,
            bytes_read=self.bytes_read + other.bytes_read,
            records_scanned=self.records_scanned + other.records_scanned,
            records_returned=self.records_returned + other.records_returned,
            shards_total=self.shards_total + other.shards_total,
            shards_read=self.shards_read + other.shards_read,
            retries=self.retries + other.retries,
            timeouts=self.timeouts + other.timeouts,
            checksum_failures=self.checksum_failures + other.checksum_failures,
            cache_hits=self.cache_hits + other.cache_hits,
            cache_misses=self.cache_misses + other.cache_misses,
            shard_retries=self.shard_retries + other.shard_retries,
            failures=self.failures + other.failures,
        )

    def __add__(self, other):
        if not isinstance(other, ReadStats):
            return NotImplemented
        return self.merge(other)

    def __radd__(self, other):
        if other == 0:  # support sum(list_of_stats)
            return self
        return NotImplemented


class _CoalescedRanges:
    """Merge (offset, nbytes) requests and serve blobs from batched reads.

    One ``readinto_at`` per merged run — for a :class:`LocalFileSource` that
    is the historical single ``seek``+``readinto`` syscall pair, verbatim.
    """

    def __init__(self, source, ranges: list[tuple[int, int]], max_gap: int):
        spans = sorted(set(r for r in ranges if r[1] > 0))
        merged: list[list[int]] = []
        for off, nb in spans:
            if merged and off <= merged[-1][1] + max_gap:
                merged[-1][1] = max(merged[-1][1], off + nb)
            else:
                merged.append([off, off + nb])
        self._source = source
        self._starts = [m[0] for m in merged]
        self._bufs: list[memoryview] = []
        self.n_reads = 0
        for start, end in merged:
            buf = bytearray(end - start)
            got = source.readinto_at(start, buf)
            if got != len(buf):
                raise IOError("short read (truncated Spatial Parquet file)")
            self.n_reads += 1
            self._bufs.append(memoryview(buf))

    def blob(self, offset: int, nbytes: int) -> memoryview:
        i = bisect_right(self._starts, offset) - 1
        rel = offset - self._starts[i]
        return self._bufs[i][rel : rel + nbytes]

    def refetch(self, offset: int, nbytes: int) -> bytes:
        """Re-read one blob straight from storage, bypassing (and healing)
        any cache layer — the checksum-mismatch recovery path."""
        return self._source.read_at(offset, nbytes, refresh=True)


class _DirectRanges:
    """One read per blob (legacy path; kept for equivalence testing)."""

    def __init__(self, source):
        self._source = source

    def blob(self, offset: int, nbytes: int) -> bytes:
        return self._source.read_at(offset, nbytes)

    def refetch(self, offset: int, nbytes: int) -> bytes:
        return self._source.read_at(offset, nbytes, refresh=True)


@dataclass
class _RowGroupLevels:
    """Decoded level streams of one row group + record start indices.

    Owns the record-range slicing shared by the host and fused read loops,
    so the two paths can never drift apart on level semantics (their
    bit-identity is part of the fused-refine contract).
    """

    types: np.ndarray
    type_rep: np.ndarray
    rep: np.ndarray
    defn: np.ndarray
    slot_starts: np.ndarray
    type_starts: np.ndarray

    @property
    def n_rec(self) -> int:
        return len(self.slot_starts)

    def append_run(self, parts, r0: int, r1: int) -> None:
        """Slice records ``[r0, r1)`` into the four level part lists; the
        first slot of a run always starts a record, so the rep/type_rep
        heads are (re)pinned to 0."""
        types_parts, type_rep_parts, rep_parts, defn_parts = parts
        n_rec = self.n_rec
        s0 = self.slot_starts[r0]
        s1 = self.slot_starts[r1] if r1 < n_rec else len(self.rep)
        t0 = self.type_starts[r0]
        t1 = self.type_starts[r1] if r1 < n_rec else len(self.types)
        types_parts.append(self.types[t0:t1])
        tr = self.type_rep[t0:t1].copy()
        rp = self.rep[s0:s1].copy()
        tr[0] = 0
        rp[0] = 0
        type_rep_parts.append(tr)
        rep_parts.append(rp)
        defn_parts.append(self.defn[s0:s1])

    def record_value_counts(self) -> np.ndarray:
        """Values per record across the whole row group (pages are
        record-aligned, so hit runs slice out of this contiguously)."""
        d64 = self.defn.astype(np.int64)
        value_idx = np.cumsum(d64) - d64
        total = int(value_idx[-1] + d64[-1]) if len(d64) else 0
        return np.diff(np.append(value_idx[self.slot_starts], total))


@dataclass
class RowGroupChunk:
    """One launch-chunk of a fully decoded row group (``device="jax"``).

    ``kind == "dev"`` carries an *unlaunched* packed page stream plus its
    refine aux (record segmentation) — the serve tier fuses multi-query
    refinement into the launch. ``kind == "host"`` carries decoded x/y
    values for pages the device path cannot pack (host-fallback codecs).
    ``rec_lo``/``rec_hi`` are the rg-local record range the chunk covers.
    """

    kind: str
    rec_lo: int
    rec_hi: int
    stream: object = None
    aux: object = None
    x: np.ndarray | None = None
    y: np.ndarray | None = None


@dataclass
class RowGroupData:
    """Every page of one row group, decoded once (see ``read_row_group``).

    ``extras`` holds the full extra-column arrays (length ``n_records``);
    ``nbytes`` is the stored bytes fetched to build this (levels + extras +
    x/y pages) — the cache-attribution unit. Exactly one of ``x``/``y``
    (``device="cpu"``) or ``chunks`` (``device="jax"``) is populated.
    """

    rg_i: int
    n_records: int
    rec_vcounts: np.ndarray
    levels: _RowGroupLevels
    extras: dict
    nbytes: int
    x: np.ndarray | None = None
    y: np.ndarray | None = None
    chunks: list[RowGroupChunk] | None = None


class SpatialParquetReader:
    """Reader over one ``.spqf`` object.

    ``path`` opens a :class:`~repro.io.source.LocalFileSource`; pass
    ``source=`` instead (e.g. a :class:`~repro.io.remote.RemoteRangeSource`)
    to read the same bytes from elsewhere — the reader owns whichever source
    it ends up with and closes it. ``verify_checksums=False`` skips the v2
    integrity checks (v1 files carry none and are never verified).
    """

    def __init__(self, path=None, *, source=None, coalesce_max_gap: int = 1 << 16,
                 prefetch_row_groups: int = 1, verify_checksums: bool = True):
        if source is None:
            if path is None:
                raise ValueError("SpatialParquetReader needs a path or a source")
            source = LocalFileSource(path)
        self.path = str(path) if path is not None else getattr(
            source, "path", "<source>")
        self.coalesce_max_gap = int(coalesce_max_gap)
        self.prefetch_row_groups = max(0, int(prefetch_row_groups))
        self._source = source
        self._closed = False
        try:
            self.footer = self._read_footer()
            self.coord_dtype = np.dtype(self.footer["coord_dtype"])
            self.codec = self.footer["codec"]
            self.n_records = self.footer["n_records"]
            self.extra_schema = self.footer.get("extra_schema", {})
            self.checksum_algo = self.footer.get("checksum_algo")
            self._verify = bool(verify_checksums) and self.checksum_algo is not None
            self._blob_crc = checksum_fn(self.checksum_algo) if self._verify else None
            self.index = SpatialIndex(self.footer)
            self._data_bytes = self._total_data_bytes()
        except Exception:
            # never leak the handle/source when construction fails mid-way
            self.close()
            raise

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self):
        if not self._closed:
            self._closed = True
            self._source.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------- internals
    def _read_footer(self) -> dict:
        src = self._source
        size = src.size()
        if size < 2 * len(MAGIC) + 4:
            raise ValueError("truncated Spatial Parquet file (too short)")
        lead = src.read_at(0, len(MAGIC))
        if lead not in (MAGIC, MAGIC_V2):
            raise ValueError("not a Spatial Parquet file (bad leading magic)")
        tail = src.read_at(size - len(MAGIC) - 4, len(MAGIC) + 4)
        (flen,) = struct.unpack("<I", tail[:4])
        trail = tail[4:]
        if trail not in (MAGIC, MAGIC_V2):
            raise ValueError("truncated Spatial Parquet file (bad trailing magic)")
        if flen > size - 2 * len(MAGIC) - 4:
            raise ValueError("truncated Spatial Parquet file (bad footer length)")
        stored = src.read_at(size - len(MAGIC) - 4 - flen, flen)
        if trail == MAGIC_V2:
            # v2 trailer: [footer][crc32c(footer): u32]; verify before unpack
            # so a corrupt footer never feeds garbage to msgpack / the index
            blob, crc_bytes = stored[:-4], stored[-4:]
            (want,) = struct.unpack("<I", crc_bytes)
            got = crc32c(blob)
            if got != want:
                raise ChecksumError("file footer", size - len(MAGIC) - 4 - flen,
                                    len(blob), want, got)
        else:
            blob = stored
        return msgpack.unpackb(blob, raw=False, strict_map_key=False)

    def _checked_blob(self, src, offset: int, nbytes: int,
                      crc: int | None, stats: ReadStats, what: str):
        """Fetch one stored blob, verifying its v2 checksum when present.

        A mismatch triggers exactly one cache-bypassing re-fetch (healing a
        poisoned remote block cache); if the fresh bytes still mismatch, the
        blob is genuinely corrupt and an attributed ChecksumError raises
        *before* any decompress/decode/launch consumes it.
        """
        blob = src.blob(offset, nbytes)
        if not self._verify or crc is None:
            return blob
        got = self._blob_crc(blob)
        if got == crc:
            return blob
        stats.checksum_failures += 1
        obs.instant("checksum.refetch", cat="io", what=what, offset=offset)
        fresh = src.refetch(offset, nbytes)
        stats.retries += 1
        got = self._blob_crc(fresh)
        if got == crc and len(fresh) == nbytes:
            return fresh
        raise ChecksumError(what, offset, nbytes, crc, got)

    def _total_data_bytes(self) -> int:
        return footer_data_bytes(self.footer)

    def _rg_ranges(self, rg, runs, base, want_geom, extra_pages):
        """Every byte range one row group's decode needs (metadata only)."""
        idx = self.index
        ranges: list[tuple[int, int]] = []
        if want_geom:
            ranges += [
                (rg[name]["offset"], rg[name]["nbytes"]) for name in _LEVEL_NAMES
            ]
        for p0, p1 in runs:
            if want_geom:
                j0, j1 = base + p0, base + p1 - 1
                ranges.append((
                    int(idx.x_offset[j0]),
                    int(idx.x_offset[j1] + idx.x_nbytes[j1] - idx.x_offset[j0]),
                ))
                ranges.append((
                    int(idx.y_offset[j0]),
                    int(idx.y_offset[j1] + idx.y_nbytes[j1] - idx.y_offset[j0]),
                ))
            for ep in extra_pages.values():
                first, last = ep[p0], ep[p1 - 1]
                ranges.append((
                    first["offset"],
                    last["offset"] + last["nbytes"] - first["offset"],
                ))
        return ranges

    def _level_blob(self, src, rg, name: str, stats: ReadStats):
        meta = rg[name]
        return self._checked_blob(src, meta["offset"], meta["nbytes"],
                                  meta.get("crc"), stats,
                                  f"{name!r} level stream")

    def _decode_rg_levels(self, src, rg, stats: ReadStats) -> _RowGroupLevels:
        """Decode one row group's four level streams from memory slices."""
        with obs.span("rg.levels", cat="decode"):
            return self._decode_rg_levels_inner(src, rg, stats)

    def _decode_rg_levels_inner(self, src, rg, stats: ReadStats) -> _RowGroupLevels:
        types = rle_decode(
            decompress(self._level_blob(src, rg, "type", stats), self.codec))
        type_rep = decode_levels(
            decompress(self._level_blob(src, rg, "type_rep", stats), self.codec))
        rep = decode_levels(
            decompress(self._level_blob(src, rg, "rep", stats), self.codec))
        defn = decode_levels(
            decompress(self._level_blob(src, rg, "defn", stats), self.codec))
        stats.bytes_read += sum(rg[name]["nbytes"] for name in _LEVEL_NAMES)
        return _RowGroupLevels(types, type_rep, rep, defn,
                               np.flatnonzero(rep == 0),
                               np.flatnonzero(type_rep == 0))

    def _decode_run_extras(self, src, extra_pages, extra_all, we: int,
                           p0: int, p1: int, stats: ReadStats) -> None:
        """Decode one run's extra-column pages into the preallocated columns
        at record cursor ``we``."""
        for k, ep in extra_pages.items():
            wk = we
            for p in range(p0, p1):
                meta = PageMeta.from_dict(ep[p])
                blob = self._checked_blob(
                    src, meta.offset, meta.nbytes, meta.crc, stats,
                    f"extra column {k!r} page {p}")
                decode_page(
                    blob, meta,
                    np.dtype(self.extra_schema[k]), self.codec,
                    out=extra_all[k][wk : wk + meta.count],
                )
                stats.bytes_read += meta.nbytes
                wk += meta.count

    def _iter_sources(self, items, coalesce: bool):
        """Yield ``(item, src)`` per hit row group, double-buffering reads.

        With coalescing on and ``prefetch_row_groups >= 1``, a single worker
        thread runs row group N+1's ``readinto`` calls while the caller
        decodes row group N (file I/O releases the GIL; the main thread only
        touches prefilled buffers, never the source). Yields in file order,
        so results are byte-identical to the sequential path.

        The read loops close this generator in a ``finally`` (triggering
        ``GeneratorExit`` here), so the pool's ``with`` block always joins
        the prefetch thread — including when a decode raises mid-row-group.
        """
        if not coalesce:
            for it in items:
                yield it, _DirectRanges(self._source)
            return

        def fetch(it):
            # the "fetch" stage span: every readinto of one row group's
            # coalesced ranges (runs on the prefetch thread when enabled —
            # obs.submit hands the span context across)
            with obs.span("rg.fetch", cat="io", rg=it[0]):
                return _CoalescedRanges(self._source, it[-1],
                                        self.coalesce_max_gap)

        lookahead = self.prefetch_row_groups
        if lookahead == 0 or len(items) <= 1:
            for it in items:
                yield it, fetch(it)
            return
        from concurrent.futures import ThreadPoolExecutor

        with ThreadPoolExecutor(max_workers=1) as pool:
            pending: deque = deque()
            nxt = 0
            while nxt < len(items) and len(pending) < lookahead:
                pending.append(obs.submit(pool, fetch, items[nxt]))
                nxt += 1
            for it in items:
                src = pending.popleft().result()
                if nxt < len(items):
                    pending.append(obs.submit(pool, fetch, items[nxt]))
                    nxt += 1
                yield it, src

    # -------------------------------------------------------------- read API
    def read_columnar(
        self,
        bbox=None,
        columns: tuple[str, ...] | None = None,
        refine: bool = False,
        coalesce: bool = True,
        device: str = "cpu",
        *,
        keep_on_device: bool = False,
        filter: Predicate | None = None,
    ) -> tuple[GeometryColumns | None, dict[str, np.ndarray], ReadStats]:
        """Decode records whose *page* bbox intersects ``bbox``.

        Returns (geometry columns, extra columns, stats). ``refine=True``
        additionally drops records whose exact bbox misses the query.
        ``columns`` restricts which extra columns decode ("geometry" is
        implied unless columns excludes it explicitly). ``filter`` is a
        :mod:`repro.core.filters` predicate over extra columns: pages whose
        zone statistics prove no match are skipped, and the surviving
        records are filtered *exactly* (the result is always identical to
        reading without zone pruning and masking afterwards — the record
        mask is ``bbox ∧ attrs`` when combined with ``refine``). Columns a
        filter needs are decoded as required but only returned when
        requested. ``coalesce=False``
        disables batched range I/O (one read per blob; identical results).
        ``device="jax"`` decodes surviving FP-delta coordinate pages on the
        accelerator (one Pallas page-stream launch per row group,
        bit-identical results); combined with ``refine=True`` the per-record
        bbox test also runs on-device and only surviving records transfer
        back. ``keep_on_device=True`` (requires ``device="jax"``) returns
        :class:`DeviceCoords` coordinate columns that never leave the
        accelerator; it is a no-op when ``columns`` excludes geometry (extra
        columns always decode on the host). ``"cpu"`` is the default and the
        oracle.

        With telemetry on (``repro.obs.enable()``) the call is wrapped in a
        ``scan.file`` span with per-row-group fetch/plan/decode/launch/
        transfer child spans, and on return folds its ``ReadStats`` plus the
        derived gauges (``scan.latency_s``, ``scan.host_cpu_s_per_gb``,
        bytes-pruned-per-level) into the metrics registry. Disabled, the
        path is allocation- and result-identical to the uninstrumented one.
        """
        if not obs.enabled():
            return self._read_columnar_impl(
                bbox, columns, refine, coalesce, device,
                keep_on_device=keep_on_device, filter=filter)
        t0 = time.perf_counter()
        c0 = time.process_time()
        with obs.span("scan.file", path=self.path, device=device,
                      refine=bool(refine), filtered=filter is not None):
            out = self._read_columnar_impl(
                bbox, columns, refine, coalesce, device,
                keep_on_device=keep_on_device, filter=filter)
        wall = time.perf_counter() - t0
        cpu = time.process_time() - c0
        stats = out[2]
        obs.observe("scan.latency_s", wall)
        scanned_gb = stats.bytes_read / 1e9
        if scanned_gb > 0:
            # process-wide CPU per scanned GB: the GPU-layout-v2 ROADMAP
            # metric (how much host planning/decode a scan still costs)
            obs.gauge("scan.host_cpu_s_per_gb", cpu / scanned_gb)
            obs.observe("scan.host_cpu_s_per_gb_hist", cpu / scanned_gb)
        obs.count("pruned.page_bytes",
                  max(0, stats.bytes_total - stats.bytes_read))
        obs.fold_read_stats(stats)
        return out

    def _read_columnar_impl(self, bbox, columns, refine, coalesce, device,
                            *, keep_on_device, filter=None):
        if device not in ("cpu", "jax"):
            raise ValueError(f"device must be 'cpu' or 'jax', got {device!r}")
        use_device = device == "jax"
        if keep_on_device and not use_device:
            raise ValueError("keep_on_device=True requires device='jax'")
        if filter is not None:
            validate_predicate(filter, self.extra_schema)
        want_geom = columns is None or "geometry" in columns
        want_extra = (
            list(self.extra_schema)
            if columns is None
            else [c for c in columns if c in self.extra_schema]
        )
        # columns the filter needs decode too, but are only *returned* when
        # requested (trimmed below)
        read_extra = list(want_extra)
        if filter is not None:
            read_extra += [c for c in sorted(filter.columns())
                           if c not in want_extra]
        idx = self.index
        stats = ReadStats(pages_total=len(idx), bytes_total=self._data_bytes)
        src_stats0 = self._source.stats.copy()

        # group hit-page runs by row group (runs arrive in file order)
        hit = idx.query(bbox, filter=filter)
        if filter is not None and obs.enabled():
            # coordinate bytes of pages the zone stats pruned beyond bbox
            zoned = np.setdiff1d(idx.query(bbox), hit, assume_unique=True)
            obs.count("pruned.zone_bytes", int(idx.nbytes[zoned].sum()))
        runs_by_rg: dict[int, list[tuple[int, int]]] = {}
        for rg_i, p0, p1 in idx.page_runs(bbox, hit=hit):
            runs_by_rg.setdefault(rg_i, []).append((p0, p1))
            stats.pages_read += p1 - p0

        # per-row-group work items: (rg_i, rg, runs, base, extra_pages, ranges)
        items = []
        for rg_i, rg in enumerate(self.footer["row_groups"]):
            runs = runs_by_rg.get(rg_i)
            if not runs:
                continue
            base = int(np.searchsorted(idx.row_group, rg_i, side="left"))
            extra_pages = {k: rg["extra"][k] for k in read_extra}
            items.append((rg_i, rg, runs, base, extra_pages,
                          self._rg_ranges(rg, runs, base, want_geom, extra_pages)))

        fused = use_device and want_geom and (
            keep_on_device or (refine and bbox is not None)
            or (filter is not None and self.coord_dtype.kind == "f")
        )
        if fused and refine and bbox is not None and self.coord_dtype.kind != "f":
            if keep_on_device:
                raise ValueError("device refinement requires float coordinates")
            fused = False  # exotic int coords: decode on device, refine on host
        if fused:
            out = self._read_columnar_fused(
                bbox, refine, coalesce, keep_on_device, read_extra,
                items, stats, hit, filter=filter)
            if filter is not None:
                geo_f, extras_f, stats_f = out
                out = (geo_f, {k: extras_f[k] for k in want_extra}, stats_f)
            self._fold_source_stats(stats, src_stats0)
            return out

        if use_device:
            # lazy: keeps jax out of host-only read paths
            from repro.kernels.fp_delta import decode_pages as _device_decode_pages

        # preallocate coordinate destinations across every hit page
        total_vals = int(idx.count[hit].sum()) if len(hit) else 0
        x_all = np.empty(total_vals, self.coord_dtype) if want_geom else None
        y_all = np.empty(total_vals, self.coord_dtype) if want_geom else None
        total_recs = int(idx.rec_count[hit].sum()) if len(hit) else 0
        extra_all = {
            k: np.empty(total_recs, np.dtype(self.extra_schema[k]))
            for k in read_extra
        }

        types_parts: list[np.ndarray] = []
        type_rep_parts: list[np.ndarray] = []
        rep_parts: list[np.ndarray] = []
        defn_parts: list[np.ndarray] = []
        w = 0   # value write cursor into x_all / y_all
        we = 0  # record write cursor into extra columns
        level_parts = (types_parts, type_rep_parts, rep_parts, defn_parts)
        src_iter = self._iter_sources(items, coalesce)
        try:
            for (rg_i, rg, runs, base, extra_pages, _ranges), src in src_iter:
                xp, yp = rg["x_pages"], rg["y_pages"]
                if want_geom:
                    lv = self._decode_rg_levels(src, rg, stats)

                deferred: list[tuple] = []  # (plan, dest array, dest offset)

                def _coord_page(axis, page_dict, j, p, dest, off, cnt):
                    """Decode one coordinate page now (host) or defer it to
                    the row group's batched device launch (fp_delta only)."""
                    meta = PageMeta.from_dict(page_dict)
                    blob = self._checked_blob(
                        src,
                        int(idx.x_offset[j] if axis == "x" else idx.y_offset[j]),
                        int(idx.x_nbytes[j] if axis == "x" else idx.y_nbytes[j]),
                        meta.crc, stats,
                        f"{axis} page {p} of row group {rg_i}")
                    if use_device and meta.encoding == ENC_FP_DELTA:
                        deferred.append(
                            (page_plan(blob, meta, self.coord_dtype, self.codec),
                             dest, off))
                    else:
                        decode_page(blob, meta, self.coord_dtype, self.codec,
                                    out=dest[off : off + cnt])

                with obs.span("rg.decode", cat="decode", rg=rg_i,
                              device=device):
                    for p0, p1 in runs:
                        j0, j1 = base + p0, base + p1 - 1
                        r0 = int(idx.rec_start[j0])
                        r1 = int(idx.rec_start[j1] + idx.rec_count[j1])
                        stats.records_scanned += r1 - r0
                        if want_geom:
                            for p in range(p0, p1):
                                j = base + p
                                cnt = int(idx.count[j])
                                _coord_page("x", xp[p], j, p, x_all, w, cnt)
                                _coord_page("y", yp[p], j, p, y_all, w, cnt)
                                w += cnt
                            stats.bytes_read += int(
                                idx.x_nbytes[j0 : j1 + 1].sum()
                                + idx.y_nbytes[j0 : j1 + 1].sum()
                            )
                            lv.append_run(level_parts, r0, r1)
                        self._decode_run_extras(src, extra_pages, extra_all,
                                                we, p0, p1, stats)
                        we += r1 - r0

                if deferred:
                    # one batched page-stream launch per row group; decoded
                    # bits are copied into the preallocated columns dtype-
                    # blind (view) so float/int columns both stay bit-exact
                    with obs.span("rg.launch", cat="device", rg=rg_i,
                                  pages=len(deferred)):
                        outs = _device_decode_pages([p for p, _, _ in deferred])
                        for (plan, dest, off), vals in zip(deferred, outs):
                            dest[off : off + plan.n_values] = vals.view(dest.dtype)
        finally:
            src_iter.close()

        if want_geom and types_parts:
            geo = GeometryColumns(
                np.concatenate(types_parts),
                np.concatenate(type_rep_parts),
                np.concatenate(rep_parts),
                np.concatenate(defn_parts),
                x_all[:w], y_all[:w],
            )
        else:
            geo = None
        extras = {k: v[:we] for k, v in extra_all.items()}
        keep_mask = None
        if refine and bbox is not None and geo is not None:
            with obs.span("refine.host", cat="refine"):
                starts = geo.record_value_starts()
                counts = np.diff(np.append(starts, geo.n_values))
                keep_mask = _bbox_keep_mask(geo.x, geo.y, counts, bbox)
        if filter is not None:
            attr = (filter.mask(extras) if we
                    else np.zeros(0, bool))
            if we:
                obs.observe("filter.selectivity", float(attr.sum()) / we)
            keep_mask = attr if keep_mask is None else keep_mask & attr
        if keep_mask is not None:
            if geo is not None:
                geo = permute_records(geo, np.flatnonzero(keep_mask))
                obs.count("pruned.record_bytes",
                          (w - geo.n_values) * 2 * self.coord_dtype.itemsize)
            extras = {k: v[keep_mask] for k, v in extras.items()}
        if filter is not None:
            extras = {k: extras[k] for k in want_extra}
        stats.records_returned = geo.n_records if geo is not None else (
            len(next(iter(extras.values()))) if extras else 0
        )
        self._fold_source_stats(stats, src_stats0)
        return geo, extras, stats

    def _fold_source_stats(self, stats: ReadStats, before) -> None:
        """Fold the source's recovery counters accrued by this read into the
        query's ReadStats (delta against the snapshot taken at entry)."""
        d = self._source.stats - before
        stats.retries += d.retries
        stats.timeouts += d.timeouts
        stats.cache_hits += d.cache_hits
        stats.cache_misses += d.cache_misses

    # ------------------------------------------------------ fused device scan
    def _read_columnar_fused(self, bbox, refine, coalesce, keep_on_device,
                             want_extra, items, stats, hit, filter=None):
        """Decode → per-record bbox refine → compact, all device-resident.

        Per row group: levels decode on the host (they drive segmentation),
        every hit coordinate page becomes a plan (raw pages via the synthetic
        raw-mode plan) and joins one fused launch chain per VMEM-sized chunk
        (`decode_refine_stream`). Only the per-record survivor mask and the
        surviving coordinate values cross back to the host — or nothing at
        all with ``keep_on_device=True``.

        With ``filter`` the host-evaluated attribute mask is AND-ed into the
        chunk's per-record ``valid`` operand before the launch, so the device
        computes ``bbox ∧ attrs`` in one pass and survivor compaction (the
        gather back to the host) already excludes records the predicate
        rejects.
        """
        from repro.kernels.fp_delta import (
            build_page_stream,
            build_refine_aux,
            chunk_plan_pairs,
            decode_refine_stream,
            decode_stream_device,
            gather_stream_values,
            ragged_ranges,
        )

        idx = self.index
        dtype = self.coord_dtype
        width = dtype.itemsize * 8
        do_refine = refine and bbox is not None
        do_compact = do_refine or filter is not None

        total_recs = int(idx.rec_count[hit].sum()) if len(hit) else 0
        extra_all = {
            k: np.empty(total_recs, np.dtype(self.extra_schema[k]))
            for k in want_extra
        }
        types_parts: list[np.ndarray] = []
        type_rep_parts: list[np.ndarray] = []
        rep_parts: list[np.ndarray] = []
        defn_parts: list[np.ndarray] = []
        keep_parts: list[np.ndarray] = []
        x_parts: list = []
        y_parts: list = []
        we = 0

        level_parts = (types_parts, type_rep_parts, rep_parts, defn_parts)
        vals_pruned = 0  # refine-dropped values (record-level byte pruning)
        src_iter = self._iter_sources(items, coalesce)
        try:
            for (rg_i, rg, runs, base, extra_pages, _ranges), src in src_iter:
                xp, yp = rg["x_pages"], rg["y_pages"]
                lv = self._decode_rg_levels(src, rg, stats)
                rec_vcounts_rg = lv.record_value_counts()
                we0 = we  # this row group's record span in the extra columns

                plans: list = []            # x,y plan per page, stream order
                pairs: list[tuple[int, int]] = []   # local record range per pair
                vc_parts: list[np.ndarray] = []
                local_base = 0
                plan_span = obs.span("rg.plan", cat="plan", rg=rg_i)
                with plan_span:
                    for p0, p1 in runs:
                        j0, j1 = base + p0, base + p1 - 1
                        r0 = int(idx.rec_start[j0])
                        r1 = int(idx.rec_start[j1] + idx.rec_count[j1])
                        stats.records_scanned += r1 - r0
                        for p in range(p0, p1):
                            j = base + p
                            meta_x = PageMeta.from_dict(xp[p])
                            meta_y = PageMeta.from_dict(yp[p])
                            # checksums gate the launch chain: a corrupt page
                            # is caught here, before any plan or Pallas
                            # kernel sees it
                            blob_x = self._checked_blob(
                                src, int(idx.x_offset[j]), int(idx.x_nbytes[j]),
                                meta_x.crc, stats,
                                f"x page {p} of row group {rg_i}")
                            blob_y = self._checked_blob(
                                src, int(idx.y_offset[j]), int(idx.y_nbytes[j]),
                                meta_y.crc, stats,
                                f"y page {p} of row group {rg_i}")
                            plans.append(page_stream_plan(
                                blob_x, meta_x, dtype, self.codec))
                            plans.append(page_stream_plan(
                                blob_y, meta_y, dtype, self.codec))
                            lo_loc = local_base + int(idx.rec_start[j]) - r0
                            pairs.append((lo_loc, lo_loc + int(idx.rec_count[j])))
                        stats.bytes_read += int(
                            idx.x_nbytes[j0 : j1 + 1].sum() + idx.y_nbytes[j0 : j1 + 1].sum()
                        )
                        vc_parts.append(rec_vcounts_rg[r0:r1])
                        local_base += r1 - r0
                        lv.append_run(level_parts, r0, r1)
                        self._decode_run_extras(src, extra_pages, extra_all, we,
                                                p0, p1, stats)
                        we += r1 - r0
                    plan_span.add(pages=len(pairs))
                rec_vcounts = (np.concatenate(vc_parts) if vc_parts
                               else np.zeros(0, np.int64))
                # host-evaluated attribute mask for this row group's read
                # records (aligned with rec_vcounts / the chunk record ranges)
                attr_rg = None
                if filter is not None:
                    attr_rg = filter.mask(
                        {k: extra_all[k][we0:we] for k in filter.columns()})

                # chunk page pairs into VMEM-sized fused launches
                for kind, cplans, cpairs, (rl, rh) in chunk_plan_pairs(plans, pairs):
                    vc = rec_vcounts[rl:rh]
                    attr_c = attr_rg[rl:rh] if attr_rg is not None else None
                    if kind == "host":
                        # a single page too large for any launch: decode this
                        # pair on the host (same bits via fp_delta_execute)
                        with obs.span("rg.launch", cat="decode", rg=rg_i,
                                      kind="host"):
                            x_v = fp_delta_execute(cplans[0])
                            y_v = fp_delta_execute(cplans[1])
                            keep_c = (_bbox_keep_mask(x_v, y_v, vc, bbox)
                                      if do_refine else np.ones(len(vc), bool))
                            if attr_c is not None:
                                keep_c = keep_c & attr_c
                            starts = np.cumsum(vc) - vc
                            iv = ragged_ranges(starts[keep_c], vc[keep_c])
                            xs, ys = x_v[iv], y_v[iv]
                        if keep_on_device:
                            xs = DeviceCoords.from_numpy(xs)
                            ys = DeviceCoords.from_numpy(ys)
                        if do_compact and obs.enabled():
                            vals_pruned += int(vc.sum() - vc[keep_c].sum())
                        keep_parts.append(keep_c)
                        x_parts.append(xs)
                        y_parts.append(ys)
                        continue
                    with obs.span("rg.launch", cat="device", rg=rg_i,
                                  kind="refine" if do_refine else "decode",
                                  pairs=len(cpairs)):
                        stream = build_page_stream(cplans)
                        aux = build_refine_aux(
                            stream, [(a - rl, b - rl) for a, b in cpairs], vc)
                        if attr_c is not None and do_refine:
                            # the device record mask is valid ∧ bbox; AND-ing
                            # the attribute mask into a fresh copy of valid
                            # makes it bbox ∧ attrs in the same launch
                            v2 = aux.valid.copy()
                            v2[:len(attr_c)] &= attr_c
                            aux = dc_replace(aux, valid=v2)
                        if do_refine:
                            res = decode_refine_stream(stream, aux, bbox)
                            keep_c, lo_d, hi_d = res.keep, res.lo, res.hi
                        else:
                            lo_d, hi_d = decode_stream_device(stream)
                            keep_c = (attr_c.copy() if attr_c is not None
                                      else np.ones(len(vc), bool))
                    if do_compact and obs.enabled():
                        vals_pruned += int(vc.sum() - vc[keep_c].sum())
                    keep_parts.append(keep_c)
                    with obs.span("rg.gather", cat="transfer", rg=rg_i):
                        ix = ragged_ranges(aux.x_start[keep_c], aux.counts[keep_c])
                        iy = ragged_ranges(aux.y_start[keep_c], aux.counts[keep_c])
                        x_parts.append(gather_stream_values(
                            lo_d, hi_d, ix, width, dtype,
                            keep_on_device=keep_on_device))
                        y_parts.append(gather_stream_values(
                            lo_d, hi_d, iy, width, dtype,
                            keep_on_device=keep_on_device))
        finally:
            src_iter.close()
        obs.count("pruned.record_bytes", vals_pruned * 2 * dtype.itemsize)

        keep_all = (np.concatenate(keep_parts) if keep_parts
                    else np.zeros(0, bool))
        if types_parts:
            types = np.concatenate(types_parts)
            type_rep = np.concatenate(type_rep_parts)
            rep = np.concatenate(rep_parts)
            defn = np.concatenate(defn_parts)
            if do_compact:
                # record-aligned level subset == permute_records on the kept
                # (sorted) records: canonical levels stay canonical
                slot_keep = keep_all[np.cumsum(rep == 0) - 1]
                type_keep = keep_all[np.cumsum(type_rep == 0) - 1]
                types = types[type_keep]
                type_rep = type_rep[type_keep]
                rep = rep[slot_keep]
                defn = defn[slot_keep]
            if keep_on_device:
                x = DeviceCoords.concat(x_parts)
                y = DeviceCoords.concat(y_parts)
            else:
                x = np.concatenate(x_parts)
                y = np.concatenate(y_parts)
            geo = GeometryColumns(types, type_rep, rep, defn, x, y)
        else:
            geo = None
        extras = {k: v[:we] for k, v in extra_all.items()}
        if do_compact and geo is not None:
            extras = {k: v[keep_all] for k, v in extras.items()}
        if filter is not None and we:
            obs.observe("filter.selectivity", float(keep_all.sum()) / we)
        stats.records_returned = geo.n_records if geo is not None else (
            len(next(iter(extras.values()))) if extras else 0
        )
        return geo, extras, stats

    # ---------------------------------------------- whole-row-group decode
    def read_row_group(self, rg_i: int, *, columns=None,
                       device: str = "cpu") -> "RowGroupData":
        """Fetch + decode *every* page of one row group, independent of any
        query bbox — the unit of the serve tier's decoded-row-group cache
        (:mod:`repro.serve.query_scheduler`).

        Pages are record-aligned, so a record's values (and therefore its
        exact [min, max]) computed from the full row group are bit-identical
        to the same record decoded through a bbox-pruned page run — the
        property that lets one decode serve queries whose page sets differ.
        ``device="cpu"`` fills ``x``/``y`` host arrays; ``device="jax"``
        returns *unlaunched* per-chunk page streams (the caller owns the
        launch so it can fuse multi-query refinement into it).
        """
        if device not in ("cpu", "jax"):
            raise ValueError(f"device must be 'cpu' or 'jax', got {device!r}")
        idx = self.index
        rg = self.footer["row_groups"][rg_i]
        base = int(np.searchsorted(idx.row_group, rg_i, side="left"))
        n_pages = len(rg["x_pages"])
        want_extra = (list(self.extra_schema) if columns is None
                      else [c for c in columns if c in self.extra_schema])
        extra_pages = {k: rg["extra"][k] for k in want_extra}
        runs = [(0, n_pages)]
        stats = ReadStats()
        with obs.span("rg.read_full", cat="io", rg=rg_i, device=device):
            src = _CoalescedRanges(
                self._source,
                self._rg_ranges(rg, runs, base, True, extra_pages),
                self.coalesce_max_gap)
            lv = self._decode_rg_levels(src, rg, stats)
            rec_vcounts = lv.record_value_counts()
            n_rec = lv.n_rec
            extra_all = {
                k: np.empty(n_rec, np.dtype(self.extra_schema[k]))
                for k in want_extra
            }
            self._decode_run_extras(src, extra_pages, extra_all, 0,
                                    0, n_pages, stats)
            if n_pages:
                j0, j1 = base, base + n_pages - 1
                stats.bytes_read += int(idx.x_nbytes[j0 : j1 + 1].sum()
                                        + idx.y_nbytes[j0 : j1 + 1].sum())
            rec0 = int(idx.rec_start[base]) if n_pages else 0

            def coord_blobs(p):
                j = base + p
                meta_x = PageMeta.from_dict(rg["x_pages"][p])
                meta_y = PageMeta.from_dict(rg["y_pages"][p])
                blob_x = self._checked_blob(
                    src, int(idx.x_offset[j]), int(idx.x_nbytes[j]),
                    meta_x.crc, stats, f"x page {p} of row group {rg_i}")
                blob_y = self._checked_blob(
                    src, int(idx.y_offset[j]), int(idx.y_nbytes[j]),
                    meta_y.crc, stats, f"y page {p} of row group {rg_i}")
                return meta_x, blob_x, meta_y, blob_y

            if device == "cpu":
                total_vals = int(idx.count[base : base + n_pages].sum())
                x_all = np.empty(total_vals, self.coord_dtype)
                y_all = np.empty(total_vals, self.coord_dtype)
                w = 0
                with obs.span("rg.decode", cat="decode", rg=rg_i, device="cpu"):
                    for p in range(n_pages):
                        meta_x, blob_x, meta_y, blob_y = coord_blobs(p)
                        cnt = int(idx.count[base + p])
                        decode_page(blob_x, meta_x, self.coord_dtype,
                                    self.codec, out=x_all[w : w + cnt])
                        decode_page(blob_y, meta_y, self.coord_dtype,
                                    self.codec, out=y_all[w : w + cnt])
                        w += cnt
                return RowGroupData(rg_i, n_rec, rec_vcounts, lv, extra_all,
                                    stats.bytes_read, x=x_all, y=y_all)

            from repro.kernels.fp_delta import (
                build_page_stream,
                build_refine_aux,
                chunk_plan_pairs,
            )

            plans: list = []
            pairs: list[tuple[int, int]] = []
            with obs.span("rg.plan", cat="plan", rg=rg_i, pages=n_pages):
                for p in range(n_pages):
                    meta_x, blob_x, meta_y, blob_y = coord_blobs(p)
                    plans.append(page_stream_plan(
                        blob_x, meta_x, self.coord_dtype, self.codec))
                    plans.append(page_stream_plan(
                        blob_y, meta_y, self.coord_dtype, self.codec))
                    j = base + p
                    r0 = int(idx.rec_start[j]) - rec0
                    pairs.append((r0, r0 + int(idx.rec_count[j])))
            chunks: list[RowGroupChunk] = []
            for kind, cplans, cpairs, (rl, rh) in chunk_plan_pairs(plans, pairs):
                if kind == "host":
                    chunks.append(RowGroupChunk(
                        "host", rl, rh,
                        x=fp_delta_execute(cplans[0]),
                        y=fp_delta_execute(cplans[1])))
                    continue
                stream = build_page_stream(cplans)
                aux = build_refine_aux(
                    stream, [(a - rl, b - rl) for a, b in cpairs],
                    rec_vcounts[rl:rh])
                chunks.append(RowGroupChunk("dev", rl, rh,
                                            stream=stream, aux=aux))
            return RowGroupData(rg_i, n_rec, rec_vcounts, lv, extra_all,
                                stats.bytes_read, chunks=chunks)

    def read(self, bbox=None, refine: bool = False) -> tuple[list[Geometry], ReadStats]:
        """Object-API read returning Geometry instances."""
        geo, _, stats = self.read_columnar(bbox=bbox, refine=refine)
        return (assemble(geo) if geo is not None else []), stats


def _bbox_keep_mask(x: np.ndarray, y: np.ndarray, counts: np.ndarray,
                    bbox) -> np.ndarray:
    """Exact per-record bbox mask over contiguous value slices (the host
    refinement oracle: NaN-propagating ``minimum.reduceat`` + float
    compares — any NaN coordinate drops its record). The query box goes
    through the shared :func:`~repro.core.filters.canonical_bbox` rule
    first, so an empty box (NaN bound / inverted extent) keeps nothing —
    the same answer the shard-, page- and device-record-level tests give.
    """
    counts = np.asarray(counts, np.int64)
    keep = np.zeros(len(counts), dtype=bool)
    bbox = canonical_bbox(bbox)
    if bbox is None:
        return keep
    starts = np.cumsum(counts) - counts
    nz = counts > 0
    if nz.any():
        s = starts[nz]
        xs = x.astype(np.float64, copy=False)
        ys = y.astype(np.float64, copy=False)
        xmin = np.minimum.reduceat(xs, s)
        xmax = np.maximum.reduceat(xs, s)
        ymin = np.minimum.reduceat(ys, s)
        ymax = np.maximum.reduceat(ys, s)
        qx0, qy0, qx1, qy1 = bbox
        keep[nz] = (xmin <= qx1) & (xmax >= qx0) & (ymin <= qy1) & (ymax >= qy0)
    return keep


def _records_intersecting(cols: GeometryColumns, bbox) -> np.ndarray:
    """Vectorized exact per-record bbox test (refinement step)."""
    starts = cols.record_value_starts()
    counts = np.diff(np.append(starts, cols.n_values))
    return np.flatnonzero(_bbox_keep_mask(cols.x, cols.y, counts, bbox))

"""Spatial Parquet file reader: projection, range-filter pushdown, pruning.

The reader exposes two access paths:

* ``read(...)`` — the object API returning :class:`Geometry` lists (paper's
  reported read path), and
* ``read_columnar(...)`` — direct access to the decoded coordinate arrays.
  The paper (§5.1) names exactly this as the fix for its read-speed gap
  ("providing a lower-level access to the coordinate arrays from Parquet
  rather than reading one value at a time"); it is our primary fast path and
  what the training data pipeline consumes.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field

import msgpack
import numpy as np

from .columnar import GeometryColumns, assemble
from .geometry import Geometry, bbox_intersects
from .index import SpatialIndex
from .pages import PageMeta, decode_page, decompress
from .rle import decode_levels, rle_decode
from .writer import MAGIC, concat_columns, permute_records


@dataclass
class ReadStats:
    """Pruning accounting for the light-weight index (paper Figure 11)."""

    pages_total: int = 0
    pages_read: int = 0
    bytes_total: int = 0
    bytes_read: int = 0
    records_scanned: int = 0
    records_returned: int = 0

    @property
    def pages_skipped(self) -> int:
        return self.pages_total - self.pages_read


class SpatialParquetReader:
    def __init__(self, path):
        self.path = str(path)
        self._fh = open(self.path, "rb")
        self.footer = self._read_footer()
        self.coord_dtype = np.dtype(self.footer["coord_dtype"])
        self.codec = self.footer["codec"]
        self.n_records = self.footer["n_records"]
        self.extra_schema = self.footer.get("extra_schema", {})
        self.index = SpatialIndex(self.footer)

    def close(self):
        self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------- internals
    def _read_footer(self) -> dict:
        fh = self._fh
        fh.seek(0)
        if fh.read(len(MAGIC)) != MAGIC:
            raise ValueError("not a Spatial Parquet file (bad leading magic)")
        fh.seek(-(len(MAGIC) + 4), 2)
        (flen,) = struct.unpack("<I", fh.read(4))
        if fh.read(len(MAGIC)) != MAGIC:
            raise ValueError("truncated Spatial Parquet file (bad trailing magic)")
        fh.seek(-(len(MAGIC) + 4 + flen), 2)
        return msgpack.unpackb(fh.read(flen), raw=False, strict_map_key=False)

    def _blob(self, meta: dict) -> bytes:
        self._fh.seek(meta["offset"])
        return self._fh.read(meta["nbytes"])

    def _levels(self, rg: dict) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        types = rle_decode(decompress(self._blob(rg["type"]), self.codec))
        type_rep = decode_levels(decompress(self._blob(rg["type_rep"]), self.codec))
        rep = decode_levels(decompress(self._blob(rg["rep"]), self.codec))
        defn = decode_levels(decompress(self._blob(rg["defn"]), self.codec))
        return types, type_rep, rep, defn

    def _decode_coord_page(self, page_dict: dict) -> np.ndarray:
        meta = PageMeta.from_dict(page_dict)
        return decode_page(self._blob(page_dict), meta, self.coord_dtype, self.codec)

    # -------------------------------------------------------------- read API
    def read_columnar(
        self,
        bbox=None,
        columns: tuple[str, ...] | None = None,
        refine: bool = False,
    ) -> tuple[GeometryColumns | None, dict[str, np.ndarray], ReadStats]:
        """Decode records whose *page* bbox intersects ``bbox``.

        Returns (geometry columns, extra columns, stats). ``refine=True``
        additionally drops records whose exact bbox misses the query.
        ``columns`` restricts which extra columns decode ("geometry" is
        implied unless columns excludes it explicitly).
        """
        want_geom = columns is None or "geometry" in columns
        want_extra = (
            list(self.extra_schema)
            if columns is None
            else [c for c in columns if c in self.extra_schema]
        )
        stats = ReadStats(
            pages_total=len(self.index),
            bytes_total=self.index.total_bytes,
        )
        hit = self.index.query(bbox)
        hit_set: dict[int, list[int]] = {}
        for idx in hit:
            e = self.index.entries[idx]
            hit_set.setdefault(e.row_group, []).append(e.page)

        geo_parts: list[GeometryColumns] = []
        extra_parts: dict[str, list[np.ndarray]] = {k: [] for k in want_extra}
        for rg_i, rg in enumerate(self.footer["row_groups"]):
            pages = sorted(hit_set.get(rg_i, []))
            if not pages:
                continue
            stats.pages_read += len(pages)
            types, type_rep, rep, defn = self._levels(rg)
            slot_starts = np.flatnonzero(rep == 0)
            type_starts = np.flatnonzero(type_rep == 0)
            n_rec = len(slot_starts)
            value_off = np.cumsum(defn.astype(np.int64)) - defn
            # merge contiguous pages into runs
            runs: list[list[int]] = [[pages[0]]]
            for p in pages[1:]:
                if p == runs[-1][-1] + 1:
                    runs[-1].append(p)
                else:
                    runs.append([p])
            xp, yp = rg["x_pages"], rg["y_pages"]
            for run in runs:
                r0 = xp[run[0]]["rec_start"]
                r1 = xp[run[-1]]["rec_start"] + xp[run[-1]]["rec_count"]
                stats.records_scanned += r1 - r0
                if want_geom:
                    xs = [self._decode_coord_page(xp[p]) for p in run]
                    ys = [self._decode_coord_page(yp[p]) for p in run]
                    stats.bytes_read += sum(xp[p]["nbytes"] + yp[p]["nbytes"] for p in run)
                    s0 = slot_starts[r0]
                    s1 = slot_starts[r1] if r1 < n_rec else len(rep)
                    t0 = type_starts[r0]
                    t1 = type_starts[r1] if r1 < n_rec else len(types)
                    geo_parts.append(
                        GeometryColumns(
                            types[t0:t1], type_rep[t0:t1].copy(),
                            rep[s0:s1].copy(), defn[s0:s1],
                            np.concatenate(xs), np.concatenate(ys),
                        )
                    )
                    # the first slot of a run always starts a record
                    geo_parts[-1].rep[0] = 0
                    geo_parts[-1].type_rep[0] = 0
                for k in want_extra:
                    ep = rg["extra"][k]
                    chunk = [
                        decode_page(
                            self._blob(ep[p]), PageMeta.from_dict(ep[p]),
                            np.dtype(self.extra_schema[k]), self.codec,
                        )
                        for p in run
                    ]
                    extra_parts[k].append(np.concatenate(chunk))

        geo = concat_columns(geo_parts) if geo_parts else None
        extras = {
            k: (np.concatenate(v) if v else np.zeros(0, np.dtype(self.extra_schema[k])))
            for k, v in extra_parts.items()
        }
        if refine and bbox is not None and geo is not None:
            keep = _records_intersecting(geo, bbox)
            geo = permute_records(geo, keep)
            extras = {k: v[keep] for k, v in extras.items()}
        stats.records_returned = geo.n_records if geo is not None else (
            len(next(iter(extras.values()))) if extras else 0
        )
        return geo, extras, stats

    def read(self, bbox=None, refine: bool = False) -> tuple[list[Geometry], ReadStats]:
        """Object-API read returning Geometry instances."""
        geo, _, stats = self.read_columnar(bbox=bbox, refine=refine)
        return (assemble(geo) if geo is not None else []), stats


def _records_intersecting(cols: GeometryColumns, bbox) -> np.ndarray:
    """Vectorized exact per-record bbox test (refinement step)."""
    starts = cols.record_value_starts()
    counts = np.diff(np.append(starts, cols.n_values))
    n_rec = cols.n_records
    keep = np.zeros(n_rec, dtype=bool)
    nz = counts > 0
    if nz.any():
        s = starts[nz]
        x = cols.x.astype(np.float64, copy=False)
        y = cols.y.astype(np.float64, copy=False)
        xmin = np.minimum.reduceat(x, s)
        xmax = np.maximum.reduceat(x, s)
        ymin = np.minimum.reduceat(y, s)
        ymax = np.maximum.reduceat(y, s)
        qx0, qy0, qx1, qy1 = bbox
        keep[nz] = (xmin <= qx1) & (xmax >= qx0) & (ymin <= qy1) & (ymax >= qy0)
    return np.flatnonzero(keep)

"""OGC geometry model for Spatial Parquet (paper §2, Appendix A.1).

Geometries are held as ``(geom_type, parts)`` where ``parts`` is a list of
``(k, 2)`` float arrays. This mirrors the paper's unified PBF schema::

    message Geometry {
      required int type;
      repeated group part { repeated group coordinate { x; y; } }
    }

Winding conventions (paper §2.3/§2.6): polygon outer shells are stored
clockwise (CW), holes counter-clockwise (CCW); MultiPolygon sub-polygon
boundaries are recovered from the winding test on read.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

TYPE_EMPTY = 0
TYPE_POINT = 1
TYPE_LINESTRING = 2
TYPE_POLYGON = 3
TYPE_MULTIPOINT = 4
TYPE_MULTILINESTRING = 5
TYPE_MULTIPOLYGON = 6
TYPE_GEOMETRYCOLLECTION = 7  # flattened on write (paper §2.7)

TYPE_NAMES = {
    TYPE_EMPTY: "Empty",
    TYPE_POINT: "Point",
    TYPE_LINESTRING: "LineString",
    TYPE_POLYGON: "Polygon",
    TYPE_MULTIPOINT: "MultiPoint",
    TYPE_MULTILINESTRING: "MultiLineString",
    TYPE_MULTIPOLYGON: "MultiPolygon",
    TYPE_GEOMETRYCOLLECTION: "GeometryCollection",
}


def signed_area(ring: np.ndarray) -> float:
    """Shoelace signed area; positive for CCW rings (math convention)."""
    x, y = ring[:, 0], ring[:, 1]
    return 0.5 * float(np.dot(x, np.roll(y, -1)) - np.dot(np.roll(x, -1), y))


def is_cw(ring: np.ndarray) -> bool:
    return signed_area(ring) <= 0.0


def close_ring(ring: np.ndarray) -> np.ndarray:
    """Repeat the first point at the end if not already closed (paper §2.3)."""
    if len(ring) and not np.array_equal(ring[0], ring[-1]):
        return np.vstack([ring, ring[:1]])
    return ring


def orient_ring(ring: np.ndarray, clockwise: bool) -> np.ndarray:
    return ring if is_cw(ring) == clockwise else ring[::-1].copy()


@dataclass
class Geometry:
    """A single geometry: type code + list of parts ((k,2) arrays)."""

    geom_type: int
    parts: list[np.ndarray] = field(default_factory=list)
    # Only for GeometryCollection: flattened sub-geometries.
    sub_geometries: list["Geometry"] = field(default_factory=list)

    # ------------------------------------------------------------------ ctor
    @staticmethod
    def point(x: float, y: float) -> "Geometry":
        return Geometry(TYPE_POINT, [np.array([[x, y]], dtype=np.float64)])

    @staticmethod
    def linestring(coords) -> "Geometry":
        return Geometry(TYPE_LINESTRING, [np.asarray(coords, dtype=np.float64)])

    @staticmethod
    def polygon(shell, holes=()) -> "Geometry":
        """Shell stored CW, holes CCW, rings closed (paper conventions)."""
        parts = [orient_ring(close_ring(np.asarray(shell, np.float64)), clockwise=True)]
        for h in holes:
            parts.append(orient_ring(close_ring(np.asarray(h, np.float64)), clockwise=False))
        return Geometry(TYPE_POLYGON, parts)

    @staticmethod
    def multipoint(coords) -> "Geometry":
        pts = np.asarray(coords, dtype=np.float64)
        # one part per point — semantically accurate per paper §2.4
        return Geometry(TYPE_MULTIPOINT, [pts[i : i + 1] for i in range(len(pts))])

    @staticmethod
    def multilinestring(lines) -> "Geometry":
        return Geometry(TYPE_MULTILINESTRING, [np.asarray(l, np.float64) for l in lines])

    @staticmethod
    def multipolygon(polygons) -> "Geometry":
        """``polygons`` is a list of (shell, holes) pairs or Polygon Geometries."""
        parts: list[np.ndarray] = []
        for poly in polygons:
            if isinstance(poly, Geometry):
                parts.extend(poly.parts)
            else:
                shell, holes = poly if isinstance(poly, tuple) else (poly, ())
                parts.append(orient_ring(close_ring(np.asarray(shell, np.float64)), True))
                for h in holes:
                    parts.append(orient_ring(close_ring(np.asarray(h, np.float64)), False))
        return Geometry(TYPE_MULTIPOLYGON, parts)

    @staticmethod
    def collection(geoms) -> "Geometry":
        """GeometryCollection; nested collections are flattened (paper §2.7)."""
        flat: list[Geometry] = []

        def _flatten(g: "Geometry"):
            if g.geom_type == TYPE_GEOMETRYCOLLECTION:
                for sub in g.sub_geometries:
                    _flatten(sub)
            else:
                flat.append(g)

        for g in geoms:
            _flatten(g)
        if len(flat) == 1:
            # canonicalize: a single-element collection is indistinguishable
            # from its element after §2.7 flattening (see columnar.py)
            return flat[0]
        return Geometry(TYPE_GEOMETRYCOLLECTION, [], flat)

    @staticmethod
    def empty() -> "Geometry":
        return Geometry(TYPE_EMPTY, [])

    # ----------------------------------------------------------------- props
    @property
    def num_points(self) -> int:
        if self.geom_type == TYPE_GEOMETRYCOLLECTION:
            return sum(g.num_points for g in self.sub_geometries)
        return sum(len(p) for p in self.parts)

    def bbox(self) -> tuple[float, float, float, float]:
        """(xmin, ymin, xmax, ymax); inverted-empty box for empty geometries."""
        arrays = (
            [p for g in self.sub_geometries for p in g.parts]
            if self.geom_type == TYPE_GEOMETRYCOLLECTION
            else self.parts
        )
        if not arrays or not sum(len(a) for a in arrays):
            return (np.inf, np.inf, -np.inf, -np.inf)
        allc = np.vstack(arrays)
        return (
            float(allc[:, 0].min()),
            float(allc[:, 1].min()),
            float(allc[:, 0].max()),
            float(allc[:, 1].max()),
        )

    def centroid(self) -> tuple[float, float]:
        b = self.bbox()
        return ((b[0] + b[2]) / 2.0, (b[1] + b[3]) / 2.0)

    # --------------------------------------------------------------- dunders
    def __eq__(self, other) -> bool:
        if not isinstance(other, Geometry):
            return NotImplemented
        if self.geom_type != other.geom_type:
            return False
        if self.geom_type == TYPE_GEOMETRYCOLLECTION:
            return self.sub_geometries == other.sub_geometries
        if len(self.parts) != len(other.parts):
            return False
        return all(
            a.shape == b.shape and np.array_equal(a.view(np.int64), b.view(np.int64))
            for a, b in zip(self.parts, other.parts)
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{TYPE_NAMES[self.geom_type]} parts={len(self.parts)} pts={self.num_points}>"


def polygons_from_rings(rings: list[np.ndarray]) -> list[list[np.ndarray]]:
    """Group a flat ring list into polygons via the winding test (paper §2.6).

    CW ring => new outer shell; CCW ring => hole of the current polygon. The
    first ring is always a shell regardless of winding (defensive).
    """
    polygons: list[list[np.ndarray]] = []
    for i, ring in enumerate(rings):
        if i == 0 or is_cw(ring):
            polygons.append([ring])
        else:
            polygons[-1].append(ring)
    return polygons


def bbox_intersects(a, b) -> bool:
    return not (a[2] < b[0] or b[2] < a[0] or a[3] < b[1] or b[3] < a[1])

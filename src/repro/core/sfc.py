"""Space-filling-curve sort keys (paper §4): Z-curve and Hilbert curve.

Both operate on coordinates quantized to a ``2^order`` grid over a bounding
box and return uint64 keys; sorting records by key clusters spatially-nearby
records so page [min,max] statistics become tight (paper Figure 7). Fully
vectorized; the Hilbert transform iterates ``order`` times over the arrays.
"""

from __future__ import annotations

import numpy as np


def quantize(v: np.ndarray, lo: float, hi: float, order: int) -> np.ndarray:
    """Map values in [lo, hi] to integers in [0, 2^order)."""
    span = max(hi - lo, 1e-300)
    q = ((v - lo) / span * (2**order - 1)).astype(np.uint64)
    return np.clip(q, 0, 2**order - 1).astype(np.uint64)


def _spread_bits(v: np.ndarray) -> np.ndarray:
    """Insert a 0 bit between each of the low 32 bits (Morton spreading)."""
    v = v.astype(np.uint64) & np.uint64(0xFFFFFFFF)
    v = (v | (v << np.uint64(16))) & np.uint64(0x0000FFFF0000FFFF)
    v = (v | (v << np.uint64(8))) & np.uint64(0x00FF00FF00FF00FF)
    v = (v | (v << np.uint64(4))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    v = (v | (v << np.uint64(2))) & np.uint64(0x3333333333333333)
    v = (v | (v << np.uint64(1))) & np.uint64(0x5555555555555555)
    return v


def z_key(xq: np.ndarray, yq: np.ndarray) -> np.ndarray:
    """Morton (Z-order) key from quantized coordinates."""
    return _spread_bits(xq) | (_spread_bits(yq) << np.uint64(1))


def hilbert_key(xq: np.ndarray, yq: np.ndarray, order: int = 16) -> np.ndarray:
    """Hilbert curve distance of quantized points (vectorized xy2d)."""
    x = xq.astype(np.uint64).copy()
    y = yq.astype(np.uint64).copy()
    d = np.zeros(x.shape, dtype=np.uint64)
    s = np.uint64(1) << np.uint64(order - 1)
    one = np.uint64(1)
    while s > 0:
        rx = ((x & s) > 0).astype(np.uint64)
        ry = ((y & s) > 0).astype(np.uint64)
        d += s * s * ((np.uint64(3) * rx) ^ ry)
        # rotate quadrant
        swap = ry == 0
        flip = swap & (rx == 1)
        xf = np.where(flip, s - one - x, x)
        yf = np.where(flip, s - one - y, y)
        x_new = np.where(swap, yf, xf)
        y_new = np.where(swap, xf, yf)
        x, y = x_new, y_new
        s >>= one
    return d


def sort_keys(
    cx: np.ndarray, cy: np.ndarray, method: str, order: int = 16,
    bbox: tuple[float, float, float, float] | None = None,
) -> np.ndarray:
    """Sort keys for record centroids; ``method`` in {'z', 'hilbert'}."""
    if bbox is None:
        bbox = (float(cx.min()), float(cy.min()), float(cx.max()), float(cy.max()))
    xq = quantize(np.asarray(cx, np.float64), bbox[0], bbox[2], order)
    yq = quantize(np.asarray(cy, np.float64), bbox[1], bbox[3], order)
    if method == "z":
        return z_key(xq, yq)
    if method == "hilbert":
        return hilbert_key(xq, yq, order)
    raise ValueError(f"unknown SFC method {method!r} (use 'z' or 'hilbert')")

"""Run-length + bit-packed encodings for the type column and level streams.

Paper §3.1 uses RLE for the geometry ``type`` column ("virtually a constant"
for single-type datasets). Repetition/definition levels are 2-bit values
(paper §2); like Parquet we pick per-chunk between RLE and fixed-width
bit-packing, whichever is smaller, with a 1-byte mode tag.
"""

from __future__ import annotations

import struct

import numpy as np

from .bitstream import bytes_to_words, pack_tokens, unpack_fixed, words_to_bytes

MODE_RLE = 0
MODE_PACKED = 1


def rle_encode(values: np.ndarray) -> bytes:
    """RLE of small non-negative ints: (uint32 count, uint8 value) pairs."""
    values = np.ascontiguousarray(values, dtype=np.uint8)
    n = len(values)
    if n == 0:
        return struct.pack("<I", 0)
    boundaries = np.flatnonzero(values[1:] != values[:-1]) + 1
    starts = np.concatenate([[0], boundaries])
    ends = np.concatenate([boundaries, [n]])
    counts = (ends - starts).astype(np.uint32)
    run_values = values[starts]
    out = struct.pack("<I", len(counts))
    interleaved = np.empty(len(counts), dtype=[("c", "<u4"), ("v", "u1")])
    interleaved["c"] = counts
    interleaved["v"] = run_values
    return out + interleaved.tobytes()


def rle_decode(buf: bytes) -> np.ndarray:
    (n_runs,) = struct.unpack_from("<I", buf, 0)
    if n_runs == 0:
        return np.zeros(0, dtype=np.uint8)
    rec = np.frombuffer(buf, dtype=[("c", "<u4"), ("v", "u1")], count=n_runs, offset=4)
    return np.repeat(rec["v"], rec["c"].astype(np.int64))


def _bits_needed(values: np.ndarray) -> int:
    if len(values) == 0:
        return 1
    m = int(values.max())
    return max(1, m.bit_length())


def encode_levels(values: np.ndarray) -> bytes:
    """Level stream encoder: min(RLE, bit-packed) with a mode tag.

    Both encodings have exactly predictable sizes (RLE: 4 + 5*runs bytes;
    packed: 5 + ceil(width*n/8) bytes), so the winner is chosen analytically
    and only that encoding is materialized — the loser is never built.
    """
    values = np.ascontiguousarray(values, dtype=np.uint8)
    n = len(values)
    n_runs = 1 + int(np.count_nonzero(values[1:] != values[:-1])) if n else 0
    rle_size = 4 + 5 * n_runs
    width = _bits_needed(values)
    packed_size = 5 + (width * n + 7) // 8
    if rle_size <= packed_size:
        return bytes([MODE_RLE]) + rle_encode(values)
    words, total = pack_tokens(
        values.astype(np.uint64), np.full(n, width, dtype=np.int64)
    )
    return bytes([MODE_PACKED]) + struct.pack("<BI", width, n) + words_to_bytes(words, total)


def decode_levels(buf: bytes) -> np.ndarray:
    mode = buf[0]
    body = buf[1:]
    if mode == MODE_RLE:
        return rle_decode(body)
    width, count = struct.unpack_from("<BI", body, 0)
    words = bytes_to_words(body[5:])
    return unpack_fixed(words, 0, count, width).astype(np.uint8)

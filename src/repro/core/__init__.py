"""Spatial Parquet core: the paper's contribution as a composable library.

Public API::

    from repro.core import (
        Geometry, GeometryColumns, shred, assemble, from_ragged,
        fp_delta_encode, fp_delta_decode, compute_best_delta_bits,
        SpatialParquetWriter, SpatialParquetReader, SpatialIndex, write_file,
    )
"""

from .columnar import GeometryColumns, assemble, from_ragged, shred
from .filters import (
    And,
    In,
    IsNull,
    Predicate,
    Range,
    canonical_bbox,
    validate_predicate,
)
from .fp_delta import (
    FPDeltaStats,
    compute_best_delta_bits,
    delta_bit_histogram,
    fp_delta_decode,
    fp_delta_encode,
    fp_delta_encode_pages,
)
from .pages import CodecUnavailable, have_codec
from .geometry import (
    TYPE_EMPTY,
    TYPE_GEOMETRYCOLLECTION,
    TYPE_LINESTRING,
    TYPE_MULTILINESTRING,
    TYPE_MULTIPOINT,
    TYPE_MULTIPOLYGON,
    TYPE_POINT,
    TYPE_POLYGON,
    Geometry,
    bbox_intersects,
)
from .index import SpatialIndex
from .reader import ReadStats, SpatialParquetReader
from .sfc import hilbert_key, sort_keys, z_key
from .writer import SpatialParquetWriter, permute_records, record_centroids, write_file

__all__ = [
    "Geometry",
    "GeometryColumns",
    "shred",
    "assemble",
    "from_ragged",
    "fp_delta_encode",
    "fp_delta_decode",
    "fp_delta_encode_pages",
    "compute_best_delta_bits",
    "CodecUnavailable",
    "have_codec",
    "delta_bit_histogram",
    "FPDeltaStats",
    "SpatialParquetWriter",
    "SpatialParquetReader",
    "SpatialIndex",
    "ReadStats",
    "Predicate",
    "Range",
    "In",
    "IsNull",
    "And",
    "canonical_bbox",
    "validate_predicate",
    "write_file",
    "permute_records",
    "record_centroids",
    "sort_keys",
    "hilbert_key",
    "z_key",
    "bbox_intersects",
    "TYPE_EMPTY",
    "TYPE_POINT",
    "TYPE_LINESTRING",
    "TYPE_POLYGON",
    "TYPE_MULTIPOINT",
    "TYPE_MULTILINESTRING",
    "TYPE_MULTIPOLYGON",
    "TYPE_GEOMETRYCOLLECTION",
]

"""Vectorized arbitrary-width bit packing.

This is the host-side (numpy) bit plane used by the FP-delta codec
(:mod:`repro.core.fp_delta`). Values are packed LSB-first into a stream of
little-endian ``uint64`` words: a value written at bit offset ``o`` with width
``w`` occupies bits ``o .. o+w-1`` of the stream, where bit ``b`` of the stream
is bit ``b % 64`` of word ``b // 64``.

Everything here is fully vectorized — there are no per-value Python loops.
Writes use ``np.bitwise_or.at`` scatter (values may share words); reads use
gather + shift + mask.
"""

from __future__ import annotations

import numpy as np

_U64 = np.uint64
_ONE = _U64(1)
_FULL = _U64(0xFFFFFFFFFFFFFFFF)

# mask[w] = w low bits set; a table gather beats the branchy shift dance
_MASK_TABLE = np.array([(1 << w) - 1 for w in range(64)] + [(1 << 64) - 1],
                       dtype=_U64)


def width_mask(width):
    """All-ones mask of ``width`` bits (scalar or array; width==64 -> full)."""
    if isinstance(width, (int, np.integer)):
        return _MASK_TABLE[int(width)]
    return _MASK_TABLE[np.asarray(width, dtype=np.int64)]


def _scatter_or(words: np.ndarray, idx: np.ndarray, vals: np.ndarray) -> None:
    """``words[idx] |= vals`` for non-decreasing ``idx``.

    Equivalent to ``np.bitwise_or.at`` but ~5x faster: contributions are
    grouped per word with one ``reduceat`` (pack_tokens guarantees ascending
    word order, and all contributions to a word are bit-disjoint).
    """
    if not len(idx):
        return
    starts = np.concatenate([[0], np.flatnonzero(idx[1:] != idx[:-1]) + 1])
    words[idx[starts]] |= np.bitwise_or.reduceat(vals, starts)


def pack_tokens(values: np.ndarray, widths: np.ndarray) -> tuple[np.ndarray, int]:
    """Pack ``values[i]`` at width ``widths[i]`` bits, consecutively.

    Returns ``(words, total_bits)`` where ``words`` is a uint64 array with one
    trailing spill word so readers may always gather ``words[idx + 1]``.
    """
    values = np.ascontiguousarray(values, dtype=_U64)
    widths = np.ascontiguousarray(widths, dtype=np.int64)
    if values.shape != widths.shape or values.ndim != 1:
        raise ValueError("values/widths must be equal-length 1-D arrays")
    ends = np.cumsum(widths, dtype=np.int64)
    total_bits = int(ends[-1]) if len(ends) else 0
    starts = ends - widths
    nwords = (total_bits + 63) // 64 + 1  # +1 spill word
    words = np.zeros(nwords, dtype=_U64)
    if not len(values):
        return words, 0
    v = values & width_mask(widths)
    word_idx = (starts >> 6).astype(np.int64)
    shift = (starts & 63).astype(_U64)
    lo = v << shift
    # High spill: v >> (64 - shift); shift-by-64 is undefined, mask the case out.
    inv = (_U64(64) - shift) & _U64(63)
    hi = np.where(shift == _U64(0), _U64(0), v >> inv)
    _scatter_or(words, word_idx, lo)
    _scatter_or(words, word_idx + 1, hi)
    return words, total_bits


def unpack_at(words: np.ndarray, bit_offsets: np.ndarray, width: int) -> np.ndarray:
    """Gather ``width``-bit values at arbitrary bit offsets (vectorized).

    ``words`` must carry the trailing spill word produced by
    :func:`pack_tokens`/:func:`bytes_to_words` so ``words[idx + 1]`` is always
    in bounds. This is the primitive behind the FP-delta fixpoint decode,
    where escape markers shift later token offsets by a non-uniform amount.
    """
    offs = np.asarray(bit_offsets, dtype=np.int64)
    if offs.size == 0 or width == 0:
        return np.zeros(offs.shape, dtype=_U64)
    word_idx = (offs >> 6).astype(np.int64)
    shift = (offs & 63).astype(_U64)
    lo = words[word_idx] >> shift
    inv = (_U64(64) - shift) & _U64(63)
    hi = np.where(shift == _U64(0), _U64(0), words[word_idx + 1] << inv)
    return (lo | hi) & width_mask(width)


def unpack_fixed(words: np.ndarray, start_bit: int, count: int, width: int) -> np.ndarray:
    """Read ``count`` consecutive ``width``-bit values starting at ``start_bit``.

    ``words`` must have the trailing spill word produced by :func:`pack_tokens`
    (or :func:`bytes_to_words`).
    """
    if count <= 0:
        return np.zeros(0, dtype=_U64)
    if width == 0:
        return np.zeros(count, dtype=_U64)
    offs = start_bit + np.int64(width) * np.arange(count, dtype=np.int64)
    return unpack_at(words, offs, width)


def marker_candidates(words: np.ndarray, n: int) -> np.ndarray:
    """Bit positions where ``n`` consecutive set bits start (sorted).

    A log-shift AND ladder over the packed words: after each step ``r[i]``
    means "bits ``i .. i+span-1`` are all set", spans doubling until they
    cover ``n``. Runs longer than ``n`` yield one candidate per possible
    start. Used by the FP-delta escape resolver: a reset marker is ``n``
    consecutive ones at a token-aligned position, so the (rare) candidates
    are the only places an escape can hide — no per-value scan needed.
    """
    r = words
    span = 1
    while span < n:
        t = min(span, n - span)
        nxt = np.empty_like(r)
        nxt[:-1] = r[1:]
        nxt[-1] = 0
        r = r & ((r >> _U64(t)) | (nxt << _U64(64 - t)))
        span += t
    nzw = np.flatnonzero(r)
    if not len(nzw):
        return np.zeros(0, dtype=np.int64)
    bits = np.unpackbits(
        np.frombuffer(r[nzw].astype("<u8").tobytes(), dtype=np.uint8),
        bitorder="little",
    )
    hot = np.flatnonzero(bits)
    return nzw[hot >> 6] * 64 + (hot & 63)


def read_one(words: np.ndarray, start_bit: int, width: int) -> int:
    """Scalar read of a single value (header parsing)."""
    return int(unpack_fixed(words, start_bit, 1, width)[0])


def words_to_bytes(words: np.ndarray, total_bits: int) -> bytes:
    """Serialize the packed stream to the minimal little-endian byte string."""
    nbytes = (total_bits + 7) // 8
    return words.astype("<u8").tobytes()[:nbytes]


def bytes_to_words(buf) -> np.ndarray:
    """Parse a bytes-like buffer into a uint64 word array with a spill word.

    Accepts any contiguous buffer (``bytes``, ``bytearray``, ``memoryview``
    slices of a coalesced-I/O read) without materializing an intermediate
    padded byte string.
    """
    n = len(buf)
    body = n >> 3
    tail = n & 7
    words = np.zeros(body + (1 if tail else 0) + 1, dtype=_U64)  # +1 spill
    if body:
        words[:body] = np.frombuffer(buf, dtype="<u8", count=body)
    if tail:
        last = np.zeros(8, dtype=np.uint8)
        last[:tail] = np.frombuffer(buf, dtype=np.uint8, count=tail, offset=body << 3)
        words[body] = last.view("<u8")[0]
    return words

"""Vectorized arbitrary-width bit packing.

This is the host-side (numpy) bit plane used by the FP-delta codec
(:mod:`repro.core.fp_delta`). Values are packed LSB-first into a stream of
little-endian ``uint64`` words: a value written at bit offset ``o`` with width
``w`` occupies bits ``o .. o+w-1`` of the stream, where bit ``b`` of the stream
is bit ``b % 64`` of word ``b // 64``.

Everything here is fully vectorized — there are no per-value Python loops.
Writes use ``np.bitwise_or.at`` scatter (values may share words); reads use
gather + shift + mask.
"""

from __future__ import annotations

import numpy as np

_U64 = np.uint64
_ONE = _U64(1)
_FULL = _U64(0xFFFFFFFFFFFFFFFF)


def width_mask(width) -> np.ndarray:
    """All-ones mask of ``width`` bits (vectorized; width==64 -> full mask)."""
    w = np.asarray(width, dtype=_U64)
    # (1 << 64) is undefined; route width==64 through the full mask.
    shifted = np.where(w >= _U64(64), _FULL, (_ONE << (w % _U64(64))) - _ONE)
    return np.where(w == _U64(0), _U64(0), shifted)


def pack_tokens(values: np.ndarray, widths: np.ndarray) -> tuple[np.ndarray, int]:
    """Pack ``values[i]`` at width ``widths[i]`` bits, consecutively.

    Returns ``(words, total_bits)`` where ``words`` is a uint64 array with one
    trailing spill word so readers may always gather ``words[idx + 1]``.
    """
    values = np.ascontiguousarray(values, dtype=_U64)
    widths = np.ascontiguousarray(widths, dtype=np.int64)
    if values.shape != widths.shape or values.ndim != 1:
        raise ValueError("values/widths must be equal-length 1-D arrays")
    ends = np.cumsum(widths, dtype=np.int64)
    total_bits = int(ends[-1]) if len(ends) else 0
    starts = ends - widths
    nwords = (total_bits + 63) // 64 + 1  # +1 spill word
    words = np.zeros(nwords, dtype=_U64)
    if not len(values):
        return words, 0
    v = values & width_mask(widths)
    word_idx = (starts >> 6).astype(np.int64)
    shift = (starts & 63).astype(_U64)
    lo = v << shift
    # High spill: v >> (64 - shift); shift-by-64 is undefined, mask the case out.
    inv = (_U64(64) - shift) & _U64(63)
    hi = np.where(shift == _U64(0), _U64(0), v >> inv)
    np.bitwise_or.at(words, word_idx, lo)
    np.bitwise_or.at(words, word_idx + 1, hi)
    return words, total_bits


def unpack_fixed(words: np.ndarray, start_bit: int, count: int, width: int) -> np.ndarray:
    """Read ``count`` consecutive ``width``-bit values starting at ``start_bit``.

    ``words`` must have the trailing spill word produced by :func:`pack_tokens`
    (or :func:`pad_words`).
    """
    if count <= 0:
        return np.zeros(0, dtype=_U64)
    if width == 0:
        return np.zeros(count, dtype=_U64)
    offs = start_bit + np.int64(width) * np.arange(count, dtype=np.int64)
    word_idx = (offs >> 6).astype(np.int64)
    shift = (offs & 63).astype(_U64)
    lo = words[word_idx] >> shift
    inv = (_U64(64) - shift) & _U64(63)
    hi = np.where(shift == _U64(0), _U64(0), words[word_idx + 1] << inv)
    return (lo | hi) & width_mask(width)


def read_one(words: np.ndarray, start_bit: int, width: int) -> int:
    """Scalar read of a single value (header parsing)."""
    return int(unpack_fixed(words, start_bit, 1, width)[0])


def words_to_bytes(words: np.ndarray, total_bits: int) -> bytes:
    """Serialize the packed stream to the minimal little-endian byte string."""
    nbytes = (total_bits + 7) // 8
    return words.astype("<u8").tobytes()[:nbytes]


def bytes_to_words(buf: bytes) -> np.ndarray:
    """Parse a byte string back into a uint64 word array with a spill word."""
    pad = (-len(buf)) % 8
    padded = buf + b"\x00" * pad
    words = np.frombuffer(padded, dtype="<u8").astype(_U64)
    return np.concatenate([words, np.zeros(1, dtype=_U64)])

"""Spatial Parquet file writer.

File layout (Parquet-architecture-faithful; byte format is ours since no JVM
Parquet stack exists in-container — see DESIGN.md §10)::

    [magic "SPQF1\\0"]
    [row group 0: type | type_rep | rep | defn | x pages | y pages | extras]
    [row group 1: ...]
    [footer (msgpack)] [footer_nbytes: uint32 LE] [magic "SPQF1\\0"]

Row groups hold up to ``row_group_records`` records (paper: ~1M sort groups;
"we process the records into groups with a fixed number of records...
whenever we have that number of records, we sort them and write them").
Coordinate columns are split into record-aligned ~``page_values``-value pages,
each carrying [min,max] statistics — the light-weight spatial index (§4).

Format v2 (checksums, the default) differs only in integrity metadata: the
magic becomes ``SPQF2\\0``, every stored blob's footer entry gains a ``crc``
of its stored (post-compression) bytes, the footer records which
``checksum_algo`` produced them, and the footer blob itself is followed by a
4-byte CRC32C (``footer_nbytes`` counts blob + CRC)::

    [footer (msgpack)] [footer_crc32c: uint32 LE]
    [footer_nbytes: uint32 LE] [magic "SPQF2\\0"]

``checksums=False`` writes the v1 layout byte-for-byte (no ``crc`` keys, v1
magic); v1 files stay readable forever, just unverified.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

import msgpack
import numpy as np

from repro.io.checksum import checksum_fn, crc32c, default_algo

from .columnar import DeviceCoords, GeometryColumns, from_ragged, shred
from .pages import PageMeta, compress, encode_pages, plan_page_splits
from .rle import encode_levels, rle_encode
from .sfc import sort_keys

MAGIC = b"SPQF1\x00"
MAGIC_V2 = b"SPQF2\x00"
FORMAT_VERSION = 1       # pre-checksum layout (still written by checksums=False)
FORMAT_VERSION_V2 = 2    # per-blob + footer checksums
assert len(MAGIC) == len(MAGIC_V2)


# --------------------------------------------------------------------- ragged
def ragged_gather_indices(lengths: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Element indices that gather ragged segments in ``perm`` order."""
    lengths = np.asarray(lengths, dtype=np.int64)
    starts = np.cumsum(lengths) - lengths
    sel_len = lengths[perm]
    total = int(sel_len.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    out_starts = np.cumsum(sel_len) - sel_len
    idx = np.arange(total, dtype=np.int64)
    seg = np.repeat(np.arange(len(perm)), sel_len)
    return idx - out_starts[seg] + starts[perm[seg]]


def permute_records(cols: GeometryColumns, perm: np.ndarray) -> GeometryColumns:
    """Reorder (or subset) records of a GeometryColumns by record indices."""
    types, coords, part_sizes, parts_per_sub, subs_per_rec = cols.to_ragged()
    perm = np.asarray(perm, dtype=np.int64)
    # level 1: records -> sub-geometry indices
    sub_idx = ragged_gather_indices(subs_per_rec, perm)
    new_types = types[sub_idx]
    new_pps = parts_per_sub[sub_idx]
    new_spr = subs_per_rec[perm]
    # level 2: sub-geometries -> part indices
    part_idx = ragged_gather_indices(parts_per_sub, sub_idx)
    new_part_sizes = part_sizes[part_idx]
    # level 3: parts -> coordinate indices
    coord_idx = ragged_gather_indices(part_sizes, part_idx)
    new_coords = coords[coord_idx]
    return from_ragged(new_types, new_coords, new_part_sizes, new_pps, new_spr)


def concat_columns(cols_list: list[GeometryColumns]) -> GeometryColumns:
    """Concatenate geometry chunks; device-resident coordinate columns
    (:class:`DeviceCoords`) merge on the accelerator, never the host."""
    if len(cols_list) == 1:
        return cols_list[0]

    def cat_coords(parts):
        if any(isinstance(p, DeviceCoords) for p in parts):
            return DeviceCoords.concat([
                p if isinstance(p, DeviceCoords) else DeviceCoords.from_numpy(p)
                for p in parts
            ])
        return np.concatenate(parts)

    return GeometryColumns(
        np.concatenate([c.types for c in cols_list]),
        np.concatenate([c.type_rep for c in cols_list]),
        np.concatenate([c.rep for c in cols_list]),
        np.concatenate([c.defn for c in cols_list]),
        cat_coords([c.x for c in cols_list]),
        cat_coords([c.y for c in cols_list]),
    )


def record_centroids(cols: GeometryColumns) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized per-record bbox centers (empty records get (0,0))."""
    n_rec = cols.n_records
    starts = cols.record_value_starts()
    counts = np.diff(np.append(starts, cols.n_values))
    cx = np.zeros(n_rec, dtype=np.float64)
    cy = np.zeros(n_rec, dtype=np.float64)
    nz = counts > 0
    if nz.any():
        s = starts[nz]
        x = cols.x.astype(np.float64, copy=False)
        y = cols.y.astype(np.float64, copy=False)
        cx[nz] = (np.minimum.reduceat(x, s) + np.maximum.reduceat(x, s)) / 2.0
        cy[nz] = (np.minimum.reduceat(y, s) + np.maximum.reduceat(y, s)) / 2.0
        # reduceat's final segment runs to the end of the array, which is what
        # we want for the last nonempty record; interior empty records were
        # masked out so every reduceat segment spans exactly one record...
        # ...except when an empty record sits between two nonempty ones: the
        # segment of the record before it still ends at the next *nonempty*
        # start because empty records own zero values. Correct by construction.
    return cx, cy


@dataclass
class _PendingGroup:
    cols_list: list
    extras: dict[str, list]
    n_records: int = 0


class SpatialParquetWriter:
    """Streaming writer with bounded-memory SFC sorting (paper §4)."""

    def __init__(
        self,
        path,
        *,
        encoding: str = "fp_delta",
        codec: str = "none",
        page_values: int = 131072,
        row_group_records: int = 1 << 20,
        sort: str | None = None,  # None | 'z' | 'hilbert'
        sfc_order: int = 16,
        extra_schema: dict[str, str] | None = None,  # name -> numpy dtype str
        checksums: bool = True,
        checksum_algo: str | None = None,  # None -> fastest available
    ):
        self.path = str(path)
        self.encoding = encoding
        self.codec = codec
        self.page_values = int(page_values)
        self.row_group_records = int(row_group_records)
        self.sort = sort
        self.sfc_order = int(sfc_order)
        self.extra_schema = dict(extra_schema or {})
        self.checksums = bool(checksums)
        self.checksum_algo = (
            (checksum_algo or default_algo()) if self.checksums else None
        )
        # resolve the algo now so an unknown name fails before any bytes land
        self._crc = checksum_fn(self.checksum_algo) if self.checksums else None
        self._fh = open(self.path, "wb")
        self._fh.write(MAGIC_V2 if self.checksums else MAGIC)
        self._offset = len(MAGIC)
        self._pending = _PendingGroup([], {k: [] for k in self.extra_schema})
        self._row_groups: list[dict] = []
        self._coord_dtype: str | None = None
        self._closed = False

    # ------------------------------------------------------------------- API
    def write_geometries(self, geometries, extra: dict | None = None) -> None:
        self.write_columns(shred(geometries), extra)

    def write_columns(self, cols: GeometryColumns, extra: dict | None = None) -> None:
        dt = np.dtype(cols.x.dtype).str
        if self._coord_dtype is None:
            self._coord_dtype = dt
        elif self._coord_dtype != dt:
            raise ValueError("mixed coordinate dtypes in one file")
        extra = extra or {}
        if set(extra) != set(self.extra_schema):
            raise ValueError(f"extra columns {set(extra)} != schema {set(self.extra_schema)}")
        for k, v in extra.items():
            v = np.ascontiguousarray(v, dtype=np.dtype(self.extra_schema[k]))
            if len(v) != cols.n_records:
                raise ValueError(f"extra column {k!r} length mismatch")
            self._pending.extras[k].append(v)
        self._pending.cols_list.append(cols)
        self._pending.n_records += cols.n_records
        while self._pending.n_records >= self.row_group_records:
            self._flush_group(self.row_group_records)

    def close(self) -> dict:
        if self._closed:
            return self._footer
        if self._pending.n_records:
            self._flush_group(self._pending.n_records)
        footer = {
            "version": FORMAT_VERSION_V2 if self.checksums else FORMAT_VERSION,
            "coord_dtype": self._coord_dtype or "<f8",
            "encoding": self.encoding,
            "codec": self.codec,
            "sort": self.sort,
            "n_records": int(sum(g["n_records"] for g in self._row_groups)),
            "extra_schema": self.extra_schema,
            "row_groups": self._row_groups,
        }
        if self.checksums:
            footer["checksum_algo"] = self.checksum_algo
        blob = msgpack.packb(footer, use_bin_type=True)
        if self.checksums:
            # the footer checksum is always CRC32C (the algo tag lives inside
            # the footer, so it cannot govern its own verification)
            blob += struct.pack("<I", crc32c(blob))
        self._fh.write(blob)
        self._fh.write(struct.pack("<I", len(blob)))
        self._fh.write(MAGIC_V2 if self.checksums else MAGIC)
        self._fh.close()
        self._footer = footer
        self._closed = True
        return footer

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -------------------------------------------------------------- internals
    def _take_records(self, n: int) -> tuple[GeometryColumns, dict[str, np.ndarray]]:
        """Pop exactly n records (and matching extras) from the pending buffer."""
        cols = concat_columns(self._pending.cols_list)
        extras = {
            k: (np.concatenate(v) if v else np.zeros(0, dtype=self.extra_schema[k]))
            for k, v in self._pending.extras.items()
        }
        total = cols.n_records
        if n < total:
            head = cols.slice_records(0, n)
            tail = cols.slice_records(n, total)
            self._pending = _PendingGroup(
                [tail], {k: [v[n:]] for k, v in extras.items()}, total - n
            )
            extras = {k: v[:n] for k, v in extras.items()}
            cols = head
        else:
            self._pending = _PendingGroup([], {k: [] for k in self.extra_schema})
        return cols, extras

    def _flush_group(self, n: int) -> None:
        cols, extras = self._take_records(n)
        if self.sort is not None and cols.n_records > 1:
            cx, cy = record_centroids(cols)
            keys = sort_keys(cx, cy, self.sort, self.sfc_order)
            perm = np.argsort(keys, kind="stable")
            cols = permute_records(cols, perm)
            extras = {k: v[perm] for k, v in extras.items()}
        self._write_row_group(cols, extras)

    def _write_blob(self, buf: bytes) -> tuple[int, int, int | None]:
        off = self._offset
        self._fh.write(buf)
        self._offset += len(buf)
        crc = self._crc(buf) if self._crc is not None else None
        return off, len(buf), crc

    def _write_row_group(self, cols: GeometryColumns, extras: dict) -> None:
        rg: dict = {"n_records": cols.n_records, "n_values": cols.n_values}
        # small columns: type (RLE, paper §3.1) + level streams
        for name, buf in (
            ("type", rle_encode(cols.types)),
            ("type_rep", encode_levels(cols.type_rep)),
            ("rep", encode_levels(cols.rep)),
            ("defn", encode_levels(cols.defn)),
        ):
            comp = compress(buf, self.codec)
            off, nb, crc = self._write_blob(comp)
            rg[name] = {"offset": off, "nbytes": nb, "raw_nbytes": len(buf)}
            if crc is not None:
                rg[name]["crc"] = crc
        # coordinate pages (x and y share record-aligned boundaries => bbox/page)
        # batch-encoded: one delta/zigzag/bit-count pass per axis feeds every
        # page's n* optimizer and token emitter (see fp_delta_encode_pages)
        starts = cols.record_value_starts()
        splits = plan_page_splits(starts, cols.n_values, self.page_values)
        bounds = np.append(starts, cols.n_values)
        vbounds = [(int(bounds[r0]), int(bounds[r1])) for r0, r1 in splits]
        for axis, values in (("x", cols.x), ("y", cols.y)):
            pages = []
            encoded = encode_pages(values, vbounds, self.encoding, self.codec)
            for (buf, st), (r0, r1), (v0, v1) in zip(encoded, splits, vbounds):
                chunk = values[v0:v1]
                off, nb, crc = self._write_blob(buf)
                pages.append(
                    PageMeta(
                        offset=off, nbytes=nb, count=v1 - v0,
                        rec_start=r0, rec_count=r1 - r0,
                        vmin=float(chunk.min()) if len(chunk) else float("inf"),
                        vmax=float(chunk.max()) if len(chunk) else float("-inf"),
                        encoding=self.encoding,
                        n_bits=st["n_bits"], n_resets=st["n_resets"],
                        crc=crc,
                    ).to_dict()
                )
            rg[f"{axis}_pages"] = pages
        # extra per-record columns, page-aligned with the coordinate pages.
        # Numeric columns get NaN-safe per-page zone stats (vmin/vmax over
        # non-NaN values + NaN count) in one batched pass per column — the
        # float32 path reduces on-device through page_minmax — plus a
        # per-row-group aggregate under rg["extra_stats"] that the catalog
        # rolls into the shard's persisted zone map.
        rg["extra"] = {}
        rg["extra_stats"] = {}
        ebounds = np.array([r0 for r0, _ in splits] + [cols.n_records], np.int64)
        for k, v in extras.items():
            pages = []
            enc = self.encoding if v.dtype.itemsize in (4, 8) else "raw"
            numeric = v.dtype.kind in "iuf"
            if numeric and len(splits):
                from repro.kernels.minmax import column_page_stats_ex

                pmin, pmax, pnan = column_page_stats_ex(v, ebounds)
            else:
                pmin = np.full(len(splits), np.inf)
                pmax = np.full(len(splits), -np.inf)
                pnan = np.zeros(len(splits), np.int64)
            encoded = encode_pages(v, [(r0, r1) for r0, r1 in splits], enc, self.codec)
            for p_i, ((buf, st), (r0, r1)) in enumerate(zip(encoded, splits)):
                off, nb, crc = self._write_blob(buf)
                pages.append(
                    PageMeta(
                        offset=off, nbytes=nb, count=r1 - r0,
                        rec_start=r0, rec_count=r1 - r0,
                        vmin=float(pmin[p_i]), vmax=float(pmax[p_i]),
                        encoding=enc, n_bits=st["n_bits"], n_resets=st["n_resets"],
                        crc=crc, nnan=int(pnan[p_i]) if numeric else None,
                    ).to_dict()
                )
            rg["extra"][k] = pages
            if numeric:
                counts = np.diff(ebounds)
                live = counts > pnan  # pages with at least one non-NaN value
                rg["extra_stats"][k] = {
                    "min": float(pmin[live].min()) if live.any() else None,
                    "max": float(pmax[live].max()) if live.any() else None,
                    "nnan": int(pnan.sum()),
                    "count": int(cols.n_records),
                }
        self._row_groups.append(rg)


def write_file(path, geometries=None, columns=None, extra=None, **kwargs) -> dict:
    """One-shot convenience writer; returns the footer."""
    with SpatialParquetWriter(path, **kwargs) as w:
        if geometries is not None:
            w.write_geometries(geometries, extra)
        if columns is not None:
            w.write_columns(columns, extra)
    return w.close()

"""FP-delta: lossless delta encoding for floating-point coordinates.

Paper-exact implementation of Spatial Parquet §3 (Algorithms 1, 2 and 3):

1. Reinterpret each IEEE-754 value as a two's-complement integer
   (``cast-long``); delta consecutive values with wrapping arithmetic.
2. Zigzag-encode: ``(delta >> W-1) ^ (delta << 1)`` (arithmetic shift).
3. Choose the storage-optimal delta width ``n*`` from the exact cost model
   ``S(n) = n * (|X|-1) + W * sum_{i>n} h[i]`` over the histogram ``h`` of
   significant-bit counts (Algorithm 3, suffix sums).
4. Emit: 8-bit header ``n*``, the first value raw (W bits), then per delta
   either its zigzag in ``n*`` bits, or the all-ones *reset marker* followed by
   the raw W-bit value when the zigzag does not fit (or collides with the
   marker).

``n* == 0`` signals raw mode (the paper's "skip the algorithm altogether" path
when the computed saving is nil): every value is stored raw at W bits.

The codec is width-parametric: ``W=64`` covers float64/int64 (the paper's
default), ``W=32`` covers float32/int32 (paper footnote 1; also the variant our
TPU Pallas kernels implement, and the one used for checkpoint compression).
All hot paths are vectorized numpy; decode is vectorized per reset segment
with galloping chunk reads (sparse-escape streams — the only kind the n*
optimizer emits — decode in O(n) with a handful of gathers).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .bitstream import (
    bytes_to_words,
    pack_tokens,
    read_one,
    unpack_fixed,
    words_to_bytes,
)

_SIGNED = {32: np.int32, 64: np.int64}
_UNSIGNED = {32: np.uint32, 64: np.uint64}

HEADER_BITS = 8


def _as_int_bits(x: np.ndarray) -> tuple[np.ndarray, int]:
    """View the input as signed two's-complement ints; return (ints, W)."""
    x = np.ascontiguousarray(x)
    if x.dtype in (np.float64, np.int64, np.uint64):
        return x.view(np.int64), 64
    if x.dtype in (np.float32, np.int32, np.uint32):
        return x.view(np.int32), 32
    raise TypeError(f"fp_delta supports 32/64-bit element types, got {x.dtype}")


def zigzag(delta: np.ndarray, width: int) -> np.ndarray:
    """Zigzag-encode signed deltas to unsigned (paper Alg. 1 line 9)."""
    s = _SIGNED[width]
    d = delta.astype(s, copy=False)
    return ((d >> s(width - 1)) ^ (d << s(1))).view(_UNSIGNED[width])


def unzigzag(z: np.ndarray, width: int) -> np.ndarray:
    """Inverse zigzag (paper Alg. 2 line 9): (z >>> 1) ^ -(z & 1)."""
    u = _UNSIGNED[width]
    z = z.astype(u, copy=False)
    neg = u(0) - (z & u(1))  # wraps to all-ones when LSB set
    return ((z >> u(1)) ^ neg).view(_SIGNED[width])


def significant_bits(z: np.ndarray, width: int) -> np.ndarray:
    """Number of significant bits of each unsigned value (0 for value 0)."""
    z64 = np.asarray(z).astype(np.uint64, copy=False)
    out = np.zeros(z64.shape, dtype=np.int64)
    nz = z64 != 0
    v = z64.copy()
    for shift in (32, 16, 8, 4, 2, 1):  # bit-halving ladder (exact, no float)
        big = v >= (np.uint64(1) << np.uint64(shift))
        out += np.where(big, shift, 0)
        v = np.where(big, v >> np.uint64(shift), v)
    out += nz.astype(np.int64)  # the leading 1 itself
    return out


def _zigzag_deltas(x: np.ndarray) -> tuple[np.ndarray, int]:
    xi, width = _as_int_bits(x)
    delta = xi[1:] - xi[:-1]  # wrapping two's-complement subtraction
    return zigzag(delta, width), width


def delta_bit_histogram(x: np.ndarray) -> np.ndarray:
    """Histogram h[n] = #deltas needing exactly n significant bits (Fig 8)."""
    xi, width = _as_int_bits(x)
    if len(xi) < 2:
        return np.zeros(width + 1, dtype=np.int64)
    z, width = _zigzag_deltas(x)
    nbits = significant_bits(z, width)
    return np.bincount(nbits, minlength=width + 1).astype(np.int64)


def compute_best_delta_bits(x: np.ndarray) -> int:
    """Paper Algorithm 3: exact argmin_n S(n) via suffix-summed histogram."""
    xi, width = _as_int_bits(x)
    n_deltas = len(xi) - 1
    if n_deltas <= 0:
        return 0
    h = delta_bit_histogram(x)
    suffix = np.cumsum(h[::-1])[::-1]  # suffix[n] = #deltas needing >= n bits
    s_all = np.arange(width + 1, dtype=np.int64) * n_deltas
    s_all[:-1] += width * suffix[1:]
    s_all[0] = width * n_deltas  # n=0 == raw mode: every value raw
    n_star = int(np.argmin(s_all[:width]))  # n in [0, width)
    return n_star


@dataclass(frozen=True)
class FPDeltaStats:
    """Encoder-side accounting (feeds benchmarks and page metadata)."""

    n_values: int
    n_bits: int          # chosen n*
    n_resets: int        # deltas escaped via reset marker
    payload_bits: int    # total encoded bits incl. header


def fp_delta_encode(x: np.ndarray, n_bits: int | None = None) -> tuple[bytes, FPDeltaStats]:
    """Encode a 1-D array of 32/64-bit values. Returns (payload, stats)."""
    xi, width = _as_int_bits(x)
    u = _UNSIGNED[width]
    n_values = len(xi)
    if n_values == 0:
        return b"", FPDeltaStats(0, 0, 0, 0)

    n = compute_best_delta_bits(x) if n_bits is None else int(n_bits)
    if not (0 <= n < width):
        raise ValueError(f"n_bits must be in [0, {width}), got {n}")

    raw_bits = xi.view(u).astype(np.uint64)

    if n == 0 or n_values == 1:
        # Raw mode: header n=0, then every value raw at W bits.
        vals = np.concatenate([[np.uint64(0)], raw_bits])
        widths = np.concatenate([[HEADER_BITS], np.full(n_values, width, np.int64)])
        words, total = pack_tokens(vals, widths)
        return words_to_bytes(words, total), FPDeltaStats(n_values, 0, 0, total)

    delta = xi[1:] - xi[:-1]
    z = zigzag(delta, width).astype(np.uint64)
    marker = np.uint64((1 << n) - 1)
    overflow = z >= marker  # any significant bit above n-1, or == marker

    n_deltas = n_values - 1
    n_over = int(overflow.sum())
    n_tokens = 2 + n_deltas + n_over  # header, first value, deltas (+escapes)
    vals = np.empty(n_tokens, dtype=np.uint64)
    widths = np.empty(n_tokens, dtype=np.int64)
    vals[0], widths[0] = np.uint64(n), HEADER_BITS
    vals[1], widths[1] = raw_bits[0], width
    # Position of each delta's first token: one extra slot per prior escape.
    pos = 2 + np.arange(n_deltas, dtype=np.int64) + np.cumsum(overflow) - overflow
    vals[pos] = np.where(overflow, marker, z)
    widths[pos] = n
    if n_over:
        esc = pos[overflow] + 1
        vals[esc] = raw_bits[1:][overflow]
        widths[esc] = width
    words, total = pack_tokens(vals, widths)
    return words_to_bytes(words, total), FPDeltaStats(n_values, n, n_over, total)


def _to_signed_scalar(base: np.uint64, width: int):
    return np.uint64(base).astype(_UNSIGNED[width]).view(_SIGNED[width])


def fp_delta_decode(payload: bytes, n_values: int, dtype) -> np.ndarray:
    """Decode ``n_values`` elements of ``dtype`` (paper Algorithm 2)."""
    dtype = np.dtype(dtype)
    width = dtype.itemsize * 8
    if width not in (32, 64):
        raise TypeError(f"unsupported dtype {dtype}")
    s, u = _SIGNED[width], _UNSIGNED[width]
    if n_values == 0:
        return np.zeros(0, dtype=dtype)

    words = bytes_to_words(payload)
    n = read_one(words, 0, HEADER_BITS)
    cursor = HEADER_BITS

    if n == 0:
        raws = unpack_fixed(words, cursor, n_values, width)
        return raws.astype(np.uint64).astype(u).view(dtype)

    marker = np.uint64((1 << n) - 1)
    first = np.uint64(read_one(words, cursor, width))
    cursor += width

    # segments: list of (base raw bits, [delta-run chunks]).
    segments: list[tuple[np.uint64, list[np.ndarray]]] = [(first, [])]
    produced = 1
    gallop = 4096
    while produced < n_values:
        remaining = n_values - produced
        chunk = unpack_fixed(words, cursor, min(remaining, gallop), n)
        hits = np.flatnonzero(chunk == marker)
        if len(hits):
            take = int(hits[0])
            # adapt to the observed segment length (marker-dense streams)
            gallop = min(max(2 * max(take, 32), 64), 1 << 22)
        else:
            take = len(chunk)
            gallop = min(gallop * 2, 1 << 22)
        if take:
            segments[-1][1].append(chunk[:take])
            produced += take
            cursor += take * n
        if len(hits) and produced < n_values:
            cursor += n  # consume the marker
            base = np.uint64(read_one(words, cursor, width))
            cursor += width
            segments.append((base, []))
            produced += 1

    out = np.empty(n_values, dtype=s)
    pos = 0
    for base, run_chunks in segments:
        base_signed = _to_signed_scalar(base, width)
        out[pos] = base_signed
        k = 0
        if run_chunks:
            run = run_chunks[0] if len(run_chunks) == 1 else np.concatenate(run_chunks)
            k = len(run)
            deltas = unzigzag(run.astype(np.uint64).astype(u), width)
            out[pos + 1 : pos + 1 + k] = base_signed + np.cumsum(deltas, dtype=s)
        pos += 1 + k
    return out.view(dtype)


def encoded_size_bits(x: np.ndarray, n: int) -> int:
    """Exact S(n) for diagnostics (Equation 2 plus header/first-value cost)."""
    xi, width = _as_int_bits(x)
    if len(xi) < 2:
        return HEADER_BITS + width * len(xi)
    if n == 0:
        return HEADER_BITS + width * len(xi)
    h = delta_bit_histogram(x)
    suffix = np.cumsum(h[::-1])[::-1]
    over = int(suffix[n + 1]) if n + 1 <= width else 0
    return HEADER_BITS + width + n * (len(xi) - 1) + width * over

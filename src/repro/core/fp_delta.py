"""FP-delta: lossless delta encoding for floating-point coordinates.

Paper-exact implementation of Spatial Parquet §3 (Algorithms 1, 2 and 3):

1. Reinterpret each IEEE-754 value as a two's-complement integer
   (``cast-long``); delta consecutive values with wrapping arithmetic.
2. Zigzag-encode: ``(delta >> W-1) ^ (delta << 1)`` (arithmetic shift).
3. Choose the storage-optimal delta width ``n*`` from the exact cost model
   ``S(n) = n * (|X|-1) + W * sum_{i>n} h[i]`` over the histogram ``h`` of
   significant-bit counts (Algorithm 3, suffix sums).
4. Emit: 8-bit header ``n*``, the first value raw (W bits), then per delta
   either its zigzag in ``n*`` bits, or the all-ones *reset marker* followed by
   the raw W-bit value when the zigzag does not fit (or collides with the
   marker).

``n* == 0`` signals raw mode (the paper's "skip the algorithm altogether" path
when the computed saving is nil): every value is stored raw at W bits.

The codec is width-parametric: ``W=64`` covers float64/int64 (the paper's
default), ``W=32`` covers float32/int32 (paper footnote 1; also the variant our
TPU Pallas kernels implement, and the one used for checkpoint compression).

Hot-path structure (this module is the decode-CPU bottleneck of the whole
read path, so every stage is one numpy pass):

* **Encode** computes the zigzag deltas and the significant-bit histogram
  exactly once and shares them between the ``n*`` optimizer and the token
  emitter (:func:`fp_delta_encode`); :func:`fp_delta_encode_pages`
  batch-encodes every page of a column from a single column-wide delta pass.
* **Decode** (:func:`fp_delta_decode`) has no per-segment Python loop; work
  never scales with the value count outside whole-array vector ops. The
  exact escape count is recovered from the payload length (W >= 32 > 7 bits
  of byte padding, so the division is exact), then marker positions are
  resolved one of two ways. Sparse streams (a handful of escapes) use a
  vectorized fixpoint: token offsets are guessed assuming no escapes,
  markers found, offsets re-derived from the escape cumsum, repeated until
  stable (typically <= 2 rounds; a stable assignment is necessarily the
  unique correct one — token 0's offset is known, and by induction every
  later offset is determined by the flags before it). Denser streams use the
  candidate scan: one log-shift AND ladder over the packed words finds every
  position where ``n`` consecutive ones start (``marker_candidates``), and a
  short walk over those candidates — O(#escapes), not O(#values) — pins the
  token-aligned ones as the true markers. Either way, reconstruction is ONE
  segmented cumsum over all reset segments at once: cumsum the inline deltas
  with escapes zeroed, then add a per-segment correction (raw value minus
  the running sum at the escape) spread with ``np.repeat``.
* ``out=`` lets callers (the coalesced reader) decode straight into a slice
  of a preallocated coordinate array, eliminating list-append +
  ``np.concatenate`` from the read path.
* **Decode is split into plan + execute.** :func:`fp_delta_plan` performs
  the only inherently sequential part of Algorithm 2 — header parsing and
  escape resolution, i.e. locating every token once reset markers shift
  later offsets — and returns an :class:`FPDeltaPlan` holding the packed
  words plus the resolved ``(offsets, flags)``. :func:`fp_delta_execute`
  finishes on the host (gather, un-zigzag, segmented cumsum);
  ``repro.kernels.fp_delta`` consumes the very same plans to run that
  second half on the accelerator (Pallas page-stream decode), so the two
  back ends can never disagree about the format. :func:`fp_delta_decode`
  is plan + host execute and stays the oracle.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .bitstream import (
    bytes_to_words,
    marker_candidates,
    pack_tokens,
    read_one,
    unpack_at,
    unpack_fixed,
    words_to_bytes,
)

_SIGNED = {32: np.int32, 64: np.int64}
_UNSIGNED = {32: np.uint32, 64: np.uint64}

HEADER_BITS = 8

_FIXPOINT_MAX_ROUNDS = 10
# sparse/dense resolver switch: the fixpoint needs ~E+1 rounds, so beyond a
# handful of escapes the candidate-scan resolver is strictly better
_FIXPOINT_MAX_ESCAPES = 4


def _as_int_bits(x: np.ndarray) -> tuple[np.ndarray, int]:
    """View the input as signed two's-complement ints; return (ints, W)."""
    x = np.ascontiguousarray(x)
    if x.dtype in (np.float64, np.int64, np.uint64):
        return x.view(np.int64), 64
    if x.dtype in (np.float32, np.int32, np.uint32):
        return x.view(np.int32), 32
    raise TypeError(f"fp_delta supports 32/64-bit element types, got {x.dtype}")


def zigzag(delta: np.ndarray, width: int) -> np.ndarray:
    """Zigzag-encode signed deltas to unsigned (paper Alg. 1 line 9)."""
    s = _SIGNED[width]
    d = delta.astype(s, copy=False)
    return ((d >> s(width - 1)) ^ (d << s(1))).view(_UNSIGNED[width])


def unzigzag(z: np.ndarray, width: int) -> np.ndarray:
    """Inverse zigzag (paper Alg. 2 line 9): (z >>> 1) ^ -(z & 1)."""
    u = _UNSIGNED[width]
    z = z.astype(u, copy=False)
    neg = u(0) - (z & u(1))  # wraps to all-ones when LSB set
    return ((z >> u(1)) ^ neg).view(_SIGNED[width])


def significant_bits(z: np.ndarray, width: int) -> np.ndarray:
    """Number of significant bits of each unsigned value (0 for value 0).

    One pass via the float64 exponent field, with an exact fix-up for the
    one case float rounding can overshoot (values just below a power of
    two round up, inflating the exponent by one).
    """
    z64 = np.asarray(z).astype(np.uint64, copy=False)
    f = z64.astype(np.float64)
    e = ((f.view(np.uint64) >> np.uint64(52)) & np.uint64(0x7FF)).astype(np.int64)
    e -= 1022  # unbias: e = #bits of the rounded float (f in [2^(e-1), 2^e))
    es = np.clip(e - 1, 0, 63).astype(np.uint64)
    over = (z64 >> es) == 0  # z < 2^(e-1): rounding overshot, e is one high
    sig = np.minimum(np.where(over, e - 1, e), 64)
    return np.where(z64 == 0, 0, sig)


def _zigzag_deltas(x: np.ndarray) -> tuple[np.ndarray, int]:
    xi, width = _as_int_bits(x)
    delta = xi[1:] - xi[:-1]  # wrapping two's-complement subtraction
    return zigzag(delta, width), width


def delta_bit_histogram(x: np.ndarray) -> np.ndarray:
    """Histogram h[n] = #deltas needing exactly n significant bits (Fig 8)."""
    xi, width = _as_int_bits(x)
    if len(xi) < 2:
        return np.zeros(width + 1, dtype=np.int64)
    z, width = _zigzag_deltas(x)
    nbits = significant_bits(z, width)
    return np.bincount(nbits, minlength=width + 1).astype(np.int64)


def best_bits_from_histogram(h: np.ndarray, n_deltas: int, width: int) -> int:
    """Paper Algorithm 3 from a precomputed histogram: exact argmin_n S(n)."""
    if n_deltas <= 0:
        return 0
    suffix = np.cumsum(h[::-1])[::-1]  # suffix[n] = #deltas needing >= n bits
    s_all = np.arange(width + 1, dtype=np.int64) * n_deltas
    s_all[:-1] += width * suffix[1:]
    s_all[0] = width * n_deltas  # n=0 == raw mode: every value raw
    return int(np.argmin(s_all[:width]))  # n in [0, width)


def compute_best_delta_bits(x: np.ndarray) -> int:
    """Paper Algorithm 3: exact argmin_n S(n) via suffix-summed histogram."""
    xi, width = _as_int_bits(x)
    n_deltas = len(xi) - 1
    if n_deltas <= 0:
        return 0
    return best_bits_from_histogram(delta_bit_histogram(x), n_deltas, width)


@dataclass(frozen=True)
class FPDeltaStats:
    """Encoder-side accounting (feeds benchmarks and page metadata)."""

    n_values: int
    n_bits: int          # chosen n*
    n_resets: int        # deltas escaped via reset marker
    payload_bits: int    # total encoded bits incl. header


def _encode_tokens(
    raw_bits: np.ndarray, z: np.ndarray, width: int, n: int
) -> tuple[bytes, FPDeltaStats]:
    """Emit the token stream for one page from precomputed zigzag deltas.

    ``raw_bits``: every value's W-bit pattern as uint64; ``z``: the page's
    zigzag deltas as uint64 (``len(z) == len(raw_bits) - 1``).
    """
    n_values = len(raw_bits)
    if n_values == 0:
        return b"", FPDeltaStats(0, 0, 0, 0)

    if n == 0 or n_values == 1:
        # Raw mode: header n=0, then every value raw at W bits.
        vals = np.concatenate([[np.uint64(0)], raw_bits])
        widths = np.concatenate([[HEADER_BITS], np.full(n_values, width, np.int64)])
        words, total = pack_tokens(vals, widths)
        return words_to_bytes(words, total), FPDeltaStats(n_values, 0, 0, total)

    marker = np.uint64((1 << n) - 1)
    overflow = z >= marker  # any significant bit above n-1, or == marker

    n_deltas = n_values - 1
    n_over = int(overflow.sum())
    n_tokens = 2 + n_deltas + n_over  # header, first value, deltas (+escapes)
    vals = np.empty(n_tokens, dtype=np.uint64)
    widths = np.empty(n_tokens, dtype=np.int64)
    vals[0], widths[0] = np.uint64(n), HEADER_BITS
    vals[1], widths[1] = raw_bits[0], width
    # Position of each delta's first token: one extra slot per prior escape.
    pos = 2 + np.arange(n_deltas, dtype=np.int64) + np.cumsum(overflow) - overflow
    vals[pos] = np.where(overflow, marker, z)
    widths[pos] = n
    if n_over:
        esc = pos[overflow] + 1
        vals[esc] = raw_bits[1:][overflow]
        widths[esc] = width
    words, total = pack_tokens(vals, widths)
    return words_to_bytes(words, total), FPDeltaStats(n_values, n, n_over, total)


def fp_delta_encode(x: np.ndarray, n_bits: int | None = None) -> tuple[bytes, FPDeltaStats]:
    """Encode a 1-D array of 32/64-bit values. Returns (payload, stats).

    One-pass: the zigzag deltas are computed once and shared between the
    ``n*`` optimizer (Algorithm 3) and the token emitter. The default path is
    the single-page case of :func:`fp_delta_encode_pages` so the two can
    never diverge.
    """
    xi, width = _as_int_bits(x)
    if n_bits is None:
        return fp_delta_encode_pages(xi, [(0, len(xi))])[0]

    n = int(n_bits)
    if not (0 <= n < width):
        raise ValueError(f"n_bits must be in [0, {width}), got {n}")
    n_values = len(xi)
    if n_values == 0:
        return b"", FPDeltaStats(0, 0, 0, 0)
    raw_bits = xi.view(_UNSIGNED[width]).astype(np.uint64)
    if n_values >= 2:
        z = zigzag(xi[1:] - xi[:-1], width).astype(np.uint64)
    else:
        z = np.zeros(0, dtype=np.uint64)
    return _encode_tokens(raw_bits, z, width, n)


def fp_delta_encode_pages(
    x: np.ndarray, bounds: list[tuple[int, int]]
) -> list[tuple[bytes, FPDeltaStats]]:
    """Batch-encode value ranges ``[v0, v1)`` of one column as independent pages.

    The column-wide zigzag deltas and significant-bit counts are computed in a
    single pass; each page then only pays for its own histogram (``bincount``
    over a slice) and token packing. Page ``[v0, v1)`` uses column deltas
    ``d[v0 : v1-1]`` — the cross-page delta at ``v1-1`` is never encoded, so
    the output is byte-identical to encoding each slice separately.
    """
    xi, width = _as_int_bits(x)
    u = _UNSIGNED[width]
    raw_bits = xi.view(u).astype(np.uint64)
    if len(xi) >= 2:
        z = zigzag(xi[1:] - xi[:-1], width).astype(np.uint64)
        nbits = significant_bits(z, width)
    else:
        z = np.zeros(0, dtype=np.uint64)
        nbits = np.zeros(0, dtype=np.int64)

    out = []
    for v0, v1 in bounds:
        cnt = v1 - v0
        if cnt <= 0:
            out.append((b"", FPDeltaStats(0, 0, 0, 0)))
            continue
        zp = z[v0 : v1 - 1]
        h = np.bincount(nbits[v0 : v1 - 1], minlength=width + 1).astype(np.int64)
        n = best_bits_from_histogram(h, cnt - 1, width)
        out.append(_encode_tokens(raw_bits[v0:v1], zp, width, n))
    return out


def _to_signed_scalar(base: np.uint64, width: int):
    return np.uint64(base).astype(_UNSIGNED[width]).view(_SIGNED[width])


def _resolve_escapes_fixpoint(
    words: np.ndarray, start_bit: int, n_deltas: int, n: int, width: int, n_escapes: int
) -> tuple[np.ndarray, np.ndarray] | None:
    """Vectorized fixpoint: find each delta token's bit offset and marker flag.

    Token ``j`` starts at ``start_bit + n*j + width*E_j`` where ``E_j`` is the
    number of escapes among deltas ``< j``. Guess ``E = 0``, unpack, flag
    markers, recompute ``E`` as the (clipped) exclusive cumsum, repeat until
    stable. A stable assignment is the unique correct one (token 0's offset
    is known; each later offset is determined by the flags before it). Each
    round locks in at least one more escape, so sparse streams converge in
    about ``n_escapes + 1`` rounds — typically <= 2. Returns
    ``(offsets, flags)`` or None when not converged (denser streams use
    :func:`_resolve_escapes_scan` instead).
    """
    marker = np.uint64((1 << n) - 1)
    idx = np.arange(n_deltas, dtype=np.int64) * np.int64(n) + np.int64(start_bit)
    esc_before = np.zeros(n_deltas, dtype=np.int64)
    w64 = np.int64(width)
    for _ in range(_FIXPOINT_MAX_ROUNDS):
        offs = idx + w64 * esc_before
        tok = unpack_at(words, offs, n)
        flags = tok == marker
        # clip keeps every offset inside the payload even mid-fixpoint
        new_esc = np.minimum(np.cumsum(flags) - flags, n_escapes)
        if np.array_equal(new_esc, esc_before):
            return offs, flags
        esc_before = new_esc
    return None


def _resolve_escapes_scan(
    words: np.ndarray, start_bit: int, n_deltas: int, n: int, width: int, n_escapes: int
) -> tuple[np.ndarray, np.ndarray]:
    """Escape resolution for any marker density, exact and O(#escapes).

    A reset marker is ``n`` consecutive set bits at a token-aligned offset.
    :func:`marker_candidates` finds every bit position where ``n`` ones start
    (one vectorized log-shift ladder over the packed words); an inline token
    can never equal the marker, so a *token-aligned* candidate inside the
    token region is always a real escape. The walk below consumes candidates
    left to right — skipping unaligned ones (run spill from neighbouring
    token/raw bits) — and jumps ``n + W`` bits past each confirmed marker.
    Work is proportional to escapes found plus stray candidates, never to
    the value count.
    """
    cands = marker_candidates(words, n)
    esc_tok = np.empty(n_escapes, dtype=np.int64)
    found = 0
    pos = start_bit  # bit offset of the current segment's first token
    j0 = 0           # token index of the current segment's first token
    for c in cands.tolist():
        if found == n_escapes:
            break
        if c < pos:
            continue
        d, r = divmod(c - pos, n)
        if r:
            continue  # candidate not token-aligned: spill from data bits
        j = j0 + d
        if j >= n_deltas:
            break
        esc_tok[found] = j
        found += 1
        pos = c + n + width  # skip the marker and its raw value
        j0 = j + 1
    flags = np.zeros(n_deltas, dtype=bool)
    flags[esc_tok[:found]] = True
    esc_before = np.cumsum(flags) - flags
    offs = (
        np.int64(start_bit)
        + np.int64(n) * np.arange(n_deltas, dtype=np.int64)
        + np.int64(width) * esc_before
    )
    return offs, flags


@dataclass(frozen=True)
class FPDeltaPlan:
    """Host-resolved decode plan for one page (the device-decode contract).

    The only inherently sequential part of Algorithm 2 — locating every token
    once reset markers shift later offsets — is resolved here on the host.
    What remains (fixed-width gather, escape injection, segmented cumsum,
    un-zigzag, float bitcast) is embarrassingly parallel; it is executed
    either by :func:`fp_delta_execute` (host numpy) or by the Pallas
    page-stream kernel in :mod:`repro.kernels.fp_delta`, which batches many
    plans into one launch.

    ``offsets[j]``/``flags[j]`` describe delta token ``j`` (``n_values - 1``
    entries): its absolute bit offset in ``words`` and whether it is the
    reset marker (the escaped raw W-bit value then sits at ``offsets[j] +
    n``). Raw mode (``n == 0``) has no delta tokens: every value is stored
    raw at ``width`` bits starting from bit ``HEADER_BITS``.
    """

    dtype: np.dtype
    width: int            # 32 or 64
    n: int                # token width n* (0 => raw mode)
    n_values: int
    first: int            # raw W-bit pattern of value 0 (0 when empty/raw)
    words: np.ndarray     # uint64 packed stream incl. trailing spill word
    offsets: np.ndarray   # (n_deltas,) int64 token bit offsets
    flags: np.ndarray     # (n_deltas,) bool: True where token is a marker
    n_escapes: int        # escape count recovered from the payload length


def _check_out(out: np.ndarray | None, n_values: int, dtype: np.dtype) -> None:
    if out is None:
        return
    if out.dtype != dtype or out.ndim != 1 or len(out) != n_values:
        raise ValueError("out must be a 1-D array of n_values elements of dtype")
    if not out.flags.c_contiguous:
        raise ValueError("out must be C-contiguous")


_EMPTY_OFFS = np.zeros(0, dtype=np.int64)
_EMPTY_FLAGS = np.zeros(0, dtype=bool)


def fp_delta_plan(payload, n_values: int, dtype) -> FPDeltaPlan:
    """Parse a payload's header and resolve every escape (Algorithm 2 front
    half). ``payload`` may be any bytes-like buffer (``bytes``,
    ``memoryview``)."""
    dtype = np.dtype(dtype)
    width = dtype.itemsize * 8
    if width not in (32, 64):
        raise TypeError(f"unsupported dtype {dtype}")
    if n_values == 0:
        return FPDeltaPlan(dtype, width, 0, 0, 0, np.zeros(1, np.uint64),
                           _EMPTY_OFFS, _EMPTY_FLAGS, 0)

    words = bytes_to_words(payload)
    n = read_one(words, 0, HEADER_BITS)
    cursor = HEADER_BITS
    if n == 0:  # raw mode: every value raw at W bits, no delta tokens
        return FPDeltaPlan(dtype, width, 0, n_values, 0, words,
                           _EMPTY_OFFS, _EMPTY_FLAGS, 0)

    first = read_one(words, cursor, width)
    cursor += width
    n_deltas = n_values - 1
    if n_deltas == 0:
        return FPDeltaPlan(dtype, width, n, n_values, first, words,
                           _EMPTY_OFFS, _EMPTY_FLAGS, 0)

    # Exact escape count from the payload length: total bits are
    # HEADER + W + n*D + W*E plus < 8 bits of byte padding, and W >= 32 > 7,
    # so the integer division is exact for well-formed payloads.
    n_escapes = (len(payload) * 8 - cursor - n * n_deltas) // width
    n_escapes = max(0, min(int(n_escapes), n_deltas))

    if n_escapes == 0:
        offs = cursor + np.int64(n) * np.arange(n_deltas, dtype=np.int64)
        flags = np.zeros(n_deltas, dtype=bool)
    else:
        resolved = None
        if n_escapes <= _FIXPOINT_MAX_ESCAPES:
            resolved = _resolve_escapes_fixpoint(
                words, cursor, n_deltas, n, width, n_escapes)
        if resolved is None:
            resolved = _resolve_escapes_scan(
                words, cursor, n_deltas, n, width, n_escapes)
        offs, flags = resolved
    return FPDeltaPlan(dtype, width, n, n_values, first, words,
                       offs, flags, n_escapes)


def fp_delta_execute(plan: FPDeltaPlan, out: np.ndarray | None = None) -> np.ndarray:
    """Finish a resolved plan on the host (Algorithm 2 back half).

    This is the oracle the accelerator path must match bit-for-bit.
    """
    dtype, width = plan.dtype, plan.width
    s, u = _SIGNED[width], _UNSIGNED[width]
    _check_out(out, plan.n_values, dtype)
    if plan.n_values == 0:
        return out if out is not None else np.zeros(0, dtype=dtype)

    out_arr = out if out is not None else np.empty(plan.n_values, dtype=dtype)
    out_int = out_arr.view(s)
    words = plan.words

    if plan.n == 0:
        raws = unpack_fixed(words, HEADER_BITS, plan.n_values, width)
        out_int[:] = raws.astype(u).view(s)
        return out_arr

    out_int[0] = _to_signed_scalar(np.uint64(plan.first), width)
    n_deltas = plan.n_values - 1
    if n_deltas == 0:
        return out_arr

    n, offs, flags = plan.n, plan.offsets, plan.flags
    if plan.n_escapes == 0:
        z = unpack_at(words, offs, n)
        deltas = unzigzag(z.astype(u), width)
        out_int[1:] = out_int[0] + np.cumsum(deltas, dtype=s)
        return out_arr

    tok = unpack_at(words, offs, n)
    # One segmented cumsum over all reset segments at once: cumsum the inline
    # deltas (escapes contribute 0), then add a per-segment correction so each
    # escape restarts the running sum at its raw value.
    deltas = np.where(flags, s(0), unzigzag(tok.astype(u), width))
    running = out_int[0] + np.cumsum(deltas, dtype=s)
    esc_idx = np.flatnonzero(flags)
    if not len(esc_idx):  # malformed payload claimed escapes; decode best-effort
        out_int[1:] = running
        return out_arr
    raws = unpack_at(words, offs[esc_idx] + n, width)
    raw_signed = raws.astype(u).view(s)
    corr = raw_signed - running[esc_idx]
    reps = np.diff(np.append(esc_idx, n_deltas))
    out_int[1 : 1 + esc_idx[0]] = running[: esc_idx[0]]
    out_int[1 + esc_idx[0] :] = running[esc_idx[0] :] + np.repeat(corr, reps)
    return out_arr


def fp_delta_decode(
    payload, n_values: int, dtype, out: np.ndarray | None = None
) -> np.ndarray:
    """Decode ``n_values`` elements of ``dtype`` (paper Algorithm 2).

    ``payload`` may be any bytes-like buffer (``bytes``, ``memoryview``).
    ``out``, if given, must be a contiguous 1-D array of exactly ``n_values``
    elements of ``dtype``; the decode writes into it and returns it, letting
    callers fill slices of a preallocated column without a concat pass.
    Wrong-dtype/wrong-length/non-contiguous buffers raise ``ValueError``
    before any byte of the payload is parsed.
    """
    dtype = np.dtype(dtype)
    if dtype.itemsize * 8 not in (32, 64):
        raise TypeError(f"unsupported dtype {dtype}")
    _check_out(out, n_values, dtype)
    return fp_delta_execute(fp_delta_plan(payload, n_values, dtype), out=out)


def encoded_size_bits(x: np.ndarray, n: int) -> int:
    """Exact S(n) for diagnostics (Equation 2 plus header/first-value cost)."""
    xi, width = _as_int_bits(x)
    if len(xi) < 2:
        return HEADER_BITS + width * len(xi)
    if n == 0:
        return HEADER_BITS + width * len(xi)
    h = delta_bit_histogram(x)
    suffix = np.cumsum(h[::-1])[::-1]
    over = int(suffix[n + 1]) if n + 1 <= width else 0
    return HEADER_BITS + width + n * (len(xi) - 1) + width * over

"""Attribute predicates pushed down three granularities (zone → page → record).

A small conjunctive AST over the file's extra (per-record attribute) columns:

- :class:`Range` — closed numeric interval ``lo <= v <= hi`` (NaN never
  matches, mirroring SQL comparison semantics),
- :class:`In` — membership in a finite value set,
- :class:`IsNull` — the value is NaN (float columns only),
- :class:`And` — conjunction.

Each node answers at two levels:

- :meth:`Predicate.mask` — the *exact* record-level answer as a numpy bool
  mask over decoded column arrays. This is the oracle every pruning level
  must agree with.
- :meth:`Predicate.zone_mask` — a *conservative* "may this zone contain a
  match?" test over per-zone min/max/NaN-count statistics (a shard's zone
  map or a page's footer stats). False means provably no match, so the zone
  can be skipped without reading it; True is always safe. Because stored
  stats pass through ``float`` (and may have rounded e.g. large int64
  values), bounds are widened outward by one ulp before testing.

Zone statistics are the vectorized :class:`ColumnZones` (one entry per
shard or page): ``vmin``/``vmax`` are float64 with NaN meaning *unknown*
and ``(+inf, -inf)`` meaning *no non-NaN values*; ``nnan``/``count`` are
int64 with ``-1`` meaning unknown. Missing statistics always keep the zone.

This module also hosts :func:`canonical_bbox` — the single bbox
canonicalization rule shared by every pruning level (shard MBRs, page
stats, and the record-level kernel's query keys): a bbox with a NaN bound
or inverted extent matches nothing, at every level.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np


def canonical_bbox(bbox) -> tuple[float, float, float, float] | None:
    """Canonicalize a query bbox ``(x0, y0, x1, y1)``; None if it is empty.

    A bbox with any NaN bound or an inverted extent (``x1 < x0`` or
    ``y1 < y0``) intersects nothing. Every pruning level — shard MBRs
    (:meth:`repro.dataset.index.DatasetIndex.query`), page statistics
    (:meth:`repro.core.index.SpatialIndex.query`) and the record-level
    kernel (:func:`repro.kernels.minmax.bbox_query_keys`) — routes through
    this helper so the same bbox produces the same answer at every level.
    """
    x0, y0, x1, y1 = (float(v) for v in bbox)
    if any(math.isnan(v) for v in (x0, y0, x1, y1)):
        return None
    if x1 < x0 or y1 < y0:
        return None
    return (x0, y0, x1, y1)


@dataclass
class ColumnZones:
    """Per-zone statistics of one column, SoA over shards or pages.

    ``vmin``/``vmax``: float64, NaN = unknown, ``(+inf, -inf)`` = zone has
    no non-NaN values. ``nnan``/``count``: int64, ``-1`` = unknown.
    """

    vmin: np.ndarray
    vmax: np.ndarray
    nnan: np.ndarray
    count: np.ndarray


# lookup(column) -> ColumnZones for that column, or None when unknown
ZoneLookup = Callable[[str], Optional[ColumnZones]]


def _widened(z: ColumnZones) -> tuple[np.ndarray, np.ndarray]:
    # stored stats went through float() and may have rounded the true
    # extremum (large int64s, float32 paths) — widen one ulp outward so the
    # zone test stays conservative. NaN (unknown) propagates through.
    return np.nextafter(z.vmin, -np.inf), np.nextafter(z.vmax, np.inf)


def _all_nan_zones(z: ColumnZones) -> np.ndarray:
    """Zones provably holding no non-NaN value (empty counts as all-NaN)."""
    return (z.nnan >= 0) & (z.count >= 0) & (z.nnan == z.count)


class Predicate:
    """Base class; see module docstring for semantics."""

    def columns(self) -> frozenset[str]:
        raise NotImplementedError

    def mask(self, extras: dict) -> np.ndarray:
        """Exact record-level bool mask over decoded column arrays."""
        raise NotImplementedError

    def zone_mask(self, lookup: ZoneLookup, n: int) -> np.ndarray:
        """Conservative per-zone "may match" mask of length ``n``."""
        raise NotImplementedError

    @property
    def key(self) -> tuple:
        """Stable hashable identity (serve-tier query dedup/caching)."""
        raise NotImplementedError

    def __and__(self, other: "Predicate") -> "And":
        return And(self, other)


def _check_bound(name: str, v) -> None:
    if v is not None and isinstance(v, float) and math.isnan(v):
        raise ValueError(f"Range {name} bound must not be NaN (use IsNull)")


@dataclass(frozen=True)
class Range(Predicate):
    """``lo <= column <= hi`` (closed; None = unbounded; NaN never matches)."""

    column: str
    lo: object = None
    hi: object = None

    def __post_init__(self):
        _check_bound("lo", self.lo)
        _check_bound("hi", self.hi)

    def columns(self) -> frozenset[str]:
        return frozenset((self.column,))

    def mask(self, extras: dict) -> np.ndarray:
        v = np.asarray(extras[self.column])
        if self.lo is None and self.hi is None:
            # pure non-null test: any comparable number matches
            return ~np.isnan(v) if v.dtype.kind == "f" else np.ones(len(v), bool)
        m = np.ones(len(v), bool)
        if self.lo is not None:
            m &= v >= self.lo  # NaN compares False
        if self.hi is not None:
            m &= v <= self.hi
        return m

    def zone_mask(self, lookup: ZoneLookup, n: int) -> np.ndarray:
        z = lookup(self.column)
        if z is None:
            return np.ones(n, bool)
        vmin, vmax = _widened(z)
        keep = np.ones(n, bool)
        with np.errstate(invalid="ignore"):
            if self.lo is not None:
                keep &= ~(vmax < self.lo)  # NaN stats stay kept
            if self.hi is not None:
                keep &= ~(vmin > self.hi)
        keep &= ~_all_nan_zones(z)
        return keep

    @property
    def key(self) -> tuple:
        return ("range", self.column, self.lo, self.hi)


@dataclass(frozen=True)
class In(Predicate):
    """``column ∈ values`` (finite set; NaN members are rejected)."""

    column: str
    values: tuple = ()

    def __post_init__(self):
        vals = tuple(self.values)
        if not vals:
            raise ValueError("In() needs at least one value")
        for v in vals:
            if isinstance(v, float) and math.isnan(v):
                raise ValueError("NaN is not a set member (use IsNull)")
        object.__setattr__(self, "values", vals)

    def columns(self) -> frozenset[str]:
        return frozenset((self.column,))

    def mask(self, extras: dict) -> np.ndarray:
        v = np.asarray(extras[self.column])
        return np.isin(v, np.asarray(self.values))

    def zone_mask(self, lookup: ZoneLookup, n: int) -> np.ndarray:
        z = lookup(self.column)
        if z is None:
            return np.ones(n, bool)
        vmin, vmax = _widened(z)
        keep = np.zeros(n, bool)
        with np.errstate(invalid="ignore"):
            for v in self.values:
                keep |= (vmin <= v) & (v <= vmax)
        keep |= np.isnan(z.vmin) | np.isnan(z.vmax)  # unknown stats keep
        keep &= ~_all_nan_zones(z)
        return keep

    @property
    def key(self) -> tuple:
        return ("in", self.column, self.values)


@dataclass(frozen=True)
class IsNull(Predicate):
    """``column`` is NaN (float columns; always False for integer columns)."""

    column: str

    def columns(self) -> frozenset[str]:
        return frozenset((self.column,))

    def mask(self, extras: dict) -> np.ndarray:
        v = np.asarray(extras[self.column])
        if v.dtype.kind == "f":
            return np.isnan(v)
        return np.zeros(len(v), bool)

    def zone_mask(self, lookup: ZoneLookup, n: int) -> np.ndarray:
        z = lookup(self.column)
        if z is None:
            return np.ones(n, bool)
        return z.nnan != 0  # -1 (unknown) keeps, 0 prunes, >0 keeps

    @property
    def key(self) -> tuple:
        return ("isnull", self.column)


@dataclass(frozen=True)
class And(Predicate):
    """Conjunction of predicates (nested Ands are flattened)."""

    preds: tuple = ()

    def __init__(self, *preds):
        flat = []
        for p in preds:
            if isinstance(p, And):
                flat.extend(p.preds)
            elif isinstance(p, Predicate):
                flat.append(p)
            else:
                raise TypeError(f"not a Predicate: {p!r}")
        if not flat:
            raise ValueError("And() needs at least one predicate")
        object.__setattr__(self, "preds", tuple(flat))

    def columns(self) -> frozenset[str]:
        return frozenset().union(*(p.columns() for p in self.preds))

    def mask(self, extras: dict) -> np.ndarray:
        m = self.preds[0].mask(extras)
        for p in self.preds[1:]:
            m = m & p.mask(extras)
        return m

    def zone_mask(self, lookup: ZoneLookup, n: int) -> np.ndarray:
        m = self.preds[0].zone_mask(lookup, n)
        for p in self.preds[1:]:
            m = m & p.zone_mask(lookup, n)
        return m

    @property
    def key(self) -> tuple:
        return ("and",) + tuple(p.key for p in self.preds)


def validate_predicate(pred, extra_schema: dict) -> Predicate:
    """Check ``pred`` references only numeric columns of ``extra_schema``."""
    if not isinstance(pred, Predicate):
        raise TypeError(f"filter must be a repro.core.filters.Predicate, got {pred!r}")
    for c in sorted(pred.columns()):
        if c not in extra_schema:
            raise ValueError(
                f"filter column {c!r} not in extra columns {sorted(extra_schema)}"
            )
        if np.dtype(extra_schema[c]).kind not in "iuf":
            raise ValueError(
                f"filter column {c!r} has non-numeric dtype {extra_schema[c]!r}"
            )
    return pred

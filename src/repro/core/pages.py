"""Page-level encode/decode: FP-delta or raw, plus general-purpose compression.

A *page* is the minimum reading unit (paper Appendix A.2): ~1MB of one
column's values, record-aligned so the light-weight index can skip whole
records. Each page is encoded (FP-delta §3 / raw) then optionally compressed
(gzip per the paper's experiments, or zstd as a modern extension) and carries
[min, max] statistics (§4).
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover - zstd optional
    _zstd = None

from .bitstream import bytes_to_words
from .fp_delta import (
    _EMPTY_FLAGS,
    _EMPTY_OFFS,
    HEADER_BITS,
    FPDeltaPlan,
    _check_out,
    fp_delta_decode,
    fp_delta_encode,
    fp_delta_encode_pages,
    fp_delta_plan,
)

ENC_FP_DELTA = "fp_delta"
ENC_RAW = "raw"

CODEC_NONE = "none"
CODEC_GZIP = "gzip"
CODEC_ZSTD = "zstd"


class CodecUnavailable(RuntimeError):
    """Raised when a file/page requests a codec whose wheel is not installed.

    The byte format itself is fine — install the codec (e.g. ``zstandard``)
    or rewrite the file with ``codec="gzip"``/``"none"``.
    """


def have_codec(codec: str) -> bool:
    """True if ``codec`` can be used in this environment."""
    if codec in (CODEC_NONE, CODEC_GZIP):
        return True
    if codec == CODEC_ZSTD:
        return _zstd is not None
    return False


def best_codec() -> str:
    """Strongest general-purpose codec usable here: zstd if present, else gzip."""
    return CODEC_ZSTD if have_codec(CODEC_ZSTD) else CODEC_GZIP


def compress(buf, codec: str) -> bytes:
    if codec == CODEC_NONE:
        return buf
    if codec == CODEC_GZIP:
        return zlib.compress(buf, 6)
    if codec == CODEC_ZSTD:
        if _zstd is None:
            raise CodecUnavailable(
                "codec 'zstd' requires the 'zstandard' package (not installed); "
                "use codec='gzip' or codec='none' instead"
            )
        return _zstd.ZstdCompressor(level=3).compress(buf)
    raise ValueError(f"unknown codec {codec!r}")


def decompress(buf, codec: str):
    if codec == CODEC_NONE:
        return buf
    if codec == CODEC_GZIP:
        return zlib.decompress(buf)
    if codec == CODEC_ZSTD:
        if _zstd is None:
            raise CodecUnavailable(
                "codec 'zstd' requires the 'zstandard' package (not installed); "
                "this file cannot be decoded until it is available"
            )
        return _zstd.ZstdDecompressor().decompress(buf)
    raise ValueError(f"unknown codec {codec!r}")


@dataclass
class PageMeta:
    """Footer metadata for one page (offsets are file-absolute)."""

    offset: int
    nbytes: int
    count: int              # number of values
    rec_start: int          # first record (row-group relative)
    rec_count: int
    vmin: float
    vmax: float
    encoding: str
    n_bits: int             # FP-delta n* (0 => raw mode inside fp_delta)
    n_resets: int
    crc: int | None = None  # checksum of the stored bytes (format v2 files)
    nnan: int | None = None  # NaN count (extra-column pages with zone stats)

    def to_dict(self) -> dict:
        d = self.__dict__.copy()
        if d.get("crc") is None:
            # v1 files carry no checksums; omitting the key keeps their
            # footers byte-identical to the pre-checksum format
            del d["crc"]
        if d.get("nnan") is None:
            # coordinate pages and pre-zone-map files omit the key, keeping
            # their footers byte-identical to the earlier format
            del d["nnan"]
        return d

    @staticmethod
    def from_dict(d: dict) -> "PageMeta":
        return PageMeta(**d)


def encode_page(values: np.ndarray, encoding: str, codec: str) -> tuple[bytes, dict]:
    """Encode one page of numeric values; returns (bytes, stats dict)."""
    values = np.ascontiguousarray(values)
    if encoding == ENC_FP_DELTA:
        payload, st = fp_delta_encode(values)
        n_bits, n_resets = st.n_bits, st.n_resets
    elif encoding == ENC_RAW:
        payload, n_bits, n_resets = values.tobytes(), 0, 0
    else:
        raise ValueError(f"unknown encoding {encoding!r}")
    out = compress(payload, codec)
    stats = {
        "n_bits": n_bits,
        "n_resets": n_resets,
        "raw_nbytes": values.nbytes,
        "encoded_nbytes": len(payload),
        "stored_nbytes": len(out),
    }
    return out, stats


def decode_page(
    buf, meta: PageMeta, dtype, codec: str, out: np.ndarray | None = None
) -> np.ndarray:
    """Decode one page; ``buf`` may be any bytes-like (memoryview slice).

    ``out``, if given, receives the decoded values in place (must be a
    contiguous 1-D array of ``meta.count`` elements) — the coalesced reader
    uses this to decode straight into preallocated column arrays.
    """
    payload = decompress(buf, codec)
    if meta.encoding == ENC_FP_DELTA:
        return fp_delta_decode(payload, meta.count, dtype, out=out)
    if meta.encoding == ENC_RAW:
        dtype = np.dtype(dtype)
        vals = np.frombuffer(payload, dtype=dtype, count=meta.count)
        if out is not None:
            # same strict contract as fp_delta_decode: a wrong-dtype buffer
            # would otherwise silently value-cast (lossy) instead of
            # receiving the stored bits
            _check_out(out, meta.count, dtype)
            out[:] = vals
            return out
        return vals.copy()
    raise ValueError(f"unknown encoding {meta.encoding!r}")


def page_plan(buf, meta: PageMeta, dtype, codec: str) -> FPDeltaPlan:
    """Host-resolve one stored page into an :class:`FPDeltaPlan`.

    The front half of the device read path: decompress + header parse +
    escape resolution on the host; the returned plan is what
    ``repro.kernels.fp_delta.decode_pages`` batches onto the accelerator.
    Only FP-delta pages have plans (raw pages are a plain ``frombuffer``).
    """
    if meta.encoding != ENC_FP_DELTA:
        raise ValueError(f"page_plan requires fp_delta pages, got {meta.encoding!r}")
    return fp_delta_plan(decompress(buf, codec), meta.count, dtype)


def page_stream_plan(buf, meta: PageMeta, dtype, codec: str) -> FPDeltaPlan:
    """Like :func:`page_plan`, but accepts **every** coordinate encoding.

    Raw pages are mapped onto a *synthetic raw-mode plan* — a zero byte
    (standing in for the fp_delta ``n* = 0`` header) prepended to the stored
    values, so every value sits at ``HEADER_BITS + i * W`` exactly like a
    raw-mode fp_delta payload. The device page-stream decode then treats
    both encodings uniformly (each value a W-bit anchor), which is what lets
    the fused decode→refine path cover whole row groups regardless of how
    individual pages were encoded. Bit-identical to ``np.frombuffer`` on the
    payload (little-endian word math either way).
    """
    if meta.encoding == ENC_FP_DELTA:
        return page_plan(buf, meta, dtype, codec)
    if meta.encoding != ENC_RAW:
        raise ValueError(f"unknown encoding {meta.encoding!r}")
    dtype = np.dtype(dtype)
    width = dtype.itemsize * 8
    if width not in (32, 64):
        raise TypeError(f"unsupported dtype {dtype}")
    payload = decompress(buf, codec)
    if meta.count == 0:
        return FPDeltaPlan(dtype, width, 0, 0, 0, np.zeros(1, np.uint64),
                           _EMPTY_OFFS, _EMPTY_FLAGS, 0)
    shifted = bytearray(1 + len(payload))
    shifted[1:] = payload
    assert HEADER_BITS == 8, "synthetic raw plan assumes a one-byte header"
    return FPDeltaPlan(dtype, width, 0, meta.count, 0, bytes_to_words(shifted),
                       _EMPTY_OFFS, _EMPTY_FLAGS, 0)


def encode_pages(
    values: np.ndarray, bounds: list[tuple[int, int]], encoding: str, codec: str
) -> list[tuple[bytes, dict]]:
    """Batch-encode value ranges ``[v0, v1)`` of one column as pages.

    For FP-delta this shares a single column-wide delta/zigzag/bit-count pass
    across all pages (byte-identical to per-page :func:`encode_page`); raw
    pages are plain slices. Compression still applies per page.
    """
    values = np.ascontiguousarray(values)
    out: list[tuple[bytes, dict]] = []
    if encoding == ENC_FP_DELTA:
        encoded = fp_delta_encode_pages(values, bounds)
        for (payload, st), (v0, v1) in zip(encoded, bounds):
            comp = compress(payload, codec)
            out.append((comp, {
                "n_bits": st.n_bits, "n_resets": st.n_resets,
                "raw_nbytes": values[v0:v1].nbytes,
                "encoded_nbytes": len(payload), "stored_nbytes": len(comp),
            }))
        return out
    if encoding == ENC_RAW:
        for v0, v1 in bounds:
            payload = values[v0:v1].tobytes()
            comp = compress(payload, codec)
            out.append((comp, {
                "n_bits": 0, "n_resets": 0,
                "raw_nbytes": values[v0:v1].nbytes,
                "encoded_nbytes": len(payload), "stored_nbytes": len(comp),
            }))
        return out
    raise ValueError(f"unknown encoding {encoding!r}")


def plan_page_splits(
    record_value_starts: np.ndarray, n_values: int, page_values: int
) -> list[tuple[int, int]]:
    """Record-aligned page boundaries targeting ``page_values`` per page.

    Returns a list of (rec_start, rec_stop) per page. Records bigger than a
    page get a page of their own (a page always holds >= 1 record).
    """
    n_records = len(record_value_starts)
    if n_records == 0:
        return []
    bounds = np.append(record_value_starts, n_values)
    pages: list[tuple[int, int]] = []
    r = 0
    while r < n_records:
        target = bounds[r] + page_values
        # furthest record whose values end within the target
        nxt = int(np.searchsorted(bounds, target, side="right")) - 1
        nxt = max(nxt, r + 1)
        nxt = min(nxt, n_records)
        pages.append((r, nxt))
        r = nxt
    return pages

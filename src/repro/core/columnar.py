"""Dremel-style shredding of geometries into Spatial Parquet columns (paper §2).

Physical columns: ``type`` (one per sub-geometry, RLE), ``x``/``y`` (one per
coordinate, FP-delta), plus 2-bit repetition and definition level streams.

Level semantics (one *slot* per coordinate, plus one per empty sub-geometry):

====  =============================================================
rep   0 = record start, 1 = sub-geometry start (GeometryCollection
      flattening, paper §2.7), 2 = part start, 3 = within part
defn  0 = empty sub-geometry marker (no x/y value), 1 = value present
====  =============================================================

``type_rep`` (one per sub-geometry, values {0,1}) marks record boundaries in
the type column; plain geometries have exactly one sub-geometry. A
single-element GeometryCollection is indistinguishable from its element after
flattening — inherent to the paper's §2.7 scheme.

Two APIs: the object API (:func:`shred` / :func:`assemble`) over
:class:`~repro.core.geometry.Geometry` lists, and the vectorized *ragged* API
(:func:`from_ragged` / :meth:`GeometryColumns.to_ragged`) used by the data
pipeline and generators (no per-record Python loop).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .geometry import (
    TYPE_EMPTY,
    TYPE_GEOMETRYCOLLECTION,
    TYPE_MULTIPOLYGON,
    TYPE_POLYGON,
    Geometry,
    polygons_from_rings,
)


@dataclass
class DeviceCoords:
    """A device-resident coordinate column (the ``keep_on_device`` form).

    Holds the decoded IEEE-754 bit patterns as uint32 limb arrays living on
    the accelerator (``hi`` is None for 32-bit coordinates) — the exact
    output of the fused device scan, with **zero host transfer** until
    :meth:`to_numpy` is called. This module stays jax-free; the fields are
    duck-typed device arrays produced by ``repro.kernels.fp_delta``.
    """

    lo: object                  # (n,) uint32 device array
    hi: object | None           # (n,) uint32 device array, None for 32-bit
    dtype: np.dtype

    def __len__(self) -> int:
        return int(self.lo.shape[0])

    def to_numpy(self) -> np.ndarray:
        """Transfer to host and bitcast to the coordinate dtype."""
        lo = np.asarray(self.lo)
        if self.hi is None:
            return lo.view(self.dtype)
        bits = (np.asarray(self.hi).astype(np.uint64) << np.uint64(32)) | lo
        return bits.view(self.dtype)

    @staticmethod
    def from_numpy(arr: np.ndarray) -> "DeviceCoords":
        """Upload a host coordinate array as limb pairs (inverse of
        :meth:`to_numpy`; used when a host-decoded chunk joins a
        device-resident result)."""
        import jax.numpy as jnp

        arr = np.ascontiguousarray(arr)
        if arr.dtype.itemsize == 4:
            return DeviceCoords(jnp.asarray(arr.view(np.uint32)), None, arr.dtype)
        bits = arr.view(np.uint64)
        lo = (bits & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        hi = (bits >> np.uint64(32)).astype(np.uint32)
        return DeviceCoords(jnp.asarray(lo), jnp.asarray(hi), arr.dtype)

    @staticmethod
    def concat(parts: list["DeviceCoords"]) -> "DeviceCoords":
        """Device-side concatenation (no host round-trip)."""
        if len(parts) == 1:
            return parts[0]
        import jax.numpy as jnp  # device parts exist, so jax is loaded

        lo = jnp.concatenate([p.lo for p in parts])
        hi = (None if parts[0].hi is None
              else jnp.concatenate([p.hi for p in parts]))
        return DeviceCoords(lo, hi, parts[0].dtype)


@dataclass
class GeometryColumns:
    """The shredded (columnar) form of a geometry column chunk.

    ``x``/``y`` are host numpy arrays on every default path; the fused
    device scan (``read_columnar(..., keep_on_device=True)``) returns them
    as :class:`DeviceCoords` instead — structural methods (record counts,
    level slicing) keep working, value-level APIs need
    :meth:`coords_to_host` first.
    """

    types: np.ndarray      # uint8, one per sub-geometry
    type_rep: np.ndarray   # uint8 {0,1}, one per sub-geometry
    rep: np.ndarray        # uint8 {0..3}, one per slot
    defn: np.ndarray       # uint8 {0,1}, one per slot
    x: np.ndarray          # float64/float32, one per value slot (defn==1)
    y: np.ndarray

    def coords_to_host(self) -> "GeometryColumns":
        """Materialize device-resident coordinates (no-op for host arrays)."""
        if not isinstance(self.x, DeviceCoords):
            return self
        return GeometryColumns(self.types, self.type_rep, self.rep, self.defn,
                               self.x.to_numpy(), self.y.to_numpy())

    @property
    def n_records(self) -> int:
        return int(np.count_nonzero(self.rep == 0))

    @property
    def n_values(self) -> int:
        return len(self.x)

    @property
    def n_slots(self) -> int:
        return len(self.rep)

    def record_value_starts(self) -> np.ndarray:
        """Index into x/y of the first value of each record (records with at
        least one coordinate; empty records contribute their successor's)."""
        starts_slots = np.flatnonzero(self.rep == 0)
        value_idx = np.cumsum(self.defn.astype(np.int64)) - self.defn
        return value_idx[starts_slots]

    def slice_records(self, start: int, stop: int) -> "GeometryColumns":
        """Record-aligned slice (used by the page writer)."""
        rec_slot_starts = np.flatnonzero(self.rep == 0)
        rec_type_starts = np.flatnonzero(self.type_rep == 0)
        n = len(rec_slot_starts)
        s0 = rec_slot_starts[start] if start < n else self.n_slots
        s1 = rec_slot_starts[stop] if stop < n else self.n_slots
        t0 = rec_type_starts[start] if start < n else len(self.types)
        t1 = rec_type_starts[stop] if stop < n else len(self.types)
        vstart = int(np.count_nonzero(self.defn[:s0]))
        vstop = int(np.count_nonzero(self.defn[:s1]))
        return GeometryColumns(
            self.types[t0:t1],
            self.type_rep[t0:t1],
            self.rep[s0:s1],
            self.defn[s0:s1],
            self.x[vstart:vstop],
            self.y[vstart:vstop],
        )

    def to_ragged(self):
        """Vectorized inverse of :func:`from_ragged`.

        Returns ``(types, coords(n,2), part_sizes, parts_per_subgeom,
        subgeoms_per_record)`` — empty sub-geometries appear with 0 parts.
        """
        value_mask = self.defn == 1
        coords = np.stack([self.x, self.y], axis=1)
        # part starts among value slots (record/sub-geom starts are also <= 2)
        vrep = self.rep[value_mask]
        part_starts = np.flatnonzero(vrep <= 2)
        part_sizes = np.diff(np.concatenate([part_starts, [len(vrep)]]))
        # parts per sub-geometry: count part starts between sub-geom starts
        sub_start_mask = self.rep <= 1
        subgeom_is_empty = (self.defn == 0)[sub_start_mask]
        vsub_starts = np.flatnonzero(vrep <= 1)
        bounds = np.concatenate([vsub_starts, [len(vrep)]])
        parts_per_nonempty = np.diff(np.searchsorted(part_starts, bounds))
        parts_per_subgeom = np.zeros(len(subgeom_is_empty), dtype=np.int64)
        parts_per_subgeom[~subgeom_is_empty] = parts_per_nonempty
        # sub-geometries per record
        sub_rep = self.rep[sub_start_mask]
        rec_start_idx = np.flatnonzero(sub_rep == 0)
        subgeoms_per_record = np.diff(np.concatenate([rec_start_idx, [len(sub_rep)]]))
        return self.types, coords, part_sizes, parts_per_subgeom, subgeoms_per_record


def from_ragged(
    types: np.ndarray,
    coords: np.ndarray,
    part_sizes: np.ndarray,
    parts_per_subgeom: np.ndarray,
    subgeoms_per_record: np.ndarray | None = None,
) -> GeometryColumns:
    """Vectorized shredding from ragged arrays (no per-record loop).

    ``types``: uint8 per sub-geometry; ``coords``: (n,2); ``part_sizes``:
    coords per part; ``parts_per_subgeom``: parts per sub-geometry (0 =>
    empty); ``subgeoms_per_record``: default all-ones (no collections).
    """
    types = np.ascontiguousarray(types, dtype=np.uint8)
    part_sizes = np.ascontiguousarray(part_sizes, dtype=np.int64)
    parts_per_subgeom = np.ascontiguousarray(parts_per_subgeom, dtype=np.int64)
    n_sub = len(types)
    if subgeoms_per_record is None:
        subgeoms_per_record = np.ones(n_sub, dtype=np.int64)
    subgeoms_per_record = np.ascontiguousarray(subgeoms_per_record, dtype=np.int64)
    if (part_sizes <= 0).any():
        raise ValueError("part_sizes must be positive (empty parts not stored)")
    if int(parts_per_subgeom.sum()) != len(part_sizes):
        raise ValueError("parts_per_subgeom does not sum to len(part_sizes)")
    if int(subgeoms_per_record.sum()) != n_sub:
        raise ValueError("subgeoms_per_record does not sum to len(types)")

    n_values = int(part_sizes.sum())
    # coords per sub-geometry via segment sums of part_sizes
    nonempty = parts_per_subgeom > 0
    csum = np.concatenate([[0], np.cumsum(part_sizes)])
    ends = np.cumsum(parts_per_subgeom)
    starts = ends - parts_per_subgeom
    coords_per_subgeom = csum[ends] - csum[starts]
    # slots per sub-geometry: #coords, or 1 for empty markers
    slots_per_subgeom = np.where(nonempty, coords_per_subgeom, 1)
    n_slots = int(slots_per_subgeom.sum())

    rep = np.full(n_slots, 3, dtype=np.uint8)
    defn = np.ones(n_slots, dtype=np.uint8)
    sub_slot_starts = np.cumsum(slots_per_subgeom) - slots_per_subgeom
    # part starts: slot offset of the owning sub-geometry + local coord offset
    if len(part_sizes):
        part_sub = np.repeat(np.arange(n_sub), parts_per_subgeom)
        excl = csum[:-1]  # exclusive coord offset of each part
        first_part_of_sub = starts  # per sub-geometry
        local_within_sub = excl - excl[first_part_of_sub[part_sub]]
        part_slot = sub_slot_starts[part_sub] + local_within_sub
        rep[part_slot] = 2
    # sub-geometry starts
    rep[sub_slot_starts] = 1
    defn[sub_slot_starts[~nonempty]] = 0
    # record starts
    rec_first_sub = np.cumsum(subgeoms_per_record) - subgeoms_per_record
    rep[sub_slot_starts[rec_first_sub]] = 0

    type_rep = np.ones(n_sub, dtype=np.uint8)
    type_rep[rec_first_sub] = 0

    coords = np.asarray(coords)
    if coords.shape != (n_values, 2):
        raise ValueError(f"coords shape {coords.shape} != ({n_values}, 2)")
    return GeometryColumns(
        types, type_rep, rep, defn,
        np.ascontiguousarray(coords[:, 0]), np.ascontiguousarray(coords[:, 1]),
    )


def shred(geometries) -> GeometryColumns:
    """Object-API shredding of a sequence of :class:`Geometry`."""
    types: list[int] = []
    part_sizes: list[int] = []
    parts_per_sub: list[int] = []
    subs_per_record: list[int] = []
    coord_arrays: list[np.ndarray] = []
    for g in geometries:
        subs = g.sub_geometries if g.geom_type == TYPE_GEOMETRYCOLLECTION else [g]
        if not subs:  # empty collection degenerates to empty geometry
            subs = [Geometry.empty()]
        subs_per_record.append(len(subs))
        for sub in subs:
            pts = sum(len(p) for p in sub.parts)
            if pts == 0:
                types.append(TYPE_EMPTY)
                parts_per_sub.append(0)
            else:
                types.append(sub.geom_type)
                parts_per_sub.append(len(sub.parts))
                for p in sub.parts:
                    part_sizes.append(len(p))
                    coord_arrays.append(np.asarray(p, dtype=np.float64))
    coords = (
        np.concatenate(coord_arrays, axis=0)
        if coord_arrays
        else np.zeros((0, 2), dtype=np.float64)
    )
    return from_ragged(
        np.array(types, dtype=np.uint8),
        coords,
        np.array(part_sizes, dtype=np.int64),
        np.array(parts_per_sub, dtype=np.int64),
        np.array(subs_per_record, dtype=np.int64),
    )


def assemble(cols: GeometryColumns) -> list[Geometry]:
    """Reconstruct Geometry objects (paper §2 read path, incl. §2.6 winding)."""
    types, coords, part_sizes, parts_per_sub, subs_per_rec = cols.to_ragged()
    part_bounds = np.cumsum(part_sizes)
    parts = np.split(coords, part_bounds[:-1]) if len(part_sizes) else []
    out: list[Geometry] = []
    pi = 0  # part cursor
    si = 0  # sub-geometry cursor
    for n_subs in subs_per_rec:
        subs: list[Geometry] = []
        for _ in range(int(n_subs)):
            t = int(types[si])
            n_parts = int(parts_per_sub[si])
            gparts = parts[pi : pi + n_parts]
            pi += n_parts
            si += 1
            if t == TYPE_EMPTY or n_parts == 0:
                subs.append(Geometry.empty())
            elif t == TYPE_MULTIPOLYGON:
                # regroup rings into sub-polygons via winding (paper §2.6)
                subs.append(Geometry(t, [r for r in gparts]))
            else:
                subs.append(Geometry(t, gparts))
        out.append(subs[0] if n_subs == 1 else Geometry(TYPE_GEOMETRYCOLLECTION, [], subs))
    return out


def multipolygon_polygons(g: Geometry) -> list[list[np.ndarray]]:
    """Decompose a (Multi)Polygon's flat ring list into per-polygon ring lists."""
    if g.geom_type not in (TYPE_POLYGON, TYPE_MULTIPOLYGON):
        raise ValueError("not a polygonal geometry")
    if g.geom_type == TYPE_POLYGON:
        return [g.parts]
    return polygons_from_rings(g.parts)

"""PartitionSpec rules: DP/FSDP over 'data' (+'pod'), TP over 'model', EP for
MoE experts, SP (sequence sharding) for long-context decode caches.

Rules are path-keyed over the parameter pytree and specify specs for the
*trailing* dims of each leaf; leading dims (the scan-stacked ``n_layers`` /
``n_sites`` axes) are padded with None. Any dim whose size does not divide
its mesh axis falls back to replication (logged by the dry-run, not silent —
see ``explain()``).
"""

from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def fsdp_axes(cfg: ModelConfig, mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not cfg.fsdp_pod:
        axes = tuple(a for a in axes if a != "pod")
    return axes if axes else None


def batch_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


def _axis_size(mesh: Mesh, axis) -> int:
    sizes = mesh_axis_sizes(mesh)
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= sizes[a]
        return n
    return sizes[axis]


class SpecBuilder:
    def __init__(self, cfg: ModelConfig, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.fsdp = fsdp_axes(cfg, mesh)
        self.tp = "model" if "model" in mesh.axis_names else None
        self.fallbacks: list[str] = []

    def dim(self, size: int, axis, what: str = ""):
        """Use ``axis`` for a dim only if the size divides the axis product."""
        if axis is None:
            return None
        if size % _axis_size(self.mesh, axis) != 0:
            self.fallbacks.append(f"{what}: dim {size} !% axis {axis} -> replicated")
            return None
        return axis

    def spec(self, shape: tuple[int, ...], *axes, what: str = "") -> P:
        assert len(axes) == len(shape), (shape, axes)
        return P(*[self.dim(s, a, what) for s, a in zip(shape, axes)])


def _leaf_spec(b: SpecBuilder, path: str, shape: tuple[int, ...]) -> P:
    """Spec for the trailing dims of a parameter leaf (path '/'-joined)."""
    cfg = b.cfg
    name = path.split("/")[-1]
    fsdp, tp = b.fsdp, b.tp

    def pad(spec_dims: list, ndim: int) -> P:
        lead = [None] * (ndim - len(spec_dims))
        return P(*lead, *spec_dims)

    nd = len(shape)
    tail = shape[-2:] if nd >= 2 else shape

    # ---- scalars / vectors: replicated
    if name in ("ln1", "ln2", "ln_cross", "final_norm", "norm", "q_norm",
                "k_norm", "kv_norm", "dt_bias", "A_log", "D"):
        return P(*[None] * nd)
    # ---- embeddings / head
    if name == "embed":
        return pad([b.dim(shape[-2], tp, name), b.dim(shape[-1], fsdp, name)], nd)
    if name == "lm_head":
        return pad([b.dim(shape[-2], fsdp, name), b.dim(shape[-1], tp, name)], nd)
    if name == "frontend_adapter":
        return pad([None, b.dim(shape[-1], tp, name)], nd)
    # ---- MoE expert stacks (trailing dims: E, in, out); shared/dense expert
    #      MLPs (paths .../moe/shared/*, .../moe/dense/*) use plain MLP rules.
    if "moe" in path and "shared" not in path and "dense" not in path:
        if name == "router":
            return pad([b.dim(shape[-2], fsdp, name), None], nd)
        if name in ("w_gate", "w_up") and nd >= 3:
            return pad([b.dim(shape[-3], tp, "EP"), b.dim(shape[-2], fsdp, name), None], nd)
        if name == "w_down" and nd >= 3:
            return pad([b.dim(shape[-3], tp, "EP"), None, b.dim(shape[-1], fsdp, name)], nd)
    # ---- MLA
    if name in ("wq_a", "wkv_a"):
        return pad([b.dim(shape[-2], fsdp, name), None], nd)
    if name in ("wq_b", "wkv_b"):
        return pad([None, b.dim(shape[-1], tp, name)], nd)
    # ---- SSM
    if name in ("wz", "wx"):
        return pad([b.dim(shape[-2], fsdp, name), b.dim(shape[-1], tp, name)], nd)
    if name in ("wB", "wC", "wdt"):
        return pad([b.dim(shape[-2], fsdp, name), None], nd)
    if name == "conv_x":
        return pad([None, b.dim(shape[-1], tp, name)], nd)
    if name in ("conv_B", "conv_C"):
        return P(*[None] * nd)
    if name == "out_proj":
        return pad([b.dim(shape[-2], tp, name), b.dim(shape[-1], fsdp, name)], nd)
    # ---- attention / MLP matrices
    if name in ("wq", "wk", "wv", "w_gate", "w_up"):
        return pad([b.dim(shape[-2], fsdp, name), b.dim(shape[-1], tp, name)], nd)
    if name in ("wo", "w_down"):
        return pad([b.dim(shape[-2], tp, name), b.dim(shape[-1], fsdp, name)], nd)
    return P(*[None] * nd)


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape) -> tuple:
    """(pytree of PartitionSpec matching params, list of fallback notes).

    ``params_shape`` is a pytree of ShapeDtypeStruct or arrays.
    """
    import jax

    b = SpecBuilder(cfg, mesh)

    def visit(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        return _leaf_spec(b, "/".join(str(k) for k in keys), leaf.shape)

    specs = jax.tree_util.tree_map_with_path(visit, params_shape)
    return specs, b.fallbacks


def batch_spec(cfg: ModelConfig, mesh: Mesh, *, microbatched: bool) -> P:
    """Sharding for (.., B, S)-shaped token arrays (leading accum dim unsharded)."""
    dp = batch_axes(mesh)
    return P(None, dp) if microbatched else P(dp)


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape) -> tuple:
    """Shardings for the serving cache pytree.

    Layer K/V caches (L, B, S, H, D): batch over dp; heads over tp; if
    ``cfg.seq_shard_cache`` and the batch cannot shard (B=1 long-context),
    the sequence dim shards over 'data' instead (SP decode).
    """
    import jax

    b = SpecBuilder(cfg, mesh)
    dp = batch_axes(mesh)
    data_only = "data" if "data" in mesh.axis_names else None

    def visit(path, leaf):
        keys = "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in path)
        shape = leaf.shape
        nd = len(shape)
        if keys.endswith("pos"):
            return P()
        batch_dim_ok = shape[1] % _axis_size(b.mesh, dp) == 0 if nd >= 2 and dp else False
        if "cross" in keys or keys.endswith("k") or keys.endswith("v"):
            # (L, B, S, H, hd) attention caches (layer or site stacked)
            if nd == 5:
                heads_ax = b.dim(shape[3], b.tp, keys)
                if batch_dim_ok:
                    if heads_ax is None:
                        # heads !% tp (MQA/GQA few-head caches): SP over the
                        # model axis on the sequence dim instead — the
                        # attention contraction psums across 'model'
                        return P(None, dp, b.dim(shape[2], b.tp, keys), None, None)
                    return P(None, dp, None, heads_ax, None)
                if cfg.seq_shard_cache:
                    return P(None, None, b.dim(shape[2], data_only, keys),
                             heads_ax, None)
                return P(None, None, None, heads_ax, None)
        if keys.endswith("c_kv"):       # (L, B, S, r) MLA latent: SP on seq
            return P(None, dp if batch_dim_ok else None,
                     b.dim(shape[2], b.tp, keys), None)
        if keys.endswith("k_rope"):     # (L, B, S, 1, rd)
            return P(None, dp if batch_dim_ok else None,
                     b.dim(shape[2], b.tp, keys), None, None)
        if keys.endswith("state"):      # (L, B, H, N, P) ssm state
            return P(None, dp if batch_dim_ok else None,
                     b.dim(shape[2], b.tp, keys), None, None)
        if "conv" in keys:              # (L, B, w-1, C)
            return P(None, dp if batch_dim_ok else None, None,
                     b.dim(shape[3], b.tp, keys))
        return P(*[None] * nd)

    specs = jax.tree_util.tree_map_with_path(visit, cache_shape)
    return specs, b.fallbacks


def to_named_sharding(mesh: Mesh, spec_tree):
    import jax

    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )

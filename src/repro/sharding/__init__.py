from .specs import (
    batch_axes,
    batch_spec,
    cache_specs,
    fsdp_axes,
    param_specs,
    to_named_sharding,
)

__all__ = [
    "param_specs",
    "cache_specs",
    "batch_spec",
    "batch_axes",
    "fsdp_axes",
    "to_named_sharding",
]

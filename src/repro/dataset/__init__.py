"""Sharded Spatial Parquet datasets: a geospatial data lake of .spqf shards.

The paper's light-weight index skips pages inside one file; this package
lifts the same idea to a *lake* of many files::

    from repro.dataset import (
        write_dataset, SpatialDatasetWriter,      # partition by SFC key
        DatasetManifest, ShardInfo, is_dataset,   # the JSON catalog
        DatasetIndex,                             # shard-level MBR pruning
        SpatialDatasetScanner,                    # async fan-out queries
        Catalog, Compactor,                       # snapshots, compaction, GC
    )

    manifest = write_dataset("lake/porto", columns=cols, n_shards=8)
    sc = SpatialDatasetScanner("lake/porto")
    geo, extras, stats = sc.scan(bbox=(-8.65, 41.14, -8.58, 41.19))
    # stats.shards_read / stats.shards_total, stats.bytes_read / bytes_total

Mutations are crash-safe: every write is an atomic snapshot commit
(:class:`Catalog`), scans pin the generation they read
(:class:`SpatialDatasetScanner`), and :class:`Compactor` merges small
adjacent shards in the background without disturbing pinned readers.
"""

from .catalog import (
    Catalog,
    CommitTx,
    Compactor,
    PinnedSnapshot,
    Snapshot,
    file_crc32c,
    pinned_generations,
)
from .errors import CommitConflict, DatasetError, ShardFailure, ShardReadError
from .index import DatasetIndex
from .manifest import (
    DATASET_FORMAT,
    MANIFEST_NAME,
    DatasetManifest,
    ShardInfo,
    is_dataset,
    shard_path,
)
from .scanner import ON_ERROR_POLICIES, SpatialDatasetScanner
from .writer import SpatialDatasetWriter, write_dataset

__all__ = [
    "DATASET_FORMAT",
    "MANIFEST_NAME",
    "DatasetManifest",
    "ShardInfo",
    "is_dataset",
    "shard_path",
    "DatasetIndex",
    "DatasetError",
    "CommitConflict",
    "ShardFailure",
    "ShardReadError",
    "ON_ERROR_POLICIES",
    "SpatialDatasetScanner",
    "SpatialDatasetWriter",
    "write_dataset",
    "Catalog",
    "CommitTx",
    "Compactor",
    "Snapshot",
    "PinnedSnapshot",
    "file_crc32c",
    "pinned_generations",
]

"""Dataset-level spatial index: prune whole shards before per-page pruning.

The manifest's per-shard MBRs are the shard-level analog of the paper's §4
per-page [min,max] statistics: a query rectangle drops every shard whose MBR
misses it without opening the shard file, then delegates to each surviving
shard's own :class:`~repro.core.index.SpatialIndex` for page pruning.

Layout mirrors :class:`~repro.core.index.SpatialIndex` — structure-of-arrays
over the manifest, vectorized queries, and :meth:`shard_runs` returning
maximal runs of consecutive hit shards, symmetric to ``page_runs`` (shards
are numbered in manifest order, which is SFC-key order, so spatially-close
queries hit consecutive shards).
"""

from __future__ import annotations

import numpy as np

from repro.core.filters import ColumnZones, Predicate, canonical_bbox

from .manifest import DatasetManifest


class DatasetIndex:
    """In-memory SoA view of the manifest MBRs with vectorized pruning."""

    def __init__(self, manifest: DatasetManifest):
        self.manifest = manifest
        n = manifest.n_shards
        self._xmin = np.empty(n, dtype=np.float64)
        self._ymin = np.empty(n, dtype=np.float64)
        self._xmax = np.empty(n, dtype=np.float64)
        self._ymax = np.empty(n, dtype=np.float64)
        self.n_records = np.empty(n, dtype=np.int64)
        self.n_pages = np.empty(n, dtype=np.int64)
        self.data_bytes = np.empty(n, dtype=np.int64)
        for i, s in enumerate(manifest.shards):
            self._xmin[i], self._ymin[i], self._xmax[i], self._ymax[i] = s.mbr
            self.n_records[i] = s.n_records
            self.n_pages[i] = s.n_pages
            self.data_bytes[i] = s.data_bytes
        self._zones: dict[str, ColumnZones] | None = None

    def zone_lookup(self, column: str) -> ColumnZones | None:
        """Per-shard zone-map statistics of one extra column.

        Built lazily from the manifest's ``ShardInfo.zone_maps``. A shard
        without a zone map for the column (older snapshots, pre-zone-map
        files) contributes unknown stats (NaN min/max, ``-1`` counts) and is
        never pruned. Returns None when *no* shard knows the column.
        """
        if self._zones is None:
            zones: dict[str, ColumnZones] = {}
            cols = set()
            for s in self.manifest.shards:
                cols.update(s.zone_maps or ())
            n = len(self)
            for k in sorted(cols):
                vmin = np.full(n, np.nan)
                vmax = np.full(n, np.nan)
                nnan = np.full(n, -1, np.int64)
                count = np.full(n, -1, np.int64)
                for i, s in enumerate(self.manifest.shards):
                    z = (s.zone_maps or {}).get(k)
                    if z is None:
                        continue
                    # min/max of None = no non-NaN values in the shard
                    vmin[i] = np.inf if z["min"] is None else z["min"]
                    vmax[i] = -np.inf if z["max"] is None else z["max"]
                    nnan[i] = z["nnan"]
                    count[i] = z["count"]
                zones[k] = ColumnZones(vmin, vmax, nnan, count)
            self._zones = zones
        return self._zones.get(column)

    def __len__(self) -> int:
        return len(self._xmin)

    @property
    def total_bytes(self) -> int:
        return int(self.data_bytes.sum())

    @property
    def total_pages(self) -> int:
        return int(self.n_pages.sum())

    def query(
        self,
        bbox: tuple[float, float, float, float] | None,
        filter: Predicate | None = None,
    ) -> np.ndarray:
        """Indices of shards that may satisfy ``bbox`` ∧ ``filter``.

        ``bbox=None`` means no spatial constraint; an empty bbox under
        :func:`~repro.core.filters.canonical_bbox` (NaN bound or inverted
        extent) hits nothing — the same rule the page- and record-level
        tests apply, so every pruning level answers consistently. ``filter``
        prunes from the manifest alone via the persisted per-shard zone
        maps, before any shard file is opened.
        """
        if bbox is None:
            hit = np.ones(len(self), bool)
        else:
            b = canonical_bbox(bbox)
            if b is None:
                return np.zeros(0, dtype=np.intp)
            qx0, qy0, qx1, qy1 = b
            hit = (
                (self._xmin <= qx1)
                & (self._xmax >= qx0)
                & (self._ymin <= qy1)
                & (self._ymax >= qy0)
            )
        if filter is not None:
            hit = hit & filter.zone_mask(self.zone_lookup, len(self))
        return np.flatnonzero(hit)

    def shard_runs(self, bbox, hit: np.ndarray | None = None) -> list[tuple[int, int]]:
        """Maximal runs of consecutive hit shards: ``(s0, s1)``.

        Shards ``s0 .. s1-1`` all intersect ``bbox``; runs are emitted in
        manifest (SFC) order — the dataset-level mirror of
        :meth:`repro.core.index.SpatialIndex.page_runs`. Pass ``hit`` (a
        ``query(bbox)`` result) to avoid re-running the query.
        """
        if hit is None:
            hit = self.query(bbox)
        if len(hit) == 0:
            return []
        brk = np.flatnonzero(np.diff(hit) != 1) + 1
        starts = np.concatenate([[0], brk])
        ends = np.append(brk, len(hit))
        return [(int(hit[s]), int(hit[e - 1]) + 1) for s, e in zip(starts, ends)]

    def selectivity(self, bbox) -> float:
        """Fraction of shards the query must open (1.0 = no pruning).

        An empty dataset reports 1.0 — "nothing was pruned" — not 0.0,
        which downstream pruning-ratio accounting would read as perfect
        pruning.
        """
        if not len(self):
            return 1.0
        return len(self.query(bbox)) / len(self)

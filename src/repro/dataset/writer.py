"""Sharded dataset writer: partition geometries by SFC key into N shards.

Records are sorted once by their space-filling-curve key (paper §4, over the
*global* extent) and split into ``n_shards`` contiguous key ranges, so each
shard covers a compact region of the curve and shard MBRs stay tight — the
same clustering argument that makes per-page [min,max] statistics selective
(paper Figure 7), lifted one level up. Shards are written pre-sorted
(``sort=None`` at the file level), which makes the concatenation of shards in
manifest order *identical* to one file written with the same global sort:
dataset reads are bit-compatible with single-file reads.

Two APIs, mirroring :mod:`repro.core.writer`:

* :func:`write_dataset` — one-shot convenience, returns the manifest.
* :class:`SpatialDatasetWriter` — buffering writer with ``write_columns`` /
  ``write_geometries`` and a closing partition+flush, for streaming callers.

Writes are **transactional**: shard files are staged through a
:class:`~repro.dataset.catalog.CommitTx` and published by an atomic snapshot
commit (temp file + fsync + rename — see :mod:`repro.dataset.catalog`).
An exception mid-write aborts the transaction and deletes the partial shard
files it staged; a simulated crash
(:class:`~repro.io.faults.InjectedCrash`) leaves them as orphans for the
catalog GC, exactly like a real kill. Either way the directory always
reopens as a complete generation — the previous one until the commit
rename, the new one after it. Writing into a directory that already holds a
dataset layers a *new generation* on top (generation-qualified shard names,
never overwriting live files) instead of clobbering it.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core.columnar import GeometryColumns, shred
from repro.core.sfc import sort_keys
from repro.core.writer import (
    concat_columns,
    permute_records,
    record_centroids,
)

from .catalog import Catalog
from .manifest import DatasetManifest, ShardInfo

SHARD_NAME = "shard-{:05d}.spqf"


def _shard_mbr(cols: GeometryColumns) -> tuple[float, float, float, float]:
    """MBR over every coordinate value; an all-empty shard gets an
    inverted box that no query intersects (it is still read by full scans,
    which never consult MBRs)."""
    if cols.n_values == 0:
        return (float("inf"), float("inf"), float("-inf"), float("-inf"))
    return (
        float(cols.x.min()), float(cols.y.min()),
        float(cols.x.max()), float(cols.y.max()),
    )


class SpatialDatasetWriter:
    """Buffering sharded writer; ``close()`` partitions and writes the lake.

    ``sort`` picks the SFC used for partitioning *and* the record order
    inside each shard ('z' | 'hilbert' | None = arrival order). Remaining
    keyword arguments (``encoding``, ``codec``, ``page_values``,
    ``row_group_records``, ``extra_schema``) pass through to each shard's
    :class:`~repro.core.writer.SpatialParquetWriter`.
    """

    def __init__(
        self,
        root,
        *,
        n_shards: int = 4,
        sort: str | None = "hilbert",
        sfc_order: int = 16,
        encoding: str = "fp_delta",
        codec: str = "none",
        page_values: int = 131072,
        row_group_records: int = 1 << 20,
        extra_schema: dict[str, str] | None = None,
        fsync: bool = True,
    ):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.root = str(root)
        self.n_shards = int(n_shards)
        self.sort = sort
        self.sfc_order = int(sfc_order)
        self.fsync = bool(fsync)
        self.extra_schema = dict(extra_schema or {})
        self._file_kwargs = dict(
            encoding=encoding,
            codec=codec,
            page_values=page_values,
            row_group_records=row_group_records,
            extra_schema=self.extra_schema,
        )
        self._cols_list: list[GeometryColumns] = []
        self._extras: dict[str, list[np.ndarray]] = {k: [] for k in self.extra_schema}
        self._manifest: DatasetManifest | None = None
        self.generation: int | None = None  # set by close()

    # ------------------------------------------------------------------- API
    def write_geometries(self, geometries, extra: dict | None = None) -> None:
        self.write_columns(shred(geometries), extra)

    def write_columns(self, cols: GeometryColumns, extra: dict | None = None) -> None:
        extra = extra or {}
        if set(extra) != set(self.extra_schema):
            raise ValueError(
                f"extra columns {set(extra)} != schema {set(self.extra_schema)}"
            )
        for k, v in extra.items():
            v = np.ascontiguousarray(v, dtype=np.dtype(self.extra_schema[k]))
            if len(v) != cols.n_records:
                raise ValueError(f"extra column {k!r} length mismatch")
            self._extras[k].append(v)
        self._cols_list.append(cols)

    def close(self) -> DatasetManifest:
        if self._manifest is not None:
            return self._manifest
        os.makedirs(self.root, exist_ok=True)
        cols = (
            concat_columns(self._cols_list)
            if self._cols_list
            else GeometryColumns(
                *(np.zeros(0, np.uint8) for _ in range(4)),
                np.zeros(0, np.float64), np.zeros(0, np.float64),
            )
        )
        extras = {
            k: (np.concatenate(v) if v else np.zeros(0, np.dtype(self.extra_schema[k])))
            for k, v in self._extras.items()
        }
        n = cols.n_records
        if self.sort is not None and n > 1:
            cx, cy = record_centroids(cols)
            keys = sort_keys(cx, cy, self.sort, self.sfc_order)
            perm = np.argsort(keys, kind="stable")
        else:
            perm = np.arange(n, dtype=np.int64)

        catalog = Catalog.open(self.root, create=True)
        tx = catalog.begin()
        try:
            shards: list[ShardInfo] = []
            for chunk in np.array_split(perm, self.n_shards):
                if len(chunk) == 0:
                    continue  # fewer records than shards: skip the empty tail
                sub = permute_records(cols, chunk)
                sub_extra = {k: v[chunk] for k, v in extras.items()}
                shards.append(tx.stage_shard(
                    sub, sub_extra, fsync=self.fsync, **self._file_kwargs))
            coord_dtype = (
                np.dtype(cols.x.dtype).str if n else np.dtype(np.float64).str
            )
            manifest = DatasetManifest(
                coord_dtype=coord_dtype,
                codec=self._file_kwargs["codec"],
                encoding=self._file_kwargs["encoding"],
                sort=self.sort,
                extra_schema=self.extra_schema,
                shards=shards,
            )
            snapshot = tx.commit(manifest, fsync=self.fsync)
        except Exception:
            # ordinary failures clean up their partial shard files; a
            # simulated crash (InjectedCrash is a BaseException) skips this
            # by design and leaves the orphans to catalog GC
            tx.abort()
            raise
        self._manifest = manifest
        self.generation = snapshot.generation
        return self._manifest

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def write_dataset(
    root,
    geometries=None,
    columns: GeometryColumns | None = None,
    extra: dict | None = None,
    **kwargs,
) -> DatasetManifest:
    """One-shot sharded write; returns the saved manifest.

    ``extra_schema`` is inferred from ``extra`` arrays when not given.
    """
    if extra and "extra_schema" not in kwargs:
        kwargs["extra_schema"] = {
            k: np.asarray(v).dtype.str for k, v in extra.items()
        }
    with SpatialDatasetWriter(root, **kwargs) as w:
        if geometries is not None:
            w.write_geometries(geometries, extra)
        if columns is not None:
            w.write_columns(columns, extra)
    return w.close()

"""Dataset manifest: the JSON catalog of a sharded Spatial Parquet lake.

A *dataset* is a directory of ``.spqf`` shard files plus a ``manifest.json``
describing them — the multi-file analog of one file's footer. Per shard it
records the MBR (the shard-level spatial index pruned before any shard file
is even opened), row/value counts, and the page/byte totals needed to keep
:class:`~repro.core.reader.ReadStats` honest for shards that were pruned
without being read. Dataset-wide schema (coordinate dtype, codec, encoding,
extra columns, SFC sort method) lives at the top level so every shard is
interchangeable.

The manifest is deliberately plain JSON (not msgpack like the footer): it is
the human-visible catalog of the lake, the piece an external orchestrator
(or a later object-store layout) would list and diff.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

from .errors import DatasetError

MANIFEST_NAME = "manifest.json"
DATASET_FORMAT = "spatial-parquet-dataset"
MANIFEST_VERSION = 1


@dataclass
class ShardInfo:
    """One shard's catalog entry (everything pruning needs, file unopened)."""

    path: str  # relative to the dataset root
    mbr: tuple[float, float, float, float]  # xmin, ymin, xmax, ymax
    n_records: int
    n_values: int
    n_pages: int  # x/y page pairs (per-page index size)
    data_bytes: int  # stored bytes of every blob in the shard
    file_bytes: int  # on-disk size incl. magic + footer
    crc32c: int | None = None  # whole-file CRC-32C (catalog commits set it)
    # per-column zone map: {col: {"min", "max", "nnan", "count"}} over the
    # whole shard (min/max are None when the column has no non-NaN values);
    # lets DatasetIndex.query(bbox, filter=) prune the shard from the
    # manifest alone, before its file is opened. Optional: older snapshots
    # and pre-zone-map shards simply never get predicate-pruned.
    zone_maps: dict | None = None

    def to_dict(self) -> dict:
        d = {
            "path": self.path,
            "mbr": [float(v) for v in self.mbr],
            "n_records": int(self.n_records),
            "n_values": int(self.n_values),
            "n_pages": int(self.n_pages),
            "data_bytes": int(self.data_bytes),
            "file_bytes": int(self.file_bytes),
        }
        if self.crc32c is not None:
            d["crc32c"] = int(self.crc32c)
        if self.zone_maps is not None:
            d["zone_maps"] = {
                k: {
                    "min": None if z["min"] is None else float(z["min"]),
                    "max": None if z["max"] is None else float(z["max"]),
                    "nnan": int(z["nnan"]),
                    "count": int(z["count"]),
                }
                for k, z in self.zone_maps.items()
            }
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ShardInfo":
        return cls(
            path=d["path"],
            mbr=tuple(d["mbr"]),
            n_records=d["n_records"],
            n_values=d["n_values"],
            n_pages=d["n_pages"],
            data_bytes=d["data_bytes"],
            file_bytes=d["file_bytes"],
            crc32c=d.get("crc32c"),
            zone_maps=d.get("zone_maps"),
        )

    def validate(self, index: int, where: str) -> None:
        """Structural checks beyond mere key presence (see ``load``)."""
        who = f"{where}: shards[{index}]"
        if not isinstance(self.path, str) or not self.path:
            raise DatasetError(f"{who}: 'path' must be a non-empty string")
        p = self.path.replace("\\", "/")
        if p.startswith("/") or p.startswith("~") or ".." in p.split("/"):
            # shard paths are catalog-relative by contract; an absolute or
            # parent-escaping path would let a manifest read arbitrary files
            raise DatasetError(
                f"{who}: path {self.path!r} escapes the dataset root")
        if len(self.mbr) != 4 or not all(
                isinstance(v, (int, float)) for v in self.mbr):
            raise DatasetError(f"{who}: 'mbr' must be 4 numbers, got "
                               f"{self.mbr!r}")
        for k in ("n_records", "n_values", "n_pages", "data_bytes",
                  "file_bytes"):
            v = getattr(self, k)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                raise DatasetError(
                    f"{who}: {k!r} must be a non-negative integer, got {v!r}")
        if self.crc32c is not None and (
                not isinstance(self.crc32c, int) or isinstance(self.crc32c, bool)
                or not (0 <= self.crc32c < 1 << 32)):
            raise DatasetError(
                f"{who}: 'crc32c' must be a uint32, got {self.crc32c!r}")
        if self.zone_maps is not None:
            if not isinstance(self.zone_maps, dict):
                raise DatasetError(
                    f"{who}: 'zone_maps' must be an object, got "
                    f"{type(self.zone_maps).__name__}")
            for col, z in self.zone_maps.items():
                zwho = f"{who}: zone_maps[{col!r}]"
                if not isinstance(z, dict) or not {
                        "min", "max", "nnan", "count"} <= set(z):
                    raise DatasetError(
                        f"{zwho}: needs min/max/nnan/count, got {z!r}")
                for k in ("min", "max"):
                    if z[k] is not None and not isinstance(
                            z[k], (int, float)):
                        raise DatasetError(
                            f"{zwho}: {k!r} must be a number or null, got "
                            f"{z[k]!r}")
                for k in ("nnan", "count"):
                    if (not isinstance(z[k], int) or isinstance(z[k], bool)
                            or z[k] < 0):
                        raise DatasetError(
                            f"{zwho}: {k!r} must be a non-negative integer, "
                            f"got {z[k]!r}")
                if (z["min"] is None) != (z["max"] is None):
                    raise DatasetError(
                        f"{zwho}: min/max must be both set or both null")


@dataclass
class DatasetManifest:
    coord_dtype: str
    codec: str
    encoding: str
    sort: str | None
    extra_schema: dict[str, str]
    shards: list[ShardInfo] = field(default_factory=list)
    version: int = MANIFEST_VERSION

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_records(self) -> int:
        return sum(s.n_records for s in self.shards)

    @property
    def n_values(self) -> int:
        return sum(s.n_values for s in self.shards)

    @property
    def mbr(self) -> tuple[float, float, float, float] | None:
        """Union MBR of all shards (None for an empty dataset)."""
        boxes = [s.mbr for s in self.shards if s.mbr[0] <= s.mbr[2]]
        if not boxes:
            return None
        return (
            min(b[0] for b in boxes),
            min(b[1] for b in boxes),
            max(b[2] for b in boxes),
            max(b[3] for b in boxes),
        )

    def to_dict(self) -> dict:
        return {
            "format": DATASET_FORMAT,
            "version": self.version,
            "coord_dtype": self.coord_dtype,
            "codec": self.codec,
            "encoding": self.encoding,
            "sort": self.sort,
            "extra_schema": dict(self.extra_schema),
            "n_shards": self.n_shards,
            "n_records": self.n_records,
            "shards": [s.to_dict() for s in self.shards],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1) + "\n"

    def save(self, root, *, fsync: bool = True) -> str:
        """Write ``manifest.json`` atomically (temp + fsync + rename).

        A crashed save can therefore never leave a torn manifest — only the
        complete old or complete new one (plus an orphan temp file the
        catalog GC removes).
        """
        from repro.io.durable import write_atomic

        path = os.path.join(str(root), MANIFEST_NAME)
        write_atomic(path, self.to_json().encode(), fsync=fsync)
        return path

    @classmethod
    def from_dict(cls, d, where: str = "<manifest>") -> "DatasetManifest":
        """Validate a parsed manifest object (shared by ``manifest.json``
        and the catalog's snapshot files, which embed the same structure).

        Any way the catalog can be wrong — wrong ``format`` tag, too-new
        version, missing keys, malformed shard entries, totals that do not
        add up — raises an attributed
        :class:`~repro.dataset.errors.DatasetError` naming ``where`` and the
        offending field, never a raw ``KeyError`` / ``TypeError``.
        """
        path = where
        if not isinstance(d, dict):
            raise DatasetError(
                f"{path}: manifest must be a JSON object, got "
                f"{type(d).__name__}")
        if d.get("format") != DATASET_FORMAT:
            raise DatasetError(
                f"{path}: not a {DATASET_FORMAT} manifest "
                f"(format={d.get('format')!r})")
        version = d.get("version", 0)
        if not isinstance(version, int) or version < 1:
            raise DatasetError(f"{path}: bad manifest version {version!r}")
        if version > MANIFEST_VERSION:
            raise DatasetError(
                f"{path}: manifest version {version} is newer than this "
                f"library understands (<= {MANIFEST_VERSION})")
        for key in ("coord_dtype", "codec", "encoding", "shards"):
            if key not in d:
                raise DatasetError(f"{path}: manifest missing key {key!r}")
        if not isinstance(d["shards"], list):
            raise DatasetError(f"{path}: 'shards' must be a list, got "
                               f"{type(d['shards']).__name__}")
        shards = []
        for i, s in enumerate(d["shards"]):
            if not isinstance(s, dict):
                raise DatasetError(
                    f"{path}: shards[{i}] must be an object, got "
                    f"{type(s).__name__}")
            try:
                info = ShardInfo.from_dict(s)
            except KeyError as exc:
                raise DatasetError(
                    f"{path}: shards[{i}] missing key {exc.args[0]!r}"
                ) from None
            except (TypeError, ValueError) as exc:
                raise DatasetError(
                    f"{path}: shards[{i}] malformed: {exc}") from exc
            info.validate(i, path)
            shards.append(info)
        extra_schema = d.get("extra_schema", {})
        if not isinstance(extra_schema, dict):
            raise DatasetError(f"{path}: 'extra_schema' must be an object")
        manifest = cls(
            coord_dtype=d["coord_dtype"],
            codec=d["codec"],
            encoding=d["encoding"],
            sort=d.get("sort"),
            extra_schema=dict(extra_schema),
            shards=shards,
            version=version,
        )
        for key, actual in (("n_shards", manifest.n_shards),
                            ("n_records", manifest.n_records)):
            declared = d.get(key)
            if declared is not None and declared != actual:
                raise DatasetError(
                    f"{path}: declared {key}={declared} but shard entries "
                    f"give {actual} (partial write?)")
        return manifest

    @classmethod
    def load(cls, root) -> "DatasetManifest":
        """Load and validate from a dataset directory (or a manifest.json
        path directly); see :meth:`from_dict` for the validation contract.

        Note: for catalog-managed datasets ``manifest.json`` is an
        atomically-maintained *mirror* of the newest committed snapshot —
        generation-aware readers should go through
        :class:`~repro.dataset.catalog.Catalog` instead.
        """
        path = str(root)
        if os.path.isdir(path):
            path = os.path.join(path, MANIFEST_NAME)
        try:
            with open(path) as fh:
                d = json.load(fh)
        except FileNotFoundError:
            raise DatasetError(
                f"{path}: no manifest found (not a dataset directory?)"
            ) from None
        except json.JSONDecodeError as exc:
            raise DatasetError(
                f"{path}: manifest is not valid JSON "
                f"(truncated or partially written?): {exc}") from exc
        except OSError as exc:
            raise DatasetError(f"{path}: cannot read manifest: {exc}") from exc
        return cls.from_dict(d, where=path)


def is_dataset(path) -> bool:
    """True if ``path`` is a dataset directory (holds a manifest.json)."""
    p = str(path)
    return os.path.isdir(p) and os.path.isfile(os.path.join(p, MANIFEST_NAME))


def shard_path(root, shard: ShardInfo) -> str:
    """Absolute path of a shard file under the dataset root."""
    return os.path.join(str(root), shard.path)

"""Async dataset scanner: fan a bbox query out over surviving shards.

The scan pipeline per query:

1. :class:`DatasetIndex` prunes whole shards by MBR (no file opened).
2. Surviving shards are submitted to a thread pool in manifest order; each
   worker opens its shard, runs the coalesced-range ``read_columnar`` path
   (per-page pruning + single ``readinto`` per merged run), and decodes.
   With ``max_workers >= 2`` the blocking range reads of shard N+1 overlap
   the numpy decode of shard N (file I/O releases the GIL); within a shard,
   the reader additionally double-buffers row groups.
3. Results are gathered in submission order — concatenated geometry/extra
   columns are **bit-identical** to a sequential shard-by-shard read,
   regardless of worker completion order.

Device scans: ``device="jax"`` runs each shard's page decode on the
accelerator; with ``refine=True`` the per-record bbox test is fused into the
same launch chain (only surviving records transfer), and
``keep_on_device=True`` merges shard results into device-resident
:class:`~repro.core.columnar.DeviceCoords` without any host round-trip.
Worker threads share one process-wide AOT compile cache
(``repro.kernels.fp_delta.ops``): shard streams are pow2-shape-bucketed and
tracing is serialized behind a lock, so an N-shard scan traces each shape
bucket exactly once instead of retracing per worker.

Aggregated :class:`~repro.core.reader.ReadStats` merge every scanned shard's
account plus the page/byte totals of pruned shards (read side zero), so
pruning ratios are measured against the whole dataset.
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.columnar import GeometryColumns, assemble
from repro.core.geometry import Geometry
from repro.core.reader import ReadStats, SpatialParquetReader
from repro.core.writer import concat_columns

from .index import DatasetIndex
from .manifest import DatasetManifest, shard_path


class SpatialDatasetScanner:
    """Query interface over a sharded Spatial Parquet dataset."""

    def __init__(self, root, *, max_workers: int = 4,
                 coalesce_max_gap: int = 1 << 16, prefetch_row_groups: int = 1):
        self.root = str(root)
        self.manifest = DatasetManifest.load(root)
        self.index = DatasetIndex(self.manifest)
        self.max_workers = max(1, int(max_workers))
        self.coalesce_max_gap = int(coalesce_max_gap)
        self.prefetch_row_groups = int(prefetch_row_groups)
        self.extra_schema = dict(self.manifest.extra_schema)
        self.n_records = self.manifest.n_records

    # ------------------------------------------------------------- internals
    def _read_shard(self, shard_i: int, bbox, columns, refine, coalesce,
                    device, keep_on_device):
        path = shard_path(self.root, self.manifest.shards[shard_i])
        with SpatialParquetReader(
            path, coalesce_max_gap=self.coalesce_max_gap,
            prefetch_row_groups=self.prefetch_row_groups,
        ) as r:
            return r.read_columnar(
                bbox=bbox, columns=columns, refine=refine, coalesce=coalesce,
                device=device, keep_on_device=keep_on_device,
            )

    # -------------------------------------------------------------- scan API
    def scan(
        self,
        bbox=None,
        columns: tuple[str, ...] | None = None,
        refine: bool = False,
        parallel: bool = True,
        coalesce: bool = True,
        device: str = "cpu",
        *,
        keep_on_device: bool = False,
    ) -> tuple[GeometryColumns | None, dict[str, np.ndarray], ReadStats]:
        """Dataset-wide ``read_columnar``: shard pruning + parallel fan-out.

        Same contract as the single-file reader, one level up; ``parallel=
        False`` forces a sequential shard loop (identical results, used by
        the equivalence tests). ``device="jax"`` runs each shard's FP-delta
        page decode on the accelerator (bit-identical results); with
        ``refine=True`` the bbox refinement is fused into the shard's decode
        launch so pruned records never reach the host, and with
        ``max_workers >= 2`` shard N's device work overlaps shard N+1's
        coalesced range reads, exactly like the host decode.
        ``keep_on_device=True`` returns device-resident coordinates merged
        across shards on the accelerator.
        """
        hit = self.index.query(bbox)
        hit_set = set(int(i) for i in hit)
        stats = ReadStats(shards_total=len(self.index), shards_read=len(hit))
        # pruned shards still count toward the totals (read side stays zero)
        for i, shard in enumerate(self.manifest.shards):
            if i not in hit_set:
                stats.pages_total += shard.n_pages
                stats.bytes_total += shard.data_bytes

        if len(hit) == 0:
            results = []
        elif parallel and self.max_workers > 1 and len(hit) > 1:
            with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
                futures = [
                    pool.submit(self._read_shard, int(i), bbox, columns,
                                refine, coalesce, device, keep_on_device)
                    for i in hit
                ]
                # gather in submission (manifest) order: deterministic output
                results = [f.result() for f in futures]
        else:
            results = [
                self._read_shard(int(i), bbox, columns, refine, coalesce,
                                 device, keep_on_device)
                for i in hit
            ]

        geos = [g for g, _, _ in results if g is not None]
        # concat_columns merges DeviceCoords shards on the accelerator
        geo = concat_columns(geos) if geos else None
        extras: dict[str, np.ndarray] = {}
        if results:
            for k in results[0][1]:
                extras[k] = np.concatenate([ex[k] for _, ex, _ in results])
        stats = sum((st for _, _, st in results), stats)
        return geo, extras, stats

    def read_columnar(
        self,
        bbox=None,
        columns: tuple[str, ...] | None = None,
        refine: bool = False,
        coalesce: bool = True,
        device: str = "cpu",
        parallel: bool = True,
        *,
        keep_on_device: bool = False,
    ):
        """Drop-in for :meth:`SpatialParquetReader.read_columnar` (same
        positional order; the extra ``parallel`` knob comes last,
        ``keep_on_device`` is keyword-only everywhere)."""
        return self.scan(
            bbox=bbox, columns=columns, refine=refine,
            parallel=parallel, coalesce=coalesce, device=device,
            keep_on_device=keep_on_device,
        )

    def read(self, bbox=None, refine: bool = False) -> tuple[list[Geometry], ReadStats]:
        """Object-API read returning Geometry instances (like the reader's)."""
        geo, _, stats = self.scan(bbox=bbox, refine=refine)
        return (assemble(geo) if geo is not None else []), stats

    def shard_paths(self, bbox=None) -> list[str]:
        """Absolute paths of shards surviving bbox pruning, manifest order
        (the unit the training pipeline stripes over)."""
        return [
            shard_path(self.root, self.manifest.shards[int(i)])
            for i in self.index.query(bbox)
        ]

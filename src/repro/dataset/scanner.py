"""Async dataset scanner: fan a bbox query out over surviving shards.

The scan pipeline per query:

1. :class:`DatasetIndex` prunes whole shards by MBR (no file opened).
2. Surviving shards are submitted to a thread pool in manifest order; each
   worker opens its shard, runs the coalesced-range ``read_columnar`` path
   (per-page pruning + single ``readinto`` per merged run), and decodes.
   With ``max_workers >= 2`` the blocking range reads of shard N+1 overlap
   the numpy decode of shard N (file I/O releases the GIL); within a shard,
   the reader additionally double-buffers row groups.
3. Results are gathered in submission order — concatenated geometry/extra
   columns are **bit-identical** to a sequential shard-by-shard read,
   regardless of worker completion order.

Device scans: ``device="jax"`` runs each shard's page decode on the
accelerator; with ``refine=True`` the per-record bbox test is fused into the
same launch chain (only surviving records transfer), and
``keep_on_device=True`` merges shard results into device-resident
:class:`~repro.core.columnar.DeviceCoords` without any host round-trip.
Worker threads share one process-wide AOT compile cache
(``repro.kernels.fp_delta.ops``): shard streams are pow2-shape-bucketed and
tracing is serialized behind a lock, so an N-shard scan traces each shape
bucket exactly once instead of retracing per worker.

Aggregated :class:`~repro.core.reader.ReadStats` merge every scanned shard's
account plus the page/byte totals of pruned shards (read side zero), so
pruning ratios are measured against the whole dataset.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro import obs
from repro.core.columnar import GeometryColumns, assemble
from repro.core.geometry import Geometry
from repro.core.reader import ReadStats, SpatialParquetReader
from repro.core.writer import concat_columns
from repro.io.source import LocalFileSource, SourceStats

from .catalog import Catalog
from .errors import ShardFailure, ShardReadError
from .index import DatasetIndex
from .manifest import DatasetManifest, shard_path

ON_ERROR_POLICIES = ("raise", "retry", "skip")


class SpatialDatasetScanner:
    """Query interface over a sharded Spatial Parquet dataset.

    ``on_error`` sets the degraded-mode policy for shards whose reads fail
    even after the byte source's own retry/backoff: ``"raise"`` (default)
    wraps the cause in an attributed :class:`ShardReadError`; ``"retry"``
    re-opens the failing shard from scratch up to ``shard_retries`` more
    times (a fresh reader + source per attempt, so poisoned state cannot
    carry over) and raises only when those are exhausted; ``"skip"`` does
    the same retries but then drops the shard, recording a
    :class:`ShardFailure` in ``stats.failures`` — the scan returns every
    healthy shard's records, bit-identical to a clean scan minus the skipped
    shards.

    ``source_factory``, if given, maps a shard's absolute path to a
    :class:`~repro.io.source.ByteRangeSource` — the hook that points a scan
    at remote storage (e.g. ``lambda p: RemoteRangeSource(server_for(p))``)
    without the scanner knowing anything about transports.

    Snapshot isolation: every scan **pins** one committed catalog generation
    for its whole duration, so a concurrent compaction / rewrite commit (and
    the GC that follows it) can neither change nor delete what the scan is
    reading — results are bit-identical to running against that generation
    alone. By default each scan pins the newest generation at its start;
    ``pin_generation=N`` pins generation ``N`` for the scanner's lifetime
    instead (release it with :meth:`close`). Legacy manifest-only
    directories behave as generation 0.
    """

    def __init__(self, root, *, max_workers: int = 4,
                 coalesce_max_gap: int = 1 << 16, prefetch_row_groups: int = 1,
                 on_error: str = "raise", shard_retries: int = 1,
                 source_factory=None, verify_checksums: bool = True,
                 pin_generation: int | None = None):
        self.root = str(root)
        self.catalog = Catalog.open(root)
        self._pin = (self.catalog.pin(pin_generation)
                     if pin_generation is not None else None)
        snap = (self._pin.snapshot if self._pin is not None
                else self.catalog.head_snapshot())
        self.generation = snap.generation
        self.manifest = snap.manifest
        self.index = DatasetIndex(self.manifest)
        self._views: dict[int, tuple[DatasetManifest, DatasetIndex]] = {
            self.generation: (self.manifest, self.index)}
        self.max_workers = max(1, int(max_workers))
        self.coalesce_max_gap = int(coalesce_max_gap)
        self.prefetch_row_groups = int(prefetch_row_groups)
        if on_error not in ON_ERROR_POLICIES:
            raise ValueError(
                f"on_error must be one of {ON_ERROR_POLICIES}, got {on_error!r}")
        self.on_error = on_error
        self.shard_retries = max(0, int(shard_retries))
        self.source_factory = source_factory
        self.verify_checksums = bool(verify_checksums)
        self.extra_schema = dict(self.manifest.extra_schema)
        self.n_records = self.manifest.n_records

    # ----------------------------------------------------------- generations
    def refresh(self) -> int:
        """Adopt the newest committed generation (no-op while pinned).

        Returns the generation the scanner now serves; the serve tier calls
        this between admission waves so a compaction commit invalidates its
        caches instead of silently serving a stale (or GC'd) layout.
        """
        if self._pin is not None:
            return self.generation
        snap = self.catalog.head_snapshot()
        if snap.generation != self.generation:
            manifest = snap.manifest
            index = DatasetIndex(manifest)
            self._views[snap.generation] = (manifest, index)
            self.generation = snap.generation
            self.manifest = manifest
            self.index = index
            self.extra_schema = dict(manifest.extra_schema)
            self.n_records = manifest.n_records
        return self.generation

    def _view(self, generation: int) -> tuple[DatasetManifest, DatasetIndex]:
        """(manifest, index) for one pinned generation (memoized)."""
        view = self._views.get(generation)
        if view is None:
            manifest = self.catalog.load_snapshot(generation).manifest
            view = (manifest, DatasetIndex(manifest))
            if len(self._views) > 8:  # old generations: drop the memo only
                self._views.clear()
                self._views[self.generation] = (self.manifest, self.index)
            self._views[generation] = view
        return view

    def close(self) -> None:
        """Release the lifetime pin (``pin_generation`` mode); idempotent."""
        if self._pin is not None:
            self._pin.release()
            self._pin = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------- internals
    def _open_source(self, path: str):
        if self.source_factory is not None:
            return self.source_factory(path)
        return LocalFileSource(path)

    def _open_shard(self, path: str) -> SpatialParquetReader:
        return SpatialParquetReader(
            source=self._open_source(path),
            coalesce_max_gap=self.coalesce_max_gap,
            prefetch_row_groups=self.prefetch_row_groups,
            verify_checksums=self.verify_checksums)

    def open_shard(self, shard_i: int) -> SpatialParquetReader:
        """Open shard ``shard_i`` as a long-lived reader (caller closes).

        The serve tier (:mod:`repro.serve.query_scheduler`) keeps these open
        across queries so row-group decodes can be shared; one-shot scans
        should keep using :meth:`scan`, which owns its readers per call.
        """
        return self._open_shard(shard_path(self.root, self.manifest.shards[shard_i]))

    def _read_shard_once(self, path: str, bbox, columns, refine, coalesce,
                         device, keep_on_device, filter):
        src = self._open_source(path)
        try:
            with SpatialParquetReader(
                    source=src, coalesce_max_gap=self.coalesce_max_gap,
                    prefetch_row_groups=self.prefetch_row_groups,
                    verify_checksums=self.verify_checksums) as r:
                return r.read_columnar(
                    bbox=bbox, columns=columns, refine=refine,
                    coalesce=coalesce, device=device,
                    keep_on_device=keep_on_device, filter=filter,
                )
        except Exception as exc:
            # a failed attempt still did real I/O (and maybe retried,
            # timed out, hit the cache); hand its accrued SourceStats to
            # the caller so degraded scans keep the counters. Each attempt
            # gets a fresh source, so .stats IS the attempt's delta.
            exc.spqf_source_stats = src.stats.copy()
            raise

    def _read_shard(self, manifest: DatasetManifest, shard_i: int, bbox,
                    columns, refine, coalesce, device, keep_on_device,
                    filter):
        """Read one shard under the scanner's error policy.

        ``manifest`` is the scan's pinned snapshot — passed explicitly so a
        concurrent :meth:`refresh` can never mix two generations' shard
        lists inside one scan.

        Returns ``(result, extra_attempts, failure, failed_stats)`` where
        exactly one of ``result`` / ``failure`` is set and ``failed_stats``
        is the summed :class:`SourceStats` of every *failed* attempt (the
        successful attempt folds its own deltas inside ``read_columnar``);
        raises only under ``on_error="raise"`` (immediately) or ``"retry"``
        (after exhausting ``shard_retries``), always as an attributed
        :class:`ShardReadError`.
        """
        path = shard_path(self.root, manifest.shards[shard_i])
        retries = 0 if self.on_error == "raise" else self.shard_retries
        last: Exception | None = None
        failed = SourceStats()
        with obs.span("shard", shard=shard_i, path=path):
            for attempt in range(retries + 1):
                try:
                    res = self._read_shard_once(
                        path, bbox, columns, refine, coalesce, device,
                        keep_on_device, filter)
                    return res, attempt, None, failed
                except Exception as exc:
                    last = exc
                    partial = getattr(exc, "spqf_source_stats", None)
                    if partial is not None:
                        failed = failed + partial
                    obs.instant("shard.error", shard=shard_i,
                                attempt=attempt, error=type(exc).__name__)
        if self.on_error == "skip":
            obs.instant("shard.skip", shard=shard_i,
                        error=type(last).__name__)
            failure = ShardFailure.from_error(shard_i, path, last, retries + 1)
            return None, retries, failure, failed
        raise ShardReadError(shard_i, path, last) from last

    # -------------------------------------------------------------- scan API
    def scan(
        self,
        bbox=None,
        columns: tuple[str, ...] | None = None,
        refine: bool = False,
        parallel: bool = True,
        coalesce: bool = True,
        device: str = "cpu",
        *,
        keep_on_device: bool = False,
        filter=None,
    ) -> tuple[GeometryColumns | None, dict[str, np.ndarray], ReadStats]:
        """Dataset-wide ``read_columnar``: shard pruning + parallel fan-out.

        Same contract as the single-file reader, one level up; ``parallel=
        False`` forces a sequential shard loop (identical results, used by
        the equivalence tests). ``device="jax"`` runs each shard's FP-delta
        page decode on the accelerator (bit-identical results); with
        ``refine=True`` the bbox refinement is fused into the shard's decode
        launch so pruned records never reach the host, and with
        ``max_workers >= 2`` shard N's device work overlaps shard N+1's
        coalesced range reads, exactly like the host decode.
        ``keep_on_device=True`` returns device-resident coordinates merged
        across shards on the accelerator.

        ``filter`` is an attribute predicate
        (:class:`~repro.core.filters.Predicate`); shards whose manifest
        zone maps cannot match are pruned before their files are opened
        (counted in ``pruned.zone_bytes``), surviving shards apply the same
        predicate at page and record granularity, and results equal a full
        scan masked by the predicate row-by-row.

        With telemetry on (``repro.obs.enable()``) the query runs inside a
        ``scan.dataset`` span with one ``shard`` child span per surviving
        shard (worker threads inherit the span context), and on return
        records the end-to-end latency histogram, the
        ``scan.host_cpu_s_per_gb`` gauge and the shard-level pruned-bytes
        counter. Telemetry off is the plain, allocation-identical path.
        """
        if not obs.enabled():
            return self._scan_impl(bbox, columns, refine, parallel, coalesce,
                                   device, keep_on_device, filter)
        t0 = time.perf_counter()
        c0 = time.process_time()
        with obs.span("scan.dataset", root=self.root, device=device,
                      refine=bool(refine),
                      filtered=filter is not None) as sp:
            geo, extras, stats = self._scan_impl(
                bbox, columns, refine, parallel, coalesce, device,
                keep_on_device, filter)
            sp.add(shards_read=stats.shards_read,
                   records=stats.records_returned)
        wall = time.perf_counter() - t0
        cpu = time.process_time() - c0
        obs.observe("scan.dataset_latency_s", wall)
        scanned_gb = stats.bytes_read / 1e9
        if scanned_gb > 0:
            # the aggregate wins over the per-shard values set mid-scan
            obs.gauge("scan.host_cpu_s_per_gb", cpu / scanned_gb)
        return geo, extras, stats

    def _scan_impl(self, bbox, columns, refine, parallel, coalesce, device,
                   keep_on_device, filter=None):
        # every scan holds a pin on its generation for its whole duration:
        # a compaction commit + GC racing the scan cannot delete the shard
        # files this scan is reading. Unpinned scanners pin the *current
        # head* (resolved atomically inside pin()), not the generation last
        # seen by __init__/refresh() — a long-lived scanner keeps working
        # after a live compactor retires that remembered generation from
        # the retention window. Lifetime-pinned scanners reuse their pin.
        pin = self._pin
        release = pin is None
        if release:
            pin = self.catalog.pin()
        generation = pin.generation
        try:
            manifest, index = self._view(generation)
            return self._scan_pinned(
                manifest, index, bbox, columns, refine, parallel, coalesce,
                device, keep_on_device, filter)
        finally:
            if release:
                pin.release()

    def _scan_pinned(self, manifest, index, bbox, columns, refine, parallel,
                     coalesce, device, keep_on_device, filter=None):
        hit = index.query(bbox, filter=filter)
        hit_set = set(int(i) for i in hit)
        stats = ReadStats(shards_total=len(index), shards_read=len(hit))
        # pruned shards still count toward the totals (read side stays zero)
        pruned_bytes = 0
        for i, shard in enumerate(manifest.shards):
            if i not in hit_set:
                stats.pages_total += shard.n_pages
                stats.bytes_total += shard.data_bytes
                pruned_bytes += shard.data_bytes
        obs.count("pruned.shard_bytes", pruned_bytes)
        if filter is not None and obs.enabled():
            # shards inside the bbox that only the zone maps eliminated
            zoned = np.setdiff1d(index.query(bbox), hit, assume_unique=True)
            obs.count("pruned.zone_bytes", int(sum(
                manifest.shards[int(i)].data_bytes for i in zoned)))

        if len(hit) == 0:
            outcomes = []
        elif parallel and self.max_workers > 1 and len(hit) > 1:
            with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
                futures = [
                    obs.submit(pool, self._read_shard, manifest, int(i), bbox,
                               columns, refine, coalesce, device,
                               keep_on_device, filter)
                    for i in hit
                ]
                # gather in submission (manifest) order: deterministic output
                outcomes = [f.result() for f in futures]
        else:
            outcomes = [
                self._read_shard(manifest, int(i), bbox, columns, refine,
                                 coalesce, device, keep_on_device, filter)
                for i in hit
            ]

        # degraded-mode accounting: skipped shards leave the result but are
        # attributed in stats.failures; extra per-shard attempts accumulate,
        # and the partial SourceStats of every *failed* attempt fold into the
        # aggregate so retry/timeout/cache counters survive degraded scans
        results = []
        for res, attempts, failure, failed_src in outcomes:
            stats.shard_retries += attempts
            stats.retries += failed_src.retries
            stats.timeouts += failed_src.timeouts
            stats.cache_hits += failed_src.cache_hits
            stats.cache_misses += failed_src.cache_misses
            obs.fold_source_stats(failed_src, prefix="io.failed_attempts")
            if failure is not None:
                stats.failures.append(failure)
                stats.shards_read -= 1  # it never contributed bytes/records
            else:
                results.append(res)
        obs.count("read.shard_retries", stats.shard_retries)
        obs.count("read.shards_failed", len(stats.failures))
        obs.count("read.shards_total", stats.shards_total)
        obs.count("read.shards_read", stats.shards_read)

        geos = [g for g, _, _ in results if g is not None]
        # concat_columns merges DeviceCoords shards on the accelerator
        geo = concat_columns(geos) if geos else None
        extras: dict[str, np.ndarray] = {}
        if results:
            for k in results[0][1]:
                extras[k] = np.concatenate([ex[k] for _, ex, _ in results])
        stats = sum((st for _, _, st in results), stats)
        return geo, extras, stats

    def read_columnar(
        self,
        bbox=None,
        columns: tuple[str, ...] | None = None,
        refine: bool = False,
        coalesce: bool = True,
        device: str = "cpu",
        parallel: bool = True,
        *,
        keep_on_device: bool = False,
        filter=None,
    ):
        """Drop-in for :meth:`SpatialParquetReader.read_columnar` (same
        positional order; the extra ``parallel`` knob comes last,
        ``keep_on_device``/``filter`` are keyword-only everywhere)."""
        return self.scan(
            bbox=bbox, columns=columns, refine=refine,
            parallel=parallel, coalesce=coalesce, device=device,
            keep_on_device=keep_on_device, filter=filter,
        )

    def read(self, bbox=None, refine: bool = False) -> tuple[list[Geometry], ReadStats]:
        """Object-API read returning Geometry instances (like the reader's)."""
        geo, _, stats = self.scan(bbox=bbox, refine=refine)
        return (assemble(geo) if geo is not None else []), stats

    def shard_paths(self, bbox=None) -> list[str]:
        """Absolute paths of shards surviving bbox pruning, manifest order
        (the unit the training pipeline stripes over)."""
        return [
            shard_path(self.root, self.manifest.shards[int(i)])
            for i in self.index.query(bbox)
        ]

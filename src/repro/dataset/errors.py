"""Dataset-level error types: attributed failures for degraded-mode scans.

A lake-scale scan has two tiers of failure. The *catalog* tier — a missing,
malformed or partially-written ``manifest.json`` — is always fatal and
surfaces as :class:`DatasetError` with the offending path and field spelled
out (never a raw ``KeyError`` or ``JSONDecodeError``). The *shard* tier — a
single shard failing its reads even after the source's own retry/backoff —
is governed by the scanner's ``on_error`` policy: ``"raise"`` wraps the
cause in :class:`ShardReadError` (which names the shard), ``"retry"``
re-opens the shard up to ``shard_retries`` times before raising, and
``"skip"`` drops the shard from the result and records a
:class:`ShardFailure` in ``ReadStats.failures`` so callers can see exactly
what a degraded answer is missing.
"""

from __future__ import annotations

from dataclasses import dataclass


class DatasetError(RuntimeError):
    """A dataset catalog problem: missing/malformed/partial manifest."""


class CommitConflict(DatasetError):
    """A snapshot commit lost the generation race.

    The commit's target generation was taken by another writer between
    ``begin()`` and the rename; the loser's staged files are aborted (or
    left for GC) and the caller decides whether to rebase and retry.
    """


class ShardReadError(RuntimeError):
    """One shard of a dataset failed to read (cause chained).

    Carries the shard's manifest index and path so a multi-shard failure is
    attributable without re-running the scan.
    """

    def __init__(self, shard_index: int, path: str, cause: Exception):
        super().__init__(
            f"shard {shard_index} ({path}) failed: "
            f"{type(cause).__name__}: {cause}"
        )
        self.shard_index = int(shard_index)
        self.path = str(path)
        self.cause = cause


@dataclass
class ShardFailure:
    """Record of one shard skipped by an ``on_error="skip"`` scan."""

    shard_index: int
    path: str
    error_type: str
    message: str
    attempts: int

    @staticmethod
    def from_error(shard_index: int, path: str, exc: Exception,
                   attempts: int) -> "ShardFailure":
        return ShardFailure(
            shard_index=int(shard_index),
            path=str(path),
            error_type=type(exc).__name__,
            message=str(exc),
            attempts=int(attempts),
        )

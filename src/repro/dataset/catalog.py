"""Crash-safe transactional catalog: snapshot-isolated dataset commits.

PR 6 made the *read* path survive a flaky store; this module makes the
*write* path survive a dying writer. Every dataset mutation is an atomic
commit of a new **snapshot** file::

    lake/
      shard-00000.spqf                     # generation 1 data files
      shard-g000002-4f9a01c2-00000.spqf    # files of later generations
      snap-0000000001.json        # snapshot: shard entries + MBRs + CRCs
      snap-0000000002.json
      HEAD                        # pointer hint (healed on open)
      manifest.json               # legacy mirror of the newest snapshot

A snapshot lists the shard entries (paths, MBRs, whole-file CRC-32Cs) of one
immutable version of the dataset. Commits follow temp-file + fsync +
exclusive-link discipline, so the *appearance of the snapshot file is the
commit point*: a crash anywhere before it leaves the previous generation
intact (new files are unreferenced orphans); a crash anywhere after it
leaves the new generation discoverable by the highest-generation rule even
when the ``HEAD`` hint / ``manifest.json`` mirror are stale (both are
healed on the next :meth:`Catalog.open`). The commit point is
``os.link``-ing the fsynced temp file to ``snap-<gen>.json`` — an
exclusive create, so when two *processes* race the same generation exactly
one link succeeds and the loser gets :class:`CommitConflict` instead of
silently overwriting the winner's snapshot.

Every transaction stages its shard files under names carrying a random
per-transaction token (``shard-g<gen>-<token>-<i>.spqf``), so racing
writers — even across processes — never share staged filenames: the CAS
loser's :meth:`CommitTx.abort` only ever unlinks files it exclusively
owns. In-flight staged names are also registered per root and excluded
from :meth:`Catalog.gc`, so an explicit GC racing a live commit cannot
collect files the about-to-commit snapshot references.

Readers call :meth:`Catalog.pin` to hold a generation: pinned generations
(and their shard files) are exempt from :meth:`Catalog.gc`, so a scan keeps
a consistent view while the background :class:`Compactor` merges
small adjacent shards into new-generation files and commits the result.
Shards are SFC-ordered within the manifest, and the compactor only ever
merges *adjacent* runs, so the concatenation order of records — and
therefore every full scan and every ``refine=True`` bbox scan — is
bit-identical across compaction.

Pins are in-process (a module-level registry shared by every ``Catalog``
instance on the same directory). Cross-process readers are protected by the
``keep_snapshots`` retention window instead.

The write-path crash points exercised by the differential fault suite live
in :mod:`repro.io.faults` (``CRASH_SHARD_TORN``, ``CRASH_COMMIT_PRE_RENAME``,
``CRASH_COMMIT_POST_RENAME``, ``CRASH_COMPACT_MID``, ``CRASH_GC_MID``).
"""

from __future__ import annotations

import json
import os
import re
import threading
import time
import uuid

import numpy as np

from repro import obs
from repro.core.reader import (
    SpatialParquetReader,
    footer_data_bytes,
    footer_page_count,
)
from repro.core.writer import concat_columns, write_file
from repro.io.checksum import crc32c
from repro.io.durable import fsync_dir, fsync_file, is_tmp_name, tmp_name_for, write_atomic
from repro.io.faults import (
    CRASH_COMMIT_POST_RENAME,
    CRASH_COMMIT_PRE_RENAME,
    CRASH_COMPACT_MID,
    CRASH_GC_MID,
    CRASH_SHARD_TORN,
    maybe_crash,
)

from .errors import CommitConflict, DatasetError
from .manifest import MANIFEST_NAME, DatasetManifest, ShardInfo, shard_path

SNAPSHOT_FORMAT = "spatial-parquet-snapshot"
SNAPSHOT_VERSION = 1
SNAP_NAME = "snap-{:010d}.json"
HEAD_NAME = "HEAD"
HEAD_FORMAT = "spatial-parquet-head"

_SNAP_RE = re.compile(r"^snap-(\d{1,19})\.json$")
_SHARD_RE = re.compile(r"^shard-(?:g\d{6}-(?:[0-9a-f]{8}-)?)?\d{5}\.spqf$")

# in-process, cross-instance state per dataset root (realpath-keyed):
# one reentrant lock serializing {commit-link, pin, gc} critical sections,
# the pin refcounts GC consults, and the staged filenames of in-flight
# transactions (GC must not collect a live commit's not-yet-referenced files)
_registry_lock = threading.Lock()
_root_locks: dict[str, threading.RLock] = {}
_root_pins: dict[str, dict[int, int]] = {}
_root_inflight: dict[str, dict[int, set[str]]] = {}


def _root_key(root) -> str:
    return os.path.realpath(str(root))


def _root_lock(root) -> threading.RLock:
    key = _root_key(root)
    with _registry_lock:
        lock = _root_locks.get(key)
        if lock is None:
            lock = _root_locks[key] = threading.RLock()
        return lock


def pinned_generations(root) -> set[int]:
    """Generations currently pinned (by any in-process reader) for ``root``."""
    key = _root_key(root)
    with _registry_lock:
        return {g for g, n in _root_pins.get(key, {}).items() if n > 0}


def inflight_names(root) -> set[str]:
    """Filenames staged by live in-process transactions on ``root`` (GC
    treats these as referenced even though no snapshot lists them yet)."""
    key = _root_key(root)
    with _registry_lock:
        out: set[str] = set()
        for names in _root_inflight.get(key, {}).values():
            out |= names
        return out


def file_crc32c(path, chunk: int = 1 << 20) -> int:
    """Whole-file CRC-32C, streamed (the snapshot's per-shard integrity tag)."""
    value = 0
    with open(str(path), "rb") as fh:
        while True:
            block = fh.read(chunk)
            if not block:
                return value
            value = crc32c(block, value)


class Snapshot:
    """One immutable committed version of the dataset."""

    __slots__ = ("generation", "parent", "manifest", "path")

    def __init__(self, generation: int, parent: int | None,
                 manifest: DatasetManifest, path: str | None):
        self.generation = int(generation)
        self.parent = parent
        self.manifest = manifest
        self.path = path  # snapshot file; None only for legacy generation 0

    def __repr__(self) -> str:
        return (f"Snapshot(gen={self.generation}, "
                f"shards={self.manifest.n_shards}, "
                f"records={self.manifest.n_records})")


class PinnedSnapshot:
    """A refcounted hold on one generation; release it (or use as a context
    manager) when the scan is done so GC can reclaim superseded files."""

    def __init__(self, catalog: "Catalog", snapshot: Snapshot):
        self._catalog = catalog
        self.snapshot = snapshot
        self._released = False

    @property
    def generation(self) -> int:
        return self.snapshot.generation

    @property
    def manifest(self) -> DatasetManifest:
        return self.snapshot.manifest

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._catalog._unpin(self.generation)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.release()

    def __repr__(self) -> str:
        state = "released" if self._released else "held"
        return f"PinnedSnapshot(gen={self.generation}, {state})"


class CommitTx:
    """One staged commit: new shard files + the atomic snapshot rename.

    Obtained from :meth:`Catalog.begin`; stage shard files with
    :meth:`stage_shard`, then :meth:`commit` a manifest listing staged and/or
    carried-over entries. On failure call :meth:`abort` to delete staged
    files — except after :class:`~repro.io.faults.InjectedCrash`, which is a
    ``BaseException`` precisely so ordinary cleanup does not run and the
    orphans are left for :meth:`Catalog.gc`, like a real kill.
    """

    def __init__(self, catalog: "Catalog", parent_gen: int):
        self.catalog = catalog
        self.parent_gen = int(parent_gen)
        self.generation = max(1, self.parent_gen + 1)
        self.staged: list[str] = []  # root-relative filenames written by us
        self._n = 0
        self._done = False
        # per-transaction token: staged filenames are unique even when two
        # transactions race the same parent generation (writer vs compactor),
        # so abort() only ever unlinks files this transaction owns
        self.token = uuid.uuid4().hex[:8]
        self._protected: set[str] = set()  # names GC must leave alone
        key = _root_key(catalog.root)
        with _registry_lock:
            inflight = _root_inflight.setdefault(key, {})
            # a concurrent creator of the same virgin directory forfeits the
            # historical plain names, keeping initial commits collision-free
            self._contended = bool(inflight)
            inflight[id(self)] = self._protected

    # --------------------------------------------------------------- staging
    def shard_filename(self, i: int | None = None) -> str:
        """Unique filename for the ``i``-th new shard of this generation.

        Generation 1 of a virgin directory keeps the historical plain names
        (``shard-00000.spqf``) when no other transaction is in flight; any
        other commit gets generation- and transaction-qualified names
        (``shard-g000002-<token>-00000.spqf``) so neither live files nor a
        concurrent transaction's staged files are ever overwritten.
        """
        if i is None:
            i, self._n = self._n, self._n + 1
        if self.parent_gen < 0 and not self._contended:
            return f"shard-{i:05d}.spqf"
        return f"shard-g{self.generation:06d}-{self.token}-{i:05d}.spqf"

    def _protect(self, name: str) -> None:
        with _registry_lock:
            self._protected.add(name)

    def _forsake(self) -> None:
        """Drop this transaction's in-flight GC protection (idempotent).

        Called when the transaction completes, aborts, or dies — including
        via :class:`~repro.io.faults.InjectedCrash`, because the registry is
        process memory a real kill would have taken with it; the files on
        disk become ordinary orphans for :meth:`Catalog.gc`.
        """
        key = _root_key(self.catalog.root)
        with _registry_lock:
            txs = _root_inflight.get(key)
            if txs is not None:
                txs.pop(id(self), None)
                if not txs:
                    _root_inflight.pop(key, None)

    def stage_shard(self, cols, extras=None, *, fsync: bool = True,
                    **file_kwargs) -> ShardInfo:
        """Write one shard file for this commit and return its entry.

        The file is written to its final (unique) name, optionally torn by
        the ``CRASH_SHARD_TORN`` fault point, fsynced, and CRC'd — it only
        becomes reachable when :meth:`commit` renames the snapshot in.
        """
        name = self.shard_filename()
        path = os.path.join(self.catalog.root, name)
        # registered before the write so abort() also cleans a file that
        # write_file itself left half-written when it raised, and so a
        # concurrent gc() never collects it out from under this commit
        self.staged.append(name)
        self._protect(name)
        try:
            footer = write_file(path, columns=cols, extra=extras or None,
                                sort=None, **file_kwargs)
            maybe_crash(CRASH_SHARD_TORN, path=path)
            if fsync:
                with open(path, "rb") as fh:
                    os.fsync(fh.fileno())
            info = ShardInfo(
                path=name,
                mbr=_mbr_of(cols),
                n_records=cols.n_records,
                n_values=cols.n_values,
                n_pages=footer_page_count(footer),
                data_bytes=footer_data_bytes(footer),
                file_bytes=os.path.getsize(path),
                crc32c=file_crc32c(path),
                zone_maps=zone_maps_from_footer(footer),
            )
        except BaseException:
            # the transaction is dead: drop its GC protection (a real kill
            # would have lost this process state too); the files stay on
            # disk for abort() or Catalog.gc() to reclaim
            self._forsake()
            raise
        return info

    # ---------------------------------------------------------------- commit
    def commit(self, manifest: DatasetManifest, *, fsync: bool = True,
               gc: bool | None = None) -> Snapshot:
        """Atomically publish ``manifest`` as generation ``self.generation``.

        Protocol: snapshot JSON → same-dir temp file → fsync →
        [``CRASH_COMMIT_PRE_RENAME``] → CAS check under the root lock →
        ``os.link`` of the temp onto ``snap-<gen>.json`` (THE commit point:
        an exclusive create, so a same-generation committer in another
        process fails instead of overwriting) → dir fsync →
        [``CRASH_COMMIT_POST_RENAME``] → HEAD + ``manifest.json`` mirror
        (each atomic) → GC of superseded, unpinned generations.

        Raises :class:`CommitConflict` if another writer took this
        generation first — detected by the head CAS for in-process races
        and by the exclusive link for cross-process ones; the dataset is
        untouched in that case. (On filesystems without hard links the
        commit falls back to ``os.replace`` behind an existence check,
        where same-generation exclusion is in-process only.)
        """
        if self._done:
            raise DatasetError("commit transaction already completed")
        cat = self.catalog
        t0 = time.perf_counter()
        snap_dict = {
            "format": SNAPSHOT_FORMAT,
            "version": SNAPSHOT_VERSION,
            "generation": self.generation,
            "parent": self.parent_gen if self.parent_gen >= 0 else None,
            "manifest": manifest.to_dict(),
        }
        data = (json.dumps(snap_dict, indent=1) + "\n").encode()
        snap_file = os.path.join(cat.root, SNAP_NAME.format(self.generation))
        try:
            with obs.span("catalog.commit", gen=self.generation,
                          shards=manifest.n_shards):
                fd, tmp = tmp_name_for(snap_file)
                self._protect(os.path.basename(tmp))
                with os.fdopen(fd, "wb") as fh:
                    fh.write(data)
                    if fsync:
                        fsync_file(fh)
                maybe_crash(CRASH_COMMIT_PRE_RENAME)
                with _root_lock(cat.root):
                    try:
                        if cat.head_generation() != self.parent_gen:
                            raise CommitConflict(
                                f"{cat.root}: generation {self.generation} "
                                f"was committed by another writer (head "
                                f"moved past {self.parent_gen})")
                        self._publish(tmp, snap_file)
                    except Exception:
                        try:
                            os.unlink(tmp)
                        except OSError:
                            pass
                        raise
                    if fsync:
                        fsync_dir(cat.root)
                    snapshot = Snapshot(self.generation, snap_dict["parent"],
                                        manifest, snap_file)
                    cat._snap_cache[self.generation] = snapshot
                    self._done = True
                    maybe_crash(CRASH_COMMIT_POST_RENAME)
                    cat._write_head(self.generation, fsync=fsync)
                    manifest.save(cat.root, fsync=fsync)
                    # committed: the head snapshot now references the staged
                    # files, so ordinary retention protects them from here on
                    self._forsake()
                    if gc if gc is not None else cat.auto_gc:
                        cat.gc(fsync=fsync)
        except BaseException:
            self._forsake()
            raise
        obs.count("catalog.commits")
        obs.observe("catalog.commit_s", time.perf_counter() - t0)
        return snapshot

    def _publish(self, tmp: str, snap_file: str) -> None:
        """Make ``tmp`` visible as ``snap_file`` — the commit point.

        ``os.link`` refuses to clobber an existing file, so exactly one of
        two processes racing the same generation number commits; the loser
        surfaces as :class:`CommitConflict` with its temp cleaned up by the
        caller.
        """
        try:
            os.link(tmp, snap_file)
        except FileExistsError:
            raise CommitConflict(
                f"{snap_file}: generation {self.generation} was committed "
                f"by another process") from None
        except OSError:
            # hard links unsupported here: atomic rename keeps crash safety,
            # same-generation exclusion degrades to the in-process CAS
            if os.path.exists(snap_file):
                raise CommitConflict(
                    f"{snap_file}: generation {self.generation} was "
                    f"committed by another process") from None
            os.replace(tmp, snap_file)
            return
        try:
            os.unlink(tmp)  # second hard link; the snapshot itself stays
        except OSError:
            pass

    def abort(self) -> None:
        """Delete staged shard files (ordinary-failure cleanup path).

        Staged names are transaction-unique, so this only ever unlinks
        files this transaction wrote — never a racing winner's.
        """
        if self._done:
            return
        self._done = True
        for name in self.staged:
            try:
                os.unlink(os.path.join(self.catalog.root, name))
            except OSError:
                pass
        self.staged.clear()
        self._forsake()

    def __del__(self):
        try:  # abandoned tx: do not hold GC protection for the process life
            self._forsake()
        except Exception:
            pass


class Catalog:
    """The versioned catalog of one dataset directory.

    ``keep_snapshots`` is the retention window: GC keeps that many of the
    newest generations (plus anything pinned in-process), so slightly-stale
    external readers survive a commit. ``auto_gc=False`` defers all orphan
    collection to explicit :meth:`gc` calls.
    """

    def __init__(self, root, *, keep_snapshots: int = 2, auto_gc: bool = True,
                 create: bool = False):
        self.root = str(root)
        self.keep_snapshots = max(1, int(keep_snapshots))
        self.auto_gc = bool(auto_gc)
        self._snap_cache: dict[int, Snapshot] = {}
        if not os.path.isdir(self.root):
            if not create:
                raise DatasetError(
                    f"{self.root}: not a directory (pass create=True to "
                    f"make a new dataset root)")
            os.makedirs(self.root, exist_ok=True)
        if create is False and self.head_generation() < 0:
            raise DatasetError(
                f"{os.path.join(self.root, MANIFEST_NAME)}: no manifest "
                f"found (not a dataset directory?)")
        self._heal()

    @classmethod
    def open(cls, root, **kwargs) -> "Catalog":
        return cls(root, **kwargs)

    # ------------------------------------------------------------- discovery
    def list_generations(self) -> list[int]:
        """Committed snapshot generations on disk, ascending (no legacy 0)."""
        gens = []
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return []
        for name in names:
            m = _SNAP_RE.match(name)
            if m:
                gens.append(int(m.group(1)))
        return sorted(gens)

    def head_generation(self) -> int:
        """Newest committed generation: highest ``snap-*.json`` wins; a
        snapshot-less directory with a legacy ``manifest.json`` is
        generation 0; a virgin directory is -1."""
        gens = self.list_generations()
        if gens:
            return gens[-1]
        if os.path.isfile(os.path.join(self.root, MANIFEST_NAME)):
            return 0
        return -1

    def head_snapshot(self) -> Snapshot:
        gen = self.head_generation()
        if gen < 0:
            raise DatasetError(
                f"{os.path.join(self.root, MANIFEST_NAME)}: no manifest "
                f"found (not a dataset directory?)")
        return self.load_snapshot(gen)

    def load_snapshot(self, generation: int) -> Snapshot:
        """Load + validate one committed snapshot (cached; immutable once
        committed). Generation 0 is the legacy ``manifest.json``."""
        generation = int(generation)
        snap = self._snap_cache.get(generation)
        if snap is not None:
            return snap
        if generation == 0:
            manifest = DatasetManifest.load(self.root)
            snap = Snapshot(0, None, manifest, None)
        else:
            path = os.path.join(self.root, SNAP_NAME.format(generation))
            try:
                with open(path) as fh:
                    d = json.load(fh)
            except FileNotFoundError:
                raise DatasetError(
                    f"{path}: snapshot {generation} not found "
                    f"(GC'd or never committed?)") from None
            except json.JSONDecodeError as exc:
                raise DatasetError(
                    f"{path}: snapshot is not valid JSON: {exc}") from exc
            except OSError as exc:
                raise DatasetError(
                    f"{path}: cannot read snapshot: {exc}") from exc
            if not isinstance(d, dict) or d.get("format") != SNAPSHOT_FORMAT:
                raise DatasetError(
                    f"{path}: not a {SNAPSHOT_FORMAT} file "
                    f"(format={d.get('format') if isinstance(d, dict) else d!r})")
            version = d.get("version", 0)
            if not isinstance(version, int) or version > SNAPSHOT_VERSION:
                raise DatasetError(
                    f"{path}: snapshot version {version!r} is newer than "
                    f"this library understands (<= {SNAPSHOT_VERSION})")
            if d.get("generation") != generation:
                raise DatasetError(
                    f"{path}: snapshot declares generation "
                    f"{d.get('generation')!r}, filename says {generation}")
            manifest = DatasetManifest.from_dict(
                d.get("manifest"), where=path)
            snap = Snapshot(generation, d.get("parent"), manifest, path)
        self._snap_cache[generation] = snap
        return snap

    # --------------------------------------------------------------- pinning
    def pin(self, generation: int | None = None) -> PinnedSnapshot:
        """Pin a generation (default: the current head) against GC.

        Atomic with respect to commits and GC on this root: the returned
        snapshot's files cannot be collected until release.
        """
        key = _root_key(self.root)
        with _root_lock(self.root):
            gen = self.head_generation() if generation is None else int(generation)
            if gen < 0:
                raise DatasetError(
                    f"{self.root}: nothing to pin (empty dataset root)")
            snap = self.load_snapshot(gen)
            with _registry_lock:
                pins = _root_pins.setdefault(key, {})
                pins[gen] = pins.get(gen, 0) + 1
        return PinnedSnapshot(self, snap)

    def _unpin(self, generation: int) -> None:
        key = _root_key(self.root)
        with _registry_lock:
            pins = _root_pins.get(key)
            if pins is None:
                return
            n = pins.get(generation, 0) - 1
            if n <= 0:
                pins.pop(generation, None)
            else:
                pins[generation] = n

    # ---------------------------------------------------------------- commit
    def begin(self) -> CommitTx:
        """Start a commit on top of the current head (CAS'd at commit)."""
        return CommitTx(self, self.head_generation())

    def commit_manifest(self, manifest: DatasetManifest, *,
                        fsync: bool = True, gc: bool | None = None) -> Snapshot:
        """Metadata-only commit: publish ``manifest`` (whose shard entries
        all reference existing files) as a new generation."""
        return self.begin().commit(manifest, fsync=fsync, gc=gc)

    # -------------------------------------------------------------------- GC
    def orphans(self) -> list[str]:
        """Filenames GC would delete right now (dry run)."""
        with _root_lock(self.root):
            return self._gc_scan()[0]

    def gc(self, *, fsync: bool = True) -> dict:
        """Delete unreferenced files: shards of collected generations,
        snapshots outside the retention window, temp files of interrupted
        writes. Pinned generations and the head are always retained; only
        filename shapes this catalog writes are ever touched.
        """
        t0 = time.perf_counter()
        with obs.span("catalog.gc"), _root_lock(self.root):
            doomed, retained_gens = self._gc_scan()
            deleted = []
            bytes_reclaimed = 0
            for name in doomed:
                path = os.path.join(self.root, name)
                try:
                    size = os.path.getsize(path)
                    os.unlink(path)
                except OSError:
                    continue
                gen = _SNAP_RE.match(name)
                if gen:
                    self._snap_cache.pop(int(gen.group(1)), None)
                deleted.append(name)
                bytes_reclaimed += size
                maybe_crash(CRASH_GC_MID)
            if deleted and fsync:
                fsync_dir(self.root)
        obs.count("catalog.gc_deleted_files", len(deleted))
        obs.count("catalog.gc_bytes_reclaimed", bytes_reclaimed)
        obs.observe("catalog.gc_s", time.perf_counter() - t0)
        return {
            "deleted": deleted,
            "bytes_reclaimed": bytes_reclaimed,
            "retained_generations": sorted(retained_gens),
        }

    def _gc_scan(self) -> tuple[list[str], set[int]]:
        """(doomed filenames, retained generations) — caller holds the lock."""
        gens = self.list_generations()
        head = self.head_generation()
        retained = set(gens[-self.keep_snapshots:])
        if head >= 0:
            retained.add(head)
        retained |= {g for g in pinned_generations(self.root)
                     if g == 0 or g in set(gens)}
        # files staged by live in-flight commits are not yet referenced by
        # any snapshot but must survive a concurrent explicit gc(): the
        # commit may still succeed and publish a snapshot naming them
        live_files: set[str] = {MANIFEST_NAME, HEAD_NAME}
        live_files |= inflight_names(self.root)
        for gen in retained:
            try:
                snap = self.load_snapshot(gen)
            except DatasetError:
                continue
            for s in snap.manifest.shards:
                live_files.add(s.path)
        doomed = []
        for name in sorted(os.listdir(self.root)):
            if name in live_files:
                continue
            m = _SNAP_RE.match(name)
            if m:
                if int(m.group(1)) not in retained:
                    doomed.append(name)
                continue
            if is_tmp_name(name):
                doomed.append(name)
                continue
            if _SHARD_RE.match(name):
                doomed.append(name)  # unreferenced by any retained snapshot
        return doomed, retained

    # ------------------------------------------------------------------ heal
    def _write_head(self, generation: int, *, fsync: bool = True) -> None:
        data = (json.dumps({"format": HEAD_FORMAT,
                            "generation": int(generation)}) + "\n").encode()
        write_atomic(os.path.join(self.root, HEAD_NAME), data, fsync=fsync)

    def _read_head_hint(self) -> int | None:
        try:
            with open(os.path.join(self.root, HEAD_NAME)) as fh:
                d = json.load(fh)
            if isinstance(d, dict) and d.get("format") == HEAD_FORMAT:
                gen = d.get("generation")
                if isinstance(gen, int):
                    return gen
        except (OSError, json.JSONDecodeError):
            pass
        return None

    def _heal(self) -> None:
        """Repair the HEAD hint and the ``manifest.json`` mirror after a
        crash between the snapshot rename and the pointer updates. The
        snapshot chain itself is the source of truth, so healing only ever
        rewrites the two convenience files, atomically."""
        head = self.head_generation()
        if head < 1:
            return  # virgin or legacy-only: nothing catalog-owned to heal
        snap = self.load_snapshot(head)
        if self._read_head_hint() != head:
            self._write_head(head)
        try:
            mirror = DatasetManifest.load(self.root)
            stale = mirror.to_dict() != snap.manifest.to_dict()
        except DatasetError:
            stale = True  # missing or torn mirror
        if stale:
            snap.manifest.save(self.root)


def _mbr_of(cols) -> tuple[float, float, float, float]:
    """MBR over every coordinate; empty shards get the inverted no-hit box
    (same convention as the dataset writer)."""
    if cols.n_values == 0:
        return (float("inf"), float("inf"), float("-inf"), float("-inf"))
    return (float(cols.x.min()), float(cols.y.min()),
            float(cols.x.max()), float(cols.y.max()))


def zone_maps_from_footer(footer: dict) -> dict | None:
    """Shard-level zone maps: the footer's per-row-group ``extra_stats``
    merged across row groups (min of mins, max of maxes, summed counts).

    Returns None when the file carries no extra-column stats (no extras, or
    written before zone maps existed) — the shard then simply never gets
    predicate-pruned. Compacted shards get fresh merged maps for free
    because every staged shard passes through here.
    """
    merged: dict[str, dict] = {}
    seen = False
    for rg in footer.get("row_groups", ()):
        for k, st in rg.get("extra_stats", {}).items():
            seen = True
            z = merged.setdefault(
                k, {"min": None, "max": None, "nnan": 0, "count": 0})
            if st["min"] is not None:
                z["min"] = st["min"] if z["min"] is None else min(z["min"], st["min"])
                z["max"] = st["max"] if z["max"] is None else max(z["max"], st["max"])
            z["nnan"] += int(st["nnan"])
            z["count"] += int(st["count"])
    return merged if seen else None


class Compactor:
    """Merge small adjacent shards back into SFC order as new generations.

    The planner walks the manifest in order (manifest order == SFC key
    order) and greedily groups adjacent runs whose combined record count
    stays within ``target_records``; each run of two or more shards is
    rewritten as one merged shard file, unchanged shards carry over by
    reference. Because only *adjacent* runs merge, the concatenated record
    stream of the new generation is byte-for-byte the old one — full scans
    and refined bbox scans are bit-identical across compaction (unrefined
    bbox scans may differ only in which extra non-matching records page
    pruning lets through, as with any re-pagination).

    ``run_once`` pins the source generation while it reads, so a crash or a
    concurrent scan never observes half-merged state; the commit is the same
    atomic snapshot rename as any other. :meth:`start` runs it on a
    background thread every ``interval_s`` until :meth:`stop`.
    """

    def __init__(self, catalog: Catalog, *, target_records: int = 1 << 20,
                 page_values: int = 131072, row_group_records: int = 1 << 20,
                 interval_s: float = 0.25):
        self.catalog = catalog
        self.target_records = int(target_records)
        self.page_values = int(page_values)
        self.row_group_records = int(row_group_records)
        self.interval_s = float(interval_s)
        self.compactions = 0
        self.errors = 0  # transient run_once failures survived by the loop
        self.last_error: BaseException | None = None
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ---------------------------------------------------------------- policy
    def plan(self, manifest: DatasetManifest) -> list[tuple[int, int]]:
        """Adjacent shard runs ``[lo, hi)`` (len >= 2) worth merging."""
        runs = []
        i, n = 0, manifest.n_shards
        while i < n:
            j = i
            total = 0
            while j < n and (j == i or
                             total + manifest.shards[j].n_records
                             <= self.target_records):
                total += manifest.shards[j].n_records
                j += 1
            if j - i >= 2:
                runs.append((i, j))
            i = max(j, i + 1)
        return runs

    # ------------------------------------------------------------------- run
    def run_once(self) -> Snapshot | None:
        """One compaction cycle; returns the committed snapshot, or None if
        there was nothing to merge (or the commit lost a generation race)."""
        t0 = time.perf_counter()
        with obs.span("catalog.compact"):
            pin = self.catalog.pin()
            try:
                runs = self.plan(pin.manifest)
                if not runs:
                    return None
                tx = self.catalog.begin()
                if tx.parent_gen != pin.generation:
                    return None  # head moved since we pinned; retry next tick
                try:
                    snap = self._compact_runs(pin.manifest, runs, tx)
                except CommitConflict:
                    tx.abort()
                    return None
                except Exception:
                    tx.abort()
                    raise
                except BaseException:
                    # simulated kill between staging calls: leave the files
                    # on disk for GC, but drop the in-memory in-flight
                    # registration a real kill would have lost
                    tx._forsake()
                    raise
            finally:
                pin.release()
        self.compactions += 1
        obs.count("catalog.compactions")
        obs.observe("catalog.compact_s", time.perf_counter() - t0)
        return snap

    def _compact_runs(self, manifest: DatasetManifest,
                      runs: list[tuple[int, int]], tx: CommitTx) -> Snapshot:
        merged: dict[int, ShardInfo] = {}
        covered: set[int] = set()
        for lo, hi in runs:
            cols_parts, extras_parts = [], []
            for i in range(lo, hi):
                geo, extras, _ = self._read_shard(manifest.shards[i])
                cols_parts.append(geo)
                extras_parts.append(extras)
            cols = concat_columns(cols_parts)
            extras = {
                k: np.concatenate([e[k] for e in extras_parts])
                for k in manifest.extra_schema
            }
            info = tx.stage_shard(
                cols, extras,
                encoding=manifest.encoding, codec=manifest.codec,
                page_values=self.page_values,
                row_group_records=self.row_group_records,
                extra_schema=dict(manifest.extra_schema))
            obs.instant("catalog.compact.merge", lo=lo, hi=hi,
                        records=cols.n_records)
            maybe_crash(CRASH_COMPACT_MID)
            merged[lo] = info
            covered.update(range(lo, hi))
        shards: list[ShardInfo] = []
        for i, s in enumerate(manifest.shards):
            if i in merged:
                shards.append(merged[i])
            elif i not in covered:
                shards.append(s)  # unchanged: carried over by reference
        new_manifest = DatasetManifest(
            coord_dtype=manifest.coord_dtype,
            codec=manifest.codec,
            encoding=manifest.encoding,
            sort=manifest.sort,
            extra_schema=dict(manifest.extra_schema),
            shards=shards,
        )
        return tx.commit(new_manifest)

    def _read_shard(self, info: ShardInfo):
        with SpatialParquetReader(
                shard_path(self.catalog.root, info)) as r:
            return r.read_columnar()

    # ------------------------------------------------------------ background
    def start(self) -> "Compactor":
        """Run :meth:`run_once` on a daemon thread every ``interval_s``.

        Ordinary exceptions (a transient ``OSError``, a shard read that
        loses a race with GC outside the retention window) are counted,
        reported through :mod:`repro.obs`, and retried with exponential
        backoff — compaction must not silently die for the process lifetime
        on one bad tick. Only a simulated kill (:class:`InjectedCrash` /
        any other ``BaseException``) stops the loop, staying observable in
        ``last_error``.
        """
        if self._thread is not None:
            raise RuntimeError("compactor already started")
        self._stop.clear()

        def loop():
            consecutive = 0
            while not self._stop.is_set():
                try:
                    self.run_once()
                    consecutive = 0
                except Exception as exc:
                    self.errors += 1
                    consecutive += 1
                    self.last_error = exc
                    obs.count("catalog.compact_errors")
                    obs.instant("catalog.compact.error",
                                error=type(exc).__name__, detail=str(exc))
                    self._stop.wait(
                        self.interval_s * min(2 ** consecutive, 64))
                    continue
                except BaseException as exc:  # keep InjectedCrash observable
                    self.last_error = exc
                    break
                self._stop.wait(self.interval_s)

        self._thread = threading.Thread(target=loop, name="spqf-compactor",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float | None = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

"""zamba2-1.2b [arXiv:2411.15242]: 38 Mamba2 layers (d=2048, state=64) + ONE
weight-shared attention block (32H, ff=8192) applied every 6th layer with
per-site KV caches. Hybrid => sub-quadratic => runs long_500k; the shared
block's KV cache is sequence-sharded (SP) at long context.
"""

from .base import ModelConfig, SSMConfig

config = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    head_dim=64,
    ssm=SSMConfig(d_state=64, headdim=64, expand=2, conv_width=4, chunk=64),
    hybrid_attn_every=6,
    sub_quadratic=True,
    seq_shard_cache=True,
    grad_accum=8,
    attn_impl="blocked",
    ssd_matmul_dtype="bfloat16",
)

"""Architecture registry: ``get_config(arch_id)`` for every assigned arch.

Sources are cited per file; exact dims follow the assignment table.
"""

from __future__ import annotations

from .base import SHAPES, MLAConfig, ModelConfig, MoEConfig, ShapeConfig, SSMConfig, shape_applicable
from .arctic_480b import config as _arctic
from .granite_20b import config as _granite
from .internlm2_1_8b import config as _internlm2
from .mamba2_130m import config as _mamba2
from .minicpm3_4b import config as _minicpm3
from .pixtral_12b import config as _pixtral
from .qwen2_moe_a2_7b import config as _qwen2moe
from .qwen3_8b import config as _qwen3
from .spatial_lm import config as _spatial_lm
from .whisper_medium import config as _whisper
from .zamba2_1_2b import config as _zamba2

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _whisper,
        _minicpm3,
        _granite,
        _qwen3,
        _internlm2,
        _zamba2,
        _arctic,
        _qwen2moe,
        _mamba2,
        _pixtral,
        _spatial_lm,
    )
}

ASSIGNED = [n for n in ARCHS if n != "spatial-lm"]


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ARCHS",
    "ASSIGNED",
    "SHAPES",
    "get_config",
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "ShapeConfig",
    "shape_applicable",
]

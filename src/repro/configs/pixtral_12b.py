"""pixtral-12b [hf:mistralai/Pixtral-12B-2409]: 40L mistral-nemo backbone,
d=5120, 32H GQA kv=8 (head_dim=128), ff=14336.

The pixtral ViT is a STUB: input_specs() provides 256 precomputed patch
embeddings (dim 1024) per sample; a trainable adapter projects to d_model and
the patches are prepended to the token stream (labels ignored there).
"""

from .base import ModelConfig

config = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    frontend="vision",
    frontend_dim=1024,
    vision_tokens=256,
    grad_accum=16,
    fsdp_pod=True,
    attn_impl="blocked",
)

"""internlm2-1.8b [arXiv:2403.17297]: 24L, d=2048, 16H GQA kv=8, ff=8192."""

from .base import ModelConfig

config = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92544,
    head_dim=128,
    grad_accum=16,
    attn_impl="blocked",
)

"""granite-20b-code [arXiv:2405.04324]: 52L, d=6144, 48H MQA (kv=1), ff=24576."""

from .base import ModelConfig

config = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    grad_accum=16,
    fsdp_pod=True,
    attn_impl="blocked",
)

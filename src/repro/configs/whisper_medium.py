"""whisper-medium [arXiv:2212.04356]: enc-dec, 24+24L, d=1024, 16H, ff=4096.

Conv frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S/2, 1024) — Whisper's stride-2 conv stack
gives 2x temporal downsampling. Decoder uses RoPE (simplification of learned
positions; noted in DESIGN.md).
"""

from .base import ModelConfig

config = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    frontend="audio",
    frontend_dim=1024,
    frontend_downsample=2,
    sub_quadratic=False,
    has_decoder=True,
    grad_accum=8,
    attn_impl="blocked",
)

"""minicpm3-4b [hf:openbmb/MiniCPM3-4B]: 62L, d=2560, 40H, ff=6400, MLA.

MLA dims from the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_rope_head_dim=32, qk_nope_head_dim=64, v_head_dim=64. The decode cache
stores the 256-d compressed latent + 32-d rope key (MLA's tiny-KV property).
"""

from .base import MLAConfig, ModelConfig

config = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    mla=MLAConfig(
        q_lora_rank=768, kv_lora_rank=256,
        rope_head_dim=32, nope_head_dim=64, v_head_dim=64,
    ),
    grad_accum=16,
    attn_impl="blocked",
)

"""mamba2-130m [arXiv:2405.21060]: 24L pure SSD, d=768, state=128, attn-free.

sub-quadratic => runs long_500k (O(1)-state decode)."""

from .base import ModelConfig, SSMConfig

config = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, conv_width=4, chunk=64),
    tie_embeddings=True,
    sub_quadratic=True,
    grad_accum=8,
    ssd_matmul_dtype="bfloat16",
)

"""Config system: one dataclass family covering all 10 assigned architectures.

Every architecture in ``repro.configs`` instantiates :class:`ModelConfig`;
shapes come from :class:`ShapeConfig` (the four assigned input-shape sets).
``reduced()`` derives the CPU smoke-test variant of any config.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0              # always-on shared experts (qwen2-moe)
    dense_ff_parallel: int = 0     # arctic: parallel dense FFN width (0=off)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3
    pad_experts_to: int = 0        # pad expert count for EP divisibility


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    rope_head_dim: int
    nope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    qk_norm: bool = False
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid_attn_every: int = 0     # zamba2: shared attn block every k layers
    n_encoder_layers: int = 0      # whisper encoder depth
    frontend: str | None = None    # None | 'audio' | 'vision' (stub embeddings)
    frontend_dim: int = 0          # stub embedding dim (0 => d_model)
    frontend_downsample: int = 1   # audio conv stack temporal downsample
    vision_tokens: int = 256       # patches per image (pixtral stub)
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # numerics
    dtype: str = "bfloat16"        # activation compute dtype
    param_dtype: str = "float32"   # parameter storage dtype
    # distribution / runtime knobs
    fsdp_pod: bool = False         # extend FSDP over the pod axis
    opt_state_dtype: str = "float32"
    remat: str = "full"            # none | full | selective
    grad_accum: int = 1
    seq_shard_cache: bool = False  # SP: shard decode KV cache over 'data'
    attn_impl: str = "ref"         # ref | blocked (online-softmax scan) | flash
    # §Perf knobs (baseline values first; see EXPERIMENTS.md §Perf)
    ce_impl: str = "onehot"        # gather (paper-baseline) | onehot
    moe_grouped: bool = False      # gshard group-local dispatch (EP all-to-all)
    ssd_matmul_dtype: str = "float32"  # intra-chunk einsum dtype (bf16 opt)
    # capability flags
    sub_quadratic: bool = False    # may run long_500k
    has_decoder: bool = True
    # dry-run/roofline calibration: Python-unroll the layer stack instead of
    # lax.scan (XLA cost_analysis counts scan bodies once, ignoring trip
    # count; unrolled lowerings give exact per-layer FLOPs/bytes/collectives)
    unroll_layers: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        changes: dict = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            d_ff=256,
            vocab=512,
            head_dim=32,
            dtype="float32",
            param_dtype="float32",
            grad_accum=1,
            remat="none",
            ssd_matmul_dtype="float32",
        )
        if self.mla:
            changes["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16,
                nope_head_dim=16, v_head_dim=32,
            )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(self.moe.top_k, 2),
                d_expert=64, n_shared=min(self.moe.n_shared, 2),
                dense_ff_parallel=64 if self.moe.dense_ff_parallel else 0,
                pad_experts_to=0,
            )
        if self.ssm:
            changes["ssm"] = dataclasses.replace(self.ssm, d_state=16, headdim=16, chunk=32)
        if self.n_encoder_layers:
            changes["n_encoder_layers"] = 2
        if self.hybrid_attn_every:
            changes["hybrid_attn_every"] = 2
        if self.frontend == "vision":
            changes["vision_tokens"] = 8
        if self.frontend_dim:
            changes["frontend_dim"] = 64
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


# The four assigned input-shape sets (LM-family shapes).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k needs sub-quadratic attention; decode
    shapes need a decoder."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: O(S^2) at 524k skipped (DESIGN.md §6)"
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only arch has no decode step"
    return True, ""

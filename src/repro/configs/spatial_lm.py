"""spatial-lm: the paper's own end-to-end arch — a small Mamba2 trajectory LM
trained on geo-token streams decoded from Spatial Parquet data lakes
(examples/train_trajectory_lm.py). Not part of the assigned 10."""

from .base import ModelConfig, SSMConfig

config = ModelConfig(
    name="spatial-lm",
    family="ssm",
    n_layers=12,
    d_model=512,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=4096,
    ssm=SSMConfig(d_state=64, headdim=32, expand=2, conv_width=4, chunk=128),
    tie_embeddings=True,
    sub_quadratic=True,
    dtype="float32",
    param_dtype="float32",
    remat="none",
)

"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L, d=2048, 16H, MoE with
60 routed experts top-4 (ff=1408) + 4 shared experts.

Experts are padded 60 -> 64 for EP divisibility over the 16-way model axis
(padding experts get -inf router logits; DESIGN.md §6).
"""

from .base import ModelConfig, MoEConfig

config = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    moe=MoEConfig(
        n_experts=60, top_k=4, d_expert=1408, n_shared=4,
        pad_experts_to=64, capacity_factor=1.25,
    ),
    grad_accum=16,
    attn_impl="blocked",
    moe_grouped=True,
)

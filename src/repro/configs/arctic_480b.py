"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: 35L, d=7168, 56H GQA
kv=8, MoE 128 experts top-2 (expert ff=4864) + parallel dense FFN residual.

At 480B params this is the memory-limit config: bf16 params + bf16 Adam
moments, FSDP extended over the pod axis, grad-accum 8.
"""

from .base import ModelConfig, MoEConfig

config = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    head_dim=128,
    moe=MoEConfig(
        n_experts=128, top_k=2, d_expert=4864,
        dense_ff_parallel=4864, capacity_factor=1.25,
    ),
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
    fsdp_pod=True,
    grad_accum=8,
    attn_impl="blocked",
    moe_grouped=True,
)

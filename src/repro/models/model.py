"""Unified model builder: one scan-over-layers stack, six families.

``build_model(cfg)`` returns a :class:`Model` whose members are pure
functions over dict pytrees:

* ``init(rng)`` — parameters (layer stacks have a leading ``n_layers`` axis
  so the forward pass is a single ``lax.scan`` — small HLO, fast compiles
  even at 512 devices).
* ``forward(params, batch)`` / ``loss(params, batch)`` — training path
  (activation-rematerialized per layer according to ``cfg.remat``).
* ``init_cache(batch)`` / ``prefill`` / ``decode_step`` — serving path with
  fixed-capacity caches (static shapes; ``serve_step`` lowers once).

Families:
  dense   — pre-norm GQA attention + SwiGLU (granite/qwen3/internlm2)
  moe     — attention + MoE FFN (arctic: +parallel dense FFN; qwen2-moe:
            +shared experts)
  ssm     — pure Mamba2/SSD (mamba2-130m)
  hybrid  — Mamba2 backbone + ONE weight-shared attention block applied every
            ``hybrid_attn_every`` layers with per-site KV caches (zamba2)
  encdec  — whisper: stub audio frames -> encoder; decoder w/ cross-attn
  vlm     — pixtral: stub ViT patch embeddings + adapter, decoder backbone
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (
    cross_entropy_loss,
    dense_init,
    dtype_of,
    embed_init,
    init_mlp,
    mlp,
    rms_norm,
    sinusoidal_embedding,
)


# ----------------------------------------------------------------- layer init
def _init_decoder_layer(cfg: ModelConfig, rng, dtype) -> dict:
    ks = jax.random.split(rng, 4)
    p: dict = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if cfg.family == "ssm" or (cfg.family == "hybrid"):
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg, dtype)
        return p
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.init_gqa(ks[0], cfg, dtype)
    p["ln2"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    if cfg.family == "encdec":  # decoder layer gains cross-attention
        p["ln_cross"] = jnp.ones((cfg.d_model,), dtype)
        p["cross"] = attn.init_cross_attention(ks[2], cfg, dtype)
    return p


def _init_encoder_layer(cfg: ModelConfig, rng, dtype) -> dict:
    ks = jax.random.split(rng, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_gqa(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _init_shared_attn_block(cfg: ModelConfig, rng, dtype) -> dict:
    """Zamba2's weight-shared attention+MLP block (simplified: hidden-only
    input; the concat-with-embedding variant is noted in DESIGN.md)."""
    ks = jax.random.split(rng, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_gqa(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(cfg: ModelConfig, rng) -> dict:
    pdt = dtype_of(cfg.param_dtype)
    keys = jax.random.split(rng, 8)
    params: dict = {
        "embed": embed_init(keys[0], (cfg.vocab, cfg.d_model), pdt),
        "final_norm": jnp.ones((cfg.d_model,), pdt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab), 0, dtype=pdt)
    lkeys = jax.random.split(keys[2], cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: _init_decoder_layer(cfg, k, pdt))(lkeys)
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_shared_attn_block(cfg, keys[3], pdt)
    if cfg.family == "encdec":
        ekeys = jax.random.split(keys[4], cfg.n_encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_encoder_layer(cfg, k, pdt))(ekeys),
            "final_norm": jnp.ones((cfg.d_model,), pdt),
        }
    if cfg.frontend is not None:
        fdim = cfg.frontend_dim or cfg.d_model
        params["frontend_adapter"] = dense_init(keys[5], (fdim, cfg.d_model), 0, dtype=pdt)
    return params


# ------------------------------------------------------------- layer forward
def _attn_block(cfg, lp, x, positions, cache=None, cache_pos=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        out, new_cache = attn.mla_forward(cfg, lp["attn"], h, positions,
                                          cache=cache, cache_pos=cache_pos)
    else:
        out, new_cache = attn.gqa_forward(cfg, lp["attn"], h, positions,
                                          cache=cache, cache_pos=cache_pos)
    return x + out, new_cache


def _ffn_block(cfg, lp, x):
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        out, aux = moe_mod.moe_block(cfg, lp["moe"], h)
        return x + out, aux
    return x + mlp(lp["mlp"], h), {}


def _shared_block(cfg, sp, x, positions, cache=None, cache_pos=None):
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    out, new_cache = attn.gqa_forward(cfg, sp["attn"], h, positions,
                                      cache=cache, cache_pos=cache_pos)
    x = x + out
    x = x + mlp(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps))
    return x, new_cache


def _decoder_layer(cfg, lp, x, positions, *, shared=None, layer_idx=None,
                   cache=None, cache_pos=None, site_caches=None, enc_out=None,
                   cross_kv=None):
    """One decoder layer; returns (x, new_layer_cache, aux, new_site_caches)."""
    aux: dict = {}
    if cfg.family in ("ssm", "hybrid"):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if cache is not None and x.shape[1] == 1:
            out, new_cache = ssm_mod.ssm_decode_step(cfg, lp["ssm"], h, cache)
        else:
            out, new_cache = ssm_mod.ssm_forward(cfg, lp["ssm"], h, cache=cache)
        x = x + out
        if cfg.family == "hybrid" and shared is not None:
            every = cfg.hybrid_attn_every
            apply_attn = (layer_idx % every) == (every - 1)
            site = layer_idx // every

            def with_attn(operand):
                x_in, sc = operand
                if sc is None:
                    y, _ = _shared_block(cfg, shared, x_in, positions)
                    return y, sc
                site_cache = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, site, 0, keepdims=False), sc
                )
                y, new_site = _shared_block(cfg, shared, x_in, positions,
                                            cache=site_cache, cache_pos=cache_pos)
                sc = jax.tree.map(
                    lambda a, n: jax.lax.dynamic_update_index_in_dim(a, n, site, 0),
                    sc, new_site,
                )
                return y, sc

            def without_attn(operand):
                return operand

            if isinstance(layer_idx, int):  # static (unrolled calibration)
                if layer_idx % every == every - 1:
                    x, site_caches = with_attn((x, site_caches))
            else:
                x, site_caches = jax.lax.cond(apply_attn, with_attn, without_attn,
                                              (x, site_caches))
        return x, new_cache, aux, site_caches

    x, new_cache = _attn_block(cfg, lp, x, positions, cache=cache, cache_pos=cache_pos)
    if cfg.family == "encdec":
        h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        x = x + attn.cross_attention(cfg, lp["cross"], h, enc_kv=cross_kv, enc_out=enc_out)
    x, aux = _ffn_block(cfg, lp, x)
    return x, new_cache, aux, site_caches


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "selective":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


# --------------------------------------------------------------- embeddings
def _embed_inputs(cfg, params, batch):
    """Returns (x (B,S,d) activations, positions (S,), label_mask or None)."""
    adt = dtype_of(cfg.dtype)
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(adt)
    label_mask = None
    if cfg.family == "vlm":
        patches = batch["patches"].astype(adt)
        vis = patches @ params["frontend_adapter"].astype(adt)
        x = jnp.concatenate([vis, x], axis=1)
        label_mask = jnp.concatenate(
            [jnp.zeros(vis.shape[:2], bool), jnp.ones(tokens.shape, bool)], axis=1
        )
    positions = jnp.arange(x.shape[1])
    return x, positions, label_mask


def _encode(cfg, params, batch):
    """Whisper encoder over stub frame embeddings."""
    adt = dtype_of(cfg.dtype)
    frames = batch["frames"].astype(adt)
    x = frames @ params["frontend_adapter"].astype(adt)
    x = x + sinusoidal_embedding(x.shape[1], cfg.d_model)[None].astype(adt)
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        h2 = rms_norm(h, lp["ln1"], cfg.norm_eps)
        out, _ = attn.gqa_forward(cfg, lp["attn"], h2, positions, causal=False)
        h = h + out
        h = h + mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, None

    body = _remat_wrap(cfg, body)
    if cfg.unroll_layers:
        for i in range(cfg.n_encoder_layers):
            lp = jax.tree.map(lambda a: a[i], params["encoder"]["layers"])
            x, _ = body(x, lp)
    else:
        x, _ = jax.lax.scan(lambda h, lp: body(h, lp), x, params["encoder"]["layers"])
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


# ------------------------------------------------------------------- forward
def forward(cfg: ModelConfig, params: dict, batch: dict):
    """Training/prefill-style full forward. Returns (logits, aux_losses)."""
    x, positions, label_mask = _embed_inputs(cfg, params, batch)
    enc_out = _encode(cfg, params, batch) if cfg.family == "encdec" else None
    shared = params.get("shared_attn")
    n_layers = cfg.n_layers
    layer_ids = jnp.arange(n_layers)

    def body(carry, scanned):
        h, aux_sum = carry
        lp, idx = scanned
        h, _, aux, _ = _decoder_layer(
            cfg, lp, h, positions, shared=shared, layer_idx=idx, enc_out=enc_out
        )
        for k in aux:
            aux_sum = dict(aux_sum, **{k: aux_sum.get(k, 0.0) + aux[k]})
        return (h, aux_sum), None

    body = _remat_wrap(cfg, body)
    aux0 = (
        {"moe_aux_loss": jnp.zeros((), jnp.float32), "router_z_loss": jnp.zeros((), jnp.float32)}
        if cfg.family == "moe"
        else {}
    )
    if cfg.unroll_layers:
        carry = (x, aux0)
        for i in range(n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            h, aux_sum = carry
            h, _, aux_i, _ = _decoder_layer(
                cfg, lp, h, positions, shared=shared, layer_idx=i, enc_out=enc_out
            )
            for k in aux_i:
                aux_sum = dict(aux_sum, **{k: aux_sum.get(k, 0.0) + aux_i[k]})
            carry = (h, aux_sum)
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), (params["layers"], layer_ids))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    return logits, aux, label_mask


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    """Next-token cross entropy (+ MoE aux). Returns (loss, metrics)."""
    logits, aux, label_mask = forward(cfg, params, batch)
    labels = batch.get("labels")
    if labels is None:
        tokens = batch["tokens"]
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1
        )
        if label_mask is not None:  # vlm: prepend ignore labels for patches
            pad = jnp.full((tokens.shape[0], logits.shape[1] - labels.shape[1]), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
    ce, count = cross_entropy_loss(logits, labels, impl=cfg.ce_impl)
    total = ce
    metrics = {"ce_loss": ce, "tokens": count}
    for k, v in aux.items():
        total = total + v
        metrics[k] = v
    metrics["loss"] = total
    return total, metrics


# ------------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    adt = dtype_of(cfg.dtype)
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("ssm", "hybrid"):
        cache["layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)).copy()
            if False else jnp.zeros((cfg.n_layers, *a.shape), a.dtype),
            ssm_mod.init_ssm_cache(cfg, batch, adt),
        )
        if cfg.family == "hybrid":
            n_sites = cfg.n_layers // cfg.hybrid_attn_every
            site = attn.init_gqa_cache(cfg, batch, max_len, adt)
            cache["sites"] = jax.tree.map(
                lambda a: jnp.zeros((n_sites, *a.shape), a.dtype), site
            )
        return cache
    if cfg.mla is not None:
        layer = attn.init_mla_cache(cfg, batch, max_len, adt)
    else:
        layer = attn.init_gqa_cache(cfg, batch, max_len, adt)
    cache["layers"] = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers, *a.shape), a.dtype), layer
    )
    if cfg.family == "encdec":
        enc_len = max_len // cfg.frontend_downsample
        hd = cfg.resolved_head_dim
        cache["cross"] = {
            "k": jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_heads, hd), adt),
            "v": jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_heads, hd), adt),
        }
    return cache


def forward_with_cache(cfg: ModelConfig, params: dict, batch: dict, cache: dict):
    """Prefill (S>=1) or decode (S==1) against the cache at ``cache['pos']``.

    Returns (logits, new_cache)."""
    adt = dtype_of(cfg.dtype)
    tokens = batch["tokens"]
    pos0 = cache["pos"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(adt)
    if cfg.family == "vlm" and "patches" in batch:
        vis = batch["patches"].astype(adt) @ params["frontend_adapter"].astype(adt)
        x = jnp.concatenate([vis, x], axis=1)
        s = x.shape[1]
    if jnp.ndim(pos0) == 0:
        positions = pos0 + jnp.arange(s)
    else:
        # per-slot positions (continuous batching): (B, S), one row per slot
        positions = pos0[:, None] + jnp.arange(s)[None, :]
    shared = params.get("shared_attn")
    new_cache = dict(cache)

    cross_kv = None
    if cfg.family == "encdec":
        if "frames" in batch:  # prefill: encode + cache cross K/V per layer
            enc_out = _encode(cfg, params, batch)

            def mk(lp):
                kv = attn.make_cross_kv(cfg, lp["cross"], enc_out)
                return kv

            new_cache["cross"] = jax.vmap(mk)(params["layers"])
        cross_kv = new_cache["cross"]

    layer_ids = jnp.arange(cfg.n_layers)
    site_caches = new_cache.get("sites")

    def body(carry, scanned):
        h, sites = carry
        lp, lcache, idx, ckv = scanned
        h, lcache_new, _, sites = _decoder_layer(
            cfg, lp, h, positions, shared=shared, layer_idx=idx,
            cache=lcache, cache_pos=pos0, site_caches=sites, cross_kv=ckv,
        )
        return (h, sites), lcache_new

    scanned = (params["layers"], cache["layers"], layer_ids,
               cross_kv if cross_kv is not None else layer_ids)
    if cfg.unroll_layers:
        # Python layer indices keep the hybrid shared-attn schedule static, so
        # calibration lowerings count exactly the executed ops per layer.
        lcaches = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            lcache = jax.tree.map(lambda a: a[i], cache["layers"])
            ckv = (jax.tree.map(lambda a: a[i], cross_kv)
                   if cfg.family == "encdec" else None)
            x, lc, _, site_caches = _decoder_layer(
                cfg, lp, x, positions, shared=shared, layer_idx=i,
                cache=lcache, cache_pos=pos0, site_caches=site_caches,
                cross_kv=ckv,
            )
            lcaches.append(lc)
        layer_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *lcaches)
    else:
        (x, site_caches), layer_caches = jax.lax.scan(body, (x, site_caches), scanned)
    new_cache["layers"] = layer_caches
    if site_caches is not None:
        new_cache["sites"] = site_caches
    new_cache["pos"] = pos0 + s
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    return logits, new_cache


def decode_step(cfg, params, tokens, cache):
    """One-token decode: tokens (B, 1) -> (logits (B,1,V), cache)."""
    return forward_with_cache(cfg, params, {"tokens": tokens}, cache)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[Any], dict]
    forward: Callable[[dict, dict], Any]
    loss: Callable[[dict, dict], Any]
    init_cache: Callable[[int, int], dict]
    forward_with_cache: Callable[[dict, dict, dict], Any]
    decode_step: Callable[[dict, Any, dict], Any]


def build_model(cfg: ModelConfig) -> Model:
    import functools

    return Model(
        cfg=cfg,
        init=functools.partial(init_params, cfg),
        forward=functools.partial(forward, cfg),
        loss=functools.partial(loss_fn, cfg),
        init_cache=functools.partial(init_cache, cfg),
        forward_with_cache=functools.partial(forward_with_cache, cfg),
        decode_step=functools.partial(decode_step, cfg),
    )

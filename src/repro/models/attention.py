"""Attention blocks: GQA (+qk-norm), MLA (latent attention), cross-attention.

All functions are pure; caches are explicit pytrees. The decode path works
against a fixed-capacity cache with a position scalar — static shapes only,
so ``serve_step`` lowers once per (arch, shape) cell.

The ``impl`` knob selects the jnp reference einsum (default; what the
dry-run lowers) or the Pallas flash kernel (validated in interpret mode).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import attention as flash_attention_op

from .layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


def _sdpa_blocked(q, k, v, *, causal: bool, block_k: int = 1024):
    """Online-softmax attention over KV blocks in pure XLA (lax.scan).

    The §Perf "blocked" impl: the (S, Sk) logits matrix is never
    materialized — peak attention memory is O(S * block_k) instead of
    O(S * Sk). This is the flash-attention *schedule* expressed as jnp (the
    Pallas kernel in repro.kernels.flash_attention is its TPU twin; this
    version lowers everywhere, including the CPU dry-run). Handles
    asymmetric QK vs V dims (MLA)."""
    b, s, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = hq // hkv
    if sk % block_k:
        block_k = math.gcd(sk, block_k) or sk
    nb = sk // block_k
    scale = 1.0 / np.sqrt(d).astype(np.float32)
    qg = q.reshape(b, s, hkv, group, d)
    kb = jnp.moveaxis(k.reshape(b, nb, block_k, hkv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, block_k, hkv, dv), 1, 0)
    rows = jnp.arange(s)[:, None] + (sk - s)  # decode-aligned diagonal

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        kblk, vblk, bi = inp
        logits = jnp.einsum("bshgd,bthd->bhgst", qg, kblk,
                            preferred_element_type=jnp.float32) * scale
        if causal:
            cols = bi * block_k + jnp.arange(block_k)[None, :]
            logits = jnp.where((cols <= rows)[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, group, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, s, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (kb, vb, jnp.arange(nb))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).reshape(b, s, hq, dv).astype(q.dtype)


def _sdpa(q, k, v, *, causal: bool, q_pos=None, k_valid_len=None, impl: str = "ref"):
    """q: (B,S,Hq,D), k/v: (B,Sk,Hkv,D) -> (B,S,Hq,D).

    ``q_pos``: absolute positions of queries (for decode masking);
    ``k_valid_len``: number of valid cache slots (scalar) — keys beyond are
    masked out.
    """
    b, s, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    if impl == "blocked" and k_valid_len is None and q_pos is None:
        return _sdpa_blocked(q, k, v, causal=causal)
    if impl == "flash" and k_valid_len is None and q_pos is None:
        qt = jnp.transpose(q, (0, 2, 1, 3))
        kt = jnp.transpose(k, (0, 2, 1, 3))
        vt = jnp.transpose(v, (0, 2, 1, 3))
        out = flash_attention_op(qt, kt, vt, causal=causal, use_pallas=True)
        return jnp.transpose(out, (0, 2, 1, 3))
    group = hq // hkv
    qg = q.reshape(b, s, hkv, group, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k, preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(d).astype(jnp.float32)
    rows = jnp.arange(s)[:, None] if q_pos is None else q_pos[..., None]
    cols = jnp.arange(sk)[None, :]
    mask = None
    if causal:
        offset = 0 if q_pos is not None else (sk - s)
        mask = cols <= rows + offset
    if k_valid_len is not None:
        kmask = cols < k_valid_len
        mask = kmask if mask is None else (mask & kmask)
    if mask is not None:
        while mask.ndim < 3:   # -> (B|1, s|1, sk)
            mask = mask[None]
        mask = mask[:, None, None]  # (B|1, 1, 1, s|1, sk)
        logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", p, v)
    return out.reshape(b, s, hq, v.shape[-1])  # v dim may differ from qk (MLA)


def _update_slots(cache_arr, new, pos):
    """Per-slot cache write: ``new[b]`` lands in ``cache_arr[b]`` at row
    offset ``pos[b]`` along axis 1 (continuous batching, where every batch
    slot sits at its own decode position)."""
    return jax.vmap(
        lambda c, u, p: jax.lax.dynamic_update_slice(
            c, u.astype(c.dtype), (p,) + (0,) * (c.ndim - 1))
    )(cache_arr, new, pos)


# ----------------------------------------------------------------------- GQA
def init_gqa(rng, cfg, dtype) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), 0, dtype=dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), 0, dtype=dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), 0, dtype=dtype),
        "wo": dense_init(ks[3], (hq * hd, d), 0, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def gqa_forward(cfg, p, x, positions, *, causal=True, cache=None, cache_pos=None,
                use_rope=True):
    """Full-sequence or cached attention.

    cache: None, or dict {k: (B, Smax, Hkv, D), v: ...}; when given, the new
    K/V are written at ``cache_pos`` and attention runs over the cache.
    Returns (out, new_cache).
    """
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, hq, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, hkv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        if jnp.ndim(cache_pos) != 0:
            # per-slot positions: each slot writes K/V at its own offset and
            # masks its own valid length; positions must already be (B, S)
            kc = _update_slots(cache["k"], k, cache_pos)
            vc = _update_slots(cache["v"], v, cache_pos)
            out = _sdpa(
                q, kc.astype(x.dtype), vc.astype(x.dtype), causal=True,
                q_pos=positions, k_valid_len=(cache_pos + s)[:, None, None],
                impl="ref",
            )
            return out.reshape(b, s, hq * hd) @ p["wo"].astype(x.dtype), \
                {"k": kc, "v": vc}
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        new_cache = {"k": kc, "v": vc}
        if s == cache["k"].shape[1]:
            # full-capacity prefill (static condition): attention over the
            # fresh K/V is equivalent and admits the blocked/flash impls
            out = _sdpa(q, k, v, causal=True, impl=cfg.attn_impl)
        else:
            out = _sdpa(
                q, kc.astype(x.dtype), vc.astype(x.dtype), causal=True,
                q_pos=positions if positions.ndim else positions[None],
                k_valid_len=cache_pos + s, impl="ref",
            )
    else:
        out = _sdpa(q, k, v, causal=causal, impl=cfg.attn_impl)
    return out.reshape(b, s, hq * hd) @ p["wo"].astype(x.dtype), new_cache


def init_gqa_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((batch, max_len, hkv, hd), dtype),
    }


# ----------------------------------------------------------------------- MLA
def init_mla(rng, cfg, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(rng, 5)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), 0, dtype=dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h * qk_dim), 0, dtype=dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.rope_head_dim), 0, dtype=dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": dense_init(
            ks[3], (m.kv_lora_rank, h * (m.nope_head_dim + m.v_head_dim)), 0, dtype=dtype
        ),
        "wo": dense_init(ks[4], (h * m.v_head_dim, d), 0, dtype=dtype),
    }


def _mla_qkv(cfg, p, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_lat = rms_norm(x @ p["wq_a"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"].astype(x.dtype)).reshape(b, s, h, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv_a = x @ p["wkv_a"].astype(x.dtype)
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)          # (B,S,r)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,rd)
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(cfg, p, q_nope, q_rope, c_kv, k_rope, *, q_pos=None, k_valid_len=None):
    m = cfg.mla
    h = cfg.n_heads
    b, s = q_nope.shape[:2]
    kv = (c_kv.astype(q_nope.dtype) @ p["wkv_b"].astype(q_nope.dtype)).reshape(
        b, -1, h, m.nope_head_dim + m.v_head_dim
    )
    k_nope, v = jnp.split(kv, [m.nope_head_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope.astype(k_nope.dtype), (*k_nope.shape[:3], m.rope_head_dim))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # full-sequence path admits the blocked impl (asymmetric dv supported)
    impl = cfg.attn_impl if (q_pos is None and k_valid_len is None) else "ref"
    out = _sdpa(q, k, v, causal=True, q_pos=q_pos, k_valid_len=k_valid_len, impl=impl)
    return out.reshape(b, s, h * m.v_head_dim) @ p["wo"].astype(q_nope.dtype)


def mla_forward(cfg, p, x, positions, *, cache=None, cache_pos=None):
    """MLA attention; cache holds the compressed latent (tiny-KV property):
    {c_kv: (B, Smax, r), k_rope: (B, Smax, 1, rd)}."""
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, positions)
    if cache is None:
        out = _mla_attend(cfg, p, q_nope, q_rope, c_kv, k_rope)
        return out, None
    if jnp.ndim(cache_pos) != 0:
        # per-slot positions (continuous batching): see gqa_forward
        cc = _update_slots(cache["c_kv"], c_kv, cache_pos)
        cr = _update_slots(cache["k_rope"], k_rope, cache_pos)
        s = x.shape[1]
        out = _mla_attend(
            cfg, p, q_nope, q_rope, cc, cr,
            q_pos=positions, k_valid_len=(cache_pos + s)[:, None, None],
        )
        return out, {"c_kv": cc, "k_rope": cr}
    cc = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_pos, 0)
    )
    cr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, cache_pos, 0, 0)
    )
    s = x.shape[1]
    if s == cache["c_kv"].shape[1]:
        # full-capacity prefill (static condition): attend over the fresh
        # latents — equivalent, and admits the blocked impl
        out = _mla_attend(cfg, p, q_nope, q_rope, c_kv, k_rope)
    else:
        out = _mla_attend(
            cfg, p, q_nope, q_rope, cc, cr,
            q_pos=positions if positions.ndim else positions[None],
            k_valid_len=cache_pos + s,
        )
    return out, {"c_kv": cc, "k_rope": cr}


def init_mla_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, 1, m.rope_head_dim), dtype),
    }


# --------------------------------------------------------------- cross-attn
def init_cross_attention(rng, cfg, dtype) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), 0, dtype=dtype),
        "wk": dense_init(ks[1], (d, h * hd), 0, dtype=dtype),
        "wv": dense_init(ks[2], (d, h * hd), 0, dtype=dtype),
        "wo": dense_init(ks[3], (h * hd, d), 0, dtype=dtype),
    }


def cross_attention(cfg, p, x, enc_kv=None, enc_out=None):
    """Decoder->encoder attention. Pass precomputed ``enc_kv`` at decode time
    (cached) or ``enc_out`` to compute K/V on the fly."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    if enc_kv is None:
        k = (enc_out @ p["wk"].astype(x.dtype)).reshape(b, -1, h, hd)
        v = (enc_out @ p["wv"].astype(x.dtype)).reshape(b, -1, h, hd)
    else:
        k, v = enc_kv["k"].astype(x.dtype), enc_kv["v"].astype(x.dtype)
    out = _sdpa(q, k, v, causal=False, impl="ref")
    return out.reshape(b, s, h * hd) @ p["wo"].astype(x.dtype)


def make_cross_kv(cfg, p, enc_out):
    b = enc_out.shape[0]
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, -1, h, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, -1, h, hd)
    return {"k": k, "v": v}

"""Shared neural-net layers: norms, RoPE, SwiGLU MLP, initializers.

Pure-functional JAX over plain dict pytrees (no flax — the framework owns its
parameter tree so checkpointing/sharding rules stay explicit).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ------------------------------------------------------------------- init
def dense_init(rng, shape, in_axis: int = 0, scale: float = 1.0, dtype=jnp.float32):
    """Truncated-normal fan-in init (what big LM stacks actually use)."""
    fan_in = shape[in_axis] if in_axis >= 0 else int(np.prod(shape)) // shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(rng, shape, dtype=jnp.float32):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ------------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


# -------------------------------------------------------------------- RoPE
def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple:
    """positions: any shape -> (cos, sin) with trailing dim head_dim//2."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (S,) or scalar."""
    d = x.shape[-1]
    cos, sin = rope_angles(positions, d, theta)  # (B, S, half) or (S, half)
    while cos.ndim < x.ndim - 1:  # broadcast to (B, S, 1, half)
        cos, sin = cos[None], sin[None]
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(n_pos: int, dim: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal positions (encoder frames)."""
    pos = np.arange(n_pos)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=1)
    return jnp.asarray(emb, dtype=jnp.float32)


# --------------------------------------------------------------------- MLP
def init_mlp(rng, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), 0, dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), 0, dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), 0, dtype=dtype),
    }


def mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU feed-forward."""
    h = jax.nn.silu(x @ params["w_gate"].astype(x.dtype)) * (x @ params["w_up"].astype(x.dtype))
    return h @ params["w_down"].astype(x.dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray, mask=None,
                       impl: str = "gather"):
    """Token-mean cross entropy (fp32 accumulation); labels < 0 are ignored.

    impl="gather" (baseline): fp32 upcast + take_along_axis. On a
    vocab-sharded mesh the gather forces an all-gather of the logits and the
    upcast materializes a fp32 (B,S,V) copy — both show up in the dry-run.

    impl="onehot" (§Perf iteration 1): keeps logits in their compute dtype;
    logsumexp runs as fused reduce (max / exp-sum) and the gold logit is a
    one-hot contraction, which shards over the vocab axis as a local dot +
    psum of (B,S) partials — no (B,S,V) fp32 copy, no vocab all-gather.
    """
    valid = (labels >= 0) if mask is None else mask & (labels >= 0)
    safe = jnp.maximum(labels, 0)
    count = jnp.maximum(valid.sum(), 1)
    if impl == "gather":
        logits32 = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits32, axis=-1)
        gold = jnp.take_along_axis(logits32, safe[..., None], axis=-1)[..., 0]
    else:
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
        shifted = logits - m[..., None]
        sumexp = jnp.sum(jnp.exp(shifted.astype(jnp.float32)), axis=-1)
        logz = jnp.log(sumexp) + m.astype(jnp.float32)
        onehot = jax.nn.one_hot(safe, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("...v,...v->...", logits, onehot,
                          preferred_element_type=jnp.float32)
    nll = (logz - gold) * valid
    return nll.sum() / count, count

"""Mamba2 (SSD — state-space duality) block, arXiv:2405.21060.

Chunked SSD forward: within chunks of Q tokens the recurrence is evaluated as
a masked quadratic form (the "duality" — attention-like einsums on the MXU);
across chunks a ``lax.scan`` carries the (H, N, P) state. Decode is the plain
O(1) recurrence against a persistent state + convolution ring buffers.

Projections are kept as separate matrices (wz/wx/wB/wC/wdt) rather than one
packed in_proj so tensor-parallel sharding falls on clean dimensions
(DESIGN.md §7). All state math runs in float32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, rms_norm


def ssm_dims(cfg) -> tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    return d_inner, n_heads, s.d_state, s.conv_width


def init_ssm(rng, cfg, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, h, n, w = ssm_dims(cfg)
    ks = jax.random.split(rng, 9)
    dt = np.exp(
        np.random.RandomState(0).uniform(np.log(1e-3), np.log(1e-1), h)
    )  # target softplus(dt_bias) in [1e-3, 1e-1]
    dt_bias = dt + np.log(-np.expm1(-dt))
    return {
        "wz": dense_init(ks[0], (d, d_inner), 0, dtype=dtype),
        "wx": dense_init(ks[1], (d, d_inner), 0, dtype=dtype),
        "wB": dense_init(ks[2], (d, n), 0, dtype=dtype),
        "wC": dense_init(ks[3], (d, n), 0, dtype=dtype),
        "wdt": dense_init(ks[4], (d, h), 0, dtype=dtype),
        "dt_bias": jnp.asarray(dt_bias, jnp.float32),
        "A_log": jnp.log(jnp.asarray(np.random.RandomState(1).uniform(1, 16, h), jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "conv_x": dense_init(ks[6], (w, d_inner), 0, dtype=dtype),
        "conv_B": dense_init(ks[7], (w, n), 0, dtype=dtype),
        "conv_C": dense_init(ks[8], (w, n), 0, dtype=dtype),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[5], (d_inner, d), 0, dtype=dtype),
    }


def _causal_depthwise_conv(x: jnp.ndarray, kernel: jnp.ndarray, tail=None):
    """x: (B, L, C), kernel: (w, C). ``tail``: (B, w-1, C) carry-in (decode /
    prefill continuation); defaults to zeros."""
    w = kernel.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * kernel[i].astype(x.dtype) for i in range(w)
    )
    return y


def ssd_scan(xh, dt, a_neg, b_mat, c_mat, chunk: int, init_state=None,
             matmul_dtype=jnp.float32):
    """Chunked SSD. xh: (B,L,H,P) f32; dt: (B,L,H) f32; a_neg: (H,) negative;
    b_mat/c_mat: (B,L,N) f32. Returns (y (B,L,H,P), final_state (B,H,N,P)).

    ``matmul_dtype`` selects the intra-chunk einsum precision (§Perf: the
    official Mamba2 kernels run these matmuls in bf16 with fp32 state math;
    the decay/cumsum/state path here always stays fp32)."""
    bsz, L, h, p = xh.shape
    n = b_mat.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    xc = xh.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)
    da = dtc * a_neg  # (B,nc,Q,H), negative
    cs = jnp.cumsum(da, axis=2)
    # intra-chunk quadratic form. NOTE: mask BEFORE exp — the upper triangle
    # has diff = cs_i - cs_j > 0 growing with chunk size; exp would overflow
    # to inf there and inf*0 NaNs the backward (hit at chunk>=64 with
    # init-scale dt*A ~ 1.6/step).
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]       # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmat = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -1e30))
    scores = jnp.einsum("bcin,bcjn->bcij", cc.astype(matmul_dtype),
                        bc.astype(matmul_dtype),
                        preferred_element_type=jnp.float32)  # shared across H
    m = (scores[..., None] * lmat * dtc[:, :, None, :, :]).astype(matmul_dtype)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xc.astype(matmul_dtype),
                         preferred_element_type=jnp.float32)
    # per-chunk end states
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)            # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", decay_to_end * dtc, bc, xc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                   # (B,nc,H)
    # inter-chunk state scan
    s0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(s_prev, inp):
        s_c, dec_c = inp
        s_new = s_prev * dec_c[:, :, None, None] + s_c
        return s_new, s_prev

    s_final, s_prevs = jax.lax.scan(
        step, s0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                    # (B,nc,H,N,P)
    y_inter = jnp.einsum("bcin,bchnp->bcihp", cc, s_prevs) * jnp.exp(cs)[..., None]
    y = (y_intra + y_inter).reshape(bsz, L, h, p)
    return y, s_final


def ssm_forward(cfg, p, x, *, cache=None):
    """Full-sequence Mamba2 block. If ``cache`` is given (prefill), the final
    state and conv tails are written into it. Returns (out, new_cache)."""
    s = cfg.ssm
    d_inner, h, n, w = ssm_dims(cfg)
    bsz, L, _ = x.shape
    z = x @ p["wz"].astype(x.dtype)
    xs = x @ p["wx"].astype(x.dtype)
    bm = x @ p["wB"].astype(x.dtype)
    cm = x @ p["wC"].astype(x.dtype)
    dt_raw = x @ p["wdt"].astype(x.dtype)
    tails = (cache or {})
    xs_c = jax.nn.silu(_causal_depthwise_conv(xs, p["conv_x"], tails.get("conv_x")))
    bm_c = jax.nn.silu(_causal_depthwise_conv(bm, p["conv_B"], tails.get("conv_B")))
    cm_c = jax.nn.silu(_causal_depthwise_conv(cm, p["conv_C"], tails.get("conv_C")))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a_neg = -jnp.exp(p["A_log"])
    from .layers import dtype_of as _dt

    xh = xs_c.astype(jnp.float32).reshape(bsz, L, h, s.headdim)
    y, s_final = ssd_scan(
        xh, dt, a_neg, bm_c.astype(jnp.float32), cm_c.astype(jnp.float32),
        chunk=min(s.chunk, L),
        init_state=tails.get("state"),
        matmul_dtype=_dt(getattr(cfg, "ssd_matmul_dtype", "float32")),
    )
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(bsz, L, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {
            "state": s_final,
            "conv_x": xs[:, -(w - 1):].astype(cache["conv_x"].dtype),
            "conv_B": bm[:, -(w - 1):].astype(cache["conv_B"].dtype),
            "conv_C": cm[:, -(w - 1):].astype(cache["conv_C"].dtype),
        }
    return out, new_cache


def ssm_decode_step(cfg, p, x, cache):
    """One-token decode. x: (B, 1, d); cache holds state + conv ring buffers.
    Returns (out (B,1,d), new_cache)."""
    s = cfg.ssm
    d_inner, h, n, w = ssm_dims(cfg)
    bsz = x.shape[0]
    xt = x[:, 0]
    z = xt @ p["wz"].astype(x.dtype)
    xs = xt @ p["wx"].astype(x.dtype)
    bm = xt @ p["wB"].astype(x.dtype)
    cm = xt @ p["wC"].astype(x.dtype)
    dt_raw = xt @ p["wdt"].astype(x.dtype)

    def conv_step(buf, new, kernel):
        full = jnp.concatenate([buf.astype(new.dtype), new[:, None]], axis=1)  # (B, w, C)
        out = jnp.einsum("bwc,wc->bc", full, kernel.astype(new.dtype))
        return jax.nn.silu(out), full[:, 1:]

    xs_c, nbx = conv_step(cache["conv_x"], xs, p["conv_x"])
    bm_c, nbb = conv_step(cache["conv_B"], bm, p["conv_B"])
    cm_c, nbc = conv_step(cache["conv_C"], cm, p["conv_C"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a_neg = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a_neg)                                      # (B,H)
    xh = xs_c.astype(jnp.float32).reshape(bsz, h, s.headdim)
    state = cache["state"].astype(jnp.float32)                       # (B,H,N,P)
    state = state * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bm_c.astype(jnp.float32), xh
    )
    y = jnp.einsum("bn,bhnp->bhp", cm_c.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(bsz, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(x.dtype))[:, None]
    return out, {"state": state, "conv_x": nbx, "conv_B": nbb, "conv_C": nbc}


def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_inner, h, n, w = ssm_dims(cfg)
    return {
        "state": jnp.zeros((batch, h, n, s.headdim), jnp.float32),
        "conv_x": jnp.zeros((batch, w - 1, d_inner), dtype),
        "conv_B": jnp.zeros((batch, w - 1, n), dtype),
        "conv_C": jnp.zeros((batch, w - 1, n), dtype),
    }

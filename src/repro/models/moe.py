"""Mixture-of-Experts feed-forward with expert parallelism (EP).

Sort-based capacity dispatch (static shapes, SPMD-shardable):

1. router logits -> top-k experts + renormalized gates per token;
2. flat (token, expert) assignments sorted by expert; each assignment gets a
   rank within its expert, assignments past ``capacity`` drop (standard
   capacity-factor semantics);
3. tokens scatter into per-expert buffers ``(E, C, d)``; experts run as a
   batched einsum (E is the EP-sharded dim — on a real mesh the scatter and
   gather around it become the MoE all-to-alls);
4. outputs gather-combine back weighted by gates.

Supports qwen2-moe (shared experts + routed top-4, experts padded to an
EP-divisible count with -inf router logits) and arctic (parallel dense FFN
residual + 128 routed top-2).

Aux losses: switch-style load-balance loss and router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, init_mlp, mlp


def _padded_experts(moe) -> int:
    return max(moe.pad_experts_to, moe.n_experts)


def _constrain(x, axes):
    """Best-effort with_sharding_constraint by standard axis names (data /
    model / pod); silently skipped when no mesh context provides them (host
    meshes in tests). Step factories enter ``with mesh:`` so this resolves
    on the production meshes."""
    from jax.sharding import PartitionSpec as P

    names: set = set()
    try:
        am = jax.sharding.get_abstract_mesh()
        names |= set(getattr(am, "axis_names", ()) or ())
    except Exception:
        pass
    try:
        from jax._src import mesh as _mesh_lib

        pm = _mesh_lib.thread_resources.env.physical_mesh
        names |= set(getattr(pm, "axis_names", ()) or ())
    except Exception:
        pass
    spec = P(*[a if (a in names) else None for a in axes])
    if all(s is None for s in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def init_moe(rng, cfg, dtype) -> dict:
    moe = cfg.moe
    d, f = cfg.d_model, moe.d_expert
    e = _padded_experts(moe)
    ks = jax.random.split(rng, 6)
    p = {
        "router": dense_init(ks[0], (d, e), 0, dtype=jnp.float32),  # fp32 router
        "w_gate": dense_init(ks[1], (e, d, f), 1, dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, f), 1, dtype=dtype),
        "w_down": dense_init(ks[3], (e, f, d), 1, dtype=dtype),
    }
    if moe.n_shared:
        p["shared"] = init_mlp(ks[4], d, moe.n_shared * f, dtype)
    if moe.dense_ff_parallel:
        p["dense"] = init_mlp(ks[5], d, moe.dense_ff_parallel, dtype)
    return p


def _router(cfg, p, xf):
    """xf: (..., d) -> (probs, gates, expert_idx, logits) with padding masked."""
    moe = cfg.moe
    e_pad = _padded_experts(moe)
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    if e_pad > moe.n_experts:
        pad_mask = jnp.arange(e_pad) >= moe.n_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, moe.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    return probs, gate_vals, expert_idx, logits


def _aux_losses(cfg, probs, expert_idx, logits):
    moe = cfg.moe
    e_pad = probs.shape[-1]
    n_assign = int(np.prod(expert_idx.shape))
    me = probs.reshape(-1, e_pad).mean(axis=0)
    ce = jnp.zeros(e_pad).at[expert_idx.reshape(-1)].add(1.0) / n_assign
    aux_loss = moe.n_experts * jnp.sum(me * ce) * moe.aux_loss_weight
    z_loss = moe.router_z_weight * jnp.mean(
        jax.scipy.special.logsumexp(logits, axis=-1) ** 2
    )
    return {"moe_aux_loss": aux_loss, "router_z_loss": z_loss}


def _rank_within_expert(sorted_e: jnp.ndarray) -> jnp.ndarray:
    """Rank of each sorted assignment within its expert run (batched, no
    searchsorted): rank = pos - cummax(segment-start positions)."""
    nk = sorted_e.shape[-1]
    pos = jnp.arange(nk)
    start = jnp.concatenate(
        [jnp.ones((*sorted_e.shape[:-1], 1), bool),
         sorted_e[..., 1:] != sorted_e[..., :-1]], axis=-1,
    )
    seg_start = jnp.where(start, pos, 0)
    running = jax.lax.cummax(seg_start, axis=sorted_e.ndim - 1)
    return pos - running


def moe_block(cfg, p, x: jnp.ndarray) -> tuple[jnp.ndarray, dict]:
    """x: (B, S, d) -> (out, aux).

    Two dispatch strategies (§Perf iteration 3):

    * ``moe_grouped=False`` (baseline): one global sort-dispatch over all
      B*S tokens. Correct, but under SPMD the scatter into the E-sharded
      buffer makes XLA all-gather the whole (E, C, d) buffer per chip.
    * ``moe_grouped=True``: gshard-style groups = batch rows. Dispatch and
      combine are *group-local* (batch is data-sharded, so no cross-chip
      traffic); only the (G, E, Cg, d) buffer crosses the mesh as a single
      data<->model all-to-all around the EP einsum — the minimal routing
      traffic of top_k * tokens * d * capacity_factor bytes.
    """
    moe = cfg.moe
    b, s, d = x.shape
    e_pad = _padded_experts(moe)
    e_real = moe.n_experts
    k = moe.top_k

    if cfg.moe_grouped:
        g, n = b, s
    else:
        g, n = 1, b * s
    capacity = max(int(moe.capacity_factor * n * k / e_real), k)

    xg = x.reshape(g, n, d)
    probs, gate_vals, expert_idx, logits = _router(cfg, p, xg)   # (g,n,·)
    aux = _aux_losses(cfg, probs, expert_idx, logits)

    flat_e = expert_idx.reshape(g, n * k)
    flat_gates = gate_vals.reshape(g, n * k)
    order = jnp.argsort(flat_e, axis=1)                          # stable
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    rank = _rank_within_expert(sorted_e)
    keep = rank < capacity
    buf_slot = jnp.where(keep, sorted_e * capacity + rank, e_pad * capacity)
    token_of = order // k                                        # (g, n*k)

    gidx = jnp.arange(g)[:, None]
    buf = jnp.zeros((g, e_pad * capacity + 1, d), x.dtype)
    vals = jnp.take_along_axis(xg, token_of[..., None], axis=1)
    buf = buf.at[gidx, buf_slot].set(vals * keep[..., None].astype(x.dtype))
    expert_in = buf[:, :-1].reshape(g, e_pad, capacity, d)
    if cfg.moe_grouped:
        # steer SPMD to the EP all-to-all: groups ride the batch (data) axis
        # into the dispatch, experts ride the model axis through the einsum
        expert_in = _constrain(expert_in, ("data", "model", None, None))

    # ---- expert computation (E is the EP axis; g is the DP axis)
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"].astype(x.dtype))
    ) * jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"].astype(x.dtype))
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))

    # ---- combine (group-local gather + scatter-add)
    if cfg.moe_grouped:
        # bring outputs home (all-to-all back to group shards) so the
        # scatter-add combine is chip-local instead of a psum over 'model'
        expert_out = _constrain(expert_out, ("data", None, None, None))
    out_flat = expert_out.reshape(g, e_pad * capacity, d)
    contrib = jnp.take_along_axis(
        out_flat, jnp.minimum(buf_slot, e_pad * capacity - 1)[..., None], axis=1
    )
    sorted_gates = jnp.take_along_axis(flat_gates, order, axis=1)
    contrib = contrib * (sorted_gates * keep)[..., None].astype(x.dtype)
    y = jnp.zeros((g, n, d), x.dtype).at[gidx, token_of].add(contrib)
    y = y.reshape(b * s, d)

    xf = x.reshape(b * s, d)
    if moe.n_shared:
        y = y + mlp(p["shared"], xf)
    if moe.dense_ff_parallel:
        y = y + mlp(p["dense"], xf)
    return y.reshape(b, s, d), aux

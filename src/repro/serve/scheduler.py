"""Batched serving: continuous-batching request scheduler.

Requests (prompts) queue up; the scheduler packs up to ``max_batch`` slots,
prefills new requests into their slots, then decodes all active slots
together one token/step. A slot frees when its request emits EOS or hits
``max_new_tokens``, and is refilled from the queue on the next cycle —
continuous batching with a fixed-capacity cache (static shapes: one compiled
prefill per wave length + one compiled decode).

The cache position is a per-slot vector (``cache["pos"]: (max_batch,)``), so
an admission wave prefills into *free* slots only: in-flight slots keep their
KV rows and decode positions untouched (the admission wave runs on a fresh
zero cache and only the admitted slots' rows are merged back). Attention
families mask per slot, so right-padding an uneven wave cannot leak into the
generated tokens; SSM state carries a small right-pad approximation for
uneven waves (positionless recurrence — noted in DESIGN.md).

Latency accounting uses ``time.perf_counter`` (monotonic, matching
``repro.obs``) and folds TTFT / total latency into the ``serve.ttft_s`` /
``serve.latency_s`` obs histograms, so the serve tier reports percentiles
the same way scans do.

For the assignment's decode shapes, ``make_serve_step`` in
repro.train.train_loop is the distributed version of the same step; this
scheduler is the host-side orchestration used by examples/serve_lm.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models.model import build_model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False
    # monotonic (perf_counter) timestamps — durations only, not wall time
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class BatchedServer:
    def __init__(self, cfg, params, *, max_batch: int = 4, max_len: int = 256,
                 eos_id: int = 2, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.model = build_model(cfg)
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id

        # per-slot caches (batch dim = max_batch); positions per slot
        self.cache = self._fresh_cache()
        self.slot_req: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []
        self._next_rid = 0
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))

    def _fresh_cache(self) -> dict:
        cache = self.model.init_cache(self.max_batch, self.max_len)
        cache["pos"] = jnp.zeros((self.max_batch,), jnp.int32)
        return cache

    # ------------------------------------------------------------------- API
    def submit(self, prompt, max_new_tokens=32, rid=None) -> Request:
        if rid is None:
            rid = self._next_rid
        # keep the counter ahead of explicit rids so later defaults never
        # collide with them (or with requests already drained from the queue)
        self._next_rid = max(self._next_rid, rid + 1)
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens,
                      t_submit=time.perf_counter())
        self.queue.append(req)
        return req

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Run until queue + slots drain. Returns completed requests."""
        completed: list[Request] = []
        seen_rids: set[int] = set()
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self._fill_slots()
            self._decode_once()
            steps += 1
            for i, req in enumerate(self.slot_req):
                if req is not None and req.done:
                    assert req.rid not in seen_rids, \
                        f"duplicate request id {req.rid}"
                    seen_rids.add(req.rid)
                    completed.append(req)
                    self.slot_req[i] = None
        return completed

    # -------------------------------------------------------------- internals
    def _merge_admitted(self, live: dict, fresh: dict, mask: np.ndarray) -> dict:
        """Take admitted slots' rows from ``fresh``, everything else from
        ``live`` — in-flight slots' KV rows and positions are untouched."""
        m = jnp.asarray(mask)
        out = dict(live)
        out["pos"] = jnp.where(m, fresh["pos"], live["pos"])
        for key in ("layers", "sites", "cross"):
            if key not in live:
                continue
            # leading axis is the layer/site stack; batch is axis 1
            out[key] = jax.tree.map(
                lambda a, b: jnp.where(
                    m.reshape((1, self.max_batch) + (1,) * (a.ndim - 2)), b, a),
                live[key], fresh[key],
            )
        return out

    def _fill_slots(self):
        """Admit queued requests into free slots while others keep decoding.

        The admission wave prefills on a *fresh* zero cache (so stale KV in
        recycled slots can't bleed in), then only the admitted slots' cache
        rows and positions are merged into the live cache. Per-slot
        positions make the merged batch decode correctly even though slots
        sit at different sequence offsets."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if not free or not self.queue:
            return
        admitted = []
        for i in free:
            if not self.queue:
                break
            req = self.queue.pop(0)
            self.slot_req[i] = req
            admitted.append((i, req))
        maxp = max(len(r.prompt) for _, r in admitted)
        toks = np.zeros((self.max_batch, maxp), np.int32)
        lens = np.zeros(self.max_batch, np.int32)
        mask = np.zeros(self.max_batch, bool)
        for i, req in admitted:
            toks[i, : len(req.prompt)] = req.prompt
            lens[i] = len(req.prompt)
            mask[i] = True
        fresh = self._fresh_cache()
        logits, fresh = self.model.forward_with_cache(
            self.params, {"tokens": jnp.asarray(toks)}, fresh
        )
        # the wave is right-padded: each admitted slot's position is its own
        # prompt length, so decode overwrites the pad KV instead of appending
        fresh["pos"] = jnp.asarray(lens)
        self.cache = self._merge_admitted(self.cache, fresh, mask)
        logits = np.asarray(logits)
        now = time.perf_counter()
        for i, req in admitted:
            # first token comes from the last *real* prompt position
            nxt = int(np.argmax(logits[i, len(req.prompt) - 1]))
            req.out_tokens = [nxt]
            req.t_first = now
            obs.observe("serve.ttft_s", req.t_first - req.t_submit)

    def _decode_once(self):
        active = [(i, r) for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        cur = np.zeros((self.max_batch, 1), np.int32)
        for i, req in active:
            cur[i, 0] = req.out_tokens[-1] if req.out_tokens else self.eos_id
        logits, self.cache = self._decode(self.params, jnp.asarray(cur), self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        pos = np.asarray(self.cache["pos"])
        for i, req in active:
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            if tok == self.eos_id or len(req.out_tokens) >= req.max_new_tokens \
               or int(pos[i]) >= self.max_len - 1:
                req.done = True
                req.t_done = time.perf_counter()
                obs.observe("serve.latency_s", req.t_done - req.t_submit)

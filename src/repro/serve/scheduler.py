"""Batched serving: continuous-batching-lite request scheduler.

Requests (prompts) queue up; the scheduler packs up to ``max_batch`` slots,
prefills new requests into their slots, then decodes all active slots
together one token/step. A slot frees when its request emits EOS or hits
``max_new_tokens``, and is refilled from the queue on the next cycle —
continuous batching with a fixed-capacity cache (static shapes: one compiled
prefill + one compiled decode).

For the assignment's decode shapes, ``make_serve_step`` in
repro.train.train_loop is the distributed version of the same step; this
scheduler is the host-side orchestration used by examples/serve_lm.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import build_model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class BatchedServer:
    def __init__(self, cfg, params, *, max_batch: int = 4, max_len: int = 256,
                 eos_id: int = 2, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.model = build_model(cfg)
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id

        # per-slot caches (batch dim = max_batch); positions per slot
        self.cache = self.model.init_cache(max_batch, max_len)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))

    # ------------------------------------------------------------------- API
    def submit(self, prompt, max_new_tokens=32, rid=None) -> Request:
        req = Request(rid=rid if rid is not None else len(self.queue),
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, t_submit=time.time())
        self.queue.append(req)
        return req

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Run until queue + slots drain. Returns completed requests."""
        completed: list[Request] = []
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self._fill_slots()
            self._decode_once()
            steps += 1
            for i, req in enumerate(self.slot_req):
                if req is not None and req.done:
                    completed.append(req)
                    self.slot_req[i] = None
        return completed

    # -------------------------------------------------------------- internals
    def _fill_slots(self):
        """Admit a wave of queued requests when the batch is idle.

        Wave batching: all slots share the cache position scalar, so a new
        wave is admitted only when every slot is free (true continuous
        batching needs per-slot positions — noted as a framework extension;
        the distributed serve_step itself is position-vector-ready since
        apply_rope accepts (B, S) positions)."""
        if any(r is not None for r in self.slot_req):
            return
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if not free or not self.queue:
            return
        admitted = []
        for i in free:
            if not self.queue:
                break
            req = self.queue.pop(0)
            self.slot_req[i] = req
            admitted.append((i, req))
        if not admitted:
            return
        # prefill each admitted slot: run a forward_with_cache over the
        # prompt for the whole batch but mask writes to other slots by
        # zero-length... static shapes require a uniform prefill, so we
        # prefill per admission wave with right-padded prompts and reset pos.
        maxp = max(len(r.prompt) for _, r in admitted)
        toks = np.zeros((self.max_batch, maxp), np.int32)
        for i, req in admitted:
            toks[i, : len(req.prompt)] = req.prompt
        cache = jax.tree.map(lambda a: a, self.cache)
        cache["pos"] = jnp.zeros((), jnp.int32)
        logits, cache = self.model.forward_with_cache(
            self.params, {"tokens": jnp.asarray(toks)}, cache
        )
        self.cache = cache
        last = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        now = time.time()
        for i, req in admitted:
            req.out_tokens = [int(last[i])]
            req.t_first = now

    def _decode_once(self):
        active = [(i, r) for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        cur = np.zeros((self.max_batch, 1), np.int32)
        for i, req in active:
            cur[i, 0] = req.out_tokens[-1] if req.out_tokens else self.eos_id
        logits, self.cache = self._decode(self.params, jnp.asarray(cur), self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        pos = int(self.cache["pos"])
        for i, req in active:
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            if tok == self.eos_id or len(req.out_tokens) >= req.max_new_tokens \
               or pos >= self.max_len - 1:
                req.done = True
                req.t_done = time.time()

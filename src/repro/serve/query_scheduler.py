"""Multi-tenant bbox query server over a Spatial Parquet dataset.

Continuous-batching-lite for spatial scans: concurrent bbox queries are
admitted in waves, their surviving ``(shard, row group)`` sets are unioned,
and each surviving row group is decoded **once** per wave — the multi-query
refinement then runs as a single launch with the queries' order-key bounds
stacked along a bbox axis (`decode_refine_stream_multi`). This is the
corrected form of the LM scheduler's admission pattern
(:mod:`repro.serve.scheduler`): shared state touched by a new wave must be
written *per slot*, never whole-batch — here the shared state is the decoded
row-group cache, and a wave only ever adds entries keyed by
``(shard, row group, generation)``; in-flight results of earlier queries are
never rewritten.

Caching and identity
--------------------

Pages are record-aligned, so a record decoded from the *full* row group is
bit-identical to the same record decoded through any bbox-pruned page run.
That makes the whole row group the natural cache unit:
:meth:`~repro.core.reader.SpatialParquetReader.read_row_group` decodes every
page once, and each query gathers only its own hit-run record ranges out of
the shared decode. In device mode the cache keeps the decoded stream limbs
and the per-record min/max **order-key stack** on the accelerator; a cache
hit re-tests new bboxes with a compare-only launch
(`refine_minmax_multi`) — no decode, no scan. Hit and miss paths share the
exact compare of the solo fused scan, so every query's survivor set (and
therefore its results) is bit-identical to a sequential
``scanner.scan(bbox, refine=True)``.

Attribution and telemetry
-------------------------

Each query carries its own :class:`~repro.core.reader.ReadStats`, computed
from index metadata to equal what its *unshared* solo scan would have
reported (pages/bytes pruned and read, records scanned/returned) — sharing
the decode changes the cost, not the attribution. Every query runs under an
``obs.span("serve.query")`` and folds its end-to-end latency into the
``serve.query_latency_s`` histogram; :meth:`SpatialQueryServer.metrics`
reports p50/p99 from that histogram plus cache hit/evict counters and the
shared-decode ratio (row-group touches per actual decode).
"""

from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field

import numpy as np

from repro import obs
from repro.core.columnar import GeometryColumns
from repro.core.reader import ReadStats, RowGroupData, _LEVEL_NAMES

__all__ = ["SpatialQuery", "SpatialQueryServer"]


@dataclass
class SpatialQuery:
    """One submitted bbox query and, after :meth:`SpatialQueryServer.run`,
    its results: the same ``(geo, extras, stats)`` triple a solo
    ``scanner.scan(bbox, refine=True)`` returns, plus timing."""

    qid: int
    bbox: tuple | None
    columns: tuple | None = None
    # attribute predicate (repro.core.filters.Predicate); evaluated against
    # the shared row-group decodes so results equal a solo
    # ``scanner.scan(bbox, refine=True, filter=...)``
    filter: object | None = None
    geo: GeometryColumns | None = None
    extras: dict = field(default_factory=dict)
    stats: ReadStats | None = None
    done: bool = False
    t_submit: float = 0.0  # perf_counter timestamps (monotonic)
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


@dataclass
class _HostChunkState:
    """Per-launch-chunk cache state, host compare flavor: decoded values
    plus the NaN-propagating per-record bbox statistics (float64; zero-count
    records hold NaN so every compare drops them, matching
    ``_bbox_keep_mask``)."""

    rec_lo: int
    rec_hi: int
    x: np.ndarray
    y: np.ndarray
    starts: np.ndarray  # chunk-local value start per record
    counts: np.ndarray
    xmin: np.ndarray
    xmax: np.ndarray
    ymin: np.ndarray
    ymax: np.ndarray

    def keep(self, bbox) -> np.ndarray:
        qx0, qy0, qx1, qy1 = bbox
        with np.errstate(invalid="ignore"):
            return ((self.xmin <= qx1) & (self.xmax >= qx0)
                    & (self.ymin <= qy1) & (self.ymax >= qy0))

    def gather(self, sub: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        from repro.kernels.fp_delta import ragged_ranges

        iv = ragged_ranges(self.starts[sub], self.counts[sub])
        return self.x[iv], self.y[iv]


@dataclass
class _DevChunkState:
    """Device flavor: decoded stream limbs + the (8, n_rec_pad) min/max
    order-key stack stay on the accelerator; ``aux`` keeps the record
    segmentation for survivor gathers."""

    rec_lo: int
    rec_hi: int
    lo: object
    hi: object
    minmax: object
    aux: object
    width: int

    def keep_multi(self, qkeys, qvalid) -> np.ndarray:
        from repro.kernels.fp_delta import refine_minmax_multi

        return refine_minmax_multi(
            self.minmax, self.aux.valid, qkeys, qvalid,
            width=self.width, n_records=self.rec_hi - self.rec_lo)

    def gather(self, sub: np.ndarray, dtype) -> tuple[np.ndarray, np.ndarray]:
        from repro.kernels.fp_delta import gather_stream_values, ragged_ranges

        xs = np.asarray(self.aux.x_start)
        ys = np.asarray(self.aux.y_start)
        cs = np.asarray(self.aux.counts)
        ix = ragged_ranges(xs[sub], cs[sub])
        iy = ragged_ranges(ys[sub], cs[sub])
        return (gather_stream_values(self.lo, self.hi, ix, self.width, dtype),
                gather_stream_values(self.lo, self.hi, iy, self.width, dtype))


def _host_chunk_stats(rec_lo, rec_hi, x, y, vcounts) -> _HostChunkState:
    counts = np.asarray(vcounts, np.int64)
    starts = np.cumsum(counts) - counts
    n = len(counts)
    mins = np.full((4, n), np.nan)
    nz = counts > 0
    if nz.any():
        s = starts[nz]
        xs = x.astype(np.float64, copy=False)
        ys = y.astype(np.float64, copy=False)
        mins[0, nz] = np.minimum.reduceat(xs, s)
        mins[1, nz] = np.maximum.reduceat(xs, s)
        mins[2, nz] = np.minimum.reduceat(ys, s)
        mins[3, nz] = np.maximum.reduceat(ys, s)
    return _HostChunkState(rec_lo, rec_hi, x, y, starts, counts,
                           mins[0], mins[1], mins[2], mins[3])


@dataclass
class _CacheEntry:
    data: RowGroupData
    chunks: list  # _HostChunkState | _DevChunkState, record order


class _RowGroupCache:
    """LRU over decoded row groups, keyed ``(shard, rg, generation)``.

    The generation is bumped by :meth:`SpatialQueryServer.invalidate` (e.g.
    after the dataset is rewritten in place); stale-generation entries can
    never be returned because the key includes it, and they are dropped
    eagerly so device memory is released."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._d: OrderedDict[tuple, _CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key) -> _CacheEntry | None:
        e = self._d.get(key)
        if e is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return e

    def put(self, key, entry: _CacheEntry) -> None:
        self._d[key] = entry
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def drop_all(self) -> None:
        self._d.clear()

    def __len__(self) -> int:
        return len(self._d)


class SpatialQueryServer:
    """Serve concurrent bbox queries over one dataset with shared decodes.

    ``device="jax"`` keeps decoded row groups accelerator-resident and runs
    multi-query refinement as one fused launch per row group (falls back to
    host compares for non-float coordinates, like the solo scan).
    ``cache_rgs`` bounds the decoded-row-group LRU; ``max_wave`` bounds how
    many pending queries join one admission wave. Queries always refine
    (results are exact, identical to ``scan(bbox, refine=True)``); a
    ``bbox=None`` query returns the full dataset.
    """

    def __init__(self, scanner, *, device: str = "cpu", cache_rgs: int = 32,
                 max_wave: int = 64):
        if device not in ("cpu", "jax"):
            raise ValueError(f"device must be 'cpu' or 'jax', got {device!r}")
        self.scanner = scanner
        self._device_requested = device
        self.coord_dtype = np.dtype(scanner.manifest.coord_dtype)
        # device refinement needs float order keys; exotic int coordinates
        # take the host compare path (same fallback as the solo fused scan)
        self.device = device if self.coord_dtype.kind == "f" else "cpu"
        self.width = self.coord_dtype.itemsize * 8
        self.cache = _RowGroupCache(cache_rgs)
        self.max_wave = int(max_wave)
        self.generation = 0
        # catalog-backed scanners: pin the generation the open readers point
        # at, so a background compaction's GC can never delete shard files
        # out from under them mid-wave
        self.data_generation = getattr(scanner, "generation", 0)
        catalog = getattr(scanner, "catalog", None)
        self._gen_pin = (catalog.pin(self.data_generation)
                         if catalog is not None else None)
        self.pending: deque[SpatialQuery] = deque()
        self._next_qid = 0
        self._readers: dict[int, object] = {}
        # shared-decode accounting: touches / decodes ≈ how many solo decodes
        # one shared decode replaced
        self.queries_total = 0
        self.waves = 0
        self.rg_touches = 0
        self.rg_decodes = 0

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        for r in self._readers.values():
            r.close()
        self._readers.clear()
        self.cache.drop_all()
        if self._gen_pin is not None:
            self._gen_pin.release()
            self._gen_pin = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def invalidate(self) -> None:
        """Invalidate every cached decode (dataset mutated in place)."""
        self.generation += 1
        self.cache.drop_all()

    def _sync_generation(self) -> bool:
        """Adopt a newer catalog generation before admitting a wave.

        Returns True when a commit (e.g. background compaction) moved the
        head since the last wave: open shard readers are closed, the decoded
        row-group cache is invalidated (its keys include the bumped
        ``generation``, so stale decodes are unreachable *and* dropped), the
        schema-derived state is re-derived, and the server's pin moves to
        the new generation so its files outlive the next GC.
        """
        refresh = getattr(self.scanner, "refresh", None)
        if refresh is None:
            return False
        gen = refresh()
        if gen == self.data_generation:
            return False
        for r in self._readers.values():
            r.close()
        self._readers.clear()
        self.invalidate()
        self.coord_dtype = np.dtype(self.scanner.manifest.coord_dtype)
        self.device = (self._device_requested
                       if self.coord_dtype.kind == "f" else "cpu")
        self.width = self.coord_dtype.itemsize * 8
        if self._gen_pin is not None:
            new_pin = self.scanner.catalog.pin(gen)
            self._gen_pin.release()
            self._gen_pin = new_pin
        obs.instant("serve.generation_bump", cat="serve",
                    old=self.data_generation, new=gen)
        self.data_generation = gen
        return True

    def _reader(self, shard_i: int):
        r = self._readers.get(shard_i)
        if r is None:
            r = self._readers[shard_i] = self.scanner.open_shard(shard_i)
        return r

    # ------------------------------------------------------------------ API
    def submit(self, bbox=None, columns=None, filter=None) -> SpatialQuery:
        if filter is not None:
            from repro.core.filters import validate_predicate

            validate_predicate(filter, self.scanner.extra_schema)
        q = SpatialQuery(self._next_qid, bbox, columns, filter,
                         t_submit=time.perf_counter())
        self._next_qid += 1
        self.pending.append(q)
        return q

    def run(self) -> list[SpatialQuery]:
        """Drain the pending queue in admission waves; returns the completed
        queries in submission order."""
        out = []
        while self.pending:
            self._sync_generation()
            wave = [self.pending.popleft()
                    for _ in range(min(self.max_wave, len(self.pending)))]
            self._run_wave(wave)
            out.extend(wave)
        return out

    def metrics(self) -> dict:
        m = {
            "queries": self.queries_total,
            "waves": self.waves,
            "rg_touches": self.rg_touches,
            "rg_decodes": self.rg_decodes,
            "shared_decode_ratio":
                self.rg_touches / max(1, self.rg_decodes),
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "cache_evictions": self.cache.evictions,
            "cache_entries": len(self.cache),
        }
        m.update({f"latency_{k}": v
                  for k, v in obs.percentiles("serve.query_latency_s").items()})
        return m

    # ------------------------------------------------------------ internals
    def _plan(self, q: SpatialQuery):
        """Shard/page pruning + metadata-only ReadStats for one query —
        exactly the accounting of its solo ``scanner.scan``."""
        dindex = self.scanner.index
        hits = [int(i) for i in dindex.query(q.bbox, filter=q.filter)]
        hit_set = set(hits)
        stats = ReadStats(shards_total=len(dindex), shards_read=len(hits))
        for i, shard in enumerate(self.scanner.manifest.shards):
            if i not in hit_set:
                stats.pages_total += shard.n_pages
                stats.bytes_total += shard.data_bytes
        want_extra = (list(self.scanner.extra_schema) if q.columns is None
                      else [c for c in q.columns
                            if c in self.scanner.extra_schema])
        # the solo scan also fetches the predicate's columns (then trims
        # them from the output); mirror that in the byte attribution
        read_extra = want_extra if q.filter is None else want_extra + sorted(
            c for c in q.filter.columns() if c not in want_extra)
        plan: dict[tuple[int, int], list[tuple[int, int]]] = {}
        for shard_i in hits:
            r = self._reader(shard_i)
            idx = r.index
            stats.pages_total += len(idx)
            stats.bytes_total += r._data_bytes
            runs_by_rg: dict[int, list[tuple[int, int]]] = {}
            for rg_i, p0, p1 in idx.page_runs(
                    q.bbox, hit=idx.query(q.bbox, filter=q.filter)):
                runs_by_rg.setdefault(rg_i, []).append((p0, p1))
            for rg_i, runs in runs_by_rg.items():
                plan[(shard_i, rg_i)] = runs
                rg = r.footer["row_groups"][rg_i]
                base = int(np.searchsorted(idx.row_group, rg_i, side="left"))
                stats.bytes_read += sum(
                    rg[name]["nbytes"] for name in _LEVEL_NAMES)
                for p0, p1 in runs:
                    j0, j1 = base + p0, base + p1 - 1
                    stats.pages_read += p1 - p0
                    stats.records_scanned += int(
                        idx.rec_start[j1] + idx.rec_count[j1]
                        - idx.rec_start[j0])
                    stats.bytes_read += int(
                        idx.x_nbytes[j0 : j1 + 1].sum()
                        + idx.y_nbytes[j0 : j1 + 1].sum())
                    for k in read_extra:
                        stats.bytes_read += sum(
                            rg["extra"][k][p]["nbytes"] for p in range(p0, p1))
        return hits, plan, want_extra, stats

    def _fill_entry(self, shard_i: int, rg_i: int, qkeys, qvalid):
        """Cache miss: decode the whole row group once. Device mode fuses
        the *current wave's* multi-query refinement into the decode launch
        and returns its keep matrix alongside the new entry."""
        r = self._reader(shard_i)
        self.rg_decodes += 1
        data = r.read_row_group(rg_i, device=self.device)
        chunks: list = []
        wave_keep: np.ndarray | None = None
        if self.device == "cpu":
            chunks.append(_host_chunk_stats(
                0, data.n_records, data.x, data.y, data.rec_vcounts))
        else:
            from repro.kernels.fp_delta import decode_refine_stream_multi

            wave_keep = np.zeros((len(qkeys), data.n_records), bool)
            for ch in data.chunks:
                vc = data.rec_vcounts[ch.rec_lo : ch.rec_hi]
                if ch.kind == "host":
                    chunks.append(_host_chunk_stats(
                        ch.rec_lo, ch.rec_hi, ch.x, ch.y, vc))
                    continue
                res = decode_refine_stream_multi(ch.stream, ch.aux,
                                                 qkeys, qvalid)
                chunks.append(_DevChunkState(
                    ch.rec_lo, ch.rec_hi, res.lo, res.hi, res.minmax,
                    ch.aux, self.width))
                wave_keep[:, ch.rec_lo : ch.rec_hi] = res.keep
        return _CacheEntry(data, chunks), wave_keep

    def _rg_keep(self, entry: _CacheEntry, bboxes, filters, qkeys, qvalid,
                 wave_keep) -> np.ndarray:
        """(Q, n_records) survivor matrix for this row group: the fused miss
        launch's matrix when fresh, else compare-only re-tests of the cached
        statistics. ``bbox=None`` rows keep everything; a query's attribute
        predicate then ANDs its exact record mask into its row (masks are
        memoized per predicate key, so same-predicate queries in a wave
        evaluate it once per row group)."""
        n_rec = entry.data.n_records
        keep = np.zeros((len(bboxes), n_rec), bool)
        dev_done = wave_keep is not None
        dev_keep = wave_keep
        if not dev_done and any(isinstance(c, _DevChunkState)
                                for c in entry.chunks):
            dev_keep = np.zeros((len(bboxes), n_rec), bool)
            for c in entry.chunks:
                if isinstance(c, _DevChunkState):
                    dev_keep[:, c.rec_lo : c.rec_hi] = c.keep_multi(
                        qkeys, qvalid)
        for c in entry.chunks:
            if isinstance(c, _DevChunkState):
                keep[:, c.rec_lo : c.rec_hi] = dev_keep[:, c.rec_lo : c.rec_hi]
            else:
                for qi, bbox in enumerate(bboxes):
                    if bbox is not None:
                        keep[qi, c.rec_lo : c.rec_hi] = c.keep(bbox)
        for qi, bbox in enumerate(bboxes):
            if bbox is None:
                keep[qi, :] = True
        masks: dict[tuple, np.ndarray] = {}
        for qi, pred in enumerate(filters):
            if pred is None:
                continue
            attr = masks.get(pred.key)
            if attr is None:
                attr = masks[pred.key] = pred.mask(
                    {k: entry.data.extras[k] for k in pred.columns()})
            keep[qi, :] &= attr
        return keep

    def _run_wave(self, wave: list[SpatialQuery]) -> None:
        from repro.kernels.fp_delta import ragged_ranges
        from repro.kernels.minmax import stack_bbox_query_keys

        self.waves += 1
        self.queries_total += len(wave)
        with obs.span("serve.wave", cat="serve", queries=len(wave)):
            plans = [self._plan(q) for q in wave]
            # (Q, 4, 2) stacked order-key bounds for the whole wave; a
            # bbox=None query gets an invalid (all-False) row that _rg_keep
            # rewrites to all-True — it must not be fenced in key space
            qkeys, qvalid = stack_bbox_query_keys(
                [q.bbox if q.bbox is not None else (np.nan,) * 4
                 for q in wave], self.coord_dtype)
            bboxes = [q.bbox for q in wave]
            filters = [q.filter for q in wave]

            acc = [_QueryAccum(list(self.scanner.extra_schema)
                               if q.columns is None else
                               [c for c in q.columns
                                if c in self.scanner.extra_schema])
                   for q in wave]
            union = sorted({key for _, plan, _, _ in plans for key in plan})
            for shard_i, rg_i in union:
                touching = [qi for qi, (_, plan, _, _) in enumerate(plans)
                            if (shard_i, rg_i) in plan]
                self.rg_touches += len(touching)
                key = (shard_i, rg_i, self.generation)
                entry = self.cache.get(key)
                wave_keep = None
                if entry is None:
                    entry, wave_keep = self._fill_entry(
                        shard_i, rg_i, qkeys, qvalid)
                    self.cache.put(key, entry)
                keep = self._rg_keep(entry, bboxes, filters, qkeys, qvalid,
                                     wave_keep)
                idx = self._reader(shard_i).index
                base = int(np.searchsorted(idx.row_group, rg_i, side="left"))
                vc = entry.data.rec_vcounts
                for qi in touching:
                    runs = plans[qi][1][(shard_i, rg_i)]
                    a = acc[qi]
                    rec_parts = []
                    for p0, p1 in runs:
                        j0, j1 = base + p0, base + p1 - 1
                        r0 = int(idx.rec_start[j0])
                        r1 = int(idx.rec_start[j1] + idx.rec_count[j1])
                        entry.data.levels.append_run(a.level_parts, r0, r1)
                        a.keep_parts.append(keep[qi, r0:r1])
                        for k in a.want_extra:
                            a.extra_parts[k].append(
                                entry.data.extras[k][r0:r1])
                        rec_parts.append(np.arange(r0, r1))
                    recs = (np.concatenate(rec_parts) if rec_parts
                            else np.zeros(0, np.int64))
                    kept = recs[keep[qi, recs]]
                    for c in entry.chunks:
                        sub = kept[(kept >= c.rec_lo) & (kept < c.rec_hi)] \
                            - c.rec_lo
                        if isinstance(c, _DevChunkState):
                            xv, yv = c.gather(sub, self.coord_dtype)
                        else:
                            xv, yv = c.gather(sub)
                        a.x_parts.append(xv)
                        a.y_parts.append(yv)

            for q, (hits, _, want_extra, stats), a in zip(wave, plans, acc):
                self._finalize(q, hits, want_extra, stats, a)

    def _finalize(self, q: SpatialQuery, hits, want_extra,
                  stats: ReadStats, a: "_QueryAccum") -> None:
        """Assemble one query's result exactly like the solo fused scan's
        tail (level compaction by the record-aligned cumsum trick)."""
        with obs.span("serve.query", cat="serve", qid=q.qid,
                      shards=len(hits)) as sp:
            self._finalize_inner(q, hits, want_extra, stats, a)
            sp.add(records=stats.records_returned)
        obs.observe("serve.query_latency_s", q.latency_s)

    def _finalize_inner(self, q: SpatialQuery, hits, want_extra,
                        stats: ReadStats, a: "_QueryAccum") -> None:
        do_refine = q.bbox is not None or q.filter is not None
        keep_all = (np.concatenate(a.keep_parts) if a.keep_parts
                    else np.zeros(0, bool))
        types_parts, type_rep_parts, rep_parts, defn_parts = a.level_parts
        if types_parts:
            types = np.concatenate(types_parts)
            type_rep = np.concatenate(type_rep_parts)
            rep = np.concatenate(rep_parts)
            defn = np.concatenate(defn_parts)
            if do_refine:
                slot_keep = keep_all[np.cumsum(rep == 0) - 1]
                type_keep = keep_all[np.cumsum(type_rep == 0) - 1]
                types = types[type_keep]
                type_rep = type_rep[type_keep]
                rep = rep[slot_keep]
                defn = defn[slot_keep]
            x = (np.concatenate(a.x_parts) if a.x_parts
                 else np.zeros(0, self.coord_dtype))
            y = (np.concatenate(a.y_parts) if a.y_parts
                 else np.zeros(0, self.coord_dtype))
            q.geo = GeometryColumns(types, type_rep, rep, defn, x, y)
        else:
            q.geo = None
        if hits:
            extras = {
                k: (np.concatenate(a.extra_parts[k]) if a.extra_parts[k]
                    else np.zeros(0, np.dtype(self.scanner.extra_schema[k])))
                for k in want_extra
            }
            if do_refine and q.geo is not None:
                extras = {k: v[keep_all] for k, v in extras.items()}
        else:
            extras = {}
        q.extras = extras
        stats.records_returned = q.geo.n_records if q.geo is not None else (
            len(next(iter(extras.values()))) if extras else 0)
        q.stats = stats
        q.done = True
        q.t_done = time.perf_counter()


class _QueryAccum:
    """Per-query result parts, appended in the query's own scan order."""

    def __init__(self, want_extra):
        self.want_extra = want_extra
        self.level_parts = ([], [], [], [])
        self.keep_parts: list[np.ndarray] = []
        self.x_parts: list[np.ndarray] = []
        self.y_parts: list[np.ndarray] = []
        self.extra_parts = {k: [] for k in want_extra}

"""Durable-write helpers: the fsync + temp-file + rename discipline.

Every catalog mutation (snapshot files, HEAD pointers, manifest mirrors)
goes through :func:`write_atomic`: bytes land in a same-directory temp file,
are fsynced, and reach their final name through ``os.replace`` — so any
observer (including a post-crash reopen) sees either the complete old file
or the complete new file, never a torn write. :func:`fsync_dir` makes the
rename itself durable on POSIX (the directory entry is metadata of the
*directory*, not the file).

Temp files embed the ``.tmp-`` marker (:data:`TMP_MARKER`) so an
interrupted writer's leftovers are recognizable as orphans by the catalog
GC instead of being mistaken for user data.
"""

from __future__ import annotations

import os
import tempfile

TMP_MARKER = ".tmp-"


def fsync_file(fh) -> None:
    """Flush and fsync an open file object."""
    fh.flush()
    os.fsync(fh.fileno())


def fsync_path(path) -> None:
    """fsync an already-written file by path (reopen read-only)."""
    fd = os.open(str(path), os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path) -> None:
    """fsync a directory so renames/creates inside it are durable.

    Silently a no-op where directories cannot be opened/fsynced (e.g.
    Windows): the rename is still atomic there, only the durability of the
    directory entry is weaker.
    """
    try:
        fd = os.open(str(path), os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def tmp_name_for(path) -> tuple[int, str]:
    """A same-directory temp file for ``path`` (mkstemp fd + name).

    The name embeds :data:`TMP_MARKER` so catalog GC can identify leftovers
    from interrupted writes.
    """
    d, base = os.path.split(str(path))
    return tempfile.mkstemp(dir=d or ".", prefix=f".{base}{TMP_MARKER}")


def is_tmp_name(name: str) -> bool:
    """Does ``name`` look like one of our interrupted-write temp files?"""
    base = os.path.basename(str(name))
    return base.startswith(".") and TMP_MARKER in base


def write_atomic(path, data: bytes, *, fsync: bool = True) -> str:
    """Write ``data`` to ``path`` atomically (temp + fsync + ``os.replace``).

    On an ordinary exception the temp file is removed; on a simulated crash
    (:class:`~repro.io.faults.InjectedCrash`, a ``BaseException``) it is
    deliberately left behind, exactly like a real kill would — catalog GC
    owns the cleanup.
    """
    path = str(path)
    fd, tmp = tmp_name_for(path)
    try:
        with os.fdopen(fd, "wb") as fh:
            fh.write(data)
            if fsync:
                fsync_file(fh)
        os.replace(tmp, path)
    except Exception:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    if fsync:
        fsync_dir(os.path.dirname(path) or ".")
    return path

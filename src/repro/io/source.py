"""ByteRangeSource: the reader's storage boundary.

Everything the Spatial Parquet reader needs from storage is positional range
reads — the footer probe and one ``readinto`` per coalesced run of blobs.
:class:`ByteRangeSource` names exactly that contract so the same read path
runs against a local file (:class:`LocalFileSource`, byte-identical to the
historical ``seek``+``readinto`` behaviour) or an object-store-style backend
(:class:`~repro.io.remote.RemoteRangeSource`: range GETs with retry/backoff,
timeouts, bounded concurrency and a read-through block cache).

Sources also keep a :class:`SourceStats` account (requests, retries,
timeouts, cache hits/misses) that the reader folds into its ``ReadStats`` so
every recovery is observable from the query result.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Protocol, runtime_checkable

from repro import obs


@dataclass
class SourceStats:
    """Monotonic I/O counters of one source (mergeable / deltable)."""

    requests: int = 0       # range fetches attempted (incl. failed attempts)
    retries: int = 0        # failed attempts that were retried
    timeouts: int = 0       # attempts dropped for exceeding the deadline
    cache_hits: int = 0     # block-cache hits (remote sources)
    cache_misses: int = 0   # block-cache misses
    bytes_fetched: int = 0  # payload bytes successfully fetched

    def copy(self) -> "SourceStats":
        return SourceStats(**self.__dict__)

    def __add__(self, other: "SourceStats") -> "SourceStats":
        return SourceStats(**{
            k: getattr(self, k) + getattr(other, k) for k in self.__dict__
        })

    def __sub__(self, other: "SourceStats") -> "SourceStats":
        return SourceStats(**{
            k: getattr(self, k) - getattr(other, k) for k in self.__dict__
        })


@runtime_checkable
class ByteRangeSource(Protocol):
    """Positional range reads over one stored object (file or remote blob).

    Implementations must be safe for the reader's double-buffered use: at
    most one thread issues reads at a time per reader, but readers built on
    the same source from multiple scanner workers are not supported — each
    shard open creates its own source.
    """

    stats: SourceStats

    def size(self) -> int:
        """Total byte length of the object."""
        ...

    def readinto_at(self, offset: int, buf) -> int:
        """Fill ``buf`` with bytes starting at ``offset``; returns the count
        actually read (short only at end-of-object or on truncation)."""
        ...

    def read_at(self, offset: int, nbytes: int, *, refresh: bool = False) -> bytes:
        """Read ``nbytes`` at ``offset``. ``refresh=True`` bypasses (and
        heals) any caching layer — the reader uses it to re-fetch a blob
        whose checksum failed."""
        ...

    def close(self) -> None: ...


class LocalFileSource:
    """Local filesystem source: the historical reader behaviour, verbatim.

    ``readinto_at`` is one ``seek`` + one ``readinto`` — the reader's
    single-syscall-per-merged-run contract — and ``read_at`` is ``seek`` +
    ``read``, exactly what ``SpatialParquetReader`` did before the storage
    boundary existed. Byte-identical results, identical syscall counts.
    """

    def __init__(self, path):
        self.path = str(path)
        self._fh = open(self.path, "rb")
        self.stats = SourceStats()
        self._closed = False

    def size(self) -> int:
        return os.fstat(self._fh.fileno()).st_size

    def readinto_at(self, offset: int, buf) -> int:
        with obs.timed("io.read_s"):
            self._fh.seek(offset)
            self.stats.requests += 1
            got = self._fh.readinto(buf)
        self.stats.bytes_fetched += int(got or 0)
        return int(got or 0)

    def read_at(self, offset: int, nbytes: int, *, refresh: bool = False) -> bytes:
        # a local re-read IS the refresh: nothing is cached in this layer
        self._fh.seek(offset)
        self.stats.requests += 1
        out = self._fh.read(nbytes)
        self.stats.bytes_fetched += len(out)
        return out

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class BytesSource:
    """In-memory source (tests / tiny objects); same contract, zero I/O."""

    def __init__(self, data: bytes, path: str = "<bytes>"):
        self.path = path
        self._data = bytes(data)
        self.stats = SourceStats()
        self._closed = False

    def size(self) -> int:
        return len(self._data)

    def readinto_at(self, offset: int, buf) -> int:
        chunk = self._data[offset : offset + len(buf)]
        view = memoryview(buf)
        view[: len(chunk)] = chunk
        self.stats.requests += 1
        self.stats.bytes_fetched += len(chunk)
        return len(chunk)

    def read_at(self, offset: int, nbytes: int, *, refresh: bool = False) -> bytes:
        self.stats.requests += 1
        out = self._data[offset : offset + nbytes]
        self.stats.bytes_fetched += len(out)
        return out

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        self._closed = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def open_source(path_or_source) -> ByteRangeSource:
    """Coerce a path (str / PathLike) or ready source to a ByteRangeSource."""
    if isinstance(path_or_source, (str, os.PathLike)):
        return LocalFileSource(path_or_source)
    if isinstance(path_or_source, (bytes, bytearray, memoryview)):
        return BytesSource(bytes(path_or_source))
    if hasattr(path_or_source, "read_at"):
        return path_or_source
    raise TypeError(
        f"expected a path or ByteRangeSource, got {type(path_or_source).__name__}"
    )

"""Blob checksums for Spatial Parquet integrity (format v2).

Every stored blob of a v2 file (level streams, coordinate/extra pages, the
footer itself) carries a 32-bit checksum so corruption — a bit-flipped
object-store response, a truncated page, a stale cache block — is detected
*before* FP-delta plans or Pallas launches consume garbage.

Two algorithms are supported and the footer records which one a file uses
(``checksum_algo``):

* ``crc32c`` — CRC-32 Castagnoli, the Parquet/iSCSI polynomial. Used when a
  native implementation (``google_crc32c``) is importable at write time; a
  pure-Python table fallback keeps such files *readable* everywhere (slow,
  correctness-plane only).
* ``crc32`` — zlib's CRC-32 (ISO-HDLC). The stdlib-only default when no
  native CRC32C is available: integrity without a pure-Python hot loop.

Both are functions ``(bytes-like) -> uint32``. Files record the stored CRC of
the blob *as written* (post-compression), so verification happens on the raw
bytes before any decompress/decode work.
"""

from __future__ import annotations

import zlib

CHECKSUM_CRC32C = "crc32c"
CHECKSUM_CRC32 = "crc32"

try:  # native CRC32C (C extension); optional
    import google_crc32c as _gcrc32c
except ImportError:  # pragma: no cover - depends on environment
    _gcrc32c = None


class ChecksumError(IOError):
    """A stored blob failed checksum verification (and re-fetch, if any).

    Carries enough attribution to name the corrupt byte range: ``what`` (a
    human label like ``"x page 3 of row group 1"``), ``offset`` and
    ``nbytes`` of the stored blob, and the stored/computed CRC values.
    """

    def __init__(self, what: str, offset: int, nbytes: int,
                 stored: int, computed: int):
        super().__init__(
            f"checksum mismatch in {what} at offset {offset} ({nbytes} bytes): "
            f"stored {stored:#010x} != computed {computed:#010x}"
        )
        self.what = what
        self.offset = int(offset)
        self.nbytes = int(nbytes)
        self.stored = int(stored)
        self.computed = int(computed)


def _crc32c_table() -> list[int]:
    poly = 0x82F63B78  # reflected Castagnoli polynomial
    table = []
    for i in range(256):
        c = i
        for _ in range(8):
            c = (c >> 1) ^ poly if c & 1 else c >> 1
        table.append(c)
    return table


_SW_TABLE: list[int] | None = None


def _crc32c_software(data, value: int = 0) -> int:
    """Pure-Python CRC32C. Correct but slow — the read-compat fallback for
    files whose footer says ``crc32c`` when no native wheel is importable."""
    global _SW_TABLE
    if _SW_TABLE is None:
        _SW_TABLE = _crc32c_table()
    table = _SW_TABLE
    crc = (value ^ 0xFFFFFFFF) & 0xFFFFFFFF
    for b in bytes(data):
        crc = table[(crc ^ b) & 0xFF] ^ (crc >> 8)
    return crc ^ 0xFFFFFFFF


def crc32c(data, value: int = 0) -> int:
    """CRC-32C (Castagnoli) of a bytes-like; native when available."""
    if _gcrc32c is not None:
        return _gcrc32c.extend(value, bytes(data))
    return _crc32c_software(data, value)


def crc32(data, value: int = 0) -> int:
    """zlib CRC-32 of a bytes-like (always fast: stdlib C)."""
    return zlib.crc32(bytes(data), value) & 0xFFFFFFFF


def have_native_crc32c() -> bool:
    return _gcrc32c is not None


def default_algo() -> str:
    """Algorithm new files should use: crc32c when it is fast here."""
    return CHECKSUM_CRC32C if have_native_crc32c() else CHECKSUM_CRC32


def checksum_fn(algo: str):
    """The ``(bytes-like) -> uint32`` function for a footer's algo tag."""
    if algo == CHECKSUM_CRC32C:
        return crc32c
    if algo == CHECKSUM_CRC32:
        return crc32
    raise ValueError(f"unknown checksum algorithm {algo!r}")

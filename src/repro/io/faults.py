"""In-process range-GET server with deterministic fault injection.

:class:`InProcessRangeServer` speaks the object-store subset the remote
source needs — ``get(offset, length) -> (status, body)`` — over a local file
or bytes, with an explicit, deterministic fault schedule. It exists so the
whole fault matrix (truncated responses, transient 5xx, stalled reads,
bit-flipped payloads) is exercised in ordinary unit tests with zero sockets
and zero nondeterminism: faults fire on exact request indices or byte
ranges, burn down a ``times`` budget, then heal.

The request log (offset, length, status per request) makes assertions about
retry behaviour — *which* ranges were re-fetched, how many attempts — exact
rather than statistical.

Write-path crash points
-----------------------

The read path's faults model a flaky *server*; the write path's model a
dying *writer*. :func:`maybe_crash` is compiled into the durable-write /
catalog commit sequence at named points (shard emission, pre-rename,
post-rename, mid-compaction, mid-GC). Arming a point
(:func:`arm_crash` / :func:`crash_injection`) makes the next ``times``
passages raise :class:`InjectedCrash` — a ``BaseException``, so ordinary
``except Exception`` cleanup handlers do *not* run, exactly like a process
kill: whatever is on disk at that instant is what a reopen must cope with.
A point armed with ``truncate_to`` / ``truncate_frac`` first tears the file
whose path the call site passes (a partially-flushed shard), then crashes.
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import NamedTuple


class RangeResponse(NamedTuple):
    status: int  # HTTP-style: 206 partial content, 5xx transient, 4xx fatal
    body: bytes


# fault kinds
FAULT_TRUNCATE = "truncate"  # drop trailing bytes of the response body
FAULT_ERROR = "error"        # status-only failure (503 by default)
FAULT_STALL = "stall"        # sleep before responding (client deadline trips)
FAULT_CORRUPT = "corrupt"    # bit-flip one payload byte (checksums catch it)


@dataclass
class FaultSpec:
    """One deterministic fault: *what* goes wrong, *when*, *how often*.

    ``times`` is the burn-down budget: the fault fires on its first
    ``times`` matching requests, then the server heals (``times=None``
    never heals — the permanent-corruption case). Matching is by request
    index (``match_request``, 0-based position in the server's lifetime
    request sequence) and/or byte overlap (``match_offset`` = [lo, hi)
    half-open range); with neither, every request matches.
    """

    kind: str
    times: int | None = 1
    status: int = 503            # for FAULT_ERROR
    delay: float = 0.0           # for FAULT_STALL, seconds
    drop_bytes: int = 1          # for FAULT_TRUNCATE
    flip_at: int = 0             # for FAULT_CORRUPT: byte index into the body
    match_request: int | None = None
    match_offset: tuple[int, int] | None = None

    def matches(self, request_i: int, offset: int, length: int) -> bool:
        if self.times is not None and self.times <= 0:
            return False
        if self.match_request is not None and request_i != self.match_request:
            return False
        if self.match_offset is not None:
            lo, hi = self.match_offset
            if offset >= hi or offset + length <= lo:
                return False
        return True


@dataclass
class RequestRecord:
    offset: int
    length: int
    status: int
    nbytes: int        # body bytes actually returned
    fault: str | None  # fault kind applied, if any


class InProcessRangeServer:
    """Serve range GETs from a file/bytes, applying a fault schedule.

    Not a socket server: calls happen on the caller's thread (stalls are a
    real ``time.sleep``, so keep injected delays small). ``get`` is safe to
    call from the remote source's fetch pool; the fault schedule and request
    log are guarded by the GIL-atomicity of list/attr ops plus the fact that
    deterministic tests drive one logical read at a time.
    """

    def __init__(self, data, faults: list[FaultSpec] | None = None,
                 *, latency: float = 0.0):
        if isinstance(data, (bytes, bytearray, memoryview)):
            self._data = bytes(data)
            self.path = "<bytes>"
        else:
            self.path = str(data)
            with open(self.path, "rb") as fh:
                self._data = fh.read()
        self.faults: list[FaultSpec] = list(faults or [])
        self.latency = float(latency)
        self.requests: list[RequestRecord] = []

    # ---------------------------------------------------------------- server
    def size(self) -> int:
        return len(self._data)

    def get(self, offset: int, length: int) -> RangeResponse:
        """One range GET. Applies the first matching active fault."""
        request_i = len(self.requests)
        if self.latency:
            time.sleep(self.latency)
        body = self._data[offset : offset + length]
        fault = None
        for f in self.faults:
            if f.matches(request_i, offset, length):
                fault = f
                if f.times is not None:
                    f.times -= 1
                break
        status = 206
        if fault is not None:
            if fault.kind == FAULT_ERROR:
                status, body = fault.status, b""
            elif fault.kind == FAULT_TRUNCATE:
                body = body[: max(0, len(body) - fault.drop_bytes)]
            elif fault.kind == FAULT_STALL:
                time.sleep(fault.delay)
            elif fault.kind == FAULT_CORRUPT:
                if len(body):
                    i = min(fault.flip_at, len(body) - 1)
                    mutated = bytearray(body)
                    mutated[i] ^= 0xFF
                    body = bytes(mutated)
            else:
                raise ValueError(f"unknown fault kind {fault.kind!r}")
        self.requests.append(RequestRecord(
            offset=offset, length=length, status=status, nbytes=len(body),
            fault=fault.kind if fault else None,
        ))
        return RangeResponse(status, body)

    # ------------------------------------------------------------ test hooks
    @property
    def n_requests(self) -> int:
        return len(self.requests)

    def n_faulted(self, kind: str | None = None) -> int:
        """How many served requests had a fault applied (optionally by kind)."""
        return sum(
            1 for r in self.requests
            if r.fault is not None and (kind is None or r.fault == kind)
        )


# --------------------------------------------------------------------------
# write-path crash points
# --------------------------------------------------------------------------

# canonical point names, in write-pipeline order
CRASH_SHARD_TORN = "writer.shard.torn"          # shard file flushed (maybe torn)
CRASH_COMMIT_PRE_RENAME = "catalog.commit.pre_rename"    # snap tmp written
CRASH_COMMIT_POST_RENAME = "catalog.commit.post_rename"  # snap live, HEAD stale
CRASH_COMPACT_MID = "catalog.compact.mid"       # merged shards written, no commit
CRASH_GC_MID = "catalog.gc.mid"                 # first orphan deleted, rest not

CRASH_POINTS = (
    CRASH_SHARD_TORN,
    CRASH_COMMIT_PRE_RENAME,
    CRASH_COMMIT_POST_RENAME,
    CRASH_COMPACT_MID,
    CRASH_GC_MID,
)


class InjectedCrash(BaseException):
    """Simulated hard kill at an armed crash point.

    Deliberately a ``BaseException``: ``except Exception`` cleanup code must
    not observe it, because a real ``kill -9`` would not have run that code
    either. Only the fault harness itself (tests) catches it.
    """

    def __init__(self, point: str):
        super().__init__(f"injected crash at {point!r}")
        self.point = point


@dataclass
class CrashSpec:
    """One armed crash point: fire the next ``times`` passages, then heal.

    ``truncate_to`` / ``truncate_frac`` tear the file the call site names
    before crashing (``truncate_frac`` keeps that fraction of the bytes),
    modelling a partially-flushed write.
    """

    point: str
    times: int = 1
    truncate_to: int | None = None
    truncate_frac: float | None = None


_crash_lock = threading.Lock()
_crash_specs: dict[str, CrashSpec] = {}


def arm_crash(point: str, *, times: int = 1, truncate_to: int | None = None,
              truncate_frac: float | None = None) -> CrashSpec:
    """Arm ``point``; the next ``times`` passages raise :class:`InjectedCrash`."""
    spec = CrashSpec(point, times=int(times), truncate_to=truncate_to,
                     truncate_frac=truncate_frac)
    with _crash_lock:
        _crash_specs[point] = spec
    return spec


def disarm_crashes() -> None:
    """Disarm every crash point (test teardown)."""
    with _crash_lock:
        _crash_specs.clear()


def crash_armed(point: str) -> bool:
    spec = _crash_specs.get(point)
    return spec is not None and spec.times > 0


def maybe_crash(point: str, path=None) -> None:
    """Fire ``point`` if armed: optionally tear ``path``, then raise.

    Unarmed points are a dict lookup — the production write path pays one
    ``dict.get`` per point, nothing else.
    """
    spec = _crash_specs.get(point)
    if spec is None:
        return
    with _crash_lock:
        if spec.times <= 0:
            return
        spec.times -= 1
    if path is not None and (spec.truncate_to is not None
                             or spec.truncate_frac is not None):
        size = os.path.getsize(path)
        keep = (spec.truncate_to if spec.truncate_to is not None
                else int(size * spec.truncate_frac))
        with open(path, "r+b") as fh:
            fh.truncate(max(0, min(size, keep)))
            fh.flush()
            os.fsync(fh.fileno())
    raise InjectedCrash(point)


class crash_injection:
    """``with crash_injection(point, ...):`` — arm on entry, disarm on exit.

    Swallows the :class:`InjectedCrash` for the armed point so the test
    body reads as "run this, crashing here"; any other exception (or a
    crash at a different point) propagates.
    """

    def __init__(self, point: str, **kwargs):
        self.point = point
        self.kwargs = kwargs
        self.crashed = False

    def __enter__(self):
        arm_crash(self.point, **self.kwargs)
        return self

    def __exit__(self, exc_type, exc, tb):
        disarm_crashes()
        if exc_type is InjectedCrash and exc.point == self.point:
            self.crashed = True
            return True
        return False

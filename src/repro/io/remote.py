"""RemoteRangeSource: object-store-style range GETs, made survivable.

Models how the reader's coalesced-read contract maps onto a remote object
store: every ``readinto_at`` becomes block-aligned range GETs against a
*server* (anything with ``size()`` and ``get(offset, length) -> (status,
body)`` — an :class:`~repro.io.faults.InProcessRangeServer` in tests, a real
HTTP range client behind the same two methods in production). On top of the
raw GET it layers exactly the machinery a data-lake client needs:

* **per-request deadline** — a response slower than ``timeout`` counts as a
  timeout and is retried (the stalled-read case);
* **retries with exponential backoff + deterministic jitter** — transient
  5xx, truncated bodies, transport exceptions and timeouts all retry up to
  ``max_retries`` times with ``backoff_base * 2^attempt`` sleeps (capped at
  ``backoff_max``), jittered by a seeded RNG so tests are reproducible;
  4xx responses are fatal immediately;
* **request coalescing** — consecutive missing cache blocks fetch as one
  range GET (capped by ``max_request_bytes``), mirroring the reader's own
  run merging one layer down;
* **bounded concurrency** — multiple missing runs fetch in parallel on a
  pool of at most ``max_concurrency`` threads;
* **read-through block cache** — an LRU of ``block_size`` blocks
  (``cache_blocks`` capacity) so re-scans of hot ranges skip the network;
  ``read_at(refresh=True)`` invalidates and re-fetches, which is how the
  reader heals a cache poisoned by a corrupt (checksum-failing) response.

Every recovery is counted in :class:`~repro.io.source.SourceStats`; the
reader folds those into the query's ``ReadStats``.
"""

from __future__ import annotations

import random
import threading
import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor

from repro import obs

from .source import SourceStats


class TransientServerError(IOError):
    """A retryable server response (5xx / truncated body / transport error)."""

    def __init__(self, msg: str, status: int | None = None):
        super().__init__(msg)
        self.status = status


class RangeRequestError(IOError):
    """A fatal (non-retryable) server response, e.g. 404/416."""

    def __init__(self, msg: str, status: int | None = None):
        super().__init__(msg)
        self.status = status


class RequestTimeout(TransientServerError):
    """The response missed the per-request deadline."""


class RetriesExhausted(IOError):
    """A range GET kept failing after every allowed retry.

    Attributed: carries the byte range, the attempt count and the last
    underlying error (also chained as ``__cause__``).
    """

    def __init__(self, offset: int, nbytes: int, attempts: int, last: Exception):
        super().__init__(
            f"range GET [{offset}, {offset + nbytes}) failed after "
            f"{attempts} attempts: {last}"
        )
        self.offset = int(offset)
        self.nbytes = int(nbytes)
        self.attempts = int(attempts)
        self.last_error = last


class RemoteRangeSource:
    """A ByteRangeSource over a range-GET server (see module docstring)."""

    def __init__(
        self,
        server,
        *,
        size: int | None = None,
        block_size: int = 256 * 1024,
        cache_blocks: int = 256,
        timeout: float = 1.0,
        max_retries: int = 4,
        backoff_base: float = 0.01,
        backoff_max: float = 0.25,
        jitter: float = 0.25,
        seed: int = 0,
        max_concurrency: int = 4,
        max_request_bytes: int = 8 << 20,
    ):
        self._server = server
        self._size = int(server.size() if size is None else size)
        self.block_size = int(block_size)
        self.cache_blocks = int(cache_blocks)
        self.timeout = float(timeout)
        self.max_retries = int(max_retries)
        self.backoff_base = float(backoff_base)
        self.backoff_max = float(backoff_max)
        self.jitter = float(jitter)
        self.max_concurrency = max(1, int(max_concurrency))
        self.max_request_bytes = max(self.block_size, int(max_request_bytes))
        self.path = getattr(server, "path", "<remote>")
        self.stats = SourceStats()
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._cache: OrderedDict[int, bytes] = OrderedDict()
        self._pool: ThreadPoolExecutor | None = None
        self._closed = False

    # ----------------------------------------------------------------- sizes
    def size(self) -> int:
        return self._size

    @property
    def closed(self) -> bool:
        return self._closed

    # ----------------------------------------------------------- fetch layer
    def _backoff_sleep(self, attempt: int) -> None:
        delay = min(self.backoff_max, self.backoff_base * (2.0 ** attempt))
        with self._lock:
            factor = 1.0 + self.jitter * self._rng.random()
        time.sleep(delay * factor)

    def _fetch_range(self, offset: int, nbytes: int) -> bytes:
        """One logical range GET with deadline + retry/backoff semantics."""
        last: Exception | None = None
        for attempt in range(self.max_retries + 1):
            self.stats.requests += 1
            t0 = time.monotonic()
            try:
                status, body = self._server.get(offset, nbytes)
            except Exception as exc:  # transport-level failure: retryable
                obs.observe("io.range_get_s", time.monotonic() - t0)
                last = TransientServerError(f"transport error: {exc!r}")
            else:
                elapsed = time.monotonic() - t0
                obs.observe("io.range_get_s", elapsed)
                if elapsed > self.timeout:
                    self.stats.timeouts += 1
                    last = RequestTimeout(
                        f"range GET [{offset}, {offset + nbytes}) exceeded "
                        f"deadline ({elapsed:.3f}s > {self.timeout:.3f}s)"
                    )
                elif status >= 500:
                    last = TransientServerError(
                        f"server returned {status} for range "
                        f"[{offset}, {offset + nbytes})", status=status)
                elif status in (200, 206):
                    if len(body) != nbytes:
                        last = TransientServerError(
                            f"truncated response: got {len(body)} of {nbytes} "
                            f"bytes at offset {offset}")
                    else:
                        self.stats.bytes_fetched += len(body)
                        obs.count("io.bytes_fetched", len(body))
                        return body
                else:
                    raise RangeRequestError(
                        f"server returned {status} for range "
                        f"[{offset}, {offset + nbytes})", status=status)
            if attempt == self.max_retries:
                raise RetriesExhausted(offset, nbytes, attempt + 1, last) from last
            self.stats.retries += 1
            obs.instant("io.retry", cat="io", offset=offset, nbytes=nbytes,
                        attempt=attempt, error=type(last).__name__)
            with obs.span("io.backoff", cat="io", attempt=attempt):
                self._backoff_sleep(attempt)
        raise AssertionError("unreachable")

    def _fetch_block_run(self, b0: int, b1: int) -> dict[int, bytes]:
        """Fetch blocks [b0, b1) in max_request_bytes-sized coalesced GETs."""
        bs = self.block_size
        got: dict[int, bytes] = {}
        blocks_per_req = max(1, self.max_request_bytes // bs)
        b = b0
        while b < b1:
            be = min(b1, b + blocks_per_req)
            off = b * bs
            nbytes = min(be * bs, self._size) - off
            body = self._fetch_range(off, nbytes)
            for i in range(b, be):
                lo = (i - b) * bs
                got[i] = body[lo : lo + bs]
            b = be
        return got

    def _require_blocks(self, b0: int, b1: int) -> dict[int, bytes]:
        """Return bytes of every block in [b0, b1), via cache or fetch."""
        got: dict[int, bytes] = {}
        runs: list[list[int]] = []
        with self._lock:
            for b in range(b0, b1):
                cached = self._cache.get(b)
                if cached is not None:
                    self._cache.move_to_end(b)
                    self.stats.cache_hits += 1
                    got[b] = cached
                else:
                    self.stats.cache_misses += 1
                    if runs and runs[-1][1] == b:
                        runs[-1][1] = b + 1
                    else:
                        runs.append([b, b + 1])
        if runs:
            # materialize every fetch BEFORE taking the lock: workers use it
            # for backoff jitter, so consuming lazily under it would deadlock
            if len(runs) > 1 and self.max_concurrency > 1:
                fetched = list(self._executor().map(
                    lambda r: self._fetch_block_run(r[0], r[1]), runs))
            else:
                fetched = [self._fetch_block_run(r0, r1) for r0, r1 in runs]
            with self._lock:
                for chunk in fetched:
                    got.update(chunk)
                    for b, data in chunk.items():
                        self._cache[b] = data
                        self._cache.move_to_end(b)
                    while len(self._cache) > self.cache_blocks:
                        self._cache.popitem(last=False)
        return got

    def _executor(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.max_concurrency,
                    thread_name_prefix="range-get",
                )
            return self._pool

    # ------------------------------------------------------------- read API
    def readinto_at(self, offset: int, buf) -> int:
        view = memoryview(buf)
        n = len(view)
        if n == 0 or offset >= self._size:
            return 0
        end = min(offset + n, self._size)
        bs = self.block_size
        b0, b1 = offset // bs, (end - 1) // bs + 1
        blocks = self._require_blocks(b0, b1)
        w = 0
        for b in range(b0, b1):
            data = blocks[b]
            lo = offset - b * bs if b == b0 else 0
            hi = end - b * bs if b == b1 - 1 else len(data)
            chunk = data[lo:hi]
            view[w : w + len(chunk)] = chunk
            w += len(chunk)
        return w

    def read_at(self, offset: int, nbytes: int, *, refresh: bool = False) -> bytes:
        if refresh:
            self.invalidate(offset, nbytes)
        avail = max(0, min(nbytes, self._size - offset))
        buf = bytearray(avail)
        got = self.readinto_at(offset, buf)
        return bytes(buf[:got])

    def invalidate(self, offset: int, nbytes: int) -> None:
        """Drop cached blocks overlapping [offset, offset + nbytes)."""
        if nbytes <= 0:
            return
        bs = self.block_size
        b0, b1 = offset // bs, (offset + nbytes - 1) // bs + 1
        with self._lock:
            for b in range(b0, b1):
                self._cache.pop(b, None)

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)
        self._cache.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

"""Fault-tolerant I/O for Spatial Parquet readers: the storage boundary.

The reader's whole storage contract is positional range reads; this package
abstracts it behind :class:`ByteRangeSource` and provides the two backends —
a local file preserving the historical single-``readinto``-per-merged-run
behaviour byte-for-byte, and an object-store-style remote source with
retry/backoff, deadlines, bounded concurrency, request coalescing and a
read-through block cache — plus the checksum layer (format v2) and the
deterministic fault-injection server the whole stack is tested against::

    from repro.io import (
        ByteRangeSource, LocalFileSource, RemoteRangeSource,  # sources
        InProcessRangeServer, FaultSpec,                      # fault harness
        crc32c, ChecksumError,                                # integrity
    )

    server = InProcessRangeServer("lake/shard-00000.spqf",
                                  faults=[FaultSpec("error", times=2)])
    src = RemoteRangeSource(server, timeout=0.2, max_retries=4)
    with SpatialParquetReader(source=src) as r:     # recovers transparently
        geo, extras, stats = r.read_columnar()      # stats.retries == 2
"""

from .checksum import (
    CHECKSUM_CRC32,
    CHECKSUM_CRC32C,
    ChecksumError,
    checksum_fn,
    crc32,
    crc32c,
    default_algo,
    have_native_crc32c,
)
from .durable import (
    TMP_MARKER,
    fsync_dir,
    fsync_file,
    fsync_path,
    is_tmp_name,
    write_atomic,
)
from .faults import (
    CRASH_COMMIT_POST_RENAME,
    CRASH_COMMIT_PRE_RENAME,
    CRASH_COMPACT_MID,
    CRASH_GC_MID,
    CRASH_POINTS,
    CRASH_SHARD_TORN,
    FAULT_CORRUPT,
    FAULT_ERROR,
    FAULT_STALL,
    FAULT_TRUNCATE,
    CrashSpec,
    FaultSpec,
    InjectedCrash,
    InProcessRangeServer,
    RangeResponse,
    arm_crash,
    crash_armed,
    crash_injection,
    disarm_crashes,
    maybe_crash,
)
from .remote import (
    RangeRequestError,
    RemoteRangeSource,
    RequestTimeout,
    RetriesExhausted,
    TransientServerError,
)
from .source import (
    ByteRangeSource,
    BytesSource,
    LocalFileSource,
    SourceStats,
    open_source,
)

__all__ = [
    "ByteRangeSource",
    "BytesSource",
    "LocalFileSource",
    "RemoteRangeSource",
    "SourceStats",
    "open_source",
    "InProcessRangeServer",
    "FaultSpec",
    "RangeResponse",
    "FAULT_TRUNCATE",
    "FAULT_ERROR",
    "FAULT_STALL",
    "FAULT_CORRUPT",
    "InjectedCrash",
    "CrashSpec",
    "arm_crash",
    "disarm_crashes",
    "crash_armed",
    "crash_injection",
    "maybe_crash",
    "CRASH_POINTS",
    "CRASH_SHARD_TORN",
    "CRASH_COMMIT_PRE_RENAME",
    "CRASH_COMMIT_POST_RENAME",
    "CRASH_COMPACT_MID",
    "CRASH_GC_MID",
    "write_atomic",
    "fsync_file",
    "fsync_path",
    "fsync_dir",
    "is_tmp_name",
    "TMP_MARKER",
    "TransientServerError",
    "RangeRequestError",
    "RequestTimeout",
    "RetriesExhausted",
    "ChecksumError",
    "checksum_fn",
    "crc32",
    "crc32c",
    "default_algo",
    "have_native_crc32c",
    "CHECKSUM_CRC32",
    "CHECKSUM_CRC32C",
]

"""Optimizers: AdamW (dtype-configurable moments) and factored Adafactor.

No optax in-container; implemented directly over dict pytrees. All math in
float32 regardless of storage dtype; moments stored in ``cfg.opt_state_dtype``
(bf16 moments halve optimizer HBM — how arctic-480b fits 16 GB/chip).
Weight decay skips rank<2 leaves (norm scales, biases), standard practice.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    kind: str = "adamw"  # adamw | adafactor


def lr_schedule(oc: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - oc.warmup_steps) / jnp.maximum(oc.total_steps - oc.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# ------------------------------------------------------------------- AdamW
def adamw_init(params, state_dtype: str = "float32") -> dict:
    sdt = dtype_of(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, sdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(oc: OptConfig, params, grads, opt_state):
    step = opt_state["step"] + 1
    lr = lr_schedule(oc, step)
    grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
    t = step.astype(jnp.float32)
    bc1 = 1 - oc.b1**t
    bc2 = 1 - oc.b2**t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * oc.b1 + gf * (1 - oc.b1)
        vf = v.astype(jnp.float32) * oc.b2 + gf * gf * (1 - oc.b2)
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + oc.eps)
        if p.ndim >= 2:
            update = update + oc.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * update
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t3: t3[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


# --------------------------------------------------------------- Adafactor
def adafactor_init(params, state_dtype: str = "float32") -> dict:
    sdt = dtype_of(state_dtype)

    def zeros_for(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], sdt),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], sdt),
            }
        return {"v": jnp.zeros(p.shape, sdt)}

    return {
        "f": jax.tree.map(zeros_for, params, is_leaf=lambda x: hasattr(x, "shape")),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(oc: OptConfig, params, grads, opt_state):
    step = opt_state["step"] + 1
    lr = lr_schedule(oc, step)
    grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
    beta2 = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(p, g, f):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + 1e-30
        if p.ndim >= 2:
            vr = f["vr"].astype(jnp.float32) * beta2 + g2.mean(-1) * (1 - beta2)
            vc = f["vc"].astype(jnp.float32) * beta2 + g2.mean(-2) * (1 - beta2)
            denom = (vr[..., None] / jnp.maximum(vr.mean(-1, keepdims=True)[..., None], 1e-30)) * vc[..., None, :]
            update = gf / jnp.sqrt(jnp.maximum(denom, 1e-30))
            newf = {"vr": vr.astype(f["vr"].dtype), "vc": vc.astype(f["vc"].dtype)}
        else:
            v = f["v"].astype(jnp.float32) * beta2 + g2 * (1 - beta2)
            update = gf / jnp.sqrt(jnp.maximum(v, 1e-30))
            newf = {"v": v.astype(f["v"].dtype)}
        # relative-scale clipping (Adafactor's d=1)
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            update = update + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), newf

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_f = tdef.flatten_up_to(opt_state["f"])
    outs = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_f = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_params, {"f": new_f, "step": step}, {"lr": lr, "grad_norm": gnorm}


def opt_init(oc: OptConfig, params, state_dtype="float32"):
    if oc.kind == "adamw":
        return adamw_init(params, state_dtype)
    return adafactor_init(params, state_dtype)


def opt_update(oc: OptConfig, params, grads, opt_state):
    if oc.kind == "adamw":
        return adamw_update(oc, params, grads, opt_state)
    return adafactor_update(oc, params, grads, opt_state)

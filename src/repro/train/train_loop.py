"""Distributed train/serve step factories (pjit) + the host training loop.

``make_train_step`` builds a jitted ``(params, opt_state, batch) -> (params,
opt_state, metrics)`` with:

* donated params/opt_state (in-place HBM update),
* microbatch gradient accumulation via ``lax.scan`` (batch arrives shaped
  ``(accum, micro_batch, seq)``),
* explicit in/out shardings from :mod:`repro.sharding.specs`,
* loss/grad in float32, params updated via the configured optimizer.

``make_serve_step`` builds the decode step against sharded caches; the cache
is donated (decode updates in place).
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.model import build_model
from repro.sharding.specs import batch_axes, batch_spec, cache_specs, param_specs, to_named_sharding

from .optimizer import OptConfig, opt_init, opt_update


def _batch_struct(cfg: ModelConfig, shape_bs: tuple[int, int], accum: int):
    """ShapeDtypeStructs for one training batch (microbatched layout)."""
    b, s = shape_bs
    assert b % accum == 0, (b, accum)
    mb = b // accum
    batch = {"tokens": jax.ShapeDtypeStruct((accum, mb, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (accum, mb, s // cfg.frontend_downsample, cfg.frontend_dim or cfg.d_model),
            jnp.float32,
        )
    if cfg.family == "vlm":
        batch["tokens"] = jax.ShapeDtypeStruct((accum, mb, s - cfg.vision_tokens), jnp.int32)
        batch["patches"] = jax.ShapeDtypeStruct(
            (accum, mb, cfg.vision_tokens, cfg.frontend_dim), jnp.float32
        )
    return batch


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_struct):
    dp = batch_axes(mesh)

    def spec_for(leaf):
        return NamedSharding(mesh, P(None, dp, *([None] * (len(leaf.shape) - 2))))

    return jax.tree.map(spec_for, batch_struct)


def make_train_step(cfg: ModelConfig, mesh: Mesh, oc: OptConfig, global_batch: int, seq: int):
    """Returns (train_step, params_shardings, opt_shardings, batch_struct)."""
    model = build_model(cfg)
    # clamp accumulation so each microbatch still tiles the DP axes
    dp = batch_axes(mesh) or ()
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    accum = max(1, min(cfg.grad_accum, max(global_batch // max(dp_size, 1), 1)))
    while global_batch % accum or (global_batch // accum) % dp_size:
        accum -= 1
        if accum == 1:
            break

    def init_all(rng):
        params = model.init(rng)
        return params, opt_init(oc, params, cfg.opt_state_dtype)

    rng0 = jax.random.PRNGKey(0)
    pshape = jax.eval_shape(model.init, rng0)
    pspecs, fallbacks = param_specs(cfg, mesh, pshape)
    pshard = to_named_sharding(mesh, pspecs)
    # optimizer states mirror parameter shardings leaf-for-leaf
    def build_opt_shardings():
        if oc.kind == "adamw":
            return {
                "m": pshard,
                "v": pshard,
                "step": NamedSharding(mesh, P()),
            }
        # adafactor: factored dims follow the param spec minus the reduced dim
        def fspec(pspec_leaf, pleaf):
            spec = pspec_leaf
            if pleaf.ndim >= 2:
                return {
                    "vr": NamedSharding(mesh, P(*spec.spec[:-1])),
                    "vc": NamedSharding(mesh, P(*spec.spec[:-2], spec.spec[-1])),
                }
            return {"v": NamedSharding(mesh, P(*spec.spec))}

        return {
            "f": jax.tree.map(fspec, pshard, pshape,
                              is_leaf=lambda x: isinstance(x, NamedSharding)),
            "step": NamedSharding(mesh, P()),
        }

    oshard = build_opt_shardings()
    bstruct = _batch_struct(cfg, (global_batch, seq), accum)
    bshard = batch_shardings(cfg, mesh, bstruct)

    def micro_loss(params, micro):
        loss, metrics = model.loss(params, micro)
        return loss, metrics

    grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

    def train_step(params, opt_state, batch):
        def one(accum_carry, micro):
            gsum, msum = accum_carry
            (loss, metrics), grads = grad_fn(params, micro)
            gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            msum = {
                "loss": msum["loss"] + metrics["loss"],
                "ce_loss": msum["ce_loss"] + metrics["ce_loss"],
            }
            return (gsum, msum), None

        gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mzero = {"loss": jnp.zeros((), jnp.float32), "ce_loss": jnp.zeros((), jnp.float32)}
        (gsum, msum), _ = jax.lax.scan(one, (gzero, mzero), batch)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        new_params, new_opt, opt_metrics = opt_update(oc, params, grads, opt_state)
        metrics = {
            "loss": msum["loss"] / accum,
            "ce_loss": msum["ce_loss"] / accum,
            **opt_metrics,
        }
        return new_params, new_opt, metrics

    scalar = NamedSharding(mesh, P())
    metric_shard = {"loss": scalar, "ce_loss": scalar, "lr": scalar, "grad_norm": scalar}
    step_fn = jax.jit(
        train_step,
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, metric_shard),
        donate_argnums=(0, 1),
    )
    return step_fn, pshard, oshard, bstruct, bshard, fallbacks


def make_forward_step(cfg: ModelConfig, mesh: Mesh, global_batch: int, seq: int):
    """Inference prefill step (forward only, no backward/optimizer) — what the
    ``prefill_*`` dry-run shapes lower."""
    model = build_model(cfg)
    rng0 = jax.random.PRNGKey(0)
    pshape = jax.eval_shape(model.init, rng0)
    pspecs, fallbacks = param_specs(cfg, mesh, pshape)
    pshard = to_named_sharding(mesh, pspecs)
    bstruct = _batch_struct(cfg, (global_batch, seq), 1)
    bstruct = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), bstruct
    )  # drop accum dim
    dp = batch_axes(mesh)
    bshard = jax.tree.map(
        lambda l: NamedSharding(mesh, P(dp, *([None] * (len(l.shape) - 1)))), bstruct
    )

    def fwd(params, batch):
        logits, aux, _ = model.forward(params, batch)
        # return only reductions (serving returns sampled tokens; here we keep
        # the lowered compute honest without materializing (B,S,V) outputs)
        return jnp.argmax(logits, axis=-1)

    out_shard = NamedSharding(mesh, P(dp, None))
    step_fn = jax.jit(fwd, in_shardings=(pshard, bshard), out_shardings=out_shard)
    return step_fn, pshard, bstruct, bshard, fallbacks


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, global_batch: int, seq: int):
    """Inference prefill: forward_with_cache filling a seq-length cache and
    returning the next-token argmax — what the ``prefill_32k`` cells lower."""
    model = build_model(cfg)
    rng0 = jax.random.PRNGKey(0)
    pshape = jax.eval_shape(model.init, rng0)
    pspecs, fb1 = param_specs(cfg, mesh, pshape)
    pshard = to_named_sharding(mesh, pspecs)
    bstruct = _batch_struct(cfg, (global_batch, seq), 1)
    bstruct = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), bstruct)
    dp = batch_axes(mesh)
    bshard = jax.tree.map(
        lambda l: NamedSharding(mesh, P(dp, *([None] * (len(l.shape) - 1)))), bstruct
    )
    cshape = jax.eval_shape(lambda: model.init_cache(global_batch, seq))
    cspecs, fb2 = cache_specs(cfg, mesh, cshape)
    cshard = to_named_sharding(mesh, cspecs)

    def prefill(params, batch, cache):
        logits, new_cache = model.forward_with_cache(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    tok_shard = NamedSharding(mesh, P(dp, None))
    step_fn = jax.jit(
        prefill,
        in_shardings=(pshard, bshard, cshard),
        out_shardings=(tok_shard, cshard),
        donate_argnums=(2,),
    )
    return step_fn, pshard, bstruct, bshard, cshape, cshard, fb1 + fb2


def make_serve_step(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    """One-token decode step with donated sharded cache."""
    model = build_model(cfg)
    rng0 = jax.random.PRNGKey(0)
    pshape = jax.eval_shape(model.init, rng0)
    pspecs, _ = param_specs(cfg, mesh, pshape)
    pshard = to_named_sharding(mesh, pspecs)
    cshape = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    cspecs, fallbacks = cache_specs(cfg, mesh, cshape)
    cshard = to_named_sharding(mesh, cspecs)
    dp = batch_axes(mesh)
    tok_ok = batch % int(np.prod([mesh.shape[a] for a in (dp or ())])) == 0 if dp else False
    tok_shard = NamedSharding(mesh, P(dp if tok_ok else None, None))

    def serve_step(params, tokens, cache):
        logits, new_cache = model.decode_step(params, tokens, cache)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    step_fn = jax.jit(
        serve_step,
        in_shardings=(pshard, tok_shard, cshard),
        out_shardings=(tok_shard, cshard),
        donate_argnums=(2,),
    )
    return step_fn, pshard, cshape, cshard, tok_shard, fallbacks


# ------------------------------------------------------------------ host loop
@dataclass
class TrainState:
    params: object
    opt_state: object
    step: int = 0


def run_train_loop(
    cfg: ModelConfig,
    mesh: Mesh,
    oc: OptConfig,
    data_iter,
    *,
    global_batch: int,
    seq: int,
    steps: int,
    checkpoint_mgr=None,
    checkpoint_every: int = 0,
    log_every: int = 10,
    resume: bool = True,
    rng_seed: int = 0,
    heartbeat=None,
    fail_at_step: int = -1,
):
    """The production host loop: init-or-resume, step, log, checkpoint.

    ``fail_at_step`` injects a crash (fault-tolerance tests/drills).
    """
    from repro.train import checkpoint as ckpt_mod

    step_fn, pshard, oshard, bstruct, bshard, fallbacks = make_train_step(
        cfg, mesh, oc, global_batch, seq
    )
    model = build_model(cfg)
    start_step = 0
    params = opt_state = None
    if checkpoint_mgr is not None and resume:
        restored = checkpoint_mgr.restore_latest(mesh, pshard, oshard)
        if restored is not None:
            start_step, params, opt_state = restored
            print(f"[train] resumed from step {start_step}")
    if params is None:
        with mesh:
            init_fn = jax.jit(
                lambda rng: model.init(rng), out_shardings=pshard
            )
            params = init_fn(jax.random.PRNGKey(rng_seed))
            opt_state = jax.jit(
                lambda p: opt_init(oc, p, cfg.opt_state_dtype), out_shardings=oshard
            )(params)

    history = []
    t0 = time.time()
    for step in range(start_step, steps):
        if step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = next(data_iter)
        batch = jax.tree.map(
            lambda arr, sh: jax.device_put(arr, sh), batch, bshard
        )
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if heartbeat is not None:
            heartbeat(step)
        if log_every and (step % log_every == 0 or step == steps - 1):
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            print(f"[train] step {step} loss={m['loss']:.4f} ce={m['ce_loss']:.4f} "
                  f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.2f} ({dt:.1f}s)")
            history.append({"step": step, **m})
        if checkpoint_mgr is not None and checkpoint_every and (step + 1) % checkpoint_every == 0:
            checkpoint_mgr.save(step + 1, params, opt_state)
    if checkpoint_mgr is not None and checkpoint_every:
        checkpoint_mgr.save(steps, params, opt_state)
    return TrainState(params, opt_state, steps), history

"""Fault-tolerant checkpointing with FP-delta compression.

The paper's FP-delta codec (32-bit variant, :mod:`repro.core.fp_delta`)
losslessly compresses float32/int32 leaves; bfloat16 leaves are viewed as
packed int32 pairs (still lossless). This is the beyond-paper integration:
checkpoint bytes directly determine restart cost and checkpoint cadence on a
1000-node cluster, so the paper's storage win becomes a fault-tolerance win.

Layout per checkpoint directory::

    step_000123/
      manifest.json    # leaf paths, shapes, dtypes, offsets, crc32s, codec
      data.bin         # concatenated (possibly compressed) leaf payloads
    latest             # text file: name of the newest complete checkpoint

Writes are atomic (tmp dir + rename); ``keep`` bounds retained checkpoints.
Restore is **mesh-agnostic**: leaves load on host and are re-sharded to any
mesh/spec (elastic restarts on a different device count).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from dataclasses import dataclass

import jax
import ml_dtypes
import numpy as np

from repro.core.fp_delta import fp_delta_decode, fp_delta_encode

_SEP = "/"

# numpy's .str for ml_dtypes types is opaque ("|V2"); persist names instead
_EXTENDED_DTYPES = {
    "bfloat16": np.dtype(ml_dtypes.bfloat16),
    "float8_e4m3fn": np.dtype(ml_dtypes.float8_e4m3fn),
    "float8_e5m2": np.dtype(ml_dtypes.float8_e5m2),
}


def dtype_to_str(dt: np.dtype) -> str:
    return dt.name if dt.name in _EXTENDED_DTYPES else dt.str


def str_to_dtype(s: str) -> np.dtype:
    return _EXTENDED_DTYPES.get(s, None) or np.dtype(s)


def _flatten_with_paths(tree) -> list[tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, leaf))
    return out


def _encode_leaf(arr: np.ndarray, compress: bool) -> tuple[bytes, str]:
    if not compress or arr.size < 1024:
        return arr.tobytes(), "raw"
    if arr.dtype == np.float32 or arr.dtype == np.int32:
        payload, _ = fp_delta_encode(arr.reshape(-1))
        return payload, "fp_delta32"
    if arr.dtype == np.float64 or arr.dtype == np.int64:
        payload, _ = fp_delta_encode(arr.reshape(-1))
        return payload, "fp_delta64"
    # bf16 & friends: view raw bytes as int32 (pad) — still lossless fp-delta
    raw = arr.tobytes()
    pad = (-len(raw)) % 4
    as_i32 = np.frombuffer(raw + b"\x00" * pad, dtype=np.int32)
    payload, _ = fp_delta_encode(as_i32)
    return payload, f"fp_delta32_bytes:{len(raw)}"


def _decode_leaf(buf: bytes, codec: str, shape, dtype) -> np.ndarray:
    dtype = str_to_dtype(dtype) if isinstance(dtype, str) else np.dtype(dtype)
    n = int(np.prod(shape)) if shape else 1
    if codec == "raw":
        return np.frombuffer(buf, dtype=dtype, count=n).reshape(shape).copy()
    if codec == "fp_delta32":
        flat = fp_delta_decode(buf, n, np.float32 if dtype == np.float32 else np.int32)
        return flat.view(dtype).reshape(shape).copy()
    if codec == "fp_delta64":
        flat = fp_delta_decode(buf, n, np.float64 if dtype == np.float64 else np.int64)
        return flat.view(dtype).reshape(shape).copy()
    if codec.startswith("fp_delta32_bytes:"):
        nbytes = int(codec.split(":")[1])
        n_i32 = (nbytes + 3) // 4
        flat = fp_delta_decode(buf, n_i32, np.int32)
        raw = flat.tobytes()[:nbytes]
        return np.frombuffer(raw, dtype=dtype, count=n).reshape(shape).copy()
    raise ValueError(f"unknown codec {codec!r}")


@dataclass
class CheckpointStats:
    raw_bytes: int
    stored_bytes: int

    @property
    def ratio(self) -> float:
        return self.raw_bytes / max(self.stored_bytes, 1)


class CheckpointManager:
    def __init__(self, directory, *, compress: bool = True, keep: int = 3,
                 async_save: bool = True):
        self.dir = str(directory)
        self.compress = compress
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(self.dir, exist_ok=True)
        self.last_stats: CheckpointStats | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, params, opt_state, metadata: dict | None = None,
             block: bool = False):
        """Snapshot to host then write (async by default)."""
        state = {"params": params, "opt_state": opt_state}
        host_tree = jax.tree.map(lambda a: np.asarray(a), state)
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, metadata or {}), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host_tree, metadata or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, metadata: dict):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, f".tmp_{name}")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        leaves = _flatten_with_paths(host_tree)
        manifest = {"step": step, "metadata": metadata, "leaves": []}
        raw_total = stored_total = 0
        with open(os.path.join(tmp, "data.bin"), "wb") as fh:
            offset = 0
            for key, arr in leaves:
                payload, codec = _encode_leaf(arr, self.compress)
                fh.write(payload)
                manifest["leaves"].append({
                    "key": key,
                    "shape": list(arr.shape),
                    "dtype": dtype_to_str(arr.dtype),
                    "offset": offset,
                    "nbytes": len(payload),
                    "codec": codec,
                    "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
                })
                offset += len(payload)
                raw_total += arr.nbytes
                stored_total += len(payload)
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(self.dir, "latest.tmp"), "w") as fh:
            fh.write(name)
        os.replace(os.path.join(self.dir, "latest.tmp"), os.path.join(self.dir, "latest"))
        self.last_stats = CheckpointStats(raw_total, stored_total)
        self._gc()

    def _gc(self):
        ckpts = sorted(d for d in os.listdir(self.dir) if d.startswith("step_"))
        for d in ckpts[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            return None
        name = open(p).read().strip()
        if not os.path.exists(os.path.join(self.dir, name, "manifest.json")):
            return None
        return int(name.split("_")[1])

    def load_host(self, step: int | None = None):
        """Load a checkpoint fully on host -> (step, state_tree of np arrays)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None
        name = f"step_{step:08d}"
        root = os.path.join(self.dir, name)
        manifest = json.load(open(os.path.join(root, "manifest.json")))
        data = open(os.path.join(root, "data.bin"), "rb").read()
        flat = {}
        for leaf in manifest["leaves"]:
            buf = data[leaf["offset"] : leaf["offset"] + leaf["nbytes"]]
            if (zlib.crc32(buf) & 0xFFFFFFFF) != leaf["crc32"]:
                raise IOError(f"checkpoint corruption at {leaf['key']} (crc mismatch)")
            flat[leaf["key"]] = _decode_leaf(buf, leaf["codec"], tuple(leaf["shape"]), leaf["dtype"])
        return manifest["step"], _unflatten(flat)

    def restore_latest(self, mesh, params_shardings, opt_shardings):
        """Elastic restore: host leaves -> device arrays under ANY mesh."""
        loaded = self.load_host()
        if loaded is None:
            return None
        step, state = loaded
        params = _put_tree(state["params"], params_shardings)
        opt_state = _put_tree(state["opt_state"], opt_shardings)
        return step, params, opt_state


def _unflatten(flat: dict[str, np.ndarray]):
    root: dict = {}
    for key, arr in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return root


def _put_tree(host_tree, shardings):
    flat_h = dict(_flatten_with_paths(host_tree))
    flat_s = _flatten_with_paths(shardings)
    out = {}
    for key, sh in flat_s:
        if key not in flat_h:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat_h[key]
        # dtype restore (bf16 stored via raw bytes keeps dtype.str in manifest)
        out[key] = jax.device_put(arr, sh)
    return _unflatten(out)

"""Oracle for the flash-attention kernel: plain softmax attention in jnp."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (B, H, Sq, D)
    k: jnp.ndarray,  # (B, H, Sk, D)
    v: jnp.ndarray,  # (B, H, Sk, D)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
) -> jnp.ndarray:
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    logits = logits * sm_scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        # decode-style alignment: query i attends to keys <= i + (sk - sq)
        mask = jnp.arange(sk)[None, :] <= (jnp.arange(sq)[:, None] + (sk - sq))
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)

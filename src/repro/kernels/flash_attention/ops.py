"""User-facing attention op: GQA handling, padding, Pallas/ref dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def attention(
    q: jnp.ndarray,   # (B, Hq, Sq, D)
    k: jnp.ndarray,   # (B, Hkv, Sk, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    use_pallas: bool = False,
    interpret: bool | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    """Attention with GQA (Hq a multiple of Hkv: k/v broadcast per group).

    ``use_pallas=False`` (default on CPU) runs the jnp oracle — the dry-run /
    CPU-training path. ``use_pallas=True`` runs the Pallas kernel (interpret
    mode off-TPU).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)
    interp = _default_interpret() if interpret is None else interpret
    # The kernel takes no mask input, so the key length must be block-aligned
    # (serving caches and training seq lens are). Queries are *front*-padded:
    # real query i lands on padded row i+pad, which preserves the causal
    # diagonal offset (c <= i + (Sk - Sq)) exactly.
    sk = k.shape[2]
    if sk % block_k:
        raise ValueError(f"pallas path needs Sk % block_k == 0, got {sk}")
    pad_q = (-sq) % block_q
    if pad_q:
        if not causal:
            raise ValueError("non-causal pallas path needs Sq % block_q == 0")
        q = jnp.pad(q, ((0, 0), (0, 0), (pad_q, 0), (0, 0)))
    out = kernel.flash_attention(
        q, k, v, causal=causal, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, interpret=interp,
    )
    return out[:, :, pad_q:]

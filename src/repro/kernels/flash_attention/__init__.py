from .kernel import flash_attention
from .ops import attention
from .ref import attention_ref

__all__ = ["attention", "flash_attention", "attention_ref"]

"""Pallas TPU flash attention (blocked online softmax).

Grid is (batch*heads, q_blocks, k_blocks) with the k dimension innermost and
sequential; running max / denominator / accumulator live in VMEM scratch and
the output block is emitted on the last k step. Causal blocks that are fully
masked are skipped with ``pl.when`` (zero FLOPs — the dominant saving for
long sequences). BlockSpecs tile Q/K/V into (block, head_dim) VMEM windows so
the working set is O(block_q*D + 2*block_k*D) regardless of sequence length —
the HBM→VMEM streaming pattern that replaces GPU shared-memory tiling on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, sm_scale: float, causal: bool, block_q: int, block_k: int, seq_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    sq = pl.num_programs(1) * block_q

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal (decode-aligned): query row r sees key col c iff c <= r + offset
    diag_offset = seq_k - sq
    q_start = qi * block_q
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, D)
        k = k_ref[0].astype(jnp.float32)  # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (block_q, block_k)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
            logits = jnp.where(cols <= rows + diag_offset, logits, NEG_INF)
        m_prev = m_scr[...]                       # (block_q, 1)
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)               # (block_q, block_k)
        alpha = jnp.exp(m_prev - m_new)           # (block_q, 1)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    if causal:
        # skip blocks strictly above the (offset) diagonal
        pl.when(k_start <= q_start + block_q - 1 + diag_offset)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, H, Sq, D)
    k: jnp.ndarray,  # (B, H, Sk, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    sk = k.shape[2]
    assert k.shape == (b, h, sk, d) and v.shape == (b, h, sk, d)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    grid = (b * h, sq // block_q, sk // block_k)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            sm_scale=float(sm_scale),
            causal=causal,
            block_q=block_q,
            block_k=block_k,
            seq_k=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)

"""Pure-jnp oracle for the TPU miniblock FP-delta codec (v2: patched coding).

Semantics (the TPU adaptation of paper §3 — see DESIGN.md §5):

* The stream is split into *miniblocks* of ``MINIBLOCK`` (1024) float32
  values. Each miniblock is **self-contained**: a raw int32 *anchor* (its
  first value), a *width* ``w ∈ {0,1,2,4,8,16,32}``, its 1024 zigzag deltas
  (``delta[0] := 0``) packed at ``w`` bits into ``1024*w/32`` int32 words,
  plus up to ``MAX_EXC`` *exceptions* — (position u16, full zigzag u32)
  pairs for deltas that do not fit ``w`` bits (FastPFOR-style patching).
* ``w`` minimizes the exact per-block cost ``1024*w + 48*n_over(w)`` over
  the lane-aligned widths, subject to ``n_over(w) <= MAX_EXC``. v1 (no
  exceptions) paid a whole block of w=32 for a single outlier — a 214%
  size regression vs the paper-exact stream on multi-record pages;
  patching restores <~15% (measured in benchmarks/bench_kernels.py).
* Exception extraction/injection is scatter-free: a (MAX_EXC, 1024) one-hot
  contraction against iota (VPU-friendly; no dynamic memory ops), so the
  Pallas kernel lowers with data-independent control flow.
* Block anchoring costs ~48 bits / 1024 values and buys embarrassingly-
  parallel decode — there is no cross-block carry at all.

This file is the *oracle*: straightforward vectorized jnp, no Pallas. The
kernel must match it bit-for-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import math

MINIBLOCK = 1024
# Lane-aligned widths: ANY w packs g = 32/gcd(w,32) values into g*w/32 whole
# words with static shift patterns (v3 — the pow2-only lattice of v2
# bracketed the typical geo n* ~ 10 badly: w=8 overflowed MAX_EXC, w=16
# wasted 6 bits/value). Chosen set keeps the candidate count modest while
# never being more than ~15% above the paper-exact n*.
WIDTHS = (1, 2, 3, 4, 6, 8, 10, 12, 16, 20, 24, 32)
MAX_EXC = 64          # exception capacity per block (static shapes)
EXC_BITS = 16 + 32    # stored cost of one exception (position + raw zigzag)


def significant_bits_u32(z: jnp.ndarray) -> jnp.ndarray:
    """Bits needed for each uint32 value (0 for value 0); exact ladder."""
    z = z.astype(jnp.uint32)
    out = jnp.zeros(z.shape, jnp.int32)
    v = z
    for s in (16, 8, 4, 2, 1):
        big = v >= (jnp.uint32(1) << jnp.uint32(s))
        out = out + jnp.where(big, jnp.int32(s), jnp.int32(0))
        v = jnp.where(big, v >> jnp.uint32(s), v)
    return out + (z != jnp.uint32(0)).astype(jnp.int32)


def zigzag_i32(delta: jnp.ndarray) -> jnp.ndarray:
    d = delta.astype(jnp.int32)
    return ((d >> jnp.int32(31)) ^ (d << jnp.int32(1))).astype(jnp.uint32)


def unzigzag_u32(z: jnp.ndarray) -> jnp.ndarray:
    z = z.astype(jnp.uint32)
    neg = jnp.uint32(0) - (z & jnp.uint32(1))
    return ((z >> jnp.uint32(1)) ^ neg).astype(jnp.int32)


def _mask(w: int) -> jnp.uint32:
    return jnp.uint32(0xFFFFFFFF) if w >= 32 else jnp.uint32((1 << w) - 1)


def _group_geometry(w: int) -> tuple[int, int]:
    """(values per group g, words per group k) for lane-aligned packing."""
    g = 32 // math.gcd(w, 32)
    return g, g * w // 32


def pack_candidate(vals_u32: jnp.ndarray, w: int) -> jnp.ndarray:
    """Pack (..., M) uint32 values at static width w -> (..., M) words
    (first M*w/32 valid, rest zero).

    Group packing: g = 32/gcd(w,32) values occupy exactly k = g*w/32 words;
    every (value i -> word j) shift is a compile-time constant, so the whole
    thing is static shifts + masked sums (VPU-clean, any w)."""
    m = vals_u32.shape[-1]
    g, k = _group_geometry(w)
    v = (vals_u32 & _mask(w)).reshape(*vals_u32.shape[:-1], m // g, g)
    words = []
    for j in range(k):
        acc = jnp.zeros(v.shape[:-1], jnp.uint32)
        for i in range(g):
            s = i * w - j * 32
            if s <= -w or s >= 32:
                continue
            if s >= 0:
                acc = acc + ((v[..., i] << jnp.uint32(s)) & jnp.uint32(0xFFFFFFFF))
            else:
                acc = acc + (v[..., i] >> jnp.uint32(-s))
        words.append(acc)
    packed = jnp.stack(words, axis=-1).reshape(*vals_u32.shape[:-1], m * w // 32)
    padding = [(0, 0)] * (packed.ndim - 1) + [(0, m - packed.shape[-1])]
    return jnp.pad(packed, padding)


def unpack_candidate(words_u32: jnp.ndarray, w: int) -> jnp.ndarray:
    """Inverse of pack_candidate: (..., M) words -> (..., M) values."""
    m = words_u32.shape[-1]
    g, k = _group_geometry(w)
    wv = words_u32[..., : m * w // 32].reshape(*words_u32.shape[:-1], -1, k)
    vals = []
    for i in range(g):
        s = i * w
        j0, s0 = s // 32, s % 32
        v = wv[..., j0] >> jnp.uint32(s0)
        if s0 + w > 32:
            v = v | (wv[..., j0 + 1] << jnp.uint32(32 - s0))
        vals.append(v & _mask(w))
    out = jnp.stack(vals, axis=-1)
    return out.reshape(*words_u32.shape[:-1], m)


def choose_width(nbits: jnp.ndarray):
    """nbits: (..., M) per-value significant bits -> (width, n_over).

    Exact per-block argmin of M*w + EXC_BITS*n_over(w) over WIDTHS with
    feasibility n_over <= MAX_EXC (w=32 always feasible)."""
    m = nbits.shape[-1]
    best_w = jnp.full(nbits.shape[:-1], 32, jnp.int32)
    best_cost = jnp.full(nbits.shape[:-1], m * 32, jnp.int32)
    # ascending scan with strict improvement: ties keep the smaller width
    for w in (0,) + WIDTHS[:-1]:  # w=32 handled by init
        n_over = jnp.sum((nbits > w).astype(jnp.int32), axis=-1)
        cost = m * w + EXC_BITS * n_over
        ok = (n_over <= MAX_EXC) & (cost < best_cost)
        best_w = jnp.where(ok, jnp.int32(w), best_w)
        best_cost = jnp.where(ok, cost, best_cost)
    return best_w, best_cost


def extract_exceptions(zig: jnp.ndarray, width: jnp.ndarray):
    """Scatter-free exception compaction for one block.

    zig: (M,) uint32; width: scalar. Returns (exc_idx (MAX_EXC,) i32,
    exc_val (MAX_EXC,) u32, count scalar i32). Slot j holds the (j+1)-th
    overflowing position via a one-hot contraction with iota."""
    m = zig.shape[0]
    nbits = significant_bits_u32(zig)
    over = nbits > width                      # (M,) bool
    rank = jnp.cumsum(over.astype(jnp.int32))  # inclusive
    slots = jnp.arange(MAX_EXC, dtype=jnp.int32)
    onehot = (over[None, :] & (rank[None, :] == (slots[:, None] + 1)))
    iota = jnp.arange(m, dtype=jnp.int32)
    exc_idx = jnp.sum(onehot * iota[None, :], axis=1).astype(jnp.int32)
    exc_val = jnp.sum(onehot.astype(jnp.uint32) * zig[None, :], axis=1)
    count = jnp.minimum(jnp.sum(over.astype(jnp.int32)), MAX_EXC)
    return exc_idx, exc_val, count


def inject_exceptions(vals: jnp.ndarray, exc_idx, exc_val, count):
    """Inverse of extract_exceptions (scatter-free overwrite)."""
    m = vals.shape[0]
    slots = jnp.arange(MAX_EXC, dtype=jnp.int32)
    live = slots < count                       # (E,)
    iota = jnp.arange(m, dtype=jnp.int32)
    onehot = (iota[None, :] == exc_idx[:, None]) & live[:, None]  # (E, M)
    patch = jnp.sum(onehot.astype(jnp.uint32) * exc_val[:, None], axis=0)
    hit = jnp.any(onehot, axis=0)
    return jnp.where(hit, patch, vals)


def _select_by_width(width: jnp.ndarray, candidates: list[jnp.ndarray]) -> jnp.ndarray:
    """Sum-of-masked-candidates select (guaranteed vector lowering)."""
    out = jnp.zeros_like(candidates[0])
    for w, c in zip(WIDTHS, candidates):
        out = out + jnp.where((width == w)[..., None], c, 0)
    return out


def _encode_one_block(x: jnp.ndarray):
    xi = jax.lax.bitcast_convert_type(x, jnp.int32)
    prev = jnp.concatenate([xi[:1], xi[:-1]])
    zig = zigzag_i32(xi - prev)  # delta[0] == 0
    nbits = significant_bits_u32(zig)
    width, _ = choose_width(nbits[None, :])
    width = width[0]
    exc_idx, exc_val, count = extract_exceptions(zig, width)
    packed = jnp.zeros(MINIBLOCK, jnp.uint32)
    for w in WIDTHS:
        packed = packed + jnp.where(width == w, pack_candidate(zig, w), jnp.uint32(0))
    return (packed.astype(jnp.int32), width, xi[0],
            exc_idx, exc_val.astype(jnp.int32), count)


def encode_blocks_ref(x: jnp.ndarray):
    """(n_blocks, MINIBLOCK) f32 -> (packed i32 (n,M), widths (n,), anchors
    (n,), exc_idx (n,E), exc_val (n,E), exc_count (n,))."""
    assert x.ndim == 2 and x.shape[1] == MINIBLOCK, x.shape
    return jax.vmap(_encode_one_block)(x)


def _decode_one_block(packed, width, anchor, exc_idx, exc_val, count):
    words = packed.astype(jnp.uint32)
    zig = jnp.zeros(MINIBLOCK, dtype=jnp.uint32)
    for w in WIDTHS:
        zig = zig + jnp.where(width == w, unpack_candidate(words, w), jnp.uint32(0))
    zig = inject_exceptions(zig, exc_idx, exc_val.astype(jnp.uint32), count)
    delta = unzigzag_u32(zig)
    xi = anchor + jnp.cumsum(delta, dtype=jnp.int32)
    return jax.lax.bitcast_convert_type(xi, jnp.float32)


def decode_blocks_ref(packed, widths, anchors, exc_idx, exc_val, exc_count):
    """Inverse of encode_blocks_ref -> (n_blocks, MINIBLOCK) float32."""
    return jax.vmap(_decode_one_block)(packed, widths, anchors,
                                       exc_idx, exc_val, exc_count)


# --------------------------------------------------------------- page stream
# The second codec in this package: on-device execution of the *paper-exact*
# FP-delta format (core/fp_delta.py), as opposed to the TPU-native miniblock
# format above. The host resolves escapes into an FPDeltaPlan; many pages are
# then concatenated into one value stream where every value is either an
# *anchor* (a raw W-bit pattern: a page's first value, an escaped reset
# value, or every value of a raw-mode page) or an inline n-bit zigzag delta.
# Decode = fixed-width gather + escape injection + segmented cumsum over the
# anchor-delimited segments + un-zigzag + float bitcast. All arithmetic is
# uint32 *limb pairs* (lo, hi) so W=64 streams decode without 64-bit lanes
# (TPUs have none; interpret mode needs no jax_enable_x64).

STREAM_BLOCK = 1024  # values per grid step of the stream kernel, one VPU tile


def gather_tokens(words_u32: jnp.ndarray, offs: jnp.ndarray, nbits: jnp.ndarray):
    """Gather token bits ``[offs, offs+nbits)`` from the LE word stream.

    Returns ``(lo, hi)`` uint32 limbs. ``nbits`` must be in [1, 64] and
    ``words_u32`` must carry >= 2 trailing spill words so the three-word
    window ``w0i .. w0i+2`` is always in bounds.
    """
    words = words_u32.astype(jnp.uint32)
    w0i = offs >> 5
    w0 = jnp.take(words, w0i, mode="clip")
    w1 = jnp.take(words, w0i + 1, mode="clip")
    w2 = jnp.take(words, w0i + 2, mode="clip")
    s = (offs & 31).astype(jnp.uint32)
    inv = (jnp.uint32(32) - s) & jnp.uint32(31)  # shift-by-32 is UB: mask + select
    lo = (w0 >> s) | jnp.where(s == 0, jnp.uint32(0), w1 << inv)
    hi = (w1 >> s) | jnp.where(s == 0, jnp.uint32(0), w2 << inv)
    full = jnp.uint32(0xFFFFFFFF)
    nlo = jnp.clip(nbits, 1, 32).astype(jnp.uint32)
    mask_lo = full >> (jnp.uint32(32) - nlo)  # exponent in [0, 31]: safe
    nhi = jnp.clip(nbits - 32, 0, 32).astype(jnp.uint32)
    mask_hi = jnp.where(
        nhi == 0, jnp.uint32(0), full >> ((jnp.uint32(32) - nhi) & jnp.uint32(31))
    )
    return lo & mask_lo, hi & mask_hi


def unzigzag_limbs(lo: jnp.ndarray, hi: jnp.ndarray):
    """64-bit unzigzag ``(z >>> 1) ^ -(z & 1)`` on uint32 limb pairs."""
    neg = jnp.uint32(0) - (lo & jnp.uint32(1))  # all-ones when LSB set
    zlo = (lo >> jnp.uint32(1)) | (hi << jnp.uint32(31))
    zhi = hi >> jnp.uint32(1)
    return zlo ^ neg, zhi ^ neg


def add_limbs(alo, ahi, blo, bhi):
    """Wrapping 64-bit add with carry propagation between uint32 limbs."""
    slo = alo + blo
    carry = (slo < blo).astype(jnp.uint32)
    return slo, ahi + bhi + carry


def seg_combine(a, b):
    """Associative combine of the segmented cumsum; ``b`` is the *later*
    operand: an anchor in ``b`` blocks ``a``'s contribution entirely.
    Elements are ``(lo, hi, is_anchor)``; identity is ``(0, 0, False)``."""
    alo, ahi, af = a
    blo, bhi, bf = b
    slo, shi = add_limbs(alo, ahi, blo, bhi)
    return jnp.where(bf, blo, slo), jnp.where(bf, bhi, shi), af | bf


def stream_values(lo: jnp.ndarray, hi: jnp.ndarray, anchor: jnp.ndarray):
    """Escape injection + un-zigzag: anchors keep their raw gathered bits,
    inline tokens become signed deltas (wrapping uint32 limbs)."""
    dlo, dhi = unzigzag_limbs(lo, hi)
    return jnp.where(anchor, lo, dlo), jnp.where(anchor, hi, dhi)


def segmented_scan(vlo, vhi, flag):
    """Inclusive Hillis–Steele segmented scan over the last axis (log-step
    shifted combines; identity-padded on the left)."""
    n = vlo.shape[-1]
    f = flag
    shift = 1
    while shift < n:
        z32 = jnp.zeros(vlo.shape[:-1] + (shift,), jnp.uint32)
        zb = jnp.zeros(vlo.shape[:-1] + (shift,), jnp.bool_)
        prev = (
            jnp.concatenate([z32, vlo[..., :-shift]], axis=-1),
            jnp.concatenate([z32, vhi[..., :-shift]], axis=-1),
            jnp.concatenate([zb, f[..., :-shift]], axis=-1),
        )
        vlo, vhi, f = seg_combine(prev, (vlo, vhi, f))
        shift *= 2
    return vlo, vhi, f


def decode_stream_limbs_ref(words_u32, tok_off, nbits, anchor):
    """Flat-scan oracle returning the decoded patterns as uint32 limb pairs
    (the fused refine chain's input form; ``hi`` is zero for 32-bit)."""
    offs = tok_off.reshape(-1)
    nb = nbits.reshape(-1)
    anc = anchor.reshape(-1) != 0
    lo, hi = gather_tokens(words_u32, offs, nb)
    vlo, vhi = stream_values(lo, hi, anc)
    flo, fhi, _ = segmented_scan(vlo, vhi, anc)
    return flo, fhi


def decode_stream_ref(words_u32, tok_off, nbits, anchor, *, width: int):
    """Pure-jnp oracle for the page-stream decode: one flat global segmented
    scan (structurally unlike the kernel's block-local scans + carry stitch,
    which is what makes the differential test meaningful).

    Returns float32 values for ``width == 32``, or ``(lo, hi)`` int32 limb
    arrays for ``width == 64`` (the float64 bitcast is a host-side view).
    """
    flo, fhi = decode_stream_limbs_ref(words_u32, tok_off, nbits, anchor)
    if width == 32:
        return jax.lax.bitcast_convert_type(flo.astype(jnp.int32), jnp.float32)
    return flo.astype(jnp.int32), fhi.astype(jnp.int32)


def payload_words(widths: jnp.ndarray) -> jnp.ndarray:
    """Valid packed word count per block (for stream compaction)."""
    return (widths.astype(jnp.int32) * MINIBLOCK) // 32


def stream_size_bits(widths: jnp.ndarray, exc_count: jnp.ndarray) -> jnp.ndarray:
    """Total compacted stream: payloads + exceptions + anchors/widths/counts."""
    per_block_fixed = 32 + 8 + 8  # anchor + width byte + exception count byte
    return (jnp.sum(payload_words(widths)) * 32
            + jnp.sum(exc_count) * EXC_BITS
            + widths.shape[0] * per_block_fixed)

from .ops import (
    MiniblockStream,
    compress_array,
    decode,
    decompress_array,
    encode,
    from_bytes,
    to_bytes,
)
from .ref import MINIBLOCK, WIDTHS, decode_blocks_ref, encode_blocks_ref

__all__ = [
    "MiniblockStream",
    "encode",
    "decode",
    "to_bytes",
    "from_bytes",
    "compress_array",
    "decompress_array",
    "encode_blocks_ref",
    "decode_blocks_ref",
    "MINIBLOCK",
    "WIDTHS",
]

"""Jit'd user-facing wrappers around the miniblock FP-delta kernels (v2).

Handles arbitrary-length inputs (padding with the last element — zero deltas
are free), Pallas/ref dispatch, and host-side stream compaction to a compact
byte format (used by checkpoint compression, :mod:`repro.train.checkpoint`).

Also home of the *page-stream* decode entry points (:func:`decode_pages`,
:func:`build_page_stream`): batched on-device execution of the paper-exact
FP-delta page format, consumed by ``SpatialParquetReader.read_columnar(
device="jax")`` — and of the **fused decode→refine** entry point
(:func:`decode_refine_stream`), which chains the page-stream decode with the
segmented per-record min/max of :mod:`repro.kernels.minmax` and a bbox
survivor test in one launch chain, so only surviving records (or just the
record mask) ever cross back to the host.

Every device callable goes through a process-wide AOT compile cache
(:func:`_aot`): shapes are pow2-bucketed upstream, and a lock serializes
tracing so concurrent shard-reader threads (``SpatialDatasetScanner``) trace
each shape bucket exactly once instead of racing to retrace per shard.
"""

from __future__ import annotations

import functools
import struct
import threading
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.columnar import DeviceCoords
from repro.core.fp_delta import HEADER_BITS, FPDeltaPlan, fp_delta_execute

from . import kernel, ref
from .ref import EXC_BITS, MAX_EXC, MINIBLOCK, STREAM_BLOCK

_MAGIC = b"FPD2"  # FP-Delta Miniblock v2 (patched)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------- AOT compile cache
# One compiled executable per (callable, shape-bucket, statics) key, shared
# process-wide. The double-checked lock means N scanner worker threads
# hitting the same bucket concurrently cost one trace+compile, not N.
_COMPILE_LOCK = threading.Lock()
_COMPILED: dict[tuple, object] = {}


def _aot(key: tuple, jitted, args: tuple, statics: dict | None = None):
    """Return the compiled executable for ``jitted`` at ``args``' shapes.

    Compile-vs-execute attribution: a cache miss traces+compiles inside a
    ``jit.compile`` span (cat ``jit``) and bumps the ``jit.compiles``
    counter; a hit bumps ``jit.cache_hits`` — so a trace separates one-time
    compilation cost from steady-state launch cost per shape bucket.
    """
    fn = _COMPILED.get(key)
    if fn is None:
        with _COMPILE_LOCK:
            fn = _COMPILED.get(key)
            if fn is None:
                with obs.span("jit.compile", cat="jit", key=repr(key)):
                    shapes = tuple(
                        jax.ShapeDtypeStruct(np.shape(a), a.dtype) for a in args
                    )
                    fn = jitted.lower(*shapes, **(statics or {})).compile()
                obs.count("jit.compiles")
                _COMPILED[key] = fn
                return fn
    obs.count("jit.cache_hits")
    return fn


def compile_cache_stats() -> dict:
    """Introspection for tests/diagnostics: which buckets have compiled."""
    return {"count": len(_COMPILED), "keys": sorted(map(repr, _COMPILED))}


@dataclass
class MiniblockStream:
    """Device-resident encoded stream (dense, pre-compaction)."""

    packed: jnp.ndarray     # (n_blocks, MINIBLOCK) int32, first w*32 words valid
    widths: jnp.ndarray     # (n_blocks,) int32 in {0} | WIDTHS
    anchors: jnp.ndarray    # (n_blocks,) int32
    exc_idx: jnp.ndarray    # (n_blocks, MAX_EXC) int32
    exc_val: jnp.ndarray    # (n_blocks, MAX_EXC) int32 (raw zigzag)
    exc_count: jnp.ndarray  # (n_blocks,) int32
    n_values: int           # unpadded element count

    @property
    def n_blocks(self) -> int:
        return int(self.packed.shape[0])

    def compact_bits(self) -> int:
        """Size of the compacted stream in bits."""
        return int(ref.stream_size_bits(self.widths, self.exc_count))


def _pad_to_blocks(x) -> tuple[jnp.ndarray, int]:
    x = jnp.asarray(x).reshape(-1)
    if x.dtype == jnp.int32:
        x = jax.lax.bitcast_convert_type(x, jnp.float32)
    if x.dtype != jnp.float32:
        raise TypeError(f"miniblock codec is 32-bit only, got {x.dtype}")
    n = x.shape[0]
    padded = ((n + MINIBLOCK - 1) // MINIBLOCK) * MINIBLOCK
    if padded == 0:
        padded = MINIBLOCK
        x = jnp.zeros(MINIBLOCK, jnp.float32)
    elif padded != n:
        x = jnp.concatenate([x, jnp.broadcast_to(x[-1:], (padded - n,))])
    return x.reshape(-1, MINIBLOCK), n


def encode(x, *, use_pallas: bool = True, interpret: bool | None = None) -> MiniblockStream:
    blocks, n = _pad_to_blocks(x)
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        outs = kernel.encode_blocks(blocks, interpret=interp)
    else:
        outs = jax.jit(ref.encode_blocks_ref)(blocks)
    return MiniblockStream(*outs, n)


def decode(stream: MiniblockStream, *, use_pallas: bool = True,
           interpret: bool | None = None, out_dtype=jnp.float32) -> jnp.ndarray:
    args = (stream.packed, stream.widths, stream.anchors,
            stream.exc_idx, stream.exc_val, stream.exc_count)
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        x = kernel.decode_blocks(*args, interpret=interp)
    else:
        x = jax.jit(ref.decode_blocks_ref)(*args)
    flat = x.reshape(-1)[: stream.n_values]
    if out_dtype == jnp.int32:
        return jax.lax.bitcast_convert_type(flat, jnp.int32)
    return flat


# ------------------------------------------------------------- host streaming
def to_bytes(stream: MiniblockStream) -> bytes:
    """Compact the dense device stream into contiguous bytes (host side)."""
    packed = np.asarray(stream.packed)
    widths = np.asarray(stream.widths).astype(np.uint8)
    anchors = np.asarray(stream.anchors)
    counts = np.asarray(stream.exc_count).astype(np.uint8)
    exc_idx = np.asarray(stream.exc_idx).astype(np.uint16)
    exc_val = np.asarray(stream.exc_val).astype("<i4")
    n_blocks = len(widths)
    valid = (widths.astype(np.int64) * MINIBLOCK) // 32
    mask = np.arange(MINIBLOCK)[None, :] < valid[:, None]
    payload = packed[mask]  # row-major → block order preserved
    emask = np.arange(MAX_EXC)[None, :] < counts[:, None].astype(np.int64)
    eidx = exc_idx[emask]
    eval_ = exc_val[emask]
    head = _MAGIC + struct.pack("<QI", stream.n_values, n_blocks)
    return (head + widths.tobytes() + counts.tobytes()
            + anchors.astype("<i4").tobytes()
            + eidx.astype("<u2").tobytes() + eval_.tobytes()
            + payload.astype("<i4").tobytes())


def from_bytes(buf: bytes) -> MiniblockStream:
    if buf[:4] != _MAGIC:
        raise ValueError("not an FPD2 stream")
    n_values, n_blocks = struct.unpack_from("<QI", buf, 4)
    off = 4 + 12
    widths = np.frombuffer(buf, np.uint8, n_blocks, off).astype(np.int32)
    off += n_blocks
    counts = np.frombuffer(buf, np.uint8, n_blocks, off).astype(np.int32)
    off += n_blocks
    anchors = np.frombuffer(buf, "<i4", n_blocks, off).astype(np.int32)
    off += 4 * n_blocks
    n_exc = int(counts.sum())
    eidx = np.frombuffer(buf, "<u2", n_exc, off)
    off += 2 * n_exc
    eval_ = np.frombuffer(buf, "<i4", n_exc, off)
    off += 4 * n_exc
    valid = (widths.astype(np.int64) * MINIBLOCK) // 32
    payload = np.frombuffer(buf, "<i4", int(valid.sum()), off)
    packed = np.zeros((n_blocks, MINIBLOCK), np.int32)
    mask = np.arange(MINIBLOCK)[None, :] < valid[:, None]
    packed[mask] = payload
    exc_idx = np.zeros((n_blocks, MAX_EXC), np.int32)
    exc_val = np.zeros((n_blocks, MAX_EXC), np.int32)
    emask = np.arange(MAX_EXC)[None, :] < counts[:, None]
    exc_idx[emask] = eidx
    exc_val[emask] = eval_
    return MiniblockStream(
        jnp.asarray(packed), jnp.asarray(widths), jnp.asarray(anchors),
        jnp.asarray(exc_idx), jnp.asarray(exc_val), jnp.asarray(counts),
        n_values,
    )


# ------------------------------------------------------ page-stream decoding
# Batched on-device execution of host-resolved FPDeltaPlans (the paper-exact
# page format of core/fp_delta.py). The host has already done the sequential
# part — escape resolution — so many pages concatenate into one flat value
# stream: per-value token bit offsets, token widths, and anchor flags, with
# the anchor flags doubling as the segment-id boundaries of the device-side
# segmented cumsum. One launch decodes a whole row group.

# Per-launch cap on packed payload bits. Two constraints: token offsets are
# int32 bit addresses (< 2^31), and the kernel stages the whole word buffer
# into VMEM each grid step, so one launch's words must fit comfortably in
# ~16 MiB of VMEM. 2^26 bits = 8 MiB of words; typical row groups are far
# smaller and still decode in a single launch.
_MAX_LAUNCH_BITS = 1 << 26


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pow2_bucket(x: int, floor: int) -> int:
    """Next power of two >= max(x, floor): stabilizes jit cache shapes."""
    n = max(int(x), int(floor))
    return 1 << (n - 1).bit_length()


@dataclass
class PageStream:
    """Many pages concatenated into one device-decodable value stream."""

    words32: np.ndarray   # (n_words,) int32, n_words % 128 == 0, >= 2 spill words
    tok_off: np.ndarray   # (n_blocks, STREAM_BLOCK) int32 token bit offsets
    nbits: np.ndarray     # (n_blocks, STREAM_BLOCK) int32 token widths [1, 64]
    anchor: np.ndarray    # (n_blocks, STREAM_BLOCK) int32 0/1 (padding = 1)
    width: int            # 32 or 64 (uniform across the stream)
    counts: tuple[int, ...]  # per-page value counts (output split points)

    @property
    def n_values(self) -> int:
        return sum(self.counts)


def build_page_stream(plans) -> PageStream:
    """Concatenate resolved plans into one :class:`PageStream`.

    Page payloads are placed word-aligned in a shared uint32 buffer; each
    value becomes either an *anchor* (page first value, escaped raw value,
    or any raw-mode value — token width W, starts a segment) or an inline
    n-bit delta token. Total payload must stay under ``_MAX_LAUNCH_BITS``
    (use :func:`decode_pages`, which chunks automatically).
    """
    plans = list(plans)
    widths = {p.width for p in plans if p.n_values}
    if len(widths) > 1:
        raise ValueError(f"mixed widths in one page stream: {sorted(widths)}")
    width = widths.pop() if widths else 32

    word_base = 0  # uint64 words placed so far
    wparts: list[np.ndarray] = []
    offp: list[np.ndarray] = []
    nbp: list[np.ndarray] = []
    anchp: list[np.ndarray] = []
    counts: list[int] = []
    for p in plans:
        counts.append(p.n_values)
        if p.n_values == 0:
            continue
        base_bit = word_base * 64
        w = p.words[:-1]  # drop the all-zero spill word; re-guarded globally
        cnt, W = p.n_values, p.width
        if p.n == 0:  # raw mode: every value a W-bit anchor
            off = base_bit + HEADER_BITS + W * np.arange(cnt, dtype=np.int64)
            nb = np.full(cnt, W, np.int64)
            an = np.ones(cnt, np.int64)
        else:
            off = np.empty(cnt, np.int64)
            nb = np.empty(cnt, np.int64)
            an = np.zeros(cnt, np.int64)
            off[0], nb[0], an[0] = base_bit + HEADER_BITS, W, 1
            if cnt > 1:
                # escaped deltas read the raw value after the marker
                off[1:] = base_bit + np.where(p.flags, p.offsets + p.n, p.offsets)
                nb[1:] = np.where(p.flags, W, p.n)
                an[1:] = p.flags
        offp.append(off)
        nbp.append(nb)
        anchp.append(an)
        word_base += len(w)
        wparts.append(w)

    total_bits = word_base * 64
    if total_bits > _MAX_LAUNCH_BITS:
        raise ValueError(
            f"page stream of {total_bits} bits exceeds the per-launch cap "
            f"of {_MAX_LAUNCH_BITS}; use decode_pages, which chunks pages "
            "across launches and host-decodes oversized single pages")

    words64 = np.concatenate(wparts) if wparts else np.zeros(0, np.uint64)
    # LE uint32 view keeps the bit layout: stream bit b = bit b%32 of word b//32
    words32 = np.ascontiguousarray(words64).view("<u4")
    nw = _pow2_bucket(_round_up(len(words32) + 2, 128), 128)
    wbuf = np.zeros(nw, np.uint32)
    wbuf[: len(words32)] = words32

    n = int(sum(counts))
    n_blocks = _pow2_bucket(-(-max(n, 1) // STREAM_BLOCK), 1)
    pad = n_blocks * STREAM_BLOCK
    off_a = np.zeros(pad, np.int64)
    nb_a = np.full(pad, width, np.int64)   # padding: W-bit anchors at bit 0
    an_a = np.ones(pad, np.int64)
    if n:
        off_a[:n] = np.concatenate(offp)
        nb_a[:n] = np.concatenate(nbp)
        an_a[:n] = np.concatenate(anchp)
    shape = (n_blocks, STREAM_BLOCK)
    return PageStream(
        wbuf.view(np.int32),
        off_a.astype(np.int32).reshape(shape),
        nb_a.astype(np.int32).reshape(shape),
        an_a.astype(np.int32).reshape(shape),
        width, tuple(counts),
    )


@functools.lru_cache(maxsize=None)
def _limbs_jit(use_pallas: bool, interpret: bool):
    """Jitted page-stream decode returning uint32 limb pairs."""
    if use_pallas:
        def fn(words32, tok_off, nbits, anchor):
            return kernel.decode_stream_limbs(
                words32, tok_off, nbits, anchor, interpret=interpret)
    else:
        def fn(words32, tok_off, nbits, anchor):
            return ref.decode_stream_limbs_ref(words32, tok_off, nbits, anchor)
    return jax.jit(fn)


def _stream_args(stream: PageStream) -> tuple:
    return (stream.words32, stream.tok_off, stream.nbits, stream.anchor)


def decode_stream_device(stream: PageStream, *, use_pallas: bool = True,
                         interpret: bool | None = None):
    """Decode a built stream, keeping the result device-resident.

    Returns ``(lo, hi)`` uint32 device arrays of length
    ``n_blocks * STREAM_BLOCK`` (tail is padding; ``hi`` is zero for 32-bit
    streams). The bit patterns equal the host decode exactly.
    """
    interp = _default_interpret() if interpret is None else interpret
    args = _stream_args(stream)
    key = ("limbs", stream.words32.shape[0], stream.tok_off.shape[0],
           use_pallas, interp)
    fn = _aot(key, _limbs_jit(use_pallas, interp), args)
    with obs.span("device.decode_launch", cat="device",
                  values=stream.n_values, width=stream.width):
        return fn(*args)


def decode_page_stream(stream: PageStream, *, use_pallas: bool = True,
                       interpret: bool | None = None) -> np.ndarray:
    """Decode a built stream; returns the concatenated values (float32 for
    W=32, float64 for W=64 — the f64 bitcast is a host-side view of the
    device-produced limbs). Bit-identical to the host ``fp_delta_decode``."""
    n = stream.n_values
    dtype = np.float32 if stream.width == 32 else np.float64
    if n == 0:
        return np.zeros(0, dtype)
    lo, hi = decode_stream_device(
        stream, use_pallas=use_pallas, interpret=interpret)
    return DeviceCoords(lo[:n], hi[:n] if stream.width == 64 else None,
                        np.dtype(dtype)).to_numpy()


def _plan_bits(p: FPDeltaPlan) -> int:
    """Packed payload bits a plan occupies in a page stream (spill word
    excluded — the single source of the launch-cap accounting)."""
    return (len(p.words) - 1) * 64


def decode_pages(plans, *, use_pallas: bool = True,
                 interpret: bool | None = None) -> list[np.ndarray]:
    """Decode many host-resolved pages on-device; one array per plan.

    Pages are greedily packed into as few VMEM-sized launches as possible
    (one launch for a typical row group). A single page too large for any
    launch falls back to the host ``fp_delta_execute`` — same bits either
    way. Results are bit-identical to the host decode on every page.
    """
    plans = list(plans)
    out: list[np.ndarray] = []

    def flush(chunk: list[FPDeltaPlan]) -> None:
        if not chunk:
            return
        with obs.span("device.decode_pages", cat="device", pages=len(chunk)):
            stream = build_page_stream(chunk)
            vals = decode_page_stream(
                stream, use_pallas=use_pallas, interpret=interpret)
        out.extend(np.split(vals, np.cumsum(stream.counts)[:-1]))

    chunk: list[FPDeltaPlan] = []
    bits = 0
    for p in plans:
        pbits = _plan_bits(p)
        if pbits > _MAX_LAUNCH_BITS:  # one giant page: host-decode it
            flush(chunk)
            chunk, bits = [], 0
            out.append(fp_delta_execute(p))
            continue
        if chunk and bits + pbits > _MAX_LAUNCH_BITS:
            flush(chunk)
            chunk, bits = [], 0
        chunk.append(p)
        bits += pbits
    flush(chunk)
    return out


def chunk_plan_pairs(plans, pairs):
    """Group x/y page-pair plans into fused launches under the VMEM cap.

    ``plans[2i]``/``plans[2i+1]`` are the x/y plans of pair ``i``;
    ``pairs[i] = (rec_lo, rec_hi)`` its record range. Yields ``("dev",
    plan_list, pair_list, (rec_lo, rec_hi))`` per launch chunk, or
    ``("host", (plan_x, plan_y), None, (rec_lo, rec_hi))`` for a pair whose
    packed payload alone exceeds the cap (the caller host-decodes it via
    ``fp_delta_execute`` — records never straddle pages, so chunk masks
    concatenate exactly). Lives next to :data:`_MAX_LAUNCH_BITS` so the cap
    accounting has a single owner (shared with :func:`decode_pages`).
    """
    cur_plans: list = []
    cur_pairs: list = []
    bits = 0
    for i, (r0, r1) in enumerate(pairs):
        px, py = plans[2 * i], plans[2 * i + 1]
        pbits = _plan_bits(px) + _plan_bits(py)
        if pbits > _MAX_LAUNCH_BITS:
            if cur_plans:
                yield ("dev", cur_plans, cur_pairs,
                       (cur_pairs[0][0], cur_pairs[-1][1]))
                cur_plans, cur_pairs, bits = [], [], 0
            yield ("host", (px, py), None, (r0, r1))
            continue
        if cur_plans and bits + pbits > _MAX_LAUNCH_BITS:
            yield ("dev", cur_plans, cur_pairs,
                   (cur_pairs[0][0], cur_pairs[-1][1]))
            cur_plans, cur_pairs, bits = [], [], 0
        cur_plans += [px, py]
        cur_pairs.append((r0, r1))
        bits += pbits
    if cur_plans:
        yield ("dev", cur_plans, cur_pairs, (cur_pairs[0][0], cur_pairs[-1][1]))


# ------------------------------------------------------ fused decode→refine
# The device half of ``read_columnar(device="jax", refine=True)``: one jit'd
# chain runs page-stream decode (Pallas), the order-key transform, the
# segmented per-record min/max (repro.kernels.minmax), and the bbox survivor
# test. Decoded coordinates stay device-resident; the host receives the
# record mask (n_records bools) and then gathers only surviving values with
# :func:`gather_stream_values`. Pruned records never materialize off-device.


@dataclass
class RefineAux:
    """Host-built segmentation of a :class:`PageStream` into record slices.

    A record's x values occupy one contiguous slice of the stream and its y
    values another (pages are record-aligned and interleave x,y per page).
    ``seg_flag`` marks slice starts (padding tail flagged, mirroring the
    anchor-padding rule of the decode kernel); ``end_pos[r] = (x_end,
    y_end)`` is where the inclusive segmented scan holds record ``r``'s
    reduction. ``x_start``/``y_start``/``counts`` are the slice geometry the
    host uses to build survivor gather indices.
    """

    seg_flag: np.ndarray   # (n_blocks, STREAM_BLOCK) int32, 1 at slice starts
    end_pos: np.ndarray    # (n_rec_pad, 2) int32
    valid: np.ndarray      # (n_rec_pad,) bool — records with >= 1 value
    n_records: int
    x_start: np.ndarray    # (n_records,) int64 stream offset of x slice
    y_start: np.ndarray    # (n_records,) int64
    counts: np.ndarray     # (n_records,) int64 values per record (per axis)


def build_refine_aux(stream: PageStream, pairs, rec_vcounts) -> RefineAux:
    """Segment a stream built from interleaved x,y page pairs by record.

    ``pairs[i] = (r0, r1)``: the record range covered by the i-th x/y page
    pair (``stream.counts[2i]``/``[2i+1]`` are its value counts); records are
    indexed locally and contiguously across pairs. ``rec_vcounts[r]`` is the
    per-axis value count of record ``r``.
    """
    counts = np.ascontiguousarray(rec_vcounts, dtype=np.int64)
    n_rec = len(counts)
    total = stream.n_values
    n_pad_vals = stream.tok_off.size
    flag = np.zeros(n_pad_vals, np.int32)
    flag[total:] = 1  # isolate padding into its own throwaway segments
    x_start = np.zeros(n_rec, np.int64)
    y_start = np.zeros(n_rec, np.int64)
    off = 0
    for i, (r0, r1) in enumerate(pairs):
        c = counts[r0:r1]
        nz = c > 0
        starts = off + np.cumsum(c) - c
        x_start[r0:r1] = starts
        flag[starts[nz]] = 1
        off += int(stream.counts[2 * i])
        starts = off + np.cumsum(c) - c
        y_start[r0:r1] = starts
        flag[starts[nz]] = 1
        off += int(stream.counts[2 * i + 1])
    if off != total:
        raise ValueError(f"refine aux covers {off} values, stream has {total}")
    n_rec_pad = _pow2_bucket(max(n_rec, 1), 8)
    end = np.zeros((n_rec_pad, 2), np.int32)
    end[:n_rec, 0] = x_start + np.maximum(counts - 1, 0)
    end[:n_rec, 1] = y_start + np.maximum(counts - 1, 0)
    valid = np.zeros(n_rec_pad, bool)
    valid[:n_rec] = counts > 0
    return RefineAux(flag.reshape(stream.tok_off.shape), end, valid, n_rec,
                     x_start, y_start, counts)


@functools.lru_cache(maxsize=None)
def _refine_jit(width: int, use_pallas: bool, interpret: bool):
    """Jitted fused chain: decode limbs → order keys → segmented min/max →
    bbox survivor mask. Returns (lo, hi, keep)."""
    from repro.kernels.minmax import (
        float_order_keys,
        inf_keys,
        lex_ge,
        lex_le,
        segment_minmax,
    )

    (neg_lo, neg_hi), (pos_lo, pos_hi) = inf_keys(width)

    def fn(words32, tok_off, nbits, anchor, seg_flag, end_pos, valid, qkeys):
        if use_pallas:
            flo, fhi = kernel.decode_stream_limbs(
                words32, tok_off, nbits, anchor, interpret=interpret)
        else:
            flo, fhi = ref.decode_stream_limbs_ref(words32, tok_off, nbits, anchor)
        klo, khi = float_order_keys(flo, fhi, width)
        n_blocks = tok_off.shape[0]
        mnlo, mnhi, mxlo, mxhi = segment_minmax(
            klo.astype(jnp.int32).reshape(n_blocks, STREAM_BLOCK),
            khi.astype(jnp.int32).reshape(n_blocks, STREAM_BLOCK),
            seg_flag, use_pallas=use_pallas, interpret=interpret)
        ex, ey = end_pos[:, 0], end_pos[:, 1]

        def stat(a, i):
            return jnp.take(a, i, mode="clip")

        q = qkeys.astype(jnp.uint32)
        kneg = (jnp.uint32(neg_lo), jnp.uint32(neg_hi))
        kpos = (jnp.uint32(pos_lo), jnp.uint32(pos_hi))
        xmn = (stat(mnlo, ex), stat(mnhi, ex))
        xmx = (stat(mxlo, ex), stat(mxhi, ex))
        ymn = (stat(mnlo, ey), stat(mnhi, ey))
        ymx = (stat(mxlo, ey), stat(mxhi, ey))
        keep = (
            valid
            # the bbox intersection test, in key space
            & lex_le(*xmn, q[1, 0], q[1, 1]) & lex_ge(*xmx, q[0, 0], q[0, 1])
            & lex_le(*ymn, q[3, 0], q[3, 1]) & lex_ge(*ymx, q[2, 0], q[2, 1])
            # NaN fence: any NaN keys strictly outside [-inf, +inf], and the
            # host oracle (NaN-propagating minimum.reduceat) drops the record
            & lex_le(*xmx, *kpos) & lex_ge(*xmn, *kneg)
            & lex_le(*ymx, *kpos) & lex_ge(*ymn, *kneg)
        )
        return flo, fhi, keep

    return jax.jit(fn)


@dataclass
class RefineResult:
    """Fused-launch output: device-resident limbs + the host record mask."""

    lo: object            # (n_pad,) uint32 device array (None when skipped)
    hi: object            # (n_pad,) uint32 device array (None when skipped)
    keep: np.ndarray      # (n_records,) bool — the only mandatory transfer


def decode_refine_stream(stream: PageStream, aux: RefineAux, bbox, *,
                         use_pallas: bool = True,
                         interpret: bool | None = None) -> RefineResult:
    """Fused decode→bbox-refine over one built page stream.

    Decodes the stream on-device, reduces per-record [min,max] of x and y in
    key space, and tests each record against ``bbox`` — all in one jit'd
    launch chain. Only the record mask crosses back to the host here; pull
    surviving coordinates afterwards with :func:`gather_stream_values`.
    The surviving record set is **bit-identical** to the host refine
    (NaN-propagating ``minimum.reduceat`` + float compares).
    """
    from repro.kernels.minmax import bbox_query_keys

    interp = _default_interpret() if interpret is None else interpret
    dtype = np.float32 if stream.width == 32 else np.float64
    qkeys = bbox_query_keys(bbox, dtype)
    if qkeys is None:  # NaN bound: the host compare keeps nothing
        return RefineResult(None, None, np.zeros(aux.n_records, bool))
    args = _stream_args(stream) + (aux.seg_flag, aux.end_pos, aux.valid, qkeys)
    key = ("refine", stream.words32.shape[0], stream.tok_off.shape[0],
           aux.end_pos.shape[0], stream.width, use_pallas, interp)
    fn = _aot(key, _refine_jit(stream.width, use_pallas, interp), args)
    with obs.span("device.refine_launch", cat="device",
                  values=stream.n_values, records=aux.n_records,
                  width=stream.width):
        lo, hi, keep = fn(*args)
        keep = np.asarray(keep)[: aux.n_records]
    return RefineResult(lo, hi, keep)


# ------------------------------------------------- multi-query refinement
# The serve-tier variant of the fused chain (repro.serve.query_scheduler):
# one decode + segmented min/max launch answers Q in-flight bbox queries at
# once by stacking the queries' order-key bounds into a (Q, 4, 2) operand
# and broadcasting the NaN-fenced survivor test over the new bbox axis.
# The per-record min/max key stack is also returned device-resident, so a
# decoded-row-group cache can answer *later* query waves with a compare-only
# launch (refine_minmax_multi) instead of re-decoding.


def _keep_from_minmax(mm, valid, qkeys, width):
    """(8, R) per-record min/max key limbs × (Q, 4, 2) query keys → (Q, R).

    ``mm`` rows: x (min_lo, min_hi, max_lo, max_hi) then y, taken at each
    record's scan end position. The test is :func:`_refine_jit`'s compare
    verbatim, broadcast over the query axis — each row is bit-identical to a
    solo refine of that query.
    """
    from repro.kernels.minmax import inf_keys, lex_ge, lex_le

    (neg_lo, neg_hi), (pos_lo, pos_hi) = inf_keys(width)
    kneg = (jnp.uint32(neg_lo), jnp.uint32(neg_hi))
    kpos = (jnp.uint32(pos_lo), jnp.uint32(pos_hi))
    q = qkeys.astype(jnp.uint32)
    xmn = (mm[0][None], mm[1][None])
    xmx = (mm[2][None], mm[3][None])
    ymn = (mm[4][None], mm[5][None])
    ymx = (mm[6][None], mm[7][None])

    def qb(row, limb):  # one query-bound limb as a (Q, 1) column
        return q[:, row, limb][:, None]

    return (
        valid[None]
        # the bbox intersection test, in key space, per query row
        & lex_le(*xmn, qb(1, 0), qb(1, 1)) & lex_ge(*xmx, qb(0, 0), qb(0, 1))
        & lex_le(*ymn, qb(3, 0), qb(3, 1)) & lex_ge(*ymx, qb(2, 0), qb(2, 1))
        # NaN fence, identical to the solo refine
        & lex_le(*xmx, *kpos) & lex_ge(*xmn, *kneg)
        & lex_le(*ymx, *kpos) & lex_ge(*ymn, *kneg)
    )


@functools.lru_cache(maxsize=None)
def _refine_multi_jit(width: int, use_pallas: bool, interpret: bool):
    """Jitted fused chain with a bbox-count axis: decode limbs → order keys
    → segmented min/max → per-record key stack → (Q, R) survivor masks."""
    from repro.kernels.minmax import float_order_keys, segment_minmax

    def fn(words32, tok_off, nbits, anchor, seg_flag, end_pos, valid, qkeys):
        if use_pallas:
            flo, fhi = kernel.decode_stream_limbs(
                words32, tok_off, nbits, anchor, interpret=interpret)
        else:
            flo, fhi = ref.decode_stream_limbs_ref(words32, tok_off, nbits, anchor)
        klo, khi = float_order_keys(flo, fhi, width)
        n_blocks = tok_off.shape[0]
        mnlo, mnhi, mxlo, mxhi = segment_minmax(
            klo.astype(jnp.int32).reshape(n_blocks, STREAM_BLOCK),
            khi.astype(jnp.int32).reshape(n_blocks, STREAM_BLOCK),
            seg_flag, use_pallas=use_pallas, interpret=interpret)
        ex, ey = end_pos[:, 0], end_pos[:, 1]

        def stat(a, i):
            return jnp.take(a, i, mode="clip")

        mm = jnp.stack([
            stat(mnlo, ex), stat(mnhi, ex), stat(mxlo, ex), stat(mxhi, ex),
            stat(mnlo, ey), stat(mnhi, ey), stat(mxlo, ey), stat(mxhi, ey),
        ])
        return flo, fhi, mm, _keep_from_minmax(mm, valid, qkeys, width)

    return jax.jit(fn)


@dataclass
class MultiRefineResult:
    """Fused multi-query launch output.

    ``lo``/``hi`` are the decoded stream limbs and ``minmax`` the (8,
    n_rec_pad) per-record min/max key stack — all device-resident and
    cacheable; ``keep`` is the (Q, n_records) host survivor matrix.
    """

    lo: object
    hi: object
    minmax: object
    keep: np.ndarray


def _pad_query_keys(qkeys) -> tuple[np.ndarray, int]:
    nq = len(qkeys)
    qp = _pow2_bucket(max(nq, 1), 4)
    qpad = np.zeros((qp, 4, 2), np.uint32)
    qpad[:nq] = qkeys
    return qpad, qp


def decode_refine_stream_multi(stream: PageStream, aux: RefineAux, qkeys,
                               qvalid, *, use_pallas: bool = True,
                               interpret: bool | None = None) -> MultiRefineResult:
    """Fused decode→refine answering Q stacked bbox queries in one launch.

    ``qkeys``/``qvalid`` come from
    :func:`repro.kernels.minmax.stack_bbox_query_keys`. Each query's
    survivor row is bit-identical to a solo :func:`decode_refine_stream`
    over the same stream; invalid (NaN-bound) queries get all-False rows.
    The query axis is pow2-padded so the compiled shape is shared across
    nearby wave sizes.
    """
    interp = _default_interpret() if interpret is None else interpret
    nq = len(qkeys)
    qpad, qp = _pad_query_keys(qkeys)
    args = _stream_args(stream) + (aux.seg_flag, aux.end_pos, aux.valid, qpad)
    key = ("refine_multi", stream.words32.shape[0], stream.tok_off.shape[0],
           aux.end_pos.shape[0], qp, stream.width, use_pallas, interp)
    fn = _aot(key, _refine_multi_jit(stream.width, use_pallas, interp), args)
    with obs.span("device.refine_multi_launch", cat="device",
                  values=stream.n_values, records=aux.n_records,
                  queries=nq, width=stream.width):
        lo, hi, mm, keep = fn(*args)
        keep = np.array(keep[:nq, : aux.n_records])
    keep[~np.asarray(qvalid, bool)] = False
    return MultiRefineResult(lo, hi, mm, keep)


@functools.lru_cache(maxsize=None)
def _minmax_keep_jit(width: int):
    return jax.jit(
        lambda mm, valid, qkeys: _keep_from_minmax(mm, valid, qkeys, width))


def refine_minmax_multi(minmax, valid, qkeys, qvalid, *, width: int,
                        n_records: int) -> np.ndarray:
    """Re-test a cached per-record min/max key stack against Q new bboxes.

    The cache-hit half of the serve tier: no decode, no scan — one tiny
    compare launch over the stored ``(8, n_rec_pad)`` stack from
    :class:`MultiRefineResult`. Same compare as the fused miss path, so hit
    and miss survivor rows are bit-identical. Returns (Q, n_records) bool.
    """
    nq = len(qkeys)
    qpad, qp = _pad_query_keys(qkeys)
    args = (minmax, valid, qpad)
    key = ("minmax_keep", int(minmax.shape[1]), qp, width)
    fn = _aot(key, _minmax_keep_jit(width), args)
    with obs.span("device.refine_cached", cat="device",
                  records=n_records, queries=nq, width=width):
        keep = np.array(np.asarray(fn(*args))[:nq, :n_records])
    keep[~np.asarray(qvalid, bool)] = False
    return keep


_take_limbs_jit = jax.jit(
    lambda lo, hi, idx: (jnp.take(lo, idx, mode="clip"),
                         jnp.take(hi, idx, mode="clip")))


def ragged_ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenate ``arange(s, s + c)`` for each (start, count) pair."""
    counts = np.asarray(counts, np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    rep_start = np.repeat(np.asarray(starts, np.int64), counts)
    excl = np.cumsum(counts) - counts
    return rep_start + (np.arange(total, dtype=np.int64) - np.repeat(excl, counts))


def gather_stream_values(lo, hi, idx: np.ndarray, width: int, dtype,
                         *, keep_on_device: bool = False):
    """Compact survivor values out of a decoded stream by position.

    ``idx`` (host int array) selects stream positions; the gather runs
    on-device through a pow2-bucketed compiled take, so the host transfer is
    bounded by the survivor count (never the full column). Returns a numpy
    array of ``dtype`` — or a :class:`~repro.core.columnar.DeviceCoords`
    when ``keep_on_device`` (zero host transfer).
    """
    dtype = np.dtype(dtype)
    n = len(idx)
    if n == 0:
        if keep_on_device:
            return DeviceCoords(jnp.zeros(0, jnp.uint32),
                                jnp.zeros(0, jnp.uint32) if width == 64 else None,
                                dtype)
        return np.zeros(0, dtype)
    size = _pow2_bucket(n, 8)
    idx_pad = np.zeros(size, np.int32)
    idx_pad[:n] = idx
    key = ("take", int(lo.shape[0]), size)
    fn = _aot(key, _take_limbs_jit, (lo, hi, idx_pad))
    with obs.span("device.gather", cat="transfer", values=n,
                  on_device=bool(keep_on_device)):
        glo, ghi = fn(lo, hi, idx_pad)
        coords = DeviceCoords(glo[:n], ghi[:n] if width == 64 else None, dtype)
        if not keep_on_device:
            coords = coords.to_numpy()
    return coords


def compress_array(x: np.ndarray, **kw) -> bytes:
    """One-shot lossless compression of a float32/int32 array (any shape)."""
    return to_bytes(encode(np.asarray(x).reshape(-1), **kw))


def decompress_array(buf: bytes, shape, dtype=np.float32, **kw) -> np.ndarray:
    stream = from_bytes(buf)
    want_i32 = np.dtype(dtype) == np.int32
    flat = decode(stream, out_dtype=jnp.int32 if want_i32 else jnp.float32, **kw)
    return np.asarray(flat).reshape(shape).view(dtype)

"""Jit'd user-facing wrappers around the miniblock FP-delta kernels (v2).

Handles arbitrary-length inputs (padding with the last element — zero deltas
are free), Pallas/ref dispatch, and host-side stream compaction to a compact
byte format (used by checkpoint compression, :mod:`repro.train.checkpoint`).

Also home of the *page-stream* decode entry points (:func:`decode_pages`,
:func:`build_page_stream`): batched on-device execution of the paper-exact
FP-delta page format, consumed by ``SpatialParquetReader.read_columnar(
device="jax")``.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fp_delta import HEADER_BITS, FPDeltaPlan, fp_delta_execute

from . import kernel, ref
from .ref import EXC_BITS, MAX_EXC, MINIBLOCK, STREAM_BLOCK

_MAGIC = b"FPD2"  # FP-Delta Miniblock v2 (patched)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@dataclass
class MiniblockStream:
    """Device-resident encoded stream (dense, pre-compaction)."""

    packed: jnp.ndarray     # (n_blocks, MINIBLOCK) int32, first w*32 words valid
    widths: jnp.ndarray     # (n_blocks,) int32 in {0} | WIDTHS
    anchors: jnp.ndarray    # (n_blocks,) int32
    exc_idx: jnp.ndarray    # (n_blocks, MAX_EXC) int32
    exc_val: jnp.ndarray    # (n_blocks, MAX_EXC) int32 (raw zigzag)
    exc_count: jnp.ndarray  # (n_blocks,) int32
    n_values: int           # unpadded element count

    @property
    def n_blocks(self) -> int:
        return int(self.packed.shape[0])

    def compact_bits(self) -> int:
        """Size of the compacted stream in bits."""
        return int(ref.stream_size_bits(self.widths, self.exc_count))


def _pad_to_blocks(x) -> tuple[jnp.ndarray, int]:
    x = jnp.asarray(x).reshape(-1)
    if x.dtype == jnp.int32:
        x = jax.lax.bitcast_convert_type(x, jnp.float32)
    if x.dtype != jnp.float32:
        raise TypeError(f"miniblock codec is 32-bit only, got {x.dtype}")
    n = x.shape[0]
    padded = ((n + MINIBLOCK - 1) // MINIBLOCK) * MINIBLOCK
    if padded == 0:
        padded = MINIBLOCK
        x = jnp.zeros(MINIBLOCK, jnp.float32)
    elif padded != n:
        x = jnp.concatenate([x, jnp.broadcast_to(x[-1:], (padded - n,))])
    return x.reshape(-1, MINIBLOCK), n


def encode(x, *, use_pallas: bool = True, interpret: bool | None = None) -> MiniblockStream:
    blocks, n = _pad_to_blocks(x)
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        outs = kernel.encode_blocks(blocks, interpret=interp)
    else:
        outs = jax.jit(ref.encode_blocks_ref)(blocks)
    return MiniblockStream(*outs, n)


def decode(stream: MiniblockStream, *, use_pallas: bool = True,
           interpret: bool | None = None, out_dtype=jnp.float32) -> jnp.ndarray:
    args = (stream.packed, stream.widths, stream.anchors,
            stream.exc_idx, stream.exc_val, stream.exc_count)
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        x = kernel.decode_blocks(*args, interpret=interp)
    else:
        x = jax.jit(ref.decode_blocks_ref)(*args)
    flat = x.reshape(-1)[: stream.n_values]
    if out_dtype == jnp.int32:
        return jax.lax.bitcast_convert_type(flat, jnp.int32)
    return flat


# ------------------------------------------------------------- host streaming
def to_bytes(stream: MiniblockStream) -> bytes:
    """Compact the dense device stream into contiguous bytes (host side)."""
    packed = np.asarray(stream.packed)
    widths = np.asarray(stream.widths).astype(np.uint8)
    anchors = np.asarray(stream.anchors)
    counts = np.asarray(stream.exc_count).astype(np.uint8)
    exc_idx = np.asarray(stream.exc_idx).astype(np.uint16)
    exc_val = np.asarray(stream.exc_val).astype("<i4")
    n_blocks = len(widths)
    valid = (widths.astype(np.int64) * MINIBLOCK) // 32
    mask = np.arange(MINIBLOCK)[None, :] < valid[:, None]
    payload = packed[mask]  # row-major → block order preserved
    emask = np.arange(MAX_EXC)[None, :] < counts[:, None].astype(np.int64)
    eidx = exc_idx[emask]
    eval_ = exc_val[emask]
    head = _MAGIC + struct.pack("<QI", stream.n_values, n_blocks)
    return (head + widths.tobytes() + counts.tobytes()
            + anchors.astype("<i4").tobytes()
            + eidx.astype("<u2").tobytes() + eval_.tobytes()
            + payload.astype("<i4").tobytes())


def from_bytes(buf: bytes) -> MiniblockStream:
    if buf[:4] != _MAGIC:
        raise ValueError("not an FPD2 stream")
    n_values, n_blocks = struct.unpack_from("<QI", buf, 4)
    off = 4 + 12
    widths = np.frombuffer(buf, np.uint8, n_blocks, off).astype(np.int32)
    off += n_blocks
    counts = np.frombuffer(buf, np.uint8, n_blocks, off).astype(np.int32)
    off += n_blocks
    anchors = np.frombuffer(buf, "<i4", n_blocks, off).astype(np.int32)
    off += 4 * n_blocks
    n_exc = int(counts.sum())
    eidx = np.frombuffer(buf, "<u2", n_exc, off)
    off += 2 * n_exc
    eval_ = np.frombuffer(buf, "<i4", n_exc, off)
    off += 4 * n_exc
    valid = (widths.astype(np.int64) * MINIBLOCK) // 32
    payload = np.frombuffer(buf, "<i4", int(valid.sum()), off)
    packed = np.zeros((n_blocks, MINIBLOCK), np.int32)
    mask = np.arange(MINIBLOCK)[None, :] < valid[:, None]
    packed[mask] = payload
    exc_idx = np.zeros((n_blocks, MAX_EXC), np.int32)
    exc_val = np.zeros((n_blocks, MAX_EXC), np.int32)
    emask = np.arange(MAX_EXC)[None, :] < counts[:, None]
    exc_idx[emask] = eidx
    exc_val[emask] = eval_
    return MiniblockStream(
        jnp.asarray(packed), jnp.asarray(widths), jnp.asarray(anchors),
        jnp.asarray(exc_idx), jnp.asarray(exc_val), jnp.asarray(counts),
        n_values,
    )


# ------------------------------------------------------ page-stream decoding
# Batched on-device execution of host-resolved FPDeltaPlans (the paper-exact
# page format of core/fp_delta.py). The host has already done the sequential
# part — escape resolution — so many pages concatenate into one flat value
# stream: per-value token bit offsets, token widths, and anchor flags, with
# the anchor flags doubling as the segment-id boundaries of the device-side
# segmented cumsum. One launch decodes a whole row group.

# Per-launch cap on packed payload bits. Two constraints: token offsets are
# int32 bit addresses (< 2^31), and the kernel stages the whole word buffer
# into VMEM each grid step, so one launch's words must fit comfortably in
# ~16 MiB of VMEM. 2^26 bits = 8 MiB of words; typical row groups are far
# smaller and still decode in a single launch.
_MAX_LAUNCH_BITS = 1 << 26


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pow2_bucket(x: int, floor: int) -> int:
    """Next power of two >= max(x, floor): stabilizes jit cache shapes."""
    n = max(int(x), int(floor))
    return 1 << (n - 1).bit_length()


@dataclass
class PageStream:
    """Many pages concatenated into one device-decodable value stream."""

    words32: np.ndarray   # (n_words,) int32, n_words % 128 == 0, >= 2 spill words
    tok_off: np.ndarray   # (n_blocks, STREAM_BLOCK) int32 token bit offsets
    nbits: np.ndarray     # (n_blocks, STREAM_BLOCK) int32 token widths [1, 64]
    anchor: np.ndarray    # (n_blocks, STREAM_BLOCK) int32 0/1 (padding = 1)
    width: int            # 32 or 64 (uniform across the stream)
    counts: tuple[int, ...]  # per-page value counts (output split points)

    @property
    def n_values(self) -> int:
        return sum(self.counts)


def build_page_stream(plans) -> PageStream:
    """Concatenate resolved plans into one :class:`PageStream`.

    Page payloads are placed word-aligned in a shared uint32 buffer; each
    value becomes either an *anchor* (page first value, escaped raw value,
    or any raw-mode value — token width W, starts a segment) or an inline
    n-bit delta token. Total payload must stay under ``_MAX_LAUNCH_BITS``
    (use :func:`decode_pages`, which chunks automatically).
    """
    plans = list(plans)
    widths = {p.width for p in plans if p.n_values}
    if len(widths) > 1:
        raise ValueError(f"mixed widths in one page stream: {sorted(widths)}")
    width = widths.pop() if widths else 32

    word_base = 0  # uint64 words placed so far
    wparts: list[np.ndarray] = []
    offp: list[np.ndarray] = []
    nbp: list[np.ndarray] = []
    anchp: list[np.ndarray] = []
    counts: list[int] = []
    for p in plans:
        counts.append(p.n_values)
        if p.n_values == 0:
            continue
        base_bit = word_base * 64
        w = p.words[:-1]  # drop the all-zero spill word; re-guarded globally
        cnt, W = p.n_values, p.width
        if p.n == 0:  # raw mode: every value a W-bit anchor
            off = base_bit + HEADER_BITS + W * np.arange(cnt, dtype=np.int64)
            nb = np.full(cnt, W, np.int64)
            an = np.ones(cnt, np.int64)
        else:
            off = np.empty(cnt, np.int64)
            nb = np.empty(cnt, np.int64)
            an = np.zeros(cnt, np.int64)
            off[0], nb[0], an[0] = base_bit + HEADER_BITS, W, 1
            if cnt > 1:
                # escaped deltas read the raw value after the marker
                off[1:] = base_bit + np.where(p.flags, p.offsets + p.n, p.offsets)
                nb[1:] = np.where(p.flags, W, p.n)
                an[1:] = p.flags
        offp.append(off)
        nbp.append(nb)
        anchp.append(an)
        word_base += len(w)
        wparts.append(w)

    total_bits = word_base * 64
    if total_bits > _MAX_LAUNCH_BITS:
        raise ValueError(
            f"page stream of {total_bits} bits exceeds the per-launch cap "
            f"of {_MAX_LAUNCH_BITS}; use decode_pages, which chunks pages "
            "across launches and host-decodes oversized single pages")

    words64 = np.concatenate(wparts) if wparts else np.zeros(0, np.uint64)
    # LE uint32 view keeps the bit layout: stream bit b = bit b%32 of word b//32
    words32 = np.ascontiguousarray(words64).view("<u4")
    nw = _pow2_bucket(_round_up(len(words32) + 2, 128), 128)
    wbuf = np.zeros(nw, np.uint32)
    wbuf[: len(words32)] = words32

    n = int(sum(counts))
    n_blocks = _pow2_bucket(-(-max(n, 1) // STREAM_BLOCK), 1)
    pad = n_blocks * STREAM_BLOCK
    off_a = np.zeros(pad, np.int64)
    nb_a = np.full(pad, width, np.int64)   # padding: W-bit anchors at bit 0
    an_a = np.ones(pad, np.int64)
    if n:
        off_a[:n] = np.concatenate(offp)
        nb_a[:n] = np.concatenate(nbp)
        an_a[:n] = np.concatenate(anchp)
    shape = (n_blocks, STREAM_BLOCK)
    return PageStream(
        wbuf.view(np.int32),
        off_a.astype(np.int32).reshape(shape),
        nb_a.astype(np.int32).reshape(shape),
        an_a.astype(np.int32).reshape(shape),
        width, tuple(counts),
    )


_ref_decode_stream = jax.jit(
    ref.decode_stream_ref, static_argnames=("width",))


def decode_page_stream(stream: PageStream, *, use_pallas: bool = True,
                       interpret: bool | None = None) -> np.ndarray:
    """Decode a built stream; returns the concatenated values (float32 for
    W=32, float64 for W=64 — the f64 bitcast is a host-side view of the
    device-produced limbs). Bit-identical to the host ``fp_delta_decode``."""
    n = stream.n_values
    dtype = np.float32 if stream.width == 32 else np.float64
    if n == 0:
        return np.zeros(0, dtype)
    args = (jnp.asarray(stream.words32), jnp.asarray(stream.tok_off),
            jnp.asarray(stream.nbits), jnp.asarray(stream.anchor))
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        out = kernel.decode_stream_blocks(
            *args, width=stream.width, interpret=interp)
    else:
        out = _ref_decode_stream(*args, width=stream.width)
    if stream.width == 32:
        return np.asarray(out)[:n]
    lo, hi = out
    bits = (np.asarray(hi).view(np.uint32).astype(np.uint64) << np.uint64(32)) | \
        np.asarray(lo).view(np.uint32).astype(np.uint64)
    return bits[:n].view(np.float64)


def decode_pages(plans, *, use_pallas: bool = True,
                 interpret: bool | None = None) -> list[np.ndarray]:
    """Decode many host-resolved pages on-device; one array per plan.

    Pages are greedily packed into as few VMEM-sized launches as possible
    (one launch for a typical row group). A single page too large for any
    launch falls back to the host ``fp_delta_execute`` — same bits either
    way. Results are bit-identical to the host decode on every page.
    """
    plans = list(plans)
    out: list[np.ndarray] = []

    def flush(chunk: list[FPDeltaPlan]) -> None:
        if not chunk:
            return
        stream = build_page_stream(chunk)
        vals = decode_page_stream(
            stream, use_pallas=use_pallas, interpret=interpret)
        out.extend(np.split(vals, np.cumsum(stream.counts)[:-1]))

    chunk: list[FPDeltaPlan] = []
    bits = 0
    for p in plans:
        pbits = (len(p.words) - 1) * 64
        if pbits > _MAX_LAUNCH_BITS:  # one giant page: host-decode it
            flush(chunk)
            chunk, bits = [], 0
            out.append(fp_delta_execute(p))
            continue
        if chunk and bits + pbits > _MAX_LAUNCH_BITS:
            flush(chunk)
            chunk, bits = [], 0
        chunk.append(p)
        bits += pbits
    flush(chunk)
    return out


def compress_array(x: np.ndarray, **kw) -> bytes:
    """One-shot lossless compression of a float32/int32 array (any shape)."""
    return to_bytes(encode(np.asarray(x).reshape(-1), **kw))


def decompress_array(buf: bytes, shape, dtype=np.float32, **kw) -> np.ndarray:
    stream = from_bytes(buf)
    want_i32 = np.dtype(dtype) == np.int32
    flat = decode(stream, out_dtype=jnp.int32 if want_i32 else jnp.float32, **kw)
    return np.asarray(flat).reshape(shape).view(dtype)

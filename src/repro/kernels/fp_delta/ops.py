"""Jit'd user-facing wrappers around the miniblock FP-delta kernels (v2).

Handles arbitrary-length inputs (padding with the last element — zero deltas
are free), Pallas/ref dispatch, and host-side stream compaction to a compact
byte format (used by checkpoint compression, :mod:`repro.train.checkpoint`).
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import kernel, ref
from .ref import EXC_BITS, MAX_EXC, MINIBLOCK

_MAGIC = b"FPD2"  # FP-Delta Miniblock v2 (patched)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@dataclass
class MiniblockStream:
    """Device-resident encoded stream (dense, pre-compaction)."""

    packed: jnp.ndarray     # (n_blocks, MINIBLOCK) int32, first w*32 words valid
    widths: jnp.ndarray     # (n_blocks,) int32 in {0} | WIDTHS
    anchors: jnp.ndarray    # (n_blocks,) int32
    exc_idx: jnp.ndarray    # (n_blocks, MAX_EXC) int32
    exc_val: jnp.ndarray    # (n_blocks, MAX_EXC) int32 (raw zigzag)
    exc_count: jnp.ndarray  # (n_blocks,) int32
    n_values: int           # unpadded element count

    @property
    def n_blocks(self) -> int:
        return int(self.packed.shape[0])

    def compact_bits(self) -> int:
        """Size of the compacted stream in bits."""
        return int(ref.stream_size_bits(self.widths, self.exc_count))


def _pad_to_blocks(x) -> tuple[jnp.ndarray, int]:
    x = jnp.asarray(x).reshape(-1)
    if x.dtype == jnp.int32:
        x = jax.lax.bitcast_convert_type(x, jnp.float32)
    if x.dtype != jnp.float32:
        raise TypeError(f"miniblock codec is 32-bit only, got {x.dtype}")
    n = x.shape[0]
    padded = ((n + MINIBLOCK - 1) // MINIBLOCK) * MINIBLOCK
    if padded == 0:
        padded = MINIBLOCK
        x = jnp.zeros(MINIBLOCK, jnp.float32)
    elif padded != n:
        x = jnp.concatenate([x, jnp.broadcast_to(x[-1:], (padded - n,))])
    return x.reshape(-1, MINIBLOCK), n


def encode(x, *, use_pallas: bool = True, interpret: bool | None = None) -> MiniblockStream:
    blocks, n = _pad_to_blocks(x)
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        outs = kernel.encode_blocks(blocks, interpret=interp)
    else:
        outs = jax.jit(ref.encode_blocks_ref)(blocks)
    return MiniblockStream(*outs, n)


def decode(stream: MiniblockStream, *, use_pallas: bool = True,
           interpret: bool | None = None, out_dtype=jnp.float32) -> jnp.ndarray:
    args = (stream.packed, stream.widths, stream.anchors,
            stream.exc_idx, stream.exc_val, stream.exc_count)
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        x = kernel.decode_blocks(*args, interpret=interp)
    else:
        x = jax.jit(ref.decode_blocks_ref)(*args)
    flat = x.reshape(-1)[: stream.n_values]
    if out_dtype == jnp.int32:
        return jax.lax.bitcast_convert_type(flat, jnp.int32)
    return flat


# ------------------------------------------------------------- host streaming
def to_bytes(stream: MiniblockStream) -> bytes:
    """Compact the dense device stream into contiguous bytes (host side)."""
    packed = np.asarray(stream.packed)
    widths = np.asarray(stream.widths).astype(np.uint8)
    anchors = np.asarray(stream.anchors)
    counts = np.asarray(stream.exc_count).astype(np.uint8)
    exc_idx = np.asarray(stream.exc_idx).astype(np.uint16)
    exc_val = np.asarray(stream.exc_val).astype("<i4")
    n_blocks = len(widths)
    valid = (widths.astype(np.int64) * MINIBLOCK) // 32
    mask = np.arange(MINIBLOCK)[None, :] < valid[:, None]
    payload = packed[mask]  # row-major → block order preserved
    emask = np.arange(MAX_EXC)[None, :] < counts[:, None].astype(np.int64)
    eidx = exc_idx[emask]
    eval_ = exc_val[emask]
    head = _MAGIC + struct.pack("<QI", stream.n_values, n_blocks)
    return (head + widths.tobytes() + counts.tobytes()
            + anchors.astype("<i4").tobytes()
            + eidx.astype("<u2").tobytes() + eval_.tobytes()
            + payload.astype("<i4").tobytes())


def from_bytes(buf: bytes) -> MiniblockStream:
    if buf[:4] != _MAGIC:
        raise ValueError("not an FPD2 stream")
    n_values, n_blocks = struct.unpack_from("<QI", buf, 4)
    off = 4 + 12
    widths = np.frombuffer(buf, np.uint8, n_blocks, off).astype(np.int32)
    off += n_blocks
    counts = np.frombuffer(buf, np.uint8, n_blocks, off).astype(np.int32)
    off += n_blocks
    anchors = np.frombuffer(buf, "<i4", n_blocks, off).astype(np.int32)
    off += 4 * n_blocks
    n_exc = int(counts.sum())
    eidx = np.frombuffer(buf, "<u2", n_exc, off)
    off += 2 * n_exc
    eval_ = np.frombuffer(buf, "<i4", n_exc, off)
    off += 4 * n_exc
    valid = (widths.astype(np.int64) * MINIBLOCK) // 32
    payload = np.frombuffer(buf, "<i4", int(valid.sum()), off)
    packed = np.zeros((n_blocks, MINIBLOCK), np.int32)
    mask = np.arange(MINIBLOCK)[None, :] < valid[:, None]
    packed[mask] = payload
    exc_idx = np.zeros((n_blocks, MAX_EXC), np.int32)
    exc_val = np.zeros((n_blocks, MAX_EXC), np.int32)
    emask = np.arange(MAX_EXC)[None, :] < counts[:, None]
    exc_idx[emask] = eidx
    exc_val[emask] = eval_
    return MiniblockStream(
        jnp.asarray(packed), jnp.asarray(widths), jnp.asarray(anchors),
        jnp.asarray(exc_idx), jnp.asarray(exc_val), jnp.asarray(counts),
        n_values,
    )


def compress_array(x: np.ndarray, **kw) -> bytes:
    """One-shot lossless compression of a float32/int32 array (any shape)."""
    return to_bytes(encode(np.asarray(x).reshape(-1), **kw))


def decompress_array(buf: bytes, shape, dtype=np.float32, **kw) -> np.ndarray:
    stream = from_bytes(buf)
    want_i32 = np.dtype(dtype) == np.int32
    flat = decode(stream, out_dtype=jnp.int32 if want_i32 else jnp.float32, **kw)
    return np.asarray(flat).reshape(shape).view(dtype)

"""Pallas TPU kernels for miniblock FP-delta encode/decode (v2: patched).

TPU adaptation of Spatial Parquet §3 (see DESIGN.md §5 and ref.py for the
format contract). Each grid step processes one miniblock of 1024 float32
values — exactly one (8, 128) VPU tile — entirely in VMEM:

* encode: bitcast → in-block delta (the anchor makes ``delta[0] = 0``, so no
  cross-block carry exists) → zigzag → exact significant-bit ladder →
  cost-optimal lane-aligned width → all six packings computed with static
  shapes and combined with a masked sum; exceptions (FastPFOR-style patches
  for deltas wider than w) are compacted with a (MAX_EXC, 1024) one-hot
  contraction against iota — data-independent control flow, no scatter.
* decode: the mirror image; exceptions re-injected with the same one-hot
  trick, and the sequential prefix sum replaced by a log2(1024) = 10-step
  shifted-add scan (VPU-parallel).

Grid iteration over miniblocks is embarrassingly parallel.

The module also hosts the *page-stream* decode kernel
(:func:`decode_stream_blocks`): on-device execution of the paper-exact
FP-delta page format from host-resolved ``FPDeltaPlan``s — see the
"page stream" section of ref.py for the format math and ops.py for the
batching layer that feeds it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (
    MAX_EXC,
    MINIBLOCK,
    STREAM_BLOCK,
    WIDTHS,
    choose_width,
    extract_exceptions,
    gather_tokens,
    inject_exceptions,
    pack_candidate,
    seg_combine,
    segmented_scan,
    significant_bits_u32,
    stream_values,
    unpack_candidate,
    unzigzag_u32,
    zigzag_i32,
)

_BLOCK_2D = (8, 128)  # 1024 values as one VPU tile


def _encode_kernel(x_ref, packed_ref, width_ref, anchor_ref,
                   exc_idx_ref, exc_val_ref, count_ref):
    x = x_ref[...].reshape(MINIBLOCK)
    xi = jax.lax.bitcast_convert_type(x, jnp.int32)
    prev = jnp.concatenate([xi[:1], xi[:-1]])
    zig = zigzag_i32(xi - prev)  # delta[0] == 0 by construction
    nbits = significant_bits_u32(zig)
    width, _ = choose_width(nbits[None, :])
    width = width[0]
    exc_idx, exc_val, count = extract_exceptions(zig, width)
    packed = jnp.zeros(MINIBLOCK, dtype=jnp.uint32)
    for w in WIDTHS:  # static unroll; masked sum select (fields disjoint)
        packed = packed + jnp.where(width == w, pack_candidate(zig, w), jnp.uint32(0))
    packed_ref[...] = packed.astype(jnp.int32).reshape(1, *_BLOCK_2D)
    width_ref[0, 0] = width
    anchor_ref[0, 0] = xi[0]
    exc_idx_ref[...] = exc_idx.reshape(1, MAX_EXC)
    exc_val_ref[...] = exc_val.astype(jnp.int32).reshape(1, MAX_EXC)
    count_ref[0, 0] = count


def _decode_kernel(packed_ref, width_ref, anchor_ref,
                   exc_idx_ref, exc_val_ref, count_ref, x_ref):
    words = packed_ref[...].reshape(MINIBLOCK).astype(jnp.uint32)
    width = width_ref[0, 0]
    anchor = anchor_ref[0, 0]
    zig = jnp.zeros(MINIBLOCK, dtype=jnp.uint32)
    for w in WIDTHS:
        zig = zig + jnp.where(width == w, unpack_candidate(words, w), jnp.uint32(0))
    zig = inject_exceptions(
        zig, exc_idx_ref[...].reshape(MAX_EXC),
        exc_val_ref[...].reshape(MAX_EXC).astype(jnp.uint32), count_ref[0, 0],
    )
    delta = unzigzag_u32(zig)
    # log-step inclusive prefix sum (10 shifted adds on the VPU)
    acc = delta
    shift = 1
    while shift < MINIBLOCK:
        shifted = jnp.concatenate([jnp.zeros(shift, jnp.int32), acc[:-shift]])
        acc = acc + shifted
        shift *= 2
    xi = anchor + acc
    x_ref[...] = jax.lax.bitcast_convert_type(xi, jnp.float32).reshape(1, *_BLOCK_2D)


@functools.partial(jax.jit, static_argnames=("interpret",))
def encode_blocks(x: jnp.ndarray, *, interpret: bool = True):
    """x: (n_blocks, MINIBLOCK) float32 -> (packed, widths, anchors, exc_idx,
    exc_val, exc_count). Bit-identical to ref.encode_blocks_ref."""
    n_blocks = x.shape[0]
    assert x.shape == (n_blocks, MINIBLOCK), x.shape
    x2 = x.reshape(n_blocks, *_BLOCK_2D)
    outs = pl.pallas_call(
        _encode_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, MAX_EXC), lambda b: (b, 0)),
            pl.BlockSpec((1, MAX_EXC), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, *_BLOCK_2D), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, MAX_EXC), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, MAX_EXC), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x2)
    packed, widths, anchors, exc_idx, exc_val, count = outs
    return (packed.reshape(n_blocks, MINIBLOCK), widths[:, 0], anchors[:, 0],
            exc_idx, exc_val, count[:, 0])


# --------------------------------------------------------------- page stream
# Decode kernel for the paper-exact FP-delta page format (see ref.py "page
# stream" section for the math). Each grid step decodes one STREAM_BLOCK of
# the concatenated value stream: fixed-width gather from the shared packed
# words (whole array resident per step), escape injection, un-zigzag, and a
# block-local segmented scan. Cross-block carries are stitched afterwards
# with one tiny associative scan over per-block summaries — the grid stays
# embarrassingly parallel, like the miniblock codec above.


def _stream_decode_kernel(words_ref, off_ref, nbits_ref, anch_ref,
                          lo_ref, hi_ref, seen_ref):
    words = words_ref[...].reshape(-1).astype(jnp.uint32)
    offs = off_ref[...].reshape(STREAM_BLOCK)
    nb = nbits_ref[...].reshape(STREAM_BLOCK)
    anc = anch_ref[...].reshape(STREAM_BLOCK) != 0
    lo, hi = gather_tokens(words, offs, nb)
    vlo, vhi = stream_values(lo, hi, anc)
    flo, fhi, seen = segmented_scan(vlo, vhi, anc)
    lo_ref[...] = flo.astype(jnp.int32).reshape(1, *_BLOCK_2D)
    hi_ref[...] = fhi.astype(jnp.int32).reshape(1, *_BLOCK_2D)
    seen_ref[...] = seen.astype(jnp.int32).reshape(1, *_BLOCK_2D)


def decode_stream_limbs(words32, tok_off, nbits, anchor, *, interpret: bool = True):
    """Page-stream decode returning the raw W-bit patterns as uint32 limbs.

    Same contract as :func:`decode_stream_blocks` but without the final
    bitcast/limb-split: returns ``(lo, hi)`` uint32 arrays flattened to
    ``(n_blocks*STREAM_BLOCK,)`` (``hi`` is all-zero for 32-bit streams).
    This is the form the fused decode→refine chain consumes — the order-key
    transform and segmented bbox reduction run directly on the limbs.
    """
    n_blocks = tok_off.shape[0]
    wr = words32.reshape(-1, 128)
    o2 = tok_off.reshape(n_blocks, *_BLOCK_2D)
    n2 = nbits.reshape(n_blocks, *_BLOCK_2D)
    a2 = anchor.reshape(n_blocks, *_BLOCK_2D)
    outs = pl.pallas_call(
        _stream_decode_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(wr.shape, lambda b: (0, 0)),  # whole words array
            pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, *_BLOCK_2D), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, *_BLOCK_2D), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, *_BLOCK_2D), jnp.int32),
        ],
        interpret=interpret,
    )(wr, o2, n2, a2)
    lo = outs[0].reshape(n_blocks, STREAM_BLOCK).astype(jnp.uint32)
    hi = outs[1].reshape(n_blocks, STREAM_BLOCK).astype(jnp.uint32)
    seen = outs[2].reshape(n_blocks, STREAM_BLOCK) != 0
    # Carry stitch: block b inherits the running value of the last anchor
    # segment before it — an exclusive segmented combine of the per-block
    # summaries (each block's last scanned element + "block saw an anchor").
    ilo, ihi, _ = jax.lax.associative_scan(
        seg_combine, (lo[:, -1], hi[:, -1], seen[:, -1]))
    clo = jnp.concatenate([jnp.zeros(1, jnp.uint32), ilo[:-1]])
    chi = jnp.concatenate([jnp.zeros(1, jnp.uint32), ihi[:-1]])
    slo = lo + clo[:, None]
    carry = (slo < lo).astype(jnp.uint32)
    shi = hi + chi[:, None] + carry
    flo = jnp.where(seen, lo, slo).reshape(-1)
    fhi = jnp.where(seen, hi, shi).reshape(-1)
    return flo, fhi


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def decode_stream_blocks(words32, tok_off, nbits, anchor, *,
                         width: int, interpret: bool = True):
    """Batched page-stream decode (one launch for many concatenated pages).

    ``words32``: (n_words,) int32 — LE uint32 view of the packed streams,
    ``n_words % 128 == 0`` with >= 2 trailing spill words. ``tok_off`` /
    ``nbits`` / ``anchor``: (n_blocks, STREAM_BLOCK) int32; padding tail
    elements must be anchors so they cannot leak into real segments.
    Returns the decoded W-bit patterns flattened to (n_blocks*STREAM_BLOCK,):
    float32 (bitcast on-device) for ``width == 32``, else (lo, hi) int32
    limbs. Bit-identical to ``ref.decode_stream_ref``.
    """
    flo, fhi = decode_stream_limbs(words32, tok_off, nbits, anchor,
                                   interpret=interpret)
    if width == 32:
        return jax.lax.bitcast_convert_type(flo.astype(jnp.int32), jnp.float32)
    return flo.astype(jnp.int32), fhi.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_blocks(packed, widths, anchors, exc_idx, exc_val, exc_count,
                  *, interpret: bool = True):
    """Inverse of encode_blocks -> (n_blocks, MINIBLOCK) float32."""
    n_blocks = packed.shape[0]
    assert packed.shape == (n_blocks, MINIBLOCK), packed.shape
    p2 = packed.reshape(n_blocks, *_BLOCK_2D)
    x = pl.pallas_call(
        _decode_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, MAX_EXC), lambda b: (b, 0)),
            pl.BlockSpec((1, MAX_EXC), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, *_BLOCK_2D), jnp.float32),
        interpret=interpret,
    )(p2, widths.reshape(n_blocks, 1), anchors.reshape(n_blocks, 1),
      exc_idx, exc_val, exc_count.reshape(n_blocks, 1))
    return x.reshape(n_blocks, MINIBLOCK)

"""Oracles for the min/max statistics kernels (paper §4 index build + the
device-side bbox refinement of the fused scan).

Two reductions live here:

* :func:`minmax_ref` — dense per-page ``[min, max]`` over a
  ``(n_pages, page_size)`` float32 matrix: the light-weight spatial index.
* :func:`segment_minmax_ref` — *segmented* running min/max over a flat value
  stream whose elements are IEEE-754 bit patterns mapped to **order keys**
  (uint32 limb pairs, see :func:`float_order_keys`). Segments are delimited
  by start flags; the inclusive scan result at a segment's last element is
  that segment's reduction. This is the per-record bbox statistic of the
  fused decode→refine read path (`repro.kernels.fp_delta.decode_refine_stream`):
  all comparisons run on uint32 limbs, so float64 coordinates refine on-device
  without 64-bit lanes (no ``jax_enable_x64``).

Order keys
----------

``key(v)`` is the classic total-order transform of an IEEE float's bit
pattern: flip all bits when the sign bit is set, else set the sign bit.
``key`` is strictly monotonic in the float total order, so
``float_cmp(a, b) == uint_cmp(key(a), key(b))`` for all non-NaN values, with
``-0.0 < +0.0`` (callers canonicalize zero-valued query bounds so the bbox
test is unaffected) and every NaN mapping strictly above ``key(+inf)``
(positive NaNs) or strictly below ``key(-inf)`` (negative NaNs) — which is
exactly how the refine step detects NaN-poisoned records and drops them,
matching numpy's NaN-propagating ``minimum.reduceat`` on the host.

64-bit patterns are handled as ``(lo, hi)`` uint32 limb pairs compared
lexicographically (``hi`` first); 32-bit patterns use ``lo = 0``.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

# per-lane scan identities: min lanes start at the largest key, max at the
# smallest, so combine(identity, b) == b
_MIN_IDENT = 0xFFFFFFFF
_MAX_IDENT = 0


def minmax_ref(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    return jnp.min(x, axis=1), jnp.max(x, axis=1)


# ------------------------------------------------------------ order-key math
def float_order_keys(lo: jnp.ndarray, hi: jnp.ndarray, width: int):
    """Map decoded W-bit patterns (uint32 limbs) to total-order keys.

    ``width == 32`` ignores ``hi`` (the pattern is ``lo``) and returns
    ``(key, 0)`` so the lexicographic compare degenerates to one limb.
    """
    if width == 32:
        u = lo.astype(jnp.uint32)
        sign = (u >> jnp.uint32(31)) != 0
        khi = u ^ jnp.where(sign, jnp.uint32(0xFFFFFFFF), jnp.uint32(0x80000000))
        return jnp.zeros_like(khi), khi
    l = lo.astype(jnp.uint32)
    h = hi.astype(jnp.uint32)
    sign = (h >> jnp.uint32(31)) != 0
    khi = h ^ jnp.where(sign, jnp.uint32(0xFFFFFFFF), jnp.uint32(0x80000000))
    klo = l ^ jnp.where(sign, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    return klo, khi


def lex_gt(alo, ahi, blo, bhi):
    """Lexicographic ``(ahi, alo) > (bhi, blo)`` on uint32 limbs."""
    return (ahi > bhi) | ((ahi == bhi) & (alo > blo))


def lex_le(alo, ahi, blo, bhi):
    return ~lex_gt(alo, ahi, blo, bhi)


def lex_ge(alo, ahi, blo, bhi):
    return ~lex_gt(blo, bhi, alo, ahi)


def minmax_seg_combine(a, b):
    """Associative combine of the segmented min/max scan; ``b`` is the
    *later* operand. State: ``(min_lo, min_hi, max_lo, max_hi, flag)`` —
    a segment-start flag in ``b`` blocks ``a``'s contribution entirely."""
    amnlo, amnhi, amxlo, amxhi, af = a
    bmnlo, bmnhi, bmxlo, bmxhi, bf = b
    a_min_gt = lex_gt(amnlo, amnhi, bmnlo, bmnhi)
    mnlo = jnp.where(a_min_gt, bmnlo, amnlo)
    mnhi = jnp.where(a_min_gt, bmnhi, amnhi)
    a_max_gt = lex_gt(amxlo, amxhi, bmxlo, bmxhi)
    mxlo = jnp.where(a_max_gt, amxlo, bmxlo)
    mxhi = jnp.where(a_max_gt, amxhi, bmxhi)
    return (
        jnp.where(bf, bmnlo, mnlo),
        jnp.where(bf, bmnhi, mnhi),
        jnp.where(bf, bmxlo, mxlo),
        jnp.where(bf, bmxhi, mxhi),
        af | bf,
    )


def segmented_minmax_scan(klo, khi, flag):
    """Inclusive Hillis–Steele segmented min/max scan over the last axis.

    ``klo``/``khi``: uint32 order-key limbs; ``flag``: bool segment starts.
    Returns the five scanned state arrays (min/max limbs + seen flag).
    """
    state = (klo, khi, klo, khi, flag)
    n = klo.shape[-1]
    shift = 1
    while shift < n:
        head = state[0].shape[:-1] + (shift,)
        prev = (
            jnp.concatenate(
                [jnp.full(head, _MIN_IDENT, jnp.uint32), state[0][..., :-shift]], -1),
            jnp.concatenate(
                [jnp.full(head, _MIN_IDENT, jnp.uint32), state[1][..., :-shift]], -1),
            jnp.concatenate(
                [jnp.full(head, _MAX_IDENT, jnp.uint32), state[2][..., :-shift]], -1),
            jnp.concatenate(
                [jnp.full(head, _MAX_IDENT, jnp.uint32), state[3][..., :-shift]], -1),
            jnp.concatenate(
                [jnp.zeros(head, jnp.bool_), state[4][..., :-shift]], -1),
        )
        state = minmax_seg_combine(prev, state)
        shift *= 2
    return state


def segment_minmax_ref(klo, khi, flag):
    """Flat-scan oracle: one global segmented scan over the whole stream
    (structurally unlike the kernel's block-local scans + carry stitch).

    Returns ``(min_lo, min_hi, max_lo, max_hi)`` flattened; the value at a
    segment's last position is the segment's reduction.
    """
    out = segmented_minmax_scan(
        klo.reshape(-1).astype(jnp.uint32),
        khi.reshape(-1).astype(jnp.uint32),
        flag.reshape(-1) != 0,
    )
    return out[0], out[1], out[2], out[3]


# -------------------------------------------------- host-side query-key math
def float_order_key_np(v, dtype: np.dtype) -> tuple[int, int]:
    """Host mirror of :func:`float_order_keys` for one scalar: (lo, hi)."""
    dtype = np.dtype(dtype)
    if dtype.itemsize == 4:
        u = int(np.array(v, dtype).view(np.uint32))
        k = u ^ (0xFFFFFFFF if u >> 31 else 0x80000000)
        return 0, k
    u = int(np.array(v, dtype).view(np.uint64))
    lo, hi = u & 0xFFFFFFFF, u >> 32
    if hi >> 31:
        return lo ^ 0xFFFFFFFF, hi ^ 0xFFFFFFFF
    return lo, hi ^ 0x80000000


def _canonical_bound(q: float, dtype: np.dtype, side: str):
    """Tightest ``dtype`` value usable for an exact float64-query compare.

    ``side == "hi"`` (tests ``v <= q``): the largest dtype value ``<= q``;
    ``side == "lo"`` (tests ``v >= q``): the smallest dtype value ``>= q``.
    Zeros canonicalize to the extreme key of the {-0.0, +0.0} equivalence
    class so key-space compares match float compares. Returns None for NaN.
    """
    q = float(q)
    if math.isnan(q):
        return None
    if np.dtype(dtype).itemsize == 4:
        with np.errstate(over="ignore"):  # out-of-range bounds round to ±inf
            qf = np.float32(q)
        # compare in float64 explicitly: NEP 50 would weakly demote the
        # Python float to float32 and the tightening would never fire
        if side == "hi" and float(qf) > q:
            qf = np.nextafter(qf, np.float32(-np.inf))
        elif side == "lo" and float(qf) < q:
            qf = np.nextafter(qf, np.float32(np.inf))
        q = float(qf)
        one = np.float32
    else:
        one = np.float64
    if q == 0.0:
        q = 0.0 if side == "hi" else -0.0
    return one(q)


def bbox_query_keys(bbox, dtype: np.dtype) -> np.ndarray | None:
    """Query bbox -> (4, 2) uint32 key limbs ``[(lo, hi) for x0, x1, y0, y1]``.

    Bounds are canonicalized per coordinate dtype (float32 bounds round to
    the tightest representable value, zeros pick the matching signed zero)
    so the device key compare is *exactly* the host float compare. Returns
    None when the bbox is empty under the shared canonicalization rule
    (:func:`repro.core.filters.canonical_bbox`: NaN bound or inverted
    extent) — the host test then keeps no record, matching the shard- and
    page-level pruning answer for the same bbox.
    """
    from repro.core.filters import canonical_bbox

    bbox = canonical_bbox(bbox)
    if bbox is None:
        return None
    qx0, qy0, qx1, qy1 = bbox
    vals = (
        _canonical_bound(qx0, dtype, "lo"),
        _canonical_bound(qx1, dtype, "hi"),
        _canonical_bound(qy0, dtype, "lo"),
        _canonical_bound(qy1, dtype, "hi"),
    )
    if any(v is None for v in vals):
        return None
    keys = [float_order_key_np(v, dtype) for v in (vals[0], vals[1], vals[2], vals[3])]
    return np.array(keys, dtype=np.uint32)


def stack_bbox_query_keys(bboxes, dtype: np.dtype) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-query bbox key limbs for a multi-query refine launch.

    Returns ``(keys, valid)``: ``keys`` is ``(Q, 4, 2)`` uint32 (row q is
    :func:`bbox_query_keys` of ``bboxes[q]``), ``valid`` is ``(Q,)`` bool.
    A NaN-bound bbox gets a zero key row and ``valid[q] = False`` — the host
    keeps no record for it, so the multi-query refine masks that row out
    after the launch instead of fencing it in key space.
    """
    keys = np.zeros((len(bboxes), 4, 2), np.uint32)
    valid = np.zeros(len(bboxes), bool)
    for q, bbox in enumerate(bboxes):
        k = bbox_query_keys(bbox, dtype)
        if k is not None:
            keys[q] = k
            valid[q] = True
    return keys, valid


def inf_keys(width: int) -> tuple[tuple[int, int], tuple[int, int]]:
    """Order keys of (-inf, +inf) as ((lo, hi), (lo, hi)) for NaN fencing."""
    dtype = np.float32 if width == 32 else np.float64
    return (float_order_key_np(-np.inf, dtype), float_order_key_np(np.inf, dtype))

"""Oracle for the page min/max statistics kernel (paper §4 index build).

Input: (n_pages, page_size) float32 column values.
Output: (n_pages,) mins and (n_pages,) maxes — the per-page [min, max]
statistics that *are* the light-weight spatial index.
"""

from __future__ import annotations

import jax.numpy as jnp


def minmax_ref(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    return jnp.min(x, axis=1), jnp.max(x, axis=1)

"""Wrapper for the page-statistics kernel: ragged pages, padding, dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import kernel, ref
from .kernel import _TILE


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def page_minmax(
    x: jnp.ndarray, *, use_pallas: bool = True, interpret: bool | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(n_pages, page_size) -> per-page (min, max); pads to the VMEM tile."""
    x = jnp.asarray(x)
    n_pages, page_size = x.shape
    pad = (-page_size) % _TILE
    if pad:
        x = jnp.concatenate([x, jnp.broadcast_to(x[:, -1:], (n_pages, pad))], axis=1)
    if not use_pallas:
        return jax.jit(ref.minmax_ref)(x)
    interp = _default_interpret() if interpret is None else interpret
    return kernel.minmax(x, interpret=interp)


def column_page_stats(values: np.ndarray, page_bounds: np.ndarray, **kw):
    """Ragged host entry: per-page stats for record-aligned page bounds.

    Used as the accelerated index-build path; equals what the writer computes
    per page on the host.
    """
    values = np.asarray(values, dtype=np.float32)
    out_min, out_max = [], []
    for i in range(len(page_bounds) - 1):
        chunk = values[page_bounds[i] : page_bounds[i + 1]]
        if not len(chunk):
            out_min.append(np.inf)
            out_max.append(-np.inf)
            continue
        pad = (-len(chunk)) % _TILE
        padded = np.concatenate([chunk, np.repeat(chunk[-1:], pad)]) if pad else chunk
        mn, mx = page_minmax(padded.reshape(1, -1), **kw)
        out_min.append(float(mn[0]))
        out_max.append(float(mx[0]))
    return np.array(out_min), np.array(out_max)

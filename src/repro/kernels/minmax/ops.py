"""Wrappers for the page-statistics kernels: ragged pages, padding, dispatch.

``column_page_stats`` is fully batched: ragged record-aligned pages are
padded edge-value style into one ``(n_pages, max_len)`` matrix and reduced in
a **single** ``page_minmax`` launch (the per-page Python loop of earlier
revisions launched the kernel once per page). Edge padding keeps per-page
results identical to the loop; empty pages are patched to ``(+inf, -inf)``
on the host afterwards.

``segment_minmax`` dispatches the segmented per-record min/max scan (order
keys, see ref.py) between the Pallas block kernel and the flat jnp oracle —
the reduction stage of ``repro.kernels.fp_delta.decode_refine_stream``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import kernel, ref
from .kernel import _TILE, SEG_BLOCK


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def page_minmax(
    x: jnp.ndarray, *, use_pallas: bool = True, interpret: bool | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(n_pages, page_size) -> per-page (min, max); pads to the VMEM tile."""
    x = jnp.asarray(x)
    n_pages, page_size = x.shape
    pad = (-page_size) % _TILE
    if pad:
        x = jnp.concatenate([x, jnp.broadcast_to(x[:, -1:], (n_pages, pad))], axis=1)
    if not use_pallas:
        return jax.jit(ref.minmax_ref)(x)
    interp = _default_interpret() if interpret is None else interpret
    return kernel.minmax(x, interpret=interp)


# dense-batch element budget of column_page_stats (float32 elements, 64 MiB):
# bounds the padded (rows, max_len) matrix so one outlier-long page cannot
# inflate the whole batch to n_pages * max_len
_BATCH_BUDGET = 1 << 24


def _batch_spans(counts: np.ndarray):
    """Split pages into contiguous row spans with rows * running_max under
    the budget (a skewed giant page lands in its own span)."""
    spans = []
    start, mx = 0, 1
    for i, c in enumerate(counts):
        mx_new = max(mx, int(c), 1)
        if i > start and (i + 1 - start) * mx_new > _BATCH_BUDGET:
            spans.append((start, i))
            start, mx = i, max(int(c), 1)
        else:
            mx = mx_new
    spans.append((start, len(counts)))
    return spans


def column_page_stats(values: np.ndarray, page_bounds: np.ndarray, **kw):
    """Ragged host entry: per-page stats for record-aligned page bounds.

    Used as the accelerated index-build path; equals what the writer computes
    per page on the host. One batched launch for the whole column (typical
    layouts): pages are edge-padded to the longest page — padding with a
    page's own last value changes neither its min nor its max — and empty
    pages patched to ``(+inf, -inf)`` afterwards. Heavily skewed page sizes
    split into a few budget-bounded launches instead of one dense matrix.
    """
    values = np.asarray(values, dtype=np.float32)
    bounds = np.asarray(page_bounds, dtype=np.int64)
    counts = np.diff(bounds)
    n_pages = len(counts)
    if n_pages == 0:
        return np.zeros(0), np.zeros(0)
    empty = counts == 0
    out_min = np.full(n_pages, np.inf)
    out_max = np.full(n_pages, -np.inf)
    if len(values) == 0 or empty.all():
        return out_min, out_max
    for lo, hi in _batch_spans(counts):
        c = counts[lo:hi]
        max_len = max(int(c.max()), 1)
        # int32 positions + in-place clip keep the gather-index temporaries
        # within a small constant factor of the float32 batch itself
        pos = np.minimum(np.arange(max_len, dtype=np.int32)[None, :],
                         np.maximum(c - 1, 0).astype(np.int32)[:, None])
        idx = bounds[lo:hi, None] + pos
        np.minimum(idx, len(values) - 1, out=idx)
        batch = values[idx]
        mn, mx = page_minmax(jnp.asarray(batch), **kw)
        out_min[lo:hi] = np.asarray(mn)
        out_max[lo:hi] = np.asarray(mx)
    out_min[empty] = np.inf
    out_max[empty] = -np.inf
    return out_min, out_max


def column_page_stats_ex(values: np.ndarray, page_bounds: np.ndarray, **kw):
    """NaN-aware per-page stats for any numeric dtype: (vmin, vmax, nnan).

    ``vmin``/``vmax`` are the per-page extrema over *non-NaN* values in the
    column's own dtype (``(+inf, -inf)`` for pages with none — empty or
    all-NaN), ``nnan`` the per-page NaN count. float32 columns reduce
    through the batched :func:`page_minmax` launch (the cast in
    :func:`column_page_stats` is exact for them); wider/integer dtypes use
    an exact host segmented reduction, since a float32 round-trip could
    move a bound across a value and make pruning unsound.
    """
    values = np.asarray(values)
    bounds = np.asarray(page_bounds, dtype=np.int64)
    counts = np.diff(bounds)
    n_pages = len(counts)
    if n_pages == 0:
        return np.zeros(0), np.zeros(0), np.zeros(0, np.int64)
    if values.dtype.kind == "f" and np.isnan(values).any():
        csum = np.concatenate([[0], np.cumsum(np.isnan(values), dtype=np.int64)])
        nnan = csum[bounds[1:]] - csum[bounds[:-1]]
    else:
        nnan = np.zeros(n_pages, np.int64)
    out_min = np.full(n_pages, np.inf)
    out_max = np.full(n_pages, -np.inf)
    if values.dtype == np.float32:
        mn, mx = column_page_stats(values, bounds, **kw)
        out_min, out_max = np.asarray(mn), np.asarray(mx)
        # jnp.min propagates NaN; recompute NaN-carrying pages exactly
        for i in np.flatnonzero((nnan > 0) & (nnan < counts)):
            v = values[bounds[i]:bounds[i + 1]]
            out_min[i], out_max[i] = np.fmin.reduce(v), np.fmax.reduce(v)
        all_nan = nnan == counts
        out_min[all_nan], out_max[all_nan] = np.inf, -np.inf
        return out_min, out_max, nnan
    nonempty = np.flatnonzero(counts > 0)
    if len(nonempty):
        # reduceat over non-empty page starts: skipped empty pages contribute
        # zero elements, so each segment reduces exactly one page; fmin/fmax
        # skip NaNs (all-NaN segments yield NaN, patched below)
        starts = bounds[:-1][nonempty]
        mn = np.fmin.reduceat(values, starts)
        mx = np.fmax.reduceat(values, starts)
        out_min[nonempty] = mn
        out_max[nonempty] = mx
        all_nan = nnan == counts
        out_min[all_nan], out_max[all_nan] = np.inf, -np.inf
    return out_min, out_max, nnan


def segment_minmax(key_lo, key_hi, flag, *, use_pallas: bool = True,
                   interpret: bool | None = None):
    """Segmented running min/max over order-key limbs.

    Inputs shaped ``(n_blocks, SEG_BLOCK)`` int32 (flags: 1 at segment
    starts; padding tail must be flagged). Returns four flattened uint32
    arrays ``(min_lo, min_hi, max_lo, max_hi)``; the value at a segment's
    last position is the segment's reduction. jit-safe (used inside the
    fused decode→refine launch chain).
    """
    if not use_pallas:
        return ref.segment_minmax_ref(key_lo, key_hi, flag)
    interp = _default_interpret() if interpret is None else interpret
    return kernel.segminmax_blocks(key_lo, key_hi, flag, interpret=interp)

"""Pallas TPU kernels: per-page [min,max] statistics (paper §4 index build)
and the segmented per-record min/max scan of the fused decode→refine path.

``minmax``: grid is (n_pages, page_tiles): the page dimension is parallel,
the tile dimension is sequential with VMEM scratch accumulation — pages of
any size stream through a fixed (8, 128)-aligned VMEM tile, so the working
set is constant regardless of page size.

``segminmax_blocks``: the record-granular sibling, structured exactly like
the page-stream decode kernel in ``repro.kernels.fp_delta``: each grid step
runs a block-local segmented min/max scan (log-step shifted combines on the
VPU) over one block of 1024 order-key limb pairs; cross-block carries are
stitched afterwards with one tiny associative scan over per-block summaries,
keeping the grid embarrassingly parallel. The scan state per element is
``(min_lo, min_hi, max_lo, max_hi, seen_flag)`` with lexicographic uint32
limb compares — see ref.py for the order-key math and the flat oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _MAX_IDENT, _MIN_IDENT, minmax_seg_combine, segmented_minmax_scan

_TILE = 2048  # values per grid step; multiple of (8, 128)

SEG_BLOCK = 1024  # values per grid step of the segmented scan, one VPU tile
_BLOCK_2D = (8, 128)


def _minmax_kernel(x_ref, min_ref, max_ref):
    t = pl.program_id(1)
    x = x_ref[...]
    tile_min = jnp.min(x)
    tile_max = jnp.max(x)

    @pl.when(t == 0)
    def _init():
        min_ref[0, 0] = tile_min
        max_ref[0, 0] = tile_max

    @pl.when(t > 0)
    def _acc():
        min_ref[0, 0] = jnp.minimum(min_ref[0, 0], tile_min)
        max_ref[0, 0] = jnp.maximum(max_ref[0, 0], tile_max)


@functools.partial(jax.jit, static_argnames=("interpret",))
def minmax(x: jnp.ndarray, *, interpret: bool = True):
    """x: (n_pages, page_size) -> ((n_pages,) min, (n_pages,) max).

    page_size must be a multiple of _TILE; ops.py pads with edge values.
    """
    n_pages, page_size = x.shape
    assert page_size % _TILE == 0, page_size
    tiles = page_size // _TILE
    mins, maxs = pl.pallas_call(
        _minmax_kernel,
        grid=(n_pages, tiles),
        in_specs=[pl.BlockSpec((1, _TILE), lambda p, t: (p, t))],
        out_specs=[
            pl.BlockSpec((1, 1), lambda p, t: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, t: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pages, 1), x.dtype),
            jax.ShapeDtypeStruct((n_pages, 1), x.dtype),
        ],
        interpret=interpret,
    )(x)
    return mins[:, 0], maxs[:, 0]


# ---------------------------------------------------------- segmented minmax
def _segminmax_kernel(klo_ref, khi_ref, flag_ref,
                      mnlo_ref, mnhi_ref, mxlo_ref, mxhi_ref, seen_ref):
    klo = klo_ref[...].reshape(SEG_BLOCK).astype(jnp.uint32)
    khi = khi_ref[...].reshape(SEG_BLOCK).astype(jnp.uint32)
    flag = flag_ref[...].reshape(SEG_BLOCK) != 0
    mnlo, mnhi, mxlo, mxhi, seen = segmented_minmax_scan(klo, khi, flag)
    mnlo_ref[...] = mnlo.astype(jnp.int32).reshape(1, *_BLOCK_2D)
    mnhi_ref[...] = mnhi.astype(jnp.int32).reshape(1, *_BLOCK_2D)
    mxlo_ref[...] = mxlo.astype(jnp.int32).reshape(1, *_BLOCK_2D)
    mxhi_ref[...] = mxhi.astype(jnp.int32).reshape(1, *_BLOCK_2D)
    seen_ref[...] = seen.astype(jnp.int32).reshape(1, *_BLOCK_2D)


@functools.partial(jax.jit, static_argnames=("interpret",))
def segminmax_blocks(key_lo, key_hi, flag, *, interpret: bool = True):
    """Batched segmented min/max over order keys (one launch per stream).

    ``key_lo``/``key_hi``: (n_blocks, SEG_BLOCK) int32 order-key limbs;
    ``flag``: (n_blocks, SEG_BLOCK) int32, 1 at segment starts (padding tail
    elements must be flagged so they cannot leak into real segments).
    Returns ``(min_lo, min_hi, max_lo, max_hi)`` uint32 arrays flattened to
    (n_blocks*SEG_BLOCK,): the inclusive segmented scan, so the value at a
    segment's last position is that segment's reduction. Bit-identical to
    ``ref.segment_minmax_ref``.
    """
    n_blocks = key_lo.shape[0]
    kl = key_lo.reshape(n_blocks, *_BLOCK_2D)
    kh = key_hi.reshape(n_blocks, *_BLOCK_2D)
    fl = flag.reshape(n_blocks, *_BLOCK_2D)
    spec = pl.BlockSpec((1, *_BLOCK_2D), lambda b: (b, 0, 0))
    shape = jax.ShapeDtypeStruct((n_blocks, *_BLOCK_2D), jnp.int32)
    outs = pl.pallas_call(
        _segminmax_kernel,
        grid=(n_blocks,),
        in_specs=[spec, spec, spec],
        out_specs=[spec] * 5,
        out_shape=[shape] * 5,
        interpret=interpret,
    )(kl, kh, fl)
    mnlo, mnhi, mxlo, mxhi = (
        o.reshape(n_blocks, SEG_BLOCK).astype(jnp.uint32) for o in outs[:4]
    )
    seen = outs[4].reshape(n_blocks, SEG_BLOCK) != 0
    # Carry stitch: block b inherits the running min/max of the last open
    # segment before it — an exclusive segmented combine of the per-block
    # summaries (each block's last scanned element + "block saw a flag").
    summ = (mnlo[:, -1], mnhi[:, -1], mxlo[:, -1], mxhi[:, -1], seen[:, -1])
    inc = jax.lax.associative_scan(minmax_seg_combine, summ)
    ident = (
        jnp.full(1, _MIN_IDENT, jnp.uint32), jnp.full(1, _MIN_IDENT, jnp.uint32),
        jnp.full(1, _MAX_IDENT, jnp.uint32), jnp.full(1, _MAX_IDENT, jnp.uint32),
        jnp.zeros(1, jnp.bool_),
    )
    carry = tuple(
        jnp.concatenate([i, s[:-1]])[:, None] for i, s in zip(ident, inc)
    )
    local = (mnlo, mnhi, mxlo, mxhi, seen)
    fin = minmax_seg_combine(carry, local)
    return tuple(f.reshape(-1) for f in fin[:4])

"""Pallas TPU kernel: per-page [min,max] statistics (paper §4 index build).

Grid is (n_pages, page_tiles): the page dimension is parallel, the tile
dimension is sequential with VMEM scratch accumulation — pages of any size
stream through a fixed (8, 128)-aligned VMEM tile, so the working set is
constant regardless of page size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TILE = 2048  # values per grid step; multiple of (8, 128)


def _minmax_kernel(x_ref, min_ref, max_ref):
    t = pl.program_id(1)
    x = x_ref[...]
    tile_min = jnp.min(x)
    tile_max = jnp.max(x)

    @pl.when(t == 0)
    def _init():
        min_ref[0, 0] = tile_min
        max_ref[0, 0] = tile_max

    @pl.when(t > 0)
    def _acc():
        min_ref[0, 0] = jnp.minimum(min_ref[0, 0], tile_min)
        max_ref[0, 0] = jnp.maximum(max_ref[0, 0], tile_max)


@functools.partial(jax.jit, static_argnames=("interpret",))
def minmax(x: jnp.ndarray, *, interpret: bool = True):
    """x: (n_pages, page_size) -> ((n_pages,) min, (n_pages,) max).

    page_size must be a multiple of _TILE; ops.py pads with edge values.
    """
    n_pages, page_size = x.shape
    assert page_size % _TILE == 0, page_size
    tiles = page_size // _TILE
    mins, maxs = pl.pallas_call(
        _minmax_kernel,
        grid=(n_pages, tiles),
        in_specs=[pl.BlockSpec((1, _TILE), lambda p, t: (p, t))],
        out_specs=[
            pl.BlockSpec((1, 1), lambda p, t: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, t: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pages, 1), x.dtype),
            jax.ShapeDtypeStruct((n_pages, 1), x.dtype),
        ],
        interpret=interpret,
    )(x)
    return mins[:, 0], maxs[:, 0]

from .ops import column_page_stats, page_minmax
from .ref import minmax_ref

__all__ = ["page_minmax", "column_page_stats", "minmax_ref"]

from .kernel import SEG_BLOCK
from .ops import column_page_stats, column_page_stats_ex, page_minmax, segment_minmax
from .ref import (
    bbox_query_keys,
    float_order_key_np,
    float_order_keys,
    inf_keys,
    lex_ge,
    lex_gt,
    lex_le,
    minmax_ref,
    segment_minmax_ref,
    stack_bbox_query_keys,
)

__all__ = [
    "page_minmax",
    "column_page_stats",
    "column_page_stats_ex",
    "segment_minmax",
    "segment_minmax_ref",
    "minmax_ref",
    "float_order_keys",
    "float_order_key_np",
    "bbox_query_keys",
    "stack_bbox_query_keys",
    "inf_keys",
    "lex_gt",
    "lex_le",
    "lex_ge",
    "SEG_BLOCK",
]

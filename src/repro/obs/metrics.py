"""Metrics registry: counters, gauges, fixed-bucket latency histograms.

The registry turns the stack's terminal totals (:class:`ReadStats`,
:class:`SourceStats`) and its per-event timings (range-GET latency, scan
latency) into queryable time series:

* :class:`Counter` — monotonic totals (``read.retries``,
  ``pruned.shard_bytes``, ``jit.compiles``);
* :class:`Gauge` — last-written values (``scan.host_cpu_s_per_gb``);
* :class:`Histogram` — fixed-bucket distributions with interpolated
  p50/p90/p99 estimates (``scan.latency_s``, ``io.range_get_s``). Buckets
  are log-spaced by default so the relative quantile error is bounded by
  one bucket ratio (~12% with the default 200 buckets over [1e-7, 1e3] s);
  exact observed min/max clamp the tails.

``fold_read_stats`` / ``fold_source_stats`` lift every numeric field of a
stats object into same-named counters, so recoveries (retries, timeouts,
checksum failures, cache hits) accumulate across queries instead of dying
with each returned stats value. All classes are thread-safe (the scanner
folds from worker threads).
"""

from __future__ import annotations

import threading
from dataclasses import fields as _dc_fields, is_dataclass as _is_dataclass

import numpy as np

DEFAULT_QUANTILES = (0.5, 0.9, 0.99)


def log_buckets(lo: float = 1e-7, hi: float = 1e3, n: int = 200) -> np.ndarray:
    """Log-spaced bucket edges (n buckets => n+1 edges)."""
    return np.geomspace(lo, hi, int(n) + 1)


class Counter:
    """A monotonic (well, additive) counter."""

    __slots__ = ("name", "_lock", "value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """A last-write-wins value."""

    __slots__ = ("name", "_lock", "value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self.value = None

    def set(self, v) -> None:
        with self._lock:
            self.value = v


class Histogram:
    """Fixed-bucket histogram with interpolated quantile estimates.

    ``bounds`` are the bucket *edges* (ascending); observations below the
    first or at/above the last edge land in dedicated under/overflow
    buckets whose quantile bounds are clamped to the exact observed
    min/max, so tail estimates never extrapolate past real data.
    """

    __slots__ = ("name", "bounds", "_counts", "_lock",
                 "count", "sum", "min", "max")

    def __init__(self, name: str, bounds=None):
        self.name = name
        self.bounds = np.asarray(
            log_buckets() if bounds is None else bounds, np.float64)
        if len(self.bounds) < 2 or np.any(np.diff(self.bounds) <= 0):
            raise ValueError("histogram bounds must be ascending, >= 2 edges")
        # index 0 = underflow, 1..m-1 = buckets, m = overflow
        self._counts = np.zeros(len(self.bounds) + 1, np.int64)
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        i = int(np.searchsorted(self.bounds, v, side="right"))
        with self._lock:
            self._counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def _bucket_bounds(self, i: int) -> tuple[float, float]:
        m = len(self.bounds)
        lo = self.min if i == 0 else self.bounds[i - 1]
        hi = self.max if i == m else self.bounds[i]
        lo = max(float(lo), self.min)
        hi = min(float(hi), self.max)
        return lo, max(hi, lo)

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (linear interpolation within the bucket).

        Bucket counts accumulate in an exact Python int (int/float compares
        are exact in Python): a float accumulator would drift past
        ``target`` once totals exceed 2**53 and fall through to the max.
        q=0 and q=1 return the exact observed extremes.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            total = self.count
            if total == 0:
                return float("nan")
            counts = self._counts.copy()
        if q == 0.0:
            return float(self.min)
        if q == 1.0:
            return float(self.max)
        target = q * total
        cum = 0
        for i, c in enumerate(counts):
            c = int(c)
            if c == 0:
                continue
            if cum + c >= target:
                lo, hi = self._bucket_bounds(i)
                frac = min(1.0, max(0.0, (target - cum) / c))
                return float(lo + frac * (hi - lo))
            cum += c
        return float(self.max)

    def percentiles(self, qs=DEFAULT_QUANTILES) -> dict[str, float]:
        return {f"p{int(q * 100)}": self.quantile(q) for q in qs}

    def snapshot(self) -> dict:
        with self._lock:
            count, total = self.count, self.sum
            mn = self.min if count else None
            mx = self.max if count else None
        out = {"count": count, "sum": total, "min": mn, "max": mx}
        if count:
            out.update(self.percentiles())
        return out


class MetricsRegistry:
    """Named counters/gauges/histograms, created on first touch."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str, bounds=None) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, bounds)
            return h

    # ------------------------------------------------------------- stats fold
    def fold_stats(self, stats, prefix: str) -> None:
        """Add every integer field of a stats dataclass into counters named
        ``{prefix}.{field}`` (duck-typed: works for ReadStats, SourceStats,
        and anything shaped like them)."""
        if _is_dataclass(stats):
            names = [f.name for f in _dc_fields(stats)]
        else:
            names = list(vars(stats))
        for name in names:
            v = getattr(stats, name)
            if isinstance(v, bool):
                continue
            if isinstance(v, (int, np.integer)):
                self.counter(f"{prefix}.{name}").inc(int(v))
            elif isinstance(v, list):  # ReadStats.failures
                self.counter(f"{prefix}.{name}").inc(len(v))

    def fold_read_stats(self, stats, prefix: str = "read") -> None:
        self.fold_stats(stats, prefix)

    def fold_source_stats(self, stats, prefix: str = "io") -> None:
        self.fold_stats(stats, prefix)

    # -------------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.snapshot() for k, h in sorted(hists.items())},
        }

"""repro.obs — scan telemetry: trace spans, metrics, Perfetto export.

One switch controls the whole subsystem::

    from repro import obs

    tracer = obs.enable()                       # fresh tracer + registry
    geo, extras, stats = scanner.scan(bbox=b, refine=True, device="jax")
    obs.disable()
    tracer.export("scan_trace.json", metrics=obs.snapshot())

Instrumented code calls the module-level helpers (:func:`span`,
:func:`instant`, :func:`count`, :func:`gauge`, :func:`observe`,
:func:`timed`, :func:`submit`, :func:`fold_read_stats`). **When disabled
(the default) every helper compiles down to one global check**: ``span`` /
``timed`` return the shared :data:`~repro.obs.trace.NULL_SPAN` singleton (no
object is allocated, ever), the recorders return immediately, and
:func:`submit` is a plain ``pool.submit`` — the read path's results and
syscall sequence are bit-identical with tracing on or off (enforced by
``tests/test_obs.py``).

Span context crosses threads explicitly: :func:`submit` wraps the worker
callable in ``contextvars.copy_context().run`` so spans opened on scanner
workers / the reader's prefetch thread parent under the span open at submit
time. This module imports only the stdlib + numpy — the kernels, I/O layer
and reader can all use it without dependency cycles.
"""

from __future__ import annotations

import contextvars
import time
from contextlib import contextmanager

from .metrics import (
    DEFAULT_QUANTILES,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    log_buckets,
)
from .trace import NULL_SPAN, NullSpan, Span, Tracer, current_span

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NullSpan", "Span",
    "Tracer", "NULL_SPAN", "DEFAULT_QUANTILES", "log_buckets",
    "current_span", "enabled", "enable", "disable", "trace", "get_tracer",
    "get_registry", "span", "instant", "count", "gauge", "observe", "timed",
    "submit", "fold_read_stats", "fold_source_stats", "snapshot",
    "percentiles",
]

_enabled: bool = False
_tracer: Tracer | None = None
_registry: MetricsRegistry | None = None


def enabled() -> bool:
    """Is telemetry collection on?"""
    return _enabled


def enable(*, reset: bool = True) -> Tracer:
    """Turn tracing + metrics on; returns the active tracer.

    ``reset=True`` (default) starts a fresh tracer and registry;
    ``reset=False`` resumes accumulating into the existing ones.
    """
    global _enabled, _tracer, _registry
    if reset or _tracer is None:
        _tracer = Tracer()
    if reset or _registry is None:
        _registry = MetricsRegistry()
    _enabled = True
    return _tracer


def disable() -> None:
    """Turn collection off. The tracer/registry stay readable (export,
    snapshot) until the next ``enable()``."""
    global _enabled
    _enabled = False


@contextmanager
def trace(export_path=None):
    """Enable telemetry for a block; yields the tracer, disables on exit.

    ``export_path`` additionally writes the Chrome trace JSON (with the
    metrics snapshot embedded) when the block closes.
    """
    tracer = enable()
    try:
        yield tracer
    finally:
        disable()
        if export_path is not None:
            tracer.export(export_path, metrics=snapshot())


def get_tracer() -> Tracer:
    global _tracer
    if _tracer is None:
        _tracer = Tracer()
    return _tracer


def get_registry() -> MetricsRegistry:
    global _registry
    if _registry is None:
        _registry = MetricsRegistry()
    return _registry


# ---------------------------------------------------------------- hot-path API
def span(name: str, cat: str = "scan", **args):
    """A ``with``-able span; the shared no-op singleton when disabled."""
    if not _enabled:
        return NULL_SPAN
    return Span(_tracer, name, cat, args)


def instant(name: str, cat: str = "event", **args) -> None:
    """Record a point event (retry, skip, backoff …); no-op when disabled."""
    if _enabled:
        _tracer.instant(name, cat, **args)


def count(name: str, n: int = 1) -> None:
    if _enabled:
        _registry.counter(name).inc(n)


def gauge(name: str, value) -> None:
    if _enabled:
        _registry.gauge(name).set(value)


def observe(name: str, value: float, bounds=None) -> None:
    if _enabled:
        _registry.histogram(name, bounds).observe(value)


class _Timed:
    """Times a block into a histogram (only built when telemetry is on)."""

    __slots__ = ("_name", "_t0")

    def __init__(self, name: str):
        self._name = name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        observe(self._name, time.perf_counter() - self._t0)
        return False


def timed(name: str):
    """``with obs.timed("io.read_s"):`` — histogram-observed duration."""
    if not _enabled:
        return NULL_SPAN
    return _Timed(name)


def submit(pool, fn, /, *args, **kwargs):
    """``pool.submit`` carrying the current span context into the worker.

    ``contextvars`` do not propagate across ``ThreadPoolExecutor``
    boundaries on their own; each submission gets its own context copy (a
    single copy cannot be entered concurrently from several threads). When
    disabled this is exactly ``pool.submit(fn, *args)``.
    """
    if not _enabled:
        return pool.submit(fn, *args, **kwargs)
    return pool.submit(contextvars.copy_context().run, fn, *args, **kwargs)


def fold_read_stats(stats, prefix: str = "read") -> None:
    """Fold a finished query's ReadStats into cumulative counters."""
    if _enabled:
        _registry.fold_read_stats(stats, prefix)


def fold_source_stats(stats, prefix: str = "io") -> None:
    """Fold a SourceStats account (e.g. a failed shard attempt's partial
    deltas) into cumulative counters."""
    if _enabled:
        _registry.fold_source_stats(stats, prefix)


def percentiles(name: str, qs=DEFAULT_QUANTILES) -> dict:
    """Interpolated percentiles of a named histogram (``{"p50": ..., ...}``);
    empty when the histogram has no observations or telemetry was never
    enabled. The serve tier reads its p50/p99 from here."""
    if _registry is None:
        return {}
    h = _registry.histogram(name)
    if h.count == 0:
        return {}
    return h.percentiles(qs)


def snapshot() -> dict:
    """The metrics registry snapshot (empty shape when never enabled)."""
    if _registry is None:
        return {"counters": {}, "gauges": {}, "histograms": {}}
    return _registry.snapshot()

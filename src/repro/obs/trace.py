"""Structured tracing: thread-aware spans, Chrome trace-event export.

A :class:`Span` is one timed stage of the scan pipeline (``plan``, ``fetch``,
``decode``, ``refine``, ``transfer`` …) with structured attributes
(``shard=``, ``rg=``). The *current* span is carried in a
:data:`contextvars.ContextVar` rather than a ``threading.local`` so an open
span stack can be handed across threads explicitly: wrap the worker callable
in ``contextvars.copy_context().run`` (what :func:`repro.obs.submit` does)
and spans opened on the worker thread parent correctly under the span that
was open at submit time — the scanner's shard fan-out and the reader's
prefetch thread both use this.

The recorded events are Chrome trace-event JSON (the ``traceEvents`` array
form), loadable in Perfetto / ``chrome://tracing`` as-is:

* spans → complete events (``"ph": "X"``) with microsecond ``ts``/``dur``,
  the real OS thread id as ``tid``, and ``args`` carrying the structured
  attributes plus ``span_id``/``parent_id`` (explicit nesting, robust across
  thread hand-offs where timestamp containment alone is ambiguous);
* :meth:`Tracer.instant` → instant events (``"ph": "i"``) for point
  occurrences (a retry, a backoff, a skipped shard);
* thread names → ``"ph": "M"`` ``thread_name`` metadata events.

This module holds no global state and imports only the stdlib; the enabled
flag and the no-op fast path live in :mod:`repro.obs`.
"""

from __future__ import annotations

import contextvars
import itertools
import json
import os
import threading
import time


class NullSpan:
    """The disabled-tracing span: one shared, allocation-free no-op.

    ``repro.obs.span(...)`` returns this singleton whenever tracing is off,
    so the instrumented hot paths allocate nothing and execute only an
    attribute load, a truthiness check and two no-op method calls per
    ``with`` block.
    """

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def add(self, **args):
        return self


NULL_SPAN = NullSpan()

# the innermost open span of the current context (thread *or* an explicit
# copy_context hand-off into a worker thread)
_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_current_span", default=None
)

_SPAN_IDS = itertools.count(1)


def current_span():
    """The innermost open span of this context (None outside any span)."""
    return _CURRENT.get()


class Span:
    """One timed, attributed stage; records itself on ``__exit__``."""

    __slots__ = ("tracer", "name", "cat", "args", "span_id", "parent_id",
                 "_t0", "_token")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.span_id = next(_SPAN_IDS)
        self.parent_id = 0

    def __enter__(self):
        parent = _CURRENT.get()
        if parent is not None:
            self.parent_id = parent.span_id
        self._token = _CURRENT.set(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        _CURRENT.reset(self._token)
        self.tracer._complete(self, self._t0, t1 - self._t0)
        return False

    def add(self, **args):
        """Attach attributes discovered mid-span (e.g. survivor counts)."""
        self.args.update(args)
        return self


class Tracer:
    """Collects trace events; thread-safe; exports Chrome trace JSON."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._threads: dict[int, str] = {}
        self.epoch_ns = time.perf_counter_ns()
        self.pid = os.getpid()

    # ------------------------------------------------------------- recording
    def _tid(self) -> int:
        t = threading.current_thread()
        tid = t.ident or 0
        if tid not in self._threads:
            self._threads[tid] = t.name
        return tid

    def _complete(self, span: Span, t0_ns: int, dur_ns: int) -> None:
        ev = {
            "name": span.name,
            "cat": span.cat,
            "ph": "X",
            "ts": (t0_ns - self.epoch_ns) / 1000.0,
            "dur": dur_ns / 1000.0,
            "pid": self.pid,
            "tid": self._tid(),
            "args": dict(span.args, span_id=span.span_id,
                         parent_id=span.parent_id),
        }
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, cat: str = "event", **args) -> None:
        """Record a point event (``"ph": "i"``, thread-scoped)."""
        ev = {
            "name": name,
            "cat": cat,
            "ph": "i",
            "s": "t",
            "ts": (time.perf_counter_ns() - self.epoch_ns) / 1000.0,
            "pid": self.pid,
            "tid": self._tid(),
            "args": dict(args),
        }
        with self._lock:
            self._events.append(ev)

    # ------------------------------------------------------------ inspection
    @property
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def spans(self, name: str | None = None) -> list[dict]:
        """Completed span events, optionally filtered by name."""
        return [e for e in self.events
                if e["ph"] == "X" and (name is None or e["name"] == name)]

    def summary(self) -> list[dict]:
        """Wall-clock per stage: ``{name, count, total_ms, max_ms}`` rows,
        heaviest first. Nested spans overlap their parents by design — this
        is attribution, not a partition of the total."""
        agg: dict[str, dict] = {}
        for ev in self.events:
            if ev["ph"] != "X":
                continue
            row = agg.setdefault(
                ev["name"],
                {"name": ev["name"], "count": 0, "total_ms": 0.0, "max_ms": 0.0},
            )
            ms = ev["dur"] / 1000.0
            row["count"] += 1
            row["total_ms"] += ms
            row["max_ms"] = max(row["max_ms"], ms)
        return sorted(agg.values(), key=lambda r: -r["total_ms"])

    # ---------------------------------------------------------------- export
    def chrome_trace(self, metrics: dict | None = None) -> dict:
        """The trace as a Chrome trace-event JSON object.

        ``metrics`` (a :meth:`~repro.obs.metrics.MetricsRegistry.snapshot`)
        rides along under a top-level ``"metrics"`` key; Perfetto ignores
        unknown top-level keys, so the file stays loadable.
        """
        with self._lock:
            threads = dict(self._threads)
            events = list(self._events)
        meta = [
            {"name": "thread_name", "ph": "M", "pid": self.pid, "tid": tid,
             "args": {"name": name}}
            for tid, name in sorted(threads.items())
        ]
        doc: dict = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
        if metrics is not None:
            doc["metrics"] = metrics
        return doc

    def export(self, path, metrics: dict | None = None) -> str:
        """Write the Chrome trace JSON to ``path``; returns the path."""
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(metrics=metrics), fh, indent=1,
                      default=str)
            fh.write("\n")
        return str(path)

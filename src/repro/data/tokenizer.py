"""Geo tokenizer: coordinates -> discrete token streams for trajectory LMs.

Tokens are Z-order cells at a fixed grid order within a bounding box
(6 bits/axis by default => vocab 4096), so spatially-nearby points share
token prefixes — exactly the locality FP-delta exploits on the storage side.
Special tokens: 0=PAD, 1=BOS, 2=EOS (cell ids shift by 3).
"""

from __future__ import annotations

import numpy as np

from repro.core.sfc import quantize, z_key

PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 3


class GeoTokenizer:
    def __init__(self, bbox: tuple[float, float, float, float], order: int = 6):
        self.bbox = bbox
        self.order = order
        self.vocab = (1 << (2 * order)) + N_SPECIAL

    def encode_points(self, x: np.ndarray, y: np.ndarray) -> np.ndarray:
        xq = quantize(np.asarray(x, np.float64), self.bbox[0], self.bbox[2], self.order)
        yq = quantize(np.asarray(y, np.float64), self.bbox[1], self.bbox[3], self.order)
        return (z_key(xq, yq) + N_SPECIAL).astype(np.int32)

    def decode_tokens(self, tokens: np.ndarray) -> np.ndarray:
        """Token -> cell-center coordinates (lossy by construction).

        Inverts :func:`repro.core.sfc.quantize` (floor onto a 2^order-1
        lattice): cell q spans [q, q+1) * span/(2^order - 1)."""
        t = np.asarray(tokens, np.uint64) - N_SPECIAL
        xq = _compact_bits(t).astype(np.float64)
        yq = _compact_bits(t >> np.uint64(1)).astype(np.float64)
        n = (1 << self.order) - 1
        xs = self.bbox[0] + (xq + 0.5) / n * (self.bbox[2] - self.bbox[0])
        ys = self.bbox[1] + (yq + 0.5) / n * (self.bbox[3] - self.bbox[1])
        return np.stack([xs, ys], 1)

    def encode_trajectories(self, cols, max_len: int) -> np.ndarray:
        """GeometryColumns (trajectories) -> (n, max_len) int32 with BOS/EOS."""
        starts = cols.record_value_starts()
        counts = np.diff(np.append(starts, cols.n_values))
        toks = self.encode_points(cols.x, cols.y)
        n = cols.n_records
        out = np.full((n, max_len), PAD, np.int32)
        out[:, 0] = BOS
        for i in range(n):
            k = min(int(counts[i]), max_len - 2)
            out[i, 1 : 1 + k] = toks[starts[i] : starts[i] + k]
            out[i, 1 + k] = EOS
        return out


def _compact_bits(v: np.ndarray) -> np.ndarray:
    """Inverse of Morton spreading: gather every other bit."""
    v = v.astype(np.uint64) & np.uint64(0x5555555555555555)
    v = (v | (v >> np.uint64(1))) & np.uint64(0x3333333333333333)
    v = (v | (v >> np.uint64(2))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    v = (v | (v >> np.uint64(4))) & np.uint64(0x00FF00FF00FF00FF)
    v = (v | (v >> np.uint64(8))) & np.uint64(0x0000FFFF0000FFFF)
    v = (v | (v >> np.uint64(16))) & np.uint64(0x00000000FFFFFFFF)
    return v

"""Training data pipeline: Spatial Parquet data lake -> sharded token batches.

Flow: SpatialParquetReader (range-filter pushdown + page pruning, the paper's
§4 index in the serving path of training) -> GeoTokenizer -> fixed-length
sequence packing -> double-buffered prefetch thread -> per-step batches shaped
``(accum, micro_batch, seq)`` ready for ``jax.device_put`` under the batch
sharding.

Straggler mitigation (host level): the prefetch queue is bounded; if the
producer stalls past ``stall_timeout`` the consumer re-serves the previous
batch and increments a counter instead of blocking the whole step loop — on a
multi-host pod this is the difference between one slow VM and a global stall.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

from repro.core.reader import SpatialParquetReader

from .tokenizer import GeoTokenizer


class TrajectoryBatcher:
    """Packs tokenized trajectories into LM batches."""

    def __init__(self, files, tokenizer: GeoTokenizer, *, seq_len: int,
                 global_batch: int, accum: int = 1, bbox=None, seed: int = 0,
                 loop: bool = True):
        self.files = list(files)
        self.tok = tokenizer
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.accum = accum
        self.bbox = bbox
        self.rng = np.random.default_rng(seed)
        self.loop = loop

    def _token_stream(self):
        while True:
            order = self.rng.permutation(len(self.files))
            for fi in order:
                with SpatialParquetReader(self.files[fi]) as r:
                    # project to geometry only: skips decoding (and reading)
                    # every extra column the tokenizer never looks at
                    cols, _, _ = r.read_columnar(
                        bbox=self.bbox, refine=True, columns=("geometry",)
                    )
                    if cols is None or cols.n_records == 0:
                        continue
                    mat = self.tok.encode_trajectories(cols, self.seq_len)
                    for row in self.rng.permutation(len(mat)):
                        yield mat[row]
            if not self.loop:
                return

    def __iter__(self):
        stream = self._token_stream()
        mb = self.global_batch // self.accum
        while True:
            rows = []
            try:
                for _ in range(self.global_batch):
                    rows.append(next(stream))
            except StopIteration:
                return
            toks = np.stack(rows).reshape(self.accum, mb, self.seq_len)
            yield {"tokens": toks.astype(np.int32)}


class Prefetcher:
    """Bounded-queue background producer with stall skip-and-reuse."""

    def __init__(self, iterable, depth: int = 4, stall_timeout: float = 30.0):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = iter(iterable)
        self._done = object()
        self._last = None
        self.stalls = 0
        self.stall_timeout = stall_timeout
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        try:
            item = self._q.get(timeout=self.stall_timeout)
        except queue.Empty:
            if self._last is None:
                item = self._q.get()  # nothing to reuse yet: block
            else:
                self.stalls += 1
                return self._last
        if item is self._done:
            raise StopIteration
        self._last = item
        return item


def synthetic_token_iter(vocab: int, *, seq_len: int, global_batch: int,
                         accum: int = 1, seed: int = 0, family: str = "dense",
                         cfg=None):
    """Structured synthetic batches for benchmarks and per-arch smoke runs."""
    rng = np.random.default_rng(seed)
    mb = global_batch // accum
    while True:
        t = rng.integers(3, vocab, (accum, mb, 1), dtype=np.int64)
        seqs = [t]
        for _ in range(seq_len - 1):
            seqs.append((seqs[-1] * 31 + 7) % (vocab - 3) + 3)
        batch = {"tokens": np.concatenate(seqs, -1).astype(np.int32)}
        if cfg is not None and cfg.family == "encdec":
            batch["frames"] = rng.normal(
                0, 1, (accum, mb, seq_len // cfg.frontend_downsample,
                       cfg.frontend_dim or cfg.d_model)
            ).astype(np.float32)
        if cfg is not None and cfg.family == "vlm":
            batch["tokens"] = batch["tokens"][..., : seq_len - cfg.vision_tokens]
            batch["patches"] = rng.normal(
                0, 1, (accum, mb, cfg.vision_tokens, cfg.frontend_dim)
            ).astype(np.float32)
        yield batch

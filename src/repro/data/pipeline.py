"""Training data pipeline: Spatial Parquet data lake -> sharded token batches.

Flow: SpatialParquetReader (range-filter pushdown + page pruning, the paper's
§4 index in the serving path of training) -> GeoTokenizer -> fixed-length
sequence packing -> double-buffered prefetch thread -> per-step batches shaped
``(accum, micro_batch, seq)`` ready for ``jax.device_put`` under the batch
sharding.

Sources may be single ``.spqf`` files *or* sharded dataset directories
(``repro.dataset``): datasets are expanded to their shard files up front —
pruned by the batcher's bbox via the manifest's shard MBRs — so the epoch
permutation stripes over *shards*, not whole files. Smaller shuffle units
mean better mixing and a bounded working set per read.

Straggler mitigation (host level): the prefetch queue is bounded; if the
producer stalls past ``stall_timeout`` the consumer re-serves the previous
batch and increments a counter instead of blocking the whole step loop — on a
multi-host pod this is the difference between one slow VM and a global stall.
Producer *failures* are not stalls: a worker-thread exception is forwarded
through the queue and re-raised by ``__next__`` promptly, not after a
timeout, so a corrupt shard surfaces as the real error at the step loop.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

from repro.core.reader import SpatialParquetReader

from .tokenizer import GeoTokenizer


def expand_sources(sources, bbox=None) -> list[str]:
    """Expand dataset directories into shard file paths; files pass through.

    Shards of a dataset are pruned by ``bbox`` against the manifest MBRs
    (shard-level index) before any shard is opened; plain file paths are
    never pruned here — the reader's page index handles them.
    """
    from repro.dataset import SpatialDatasetScanner, is_dataset

    out: list[str] = []
    for src in sources:
        if is_dataset(src):
            out.extend(SpatialDatasetScanner(src).shard_paths(bbox))
        else:
            out.append(str(src))
    return out


class TrajectoryBatcher:
    """Packs tokenized trajectories into LM batches.

    ``device="jax"`` serves each shard read through the fused device scan
    (``read_columnar(device="jax", refine=True, keep_on_device=True)``):
    decode and bbox refinement run on the accelerator and the batcher
    receives device-resident :class:`~repro.core.columnar.DeviceCoords` —
    the only host materialization is the single survivor-coordinate
    transfer at tokenize time, so pruned records never cross the bus.
    Batches are bit-identical to the host path.
    """

    def __init__(self, files, tokenizer: GeoTokenizer, *, seq_len: int,
                 global_batch: int, accum: int = 1, bbox=None, seed: int = 0,
                 loop: bool = True, device: str = "cpu"):
        self.files = expand_sources(files, bbox)
        if not self.files:
            raise ValueError(
                "TrajectoryBatcher has no input shards/files"
                + (" (bbox pruned every shard)" if bbox is not None else "")
            )
        if device not in ("cpu", "jax"):
            raise ValueError(f"device must be 'cpu' or 'jax', got {device!r}")
        self.tok = tokenizer
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.accum = accum
        self.bbox = bbox
        self.rng = np.random.default_rng(seed)
        self.loop = loop
        self.device = device

    def _token_stream(self):
        device_kw = (
            {"device": "jax", "keep_on_device": True}
            if self.device == "jax" else {}
        )
        while True:
            order = self.rng.permutation(len(self.files))
            for fi in order:
                with SpatialParquetReader(self.files[fi]) as r:
                    # project to geometry only: skips decoding (and reading)
                    # every extra column the tokenizer never looks at
                    cols, _, _ = r.read_columnar(
                        bbox=self.bbox, refine=True, columns=("geometry",),
                        **device_kw,
                    )
                    if cols is None or cols.n_records == 0:
                        continue
                    # the zero-copy handoff boundary: device-resident columns
                    # materialize survivors exactly once, here
                    mat = self.tok.encode_trajectories(
                        cols.coords_to_host(), self.seq_len)
                    for row in self.rng.permutation(len(mat)):
                        yield mat[row]
            if not self.loop:
                return

    def __iter__(self):
        stream = self._token_stream()
        mb = self.global_batch // self.accum
        while True:
            rows = []
            try:
                for _ in range(self.global_batch):
                    rows.append(next(stream))
            except StopIteration:
                return
            toks = np.stack(rows).reshape(self.accum, mb, self.seq_len)
            yield {"tokens": toks.astype(np.int32)}


class _ProducerFailure:
    """In-queue envelope carrying a worker-thread exception to the consumer."""

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException):
        self.exc = exc


class Prefetcher:
    """Bounded-queue background producer with stall skip-and-reuse.

    Worker exceptions are delivered in-band (after any items already
    buffered) and re-raised by ``__next__`` as soon as they are dequeued —
    the consumer never waits out ``stall_timeout`` for a producer that is
    already dead, and the failure is never silently converted into an early
    ``StopIteration``.
    """

    def __init__(self, iterable, depth: int = 4, stall_timeout: float = 30.0):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = iter(iterable)
        self._done = object()
        self._last = None
        self._exc: BaseException | None = None
        self._finished = False
        self.stalls = 0
        self.stall_timeout = stall_timeout
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        except BaseException as e:  # noqa: BLE001 - forwarded to the consumer
            self._q.put(_ProducerFailure(e))
        else:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        if self._exc is not None:
            raise self._exc  # producer already failed; keep failing
        if self._finished:
            raise StopIteration  # exhaustion is sticky too: no reuse-as-stall
        try:
            item = self._q.get(timeout=self.stall_timeout)
        except queue.Empty:
            if self._last is None:
                item = self._q.get()  # nothing to reuse yet: block
            else:
                self.stalls += 1
                return self._last
        if isinstance(item, _ProducerFailure):
            self._exc = item.exc
            raise self._exc
        if item is self._done:
            self._finished = True
            raise StopIteration
        self._last = item
        return item


def synthetic_token_iter(vocab: int, *, seq_len: int, global_batch: int,
                         accum: int = 1, seed: int = 0, family: str = "dense",
                         cfg=None):
    """Structured synthetic batches for benchmarks and per-arch smoke runs."""
    rng = np.random.default_rng(seed)
    mb = global_batch // accum
    while True:
        t = rng.integers(3, vocab, (accum, mb, 1), dtype=np.int64)
        seqs = [t]
        for _ in range(seq_len - 1):
            seqs.append((seqs[-1] * 31 + 7) % (vocab - 3) + 3)
        batch = {"tokens": np.concatenate(seqs, -1).astype(np.int32)}
        if cfg is not None and cfg.family == "encdec":
            batch["frames"] = rng.normal(
                0, 1, (accum, mb, seq_len // cfg.frontend_downsample,
                       cfg.frontend_dim or cfg.d_model)
            ).astype(np.float32)
        if cfg is not None and cfg.family == "vlm":
            batch["tokens"] = batch["tokens"][..., : seq_len - cfg.vision_tokens]
            batch["patches"] = rng.normal(
                0, 1, (accum, mb, cfg.vision_tokens, cfg.frontend_dim)
            ).astype(np.float32)
        yield batch

"""Synthetic analogs of the paper's four evaluation datasets (Table 1).

The real datasets (Porto Taxi, TIGER roads, MSBuildings, eBird) live on
UCR-Star and are not downloadable offline; these generators match their
*structure* (geometry type, clustering, point counts per geometry, GPS-like
coordinate precision) at configurable scale. All generators emit the ragged
fast path (:func:`repro.core.columnar.from_ragged`) — no per-record loops.
"""

from __future__ import annotations

import numpy as np

from repro.core.columnar import GeometryColumns, from_ragged
from repro.core.geometry import (
    TYPE_MULTILINESTRING,
    TYPE_MULTIPOINT,
    TYPE_POINT,
    TYPE_POLYGON,
)

# Porto-ish / continental bounding boxes for realism
PORTO_BBOX = (-8.70, 41.10, -8.50, 41.25)
US_BBOX = (-124.0, 25.0, -67.0, 49.0)


def _round_gps(a: np.ndarray, decimals: int = 6) -> np.ndarray:
    return np.round(a, decimals)


def porto_taxi_like(n_traj: int = 20_000, mean_pts: int = 48, seed: int = 0) -> GeometryColumns:
    """MultiPoint trajectories: random-walk GPS traces inside Porto (PT)."""
    rng = np.random.default_rng(seed)
    npts = rng.poisson(mean_pts, n_traj).clip(2, 4 * mean_pts)
    total = int(npts.sum())
    x0 = rng.uniform(PORTO_BBOX[0], PORTO_BBOX[2], n_traj)
    y0 = rng.uniform(PORTO_BBOX[1], PORTO_BBOX[3], n_traj)
    # ~15 m GPS steps at ~1e-4 degrees
    steps = rng.normal(0, 1.5e-4, (total, 2))
    traj_id = np.repeat(np.arange(n_traj), npts)
    first = np.concatenate([[0], np.cumsum(npts)[:-1]])
    steps[first] = 0.0
    walk = np.cumsum(steps, axis=0)
    walk -= np.repeat(walk[first], npts, axis=0)
    coords = np.stack([x0[traj_id], y0[traj_id]], 1) + walk
    coords = _round_gps(coords)
    # MultiPoint: one part per point (paper §2.4)
    return from_ragged(
        np.full(n_traj, TYPE_MULTIPOINT, np.uint8),
        coords,
        np.ones(total, np.int64),
        npts.astype(np.int64),
    )


def roads_like(n_roads: int = 50_000, mean_pts: int = 18, seed: int = 1) -> GeometryColumns:
    """MultiLineString road segments across a US-like extent (TR)."""
    rng = np.random.default_rng(seed)
    lines_per = rng.integers(1, 4, n_roads)
    n_lines = int(lines_per.sum())
    pts_per_line = rng.poisson(mean_pts, n_lines).clip(2, 4 * mean_pts)
    total = int(pts_per_line.sum())
    # cluster roads around towns
    towns = np.stack(
        [rng.uniform(US_BBOX[0], US_BBOX[2], 400), rng.uniform(US_BBOX[1], US_BBOX[3], 400)], 1
    )
    line_town = rng.integers(0, len(towns), n_lines)
    start = towns[line_town] + rng.normal(0, 0.05, (n_lines, 2))
    heading = rng.uniform(0, 2 * np.pi, n_lines)
    step = 2e-4  # ~20 m
    line_id = np.repeat(np.arange(n_lines), pts_per_line)
    t = np.concatenate([np.arange(k) for k in pts_per_line])
    wiggle = rng.normal(0, 3e-5, (total, 2))
    coords = start[line_id] + np.stack(
        [np.cos(heading[line_id]) * t * step, np.sin(heading[line_id]) * t * step], 1
    ) + wiggle
    coords = _round_gps(coords)
    return from_ragged(
        np.full(n_roads, TYPE_MULTILINESTRING, np.uint8),
        coords,
        pts_per_line.astype(np.int64),
        lines_per.astype(np.int64),
    )


def buildings_like(n_buildings: int = 100_000, seed: int = 2) -> GeometryColumns:
    """Polygon building footprints: small axis-ish rectangles w/ jitter (MB)."""
    rng = np.random.default_rng(seed)
    towns = np.stack(
        [rng.uniform(US_BBOX[0], US_BBOX[2], 800), rng.uniform(US_BBOX[1], US_BBOX[3], 800)], 1
    )
    center = towns[rng.integers(0, len(towns), n_buildings)] + rng.normal(0, 0.02, (n_buildings, 2))
    w = rng.uniform(5e-5, 3e-4, n_buildings)   # ~5-30 m
    h = rng.uniform(5e-5, 3e-4, n_buildings)
    # 5-point closed CW rings with vertex jitter
    dx = np.stack([-w, w, w, -w, -w], 1) / 2
    dy = np.stack([h, h, -h, -h, h], 1) / 2   # CW order
    xs = center[:, :1] + dx + rng.normal(0, 5e-6, (n_buildings, 5))
    ys = center[:, 1:] + dy + rng.normal(0, 5e-6, (n_buildings, 5))
    xs[:, 4] = xs[:, 0]
    ys[:, 4] = ys[:, 0]
    coords = _round_gps(np.stack([xs.reshape(-1), ys.reshape(-1)], 1))
    return from_ragged(
        np.full(n_buildings, TYPE_POLYGON, np.uint8),
        coords,
        np.full(n_buildings, 5, np.int64),
        np.ones(n_buildings, np.int64),
    )


def ebird_like(n_points: int = 500_000, seed: int = 3, shuffled: bool = True) -> GeometryColumns:
    """Point observations: heavy hotspot clustering, unsorted from source (eB).

    The paper notes eBird is NOT pre-sorted — alternating-sign coordinates
    produce the 64-bit delta spike of Figure 8a. ``shuffled=True`` reproduces
    that; sorting (writer ``sort='hilbert'``) collapses it.
    """
    rng = np.random.default_rng(seed)
    n_hot = 2000
    hots = np.stack(
        [rng.uniform(US_BBOX[0], US_BBOX[2], n_hot), rng.uniform(US_BBOX[1], US_BBOX[3], n_hot)], 1
    )
    weights = rng.pareto(1.2, n_hot) + 1
    weights /= weights.sum()
    hid = rng.choice(n_hot, n_points, p=weights)
    coords = hots[hid] + rng.normal(0, 0.01, (n_points, 2))
    coords = _round_gps(coords)
    if shuffled:
        coords = coords[rng.permutation(n_points)]
    return from_ragged(
        np.full(n_points, TYPE_POINT, np.uint8),
        coords,
        np.ones(n_points, np.int64),
        np.ones(n_points, np.int64),
    )


DATASETS = {
    "PT": porto_taxi_like,
    "TR": roads_like,
    "MB": buildings_like,
    "eB": ebird_like,
}

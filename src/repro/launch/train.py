"""Training CLI: ``python -m repro.launch.train --arch <id> [...]``.

Trains any registry architecture on either the Spatial-Parquet trajectory
pipeline (``--data-dir`` with .spqf files; the paper-integration path) or the
structured synthetic stream. Always checkpoint/restart-safe: on boot it
restores the latest checkpoint if one exists (this is what makes the
supervisor's kill-and-relaunch loop a complete fault-tolerance story).
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import os
import time

import jax


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="spatial-lm")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data-dir", default=None, help="dir of .spqf files (trajectory LM)")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", help="use the smoke-test config")
    ap.add_argument("--heartbeat", default=None)
    ap.add_argument("--fail-at-step", type=int, default=-1, help="fault injection")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data.pipeline import Prefetcher, TrajectoryBatcher, synthetic_token_iter
    from repro.data.tokenizer import GeoTokenizer
    from repro.data.synthetic import PORTO_BBOX
    from repro.launch.mesh import make_host_mesh
    from repro.train.checkpoint import CheckpointManager
    from repro.train.optimizer import OptConfig
    from repro.train.train_loop import run_train_loop

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    oc = OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                   total_steps=args.steps, kind=args.optimizer)

    accum = max(cfg.grad_accum, 1)
    if args.global_batch % accum:
        accum = 1
    if args.data_dir:
        files = sorted(glob.glob(os.path.join(args.data_dir, "*.spqf")))
        assert files, f"no .spqf files in {args.data_dir}"
        tok = GeoTokenizer(PORTO_BBOX, order=6)
        cfg = dataclasses.replace(cfg, vocab=max(cfg.vocab, tok.vocab))
        data = Prefetcher(TrajectoryBatcher(
            files, tok, seq_len=args.seq, global_batch=args.global_batch, accum=accum))
    else:
        data = Prefetcher(synthetic_token_iter(
            cfg.vocab, seq_len=args.seq, global_batch=args.global_batch,
            accum=accum, cfg=cfg))
    cfg = dataclasses.replace(cfg, grad_accum=accum)

    mgr = CheckpointManager(args.ckpt_dir, compress=True, keep=3)

    # fault injection is once-only (a transient fault, not a deterministic
    # crash loop): a marker in the ckpt dir disarms it after the first hit
    fail_at = args.fail_at_step
    marker = os.path.join(args.ckpt_dir, ".fault_injected")
    if fail_at >= 0:
        if os.path.exists(marker):
            fail_at = -1
        else:
            os.makedirs(args.ckpt_dir, exist_ok=True)
            with open(marker, "w") as fh:
                fh.write("armed")

    def heartbeat(step):
        if args.heartbeat:
            with open(args.heartbeat, "w") as fh:
                fh.write(str(step))

    t0 = time.time()
    state, history = run_train_loop(
        cfg, mesh, oc, iter(data),
        global_batch=args.global_batch, seq=args.seq, steps=args.steps,
        checkpoint_mgr=mgr, checkpoint_every=args.ckpt_every,
        resume=not args.no_resume, heartbeat=heartbeat,
        fail_at_step=fail_at,
    )
    mgr.wait()
    print(f"[train] done: {args.steps} steps in {time.time()-t0:.1f}s; "
          f"final loss {history[-1]['loss']:.4f}" if history else "[train] done")


if __name__ == "__main__":
    main()

"""Fold results/dryrun/*.json into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report [--tag TAG] [--diff TAG2]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")
ARCH_ORDER = [
    "whisper-medium", "minicpm3-4b", "granite-20b", "qwen3-8b", "internlm2-1.8b",
    "zamba2-1.2b", "arctic-480b", "qwen2-moe-a2.7b", "mamba2-130m", "pixtral-12b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(tag: str = "") -> dict:
    recs = {}
    suffix = f"_{tag}" if tag else ""
    for f in glob.glob(os.path.join(RESULTS_DIR, f"*{suffix}.json")):
        base = os.path.basename(f)[: -len(".json")]
        if tag:
            if not base.endswith(suffix):
                continue
            base = base[: -len(suffix)]
        elif base.count("__") != 2:
            continue
        arch, shape, pod = base.split("__")
        recs[(arch, shape, pod)] = json.load(open(f))
    return recs


def fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def fmt_ms(s):
    return f"{1e3 * s:.2f}" if s is not None else "-"


def roofline_table(recs, pod="pod1") -> list[str]:
    out = [
        "| arch | shape | fits? peak HBM/chip | compute ms | memory ms | collective ms | dominant | roofline frac | MODEL/HLO flops |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, pod))
            if r is None:
                continue
            if r.get("status") == "skipped":
                out.append(f"| {arch} | {shape} | skipped: {r['reason'][:40]}... | | | | | | |")
                continue
            if r.get("status") != "ok":
                out.append(f"| {arch} | {shape} | ERROR | | | | | | |")
                continue
            mem = r.get("memory", {})
            peak = mem.get("peak_hbm_bytes")
            fits = "Y" if (peak or 0) <= 16 * 2**30 else "OVER"
            t = r.get("roofline", {})
            out.append(
                f"| {arch} | {shape} | {fits} {fmt_bytes(peak)} "
                f"| {fmt_ms(t.get('compute_s'))} | {fmt_ms(t.get('memory_s'))} "
                f"| {fmt_ms(t.get('collective_s'))} | {t.get('dominant','-')} "
                f"| {t.get('roofline_fraction', 0):.3f} "
                f"| {r.get('useful_flops_ratio', 0):.2f} |"
            )
    return out


def multipod_table(recs) -> list[str]:
    out = [
        "| arch | shape | pod2 compile | peak HBM/chip | collectives |",
        "|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "pod2"))
            if r is None:
                continue
            if r.get("status") == "skipped":
                continue
            if r.get("status") != "ok":
                out.append(f"| {arch} | {shape} | ERROR | | |")
                continue
            mem = r.get("memory", {})
            coll = ", ".join(f"{k}x{v['count']}" for k, v in r.get("collectives", {}).items()) or "(in scan bodies)"
            out.append(
                f"| {arch} | {shape} | ok ({r.get('compile_s', 0):.0f}s) "
                f"| {fmt_bytes(mem.get('peak_hbm_bytes'))} | {coll} |"
            )
    return out


def diff_table(base: dict, new: dict, cells: list[tuple[str, str]]) -> list[str]:
    out = [
        "| cell | term | before | after | delta |",
        "|---|---|---|---|---|",
    ]
    for arch, shape in cells:
        b = base.get((arch, shape, "pod1"), {})
        n = new.get((arch, shape, "pod1"), {})
        for term in ("compute_s", "memory_s", "collective_s"):
            tb = b.get("roofline", {}).get(term)
            tn = n.get("roofline", {}).get(term)
            if tb is None or tn is None:
                continue
            delta = (tn - tb) / tb * 100 if tb else 0.0
            out.append(f"| {arch}/{shape} | {term[:-2]} | {fmt_ms(tb)}ms | {fmt_ms(tn)}ms | {delta:+.1f}% |")
        pb = b.get("memory", {}).get("peak_hbm_bytes")
        pn = n.get("memory", {}).get("peak_hbm_bytes")
        if pb and pn:
            out.append(f"| {arch}/{shape} | peak HBM | {fmt_bytes(pb)} | {fmt_bytes(pn)} | {(pn-pb)/pb*100:+.1f}% |")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--diff", default=None, help="second tag to diff against --tag")
    args = ap.parse_args()
    recs = load(args.tag)
    print(f"# Roofline (single-pod 16x16, {len(recs)} cells loaded, tag={args.tag or 'baseline'})\n")
    print("\n".join(roofline_table(recs)))
    print("\n# Multi-pod (2x16x16) compile matrix\n")
    print("\n".join(multipod_table(recs)))
    if args.diff is not None:
        new = load(args.diff)
        cells = sorted({(a, s) for (a, s, p) in new if p == "pod1"})
        print(f"\n# Diff {args.tag or 'baseline'} -> {args.diff}\n")
        print("\n".join(diff_table(recs, new, cells)))


if __name__ == "__main__":
    main()

"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch, shape) on the single-pod mesh (v5e constants):

    compute    = corrected_HLO_FLOPs_per_chip / 197e12      [bf16 peak]
    memory     = corrected_HLO_bytes_per_chip / 819e9       [HBM bw]
    collective = per_chip_ring_bytes / 50e9                 [ICI link bw]

Two methodology notes (both discovered by calibration, see EXPERIMENTS.md):

* XLA ``cost_analysis`` counts a ``while``-loop (lax.scan) body ONCE,
  ignoring the trip count. Totals are therefore corrected from *unrolled
  calibration lowerings* at small layer counts: with per-period cost ``g``
  and outside-stack cost ``o`` measured from two unrolled compiles,
  ``total = o + (L // p) * g + (L % p) * m`` (p = hybrid period or 1,
  m = single-layer cost).
* Collective bytes are not in cost_analysis. We parse the post-SPMD HLO
  (``compiled.as_text()``), resolve operand shapes through a symbol table,
  and model per-chip ICI traffic with ring algorithms over the collective's
  group size g: all-reduce 2*S*(g-1)/g, all-gather/reduce-scatter/all-to-all
  S*(g-1)/g, collective-permute S. The raw operand-byte sum is also kept.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

# TPU v5e hardware model (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# name = <type> opcode(...): lazy type group + mandatory space keeps
# hyphenated opcodes (all-reduce, all-gather, ...) intact. (v2: the v1
# greedy character-class regex captured "-reduce" as the opcode and missed
# ~70% of collectives — see EXPERIMENTS.md §Roofline metric notes.)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Returns {opcode: {count, ring_bytes, raw_bytes}} per-chip."""
    # symbol table: name -> output bytes
    sym: dict[str, int] = {}
    out: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        nbytes = _shape_bytes(type_str)
        sym[name] = nbytes
        base = None
        for c in COLLECTIVES:
            if opcode == c or opcode.startswith(c + "-start") or opcode == c + "-start":
                base = c
                break
        if base is None:
            continue
        # group size
        g = 0
        mg = _GROUPS_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                g = int(mi.group(2))
        g = max(g, 2)
        s_out = nbytes
        # operand bytes (resolve via symbol table)
        opnd = 0
        args = line.split("(", 1)[1].split(")", 1)[0]
        for tok in args.split(","):
            tok = tok.strip().lstrip("%")
            opnd += sym.get(tok, 0)
        raw = opnd or s_out
        if base == "all-reduce":
            ring = 2 * s_out * (g - 1) / g
        elif base == "all-gather":
            ring = s_out * (g - 1) / g
        elif base == "reduce-scatter":
            ring = raw * (g - 1) / g
        elif base == "all-to-all":
            ring = max(raw, s_out) * (g - 1) / g
        else:  # collective-permute
            ring = s_out
        rec = out.setdefault(base, {"count": 0, "ring_bytes": 0.0, "raw_bytes": 0.0})
        rec["count"] += 1
        rec["ring_bytes"] += ring
        rec["raw_bytes"] += float(raw)
    return out


def cost_metrics(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_metrics(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_hbm_bytes": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        ),
    }


@dataclass
class Corrected:
    flops: float
    bytes: float
    coll_ring: float
    coll_raw: float


def correct_with_calibration(period_metrics: dict, layer_metrics: dict | None,
                             outside_base: dict, n_layers: int, period: int) -> Corrected:
    """total = outside + (L // p) * group + (L % p) * layer."""
    reps, rem = divmod(n_layers, period)

    def total(key):
        g = period_metrics[key]
        m = layer_metrics[key] if layer_metrics else 0.0
        o = outside_base[key]
        return o + reps * g + rem * m

    return Corrected(
        flops=total("flops"), bytes=total("bytes"),
        coll_ring=total("coll_ring"), coll_raw=total("coll_raw"),
    )


def roofline_terms(flops: float, bytes_: float, coll_ring: float) -> dict:
    t_c = flops / PEAK_FLOPS_BF16
    t_m = bytes_ / HBM_BW
    t_x = coll_ring / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    bound = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "bound_s": bound,
        "roofline_fraction": (t_c / bound) if bound > 0 else 0.0,
    }


# --------------------------------------------------------- analytic FLOPs
def count_params(cfg, active_only: bool = False) -> float:
    """Parameter count (non-embedding by convention for 6ND).

    ``active_only`` gives the *execution-weighted* count used for
    MODEL_FLOPS: MoE experts at top_k of n_experts; the zamba2 shared block
    at n_sites executions (stored once, run L/p times)."""
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    hd = cfg.resolved_head_dim
    per_layer = 0.0
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        din = s.expand * d
        h = din // s.headdim
        per_layer = d * din * 2 + d * s.d_state * 2 + d * h + din * d
        total = per_layer * L
        if cfg.family == "hybrid":
            n_sites = L // cfg.hybrid_attn_every
            attn = d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2 + 3 * d * ff
            total += attn * (n_sites if active_only else 1)
        return float(total)
    elif cfg.mla is not None:
        m = cfg.mla
        qk = m.nope_head_dim + m.rope_head_dim
        per_layer = (
            d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk
            + d * (m.kv_lora_rank + m.rope_head_dim)
            + m.kv_lora_rank * cfg.n_heads * (m.nope_head_dim + m.v_head_dim)
            + cfg.n_heads * m.v_head_dim * d + 3 * d * ff
        )
    elif cfg.family == "moe":
        moe = cfg.moe
        attn = d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2
        e_used = moe.top_k if active_only else moe.n_experts
        experts = e_used * 3 * d * moe.d_expert
        shared = moe.n_shared * 3 * d * moe.d_expert
        dense = 3 * d * moe.dense_ff_parallel
        router = d * moe.n_experts
        per_layer = attn + experts + shared + dense + router
    else:
        attn = d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2
        per_layer = attn + 3 * d * ff
    total = per_layer * L
    if cfg.family == "encdec":
        enc_layer = d * cfg.n_heads * hd * 4 + 3 * d * ff
        cross = d * cfg.n_heads * hd * 4
        total += enc_layer * cfg.n_encoder_layers + cross * cfg.n_layers
    return float(total)


def model_flops(cfg, shape) -> float:
    """Global MODEL_FLOPS for the cell: 6*N_active*D train, 2*N_active*D
    prefill, 2*N_active*B decode-step."""
    n_act = count_params(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch  # one decode token per sequence

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent at 512 chips.

For every (architecture x input shape) cell this lowers + compiles the
appropriate step — ``train_step`` (fwd+bwd+AdamW) for train_4k,
``prefill_step`` (forward_with_cache) for prefill_32k, ``serve_step``
(one-token decode against a seq_len cache) for decode shapes — on

* the single-pod production mesh (16, 16) axes (data, model), and
* the multi-pod mesh (2, 16, 16) axes (pod, data, model),

prints ``compiled.memory_analysis()`` / ``cost_analysis()``, parses the
post-SPMD HLO collective schedule, and (single-pod only) runs the unrolled
calibration lowerings that feed §Roofline (see roofline.py for why).

Results cache as JSON under results/dryrun/; ``--all`` sweeps every runnable
cell in per-cell subprocesses (isolation: one cell OOM/crash cannot kill the
sweep, and jit caches do not accumulate).

NOTE: the XLA_FLAGS line above MUST run before any jax import — jax locks
the device count on first init. Only this entry point forces 512 host
devices; tests and benches see the real device count.
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import ASSIGNED, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    Corrected,
    correct_with_calibration,
    cost_metrics,
    memory_metrics,
    model_flops,
    parse_collectives,
    roofline_terms,
)
from repro.train.optimizer import OptConfig
from repro.train.train_loop import make_prefill_step, make_serve_step, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def input_specs(cfg, shape, kind: str | None = None, mesh=None):
    """ShapeDtypeStruct stand-ins for every model input of a cell (the
    assignment's §2 contract: weak-type-correct, shardable, no allocation).

    When ``mesh`` is given, the train microbatch layout matches the clamped
    grad-accumulation the step factory will use on that mesh.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.models.model import build_model
    from repro.sharding.specs import batch_axes
    from repro.train.optimizer import opt_init
    from repro.train.train_loop import _batch_struct

    kind = kind or shape.kind
    model = build_model(cfg)
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if kind == "train":
        accum = max(cfg.grad_accum, 1)
        if mesh is not None:
            dp = batch_axes(mesh) or ()
            dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
            accum = max(1, min(accum, max(shape.global_batch // max(dp_size, 1), 1)))
            while shape.global_batch % accum or (shape.global_batch // accum) % dp_size:
                accum -= 1
                if accum == 1:
                    break
        oshape = jax.eval_shape(lambda p: opt_init(OptConfig(), p, cfg.opt_state_dtype), pshape)
        bstruct = _batch_struct(cfg, (shape.global_batch, shape.seq_len), accum)
        return {"params": pshape, "opt_state": oshape, "batch": bstruct}
    if kind == "prefill":
        bstruct = _batch_struct(cfg, (shape.global_batch, shape.seq_len), 1)
        bstruct = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), bstruct)
        cshape = jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len))
        return {"params": pshape, "batch": bstruct, "cache": cshape}
    cshape = jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len))
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return {"params": pshape, "tokens": tok, "cache": cshape}


def lower_and_compile(cfg, shape, mesh, *, verbose=True):
    """Lower + compile one cell; returns (compiled, fallbacks, secs).

    Lowering happens under ``with mesh:`` so mesh-contextual sharding
    constraints (e.g. the MoE EP steering in repro.models.moe) resolve."""
    t0 = time.time()
    specs = input_specs(cfg, shape, mesh=mesh)
    with mesh:
        return _lower_inner(cfg, shape, mesh, specs, t0, verbose)


def _lower_inner(cfg, shape, mesh, specs, t0, verbose):
    if shape.kind == "train":
        step_fn, _, _, bstruct, _, fb = make_train_step(
            cfg, mesh, OptConfig(), shape.global_batch, shape.seq_len
        )
        lowered = step_fn.lower(specs["params"], specs["opt_state"], bstruct)
    elif shape.kind == "prefill":
        step_fn, _, bstruct, _, cshape, _, fb = make_prefill_step(
            cfg, mesh, shape.global_batch, shape.seq_len
        )
        lowered = step_fn.lower(specs["params"], bstruct, cshape)
    else:
        step_fn, _, cshape, _, _, fb = make_serve_step(
            cfg, mesh, shape.global_batch, shape.seq_len
        )
        lowered = step_fn.lower(specs["params"], specs["tokens"], cshape)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    if verbose:
        print(f"    lowered {t_lower:.1f}s, compiled {t_compile:.1f}s")
    return compiled, fb, t_lower + t_compile


def _calib_cfg(cfg, n_layers: int):
    """Unrolled small-depth variant for calibration (same dims/shape)."""
    changes = dict(n_layers=n_layers, unroll_layers=True, grad_accum=1)
    if cfg.family == "encdec":
        changes["n_encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **changes)


def _calib_metrics(cfg, shape, mesh) -> dict:
    compiled, _, secs = lower_and_compile(cfg, shape, mesh, verbose=False)
    cm = cost_metrics(compiled)
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": cm["flops"],
        "bytes": cm["bytes"],
        "coll_ring": sum(c["ring_bytes"] for c in coll.values()),
        "coll_raw": sum(c["raw_bytes"] for c in coll.values()),
        "secs": secs,
    }


def calibrate(cfg, shape, mesh) -> tuple[Corrected, dict]:
    """Unrolled L-sweep -> corrected per-chip totals (see roofline.py)."""
    period = cfg.hybrid_attn_every if cfg.family == "hybrid" else 1
    f_p = _calib_metrics(_calib_cfg(cfg, period), shape, mesh)
    f_2p = _calib_metrics(_calib_cfg(cfg, 2 * period), shape, mesh)
    group = {k: f_2p[k] - f_p[k] for k in ("flops", "bytes", "coll_ring", "coll_raw")}
    outside = {k: f_p[k] - group[k] for k in group}
    layer = None
    if period > 1 and cfg.n_layers % period:
        f_p1 = _calib_metrics(_calib_cfg(cfg, period + 1), shape, mesh)
        layer = {k: f_p1[k] - f_p[k] for k in group}
    corrected = correct_with_calibration(group, layer, outside, cfg.n_layers, period)
    detail = {"per_period": group, "outside": outside, "per_layer_rem": layer}
    return corrected, detail


def apply_overrides(cfg, overrides: list[str]):
    """--set key=value config overrides (perf iterations); nested keys use
    'ssm.chunk=64' style paths into sub-configs."""
    for ov in overrides:
        key, _, raw = ov.partition("=")
        if "." in key:
            sub_name, field = key.split(".", 1)
            sub = getattr(cfg, sub_name)
            cur = getattr(sub, field)
            val = type(cur)(raw) if not isinstance(cur, bool) else raw.lower() in ("1", "true")
            cfg = dataclasses.replace(cfg, **{sub_name: dataclasses.replace(sub, **{field: val})})
        else:
            cur = getattr(cfg, key)
            if isinstance(cur, bool):
                val = raw.lower() in ("1", "true")
            elif cur is None:
                val = raw
            else:
                val = type(cur)(raw)
            cfg = dataclasses.replace(cfg, **{key: val})
    return cfg


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, do_calibrate: bool = True,
             overrides: list[str] | None = None) -> dict:
    cfg = apply_overrides(get_config(arch), overrides or [])
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    print(f"[dryrun] {arch} x {shape_name} mesh={dict(mesh.shape)} ({n_chips} chips)")
    rec: dict = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                 "chips": n_chips, "status": "ok", "overrides": overrides or []}
    compiled, fallbacks, secs = lower_and_compile(cfg, shape, mesh)
    rec["compile_s"] = secs
    rec["sharding_fallbacks"] = fallbacks
    mem = memory_metrics(compiled)
    print(f"    memory_analysis: {mem}")
    rec["memory"] = mem
    cm = cost_metrics(compiled)
    rec["cost_raw"] = cm
    coll = parse_collectives(compiled.as_text())
    rec["collectives"] = coll
    print(f"    collectives: { {k: v['count'] for k, v in coll.items()} }")
    if do_calibrate and not multi_pod:
        corrected, detail = calibrate(cfg, shape, mesh)
        rec["corrected"] = dataclasses.asdict(corrected)
        rec["calibration"] = detail
        terms = roofline_terms(corrected.flops, corrected.bytes, corrected.coll_ring)
        rec["roofline"] = terms
        mf = model_flops(cfg, shape)
        rec["model_flops_global"] = mf
        rec["model_flops_per_chip"] = mf / n_chips
        rec["useful_flops_ratio"] = (mf / n_chips) / corrected.flops if corrected.flops else 0.0
        print(f"    roofline: compute={terms['compute_s']*1e3:.2f}ms "
              f"memory={terms['memory_s']*1e3:.2f}ms "
              f"collective={terms['collective_s']*1e3:.2f}ms "
              f"dominant={terms['dominant']} frac={terms['roofline_fraction']:.2f} "
              f"useful={rec['useful_flops_ratio']:.2f}")
    return rec


def cell_path(arch, shape_name, multi_pod, tag=""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    pod = "pod2" if multi_pod else "pod1"
    suffix = f"_{tag}" if tag else ""
    return os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{pod}{suffix}.json")


def runnable_cells():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            yield arch, shape_name, shape_applicable(cfg, shape)[0]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="sweep all cells (subprocess per cell)")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--tag", default="", help="results filename tag (perf iterations)")
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="config override key=value (repeatable)")
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch, shape_name, ok in runnable_cells():
            for mp in (False, True):
                path = cell_path(arch, shape_name, mp, args.tag)
                if os.path.exists(path) and not args.force:
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name]
                cmd.append("--multi-pod" if mp else "--single-pod")
                if args.no_calibrate:
                    cmd.append("--no-calibrate")
                if args.tag:
                    cmd += ["--tag", args.tag]
                for ov in args.overrides:
                    cmd += ["--set", ov]
                print(f"=== {arch} x {shape_name} {'pod2' if mp else 'pod1'} ===", flush=True)
                r = subprocess.run(cmd, cwd=os.getcwd())
                if r.returncode != 0:
                    failures.append((arch, shape_name, mp))
                    with open(path, "w") as fh:
                        json.dump({"arch": arch, "shape": shape_name, "multi_pod": mp,
                                   "status": "error", "returncode": r.returncode}, fh)
        print(f"sweep done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    mp = bool(args.multi_pod)
    try:
        rec = run_cell(args.arch, args.shape, multi_pod=mp,
                       do_calibrate=not args.no_calibrate,
                       overrides=args.overrides)
    except Exception:
        traceback.print_exc()
        rec = {"arch": args.arch, "shape": args.shape, "multi_pod": mp,
               "status": "error", "traceback": traceback.format_exc()}
        with open(cell_path(args.arch, args.shape, mp, args.tag), "w") as fh:
            json.dump(rec, fh, indent=1)
        sys.exit(1)
    with open(cell_path(args.arch, args.shape, mp, args.tag), "w") as fh:
        json.dump(rec, fh, indent=1)
    print(f"[dryrun] saved {cell_path(args.arch, args.shape, mp, args.tag)}")


if __name__ == "__main__":
    main()

"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) — the 'pod' axis
carries cross-pod DP (or FSDP for the pod-FSDP configs); 'data' carries
in-pod DP/FSDP; 'model' carries TP/EP.
"""

from __future__ import annotations

import jax


def _mk_mesh(shape, axes):
    # jax < 0.5 has no sharding.AxisType; Auto axes are then the default
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return _mk_mesh((data, model), ("data", "model"))

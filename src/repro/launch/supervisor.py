"""Fault-tolerant training supervisor.

On a real 1000-node cluster every worker runs under a supervisor that (a)
restarts crashed trainers from the latest checkpoint, (b) detects hangs via a
heartbeat file (stragglers/network partitions look like silence, not crashes),
and (c) bounds restart storms with a budget. This module implements that
control loop for the single-host container; the trainer process is the same
``repro.launch.train`` that would run per-host under multi-controller JAX
(jax.distributed.initialize with coordinator address per pod — see README
"Scaling out").

Fault injection for drills/tests: ``--fail-at-step N`` makes the trainer
raise mid-run; the supervisor must resume it to completion.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


class Supervisor:
    def __init__(self, cmd: list[str], *, heartbeat_path: str,
                 hang_timeout: float = 600.0, max_restarts: int = 5,
                 poll_s: float = 1.0):
        self.cmd = cmd
        self.heartbeat_path = heartbeat_path
        self.hang_timeout = hang_timeout
        self.max_restarts = max_restarts
        self.poll_s = poll_s
        self.restarts = 0
        self.events: list[str] = []

    def _heartbeat_age(self) -> float:
        try:
            return time.time() - os.path.getmtime(self.heartbeat_path)
        except OSError:
            return 0.0

    def run(self) -> int:
        while True:
            self.events.append(f"launch attempt {self.restarts + 1}")
            proc = subprocess.Popen(self.cmd)
            rc = None
            while rc is None:
                time.sleep(self.poll_s)
                rc = proc.poll()
                if rc is None and self._heartbeat_age() > self.hang_timeout:
                    self.events.append("hang detected (heartbeat stale); killing")
                    proc.kill()
                    proc.wait()
                    rc = -9
            if rc == 0:
                self.events.append("trainer exited cleanly")
                return 0
            self.restarts += 1
            self.events.append(f"trainer died rc={rc}; restart {self.restarts}")
            if self.restarts > self.max_restarts:
                self.events.append("restart budget exhausted")
                return rc
            # resume comes free: the trainer always restores latest checkpoint


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hang-timeout", type=float, default=600.0)
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("--heartbeat", default="/tmp/repro_heartbeat")
    ap.add_argument("trainer_args", nargs=argparse.REMAINDER,
                    help="-- args passed to repro.launch.train")
    args = ap.parse_args()
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--heartbeat", args.heartbeat] + [a for a in args.trainer_args if a != "--"]
    sup = Supervisor(cmd, heartbeat_path=args.heartbeat,
                     hang_timeout=args.hang_timeout, max_restarts=args.max_restarts)
    rc = sup.run()
    for e in sup.events:
        print(f"[supervisor] {e}")
    sys.exit(rc)


if __name__ == "__main__":
    main()

"""Quickstart: write a Spatial Parquet file, read it back, run range queries.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    Geometry,
    SpatialParquetReader,
    SpatialParquetWriter,
)
from repro.core.pages import best_codec


def main():
    rng = np.random.default_rng(0)

    # 1. Build some geometries: a point-of-interest layer + a few polygons
    pois = [Geometry.point(*p) for p in rng.uniform(-10, 10, (50_000, 2))]
    shell = np.array([[0, 0], [0, 2], [2, 2], [2, 0], [0, 0]], float)
    parks = [Geometry.polygon(shell + rng.uniform(-10, 8, 2)) for _ in range(500)]

    path = os.path.join(tempfile.gettempdir(), "quickstart.spqf")

    # 2. Write: FP-delta encoding + Hilbert sort + zstd pages + timestamps
    with SpatialParquetWriter(
        path, encoding="fp_delta", codec=best_codec(), sort="hilbert",
        page_values=8192, extra_schema={"ts": "<i8"},
    ) as w:
        w.write_geometries(pois, extra={"ts": np.arange(len(pois))})
        w.write_geometries(parks, extra={"ts": np.arange(len(parks))})
    print(f"wrote {path}: {os.path.getsize(path)/1e6:.2f} MB "
          f"({(len(pois)+5*len(parks))*16/1e6:.2f} MB of raw coordinates)")

    # 3. Read back with a range filter — the light-weight index prunes pages
    with SpatialParquetReader(path) as r:
        print(f"file holds {r.n_records} records, {len(r.index)} pages")
        query = (-2.0, -2.0, 2.0, 2.0)
        geoms, stats = r.read(bbox=query, refine=True)
        print(f"range query {query}: {len(geoms)} records, "
              f"read {stats.pages_read}/{stats.pages_total} pages "
              f"({stats.bytes_read/1e3:.0f} of {stats.bytes_total/1e3:.0f} KB)")

        # columnar fast path (no Geometry objects): raw coordinate arrays
        cols, extras, stats = r.read_columnar(bbox=query)
        print(f"columnar: {cols.n_values} coordinates, "
              f"ts column range {extras['ts'].min()}..{extras['ts'].max()}")

    os.unlink(path)


if __name__ == "__main__":
    main()

"""End-to-end driver: geospatial data lake -> train a trajectory LM.

Builds a Porto-taxi-like Spatial Parquet data lake, then trains the
``spatial-lm`` Mamba2 architecture on tokenized GPS trajectories with
checkpointing — the paper's format feeding the framework's training loop.

    PYTHONPATH=src python examples/train_trajectory_lm.py \
        --steps 200 --n-traj 4000 [--arch spatial-lm] [--full-size]

``--full-size`` trains the ~100M-parameter variant (slow on CPU; the default
is a CPU-friendly model with identical plumbing).
"""

import argparse
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--n-traj", type=int, default=3000)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--arch", default="spatial-lm")
    ap.add_argument("--full-size", action="store_true",
                    help="~100M params (12L/768d) instead of the CPU-friendly size")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    import numpy as np

    from repro.configs import get_config
    from repro.core.pages import best_codec
    from repro.core.writer import write_file
    from repro.data.pipeline import Prefetcher, TrajectoryBatcher
    from repro.data.synthetic import PORTO_BBOX, porto_taxi_like
    from repro.data.tokenizer import GeoTokenizer
    from repro.launch.mesh import make_host_mesh
    from repro.train.checkpoint import CheckpointManager
    from repro.train.optimizer import OptConfig
    from repro.train.train_loop import run_train_loop

    # ---- 1. build the data lake (two Spatial Parquet shards)
    lake = tempfile.mkdtemp(prefix="geolake_")
    files = []
    for shard in range(2):
        cols = porto_taxi_like(n_traj=args.n_traj // 2, seed=shard)
        p = os.path.join(lake, f"porto_{shard}.spqf")
        write_file(p, columns=cols, sort="hilbert", codec=best_codec())
        files.append(p)
    lake_mb = sum(os.path.getsize(p) for p in files) / 1e6
    print(f"[lake] {len(files)} shards, {lake_mb:.1f} MB at {lake}")

    # ---- 2. tokenizer + pipeline
    tok = GeoTokenizer(PORTO_BBOX, order=6)
    cfg = get_config(args.arch)
    if args.full_size:
        cfg = dataclasses.replace(cfg, n_layers=12, d_model=768)
    cfg = dataclasses.replace(cfg, vocab=tok.vocab)
    data = Prefetcher(TrajectoryBatcher(
        files, tok, seq_len=args.seq, global_batch=args.global_batch))

    # ---- 3. train with checkpoint/restart
    mesh = make_host_mesh(1, 1)
    oc = OptConfig(lr=3e-3, warmup_steps=max(args.steps // 10, 1),
                   total_steps=args.steps)
    ckpt_dir = args.ckpt_dir or os.path.join(lake, "ckpt")
    mgr = CheckpointManager(ckpt_dir, compress=True, keep=2)
    state, history = run_train_loop(
        cfg, mesh, oc, iter(data), global_batch=args.global_batch,
        seq=args.seq, steps=args.steps, checkpoint_mgr=mgr,
        checkpoint_every=max(args.steps // 3, 1), log_every=10,
    )
    mgr.wait()
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"[train] loss {first:.3f} -> {last:.3f} over {args.steps} steps")
    print(f"[ckpt] compression ratio {mgr.last_stats.ratio:.2f}x "
          f"({mgr.last_stats.stored_bytes/1e6:.1f} MB stored)")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()

"""Batched serving example: trajectory continuation with the wave scheduler.

Loads (or trains briefly) a spatial-lm checkpoint, then serves batched
"continue this trajectory" requests: prompts are tokenized GPS prefixes,
responses decode back to coordinates.

    PYTHONPATH=src python examples/serve_lm.py --requests 8 --max-new 24
"""

import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--warm-steps", type=int, default=40,
                    help="brief training so generations aren't uniform noise")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.data.pipeline import Prefetcher, synthetic_token_iter
    from repro.data.synthetic import PORTO_BBOX, porto_taxi_like
    from repro.data.tokenizer import GeoTokenizer
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import build_model
    from repro.serve.scheduler import BatchedServer
    from repro.train.optimizer import OptConfig
    from repro.train.train_loop import run_train_loop

    tok = GeoTokenizer(PORTO_BBOX, order=6)
    cfg = dataclasses.replace(get_config("spatial-lm"), vocab=tok.vocab)
    model = build_model(cfg)

    # warm the model on real trajectories so next-token mass is spatial
    from repro.data.pipeline import TrajectoryBatcher
    from repro.core.writer import write_file
    import tempfile
    lake = tempfile.mkdtemp()
    p = os.path.join(lake, "traj.spqf")
    write_file(p, columns=porto_taxi_like(1200, seed=3), sort="hilbert")
    data = Prefetcher(TrajectoryBatcher([p], tok, seq_len=96, global_batch=8))
    mesh = make_host_mesh(1, 1)
    oc = OptConfig(lr=3e-3, warmup_steps=4, total_steps=args.warm_steps)
    state, hist = run_train_loop(cfg, mesh, oc, iter(data), global_batch=8,
                                 seq=96, steps=args.warm_steps, log_every=20)
    params = state.params

    # serve batched continuation requests
    srv = BatchedServer(cfg, params, max_batch=args.max_batch, max_len=192)
    cols = porto_taxi_like(args.requests, seed=9)
    mat = tok.encode_trajectories(cols, 64)
    t0 = time.time()
    for i in range(args.requests):
        prompt = mat[i][mat[i] > 0][:16]  # BOS + 15 cells
        srv.submit(prompt, max_new_tokens=args.max_new, rid=i)
    done = srv.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s, batch={args.max_batch})")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        cells = [t for t in r.out_tokens if t >= 3]
        coords = tok.decode_tokens(np.array(cells)) if cells else []
        ttfb = (r.t_first - r.t_submit) * 1e3
        print(f"  req {r.rid}: ttfb {ttfb:.0f}ms, {len(r.out_tokens)} new tokens, "
              f"first coords {np.round(coords[:2], 4).tolist() if len(coords) else '[]'}")


if __name__ == "__main__":
    main()

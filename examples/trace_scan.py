"""Trace a fused device scan: where does a bbox query spend its time?

    PYTHONPATH=src python examples/trace_scan.py

Writes a small sharded dataset, runs one traced fused decode→refine scan on
the accelerator path (``device="jax"``, ``refine=True``), prints the
per-stage wall-clock breakdown and the metrics snapshot highlights, and
emits ``scan_trace.json`` — open it in https://ui.perfetto.dev or
``chrome://tracing`` to see the shard fan-out, per-row-group fetch/plan/
launch spans, and the jit compile-vs-execute split on a timeline.
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import obs
from repro.core.columnar import from_ragged
from repro.dataset import SpatialDatasetScanner, write_dataset


def main():
    rng = np.random.default_rng(0)

    # 1. A small sharded lake: 40k points over 4 shards
    n = 40_000
    pts = np.round(rng.uniform(-100, 100, (n, 2)), 6)
    cols = from_ragged(np.ones(n, np.uint8), pts,
                       np.ones(n, np.int64), np.ones(n, np.int64))
    root = os.path.join(tempfile.mkdtemp(prefix="trace_scan_"), "lake")
    write_dataset(root, columns=cols, n_shards=4, sort="hilbert")
    sc = SpatialDatasetScanner(root, max_workers=4)
    bbox = (-50.0, -50.0, 50.0, 50.0)

    # 2. One untraced warm-up scan compiles the kernels off the clock,
    #    so the trace below shows steady-state stage costs
    sc.scan(bbox=bbox, refine=True, device="jax")

    # 3. The traced scan: same query, same results, full attribution
    tracer = obs.enable()
    geo, _, stats = sc.scan(bbox=bbox, refine=True, device="jax")
    obs.disable()
    print(f"scan: {stats.records_returned}/{stats.records_scanned} records, "
          f"{stats.bytes_read}/{stats.bytes_total} bytes read")

    # 4. Per-stage wall-clock breakdown (nested spans overlap their parents:
    #    this is attribution, not a partition of the total)
    print(f"\n{'stage':<22}{'count':>6}{'total ms':>11}{'max ms':>9}")
    for row in tracer.summary():
        print(f"{row['name']:<22}{row['count']:>6}"
              f"{row['total_ms']:>11.3f}{row['max_ms']:>9.3f}")

    # 5. Metrics snapshot highlights: latency percentiles + derived gauges
    snap = obs.snapshot()
    lat = snap["histograms"]["scan.dataset_latency_s"]
    print(f"\nscan latency: p50={lat['p50'] * 1e3:.2f}ms "
          f"p99={lat['p99'] * 1e3:.2f}ms over {lat['count']} scan(s)")
    print(f"host CPU per scanned GB: "
          f"{snap['gauges']['scan.host_cpu_s_per_gb']:.2f} s/GB")
    for level in ("shard", "page", "record"):
        print(f"bytes pruned at {level} level: "
              f"{snap['counters'].get(f'pruned.{level}_bytes', 0)}")
    print(f"jit: {snap['counters'].get('jit.compiles', 0)} compiles, "
          f"{snap['counters'].get('jit.cache_hits', 0)} cache hits")

    # 6. Export for Perfetto / chrome://tracing
    out = tracer.export("scan_trace.json", metrics=snap)
    print(f"\nwrote {out} — open in https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
